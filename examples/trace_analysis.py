"""Workload analysis: reproduce the paper's motivating observation — long
reuse distances and sparse local recurrence make recency/frequency weak
signals (paper §1, [56]) — on both trace families.

    PYTHONPATH=src python examples/trace_analysis.py
"""
import numpy as np

from repro.core import (OASSTConfig, SynthConfig, hr_full,
                        measured_long_reuse_ratio, oasst_style_trace,
                        synthetic_trace)


def analyze(name, trace, capacity):
    reqs = trace.requests
    last = {}
    dists = []
    for r in reqs:
        if r.cid in last:
            dists.append(r.t - last[r.cid])
        last[r.cid] = r.t
    dists = np.array(dists)
    counts = {}
    for r in reqs:
        counts[r.cid] = counts.get(r.cid, 0) + 1
    singles = sum(1 for v in counts.values() if v == 1)
    print(f"\n[{name}] {len(reqs)} requests, {len(counts)} unique, "
          f"HR_full={hr_full(trace):.3f}")
    print(f"  accessed exactly once: {singles}/{len(counts)} "
          f"({singles / len(counts):.1%})  <- sparse local recurrence")
    if len(dists):
        print(f"  reuse distance: median {int(np.median(dists))}, "
              f"p90 {int(np.percentile(dists, 90))}, "
              f"max {int(dists.max())}")
        print(f"  long-reuse fraction (dist > capacity {capacity}): "
              f"{measured_long_reuse_ratio(trace, capacity):.1%} "
              f"<- beyond any recency window")


syn = synthetic_trace(SynthConfig(trace_len=10_000, seed=0))
analyze("synthetic semi-Markov", syn, int(0.1 * syn.meta["unique"]))
oa = oasst_style_trace(OASSTConfig(trace_len=10_000, seed=0))
analyze("OASST-style dialogue", oa, int(0.1 * oa.meta["unique"]))
