"""End-to-end serving driver (the paper's deployment scenario): a small LM
served with continuous batching behind a RAC-managed semantic cache.

Replays an OASST-style dialogue trace; cache hits return the cached
response with zero model compute, misses generate and admit under RAC
eviction.  Also exercises the RAC-scored KV prefix-block manager.

    PYTHONPATH=src python examples/serve_semantic_cache.py
"""
import time

import numpy as np

from repro.configs import get_config
from repro.core import SynthConfig, synthetic_trace
from repro.models import smoke_variant
from repro.serving import EngineConfig, KVBlockManager, ServingEngine

N_REQUESTS = 300
CAPACITY = 96

mcfg = smoke_variant(get_config("paper"))
# async_admit: completed slots enqueue their admission; a background
# worker pays insert + RAC eviction scoring off the generation loop and
# the engine flushes the queue at batch boundaries (same outputs as
# blocking admission — tests/test_serving.py asserts it)
engine = ServingEngine(mcfg, EngineConfig(cache_capacity=CAPACITY,
                                          max_new_tokens=8, max_batch=8,
                                          max_seq=96, async_admit=True))

# the engine's cache is the unified repro.cache.SemanticCache — observe
# evictions through the event hook surface instead of poking internals
evicted = []
engine.cache.subscribe("evict", lambda ev: evicted.append(ev.cid))

# multi-turn sessions with recurring topic anchors (the paper's workload)
trace = synthetic_trace(SynthConfig(trace_len=N_REQUESTS, n_topics=24,
                                    seed=1))
rng = np.random.default_rng(1)
requests = [(r.cid, r.emb,
             list(rng.integers(2, mcfg.vocab_size, size=6)))
            for r in trace.requests]

t0 = time.perf_counter()
done = engine.run(requests)
dt = time.perf_counter() - t0
s = engine.stats
hr = s["hits"] / max(1, s["hits"] + s["misses"])
print(f"[semantic-cache] {len(done)} requests in {dt:.1f}s")
print(f"  hit_ratio={hr:.3f}  hits={s['hits']}  misses={s['misses']}")
print(f"  generated {s['generated_tokens']} tokens in {s['batches']} "
      f"batched decode steps")
saved = s["hits"] * 8
print(f"  generation saved by the cache ≈ {saved} tokens "
      f"({saved / max(1, saved + s['generated_tokens']):.1%})")
m = engine.cache.metrics
print(f"  cache: {m.evictions} evictions ({len(evicted)} seen by hook), "
      f"lookup {1e3 * m.lookup_s:.1f} ms total / "
      f"{1e6 * m.lookup_s / max(1, m.lookups):.0f} us per op")
adm = engine.cache.admitter
print(f"  async admission: slot stall {1e3 * adm.enqueue_s:.2f} ms "
      f"(enqueue only), flush waits {1e3 * adm.flush_s:.2f} ms, "
      f"{adm.applied} applied in background")
engine.close()

# --- KV prefix-block reuse under RAC scoring --------------------------
# the block manager rides the SAME facade (content mode + RadixRAC):
# block eviction shares the metrics/hook surface with the response cache
print("\n[kv-prefix] RAC-scored radix block manager (facade-routed):")
mgr = KVBlockManager(n_blocks=48, block_tokens=8)
hot_prefix = list(range(32))                 # a popular system prompt
hit_tokens = total_tokens = 0
for i in range(120):
    if rng.random() < 0.4:
        conv = hot_prefix + list(rng.integers(500, 1000, size=16))
    else:
        conv = list(range(1000 + 64 * i, 1000 + 64 * i + 48))
    r = mgr.on_request(conv)
    hit_tokens += r["hit_tokens"]
    total_tokens += len(conv)
km = mgr.cache.metrics
print(f"  prefix tokens served from cache: {hit_tokens}/{total_tokens} "
      f"({hit_tokens / total_tokens:.1%}); blocks used {mgr.used}/48")
print(f"  facade metrics: block hit_ratio={km.hit_ratio:.3f} "
      f"({km.hits} hits / {km.misses} misses, {km.evictions} evictions)")
