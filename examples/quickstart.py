"""Quickstart: run RAC against the full baseline set on a synthetic
semi-Markov workload (paper §4.2) and print the comparison table.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (SynthConfig, default_factories, run_many,
                        synthetic_trace, summarize)

# a paper-shaped workload: 120 topics, topic-core DAGs, 70% of reuse
# events beyond the cache horizon (the paper's adversarial regime)
trace = synthetic_trace(SynthConfig(trace_len=10_000, seed=0,
                                    long_reuse_ratio=0.7))
capacity = int(0.10 * trace.meta["unique"])      # 10% of unique footprint

print(f"trace: {len(trace)} requests, {trace.meta['unique']} unique, "
      f"capacity {capacity}")
stats = run_many(trace, capacity, default_factories(include_belady=True))
stats.sort(key=lambda s: -s.hit_ratio)
print(summarize(stats))

best = max((s for s in stats if s.policy not in
            ("RAC", "RAC w/o TP", "RAC w/o TSI", "Belady")),
           key=lambda s: s.hit_ratio)
rac = next(s for s in stats if s.policy == "RAC")
print(f"\nRAC {rac.hit_ratio:.4f} vs best baseline {best.policy} "
      f"{best.hit_ratio:.4f}  ({100 * (rac.hit_ratio / best.hit_ratio - 1):+.1f}%)")
