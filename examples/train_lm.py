"""Train a reduced smollm-family LM for a few hundred steps on CPU with the
full production loop: deterministic data pipeline, AdamW + cosine schedule,
atomic checkpointing, restart-resume.

    PYTHONPATH=src python examples/train_lm.py
"""
import tempfile

from repro.launch.train import main as train_main

with tempfile.TemporaryDirectory() as ckpt:
    print("=== phase 1: steps 0-149 (checkpoint every 50) ===")
    train_main(["--arch", "smollm-360m", "--smoke", "--steps", "300",
                "--stop-at", "150", "--batch", "8", "--seq", "128",
                "--ckpt-dir", ckpt, "--ckpt-every", "50",
                "--log-every", "25"])
    print("=== phase 2: restart from the checkpoint, steps 150-299 ===")
    losses = train_main(["--arch", "smollm-360m", "--smoke", "--steps",
                         "300", "--batch", "8", "--seq", "128",
                         "--ckpt-dir", ckpt, "--ckpt-every", "100",
                         "--log-every", "25"])
print(f"final loss {losses[-1]:.4f}")
