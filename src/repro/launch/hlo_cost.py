"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE — every
``lax.scan`` (layer stacks, attention chunk loops) under-reports by its
trip count.  The compiled HLO records ``known_trip_count`` per while op,
so we walk the module recursively and multiply.

Per-device terms extracted:
  - flops:        2·M·N·K per dot (batch dims included), trip-aware
  - coll_bytes:   ring-model link bytes per collective, trip-aware
  - hbm_bytes:    Σ (operand + result bytes) over materialized (top-level
                  or fusion-root) ops — the roofline HBM-traffic proxy

Shapes in the partitioned module are per-device, so all terms are
per-device automatically.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')
_CALL_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w\.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BDIMS_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "all-reduce-start", "all-gather-start",
               "collective-permute-start", "reduce-scatter-start",
               "all-to-all-start"}

# Ops the TPU backend fuses into their consumers/producers — they don't
# round-trip HBM, so the memory term skips them.  (The CPU backend leaves
# them standalone, which would overstate TPU HBM traffic ~5-10×.)
FUSED_ON_TPU = {
    "add", "subtract", "multiply", "divide", "negate", "abs", "sign",
    "convert", "broadcast", "reshape", "bitcast", "slice", "pad",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "rsqrt", "sqrt", "power", "maximum", "minimum", "compare",
    "select", "and", "or", "not", "xor", "clamp", "floor", "ceil",
    "round-nearest-afz", "round-nearest-even", "iota", "is-finite",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "copy",
    "rng", "rng-bit-generator", "reverse", "real", "imag", "cosine", "sine",
    "exp", "erf", "atan2", "remainder", "stochastic-convert", "reduce",
    "map", "concatenate", "expm1", "log1p",
    # TPU dots take arbitrary dimension numbers — the explicit layout
    # transposes the CPU backend materializes don't exist there
    "transpose",
}


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    """Total (elements, bytes) over all arrays in a (possibly tuple) shape."""
    elems = total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def _first_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


_META_RE = re.compile(r'op_name="([^"]*)"')
FUSED_REGION_TAG = "fused_attn"


@dataclasses.dataclass
class _Op:
    name: str
    shape_str: str
    opcode: str
    operands: list[str]
    tail: str

    @property
    def meta(self) -> str:
        m = _META_RE.search(self.tail)
        return m.group(1) if m else ""

    @property
    def in_fused_region(self) -> bool:
        return FUSED_REGION_TAG in self.meta


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[_Op]] = {}
        self.op_shape: dict[str, str] = {}
        self.op_fused: dict[str, bool] = {}
        self.consumers_fused: dict[str, list[bool]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        # effective fused-region membership: explicit tag, or (CPU lowering
        # artifacts) metadata-less ops whose data operands are all fused —
        # propagated in SSA order, two rounds for short chains
        op_code = {op.name: op.opcode
                   for ops in self.comps.values() for op in ops}
        neutral = {"constant", "iota", "parameter"}
        for _ in range(2):
            for ops in self.comps.values():
                for op in ops:
                    if op.in_fused_region:
                        self.op_fused[op.name] = True
                        continue
                    if op.meta or op.opcode in neutral:
                        self.op_fused.setdefault(op.name, False)
                        continue
                    data_ops = [o for o in op.operands
                                if op_code.get(o) not in neutral]
                    self.op_fused[op.name] = bool(data_ops) and all(
                        self.op_fused.get(o, False) for o in data_ops)
        for ops in self.comps.values():
            for op in ops:
                for o in op.operands:
                    self.consumers_fused.setdefault(o, []).append(
                        self.op_fused.get(op.name, False))
        self._memo: dict[str, tuple[float, float, float]] = {}

    # ------------------------------------------------------------- parsing
    def _parse(self, text: str):
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            if not line.startswith(" ") and ("->" in line) and line.endswith("{"):
                m = _COMP_RE.match(line.strip())
                if m:
                    cur = m.group(1)
                    self.comps[cur] = []
                    if line.strip().startswith("ENTRY"):
                        self.entry = cur
                    continue
            if cur is None or line.strip() == "}":
                if line.strip() == "}":
                    cur = None
                continue
            m = _NAME_RE.match(line)
            if not m:
                continue
            name = m.group(1)
            rest = line[m.end():]
            # shape: either a balanced-paren tuple (may contain /*index=N*/
            # comments and layout braces) or a space-free array shape
            if rest.startswith("("):
                depth = 0
                for i, ch in enumerate(rest):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            shape_str, rest = rest[:i + 1], rest[i + 1:]
                            break
                else:
                    continue
            else:
                sp = rest.find(" ")
                if sp < 0:
                    continue
                shape_str, rest = rest[:sp], rest[sp:]
            om = _OPCODE_RE.match(rest)
            if not om:
                continue
            opcode = om.group(1)
            rest = rest[om.end():]
            # operands: up to the matching close paren at depth 0
            depth = 0
            tail = ""
            ops_str = rest
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    if depth == 0:
                        ops_str = rest[:i]
                        tail = rest[i + 1:]
                        break
                    depth -= 1
            operands = re.findall(r"%([\w\.\-]+)", ops_str)
            op = _Op(name, shape_str.strip(), opcode, operands, tail)
            self.comps[cur].append(op)
            self.op_shape[name] = op.shape_str

    # ---------------------------------------------------------------- cost
    def _dot_flops(self, op: _Op) -> float:
        _, out_elems_bytes = _shape_elems_bytes(op.shape_str)
        out_elems, _ = _shape_elems_bytes(op.shape_str)
        lhs_shape = self.op_shape.get(op.operands[0], "") if op.operands else ""
        dims = _first_dims(lhs_shape)
        cm = _CDIMS_RE.search(op.tail)
        contract = 1
        if cm and dims:
            for i in (int(x) for x in cm.group(1).split(",") if x):
                if i < len(dims):
                    contract *= dims[i]
        return 2.0 * out_elems * contract

    def _coll_bytes(self, op: _Op) -> float:
        _, b = _shape_elems_bytes(op.shape_str)
        gm = _GROUPS_RE.search(op.tail)
        if gm:
            n = len([x for x in gm.group(1).split(",") if x])
        else:
            gi = _GROUPS_IOTA_RE.search(op.tail)
            n = int(gi.group(2)) if gi else 1
        kind = op.opcode.replace("-start", "")
        if n <= 1:
            return 0.0
        if kind == "all-reduce":
            return 2.0 * b * (n - 1) / n
        if kind == "all-gather":
            return b * (n - 1) / n
        if kind == "reduce-scatter":
            return b * (n - 1)
        if kind == "all-to-all":
            return b * (n - 1) / n
        return float(b)              # collective-permute

    def _is_elementwise(self, comp: str) -> bool:
        """True when a fusion body is pure elementwise/layout ops — the TPU
        backend fuses such chains into neighbors (no HBM round-trip)."""
        for op in self.comps.get(comp, []):
            if op.opcode in ("parameter", "constant", "tuple",
                             "get-tuple-element"):
                continue
            if op.opcode not in FUSED_ON_TPU:
                return False
        return True

    def _body_kinds(self, op: _Op, seen: set[str]) -> set[str]:
        """Opcodes reachable inside an op's called computations, looking
        through nested fusion/call wrappers (the CPU backend wraps
        partitioned fusions in ``call`` ops)."""
        kinds: set[str] = set()
        for cm_ in _CALL_RE.finditer(op.tail):
            comp = cm_.group(1)
            if comp in seen:
                continue
            seen.add(comp)
            for o in self.comps.get(comp, []):
                if o.opcode in ("fusion", "call"):
                    kinds |= self._body_kinds(o, seen)
                else:
                    kinds.add(o.opcode)
        return kinds

    def _root_kind(self, op: _Op) -> str:
        """Effective opcode: for fusions/calls, the dominant body op
        (layout and elementwise wrappers like bitcast/convert don't change
        the class)."""
        if op.opcode not in ("fusion", "call"):
            return op.opcode
        kinds = self._body_kinds(op, set())
        for heavy in ("dot", "scatter", "gather", "sort", "reduce-window"):
            if heavy in kinds:
                return heavy
        if "dynamic-update-slice" in kinds:
            return "dynamic-update-slice"
        if "dynamic-slice" in kinds:
            return "dynamic-slice"
        return op.opcode

    def _op_traffic(self, op: _Op) -> float:
        """HBM bytes of one materialized op.

        - ``fused_attn``-scoped ops model the Pallas flash-attention kernel
          (kernels/): interior tensors stay in VMEM, only region-boundary
          traffic counts.
        - dynamic-slice reads only the slice (2×result), NOT its full
          operand; dynamic-update-slice writes only the update in place
          (2×update) — naive operand counting would bill the whole stacked
          scan carry per layer iteration.
        """
        hb = 0.0
        if self.op_fused.get(op.name, False):
            for o in op.operands:
                if not self.op_fused.get(o, False):
                    hb += _shape_elems_bytes(self.op_shape.get(o, ""))[1]
            cons = self.consumers_fused.get(op.name, [])
            if not cons or any(not c for c in cons):
                hb += _shape_elems_bytes(op.shape_str)[1]
            return hb
        kind = self._root_kind(op)
        if kind == "dynamic-slice":
            return 2.0 * _shape_elems_bytes(op.shape_str)[1]
        if kind == "dynamic-update-slice":
            # in-place (donated) update: read+write the update tensor only;
            # operands = [target, update, indices...] — indices are scalars
            sizes = sorted(_shape_elems_bytes(self.op_shape.get(o, ""))[1]
                           for o in op.operands)
            sizes = [s for s in sizes if s > 64]    # drop index scalars
            return 2.0 * (sizes[0] if sizes else
                          _shape_elems_bytes(op.shape_str)[1])
        hb += _shape_elems_bytes(op.shape_str)[1]
        for o in op.operands:
            hb += _shape_elems_bytes(self.op_shape.get(o, ""))[1]
        return hb

    def comp_cost(self, comp: str) -> tuple[float, float, float]:
        """(flops, coll_bytes, hbm_bytes) for one computation, trip-aware."""
        if comp in self._memo:
            return self._memo[comp]
        self._memo[comp] = (0.0, 0.0, 0.0)      # cycle guard
        fl = cb = hb = 0.0
        for op in self.comps.get(comp, []):
            if op.opcode in ("parameter", "constant", "get-tuple-element",
                             "tuple", "bitcast", "after-all"):
                continue
            if op.opcode == "while":
                tm = _TRIP_RE.search(op.tail)
                trips = int(tm.group(1)) if tm else 1
                for cm_ in _CALL_RE.finditer(op.tail):
                    f2, c2, h2 = self.comp_cost(cm_.group(1))
                    fl += trips * f2
                    cb += trips * c2
                    hb += trips * h2
                continue
            if op.opcode in ("fusion", "call", "custom-call", "conditional",
                             "sort", "scatter", "reduce-window",
                             "select-and-scatter"):
                # flops inside called computations (fusion bodies etc.)
                materialized = op.opcode != "fusion"
                for cm_ in _CALL_RE.finditer(op.tail):
                    f2, c2, _ = self.comp_cost(cm_.group(1))
                    fl += f2
                    cb += c2
                    if op.opcode == "fusion" and not self._is_elementwise(
                            cm_.group(1)):
                        materialized = True
                if materialized:
                    hb += self._op_traffic(op)
                continue
            if op.opcode == "dot":
                fl += self._dot_flops(op)
            elif op.opcode == "convolution":
                # rare here: approximate as dot on result × guessed contract
                out_e, _ = _shape_elems_bytes(op.shape_str)
                fl += 2.0 * out_e * 128
            elif op.opcode.replace("-start", "") in {
                    "all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute"}:
                cb += self._coll_bytes(op)
            if op.opcode in FUSED_ON_TPU:
                continue            # fused on TPU: no HBM round-trip
            hb += self._op_traffic(op)
        self._memo[comp] = (fl, cb, hb)
        return self._memo[comp]

    def entry_cost(self) -> tuple[float, float, float]:
        assert self.entry is not None, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def analyze(hlo_text: str) -> dict:
    m = HloCostModel(hlo_text)
    fl, cb, hb = m.entry_cost()
    return {"flops": fl, "coll_bytes": cb, "hbm_bytes": hb}
