"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Production shapes:

  - single pod:  (16, 16)        axes ("data", "model")  = 256 chips
  - multi-pod:   (2, 16, 16)     axes ("pod", "data", "model") = 512 chips

The dry-run spawns these over 512 XLA host-platform placeholder devices;
on real hardware the same function builds the mesh over TPU devices with
ICI-contiguous model axes.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Degenerate 1-device mesh for laptop runs (same code path)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_cache_mesh(n_shards: int):
    """1-D ``("cache",)`` mesh over the first ``n_shards`` devices for the
    sharded semantic-cache resident store (row-partitioned slab, one shard
    per device).

    Returns ``None`` when fewer devices exist (or ``n_shards <= 1``) —
    callers fall back to a single-device per-shard loop that computes the
    identical per-shard/merge math, so shard-count semantics never depend
    on the machine the code happens to run on.
    """
    import numpy as np
    devices = jax.devices()
    if n_shards <= 1 or len(devices) < n_shards:
        return None
    from jax.sharding import Mesh
    return Mesh(np.asarray(devices[:n_shards]), ("cache",))


def abstract_mesh(shape, axis_names):
    """Version-portable ``jax.sharding.AbstractMesh`` construction.

    The AbstractMesh calling convention differs across jax releases: some
    take a single tuple of ``(name, size)`` pairs (e.g. 0.4.37, tried
    first), others take ``(shape, axis_names)`` as two positional tuples
    (the fallback).  Every analysis
    path (sharding-plan rules, HLO cost tests) builds device-free meshes
    through this helper so the repo tracks either convention.
    """
    from jax.sharding import AbstractMesh
    shape = tuple(int(s) for s in shape)
    axis_names = tuple(axis_names)
    assert len(shape) == len(axis_names)
    try:
        return AbstractMesh(tuple(zip(axis_names, shape)))
    except TypeError:
        return AbstractMesh(shape, axis_names)
