"""Training driver: mesh-aware, checkpoint/restart, deterministic resume.

Laptop mode (1 CPU device) and production mode (real TPU mesh) share this
code path; only the mesh differs.  Fault-tolerance wiring:

  - checkpoint every ``--ckpt-every`` steps (atomic, sharded);
  - on start, restore the newest committed step and resume the data cursor
    (bit-for-bit identical batch stream);
  - per-step heartbeats + straggler detection hooks
    (distributed/fault_tolerance.py) — single-host here, fleet-ready API.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 100 --batch 8 --seq 256 --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, TokenPipeline
from repro.distributed.checkpoint import restore_checkpoint, save_checkpoint
from repro.distributed.fault_tolerance import HeartbeatMonitor, StragglerDetector
from repro.models import build_model, make_train_step, smoke_variant
from repro.optim import AdamWConfig, adamw_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=100,
                    help="total schedule length")
    ap.add_argument("--stop-at", type=int, default=None,
                    help="halt early (schedule still spans --steps)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(1, args.steps // 20))
    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      accum_steps=args.accum))

    data = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size,
                                    seq_len=args.seq,
                                    global_batch=args.batch))

    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    opt_state = adamw_init(params)
    start = 0
    if args.ckpt_dir:
        state, extra = restore_checkpoint(args.ckpt_dir,
                                          {"params": params, "opt": opt_state})
        if state is not None:
            params, opt_state = state["params"], state["opt"]
            start = int(extra["cursor"])
            print(f"[train] restored step {start} from {args.ckpt_dir}")

    hb = HeartbeatMonitor(n_hosts=jax.process_count())
    straggler = StragglerDetector(n_hosts=jax.process_count())
    losses = []
    t0 = time.perf_counter()
    for step in range(start, args.stop_at or args.steps):
        batch = jax.tree.map(lambda x: jax.numpy.asarray(x),
                             data.batch_at(step))
        ts = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        hb.beat(jax.process_index(), step)
        flagged = straggler.observe([time.perf_counter() - ts])
        if flagged:
            print(f"[train] straggler flagged: hosts {flagged}")
        if (step + 1) % args.log_every == 0:
            dt = time.perf_counter() - t0
            print(f"[train] step {step+1} loss {loss:.4f} "
                  f"({dt/args.log_every*1000:.0f} ms/step)", flush=True)
            t0 = time.perf_counter()
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1,
                            {"params": params, "opt": opt_state},
                            extra={"cursor": step + 1})
    if len(losses) >= 20:
        first = float(np.mean(losses[:10]))
        last = float(np.mean(losses[-10:]))
        print(f"[train] loss first10 {first:.4f} -> last10 {last:.4f} "
              f"({'improved' if last < first else 'NOT improved'})")
    return losses


if __name__ == "__main__":
    main()
