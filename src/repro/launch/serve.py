"""Serving driver: RAC-fronted engine over a trace of requests.

Replays a dialogue trace (synthetic or OASST-style) against the serving
engine: semantic-cache hits skip generation entirely; misses run batched
decode and admit their responses under RAC eviction.  Reports hit ratio +
generation savings — the end-to-end instantiation of the paper's claim
(hit ratio ∝ saved compute/latency).

Usage:
    PYTHONPATH=src python -m repro.launch.serve --requests 200 \
        --capacity 64 --arch paper
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_config
from repro.core import SynthConfig, synthetic_trace
from repro.models import smoke_variant
from repro.serving import EngineConfig, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--capacity", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    mcfg = smoke_variant(get_config(args.arch))
    ecfg = EngineConfig(cache_capacity=args.capacity,
                        max_new_tokens=args.max_new)
    engine = ServingEngine(mcfg, ecfg)

    trace = synthetic_trace(SynthConfig(trace_len=args.requests,
                                        n_topics=24, seed=args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = []
    for r in trace.requests:
        prompt = list(rng.integers(2, mcfg.vocab_size,
                                   size=int(rng.integers(4, 12))))
        reqs.append((r.cid, r.emb, prompt))

    t0 = time.perf_counter()
    done = engine.run(reqs)
    dt = time.perf_counter() - t0
    s = engine.stats
    hr = s["hits"] / max(1, s["hits"] + s["misses"])
    print(f"[serve] {len(done)} requests in {dt:.1f}s | hit_ratio {hr:.3f} "
          f"| generated {s['generated_tokens']} tokens in {s['batches']} "
          f"batched steps | hits {s['hits']} misses {s['misses']}")
    saved = s["hits"] * ecfg.max_new_tokens
    print(f"[serve] generation saved by cache ≈ {saved} tokens "
          f"({saved / max(1, saved + s['generated_tokens']):.1%} of total)")
    return s


if __name__ == "__main__":
    main()
