"""Post-compile HLO analysis: collective-traffic extraction + roofline terms.

``cost_analysis()`` gives HLO FLOPs and bytes-accessed but NOT collective
bytes; we parse the compiled HLO text and sum the traffic of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
using ring-algorithm factors and the replica-group size.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (DESIGN.md / assignment constants).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Collective:
    kind: str
    bytes_result: int
    group_size: int

    @property
    def link_bytes(self) -> float:
        """Ring-algorithm bytes crossing any one chip's links."""
        n, b = self.group_size, self.bytes_result
        if n <= 1:
            return 0.0
        if self.kind == "all-reduce":
            return 2.0 * b * (n - 1) / n
        if self.kind == "all-gather":
            return b * (n - 1) / n          # b = gathered result
        if self.kind == "reduce-scatter":
            return b * (n - 1)              # b = scattered result shard
        if self.kind == "all-to-all":
            return b * (n - 1) / n
        if self.kind == "collective-permute":
            return float(b)
        return float(b)


def parse_collectives(hlo_text: str) -> list[Collective]:
    out: list[Collective] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str = m.group(1) or m.group(2)
        kind = m.group(3)
        gm = _GROUPS_RE.search(line)
        if gm:
            gsize = len(gm.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            gsize = int(gi.group(2)) if gi else 1
        out.append(Collective(kind, _shape_bytes(shape_str), gsize))
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device link bytes
    n_chips: int

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def row(self) -> dict:
        return dict(flops=self.flops, hbm_bytes=self.hbm_bytes,
                    coll_bytes=self.coll_bytes,
                    t_compute=self.t_compute, t_memory=self.t_memory,
                    t_collective=self.t_collective,
                    bottleneck=self.bottleneck)


def roofline_from_compiled(compiled, n_chips: int) -> Roofline:
    """Trip-count-aware terms via the custom HLO walker (hlo_cost.py);
    XLA's cost_analysis counts while bodies once, so scanned layer stacks
    would otherwise under-report (see EXPERIMENTS.md §Dry-run notes)."""
    from .hlo_cost import analyze
    r = analyze(compiled.as_text())
    return Roofline(flops=r["flops"], hbm_bytes=r["hbm_bytes"],
                    coll_bytes=r["coll_bytes"], n_chips=n_chips)
