"""Launchers: mesh construction, multi-pod dry-run, profiler, train, serve.

NOTE: import repro.launch.dryrun (or profile_cell) FIRST in a fresh process
when you need the 512-device placeholder mesh — they set XLA_FLAGS before
jax initializes.
"""
