import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("DRYRUN_DEVICES", "512")).strip()
"""Dry-run profiler for §Perf hillclimbing: per-opcode / per-metadata
breakdown of the roofline terms of one (arch × shape) cell.

    PYTHONPATH=src python -m repro.launch.profile_cell --arch qwen1.5-110b \
        --shape decode_32k [--by meta|opcode] [--top 15]
"""
import argparse
from collections import Counter


def profile(arch: str, shape: str, multi_pod: bool = False,
            top: int = 15, by: str = "opcode"):
    import repro.launch.hlo_cost as hc
    from repro.launch.dryrun import _build_compiled

    compiled, ctx = _build_compiled(arch, shape, multi_pod)
    m = hc.HloCostModel(compiled.as_text())
    traffic: Counter = Counter()
    flops: Counter = Counter()
    colls: Counter = Counter()

    def key(op):
        if by == "meta":
            meta = op.meta
            # keep the trailing (most specific) scopes
            return "/".join(meta.split("/")[-3:]) if meta else f"({op.opcode})"
        return op.opcode

    def walk(comp, mult=1.0):
        for op in m.comps.get(comp, []):
            if op.opcode in ("parameter", "constant", "get-tuple-element",
                             "tuple", "bitcast", "after-all"):
                continue
            if op.opcode == "while":
                tm = hc._TRIP_RE.search(op.tail)
                trips = int(tm.group(1)) if tm else 1
                for cm in hc._CALL_RE.finditer(op.tail):
                    walk(cm.group(1), mult * trips)
                continue
            if op.opcode in ("fusion", "call", "custom-call", "conditional",
                             "sort", "scatter", "reduce-window",
                             "select-and-scatter"):
                mat = op.opcode != "fusion"
                for cm in hc._CALL_RE.finditer(op.tail):
                    f2, c2, _ = m.comp_cost(cm.group(1))
                    flops[key(op)] += mult * f2
                    colls[key(op)] += mult * c2
                    if op.opcode == "fusion" and not m._is_elementwise(
                            cm.group(1)):
                        mat = True
                if mat:
                    traffic[key(op)] += mult * m._op_traffic(op)
                continue
            if op.opcode == "dot":
                flops[key(op)] += mult * m._dot_flops(op)
            elif op.opcode.replace("-start", "") in {
                    "all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute"}:
                colls[key(op)] += mult * m._coll_bytes(op)
            if op.opcode in hc.FUSED_ON_TPU:
                continue
            traffic[key(op)] += mult * m._op_traffic(op)

    walk(m.entry)
    print(f"== {arch} × {shape} ({'2x16x16' if multi_pod else '16x16'}) ==")
    print(f"-- HBM traffic by {by} (GB/device/step) --")
    for k, v in traffic.most_common(top):
        print(f"  {v/1e9:10.1f}  {k}")
    print(f"-- flops by {by} (G) --")
    for k, v in flops.most_common(top):
        print(f"  {v/1e9:10.1f}  {k}")
    print(f"-- collective link-bytes by {by} (GB) --")
    for k, v in colls.most_common(top):
        print(f"  {v/1e9:10.1f}  {k}")
    return traffic, flops, colls


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--by", default="opcode", choices=["opcode", "meta"])
    ap.add_argument("--top", type=int, default=15)
    a = ap.parse_args()
    profile(a.arch, a.shape, a.multi_pod, a.top, a.by)


if __name__ == "__main__":
    main()
