import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("DRYRUN_DEVICES", "512")).strip()
"""Multi-pod dry-run: prove every (architecture × input shape × mesh) cell
lowers, SPMD-partitions and compiles on the production mesh, and extract
the roofline terms from the compiled artifact.

MUST be imported/run before anything else initializes jax (the XLA_FLAGS
assignment above is the very first executable statement).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b \
        --shape train_4k [--multi-pod] [--all] [--out results.json]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ALIASES, ARCH_IDS, get_config, input_specs, shape_cells
from repro.distributed.api import use_rules
from repro.distributed.sharding import (ShardingPlan, activation_rules,
                                        batch_shardings, param_shardings)
from repro.launch.hlo_analysis import roofline_from_compiled
from repro.launch.mesh import make_production_mesh
from repro.models import build_model, make_train_step
from repro.models.config import SHAPES
from repro.optim import AdamWConfig, adamw_init


def _build_compiled(arch: str, shape: str, multi_pod: bool):
    """Lower + compile one cell; returns (compiled, context)."""
    cfg = get_config(arch)
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    sc = SHAPES[shape]
    plan = ShardingPlan.for_mesh(mesh, cfg, shape_kind=sc.kind)

    specs = input_specs(cfg, shape)
    params_struct = model.init_shapes()
    p_shard = param_shardings(params_struct, cfg, plan, mesh)
    b_shard = batch_shardings(cfg, shape, specs, plan, mesh)
    rules = activation_rules(cfg, shape, plan, mesh)

    with mesh, use_rules(mesh, rules):
        if sc.kind == "train":
            opt_cfg = AdamWConfig()
            opt_struct = jax.eval_shape(adamw_init, params_struct)
            # moments share the param specs; step is replicated
            o_shard = {
                "m": jax.tree.map(lambda p: p, p_shard),
                "v": jax.tree.map(lambda p: p, p_shard),
                "step": jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()),
            }
            # grad accumulation: cap the per-device microbatch token count
            # (DRYRUN_MICROBATCH_TOKENS tunes the memory/collective trade:
            # fewer microbatches = fewer FSDP weight re-gathers)
            budget = int(os.environ.get("DRYRUN_MICROBATCH_TOKENS", "16384"))
            dp_size = 1
            for a in plan.dp:
                dp_size *= mesh.shape[a]
            local_tokens = sc.global_batch // dp_size * sc.seq_len
            accum = max(1, min(sc.global_batch // dp_size,
                               local_tokens // budget))
            step = make_train_step(model, opt_cfg, accum_steps=accum)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_struct, opt_struct, specs)
        elif sc.kind == "prefill":
            def prefill_step(params, batch):
                return model.prefill(params, batch)
            jitted = jax.jit(prefill_step,
                             in_shardings=(p_shard, b_shard),
                             out_shardings=None)
            lowered = jitted.lower(params_struct, specs)
        else:  # decode
            cache_struct = specs.pop("cache")
            b_shard.pop("cache")
            cache_shard = batch_shardings(cfg, shape, {"cache": cache_struct},
                                          plan, mesh)["cache"]
            def serve_step(params, cache, batch):
                logits, new_cache = model.decode_step(params, cache, batch)
                return jnp.argmax(logits, -1), new_cache
            jitted = jax.jit(serve_step,
                             in_shardings=(p_shard, cache_shard, b_shard),
                             out_shardings=(None, cache_shard),
                             donate_argnums=(1,))   # in-place cache update
            lowered = jitted.lower(params_struct, cache_struct, specs)

        compiled = lowered.compile()
    return compiled, dict(cfg=cfg, mesh=mesh, plan=plan, sc=sc)


def lower_cell(arch: str, shape: str, multi_pod: bool,
               verbose: bool = True) -> dict:
    """Lower + compile one (arch × shape) cell; return roofline record."""
    t0 = time.time()
    compiled, ctx = _build_compiled(arch, shape, multi_pod)
    cfg, mesh, sc = ctx["cfg"], ctx["mesh"], ctx["sc"]

    mem = compiled.memory_analysis()
    n_chips = mesh.devices.size
    rl = roofline_from_compiled(compiled, n_chips)
    n_params = cfg.n_params()
    # MODEL_FLOPS = 6·N·D for train, 2·N·D for inference (per token),
    # MoE uses active params
    active = n_params
    if cfg.is_moe:
        e_ff = cfg.expert_d_ff or cfg.d_ff
        n_in = 3 if cfg.mlp in ("swiglu", "geglu") else 2
        moe_total = cfg.n_layers * cfg.n_experts * n_in * cfg.d_model * e_ff
        moe_active = cfg.n_layers * cfg.top_k * n_in * cfg.d_model * e_ff
        active = n_params - moe_total + moe_active
    tokens = sc.global_batch * (sc.seq_len if sc.kind != "decode" else 1)
    model_flops = (6 if sc.kind == "train" else 2) * active * tokens

    rec = dict(
        arch=arch, shape=shape, mesh="2x16x16" if multi_pod else "16x16",
        n_chips=n_chips, kind=sc.kind,
        seconds_to_compile=round(time.time() - t0, 1),
        params_b=round(n_params / 1e9, 2),
        argument_bytes_per_device=getattr(mem, "argument_size_in_bytes", 0),
        output_bytes_per_device=getattr(mem, "output_size_in_bytes", 0),
        temp_bytes_per_device=getattr(mem, "temp_size_in_bytes", 0),
        peak_bytes_per_device=(getattr(mem, "argument_size_in_bytes", 0) +
                               getattr(mem, "output_size_in_bytes", 0) +
                               getattr(mem, "temp_size_in_bytes", 0)),
        model_flops_total=model_flops,
        **rl.row(),
    )
    rec["model_flops_per_chip"] = model_flops / n_chips
    rec["useful_flop_frac"] = (model_flops / n_chips) / max(rl.flops, 1.0)
    if verbose:
        print(f"[dryrun] {arch} × {shape} × {rec['mesh']}: "
              f"compile {rec['seconds_to_compile']}s, "
              f"peak {rec['peak_bytes_per_device']/2**30:.2f} GiB/dev, "
              f"t_comp {rl.t_compute*1e3:.2f} ms, "
              f"t_mem {rl.t_memory*1e3:.2f} ms, "
              f"t_coll {rl.t_collective*1e3:.2f} ms "
              f"-> {rl.bottleneck}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="assignment id (e.g. gemma-7b) or module id")
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all (arch × shape) cells")
    ap.add_argument("--out", default=None, help="append JSON records here")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    if args.all:
        for a in ARCH_IDS:
            for s in shape_cells(get_config(a)):
                cells.append((a, s))
    else:
        arch = args.arch or "gemma-7b"
        shapes = [args.shape] if args.shape else shape_cells(
            get_config(arch))
        cells = [(arch, s) for s in shapes]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    records, failures = [], []
    for arch, shape in cells:
        for mp in meshes:
            try:
                records.append(lower_cell(arch, shape, mp))
            except Exception as e:  # noqa: BLE001 — report and continue
                traceback.print_exc()
                failures.append(dict(arch=arch, shape=shape,
                                     mesh="2x16x16" if mp else "16x16",
                                     error=str(e)[:500]))
    if args.out:
        with open(args.out, "a") as f:
            for r in records + failures:
                f.write(json.dumps(r) + "\n")
    print(f"[dryrun] {len(records)} ok, {len(failures)} failed")
    if failures:
        for f_ in failures:
            print("  FAIL:", f_["arch"], f_["shape"], f_["mesh"],
                  f_["error"][:200])
        raise SystemExit(1)


if __name__ == "__main__":
    main()
