"""Serving engine: continuous batching + RAC semantic cache front-end.

Request path (the paper's semantic-cache setting, §2):
  1. embed the query (synthetic embedding space offline; a real deployment
     plugs a sentence encoder into ``embed_fn``);
  2. semantic lookup against resident entries through the unified
     :class:`repro.cache.SemanticCache` facade — the whole waiting queue is
     scored in ONE fused ``decide_batch`` launch (the backends' one-dispatch
     decision pass over the device-mirrored slab + RAC PolicyTable under
     the ``"kernel"``/``"sharded"`` backends), and subsequent rescans only
     rescore waiting requests against rows admitted since (``peek_rows``);
     Top-1 cosine ≥ tau_hit hits return their cached response with zero
     model compute;
  3. miss → schedule for generation under continuous batching; on
     completion, admit (query-embedding, response) into the cache.  The
     facade owns eviction (RAC Value scoring) and drops the evicted
     response payloads itself — the engine only observes via the
     ``"evict"`` event hook.

Event-driven admission: with ``EngineConfig.async_admit`` the cache runs
in ``async_admit`` mode — a completed slot only *enqueues* its admission
(generation never blocks on eviction scoring) and the engine settles the
queue with one ``flush()`` at batch boundaries, just before the waiting
queue is rescored.  Request outputs (tokens, hit flags) are identical to
the synchronous path; the admit stall moves off the slot loop
(``benchmarks/serving_async_bench.py`` measures the difference).

The KV-prefix instantiation rides underneath via
:class:`repro.serving.kv_manager.KVBlockManager` for multi-turn requests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import CacheConfig, SemanticCache, TierConfig
from repro.models import Model, build_model, make_decode_step
from repro.models.config import ModelConfig
from repro.telemetry.tracker import make_tracker


@dataclasses.dataclass
class EngineConfig:
    cache_capacity: int = 512
    tau_hit: float = 0.85
    max_new_tokens: int = 16
    max_batch: int = 8            # continuous-batching slot count
    max_seq: int = 256
    emb_dim: int = 64
    cache_backend: str = "numpy"  # "numpy" | "kernel" | "sharded"
                                  # (device sim_top1; sharded = multi-device
                                  #  slab, see repro/cache/sharded.py)
    async_admit: bool = False     # queue admissions, flush at batch bounds
    host_capacity: int = 0        # host-DRAM tier rows (0 = single-tier);
                                  # device evictions demote here and host
                                  # hits promote back via the admit path
    ghost_capacity: int = 0       # metadata-only ghost tier entries (0 =
                                  # policy-internal ghosts only)
    tracker: object = None        # telemetry sink: a repro.telemetry.Tracker
                                  # instance or spec string ("memory",
                                  # "jsonl:<path>", "a+b"); shared with the
                                  # cache so request-path spans and cache
                                  # latencies land in ONE trace/registry.
                                  # None (default) disables emission.


@dataclasses.dataclass
class RequestState:
    rid: int
    cid: int
    emb: np.ndarray
    tokens: list
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    cached: bool = False
    t_submit: float = 0.0
    t_sched: float = 0.0          # scheduled into a generation slot
    t_first: float = 0.0          # first output token (TTFT proxy anchor)
    t_done: float = 0.0


class ServingEngine:
    def __init__(self, model_cfg: ModelConfig, ecfg: EngineConfig,
                 params=None, rng=None, policy_kwargs: Optional[dict] = None):
        self.cfg = ecfg
        self.model = build_model(model_cfg)
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.params = params if params is not None else self.model.init(rng)
        self.decode = jax.jit(make_decode_step(self.model))
        # one tracker instance shared with the cache: engine request-path
        # spans and cache.* latencies land in the same registry/trace
        self._trk = make_tracker(ecfg.tracker)
        # semantic cache (RAC-managed) behind the unified facade
        self.cache = SemanticCache(CacheConfig(
            capacity=ecfg.cache_capacity, dim=ecfg.emb_dim,
            tau_hit=ecfg.tau_hit, hit_mode="semantic",
            backend=ecfg.cache_backend, policy="RAC",
            policy_kwargs=policy_kwargs or {},
            async_admit=ecfg.async_admit,
            tiers=(TierConfig(host_capacity=ecfg.host_capacity,
                              ghost_capacity=ecfg.ghost_capacity)
                   if ecfg.host_capacity > 0 or ecfg.ghost_capacity > 0
                   else None),
            tracker=self._trk))
        self._gen = {"generated_tokens": 0, "batches": 0,
                     "evicted_responses": 0}
        self.cache.subscribe("evict", self._on_evict)
        self._recent_admits: list[int] = []          # admits since last scan
        self.cache.subscribe("admit",
                             lambda ev: self._recent_admits.append(ev.cid))

    def _on_evict(self, ev):
        # the facade already dropped the payload with the entry; the engine
        # only observes (metrics / future writeback)
        if ev.payload is not None:
            self._gen["evicted_responses"] += 1

    def close(self):
        """Release engine-owned resources (stops the async admission
        worker after flushing it; a no-op in blocking mode)."""
        self.cache.close()

    # legacy attribute surface (tests, examples, notebooks) --------------
    @property
    def store(self):
        return self.cache.store

    @property
    def policy(self):
        return self.cache.policy

    @property
    def responses(self):
        return self.cache.payloads

    @property
    def tracker(self):
        """The engine's telemetry sink (None when telemetry is off)."""
        return self._trk

    @property
    def stats(self) -> dict:
        """Serving counters on top of the cache's consolidated metrics
        surface (:meth:`SemanticCache.metrics_snapshot`) — one merge
        point instead of hand-picking attributes per layer.  With a
        tracker attached, the admission-stall distribution's p50/p99
        ride along (the serving SLO summary)."""
        snap = self.cache.metrics_snapshot()
        out = {**self._gen, "hits": snap["hits"], "misses": snap["misses"],
               "evictions": snap["evictions"],
               "hit_ratio": snap["hit_ratio"],
               "admit_stall_s": snap["admit_stall_s"]}
        if self._trk is not None:
            pct = self._trk.percentiles("cache.admit_stall_s")
            if pct is not None:
                out["admit_stall_p50_s"] = pct["p50"]
                out["admit_stall_p99_s"] = pct["p99"]
        return out

    def _finish(self, req: RequestState, outcome: str) -> None:
        """Emit the request's lifecycle spans + TTFT proxy (no-op without
        a tracker).  Hits resolve in one span; generated requests split
        into queue (submit→slot) and generate (slot→done) child spans on
        the request's own track, so a Chrome trace shows where each
        request's latency went."""
        trk = self._trk
        if trk is None:
            return
        tags = {"rid": req.rid, "cid": req.cid, "outcome": outcome}
        trk.add_span("serve.request", req.t_submit, req.t_done,
                     track=req.rid, tags=tags)
        if outcome == "hit":
            trk.observe("serve.ttft_s", req.t_done - req.t_submit)
            return
        trk.add_span("serve.queue", req.t_submit, req.t_sched,
                     track=req.rid, tags={"rid": req.rid})
        trk.add_span("serve.generate", req.t_sched, req.t_done,
                     track=req.rid, tags={"rid": req.rid})
        if req.t_first:
            trk.observe("serve.ttft_s", req.t_first - req.t_submit)
        trk.observe("serve.queue_s", req.t_sched - req.t_submit)

    # -- continuous batching -------------------------------------------
    def run(self, requests: list[tuple[int, np.ndarray, list]]) -> list[RequestState]:
        """Process requests: (cid, embedding, prompt_tokens).  Returns the
        completed RequestState list (cache hits answer immediately)."""
        ecfg = self.cfg
        pending = [RequestState(rid=i, cid=c, emb=e, tokens=list(tk),
                                t_submit=time.perf_counter())
                   for i, (c, e, tk) in enumerate(requests)]
        done: list[RequestState] = []
        slots: list[Optional[RequestState]] = [None] * ecfg.max_batch

        cache = self.model.init_cache(ecfg.max_batch, ecfg.max_seq)
        pos = np.zeros(ecfg.max_batch, np.int32)
        cur = np.zeros(ecfg.max_batch, np.int32)
        budget = np.zeros(ecfg.max_batch, np.int32)
        queue = list(pending)

        peeked: dict[int, tuple[int, float]] = {}   # rid -> best-known top-1
        peeked_once = [False]
        recent = self._recent_admits

        def serve_hit(req: RequestState, res):
            req.out_tokens = list(res.payload or [])
            req.done = True
            req.cached = True
            req.t_done = time.perf_counter()
            self._finish(req, "hit")
            done.append(req)

        def drain_hits():
            # resolve every waiting request whose best-known similarity
            # clears tau_hit; the definitive miss is only charged when a
            # request is scheduled, so each request is counted exactly once
            waiting = []
            for req in queue:
                c, s = peeked[req.rid]
                if s >= ecfg.tau_hit and (c in self.cache
                                          or self.cache.in_host(c)):
                    res = self.cache.lookup(req.emb, cid=req.cid,
                                            top1=(c, s))
                    serve_hit(req, res)
                else:
                    waiting.append(req)
            queue[:] = waiting

        def try_fill():
            # batch boundary: settle queued admissions before any hit
            # determination, so async and synchronous admission see the
            # same store state at every lookup (identical outputs)
            if queue:
                self.cache.flush()
            # batched hit determination: the full queue is scored in ONE
            # fused decide_batch launch at first entry (hit Top-1 through
            # the policy's device-mirrored PolicyTable state); afterwards
            # each waiting request only scores against entries admitted
            # since the last pass (O(queue x new-admits), not O(queue x
            # store)), keeping its running best-known top-1 in `peeked`.
            # A stale best whose entry was evicted is caught by residency
            # checks here and by lookup()'s revalidation at scheduling time.
            if queue and not peeked_once[0]:
                peeked_once[0] = True
                dec = self.cache.decide_batch(
                    np.stack([r.emb for r in queue]))
                for req, c, s in zip(queue, dec.hit_cid, dec.hit_sim):
                    peeked[req.rid] = (int(c), float(s))
                if dec.host_cid is not None:
                    # tiered: a host-resident entry can out-score every
                    # device row; drain_hits serves it through lookup(),
                    # which falls through to the host tier and promotes
                    for req, c, s in zip(queue, dec.host_cid, dec.host_sim):
                        if float(s) > peeked[req.rid][1]:
                            peeked[req.rid] = (int(c), float(s))
                recent.clear()
                drain_hits()
            elif queue and recent:
                # row-restricted peek THROUGH the backend: the rescan uses
                # the same cosine scoring as the full peek, so peeked sims
                # and backend sims cannot disagree near tau_hit
                fresh = list(dict.fromkeys(recent))
                recent.clear()
                cids, sims = self.cache.peek_rows(
                    np.stack([r.emb for r in queue]), fresh)
                for i, req in enumerate(queue):
                    if sims[i] > peeked[req.rid][1]:
                        peeked[req.rid] = (int(cids[i]), float(sims[i]))
                drain_hits()
            while queue:
                free = [i for i, s in enumerate(slots) if s is None]
                if not free:
                    return
                i = free[0]
                req = queue.pop(0)
                res = self.cache.lookup(req.emb, cid=req.cid,
                                        top1=peeked.get(req.rid))
                if res.hit:          # store unchanged since peek: rare race
                    serve_hit(req, res)
                    continue
                slots[i] = req
                req.t_sched = time.perf_counter()
                # (prefill folded into decode slots for simplicity: prompt
                # tokens are fed one per step — fine at smoke scale)
                req._feed = list(req.tokens)
                pos[i] = 0
                cur[i] = req._feed.pop(0)
                budget[i] = ecfg.max_new_tokens

        try_fill()
        while any(s is not None for s in slots):
            batch = {"tokens": jnp.asarray(cur[:, None]),
                     "pos": jnp.asarray(pos)}
            nxt, _, cache = self.decode(self.params, cache, batch)
            nxt = np.asarray(nxt)
            self._gen["batches"] += 1
            for i, s in enumerate(slots):
                if s is None:
                    continue
                pos[i] += 1
                if s._feed:                      # still consuming the prompt
                    cur[i] = s._feed.pop(0)
                    continue
                tok = int(nxt[i])
                if not s.out_tokens:
                    s.t_first = time.perf_counter()
                s.out_tokens.append(tok)
                self._gen["generated_tokens"] += 1
                budget[i] -= 1
                if budget[i] <= 0 or pos[i] >= ecfg.max_seq - 1:
                    s.done = True
                    s.t_done = time.perf_counter()
                    self.cache.admit(s.cid, s.emb,
                                     payload=list(s.out_tokens))
                    self._finish(s, "generated")
                    done.append(s)
                    slots[i] = None
                else:
                    cur[i] = tok
            try_fill()
        self.cache.flush()           # settle admissions queued in the tail
        return sorted(done, key=lambda r: r.rid)
