"""Serving engine: continuous batching + RAC semantic cache front-end.

Request path (the paper's semantic-cache setting, §2):
  1. embed the query (synthetic embedding space offline; a real deployment
     plugs a sentence encoder into ``embed_fn``);
  2. semantic lookup against resident entries — Top-1 cosine ≥ tau_hit is a
     hit (kernels/ops.sim_top1 is the device path) → return cached response,
     zero model compute;
  3. miss → schedule for generation under continuous batching; on
     completion, admit (query-embedding, response) into the cache, evicting
     by RAC Value when full (core/rac.py drives the decision).

The KV-prefix instantiation rides underneath via
:class:`repro.serving.kv_manager.KVBlockManager` for multi-turn requests.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rac import RACPolicy
from repro.core.store import ResidentStore
from repro.core.types import Request
from repro.models import Model, build_model, make_decode_step
from repro.models.config import ModelConfig


@dataclasses.dataclass
class EngineConfig:
    cache_capacity: int = 512
    tau_hit: float = 0.85
    max_new_tokens: int = 16
    max_batch: int = 8            # continuous-batching slot count
    max_seq: int = 256
    emb_dim: int = 64


@dataclasses.dataclass
class RequestState:
    rid: int
    cid: int
    emb: np.ndarray
    tokens: list
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    cached: bool = False
    t_submit: float = 0.0
    t_done: float = 0.0


class ServingEngine:
    def __init__(self, model_cfg: ModelConfig, ecfg: EngineConfig,
                 params=None, rng=None, policy_kwargs: Optional[dict] = None):
        self.cfg = ecfg
        self.model = build_model(model_cfg)
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        self.params = params if params is not None else self.model.init(rng)
        self.decode = jax.jit(make_decode_step(self.model))
        # semantic cache (RAC-managed)
        self.store = ResidentStore(ecfg.cache_capacity, ecfg.emb_dim)
        self.policy = RACPolicy(ecfg.cache_capacity, self.store,
                                **(policy_kwargs or {}))
        self.responses: dict[int, list] = {}      # cid -> cached response
        self.t = 0
        self.stats = {"hits": 0, "misses": 0, "generated_tokens": 0,
                      "batches": 0}

    # -- cache front-end ----------------------------------------------
    def _lookup(self, emb: np.ndarray) -> int:
        cid, sim = self.store.nearest(emb)
        return cid if sim >= self.cfg.tau_hit else -1

    def _admit(self, req: RequestState):
        self.responses[req.cid] = list(req.out_tokens)
        if req.cid not in self.store:
            self.store.insert(req.cid, req.emb)
            self.policy.on_admit(req.cid,
                                 Request(t=self.t, cid=req.cid, emb=req.emb),
                                 self.t)
            while len(self.store) > self.cfg.cache_capacity:
                victim = self.policy.victim(self.t)
                self.store.remove(victim)
                self.responses.pop(victim, None)

    # -- continuous batching -------------------------------------------
    def run(self, requests: list[tuple[int, np.ndarray, list]]) -> list[RequestState]:
        """Process requests: (cid, embedding, prompt_tokens).  Returns the
        completed RequestState list (cache hits answer immediately)."""
        ecfg = self.cfg
        pending = [RequestState(rid=i, cid=c, emb=e, tokens=list(tk),
                                t_submit=time.perf_counter())
                   for i, (c, e, tk) in enumerate(requests)]
        done: list[RequestState] = []
        slots: list[Optional[RequestState]] = [None] * ecfg.max_batch

        cache = self.model.init_cache(ecfg.max_batch, ecfg.max_seq)
        pos = np.zeros(ecfg.max_batch, np.int32)
        cur = np.zeros(ecfg.max_batch, np.int32)
        budget = np.zeros(ecfg.max_batch, np.int32)
        queue = list(pending)

        def try_fill():
            while queue:
                req = queue[0]
                if not hasattr(req, "_missed"):
                    # lookup exactly once per request arrival
                    self.t += 1
                    hit = self._lookup(req.emb)
                    if hit >= 0:
                        queue.pop(0)
                        self.policy.on_hit(
                            hit, Request(t=self.t, cid=hit, emb=req.emb),
                            self.t)
                        req.out_tokens = list(self.responses.get(hit, []))
                        req.done = True
                        req.cached = True
                        req.t_done = time.perf_counter()
                        self.stats["hits"] += 1
                        done.append(req)
                        continue
                    req._missed = True
                    self.stats["misses"] += 1
                free = [i for i, s in enumerate(slots) if s is None]
                if not free:
                    return
                i = free[0]
                queue.pop(0)
                slots[i] = req
                # (prefill folded into decode slots for simplicity: prompt
                # tokens are fed one per step — fine at smoke scale)
                req._feed = list(req.tokens)
                pos[i] = 0
                cur[i] = req._feed.pop(0)
                budget[i] = ecfg.max_new_tokens

        try_fill()
        while any(s is not None for s in slots):
            batch = {"tokens": jnp.asarray(cur[:, None]),
                     "pos": jnp.asarray(pos)}
            nxt, _, cache = self.decode(self.params, cache, batch)
            nxt = np.asarray(nxt)
            self.stats["batches"] += 1
            for i, s in enumerate(slots):
                if s is None:
                    continue
                pos[i] += 1
                if s._feed:                      # still consuming the prompt
                    cur[i] = s._feed.pop(0)
                    continue
                tok = int(nxt[i])
                s.out_tokens.append(tok)
                self.stats["generated_tokens"] += 1
                budget[i] -= 1
                if budget[i] <= 0 or pos[i] >= ecfg.max_seq - 1:
                    s.done = True
                    s.t_done = time.perf_counter()
                    self._admit(s)
                    done.append(s)
                    slots[i] = None
                else:
                    cur[i] = tok
            try_fill()
        return sorted(done, key=lambda r: r.rid)
