"""RAC-scored paged KV-block manager (the paper's KV-cache instantiation).

Prefix blocks form a radix tree (SGLang-style): a cached prefix of tokens
maps to a chain of fixed-size blocks; a new request reuses the longest
cached prefix ("compositional content equivalence", paper §2).  Eviction
under block pressure uses RAC's Value = TP(topic)·TSI(block):

  - each *root* block routes to a topic by its prefix embedding; child
    blocks inherit the topic (a conversation = a topic episode);
  - the radix parent edge IS the dependency link — dep(parent) accumulates
    child hit mass exactly as Alg. 3 does via DetectParent;
  - structural validity (SGLang: children must be evicted before parents)
    is preserved by masking blocks with live children out of the victim
    scan — RAC's TSI already biases the same way (Theorem 1), the mask
    makes it a hard constraint.

Host-side data structure (like production engines); the device-side scoring
path is kernels/ops.rac_value over the block table.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Block:
    bid: int
    parent: int                  # -1 for root
    tokens: tuple                # the token slice this block covers
    topic: int = -1
    freq: float = 0.0
    dep: float = 0.0
    last_t: int = -1
    children: set = dataclasses.field(default_factory=set)

    @property
    def tsi(self) -> float:
        return self.freq + self.dep


class KVBlockManager:
    def __init__(self, n_blocks: int, block_tokens: int = 16, *,
                 alpha: float = 0.001, lam: float = 2.0):
        self.n_blocks = n_blocks
        self.block_tokens = block_tokens
        self.alpha = alpha
        self.lam = lam
        self.blocks: dict[int, Block] = {}
        self.root_index: dict[tuple, int] = {}     # token-slice -> root bid
        self.child_index: dict[tuple[int, tuple], int] = {}
        self.free: list[int] = list(range(n_blocks - 1, -1, -1))
        # topic TP state (persistent, Alg. 2 Data)
        self.tp_last: dict[int, float] = {}
        self.t_last: dict[int, int] = {}
        self.t = 0

    # -- topic handling (one conversation root = one topic) ---------------
    def _refresh_tp(self, topic: int):
        tp = self.tp_last.get(topic, 0.0)
        tl = self.t_last.get(topic, self.t)
        self.tp_last[topic] = 0.5 ** (self.alpha * (self.t - tl)) * tp + 1.0
        self.t_last[topic] = self.t

    def tp_now(self, topic: int) -> float:
        tp = self.tp_last.get(topic, 0.0)
        tl = self.t_last.get(topic, self.t)
        return 0.5 ** (self.alpha * (self.t - tl)) * tp

    # -- prefix match / insert --------------------------------------------
    def match_prefix(self, tokens: list[int]) -> tuple[list[int], int]:
        """Longest cached block-chain prefix.  Returns (bids, n_tokens)."""
        bids: list[int] = []
        pos = 0
        parent = -1
        while pos + self.block_tokens <= len(tokens):
            key = tuple(tokens[pos:pos + self.block_tokens])
            bid = (self.root_index.get(key) if parent < 0
                   else self.child_index.get((parent, key)))
            if bid is None:
                break
            bids.append(bid)
            parent = bid
            pos += self.block_tokens
        return bids, pos

    def on_request(self, tokens: list[int], topic: int | None = None) -> dict:
        """Serve one request's prefix: hit blocks get Alg.3 updates; missing
        blocks are allocated (evicting by Value when full)."""
        self.t += 1
        bids, pos = self.match_prefix(tokens)
        hit_tokens = pos
        # topic: from the matched root or a fresh label per new conversation
        if bids:
            tpc = self.blocks[bids[0]].topic
        else:
            tpc = topic if topic is not None else (max(
                self.tp_last.keys(), default=-1) + 1)
        self._refresh_tp(tpc)
        for bid in bids:                      # hits: freq + dep cascade
            b = self.blocks[bid]
            b.freq += 1
            b.last_t = self.t
            if b.parent >= 0 and b.parent in self.blocks:
                self.blocks[b.parent].dep += 1
        parent = bids[-1] if bids else -1
        new_bids = []
        while pos + self.block_tokens <= len(tokens):
            key = tuple(tokens[pos:pos + self.block_tokens])
            bid = self._alloc(parent, key, tpc)
            if bid < 0:
                break                          # no evictable block
            new_bids.append(bid)
            parent = bid
            pos += self.block_tokens
        return {"hit_blocks": bids, "new_blocks": new_bids,
                "hit_tokens": hit_tokens, "topic": tpc}

    def _alloc(self, parent: int, key: tuple, topic: int) -> int:
        if not self.free:
            victim = self._find_victim(exclude=parent)
            if victim < 0:
                return -1
            self._evict(victim)
        bid = self.free.pop()
        b = Block(bid=bid, parent=parent, tokens=key, topic=topic,
                  freq=1.0, last_t=self.t)
        self.blocks[bid] = b
        if parent < 0:
            self.root_index[key] = bid
        else:
            self.child_index[(parent, key)] = bid
            p = self.blocks.get(parent)
            if p is not None:
                p.children.add(bid)
                p.dep += 1.0                  # new link: Alg.3 new=1 path
        return bid

    def _find_victim(self, exclude: int = -1) -> int:
        """argmin TP(topic)·TSI over leaf blocks (children-first order).
        ``exclude`` protects the chain tip currently being extended."""
        best, best_v = -1, None
        for bid, b in self.blocks.items():
            if b.children or bid == exclude:
                continue                      # structural validity (radix)
            v = (self.tp_now(b.topic) * (b.freq + self.lam * b.dep),
                 b.last_t, bid)
            if best_v is None or v < best_v:
                best, best_v = bid, v
        return best

    def _evict(self, bid: int):
        b = self.blocks.pop(bid)
        if b.parent >= 0:
            self.child_index.pop((b.parent, b.tokens), None)
            p = self.blocks.get(b.parent)
            if p is not None:
                p.children.discard(bid)
        else:
            self.root_index.pop(b.tokens, None)
        self.free.append(bid)

    @property
    def used(self) -> int:
        return len(self.blocks)
