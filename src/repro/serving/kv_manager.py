"""RAC-scored paged KV-block manager (the paper's KV-cache instantiation).

Prefix blocks form a radix tree (SGLang-style): a cached prefix of tokens
maps to a chain of fixed-size blocks; a new request reuses the longest
cached prefix ("compositional content equivalence", paper §2).  Eviction
under block pressure uses RAC's Value = TP(topic)·TSI(block):

  - each *root* block routes to a topic by its conversation; child blocks
    inherit the topic (a conversation = a topic episode);
  - the radix parent edge IS the dependency link — dep(parent) accumulates
    child hit mass exactly as Alg. 3 does via DetectParent;
  - structural validity (SGLang: children must be evicted before parents)
    is preserved by masking blocks with live children out of the victim
    scan — RAC's TSI already biases the same way (Theorem 1), the mask
    makes it a hard constraint.

:class:`KVBlockManager` is built ON the unified cache facade: it owns the
radix *tree* (token keys, prefix matching) but delegates residency,
admission, eviction scoring, payloads, metrics, and hooks to a
content-mode :class:`repro.cache.SemanticCache` running
:class:`repro.core.radix.RadixRACPolicy`.  Victim selection is one
batched ``rac_value`` call through the cache backend — host numpy or the
device kernel — so block eviction and response eviction share one
metrics/hook/checkpoint surface and one scoring path.

:class:`LegacyKVBlockManager` is the original self-contained host
implementation, kept as the decision-parity oracle
(``tests/test_kv_facade.py`` replays token traces through both).
"""
from __future__ import annotations

import copy
import dataclasses

import numpy as np

from repro.cache import CacheConfig, SemanticCache


@dataclasses.dataclass
class Block:
    bid: int
    parent: int                  # -1 for root
    tokens: tuple                # the token slice this block covers
    topic: int = -1
    freq: float = 0.0
    dep: float = 0.0
    last_t: int = -1
    children: set = dataclasses.field(default_factory=set)

    @property
    def tsi(self) -> float:
        return self.freq + self.dep


class KVBlockManager:
    """Radix prefix-block cache behind the :class:`SemanticCache` facade.

    The manager walks/updates the radix indexes; every residency decision
    (hit bookkeeping, admission, victim election) goes through the
    facade.  Block ids are monotone uids — ``blocks``/``root_index``/
    ``child_index`` mirror the tree for prefix matching and tests; the
    authoritative scoring state lives in the policy's slabs.
    """

    def __init__(self, n_blocks: int, block_tokens: int = 16, *,
                 alpha: float = 0.001, lam: float = 2.0,
                 backend: str = "numpy", use_pallas: bool = False):
        self.n_blocks = n_blocks
        self.block_tokens = block_tokens
        self.cache = SemanticCache(CacheConfig(
            capacity=n_blocks, dim=1, hit_mode="content",
            backend=backend, policy="RadixRAC", use_pallas=use_pallas,
            policy_kwargs={"alpha": alpha, "lam": lam}))
        self._emb = np.zeros(1, dtype=np.float32)   # content mode: unused
        self.blocks: dict[int, Block] = {}
        self.root_index: dict[tuple, int] = {}     # token-slice -> root bid
        self.child_index: dict[tuple[int, tuple], int] = {}
        self._next_bid = 0
        self._evicted_now: list[int] = []          # victims, current request
        self.t = 0
        self.cache.subscribe("evict", self._on_evict)

    @property
    def policy(self):
        return self.cache.policy

    @property
    def used(self) -> int:
        return len(self.cache)

    # -- prefix match / insert --------------------------------------------
    def match_prefix(self, tokens: list[int]) -> tuple[list[int], int]:
        """Longest cached block-chain prefix.  Returns (bids, n_tokens)."""
        bids: list[int] = []
        pos = 0
        parent = -1
        while pos + self.block_tokens <= len(tokens):
            key = tuple(tokens[pos:pos + self.block_tokens])
            bid = (self.root_index.get(key) if parent < 0
                   else self.child_index.get((parent, key)))
            if bid is None:
                break
            bids.append(bid)
            parent = bid
            pos += self.block_tokens
        return bids, pos

    def on_request(self, tokens: list[int], topic: int | None = None) -> dict:
        """Serve one request's prefix: hit blocks get Alg.3 updates through
        the facade; missing blocks are admitted (evicting by Value when
        full).  Returns hit/new block ids plus the victims this request
        caused."""
        self.t += 1
        bids, pos = self.match_prefix(tokens)
        hit_tokens = pos
        # topic: from the matched root or a fresh label per new conversation
        tpc = self.blocks[bids[0]].topic if bids else topic
        tpc = self.policy.touch_topic(tpc, self.t)       # Alg. 2, once/request
        self._evicted_now = []
        for bid in bids:                      # hits: the facade drives the
            self.cache.lookup(self._emb, cid=bid, t=self.t)   # Alg.3 cascade
        parent = bids[-1] if bids else -1
        new_bids = []
        while pos + self.block_tokens <= len(tokens):
            key = tuple(tokens[pos:pos + self.block_tokens])
            bid = self._alloc(parent, key, tpc)
            if bid < 0:
                break                          # no evictable block
            new_bids.append(bid)
            parent = bid
            pos += self.block_tokens
        return {"hit_blocks": bids, "new_blocks": new_bids,
                "hit_tokens": hit_tokens, "topic": tpc,
                "evicted": self._evicted_now}

    def _alloc(self, parent: int, key: tuple, topic: int) -> int:
        bid = self._next_bid
        self._next_bid += 1
        self.cache.lookup(self._emb, cid=bid, t=self.t)   # charge the miss
        self.policy.stage(topic=topic, parent=parent)
        evicted = self.cache.admit(bid, self._emb, payload=key, t=self.t)
        self.policy.protect.clear()
        if bid in evicted:
            return -1            # every block structurally protected: fail
        # the mirror records STRUCTURE only (tokens/parent/children/topic
        # for prefix matching); freq/dep/last_t live in the policy slabs
        b = Block(bid=bid, parent=parent, tokens=key, topic=topic)
        self.blocks[bid] = b
        if parent < 0:
            self.root_index[key] = bid
        else:
            self.child_index[(parent, key)] = bid
            p = self.blocks.get(parent)
            if p is not None:
                p.children.add(bid)
        return bid

    # -- checkpoint/restore ------------------------------------------------
    def checkpoint(self) -> dict:
        """Snapshot the facade state AND the radix mirror together (the
        facade's checkpoint alone would leave the mirror claiming prefix
        hits for blocks the restored cache no longer holds)."""
        return {"cache": self.cache.checkpoint(),
                "mirror": copy.deepcopy(
                    (self.blocks, self.root_index, self.child_index,
                     self._next_bid, self.t))}

    def restore(self, state: dict):
        self.cache.restore(state["cache"])
        (self.blocks, self.root_index, self.child_index,
         self._next_bid, self.t) = copy.deepcopy(state["mirror"])

    def _on_evict(self, ev):
        """Facade victim applied: prune the radix mirror."""
        b = self.blocks.pop(ev.cid, None)
        if b is None:
            return                            # self-evicted fresh block
        self._evicted_now.append(ev.cid)
        if b.parent >= 0:
            self.child_index.pop((b.parent, b.tokens), None)
            p = self.blocks.get(b.parent)
            if p is not None:
                p.children.discard(ev.cid)
        else:
            self.root_index.pop(b.tokens, None)


class LegacyKVBlockManager:
    """The pre-facade host implementation (self-contained TP/TSI scoring
    over host dicts).  Kept verbatim as the parity oracle for the
    facade-routed manager."""

    def __init__(self, n_blocks: int, block_tokens: int = 16, *,
                 alpha: float = 0.001, lam: float = 2.0):
        self.n_blocks = n_blocks
        self.block_tokens = block_tokens
        self.alpha = alpha
        self.lam = lam
        self.blocks: dict[int, Block] = {}
        self.root_index: dict[tuple, int] = {}     # token-slice -> root bid
        self.child_index: dict[tuple[int, tuple], int] = {}
        self.free: list[int] = list(range(n_blocks - 1, -1, -1))
        # topic TP state (persistent, Alg. 2 Data)
        self.tp_last: dict[int, float] = {}
        self.t_last: dict[int, int] = {}
        self.t = 0

    # -- topic handling (one conversation root = one topic) ---------------
    def _refresh_tp(self, topic: int):
        tp = self.tp_last.get(topic, 0.0)
        tl = self.t_last.get(topic, self.t)
        self.tp_last[topic] = 0.5 ** (self.alpha * (self.t - tl)) * tp + 1.0
        self.t_last[topic] = self.t

    def tp_now(self, topic: int) -> float:
        tp = self.tp_last.get(topic, 0.0)
        tl = self.t_last.get(topic, self.t)
        return 0.5 ** (self.alpha * (self.t - tl)) * tp

    # -- prefix match / insert --------------------------------------------
    def match_prefix(self, tokens: list[int]) -> tuple[list[int], int]:
        """Longest cached block-chain prefix.  Returns (bids, n_tokens)."""
        bids: list[int] = []
        pos = 0
        parent = -1
        while pos + self.block_tokens <= len(tokens):
            key = tuple(tokens[pos:pos + self.block_tokens])
            bid = (self.root_index.get(key) if parent < 0
                   else self.child_index.get((parent, key)))
            if bid is None:
                break
            bids.append(bid)
            parent = bid
            pos += self.block_tokens
        return bids, pos

    def on_request(self, tokens: list[int], topic: int | None = None) -> dict:
        """Serve one request's prefix: hit blocks get Alg.3 updates; missing
        blocks are allocated (evicting by Value when full)."""
        self.t += 1
        bids, pos = self.match_prefix(tokens)
        hit_tokens = pos
        # topic: from the matched root or a fresh label per new conversation
        if bids:
            tpc = self.blocks[bids[0]].topic
        else:
            tpc = topic if topic is not None else (max(
                self.tp_last.keys(), default=-1) + 1)
        self._refresh_tp(tpc)
        for bid in bids:                      # hits: freq + dep cascade
            b = self.blocks[bid]
            b.freq += 1
            b.last_t = self.t
            if b.parent >= 0 and b.parent in self.blocks:
                self.blocks[b.parent].dep += 1
        parent = bids[-1] if bids else -1
        new_bids = []
        while pos + self.block_tokens <= len(tokens):
            key = tuple(tokens[pos:pos + self.block_tokens])
            bid = self._alloc(parent, key, tpc)
            if bid < 0:
                break                          # no evictable block
            new_bids.append(bid)
            parent = bid
            pos += self.block_tokens
        return {"hit_blocks": bids, "new_blocks": new_bids,
                "hit_tokens": hit_tokens, "topic": tpc}

    def _alloc(self, parent: int, key: tuple, topic: int) -> int:
        if not self.free:
            victim = self._find_victim(exclude=parent)
            if victim < 0:
                return -1
            self._evict(victim)
        bid = self.free.pop()
        b = Block(bid=bid, parent=parent, tokens=key, topic=topic,
                  freq=1.0, last_t=self.t)
        self.blocks[bid] = b
        if parent < 0:
            self.root_index[key] = bid
        else:
            self.child_index[(parent, key)] = bid
            p = self.blocks.get(parent)
            if p is not None:
                p.children.add(bid)
                p.dep += 1.0                  # new link: Alg.3 new=1 path
        return bid

    def _find_victim(self, exclude: int = -1) -> int:
        """argmin TP(topic)·TSI over leaf blocks (children-first order).
        ``exclude`` protects the chain tip currently being extended."""
        best, best_v = -1, None
        for bid, b in self.blocks.items():
            if b.children or bid == exclude:
                continue                      # structural validity (radix)
            v = (self.tp_now(b.topic) * (b.freq + self.lam * b.dep),
                 b.last_t, bid)
            if best_v is None or v < best_v:
                best, best_v = bid, v
        return best

    def _evict(self, bid: int):
        b = self.blocks.pop(bid)
        if b.parent >= 0:
            self.child_index.pop((b.parent, b.tokens), None)
            p = self.blocks.get(b.parent)
            if p is not None:
                p.children.discard(bid)
        else:
            self.root_index.pop(b.tokens, None)
        self.free.append(bid)

    @property
    def used(self) -> int:
        return len(self.blocks)
