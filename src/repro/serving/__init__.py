from .engine import EngineConfig, ServingEngine
from .kv_manager import KVBlockManager

__all__ = ["EngineConfig", "ServingEngine", "KVBlockManager"]
