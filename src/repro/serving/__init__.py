"""Event-driven serving stack: both RAC instantiations behind one facade.

The paper's two deployments of relation-aware caching are served here,
and BOTH route every cache decision through
:class:`repro.cache.SemanticCache` — the facade is the single owner of
lookup, admission, and eviction in the repo:

  - **Query-level response cache** — :class:`ServingEngine` runs
    continuous batching with a semantic-mode facade in front: the waiting
    queue is scored in one batched peek, incremental rescans go through a
    row-restricted backend peek, and completed responses are *queued* for
    admission (``EngineConfig.async_admit``) so generation slots never
    block on eviction scoring; the queue is flushed at batch boundaries
    with outputs identical to synchronous admission.
  - **KV prefix-block cache** — :class:`KVBlockManager` keeps the radix
    tree (SGLang-style compositional prefix reuse) but delegates
    residency, Alg. 3 TSI bookkeeping, and batched TP·TSI victim scoring
    to a content-mode facade running
    :class:`repro.core.radix.RadixRACPolicy`; children-first structural
    validity is a hard mask in the backend's ``rac_value_masked`` scan.

Block eviction and response eviction therefore share one metrics, hook,
checkpoint, and device-scoring surface.  :class:`LegacyKVBlockManager`
is the pre-facade host implementation, kept as the decision-parity
oracle.
"""
from .engine import EngineConfig, ServingEngine
from .kv_manager import KVBlockManager, LegacyKVBlockManager

__all__ = ["EngineConfig", "ServingEngine", "KVBlockManager",
           "LegacyKVBlockManager"]
