"""Assigned architecture configs (exact assignment numbers) + the paper's
serving config.  ``get_config(arch_id)`` returns the full ModelConfig;
``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of that (arch × shape) cell — no device allocation.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, SHAPES, ShapeConfig

ARCH_IDS = [
    "gemma_7b", "qwen15_110b", "smollm_360m", "nemotron4_340b",
    "deepseek_v2_lite_16b", "grok1_314b", "hymba_15b", "xlstm_125m",
    "whisper_medium", "internvl2_26b",
]

# canonical assignment ids -> module names
ALIASES = {
    "gemma-7b": "gemma_7b",
    "qwen1.5-110b": "qwen15_110b",
    "smollm-360m": "smollm_360m",
    "nemotron-4-340b": "nemotron4_340b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "grok-1-314b": "grok1_314b",
    "hymba-1.5b": "hymba_15b",
    "xlstm-125m": "xlstm_125m",
    "whisper-medium": "whisper_medium",
    "internvl2-26b": "internvl2_26b",
    "paper": "paper",
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch.replace("-", "_").replace(".", ""))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def shape_cells(cfg: ModelConfig) -> list[str]:
    """The assigned shape cells this arch runs (skips noted in DESIGN.md)."""
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        cells.append("long_500k")
    return cells


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """ShapeDtypeStructs for every model input of this cell (weak-type
    correct, shardable, no allocation).  For decode shapes the KV/state
    cache structs are included under "cache"."""
    from repro.models.model import Model

    sc: ShapeConfig = SHAPES[shape]
    b, s = sc.global_batch, sc.seq_len
    i32 = jnp.int32
    specs: dict = {}
    if sc.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    elif sc.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    else:  # decode: one new token against a cache of seq_len
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
        specs["pos"] = jax.ShapeDtypeStruct((b,), i32)
        specs["cache"] = Model(cfg).cache_shape_structs(b, s)
    if cfg.frontend == "audio":
        if sc.kind == "decode":
            # encoder ran at prefill; decode consumes its cached output
            specs["enc_out"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_model), cfg.cdtype)
        else:
            specs["audio_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_model), cfg.cdtype)
    if cfg.frontend == "vision" and sc.kind != "decode":
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_frontend_tokens, cfg.d_model), cfg.cdtype)
    return specs
