"""deepseek-v2-lite-16b [moe]: 27L d_model=2048 16H d_ff=1408(expert)
vocab=102400, MoE 64e top-6, MLA kv_lora=512 [arXiv:2405.04434; hf].

Assignment header says "MoE 64e top-6"; the note mentions "2 shared+160
routed" (the full V2).  We follow the header: 64 routed + 2 shared, top-6.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab_size=102_400, mlp="swiglu",
    attention="mla", kv_lora_rank=512, rope_head_dim=64,
    n_experts=64, n_shared_experts=2, top_k=6, expert_d_ff=1408,
)
