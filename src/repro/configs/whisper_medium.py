"""whisper-medium [audio]: 24L enc + 24L dec, d_model=1024 16H d_ff=4096
vocab=51865 — enc-dec, conv frontend STUB (input_specs provides
precomputed frame embeddings, 1500 frames = 30 s) [arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=51_865, mlp="gelu",
    n_enc_layers=24, frontend="audio", n_frontend_tokens=1500,
)
