"""The paper's own serving configuration: RAC semantic-cache front-end over
a small production LM (we use the smollm-360m backbone as the served model
in examples/serve_semantic_cache.py) plus the RAC hyperparameters of §4.2.
"""
from repro.models.config import ModelConfig

RAC_DEFAULTS = dict(
    tau_route=0.65,     # topic routing gate (paper couples hit/route at 0.85;
                        # see DESIGN.md §6 on decoupling)
    tau_edge=0.60,      # edge-pruning threshold (paper §4.2)
    alpha=0.001,        # TP decay
    lam=2.0,            # structural weight
    lookback=64,        # DetectParent window T
    shortlist_k=8,      # ANN shortlist (Alg. 4)
)
TAU_HIT = 0.85          # semantic-equivalence hit threshold (paper §4.2)

CONFIG = ModelConfig(
    name="paper-served-lm", family="dense",
    n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, head_dim=64,
    d_ff=2560, vocab_size=49_152, mlp="swiglu",
)
