"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT STUB (input_specs provides pre-projected patch
embeddings, 256 tokens) + InternLM2 backbone [arXiv:2404.16821; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=92_553, mlp="swiglu",
    frontend="vision", n_frontend_tokens=256,
)
