"""Hillclimb variant of smollm-360m (§Perf iteration): q heads padded
15→16 and kv heads 5→8 so attention shards over the 16-way TP axis
(baseline replicates all attention compute per device).  +4.5% params.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m-hc", family="dense",
    n_layers=32, d_model=960, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=2560, vocab_size=49_152, mlp="swiglu",
)
