"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads
[arXiv:2411.13676; hf].  Sliding-window attention (2048) on all layers +
parallel Mamba heads (the paper keeps 3 global-attn layers; we use the
sliding form everywhere so the arch is long_500k capable — noted in
DESIGN.md)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32_001, mlp="swiglu",
    attention="sliding", sliding_window=2048,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
)
