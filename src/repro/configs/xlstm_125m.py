"""xlstm-125m [ssm]: 12L d_model=768 4H vocab=50304 — sLSTM + mLSTM
blocks [arXiv:2405.04517; unverified].  sLSTM at block positions (5, 7),
mLSTM elsewhere (xLSTM[7:1]-style mix).  12 layers -> unrolled stack."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, head_dim=192,
    d_ff=0, vocab_size=50_304, attention="none",
    slstm_at=(5, 7), xlstm_expand=2,
    scan_layers=True,
)
