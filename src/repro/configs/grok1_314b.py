"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8e top-2 [hf:xai-org/grok-1; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=32768, vocab_size=131_072, mlp="geglu",
    n_experts=8, n_shared_experts=0, top_k=2, expert_d_ff=32768,
)
