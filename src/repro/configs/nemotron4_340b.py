"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000 — GQA, squared-ReLU [arXiv:2402.16819; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, head_dim=192,
    d_ff=73728, vocab_size=256_000, mlp="relu2",
)
