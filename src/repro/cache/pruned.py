"""Topic-pruned two-stage lookup: IVF-style candidate scan over RAC's
own topic structure (``CacheConfig.pruned_lookup``).

Every exact lookup touches all S resident rows — O(S·D) traffic per
query no matter how few rows could plausibly win.  But RAC already
maintains a pruning index for free: the journaled dense topic-
representative matrix (``PolicyTable.rep``).  The pruned path scores
the query against the (T, D) representatives first (T ≪ S), probes the
top-P topics, and scans only their member rows.

Decisions stay **identical** to the exact path by construction — this
module never trusts the routing heuristic.  Each per-query decision is
certified by a safety predicate built on a per-topic *spread* bound
(Cauchy–Schwarz: for any member ``x`` of topic ``t`` with
representative ``r_t`` and spread ``σ_t = max_x ‖x − r_t‖``,

    q·x  ≤  q·r_t + ‖q‖·‖x − r_t‖  ≤  q·r_t + ‖q‖·σ_t  =:  bound(q, t)

so the best row of an *unprobed* topic cannot beat that topic's bound).
Routing scores the augmented matrix ``[r_t | σ_t]`` against ``[q |
‖q‖]`` — one (T, D+1) matmul yields the bounds directly, and the top-P
*bounds* are the probe set (greedily minimising the strongest unprobed
bound).  Uncertifiable queries take an exact full-scan fallback,
counted in ``prune_stats["fallbacks"]`` and surfaced as the
``cache.prune_fallbacks`` tracker counter.

The topic→slots bucket index here (:class:`TopicBucketIndex`) is
CSR-style packed arrays rebuilt *incrementally* from the same mutation
journals the device mirrors sync against (store row journal +
``PolicyTable``'s ``dirty_slots_since`` / ``dirty_topics_since``), so
steady-state maintenance is O(mutated slots), not O(capacity).  See
``docs/pruned_lookup.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core.store import MutationJournal

# Finite "cannot win, cannot bound-block" sentinel for the spread column
# of memberless topics.  Finite (not -inf) so the routing matmul never
# produces inf·0 NaNs; -1e30 keeps the topic's bound astronomically
# negative, so it neither attracts probes nor blocks certification.
NEG = np.float32(-1e30)

# Spread inflation absorbing fp32 kernel evaluation error: the routing
# matmul and the candidate scan both run in fp32 (~1e-5 relative at
# D=128 unit rows); the bound is computed in float64 and padded before
# the fp32 cast so it stays an upper bound of every computed score.
_SPREAD_PAD_REL = 1.05
_SPREAD_PAD_ABS = 1e-4


@dataclasses.dataclass(frozen=True)
class PrunedLookupConfig:
    """Configuration for the topic-pruned candidate scan.

    ``probes`` is the number of topic buckets stage 2 scans per query
    (P).  ``tau_hit`` arms the certain-miss arm of the safety predicate
    (every topic bound and every scanned candidate below tau ⇒ certain
    miss); the facade copies its own ``tau_hit`` in for semantic-mode
    stores when left ``None``.

    ``max_scan_frac`` caps each query's gathered candidate rows at that
    fraction of the resident count (floored at ``min_scan_rows`` so
    small stores stay uncapped): probes are kept greedily in
    descending-bound order while the cumulative bucket rows fit the
    budget, and the first dropped probe's bound becomes the query's
    certification bound — wide-P queries landing in fat buckets degrade
    to fewer probes (at worst the tau short-circuit) instead of
    gathering more bytes than the exact scan would stream.  Capped
    queries are counted in ``prune_stats["capped"]``.  ``None`` disables
    the cap.  ``fused`` routes kernel backends through the
    device-resident fused pipeline (one launch from routing to certified
    decision; see ``docs/fused_pipeline.md``) — the staged multi-launch
    driver remains available with ``fused=False``.  ``fused_max_batch``
    is the chunk-size dispatch policy: the fused program gathers a full
    ``cap_c``-row candidate block per query, so past this batch width
    the staged driver's signature-grouped shared gathers win and wide
    chunks fall through to it.
    """
    probes: int = 2
    tau_hit: Optional[float] = None
    max_scan_frac: Optional[float] = 0.02
    min_scan_rows: int = 256
    fused: bool = True
    fused_max_batch: int = 16


def as_pruned_config(spec) -> Optional[PrunedLookupConfig]:
    """Normalize ``CacheConfig.pruned_lookup`` specs: ``None``/``False``
    → off, ``True`` → defaults, a dict → kwargs, or a ready config."""
    if spec is None or spec is False:
        return None
    if spec is True:
        return PrunedLookupConfig()
    if isinstance(spec, PrunedLookupConfig):
        return spec
    if isinstance(spec, dict):
        return PrunedLookupConfig(**spec)
    raise ValueError(f"bad pruned_lookup spec: {spec!r}")


def new_prune_stats() -> dict:
    """Zeroed pruned-scan ledger (always present in
    ``metrics_snapshot()["prune"]``, even with the path off)."""
    return {"scans": 0, "queries": 0, "fallbacks": 0, "probed_topics": 0,
            "scanned_rows": 0, "rows_exact": 0,
            "bytes_scanned": 0, "bytes_exact": 0, "capped": 0}


def account_prune(stats: dict, *, n_valid: int, dim: int, n_topics: int,
                  batch: int, probes: int, scanned_rows: int,
                  slab_bytes: int, n_fallback: int,
                  n_capped: int = 0) -> None:
    """Ledger one pruned batch scan.

    ``bytes_exact`` is what the exact path would have streamed (the fp32
    slab once per scan); ``bytes_scanned`` is the routing matrix plus the
    gathered candidate slabs actually read (``slab_bytes``, quantized
    gathers included by the caller), plus a whole exact slab per scan
    containing fallbacks.  ``scanned_rows`` / ``rows_exact`` are the
    per-query row-scoring counts (Σ_q |candidates(q)| vs batch·S) — the
    compute-side reduction the CI gate is on.
    """
    stats["scans"] += 1
    stats["queries"] += batch
    stats["fallbacks"] += n_fallback
    stats["capped"] += n_capped
    stats["probed_topics"] += probes
    stats["scanned_rows"] += scanned_rows
    stats["rows_exact"] += n_valid * batch
    stats["bytes_exact"] += n_valid * dim * 4
    stats["bytes_scanned"] += n_topics * (dim + 1) * 4 + slab_bytes
    if n_fallback:
        stats["bytes_scanned"] += n_valid * dim * 4


class TopicBucketIndex:
    """Incremental topic→slots bucket index with per-topic spread.

    Maintains, against the store/table mutation journals:

    - a slot-state vector (−2 = free slot, −1 = occupied but unassigned
      to any topic, t ≥ 0 = member of topic ``t``);
    - per-topic member sets packed into CSR arrays (``indptr`` /
      ``slot_ids``, members ascending) plus the ``unassigned`` bucket —
      occupied rows with no topic are in **every** candidate set, since
      no representative bounds them;
    - the augmented routing matrix ``aug`` of shape (T, D+1): row ``t``
      is ``[rep_t | σ_t_eff]`` with the inflated spread in the last
      column (memberless topics get ``[0…0, NEG]``).

    ``aug`` rows carry their own :class:`MutationJournal` (``log``) so
    device backends can mirror the routing matrix with the standard
    dirty-row scatter; a full rebuild swaps in a fresh journal, which
    foreign-lineage mirrors answer with a full upload.
    """

    def __init__(self):
        self.log = MutationJournal()
        self.aug: Optional[np.ndarray] = None          # (T, D+1) float32
        self.indptr = np.zeros(1, dtype=np.int64)
        self.slot_ids = np.zeros(0, dtype=np.int64)
        self.unassigned = np.zeros(0, dtype=np.int64)
        self.stats = {"full": 0, "incremental": 0, "slots": 0, "topics": 0}
        self._key = None              # (store.version, slot_ver, topic_ver)
        self._shape = None            # (n_slots, n_topic_rows, dim)
        self._state: Optional[np.ndarray] = None
        self._members: dict[int, set] = {}
        self._unassigned: set = set()
        self._csr_fresh = False
        self._cand_cache: dict[tuple, np.ndarray] = {}

    # ------------------------------------------------------------ mirror API
    @property
    def version(self) -> int:
        return self.log.version

    @property
    def key(self):
        """Identity of the last-synced (store, table) journal state.

        Device CSR mirrors must key on this triple, **not** on
        ``version``: membership churn confined to the unassigned bucket
        (e.g. evicting a topicless row) touches no aug row, so the aug
        journal doesn't move even though the CSR arrays changed."""
        return self._key

    def dirty_since(self, version: int):
        return self.log.dirty_since(version)

    # ---------------------------------------------------------------- sync
    def sync(self, store, table) -> "TopicBucketIndex":
        """Freshen the index against ``(store, table)``; no-op when the
        journal versions match the last sync."""
        key = (store.version, table.slot_version, table.topic_version)
        shape = (store.emb.shape[0], table.rep.shape[0], store.emb.shape[1])
        if key == self._key and shape == self._shape:
            return self
        incremental = self._key is not None and shape == self._shape
        if incremental:
            d_emb = store.dirty_since(self._key[0])
            d_slot = table.dirty_slots_since(self._key[1])
            d_topic = table.dirty_topics_since(self._key[2])
            incremental = (d_emb is not None and d_slot is not None
                           and d_topic is not None)
        if incremental:
            self._apply(store, table, d_emb, d_slot, d_topic)
        else:
            self._rebuild(store, table)
        self._key = key
        self._shape = shape
        return self

    def _rebuild(self, store, table) -> None:
        n_slots, dim = store.emb.shape
        n_top = table.rep.shape[0]
        state = np.full(n_slots, -2, dtype=np.int64)
        occ = np.flatnonzero(store.occ)
        state[occ] = np.where(table.topic_of[occ] >= 0,
                              table.topic_of[occ], -1)
        self._state = state
        self._unassigned = set(np.flatnonzero(state == -1).tolist())
        self._members = {int(t): set(np.flatnonzero(state == t).tolist())
                         for t in np.unique(state[state >= 0])}
        self.aug = np.zeros((n_top, dim + 1), dtype=np.float32)
        self.aug[:, -1] = NEG
        # fresh journal lineage: mirrors that synced the old aug see a
        # foreign journal and fall back to a full upload
        self.log = MutationJournal()
        for t in self._members:
            self._refresh_topic(t, store, table)
        self.log.bump()
        self.stats["full"] += 1
        self._csr_fresh = False
        self._cand_cache = {}

    def _apply(self, store, table, d_emb: set, d_slot: set,
               d_topic: set) -> None:
        state = self._state
        n_slots = state.shape[0]
        n_top = self.aug.shape[0]
        touched: set[int] = set()
        for slot in (d_emb | d_slot):
            if slot >= n_slots:
                continue
            old = int(state[slot])
            if store.occ[slot]:
                t = int(table.topic_of[slot])
                new = t if t >= 0 else -1
            else:
                new = -2
            if new != old:
                if old >= 0:
                    m = self._members.get(old)
                    if m:
                        m.discard(slot)
                    touched.add(old)
                elif old == -1:
                    self._unassigned.discard(slot)
                if new >= 0:
                    self._members.setdefault(new, set()).add(slot)
                    touched.add(new)
                elif new == -1:
                    self._unassigned.add(slot)
                state[slot] = new
                self._csr_fresh = False
            elif new >= 0 and slot in d_emb:
                # embedding rewritten in place within its bucket: the
                # spread may have grown
                touched.add(new)
        for t in d_topic:
            # representative moved (or topic retired/revived): every
            # member distance is stale
            if 0 <= t < n_top:
                touched.add(t)
        for t in touched:
            self._refresh_topic(t, store, table)
        self.stats["incremental"] += 1
        self.stats["slots"] += len(d_emb | d_slot)
        if touched:
            self._cand_cache = {}

    def _refresh_topic(self, t: int, store, table) -> None:
        """Recompute topic ``t``'s aug row ([rep | inflated spread], or
        the inert memberless row) and journal the mutation."""
        row = self.aug[t]
        members = self._members.get(t)
        if not members:
            row[:-1] = 0.0
            row[-1] = NEG
        else:
            slots = np.fromiter(members, dtype=np.int64, count=len(members))
            rep = table.rep[t].astype(np.float64)
            d = store.emb[slots].astype(np.float64) - rep
            spread = float(np.sqrt(np.max(np.sum(d * d, axis=1))))
            row[:-1] = table.rep[t]
            row[-1] = np.float32(spread * _SPREAD_PAD_REL + _SPREAD_PAD_ABS)
        self.log.stamp(t)
        self.stats["topics"] += 1

    # ------------------------------------------------------------ candidates
    def _pack_csr(self) -> None:
        n_top = self.aug.shape[0]
        counts = np.zeros(n_top + 1, dtype=np.int64)
        for t, members in self._members.items():
            counts[t + 1] = len(members)
        self.indptr = np.cumsum(counts)
        self.slot_ids = np.empty(int(self.indptr[-1]), dtype=np.int64)
        for t, members in self._members.items():
            self.slot_ids[self.indptr[t]:self.indptr[t + 1]] = \
                sorted(members)
        self.unassigned = np.fromiter(sorted(self._unassigned),
                                      dtype=np.int64,
                                      count=len(self._unassigned))
        self._csr_fresh = True
        self._cand_cache = {}

    def csr(self) -> tuple:
        """Fresh packed CSR view: ``(indptr, slot_ids, unassigned)``.
        Packs lazily if membership churned since the last pack."""
        if not self._csr_fresh:
            self._pack_csr()
        return self.indptr, self.slot_ids, self.unassigned

    def group_key(self, tids) -> tuple:
        """Canonical probe signature: sorted topic ids with non-empty
        buckets (empty buckets contribute no candidates and are dropped
        so batches group better)."""
        if not self._csr_fresh:
            self._pack_csr()
        return tuple(sorted(int(t) for t in np.unique(np.asarray(tids))
                            if self.indptr[t] < self.indptr[t + 1]))

    def candidate_rows(self, sig: tuple) -> np.ndarray:
        """Ascending slot ids of every candidate for probe signature
        ``sig``: the probed buckets' members plus the unassigned bucket.
        Buckets are disjoint, so concatenate + sort needs no dedup; the
        ascending order preserves the exact path's lower-slot tie rule."""
        if not self._csr_fresh:
            self._pack_csr()
        rows = self._cand_cache.get(sig)
        if rows is None:
            parts = [self.slot_ids[self.indptr[t]:self.indptr[t + 1]]
                     for t in sig]
            parts.append(self.unassigned)
            rows = np.sort(np.concatenate(parts))
            self._cand_cache[sig] = rows
        return rows


def route_topics_host(queries: np.ndarray, aug: np.ndarray, n_topics: int,
                      probes: int) -> tuple[np.ndarray, np.ndarray]:
    """Host (numpy) routing oracle: fp32 bound matmul + stable descending
    argsort over the live topics.  Routing need not be bit-identical
    across backends — it only picks *which* buckets to probe; the safety
    predicate certifies decisions regardless."""
    qn = np.linalg.norm(queries.astype(np.float32),
                        axis=1, keepdims=True).astype(np.float32)
    qa = np.concatenate([queries.astype(np.float32), qn], axis=1)
    scores = qa @ aug[:n_topics].T                       # (B, T) fp32
    k = min(probes + 1, n_topics)
    order = np.argsort(-scores, axis=1, kind="stable")[:, :k]
    vals = np.take_along_axis(scores, order, axis=1).astype(np.float64)
    return vals, order.astype(np.int64)


def resolve_pruned(cand_cids, cand_sims, bound, tau_hit,
                   exact_fn: Callable) -> tuple:
    """Certify each candidate-scan result against the unprobed bound.

    ``bound[i]`` is an upper bound on the true score of every row *not*
    in query ``i``'s candidate set.  Two arms:

    1. **Top-1 certified**: ``cand_sim > bound`` (strict) — no
       non-candidate can beat or tie it, and candidates were scanned
       ascending, so ``(cid, sim)`` is bit-equal to the exact path.
    2. **Miss certified**: ``cand_sim < tau`` and ``bound < tau`` — no
       row anywhere reaches the tau band; decision-equal (the reported
       best-effort sim may differ from the exact scan's).

    Anything else falls back to ``exact_fn`` (exact full scan) for those
    queries; the fallback count is returned for the ledger.
    """
    cids = np.asarray(cand_cids, dtype=np.int64).copy()
    sims = np.asarray(cand_sims, dtype=np.float64).copy()
    bound = np.asarray(bound, dtype=np.float64)
    safe = sims > bound
    if tau_hit is not None:
        safe |= (sims < tau_hit) & (bound < tau_hit)
    n_fb = int(sims.shape[0] - np.count_nonzero(safe))
    if n_fb:
        sel = np.flatnonzero(~safe)
        f_cids, f_sims = exact_fn(sel)
        cids[sel] = np.asarray(f_cids, dtype=np.int64)
        sims[sel] = np.asarray(f_sims, dtype=np.float64)
    sims = np.where(cids >= 0, sims, -np.inf)
    return cids, sims, n_fb


def pruned_top1_batch(store, table, queries: np.ndarray,
                      cfg: PrunedLookupConfig, idx: TopicBucketIndex,
                      stats: dict, *, route_fn: Callable,
                      scan_fn: Callable, exact_fn: Callable) -> tuple:
    """The backend-agnostic two-stage driver.

    ``route_fn(queries, aug, n_topics) -> (vals, tids)`` scores the
    (B, P+1) strongest topic *bounds* (vals descending; entries past the
    live-topic count are −inf).  ``scan_fn(sel, rows) -> (cids, sims,
    nbytes)`` scans queries ``queries[sel]`` against the gathered
    ascending candidate ``rows`` and reports the slab bytes it read.
    ``exact_fn(sel) -> (cids, sims)`` is the exact full scan used for
    uncertifiable queries.

    Queries sharing a probe signature are scanned as one group (one
    gather + one kernel launch).  When ``tau_hit`` is armed, a query
    whose *strongest* topic bound is already below tau short-circuits
    stage 2 entirely (no assigned row can reach tau — only the unbounded
    unassigned bucket still needs scanning).
    """
    idx.sync(store, table)
    b, dim = queries.shape
    n_top = int(table.topic_hwm)
    probes = int(cfg.probes)
    if n_top > 0:
        vals, tids = route_fn(queries, idx.aug, n_top)
        vals = np.asarray(vals, dtype=np.float64)
        tids = np.asarray(tids, dtype=np.int64)
        ub = (vals[:, probes].copy() if vals.shape[1] > probes
              else np.full(b, -np.inf))
        probe_vals = vals[:, :probes]
        probe_tids = tids[:, :probes]
    else:
        ub = np.full(b, -np.inf)
        probe_vals = np.zeros((b, 0))
        probe_tids = np.zeros((b, 0), dtype=np.int64)
    # certain-miss routing short-circuit: strongest bound < tau means no
    # assigned row can reach the band — probe nothing, scan unassigned
    skip = np.zeros(b, dtype=bool)
    if cfg.tau_hit is not None and probe_vals.shape[1] > 0:
        skip = probe_vals[:, 0] < cfg.tau_hit
        ub[skip] = probe_vals[skip, 0]
    budget = None
    if cfg.max_scan_frac is not None:
        budget = max(int(cfg.min_scan_rows),
                     int(cfg.max_scan_frac * store.hwm))
    groups: dict[tuple, list[int]] = {}
    n_probed = 0
    n_capped = 0
    empty_sig = ()
    for i in range(b):
        if skip[i]:
            sig = empty_sig
        else:
            live = probe_tids[i][np.isfinite(probe_vals[i])]
            if budget is not None and live.size:
                # adaptive probe cap: keep the longest descending-bound
                # prefix whose cumulative bucket rows fit the budget; the
                # first dropped probe's bound (≥ every later bound and ≥
                # the unprobed bound) becomes the certification bound
                indptr, _, _ = idx.csr()
                cnts = indptr[live + 1] - indptr[live]
                keep = int(np.searchsorted(np.cumsum(cnts), budget,
                                           side="right"))
                if keep < live.size:
                    n_capped += 1
                    ub[i] = probe_vals[i, keep]
                    live = live[:keep]
            sig = idx.group_key(live)
            n_probed += len(sig)
        groups.setdefault(sig, []).append(i)
    cids = np.full(b, -1, dtype=np.int64)
    sims = np.full(b, -np.inf)
    scanned = 0
    slab_bytes = 0
    for sig, members in groups.items():
        rows = idx.candidate_rows(sig)
        if rows.size == 0:
            continue
        sel = np.asarray(members, dtype=np.int64)
        scanned += rows.size * sel.size
        g_cids, g_sims, nbytes = scan_fn(sel, rows)
        cids[sel] = np.asarray(g_cids, dtype=np.int64)
        sims[sel] = np.asarray(g_sims, dtype=np.float64)
        slab_bytes += int(nbytes)
    out_cids, out_sims, n_fb = resolve_pruned(cids, sims, ub, cfg.tau_hit,
                                              exact_fn)
    account_prune(stats, n_valid=store.hwm, dim=dim, n_topics=n_top,
                  batch=b, probes=n_probed, scanned_rows=scanned,
                  slab_bytes=slab_bytes, n_fallback=n_fb,
                  n_capped=n_capped)
    return out_cids, out_sims
