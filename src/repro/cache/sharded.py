"""Sharded resident store: multi-device semantic-cache lookup.

Scales :class:`repro.cache.SemanticCache` capacity past one chip's HBM by
partitioning the resident slab row-wise across the devices of a 1-D
``("cache",)`` mesh (``repro.launch.mesh.make_cache_mesh``):

  - **Layout** — :class:`ShardedStore` keeps one contiguous ``(S·R, D)``
    slab viewed as ``(S, R, D)``: shard ``s`` owns rows
    ``[s·R, (s+1)·R)``.  Slot placement routes every new entry onto the
    least-loaded shard (ties → lowest shard id), and each shard tracks a
    local high-water mark so device lookups only score its locally-valid
    prefix (runtime ``n_valid``, scalar-prefetched into the kernel).
  - **Lookup** — :class:`ShardedKernelBackend` runs ``kernels/ops.sim_top1``
    per shard under ``shard_map`` (every device scores its own ``(R, D)``
    block against the replicated query batch), ``all_gather``\\ s the
    per-shard ``(val, local_idx)`` pairs and merges them with a single
    argmax-reduce over the shard axis into global ``(cid, sim)``.
  - **Eviction** — ``rac_value`` shards the resident-table entry axis over
    the same mesh (each device scores its chunk with the ``rac_value``
    kernel); ``shard_map`` stitches the chunks back into one value vector
    and the policy's deterministic ``(value, last-access, cid)`` lexsort
    takes the global min.  Doing the min inside the collective would lose
    those tie-breaks, so the merge hands back values, not a victim.
  - **Fallback** — with fewer devices than shards (e.g. a 1-device CPU
    box) the backend loops the identical per-shard kernel + argmax merge
    on one device, so hit/admit/evict decisions never depend on the
    machine: ``tests/test_cache_api.py`` asserts decision parity with the
    numpy backend for shard counts {1, 2, 4}.
  - **Checkpoint/restore** — all sharded state (slab, per-shard free lists,
    loads, high-water marks) lives in the store object; the facade's
    ``checkpoint()`` deep copy captures it with no backend cooperation.
    Device-side slabs are cached keyed by the store's globally-unique
    mutation ``version`` stamp, so a restored snapshot re-attaches to its
    uploaded slab for free and any divergence forces a re-upload.
"""
from __future__ import annotations

import numpy as np

from repro.core.store import ResidentStore
from repro.telemetry.tracing import annotate

from .types import DecisionBatch


class ShardedStore(ResidentStore):
    """Row-partitioned resident slab with least-loaded shard placement.

    ``n_shards`` shards of ``rows_per_shard = ceil((capacity+1)/n_shards)``
    rows each (the +1 is Alg. 1's insert-then-evict spare slot).  The numpy
    arrays are the plain :class:`ResidentStore` layout, so every host-side
    consumer (policies, the numpy backend, metrics) works unchanged — only
    slot *placement* differs.
    """

    def __init__(self, capacity: int, dim: int, n_shards: int = 1):
        n_shards = max(1, int(n_shards))
        rows = -(-(capacity + 1) // n_shards)          # ceil division
        super().__init__(capacity, dim, n_slots=rows * n_shards)
        self.n_shards = n_shards
        self.rows_per_shard = rows
        # per-shard LIFO free lists mirror the parent's slot-reuse order,
        # keeping each shard's occupied slots below its local high-water
        # mark; the parent's single free list is retired so no stale copy
        # rides along in checkpoints
        self._free.clear()
        self._free_by_shard = [list(range((s + 1) * rows - 1, s * rows - 1, -1))
                               for s in range(n_shards)]
        self.load = np.zeros(n_shards, dtype=np.int64)
        self.local_hwm = np.zeros(n_shards, dtype=np.int64)

    def shard_of(self, slot: int) -> int:
        return slot // self.rows_per_shard

    def shard_view(self) -> np.ndarray:
        """The slab as ``(n_shards, rows_per_shard, D)`` (a zero-copy view)."""
        return self.emb.reshape(self.n_shards, self.rows_per_shard, -1)

    def _alloc(self) -> int:
        shard = int(np.argmin(self.load))              # ties → lowest shard
        slot = self._free_by_shard[shard].pop()
        self.load[shard] += 1
        local = slot - shard * self.rows_per_shard
        if local + 1 > self.local_hwm[shard]:
            self.local_hwm[shard] = local + 1
        return slot

    def _release(self, slot: int):
        shard = self.shard_of(slot)
        self._free_by_shard[shard].append(slot)
        self.load[shard] -= 1


class ShardedKernelBackend:
    """Multi-device lookup/scoring over a :class:`ShardedStore`.

    ``n_shards=None`` means one shard per addressable device.  When the
    machine has at least ``n_shards`` devices the lookup runs under
    ``shard_map`` on a ``("cache",)`` mesh; otherwise a per-shard loop on
    one device computes the identical math (see module docstring).
    ``use_pallas=False`` routes through the jnp oracles, as in
    :class:`~repro.cache.backends.KernelBackend`.
    """

    name = "sharded"

    def __init__(self, n_shards: int | None = None, use_pallas: bool = True,
                 interpret: bool | None = None, q_pad: int = 8,
                 quantized=None, pruned=None):
        from .backends import _DeviceMirror
        from .pruned import (TopicBucketIndex, as_pruned_config,
                             new_prune_stats)
        from .quantized import (QuantizedSlabMirror, as_quantized_config,
                                new_quant_stats)
        self._n_shards = n_shards
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.q_pad = max(1, q_pad)
        self.quantized = as_quantized_config(quantized)
        self.quant_stats = new_quant_stats()
        # topic-pruned two-stage scan: the routing + gathered candidate
        # scans delegate to the dense KernelBackend body (small blocks —
        # same rationale as top1_rows below); only the exact-fallback leg
        # fans out across the mesh
        self.pruned = as_pruned_config(pruned)
        self.prune_stats = new_prune_stats()
        self._pidx = TopicBucketIndex()
        self._pidx_arena: dict[int, TopicBucketIndex] = {}
        self.route_table = None
        self.route_store = None
        self._route_mirror = _DeviceMirror({"aug": np.float32})
        self._mesh = None
        self._mesh_built = False
        self._lookup_fn = None
        self._multi_fn = None                      # arena stacked lookup
        self._arena_cache = None       # (version, rearranged slab, shape)
        self._arena_scatter_fn = None
        self._rac_fns: dict[float, object] = {}
        self._decide_fns: dict[float, object] = {}
        self._slab_cache: dict[int, tuple] = {}    # store.version -> (slab, nv)
        self._scatter_fn = None                    # dirty-row device update
        # quantized path: host int8 requantizer + its sharded device slab
        # cache (same version-keyed dirty-row scatter protocol as _slab);
        # the arena variants back the dense stacked delegation (see
        # top1_multi) with KernelBackend-compatible mirror attributes
        self._qhost = QuantizedSlabMirror()
        self._qhost_arena = QuantizedSlabMirror()
        self._q8_arena_mirror = _DeviceMirror({"q8": np.int8,
                                               "scale": np.float32,
                                               "l1": np.float32})
        # fused-pipeline delegation mirrors: the pruned pass hands the
        # whole batch to KernelBackend._fused_pruned_batch (unbound), which
        # expects the dense backend's mirror attributes on ``self`` — the
        # fp32/int8 single-device copies it launches against, the arena's
        # flat stacked slab, and the device CSR form of each bucket index
        self._store_mirror = _DeviceMirror({"emb": np.float32,
                                            "occ": np.int32})
        self._q8_mirror = _DeviceMirror({"q8": np.int8,
                                         "scale": np.float32,
                                         "l1": np.float32})
        self._arena_mirror = _DeviceMirror({"emb": np.float32})
        self._csr_mirror = _DeviceMirror({"indptr": np.int32,
                                          "slots": np.int32})
        self._csr_arena: dict[int, _DeviceMirror] = {}
        self._q8_slab_cache: dict[int, tuple] = {}
        self._q8_scatter_fn = None
        self._qlookup_fns: dict[int, object] = {}   # k -> shard_map lookup
        # observability for the incremental path: full uploads vs dirty-row
        # scatters, how many rows the scatters moved in total, and the
        # host→device bytes those transfers shipped
        self._sync = {"full": 0, "incremental": 0, "rows": 0, "bytes": 0}
        self._tracker = None                # telemetry sink (observation-only)
        self._sync_seen: dict[str, int] = {}   # last sync_stats flushed to it

    @property
    def sync_stats(self) -> dict:
        """Aggregate sync observability: the sharded slab caches' own
        ledger plus every dense-delegation device mirror (the arena int8
        mirror, the routing matrix, and the fused pipeline's fp32/int8/CSR
        copies) — their uploads land here alongside the fp32 slab
        traffic."""
        mirrors = (self._q8_arena_mirror, self._route_mirror,
                   self._store_mirror, self._q8_mirror, self._arena_mirror,
                   self._csr_mirror, *self._csr_arena.values())
        return {k: self._sync[k] + sum(m.stats[k] for m in mirrors)
                for k in ("full", "incremental", "rows", "bytes")}

    @property
    def dispatch_stats(self) -> dict:
        """Launch/transfer observability: jitted dispatches issued, blocking
        device→host syncs, and seconds spent inside timed kernel intervals.
        Process-global (the jit caches are too) — consumers read deltas."""
        from repro.kernels import ops
        return dict(ops.dispatch_stats)

    def set_tracker(self, tracker) -> None:
        """Attach a :class:`repro.telemetry.Tracker` child; the backend
        emits ``sync.*`` counter deltas after each fused decision pass.
        Strictly observation-only — decisions are unaffected."""
        self._tracker = tracker

    def _flush_sync(self) -> None:
        """Emit the since-last-flush delta of ``sync_stats`` as counters."""
        trk = self._tracker
        if trk is None:
            return
        for k, v in self.sync_stats.items():
            d = v - self._sync_seen.get(k, 0)
            if d:
                trk.count(f"sync.{k}", d)
        self._sync_seen = dict(self.sync_stats)

    # ------------------------------------------------------------- topology
    @property
    def n_shards(self) -> int:
        if self._n_shards is None:
            import jax
            self._n_shards = max(1, len(jax.devices()))
        return self._n_shards

    def make_store(self, capacity: int, dim: int) -> ShardedStore:
        """Facade hook: the sharded backend owns its store geometry."""
        return ShardedStore(capacity, dim, n_shards=self.n_shards)

    def mesh(self):
        """The 1-D cache mesh, or None on machines with too few devices."""
        if not self._mesh_built:
            from repro.launch.mesh import make_cache_mesh
            self._mesh = make_cache_mesh(self.n_shards)
            self._mesh_built = True
        return self._mesh

    # ---------------------------------------------------------- device slab
    def _build_scatter(self):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = NamedSharding(self._mesh, P("cache"))

        def scatter(slab, shards, locals_, vals):
            return slab.at[shards, locals_].set(vals)

        return jax.jit(scatter, out_shardings=spec)

    def _slab(self, store: ShardedStore):
        """(S, R, D) slab + per-shard valid counts, cached by store version.

        The version stamp is globally unique per mutation, so a checkpoint
        restored from this store lineage re-attaches to its uploaded slab;
        any divergent mutation forces a fresh upload.  (Host fallback keeps
        a zero-copy numpy view, so the cache is free there.)

        On a version miss the backend first asks the store which rows
        changed since a cached snapshot (:meth:`ResidentStore.dirty_since`)
        and, when the answer is small, scatters only those rows into the
        device slab instead of re-uploading the whole thing — admission-
        heavy replay moves O(mutations) rows per sync, not O(capacity).
        """
        if self.mesh() is None:
            # host fallback: the live zero-copy view is always current —
            # caching it would alias rows the store later overwrites
            return store.shard_view(), store.local_hwm.astype(np.int32)
        hit = self._slab_cache.get(store.version)
        if hit is not None:
            return hit
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = NamedSharding(self._mesh, P("cache"))
        nv = jax.device_put(store.local_hwm.astype(np.int32), spec)
        slab = self._incremental_slab(store, spec)
        if slab is None:
            self._sync["full"] += 1
            self._sync["bytes"] += store.emb.nbytes
            slab = jax.device_put(np.ascontiguousarray(store.shard_view()),
                                  spec)
        if len(self._slab_cache) >= 4:              # keep a few snapshots
            self._slab_cache.pop(next(iter(self._slab_cache)))
        self._slab_cache[store.version] = (slab, nv)
        return slab, nv

    def _incremental_slab(self, store: ShardedStore, spec):
        """Dirty-row DMA: patch the freshest reusable cached slab, or None
        when no cached version of this lineage can answer (→ full upload)."""
        best = None
        for version, (slab, _) in self._slab_cache.items():
            dirty = store.dirty_since(version)
            if dirty is not None and (best is None or len(dirty) < len(best[0])):
                best = (dirty, slab)
        if best is None:
            return None
        dirty, slab = best
        from .backends import bucket_rows, small_delta
        if not small_delta(len(dirty), store.emb.shape[0]):
            return None                  # not worth a scatter: bulk upload
        if not dirty:
            return slab
        slots = bucket_rows(np.fromiter(sorted(dirty), dtype=np.int64,
                                        count=len(dirty)))
        if self._scatter_fn is None:
            self._scatter_fn = self._build_scatter()
        self._sync["incremental"] += 1
        self._sync["rows"] += len(dirty)
        self._sync["bytes"] += (slots.size * store.emb.shape[1]
                                     * store.emb.itemsize)
        return self._scatter_fn(slab,
                                (slots // store.rows_per_shard).astype(np.int32),
                                (slots % store.rows_per_shard).astype(np.int32),
                                store.emb[slots])

    # ------------------------------------------------- quantized device slab
    def _build_q8_scatter(self):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = NamedSharding(self._mesh, P("cache"))

        def scatter(q8slab, csslab, shards, locals_, qv, sv):
            return (q8slab.at[shards, locals_].set(qv),
                    csslab.at[shards, locals_].set(sv))

        return jax.jit(scatter, out_shardings=(spec, spec))

    def _q8_slab(self, store: ShardedStore, qm):
        """(S, R, D) int8 slab + (S, R) per-row scales for the quantized
        scan, cached by store version exactly like :meth:`_slab` (dirty-row
        scatter on a version miss, full upload otherwise).  ``qm`` is the
        freshly synced host mirror; the host fallback scans its zero-copy
        reshape directly, so the cache is free there."""
        s, r = store.n_shards, store.rows_per_shard
        if self.mesh() is None:
            return qm.q8.reshape(s, r, -1), qm.scale.reshape(s, r)
        hit = self._q8_slab_cache.get(store.version)
        if hit is not None:
            return hit
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = NamedSharding(self._mesh, P("cache"))
        slabs = self._incremental_q8_slab(store, qm)
        if slabs is None:
            self._sync["full"] += 1
            self._sync["bytes"] += qm.q8.nbytes + qm.scale.nbytes
            slabs = (jax.device_put(
                         np.ascontiguousarray(qm.q8.reshape(s, r, -1)), spec),
                     jax.device_put(
                         np.ascontiguousarray(qm.scale.reshape(s, r)), spec))
        if len(self._q8_slab_cache) >= 4:           # keep a few snapshots
            self._q8_slab_cache.pop(next(iter(self._q8_slab_cache)))
        self._q8_slab_cache[store.version] = slabs
        return slabs

    def _incremental_q8_slab(self, store: ShardedStore, qm):
        """Dirty-row DMA for the int8 slab pair: one int8 row + one fp32
        scale per dirty slot, or None when no cached version can answer."""
        best = None
        for version, slabs in self._q8_slab_cache.items():
            dirty = store.dirty_since(version)
            if dirty is not None and (best is None
                                      or len(dirty) < len(best[0])):
                best = (dirty, slabs)
        if best is None:
            return None
        dirty, (q8slab, csslab) = best
        from .backends import bucket_rows, small_delta
        if not small_delta(len(dirty), store.emb.shape[0]):
            return None                  # not worth a scatter: bulk upload
        if not dirty:
            return q8slab, csslab
        slots = bucket_rows(np.fromiter(sorted(dirty), dtype=np.int64,
                                        count=len(dirty)))
        if self._q8_scatter_fn is None:
            self._q8_scatter_fn = self._build_q8_scatter()
        self._sync["incremental"] += 1
        self._sync["rows"] += len(dirty)
        self._sync["bytes"] += slots.size * (store.emb.shape[1] + 4)
        return self._q8_scatter_fn(
            q8slab, csslab,
            (slots // store.rows_per_shard).astype(np.int32),
            (slots % store.rows_per_shard).astype(np.int32),
            qm.q8[slots], qm.scale[slots])

    # -------------------------------------------------------------- lookup
    def _build_lookup(self):
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.kernels.ops import sim_top1_raw
        use_pallas, interpret = self.use_pallas, self.interpret

        def local_top1(q, slab, nv):
            # q (B, D) replicated; slab (1, R, D) / nv (1,) = this shard
            vals, idx = sim_top1_raw(q, slab[0], nv[0],
                                     use_pallas=use_pallas,
                                     interpret=interpret)
            gv = jax.lax.all_gather(vals, "cache")             # (S, B)
            gi = jax.lax.all_gather(idx, "cache")              # (S, B)
            win = jnp.argmax(gv, axis=0)       # ONE argmax-reduce over shards
            b = jnp.arange(gv.shape[1])
            return gv[win, b], win.astype(jnp.int32), gi[win, b]

        return jax.jit(shard_map(
            local_top1, mesh=self._mesh,
            in_specs=(P(), P("cache"), P("cache")),
            out_specs=(P(), P(), P()), check_rep=False))

    def top1(self, store: ShardedStore, query: np.ndarray) -> tuple[int, float]:
        cids, sims = self.top1_batch(store, np.asarray(query)[None, :])
        return int(cids[0]), float(sims[0])

    def top1_batch(self, store: ShardedStore,
                   queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        queries = np.asarray(queries, dtype=np.float32)
        if self.pruned is not None and store.slot_of:
            out = self._top1_batch_pruned(store, queries)
            if out is not None:
                return out
        if self.quantized is not None and store.slot_of:
            return self._top1_batch_quantized(store, queries)
        return self._top1_batch_exact(store, queries)

    def _top1_batch_pruned(self, store: ShardedStore, queries: np.ndarray):
        # routing scores a (T, D+1) matrix and stage 2 scans small
        # gathered candidate blocks — dense single-device work, so the
        # whole two-stage driver delegates to the KernelBackend body
        # (same rationale as top1_rows); the exact-fallback leg it closes
        # over is *this* backend's _top1_batch_exact, i.e. the per-shard
        # scan with the all_gather argmax merge
        from .backends import KernelBackend
        return KernelBackend._top1_batch_pruned(self, store, queries)

    def _top1_batch_exact(self, store: ShardedStore,
                          queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        queries = np.asarray(queries, dtype=np.float32)
        b = queries.shape[0]
        if not store.slot_of:
            return (np.full(b, -1, dtype=np.int64),
                    np.full(b, -np.inf, dtype=np.float64))
        pad = (-b) % self.q_pad
        qp = np.pad(queries, ((0, pad), (0, 0))) if pad else queries
        slab, nv = self._slab(store)
        rows = store.rows_per_shard
        if self.mesh() is not None:
            if self._lookup_fn is None:
                self._lookup_fn = self._build_lookup()
            with annotate("rac/sharded_top1"):
                vals, shard, local = self._lookup_fn(qp, slab, nv)
            vals = np.asarray(vals[:b], dtype=np.float64)
            gslot = (np.asarray(shard[:b], dtype=np.int64) * rows
                     + np.asarray(local[:b], dtype=np.int64))
        else:
            # single-device fallback: same per-shard kernel, same merge
            from repro.kernels import ops
            per_v, per_i = [], []
            for s in range(store.n_shards):
                v, i = ops.sim_top1(qp, slab[s], n_valid=int(nv[s]),
                                    use_pallas=self.use_pallas,
                                    interpret=self.interpret)
                per_v.append(np.asarray(v))
                per_i.append(np.asarray(i))
            gv = np.stack(per_v)                               # (S, B)
            gi = np.stack(per_i)
            win = np.argmax(gv, axis=0)
            cols = np.arange(qp.shape[0])
            vals = gv[win, cols][:b].astype(np.float64)
            gslot = (win * rows + gi[win, cols])[:b].astype(np.int64)
        cids = store.cid[gslot].copy()
        # a free (zeroed) slot can only win when all real sims < 0 → miss
        sims = np.where(cids >= 0, vals, -np.inf)
        return cids, sims

    def _build_qlookup(self, ks: int, km: int):
        """Quantized shard_map lookup: per-shard int8 Top-``ks`` merged
        into a global Top-``km``.  The width split keeps the error-bound
        argument sound: either ``ks`` equals the shard row count (no shard
        can hide a row) or ``km == ks`` (any hidden row sits below its
        shard's ``ks`` survivors, hence below the merged ``km``-th)."""
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.kernels.ops import sim_topk_q8_raw
        use_pallas, interpret = self.use_pallas, self.interpret

        def local_qtopk(q8, qs, slab, cs, nv):
            # q8/qs replicated; slab (1, R, D) / cs (1, R) / nv (1,) = shard
            vals, idx = sim_topk_q8_raw(q8, qs, slab[0], cs[0], nv[0], ks,
                                        use_pallas=use_pallas,
                                        interpret=interpret)
            gv = jax.lax.all_gather(vals, "cache")             # (S, B, ks)
            gi = jax.lax.all_gather(idx, "cache")              # (S, B, ks)
            s, b = gv.shape[0], gv.shape[1]
            offs = (jnp.arange(s, dtype=jnp.int32)
                    * slab.shape[1])[:, None, None]
            # shard-major concat: equal-value ties pick the earlier entry,
            # i.e. the globally lower slot — the same tie contract as the
            # host fallback's stable descending sort
            allv = jnp.moveaxis(gv, 0, 1).reshape(b, s * ks)
            alli = jnp.moveaxis(gi + offs, 0, 1).reshape(b, s * ks)
            mv, pos = jax.lax.top_k(allv, km)
            return mv, jnp.take_along_axis(alli, pos, axis=1)

        return jax.jit(shard_map(
            local_qtopk, mesh=self._mesh,
            in_specs=(P(), P(), P("cache"), P("cache"), P("cache")),
            out_specs=(P(), P()), check_rep=False))

    def _top1_batch_quantized(self, store: ShardedStore, queries: np.ndarray
                              ) -> tuple[np.ndarray, np.ndarray]:
        """Quantized candidate scan over the sharded int8 slab.

        Every shard streams its (R, D) int8 block (4× fewer slab bytes)
        through ``sim_topk_q8_raw`` and contributes k survivors; the
        all-gathered (S·K) candidates merge into a global Top-K by one
        ``top_k`` — the quantized analogue of the exact path's
        argmax-reduce.  The merged union is rescored in fp32 by
        :meth:`top1_rows` and certified by the shared safety predicate
        (per-shard exact scan fallback), so hit/miss decisions match
        :meth:`_top1_batch_exact` by construction.  Any row outside the
        merged Top-K has approximate score ≤ the merged kth value (its
        own shard kept k candidates at or above it), so the single-slab
        error bound applies unchanged."""
        from repro.kernels import ops
        from repro.kernels.quant import quantize_rows_int8, scan_margin

        from .quantized import account_scan, resolve_topk
        b = queries.shape[0]
        dim = store.emb.shape[1]
        qm = self._qhost.sync(store.version, store.dirty_since, store.emb)
        q8slab, csslab = self._q8_slab(store, qm)
        pad = (-b) % self.q_pad
        qp = np.pad(queries, ((0, pad), (0, 0))) if pad else queries
        q8, qs, ql1 = quantize_rows_int8(qp)
        k = self.quantized.k
        rows_per = store.rows_per_shard
        # per-shard shortlist width cannot exceed the shard row count; the
        # merged width then cannot exceed the concat width (see
        # _build_qlookup for why this split keeps the bound sound)
        ks = min(k, rows_per)
        km = min(k, store.n_shards * ks)
        hwm_total = int(store.local_hwm.sum())
        if self.mesh() is not None:
            import jax
            from jax.sharding import NamedSharding, PartitionSpec as P
            spec = NamedSharding(self._mesh, P("cache"))
            nv = jax.device_put(store.local_hwm.astype(np.int32), spec)
            fn = self._qlookup_fns.get((ks, km))
            if fn is None:
                fn = self._qlookup_fns[(ks, km)] = self._build_qlookup(ks, km)
            with annotate("rac/sharded_topk_q8"):
                mv, mi = fn(q8, qs, q8slab, csslab, nv)
            vals = np.asarray(mv[:b], dtype=np.float64)
            rows = np.asarray(mi[:b], dtype=np.int64)
        else:
            # single-device fallback: same per-shard quantized kernel, and
            # the stable descending sort implements the same lower-slot
            # tie merge as the mesh path's shard-major top_k
            per_v, per_i = [], []
            with annotate("rac/sharded_topk_q8"):
                for si in range(store.n_shards):
                    v, i = ops.sim_topk_q8(
                        q8, qs, q8slab[si], csslab[si], ks,
                        n_valid=int(store.local_hwm[si]),
                        use_pallas=self.use_pallas,
                        interpret=self.interpret)
                    per_v.append(np.asarray(v))
                    per_i.append(np.asarray(i, dtype=np.int64)
                                 + si * rows_per)
            allv = np.concatenate(per_v, axis=1)               # (Bp, S·K)
            alli = np.concatenate(per_i, axis=1)
            order = np.argsort(-allv, axis=1, kind="stable")[:, :km]
            vals = np.take_along_axis(allv, order,
                                      axis=1)[:b].astype(np.float64)
            rows = np.take_along_axis(alli, order, axis=1)[:b]
        eps = scan_margin(qs[:b], ql1[:b], qm.scale, qm.l1, dim)
        cids, sims, n_fb, n_union = resolve_topk(
            vals, rows, eps, k >= hwm_total, self.quantized.tau_hit,
            lambda r: self.top1_rows(store, queries, r),
            lambda sel: self._top1_batch_exact(store, queries[sel]))
        account_scan(self.quant_stats, n_valid=hwm_total, dim=dim, batch=b,
                     n_union=n_union, n_fallback=n_fb)
        self._flush_sync()
        return cids, sims

    # ------------------------------------------------- multi-policy arena
    def _build_arena_scatter(self):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = NamedSharding(self._mesh, P("cache"))

        def scatter(slab, sh, ps, loc, vals):
            return slab.at[sh, ps, loc].set(vals)

        return jax.jit(scatter, out_shardings=spec)

    def _arena_slab(self, arena, rows: int):
        """(n_shards, P, R, D) rearranged stacked slab, version-keyed
        against the arena's flat journal: the mesh path keeps a device
        copy freshened by dirty-row scatter, the host fallback a
        rearranged host copy patched in place — steady-state chunks move
        O(mutations) rows, exactly like the single-policy ``_slab``."""
        import numpy as _np

        from .backends import bucket_rows, small_delta
        n_pol, n_slots = arena.occ.shape
        dim = arena.emb.shape[-1]
        shape_key = (n_pol, rows, dim)
        cached = self._arena_cache
        if cached is not None and cached[2] == shape_key:
            if cached[0] == arena.version:
                return cached[1]
            dirty = arena.dirty_since(cached[0])
            if dirty is not None and small_delta(len(dirty),
                                                 n_pol * n_slots):
                slab = cached[1]
                if dirty:
                    flat = _np.fromiter(sorted(dirty), dtype=_np.int64,
                                        count=len(dirty))
                    self._sync["incremental"] += 1
                    self._sync["rows"] += len(dirty)
                    self._sync["bytes"] += (len(dirty) * dim
                                                 * arena.emb.itemsize)
                    if self.mesh() is not None:
                        flat = bucket_rows(flat)
                        ps = flat // n_slots
                        slot = flat % n_slots
                        if self._arena_scatter_fn is None:
                            self._arena_scatter_fn = \
                                self._build_arena_scatter()
                        slab = self._arena_scatter_fn(
                            slab, (slot // rows).astype(_np.int32),
                            ps.astype(_np.int32),
                            (slot % rows).astype(_np.int32),
                            arena.emb[ps, slot])
                    else:
                        ps = flat // n_slots
                        slot = flat % n_slots
                        slab[slot // rows, ps, slot % rows] = \
                            arena.emb[ps, slot]
                self._arena_cache = (arena.version, slab, shape_key)
                return slab
        # full (re)build: pad the slot axis and rearrange shard-major
        s = self.n_shards
        tail = rows * s - n_slots
        emb = arena.emb
        if tail:
            emb = _np.concatenate(
                [emb, _np.zeros((n_pol, tail, dim), _np.float32)], axis=1)
        slab = _np.ascontiguousarray(
            emb.reshape(n_pol, s, rows, dim).transpose(1, 0, 2, 3))
        self._sync["full"] += 1
        self._sync["bytes"] += slab.nbytes
        if self.mesh() is not None:
            import jax
            from jax.sharding import NamedSharding, PartitionSpec as P
            slab = jax.device_put(slab, NamedSharding(self._mesh,
                                                      P("cache")))
        self._arena_cache = (arena.version, slab, shape_key)
        return slab

    def _build_multi_lookup(self):
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.kernels.ops import sim_top1_multi_raw
        use_pallas, interpret = self.use_pallas, self.interpret

        def local_multi(q, slab, nv):
            # q (B, D) replicated; slab (1, P, R, D) / nv (1, P) = this
            # shard's slice of every policy's slab
            vals, idx = sim_top1_multi_raw(q, slab[0], nv[0],
                                           use_pallas=use_pallas,
                                           interpret=interpret)
            gv = jax.lax.all_gather(vals, "cache")         # (S, P, B)
            gi = jax.lax.all_gather(idx, "cache")          # (S, P, B)
            win = jnp.argmax(gv, axis=0)   # ONE argmax-reduce over shards
            p = jnp.arange(gv.shape[1])[:, None]
            b = jnp.arange(gv.shape[2])[None, :]
            return gv[win, p, b], win.astype(jnp.int32), gi[win, p, b]

        return jax.jit(shard_map(
            local_multi, mesh=self._mesh,
            in_specs=(P(), P("cache"), P("cache")),
            out_specs=(P(), P(), P()), check_rep=False))

    def top1_multi(self, arena, queries: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Policy-stacked Top-1 with the shard_map merge.

        The arena's dense (P, S, D) slab is row-partitioned over the cache
        mesh along the SLOT axis — each device holds every policy's slice
        of R rows as (P, R, D) — and runs the stacked per-shard kernel
        (``sim_top1_multi_raw``); the per-(policy, query) candidates are
        all-gathered and merged by the same single argmax-reduce as
        ``top1_batch``.  Per-shard valid counts derive from each policy's
        dense high-water mark (LIFO slot reuse keeps occupied slots below
        it), so free tails are never scored.  With too few devices the
        identical per-shard math runs as a host loop."""
        import numpy as _np
        if not arena.track_rows:
            # the version-keyed slab cache syncs against the arena's flat
            # journal; a host-only arena never stamps it
            raise ValueError("ShardedKernelBackend.top1_multi needs an "
                             "ArenaStore built with track_rows=True")
        queries = _np.asarray(queries, dtype=_np.float32)
        b = queries.shape[0]
        n_pol, n_slots = arena.occ.shape
        if not any(v.slot_of for v in arena.views):
            return (_np.full((n_pol, b), -1, dtype=_np.int64),
                    _np.full((n_pol, b), -_np.inf, dtype=_np.float64))
        if self.pruned is not None:
            # the per-policy pruned pass is dense (arena slabs are small
            # next to the resident slab): delegate to the KernelBackend
            # body — same precedent as top1_rows
            from .backends import KernelBackend
            out = KernelBackend._top1_multi_pruned(self, arena, queries)
            if out is not None:
                return out
        if self.quantized is not None:
            # the stacked quantized pass is dense (arena slabs are small
            # next to the resident slab): delegate to the KernelBackend
            # body, which only needs the q_pad/mirror attributes this
            # backend also carries — same precedent as top1_rows
            from .backends import KernelBackend
            return KernelBackend._top1_multi_quantized(self, arena, queries)
        pad = (-b) % self.q_pad
        qp = _np.pad(queries, ((0, pad), (0, 0))) if pad else queries
        s = self.n_shards
        rows = -(-n_slots // s)                        # ceil division
        # per-(shard, policy) valid prefix of the dense hwm
        hwms = arena.hwms()[None, :]                   # (1, P)
        offs = (_np.arange(s) * rows)[:, None]         # (S, 1)
        lnv = _np.clip(hwms - offs, 0, rows).astype(_np.int32)   # (S, P)
        shard_slab = self._arena_slab(arena, rows)
        if self.mesh() is not None:
            import jax
            from jax.sharding import NamedSharding, PartitionSpec as P
            spec = NamedSharding(self._mesh, P("cache"))
            dnv = jax.device_put(lnv, spec)
            if self._multi_fn is None:
                self._multi_fn = self._build_multi_lookup()
            with annotate("rac/sharded_top1_multi"):
                vals, win, local = self._multi_fn(qp, shard_slab, dnv)
            vals = _np.asarray(vals[:, :b], dtype=_np.float64)
            gslot = (_np.asarray(win[:, :b], dtype=_np.int64) * rows
                     + _np.asarray(local[:, :b], dtype=_np.int64))
        else:
            # single-device fallback: same per-shard stacked kernel + the
            # same argmax merge, looped on one device
            from repro.kernels import ops
            per_v, per_i = [], []
            for si in range(s):
                v, i = ops.sim_top1_multi(qp, shard_slab[si],
                                          n_valid=lnv[si],
                                          use_pallas=self.use_pallas,
                                          interpret=self.interpret)
                per_v.append(_np.asarray(v))
                per_i.append(_np.asarray(i))
            gv = _np.stack(per_v)                      # (S, P, B)
            gi = _np.stack(per_i)
            win = _np.argmax(gv, axis=0)               # (P, Bp)
            pi = _np.arange(n_pol)[:, None]
            bi = _np.arange(qp.shape[0])[None, :]
            vals = gv[win, pi, bi][:, :b].astype(_np.float64)
            gslot = (win * rows + gi[win, pi, bi])[:, :b].astype(_np.int64)
        # padded tail rows are zeros: they can only win when every real
        # sim < 0, which maps to a miss exactly like a free slot
        safe = _np.minimum(gslot, n_slots - 1)
        cids = _np.where(gslot < n_slots,
                         arena.cid[_np.arange(n_pol)[:, None], safe], -1)
        sims = _np.where(cids >= 0, vals, -_np.inf)
        self._flush_sync()
        return cids, sims

    def top1_rows(self, store: ShardedStore, queries: np.ndarray,
                  rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        # a row-restricted rescan touches a handful of rows — one gathered
        # single-device kernel call (KernelBackend's path, which only needs
        # q_pad/use_pallas/interpret) beats fanning a tiny candidate block
        # across the mesh
        from .backends import KernelBackend
        return KernelBackend.top1_rows(self, store, queries, rows)

    def topk_rows(self, store: ShardedStore, queries: np.ndarray,
                  rows: np.ndarray, k: int
                  ) -> tuple[np.ndarray, np.ndarray]:
        # same rationale as top1_rows: a restricted Top-K touches a small
        # gathered candidate block, so the single-device kernel path wins
        from .backends import KernelBackend
        return KernelBackend.topk_rows(self, store, queries, rows, k)

    # ------------------------------------------------------------- eviction
    def _build_rac(self, alpha: float):
        import jax
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.kernels.ops import rac_value_raw
        use_pallas, interpret = self.use_pallas, self.interpret

        def local_rac(tsi, tid, tp_last, t_last):
            # tsi/tid (chunk,) = this shard's slice of the resident table
            return rac_value_raw(tsi, tid, tp_last, t_last, alpha, 0,
                                 use_pallas=use_pallas, interpret=interpret)

        return jax.jit(shard_map(
            local_rac, mesh=self._mesh,
            in_specs=(P("cache"), P("cache"), P(), P()),
            out_specs=P("cache"), check_rep=False))

    def rac_value(self, tsi, tids, tp_last, t_last, alpha, t_now):
        """Per-shard Eq. 1 scoring over the resident-table entry axis.

        Each shard scores its chunk; the stitched value vector goes back to
        the policy whose lexsort performs the global min-merge (keeping the
        deterministic (value, last-access, cid) tie-breaks)."""
        from repro.kernels import ops
        tsi = np.asarray(tsi, dtype=np.float32)
        tids = np.asarray(tids, dtype=np.int32)
        tp_last = np.asarray(tp_last, dtype=np.float32)
        # shift timestamps so t_now is the static constant 0 (no recompiles
        # as simulation time advances; same trick as KernelBackend)
        t_rel = np.asarray(t_last - t_now, dtype=np.int32)
        n, s = tsi.shape[0], self.n_shards
        if self.mesh() is None or n < s:
            out = ops.rac_value(tsi, tids, tp_last, t_rel, float(alpha), 0,
                                use_pallas=self.use_pallas,
                                interpret=self.interpret)
            return np.asarray(out, dtype=np.float64)
        fn = self._rac_fns.get(float(alpha))
        if fn is None:
            fn = self._rac_fns[float(alpha)] = self._build_rac(float(alpha))
        chunk = -(-n // s)
        pad = chunk * s - n
        out = fn(np.pad(tsi, (0, pad)), np.pad(tids, (0, pad)),
                 tp_last, t_rel)
        return np.asarray(out[:n], dtype=np.float64)

    def rac_value_masked(self, tsi, tids, tp_last, t_last, alpha, t_now,
                         valid):
        vals = self.rac_value(tsi, tids, tp_last, t_last, alpha, t_now)
        return np.where(np.asarray(valid, dtype=bool), vals, np.inf)

    # ------------------------------------------------------ fused decisions
    def _build_decide(self, alpha: float):
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.kernels.ops import fused_decide_raw
        use_pallas, interpret = self.use_pallas, self.interpret

        def local_decide(q, slab, nv, reps, ntop, tsi, tid, occ, tp, tl, tn):
            # q/reps/topic tables replicated; slab (1, R, D), nv (1,), and
            # the flat slot arrays' (R,) slices belong to this shard
            hv, hi, rv, ri, vv = fused_decide_raw(
                q, slab[0], nv[0], reps, ntop[0], tsi, tid, occ, tp, tl,
                tn[0], alpha=alpha, use_pallas=use_pallas,
                interpret=interpret)
            gv = jax.lax.all_gather(hv, "cache")               # (S, B)
            gi = jax.lax.all_gather(hi, "cache")               # (S, B)
            win = jnp.argmax(gv, axis=0)   # ONE argmax-reduce over shards —
            b = jnp.arange(gv.shape[1])    # the same merge as top1_batch
            return (gv[win, b], win.astype(jnp.int32), gi[win, b],
                    rv, ri, vv)

        return jax.jit(shard_map(
            local_decide, mesh=self._mesh,
            in_specs=(P(), P("cache"), P("cache"), P(), P(), P("cache"),
                      P("cache"), P("cache"), P(), P(), P()),
            out_specs=(P(), P(), P(), P(), P(), P("cache")),
            check_rep=False))

    def decide_batch(self, store: ShardedStore, table, queries, *,
                     alpha=0.0, t_now=0):
        """Fused per-shard decision pass with the PR 2 Top-1 merge.

        Every shard runs the identical fused body (hit Top-1 over its slab
        rows + replicated routing Top-1 + masked Eq. 1 over its slice of
        the slot table) in ONE ``shard_map`` launch; the per-shard hit
        candidates are all-gathered and merged by a single argmax-reduce —
        exactly how ``top1_batch`` merges — and the per-shard victim
        slices are stitched back into one slot-indexed value vector.  The
        big embedding slab rides the version-keyed device cache
        (dirty-row scatter); the small slot/topic arrays are shipped per
        call.  With too few devices the identical math runs as the
        single-device loop, so decisions stay topology-independent.
        """
        queries = np.asarray(queries, dtype=np.float32)
        b = queries.shape[0]
        if table is None:
            hit_cid, hit_sim = self.top1_batch(store, queries)
            return DecisionBatch(hit_cid, hit_sim,
                                 np.full(b, -1, dtype=np.int64),
                                 np.full(b, -np.inf, dtype=np.float64), None)
        from repro.kernels import ops
        pad = (-b) % self.q_pad
        qp = np.pad(queries, ((0, pad), (0, 0))) if pad else queries
        tsi = table.tsi.astype(np.float32)
        tid = table.topic_of.astype(np.int32)
        occ = store.occ.astype(np.int32)
        tp = table.tp_last.astype(np.float32)
        tl = table.t_last.astype(np.int32)
        rows = store.rows_per_shard
        # quantized/pruned lookups take the split path below: its
        # top1_batch call dispatches to the reduced-traffic scan while
        # routing + victim stay fused
        if (self.mesh() is not None and self.quantized is None
                and self.pruned is None):
            slab, nv = self._slab(store)
            fn = self._decide_fns.get(float(alpha))
            if fn is None:
                fn = self._decide_fns[float(alpha)] = \
                    self._build_decide(float(alpha))
            with annotate("rac/sharded_fused_decide"):
                hv, shard, local, rv, ri, vv = fn(
                    qp, slab, nv, table.rep, np.asarray([table.topic_hwm],
                                                        dtype=np.int32),
                    tsi, tid, occ, tp, tl,
                    np.asarray([t_now], dtype=np.int32))
            hv = np.asarray(hv[:b], dtype=np.float64)
            gslot = (np.asarray(shard[:b], dtype=np.int64) * rows
                     + np.asarray(local[:b], dtype=np.int64))
            rv = np.asarray(rv[:b], dtype=np.float64)
            ri = np.asarray(ri[:b], dtype=np.int64)
            vv = np.asarray(vv, dtype=np.float64)
        else:
            # single-device fallback: the hit merge is top1_batch's loop
            # (identical decisions), routing + victim are one call each
            hit_cid, hit_sim = self.top1_batch(store, queries)
            rv_, ri_ = ops.sim_top1(qp, table.rep, n_valid=table.topic_hwm,
                                    use_pallas=self.use_pallas,
                                    interpret=self.interpret)
            vv = np.asarray(ops.victim_value(
                tsi, tid, occ, tp, tl, t_now, alpha=float(alpha),
                use_pallas=self.use_pallas, interpret=self.interpret),
                dtype=np.float64)
            rv = np.asarray(rv_[:b], dtype=np.float64)
            ri = np.where(np.isfinite(rv),
                          np.asarray(ri_[:b], dtype=np.int64), -1)
            self._flush_sync()
            return DecisionBatch(hit_cid, hit_sim, ri, rv, vv)
        cids = store.cid[gslot].copy()
        # a free (zeroed) slot can only win when all real sims < 0 → miss
        sims = np.where(cids >= 0, hv, -np.inf)
        ri = np.where(np.isfinite(rv), ri, -1)
        self._flush_sync()
        return DecisionBatch(cids, sims, ri, rv, vv)
