"""Datatypes for the unified semantic-cache facade.

One configuration object (:class:`CacheConfig`), one result algebra
(:class:`CacheHit` / :class:`CacheMiss`), one metrics block
(:class:`CacheMetrics`), and one event record (:class:`CacheEvent`) shared
by every consumer of :class:`repro.cache.SemanticCache` — the simulator,
the serving engine, examples, and benchmarks all see the same protocol.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Union


@dataclasses.dataclass
class TierConfig:
    """Configuration for the tiering subsystem behind the facade
    (:mod:`repro.cache.tiers`).

    ``host_capacity`` sizes the host-DRAM second tier that catches device
    evictions (demotion) and serves device misses (promotion back through
    the admission path); 0 disables it.  ``ghost_capacity`` bounds the
    metadata-only ghost tier underneath (ARC B1/B2-style: one list for
    never-promoted demotions, one for promoted-then-re-evicted entries);
    0 disables ghosts.  ``promote_k`` is the host-tier scan width: the
    Top-K shortlist scored per miss (K > 1 reserved for prefetch-style
    co-promotion policies; the serve decision itself is Top-1).

    With ``host_capacity=0`` and ``ghost_capacity=0`` the facade never
    constructs a tier manager and every decision is bit-identical to the
    single-tier path.
    """

    host_capacity: int = 0
    ghost_capacity: int = 0
    promote_k: int = 1


@dataclasses.dataclass
class CacheConfig:
    """Configuration for one :class:`~repro.cache.SemanticCache` instance.

    ``hit_mode`` mirrors the simulator's two equivalent hit semantics:
    ``"semantic"`` (Top-1 cosine >= tau_hit; the paper's semantic cache) and
    ``"content"`` (content-id residency; O(1), used for large sweeps).
    ``backend`` selects the lookup/scoring implementation: ``"numpy"`` (host
    slab scan), ``"kernel"`` (batched through ``kernels/ops.sim_top1`` and
    ``kernels/ops.rac_value``), or ``"sharded"`` (the slab row-partitioned
    across the devices of a 1-D cache mesh with a shard_map Top-1 merge);
    all produce identical hit decisions.  ``backend_kwargs`` are forwarded
    to the backend constructor (e.g. ``{"n_shards": 4}`` for ``"sharded"``).

    ``async_admit`` decouples admission from the request path: ``False``
    (default) applies insert + eviction scoring inline; ``True`` queues
    admissions for a background worker and ``flush()`` settles them at
    batch boundaries; ``"sync"`` queues without a worker — the queue only
    drains inside ``flush()``/``drain()``, the deterministic replay-parity
    mode.  After a flush all three produce identical state.

    ``tracker`` attaches a :class:`repro.telemetry.Tracker` (instance or
    spec string like ``"memory"`` / ``"jsonl:<path>"``) that the facade,
    the admitter, the tier manager, and the device backends emit
    latencies, counters, windowed series, and spans through — strictly
    observation-only: decisions are bit-identical with any tracker, and
    ``None`` (the default) skips emission entirely.

    ``debug_hooks`` controls event-subscriber failure handling: by
    default a raising hook is caught mid-operation and counted
    (``CacheMetrics.hook_errors`` + the ``cache.hook_errors`` tracker
    counter); with ``debug_hooks=True`` the exception propagates to the
    ``lookup``/``admit`` caller (the development mode).

    ``quantized_lookup`` switches the Top-1 candidate scan onto the int8
    per-row-scaled slab mirror (:mod:`repro.cache.quantized`): ``False``
    (default) keeps the fp32 path bit-exactly as before; ``True`` enables
    it with defaults; a dict or :class:`~repro.cache.quantized.
    QuantizedLookupConfig` overrides the survivor width ``k``.  The
    facade fills the config's ``tau_hit`` from its own when unset, so the
    certain-miss arm of the safety predicate is active in semantic mode.
    Decisions (hit/miss/eviction sequences) are identical to the exact
    path by construction — queries the error margin cannot certify fall
    back to the exact scan (``cache.rescore_fallbacks`` telemetry).

    ``pruned_lookup`` bounds the Top-1 candidate scan to the few topics
    a query can plausibly land in (:mod:`repro.cache.pruned`): stage 1
    routes the query against the (T, D) topic-representative matrix,
    stage 2 scans only the probed topics' rows through a journal-
    maintained topic->slots bucket index.  ``False`` (default) keeps the
    full scan; ``True`` enables it with defaults; a dict or
    :class:`~repro.cache.pruned.PrunedLookupConfig` overrides the probe
    width.  The facade fills ``tau_hit`` from its own when unset.  A
    routing-margin / certain-miss safety predicate certifies every
    decision, with exact full-scan fallback (``cache.prune_fallbacks``)
    for anything uncertifiable — decisions stay identical to the exact
    path by construction.  Composes with ``quantized_lookup`` (the
    probed candidate slab is scanned through the int8 kernel).
    """

    capacity: int
    dim: int
    tau_hit: float = 0.85
    hit_mode: str = "semantic"           # "semantic" | "content"
    backend: str = "numpy"               # "numpy" | "kernel" | "sharded"
    policy: str = "RAC"                  # BASELINES name, "RAC", "RadixRAC"
    policy_kwargs: dict = dataclasses.field(default_factory=dict)
    use_pallas: bool = True              # device backends: pallas vs jnp oracle
    backend_kwargs: dict = dataclasses.field(default_factory=dict)
    async_admit: bool | str = False      # False | True (worker) | "sync"
    tiers: Optional[TierConfig] = None   # None = single-tier (bit-exact)
    tracker: Any = None                  # Tracker | spec str | None (off)
    debug_hooks: bool = False            # re-raise subscriber-hook errors
    quantized_lookup: Any = False        # False | True | dict | config obj
    pruned_lookup: Any = False           # False | True | dict | config obj


@dataclasses.dataclass
class CacheHit:
    """Lookup resolved to a resident entry."""

    cid: int                             # resident entry that served the query
    sim: float                           # Top-1 cosine (nan in content mode)
    payload: Any = None                  # whatever admit() stored, or None
    t: int = -1                          # logical time of the lookup

    @property
    def hit(self) -> bool:
        return True

    def __bool__(self) -> bool:
        return True


@dataclasses.dataclass
class CacheMiss:
    """Lookup found no resident entry above the hit threshold."""

    best_cid: int = -1                   # nearest resident (may be -1: empty)
    best_sim: float = float("-inf")      # its similarity (below tau_hit)
    t: int = -1

    @property
    def hit(self) -> bool:
        return False

    def __bool__(self) -> bool:
        return False


CacheResult = Union[CacheHit, CacheMiss]


@dataclasses.dataclass
class DecisionBatch:
    """One fused decision launch over a (B, D) query block.

    The snapshot scoring surface of the whole RAC decision loop (see
    ``LookupBackend.decide_batch``): Top-1 hit candidates per query, Alg. 4
    topic-routing candidates per query, and Eq. 1 victim values over the
    slot table.  Routing outputs are *candidates* — gate ``route_sim``
    against ``tau_route`` before use (an invalid/retired topic row can win
    only with a non-positive similarity).  ``victim_value`` is the
    Eq.1-literal ``TP·TSI`` (the ``value_mode="paper"`` reading, what
    ``rac_value`` computes); free slots score ``+inf``.  It is ``None``
    when the policy has no :class:`~repro.core.policy_table.PolicyTable`
    (baseline policies), in which case ``route_*`` degrade to ``-1``/
    ``-inf`` and only the hit columns are meaningful.
    """

    hit_cid: "np.ndarray"                # (B,) int64: Top-1 resident or -1
    hit_sim: "np.ndarray"                # (B,) float64: its cosine or -inf
    route_tid: "np.ndarray"              # (B,) int64: best topic row or -1
    route_sim: "np.ndarray"              # (B,) float64: rep cosine or -inf
    victim_value: Optional["np.ndarray"] = None   # (n_slots,) float64
    # tier-aware fall-through (None on single-tier caches): the host tier's
    # Top-1 per query — a host_sim >= tau_hit means the entry can be served
    # (and promoted) from host DRAM even though the device tier missed
    host_cid: Optional["np.ndarray"] = None       # (B,) int64 or None
    host_sim: Optional["np.ndarray"] = None       # (B,) float64 or None


@dataclasses.dataclass
class CacheEvent:
    """One observable cache transition, delivered to subscribed hooks."""

    kind: str                            # "hit" | "miss" | "admit" | "evict"
    cid: int
    t: int
    sim: float = float("nan")
    payload: Any = None
    tier: str = "device"                 # tier that produced the transition:
                                         # "device" | "host" (host-tier hit /
                                         # demoted-not-dropped eviction)


@dataclasses.dataclass
class CacheMetrics:
    """Counters + per-op latency accumulators (seconds)."""

    hits: int = 0
    misses: int = 0
    admissions: int = 0
    evictions: int = 0
    lookups: int = 0
    lookup_s: float = 0.0
    admit_s: float = 0.0
    hook_errors: int = 0                 # subscriber hooks that raised

    @property
    def requests(self) -> int:
        return self.hits + self.misses

    @property
    def hit_ratio(self) -> float:
        return self.hits / max(1, self.requests)

    def snapshot(self) -> dict:
        return {
            "hits": self.hits, "misses": self.misses,
            "admissions": self.admissions, "evictions": self.evictions,
            "lookups": self.lookups, "hit_ratio": self.hit_ratio,
            "lookup_s": self.lookup_s, "admit_s": self.admit_s,
            "hook_errors": self.hook_errors,
        }
