"""Unified semantic-cache subsystem: one batched, backend-pluggable API.

:class:`SemanticCache` owns hit determination, admission, and eviction
end-to-end; the trace simulator, the serving engine, the examples, and the
benchmarks all sit behind it.  Lookups dispatch through a pluggable
:class:`LookupBackend` — :class:`NumpyBackend` scans the host slab,
:class:`KernelBackend` batches Top-1 retrieval through the
``kernels/ops.sim_top1`` Pallas kernel and scores evictions with
``kernels/ops.rac_value`` on device — with identical hit decisions.

Usage::

    import numpy as np
    from repro.cache import CacheConfig, SemanticCache

    cache = SemanticCache(CacheConfig(capacity=512, dim=64, tau_hit=0.85,
                                      backend="numpy", policy="RAC"))
    cache.subscribe("evict", lambda ev: print("evicted", ev.cid))

    q = np.random.default_rng(0).standard_normal(64).astype(np.float32)
    q /= np.linalg.norm(q)

    r = cache.lookup(q, cid=7)             # CacheHit | CacheMiss
    if not r.hit:
        cache.admit(7, q, payload=["the", "response"])
    assert cache.lookup(q, cid=7).payload == ["the", "response"]

    # hot path: score a whole queue in ONE backend call
    queries = np.stack([q] * 32)
    results = cache.lookup_batch(queries, cids=list(range(32)))

    state = cache.checkpoint()             # deep snapshot...
    cache.restore(state)                   # ...restored exactly

    print(cache.metrics.snapshot())        # hits/misses/evictions/latency

Policy selection follows the simulator: ``policy="RAC"`` (or any name in
``repro.core.policies.BASELINES``) plus ``policy_kwargs``, or pass a
``policy_factory=(capacity, store) -> Policy`` for sweep drivers.
"""
from .backends import (KernelBackend, LookupBackend, NumpyBackend,
                       get_backend)
from .facade import SemanticCache
from .types import (CacheConfig, CacheEvent, CacheHit, CacheMetrics,
                    CacheMiss, CacheResult)

__all__ = [
    "SemanticCache", "CacheConfig", "CacheHit", "CacheMiss", "CacheResult",
    "CacheEvent", "CacheMetrics", "LookupBackend", "NumpyBackend",
    "KernelBackend", "get_backend",
]
