"""Unified semantic-cache subsystem: one batched, backend-pluggable,
event-driven API.

:class:`SemanticCache` owns hit determination, admission, and eviction
end-to-end; the trace simulator, the serving engine, the KV prefix-block
manager, the examples, and the benchmarks all sit behind it.  Lookups
dispatch through a pluggable :class:`LookupBackend` — :class:`NumpyBackend`
scans the host slab, :class:`KernelBackend` batches Top-1 retrieval through
the ``kernels/ops.sim_top1`` Pallas kernel and scores evictions with
``kernels/ops.rac_value`` on device — with identical hit decisions.
``decide_batch`` goes further: one fused launch per query chunk scores hit
Top-1, Alg. 4 topic routing, and masked Eq. 1 victim values against the
RAC policy's journaled :class:`~repro.core.policy_table.PolicyTable`,
which device backends mirror with dirty-row scatters (the exact batched
replay and the serving engine's queue scan both ride it).

The facade is *event-driven*: every transition fires a subscribable hook
(``"hit" | "miss" | "admit" | "evict"``, each event tagged with the tier
that produced it), and admission itself can leave the request path — with
``CacheConfig.async_admit`` an
:class:`~repro.cache.async_admit.AsyncAdmitter` queues admissions and a
background worker (or a deterministic ``flush()`` drain) applies insert +
eviction scoring off the caller's thread, firing the same hooks and
metrics.  After a ``flush()`` the state is identical to synchronous
admission, so replay parity and checkpointing are preserved.

The facade is also *tiered* (``CacheConfig.tiers``, see
:mod:`repro.cache.tiers` and ``docs/tiering.md``): a host-DRAM
:class:`~repro.cache.tiers.HostTier` — sized well past the device slab —
catches device evictions (*demotion*: payload, embedding, and relation
metadata survive) and serves device misses (*promotion* back through the
admission path, riding the AsyncAdmitter queue so the request path never
blocks), while a capacity-bounded ARC-style
:class:`~repro.cache.tiers.GhostTier` keeps metadata-only records of what
fell out entirely so a re-admitted entry restores its RAC counters and its
topic's TP state instead of cold-starting.  Every tier move is a journal
entry on the same :class:`~repro.core.store.MutationJournal` protocol the
device mirrors sync against, ``checkpoint()/restore()`` captures all three
tiers, and with ``tiers=None`` (the default) every decision is
bit-identical to the single-tier facade.

Lookup candidate generation is optionally *quantized*
(``CacheConfig.quantized_lookup``, see :mod:`repro.cache.quantized` and
``docs/quantized_lookup.md``): every backend can scan a per-row-scaled
int8 mirror of the embedding slab — 4× fewer slab bytes — then rescore
the ≤k int8 survivors in fp32 against the exact rows and certify the
result with an error-bound safety predicate, falling back to the exact
full scan for any query it cannot certify (counted as
``cache.rescore_fallbacks``).  Hit/miss/eviction sequences are identical
to the exact path by construction; with the flag off (the default) the
quantized machinery never runs and behaviour is bit-exact to before.

Lookup candidate generation is also optionally *topic-pruned*
(``CacheConfig.pruned_lookup``, see :mod:`repro.cache.pruned` and
``docs/pruned_lookup.md``): a two-stage IVF-style scan first routes each
query against the policy's (T, D) topic-representative matrix, then
scans only the top-P probe topics' rows through a journal-maintained
topic→slots bucket index — so lookup traffic scales with the *hot*
working set instead of total capacity.  A routing-margin /
certain-miss-under-tau safety predicate certifies every decision, with
exact full-scan fallback (counted as ``cache.prune_fallbacks``) for
anything uncertifiable; hit/miss/eviction sequences are identical to the
exact path by construction.  Pruning composes multiplicatively with
``quantized_lookup`` — probed candidates stream through the int8 kernel.

The facade is *observable* (``CacheConfig.tracker``, see
:mod:`repro.telemetry` and ``docs/observability.md``): attach any
:class:`~repro.telemetry.Tracker` — or a spec string like ``"memory"``
or ``"jsonl:run.jsonl"`` — and every layer emits into it through scoped
``child()`` views: ``cache.*`` latency histograms and hit/occupancy
series from the facade and admitter, ``tier.*`` flow counters,
``backend.sync.*`` mirror-upload deltas (rows and bytes), and request
spans in the serving engine's ``serve.*`` namespace.  Telemetry is
strictly observation-only — decisions are bit-identical with any sink
attached, ``tracker=None`` adds zero work, and
``SemanticCache.metrics_snapshot()`` consolidates every counter surface
into one dict whether or not a tracker is configured.

Usage::

    import numpy as np
    from repro.cache import CacheConfig, SemanticCache

    cache = SemanticCache(CacheConfig(capacity=512, dim=64, tau_hit=0.85,
                                      backend="numpy", policy="RAC"))
    cache.subscribe("evict", lambda ev: print("evicted", ev.cid))

    q = np.random.default_rng(0).standard_normal(64).astype(np.float32)
    q /= np.linalg.norm(q)

    r = cache.lookup(q, cid=7)             # CacheHit | CacheMiss
    if not r.hit:
        cache.admit(7, q, payload=["the", "response"])
    assert cache.lookup(q, cid=7).payload == ["the", "response"]

    # hot path: score a whole queue in ONE backend call
    queries = np.stack([q] * 32)
    results = cache.lookup_batch(queries, cids=list(range(32)))

    state = cache.checkpoint()             # deep snapshot...
    cache.restore(state)                   # ...restored exactly

    print(cache.metrics.snapshot())        # hits/misses/evictions/latency

Policy selection follows the simulator: ``policy="RAC"`` (or any name in
``repro.core.policies.BASELINES``) plus ``policy_kwargs``, or pass a
``policy_factory=(capacity, store) -> Policy`` for sweep drivers.

Backend topology
----------------

Three backends share one decision semantics (identical hit/admit/evict
outcomes on the same request stream):

  - ``"numpy"``   — single host: one dense ``(capacity+1, D)`` slab, masked
    matmul Top-1.  The parity oracle everything else is tested against.
  - ``"kernel"``  — single device: the same slab scored by the
    ``sim_top1`` Pallas kernel up to the store's high-water mark (the
    resident count is a scalar-prefetched runtime value, one compilation
    per geometry), evictions via the ``rac_value`` kernel.
  - ``"sharded"`` — multi-device: the slab is row-partitioned into
    ``n_shards`` blocks of ``ceil((capacity+1)/n_shards)`` rows, shard
    ``s`` owning rows ``[s·R, (s+1)·R)`` on device ``s`` of a 1-D
    ``("cache",)`` mesh (``repro.launch.mesh.make_cache_mesh``).  Lookups
    fan out under ``shard_map``: every device scores its own block against
    the replicated query batch with a locally-valid slot count, the
    per-shard ``(val, local_idx)`` pairs are all-gathered and merged into
    global ``(cid, sim)`` by a single argmax-reduce over the shard axis.
    Admission places new entries on the least-loaded shard; eviction
    scoring shards the resident table's entry axis over the same mesh and
    the policy's deterministic lexsort takes the global min.  On machines
    with fewer devices than shards the identical per-shard math runs as a
    loop on one device, so decisions are topology-independent.

Capacity therefore scales with the mesh: each device holds and scores only
``1/n_shards`` of the resident slab.  The sharded device slab syncs
incrementally: the store journals which rows each mutation touched, and
the backend scatters only the dirty rows into the cached device slab
instead of re-uploading the whole thing.
"""
from .async_admit import AsyncAdmitter
from .backends import (KernelBackend, LookupBackend, NumpyBackend,
                       get_backend)
from .facade import SemanticCache
from .pruned import PrunedLookupConfig
from .quantized import QuantizedLookupConfig
from .sharded import ShardedKernelBackend, ShardedStore
from .tiers import GhostTier, HostTier, TierManager, TierStats
from .types import (CacheConfig, CacheEvent, CacheHit, CacheMetrics,
                    CacheMiss, CacheResult, DecisionBatch, TierConfig)

__all__ = [
    "SemanticCache", "CacheConfig", "CacheHit", "CacheMiss", "CacheResult",
    "CacheEvent", "CacheMetrics", "DecisionBatch", "LookupBackend",
    "NumpyBackend", "KernelBackend", "ShardedKernelBackend", "ShardedStore",
    "get_backend", "AsyncAdmitter", "TierConfig", "TierManager", "TierStats",
    "HostTier", "GhostTier", "QuantizedLookupConfig", "PrunedLookupConfig",
]
