"""Asynchronous admission: decouple ``admit`` from the request path.

Production engines never block a generation slot on eviction scoring — a
completed response is *queued* for admission and a background worker pays
the insert + RAC victim-scan cost off the critical path.  This module is
that queue for :class:`repro.cache.SemanticCache`:

  - :meth:`AsyncAdmitter.submit` appends ``(cid, emb, payload, t, req)``
    and returns immediately (the producer-visible cost is one deque append
    under a condition variable);
  - a daemon worker drains the queue in FIFO order, applying each
    admission through the facade's synchronous path — so policies, event
    hooks, payload bookkeeping, and metrics behave exactly as if the
    caller had admitted inline, just later;
  - :meth:`flush` blocks until everything queued (and in flight) has been
    applied and returns the cids evicted since the previous flush — the
    facade calls it at batch boundaries and before checkpoint/restore.

Determinism: admissions carry the logical time assigned at *submit* and
are applied in submission order, so after a ``flush()`` the cache state
(store, policy, payloads, metrics counters, clock) is identical to the
synchronous path given the same call sequence.  ``background=False`` goes
one step further for replay parity: nothing runs concurrently at all —
the queue only drains inside ``flush()``/``drain()`` on the caller's
thread.

Thread safety: the facade serializes all state mutation behind its own
lock; the admitter only orders *when* admissions happen.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any

__all__ = ["AsyncAdmitter"]


class AsyncAdmitter:
    """FIFO admission queue with an optional background drain worker.

    ``tracker`` (a :class:`repro.telemetry.Tracker`, observation-only)
    receives a ``cache.queue_depth`` gauge at every submit plus
    ``cache.enqueue_s`` / ``cache.flush_s`` stall histograms — the
    producer-visible admission-stall distributions behind the serving
    SLO report."""

    def __init__(self, cache, background: bool = True, tracker=None):
        self._cache = cache
        self._trk = tracker
        self._cv = threading.Condition()
        self._pending: deque[tuple] = deque()
        self._evicted: list[int] = []       # victims since the last flush
        self._inflight = 0                  # items popped but not yet applied
        self._error: BaseException | None = None   # first failed admission
        self._closed = False
        self.background = background
        self.enqueue_s = 0.0                # producer blocking: submit calls
        self.flush_s = 0.0                  # producer blocking: flush waits
        self.applied = 0
        self._worker = None
        if background:
            self._worker = threading.Thread(
                target=self._run, name="cache-admit", daemon=True)
            self._worker.start()

    # ------------------------------------------------------------ producer
    def __len__(self) -> int:
        with self._cv:
            return len(self._pending) + self._inflight

    def submit(self, cid: int, emb, payload: Any, t: int, req) -> None:
        """Queue one admission (logical time already assigned by the
        facade, so ordering is locked in at submit time)."""
        t0 = time.perf_counter()
        with self._cv:
            if self._closed:
                raise RuntimeError("AsyncAdmitter is closed")
            self._pending.append((cid, emb, payload, t, req))
            depth = len(self._pending) + self._inflight
            self._cv.notify_all()
        dt = time.perf_counter() - t0
        self.enqueue_s += dt
        if self._trk is not None:
            self._trk.observe("cache.enqueue_s", dt)
            self._trk.gauge("cache.queue_depth", depth)

    def flush(self) -> list[int]:
        """Apply every queued admission; return victims since last flush.

        If a queued admission raised while draining, that exception is
        re-raised here (once) — an error the synchronous path would have
        raised at the admit() call site must not become a silent drop."""
        t0 = time.perf_counter()
        if self.background:
            with self._cv:
                while self._pending or self._inflight:
                    self._cv.wait()
                out, self._evicted = self._evicted, []
        else:
            self._drain_inline()
            with self._cv:
                out, self._evicted = self._evicted, []
        dt = time.perf_counter() - t0
        self.flush_s += dt
        if self._trk is not None:
            self._trk.observe("cache.flush_s", dt)
        if self._error is not None:
            err, self._error = self._error, None
            with self._cv:
                self._evicted[:0] = out     # keep victims for the next
            raise err                       # flush() after the error
        return out

    drain = flush                           # replay-parity alias

    @property
    def stall_s(self) -> float:
        """Total producer-visible blocking (enqueue + flush waits)."""
        return self.enqueue_s + self.flush_s

    def close(self):
        """Flush outstanding work and stop the worker thread (the worker
        is stopped even when the flush re-raises a drain error).

        Deterministic shutdown guarantee: anything submitted *between* the
        flush and the close mark — e.g. a tier promotion raced in by a
        concurrent lookup — is still applied.  The background worker
        drains its queue before exiting; without a worker the final
        inline drain below covers the same window, so a close can never
        silently drop a queued admission or tier move."""
        try:
            self.flush()
        finally:
            with self._cv:
                self._closed = True
                self._cv.notify_all()
            if self._worker is not None:
                self._worker.join(timeout=5)
                self._worker = None
            self._drain_inline()            # tail drain: no dropped moves

    # ------------------------------------------------------------ consumer
    def _apply(self, item: tuple):
        evicted, error = [], None
        try:
            evicted = self._cache._admit_now(*item)
        except BaseException as e:          # surface via flush(), keep the
            error = e                       # worker (and flush waits) alive
        with self._cv:
            self._evicted.extend(evicted)
            if error is not None and self._error is None:
                self._error = error
            self.applied += 1
            self._inflight -= 1
            self._cv.notify_all()

    def _drain_inline(self):
        while True:
            with self._cv:
                if not self._pending:
                    return
                item = self._pending.popleft()
                self._inflight += 1
            self._apply(item)

    def _run(self):
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self._cv.wait()
                if not self._pending:       # closed and drained
                    return
                item = self._pending.popleft()
                self._inflight += 1
            self._apply(item)
