"""Tiered cache hierarchy behind :class:`repro.cache.SemanticCache`.

Three tiers, coldest evidence surviving longest (production LLM caches are
inherently multi-tier — HBM holds a fraction of the working set, so an
eviction from the device slab should *demote*, not drop):

  - **Device tier** — the existing journaled
    :class:`~repro.core.store.ResidentStore` slab the backends score
    (unchanged by this module; the facade owns it).
  - **Host tier** (:class:`HostTier`) — a much larger host-DRAM slab that
    catches device evictions (payload + embedding + policy metadata ride
    along) and serves device-tier misses.  It reuses ``ResidentStore``, so
    every tier move is a journal entry on the same
    :class:`~repro.core.store.MutationJournal` protocol the device mirrors
    and checkpoint/restore already speak.  Scoring is host-side
    (:class:`~repro.cache.backends.NumpyBackend`-style ``topk_rows`` over
    the occupied rows) — the host tier is DRAM-resident by definition, and
    its promotion scan is a shortlist, not the hot path.
  - **Ghost tier** (:class:`GhostTier`) — metadata only (id, topic, TP/TSI
    counters), ARC B1/B2-style: one capacity-bounded list for entries
    demoted and never promoted, one for entries that were promoted and
    later fell all the way out again.  A ghost hit at re-admission feeds
    the preserved relation evidence back into the policy (RAC's lifetime
    ``freq``/``dep`` counters and the dead topic's TP state), so
    demoted-then-requested topics re-enter hot instead of cold-starting.

Flow (all under the facade's lock):

  - **demote** — ``_admit_now``'s eviction loop hands the victim's
    embedding, payload, and ``RACPolicy.ghost_meta`` snapshot to
    :meth:`TierManager.demote`; the host tier inserts (insert-then-evict,
    LRU on demote/serve time) and anything it drops falls through to the
    ghost lists.
  - **promote** — a device miss falls through to :meth:`TierManager.serve`
    (Top-K scan via the backend ``topk_rows`` op); the served entry is
    removed here and re-admitted through the facade's normal admission
    path — the :class:`~repro.cache.async_admit.AsyncAdmitter` queue when
    configured, so the request path never blocks on eviction scoring.
  - **revive** — ``_admit_now`` asks :meth:`TierManager.on_admit` whether
    the cid is a known ghost; if so the metadata is pushed back into the
    policy (``revive_ghost``) *before* ``policy.on_admit`` runs, so the
    normal arrival path restores the counters.

The manager holds no reference to the facade or the policy (it is handed
the policy per call), so the facade's ``checkpoint()`` deep copy captures
the whole hierarchy with zero cooperation.  With ``host_capacity=0`` and
``ghost_capacity=0`` the facade never constructs a manager and the single-
tier decision sequence is bit-identical to the pre-tiering code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from repro.core.store import ResidentStore

from .types import TierConfig

__all__ = ["GhostTier", "HostTier", "TierManager", "TierStats", "TierConfig"]


class GhostTier:
    """Capacity-bounded insertion-ordered metadata map (FIFO eviction).

    The one bounded-ghost structure shared by the tier manager's ARC-style
    B1/B2 lists *and* :class:`~repro.core.rac.RACPolicy`'s lifetime ghost
    counters / ghost topic memory (which it unifies — the policy used to
    hand-roll the same FIFO drop loop twice).

    ``put`` inserts (or updates in place, keeping the original insertion
    position — plain dict semantics) and then enforces the bound: when the
    size exceeds ``capacity`` it drops the oldest entries and returns their
    keys so the caller can release any side state.  ``batch_div`` selects
    the drop batch ``max(1, capacity // batch_div, overshoot)`` —
    ``batch_div=16`` amortizes dict churn for the policy's large ghost
    table, ``batch_div=None`` drops exactly the overshoot (the topic-memory
    behavior).  Both keep the bound hard even for tiny capacities.
    """

    def __init__(self, capacity: int, batch_div: Optional[int] = None):
        self.capacity = int(capacity)
        self.batch_div = batch_div
        self._data: dict[Any, Any] = {}

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key) -> bool:
        return key in self._data

    def __getitem__(self, key):
        return self._data[key]

    def __iter__(self):
        return iter(self._data)

    def keys(self):
        return self._data.keys()

    def items(self):
        return self._data.items()

    def get(self, key, default=None):
        return self._data.get(key, default)

    def pop(self, key, *default):
        return self._data.pop(key, *default)

    def put(self, key, value) -> list:
        """Insert/update ``key`` and enforce the capacity bound; returns
        the keys dropped (oldest first), empty when nothing fell out."""
        self._data[key] = value
        dropped: list = []
        if len(self._data) > self.capacity:
            batch = self.capacity // self.batch_div if self.batch_div else 0
            drop = max(1, batch, len(self._data) - self.capacity)
            it = iter(self._data)
            dropped = [next(it) for _ in range(min(drop, len(self._data)))]
            for old in dropped:
                del self._data[old]
        return dropped


@dataclasses.dataclass
class TierStats:
    """Per-tier observability counters (the facade's ``tier_stats``)."""

    host_lookups: int = 0        # device misses that scanned the host tier
    host_hits: int = 0           # ...that the host tier served
    demotions: int = 0           # device evictions caught by the host tier
    promotions: int = 0          # host entries re-admitted toward device
    host_evictions: int = 0      # entries the host tier dropped (LRU)
    host_invalidations: int = 0  # stale host copies dropped at re-admit
    ghost_inserts: int = 0       # metadata records entering B1/B2
    ghost_drops: int = 0         # metadata records aged out of B1/B2
    ghost_revivals: int = 0      # re-admissions that found ghost metadata

    def snapshot(self) -> dict:
        return dataclasses.asdict(self)


class HostTier:
    """Host-DRAM second tier: a journaled ``ResidentStore`` slab plus the
    demoted entries' payloads and policy metadata, evicted LRU by
    demote/serve time (deterministic ``(last_t, cid)`` tie-break).

    Insert-then-evict like the device tier (the store carries the +1 spare
    slot), so a demote burst never loses the newest entry.  All mutations
    go through ``store.insert``/``store.remove`` — i.e. every tier move is
    a stamped :class:`~repro.core.store.MutationJournal` entry.
    """

    def __init__(self, capacity: int, dim: int):
        self.capacity = int(capacity)
        self.store = ResidentStore(capacity, dim)
        self.payloads: dict[int, Any] = {}
        self.meta: dict[int, Optional[dict]] = {}
        self.last_t: dict[int, int] = {}

    def __len__(self) -> int:
        return len(self.store)

    def __contains__(self, cid: int) -> bool:
        return cid in self.store

    def put(self, cid: int, emb: np.ndarray, payload: Any, t: int,
            meta: Optional[dict]) -> list[tuple[int, Optional[dict]]]:
        """Demote one entry in; returns ``(cid, meta)`` for everything the
        LRU bound pushed out (→ ghost tier)."""
        if cid in self.store:
            self.store.remove(cid)          # refresh = journaled re-insert
        self.store.insert(cid, np.asarray(emb, dtype=np.float32))
        self.payloads[cid] = payload
        self.meta[cid] = meta
        self.last_t[cid] = t
        dropped: list[tuple[int, Optional[dict]]] = []
        while len(self.store) > self.capacity:
            old = min(self.store.slot_of,
                      key=lambda c: (self.last_t.get(c, -1), c))
            self.store.remove(old)
            self.payloads.pop(old, None)
            self.last_t.pop(old, None)
            dropped.append((old, self.meta.pop(old, None)))
        return dropped

    def take(self, cid: int, t: int) -> tuple[np.ndarray, Any,
                                              Optional[dict]]:
        """Remove-at-serve: pop the entry for promotion (the admission
        path owns it from here)."""
        slot = self.store.slot_of[cid]
        emb = self.store.emb[slot].copy()
        self.store.remove(cid)
        payload = self.payloads.pop(cid, None)
        meta = self.meta.pop(cid, None)
        self.last_t.pop(cid, None)
        return emb, payload, meta

    def drop(self, cid: int) -> bool:
        """Invalidate a (stale) host copy without serving it."""
        if cid not in self.store:
            return False
        self.store.remove(cid)
        self.payloads.pop(cid, None)
        self.meta.pop(cid, None)
        self.last_t.pop(cid, None)
        return True

    def occupied_rows(self) -> np.ndarray:
        return np.fromiter(self.store.slot_of.values(), dtype=np.int64,
                           count=len(self.store.slot_of))

    def top1_batch(self, queries: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Host Top-1 per query — the ``decide_batch`` fall-through
        columns (host_cid/host_sim)."""
        from .backends import NumpyBackend
        queries = np.asarray(queries, dtype=np.float32)
        rows = self.occupied_rows()
        if rows.size == 0:
            b = queries.shape[0]
            return (np.full(b, -1, dtype=np.int64),
                    np.full(b, -np.inf, dtype=np.float64))
        return NumpyBackend().top1_rows(self.store, queries, rows)

    def topk(self, emb: np.ndarray, k: int, backend=None
             ) -> tuple[np.ndarray, np.ndarray]:
        """Promotion scan: Top-K over the occupied rows through a backend's
        ``topk_rows`` op (host-side numpy scoring by default)."""
        if backend is None:
            from .backends import NumpyBackend
            backend = NumpyBackend()
        rows = self.occupied_rows()
        if rows.size == 0:
            return (np.full((1, k), -1, dtype=np.int64),
                    np.full((1, k), -np.inf, dtype=np.float64))
        return backend.topk_rows(
            self.store, np.asarray(emb, dtype=np.float32)[None, :], rows, k)


class TierManager:
    """Owns the host tier and the ARC-style ghost lists; the facade calls
    it at three points (all under the facade's lock): device eviction
    (:meth:`demote`), device miss (:meth:`serve`), and admission
    (:meth:`on_admit`).  It never calls back into the facade, so the
    checkpoint deep copy needs no cooperation.

    ``tracker`` (a scoped :class:`repro.telemetry.Tracker` child, or
    None) receives the same flow counters :class:`TierStats` accumulates
    — demotions, promotions, host hits/evictions, ghost churn — plus a
    windowed promotion-rate series, so the per-tier flow shows up in the
    unified metric registry alongside the cache-level series.  Trackers
    deep-copy as shared references, so checkpointing a tiered cache never
    clones the sink."""

    def __init__(self, cfg: TierConfig, dim: int, tracker=None):
        self.cfg = cfg
        self.dim = dim
        self._trk = tracker
        self.host = (HostTier(cfg.host_capacity, dim)
                     if cfg.host_capacity > 0 else None)
        # ARC-style split: b1 = demoted, never promoted; b2 = promoted at
        # least once, then lost again (each bounded at ghost_capacity)
        cap = max(0, int(cfg.ghost_capacity))
        self.ghost_b1 = GhostTier(cap)
        self.ghost_b2 = GhostTier(cap)
        # promotion memory for the B1/B2 routing: the policy rebuilds an
        # eviction's metadata from scratch, so the "was promoted" bit has
        # to live here (bounded like the ghost lists themselves)
        self.promoted = GhostTier(cap)
        self.stats = TierStats()

    def _count(self, name: str, n: int = 1):
        # tolerate pre-telemetry snapshots restored into this process
        trk = getattr(self, "_trk", None)
        if trk is not None and n:
            trk.count(name, n)

    # ------------------------------------------------------------- ghosts
    def _ghost_insert(self, cid: int, meta: Optional[dict]):
        if self.cfg.ghost_capacity <= 0:
            return
        meta = dict(meta) if meta is not None else {}
        if meta.get("promoted") or cid in self.promoted:
            meta["promoted"] = True
        lst = self.ghost_b2 if meta.get("promoted") else self.ghost_b1
        dropped = lst.put(cid, meta)
        self.stats.ghost_inserts += 1
        self.stats.ghost_drops += len(dropped)
        self._count("ghost_inserts")
        self._count("ghost_drops", len(dropped))

    def ghost_get(self, cid: int) -> Optional[dict]:
        """Peek (no removal) at a cid's ghost record, B2 before B1."""
        hit = self.ghost_b2.get(cid)
        return hit if hit is not None else self.ghost_b1.get(cid)

    # ------------------------------------------------------------- demote
    def demote(self, cid: int, emb: np.ndarray, payload: Any, t: int,
               meta: Optional[dict]) -> bool:
        """Catch a device eviction.  Returns True when the entry landed in
        the host tier (payload retained), False when it fell straight to
        ghost metadata (or nowhere)."""
        if self.host is None:
            self._ghost_insert(cid, meta)
            return False
        self.stats.demotions += 1
        self._count("demotions")
        for old, old_meta in self.host.put(cid, emb, payload, t, meta):
            self.stats.host_evictions += 1
            self._count("host_evictions")
            self._ghost_insert(old, old_meta)
        return True

    # -------------------------------------------------------------- serve
    def serve(self, emb: np.ndarray, *, cid: int = -1,
              hit_mode: str = "semantic", tau_hit: float = 0.85,
              t: int = 0) -> list[tuple[int, float, np.ndarray, Any,
                                        Optional[dict]]]:
        """Host-tier fall-through for a device miss.

        Returns the served entries, best first — ``(cid, sim, emb,
        payload, meta)`` — already *removed* from the host tier (the
        caller re-admits them; remove-at-serve keeps exactly one
        authoritative copy).  Ranks past the first are ``promote_k``
        co-promotion candidates that also cleared ``tau_hit``.  Empty
        list = genuine miss."""
        if self.host is None or len(self.host) == 0:
            return []
        self.stats.host_lookups += 1
        self._count("host_lookups")
        if hit_mode == "content":
            if cid not in self.host:
                return []
            hemb, payload, meta = self.host.take(cid, t)
            if meta is not None:
                meta["promoted"] = True
            if self.cfg.ghost_capacity > 0:
                self.promoted.put(cid, True)
            self.stats.host_hits += 1
            self.stats.promotions += 1
            self._record_promotions(1, t)
            return [(cid, float("nan"), hemb, payload, meta)]
        k = max(1, int(self.cfg.promote_k))
        cids, sims = self.host.topk(emb, k)
        out = []
        for hcid, sim in zip(cids[0], sims[0]):
            if hcid < 0 or sim < tau_hit:
                break                    # sorted descending: nothing below
            hemb, payload, meta = self.host.take(int(hcid), t)
            if meta is not None:
                meta["promoted"] = True
            if self.cfg.ghost_capacity > 0:
                self.promoted.put(int(hcid), True)
            out.append((int(hcid), float(sim), hemb, payload, meta))
        if out:
            self.stats.host_hits += 1
            self.stats.promotions += len(out)
            self._record_promotions(len(out), t)
        return out

    def _record_promotions(self, n: int, t: int):
        trk = getattr(self, "_trk", None)
        if trk is None:
            return
        trk.count("host_hits")
        trk.count("promotions", n)
        # windowed promotion rate over logical time
        trk.observe("promotion", float(n), t)

    # ------------------------------------------------------------ admission
    def on_admit(self, cid: int, policy, emb: np.ndarray):
        """Admission-side bookkeeping, called between the device-store
        insert and ``policy.on_admit``: drop any stale host copy (the
        device entry is authoritative now) and, if the cid is a known
        ghost, feed the preserved metadata back into the policy
        (``revive_ghost``) so the normal arrival path restores the
        counters — and the demoted topic re-enters hot."""
        if self.host is not None and self.host.drop(cid):
            self.stats.host_invalidations += 1
            self._count("host_invalidations")
        meta = self.ghost_b2.pop(cid, None)
        if meta is None:
            meta = self.ghost_b1.pop(cid, None)
        if meta is None:
            return
        self.stats.ghost_revivals += 1
        self._count("ghost_revivals")
        revive = getattr(policy, "revive_ghost", None)
        if revive is not None:
            revive(cid, meta, rep=emb)
