"""Pluggable lookup/scoring backends behind :class:`repro.cache.SemanticCache`.

A backend answers three questions over the resident slab
(:class:`repro.core.store.ResidentStore`) and the RAC scoring state
(:class:`repro.core.policy_table.PolicyTable`):

  - Top-1 retrieval: for a (batch of) query embedding(s), which resident
    entry is most similar, and how similar?  (hit determination)
  - RAC value scoring: Eq. 1 ``TP(Z_q)·TSI(q)`` over the resident table.
    (eviction scoring)
  - Fused decision scoring (``decide_batch``): hit Top-1 + Alg. 4 topic
    routing against the representative table + occupancy-masked Eq. 1
    victim values, all from ONE launch per query chunk — the replay loop's
    and the serving engine's snapshot scoring surface.

Three implementations with identical hit decisions:

  - :class:`NumpyBackend` — the host path: masked matmul over the dense
    slab (exactly ``ResidentStore.nearest`` for single queries, so the
    refactored simulator stays bit-for-bit with the historical loop).
  - :class:`KernelBackend` — the device path: one ``kernels/ops.sim_top1``
    call scores the whole query batch against the fixed-shape slab up to
    the store's high-water mark (the resident count is a scalar-prefetched
    runtime value, so one XLA compilation serves every fill level), and
    ``kernels/ops.rac_value`` scores evictions.  Free slots hold zero
    embeddings: a zero row can only win Top-1 when every real similarity
    is negative, in which case the query is far below any sensible
    ``tau_hit`` and is reported as a miss ``(-1, -inf)`` — the same
    *decision* the numpy path makes.
  - :class:`~repro.cache.sharded.ShardedKernelBackend` (``"sharded"``) —
    the multi-device path: the slab is row-partitioned across a 1-D cache
    mesh and ``sim_top1`` runs per shard under ``shard_map`` with an
    argmax-reduce merge (see ``repro/cache/sharded.py``).

Backends are stateless with respect to the host store: they read the store
that is passed in, so one backend instance can serve many caches and
``checkpoint()/restore()`` needs no backend cooperation.  Device backends
keep *mirrors* — device copies of the host arrays keyed by the owners'
globally-unique mutation versions, kept fresh by scattering only the rows
the :class:`~repro.core.store.MutationJournal` reports dirty (a full
re-upload only on a journal miss, a shape change, or bulk churn).  The
embedding slab mirrors against the store's journal; the policy table's
slot and topic array families mirror against its two journals the same
way, which is what makes the whole decision state device-resident.
"""
from __future__ import annotations

from typing import Optional, Protocol, runtime_checkable

import numpy as np

from repro.core.policy_table import PolicyTable
from repro.core.store import ResidentStore
from repro.telemetry.tracing import annotate

from .pruned import (TopicBucketIndex, account_prune, as_pruned_config,
                     new_prune_stats, pruned_top1_batch, route_topics_host)
from .quantized import (QuantizedSlabMirror, account_scan,
                        as_quantized_config, new_quant_stats, resolve_topk)
from .types import DecisionBatch


@runtime_checkable
class LookupBackend(Protocol):
    """Protocol every lookup/scoring backend implements."""

    name: str

    def top1(self, store: ResidentStore,
             query: np.ndarray) -> tuple[int, float]:
        """Top-1 resident for one query -> (cid, sim) or (-1, -inf)."""
        ...

    def top1_batch(self, store: ResidentStore,
                   queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Top-1 residents for (B, D) queries -> (cids (B,), sims (B,))."""
        ...

    def top1_rows(self, store: ResidentStore, queries: np.ndarray,
                  rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Top-1 restricted to the given store ``rows`` (slot indices) —
        the same cosine scoring as :meth:`top1_batch`, so an incremental
        rescan over recently-admitted rows can never disagree with a full
        peek near ``tau_hit``."""
        ...

    def topk_rows(self, store: ResidentStore, queries: np.ndarray,
                  rows: np.ndarray, k: int
                  ) -> tuple[np.ndarray, np.ndarray]:
        """Top-K restricted to the given store ``rows`` (slot indices) —
        the K-generalization of :meth:`top1_rows`, behind the host-tier
        promotion scan and shortlist peeks.  Returns ((B, K) cids, (B, K)
        sims) sorted descending per query, ties toward the lower row
        position; ranks past the restriction size are ``(-1, -inf)``."""
        ...

    def rac_value(self, tsi: np.ndarray, tids: np.ndarray,
                  tp_last: np.ndarray, t_last: np.ndarray,
                  alpha: float, t_now: int) -> np.ndarray:
        """RAC Eq. 1 ``2^(-alpha·(t_now - t_last[tid])) · TP_last[tid] · tsi``."""
        ...

    def rac_value_masked(self, tsi: np.ndarray, tids: np.ndarray,
                         tp_last: np.ndarray, t_last: np.ndarray,
                         alpha: float, t_now: int,
                         valid: np.ndarray) -> np.ndarray:
        """Eq. 1 with a validity mask: invalid entries score ``+inf``
        (used by radix block eviction, where structurally-protected blocks
        must never win the min-value victim scan)."""
        ...

    def decide_batch(self, store: ResidentStore,
                     table: Optional[PolicyTable], queries: np.ndarray, *,
                     alpha: float = 0.0, t_now: int = 0) -> DecisionBatch:
        """Fused snapshot decision scoring for a (B, D) query block: hit
        Top-1 + routing Top-1 + masked Eq. 1 victim values in one launch.
        ``table=None`` (baseline policies) degrades to hit Top-1 only."""
        ...

    def top1_multi(self, arena, queries: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Policy-stacked Top-1 over an :class:`~repro.core.arena.
        ArenaStore`'s (P, S, D) slab — the multi-policy arena's snapshot
        scoring surface.  Returns ((P, B) cids, (P, B) sims); each row is
        exactly the answer :meth:`top1_batch` would give for that policy's
        store view."""
        ...


def small_delta(n_dirty: int, n_rows: int) -> bool:
    """The shared dirty-row sync policy: a delta this small is worth a
    device scatter; anything bigger re-uploads in full.  One definition
    for every mirror (``_DeviceMirror``, the sharded slab caches), so the
    threshold can never drift between copies."""
    return n_dirty <= max(64, n_rows // 4)


def bucket_rows(rows: np.ndarray, bucket: int = 64) -> np.ndarray:
    """Pad a sorted dirty-row index vector to a ``bucket`` multiple by
    repeating the last row (re-setting a row to the same value is a
    no-op), so XLA compiles one scatter per bucket, not one per distinct
    dirty count.  Shared by every dirty-row scatter path."""
    pad = (-len(rows)) % bucket
    if pad:
        rows = np.pad(rows, (0, pad), mode="edge")
    return rows


class _DeviceMirror:
    """Device copy of equally-row-indexed host arrays, kept fresh by
    dirty-row scatter against a :class:`MutationJournal`'s answers.

    ``sync(version, dirty_since, host_fn)`` returns jnp arrays of the
    ``dtypes`` declared at construction.  Same version → cached as-is with
    ZERO host work (``host_fn`` is only called on staleness, and the
    incremental branch casts only the dirty rows, so steady state is
    O(mutated rows) on the host too); journal-answerable small delta →
    ``.at[rows].set`` scatter; anything else (foreign lineage, aged-out
    journal, array growth, bulk churn) → full upload."""

    def __init__(self, dtypes: dict):
        self.dtypes = dtypes
        self.version = None
        self.arrays: Optional[dict] = None
        # "bytes" = host->device traffic this mirror moved (scattered rows
        # for incremental syncs, whole arrays for full uploads)
        self.stats = {"full": 0, "incremental": 0, "rows": 0, "bytes": 0}

    def sync(self, version: int, dirty_since, host_fn) -> dict:
        import jax.numpy as jnp
        if self.arrays is not None and version == self.version:
            return self.arrays
        host = host_fn()                       # raw host arrays, no casts
        dirty = None
        if self.arrays is not None and all(
                self.arrays[k].shape == v.shape for k, v in host.items()):
            dirty = dirty_since(self.version)
        n_rows = next(iter(host.values())).shape[0]
        if dirty is not None and small_delta(len(dirty), n_rows):
            if dirty:
                rows = bucket_rows(np.fromiter(sorted(dirty),
                                               dtype=np.int64,
                                               count=len(dirty)))
                out = {}
                for k, v in host.items():
                    block = np.asarray(v[rows], dtype=self.dtypes[k])
                    out[k] = self.arrays[k].at[rows].set(block)
                    self.stats["bytes"] += block.nbytes
                self.arrays = out
                self.stats["incremental"] += 1
                self.stats["rows"] += len(dirty)
        else:
            self.arrays = {k: jnp.asarray(np.asarray(v, self.dtypes[k]))
                           for k, v in host.items()}
            self.stats["full"] += 1
            self.stats["bytes"] += sum(
                v.size * np.dtype(self.dtypes[k]).itemsize
                for k, v in host.items())
        self.version = version
        return self.arrays


class NumpyBackend:
    """Host-side slab scan (the historical ``ResidentStore.nearest`` path).

    With ``quantized`` set (a :class:`~repro.cache.quantized.
    QuantizedLookupConfig`, or ``True``/a dict spec) this is the quantized
    path's *host oracle*: the same per-row int8 mirror, an exact int8 gemm
    (``kernels.quant.int8_scores``) instead of the Pallas scan, and the
    shared rescore/certify driver — bit-identical survivor scores to the
    device engines, so the whole quantized decision stack can be parity-
    tested without a device."""

    name = "numpy"

    def __init__(self, quantized=None, pruned=None):
        self.quantized = as_quantized_config(quantized)
        self.quant_stats = new_quant_stats()
        self._qhost = QuantizedSlabMirror()
        self._qhost_arena = QuantizedSlabMirror()
        # topic-pruned two-stage scan (cache/pruned.py): the facade wires
        # route_table/route_store when the acting policy exposes a
        # PolicyTable; run_arena wires route_tables (one per policy)
        self.pruned = as_pruned_config(pruned)
        self.prune_stats = new_prune_stats()
        self._pidx = TopicBucketIndex()
        self._pidx_arena: dict[int, TopicBucketIndex] = {}
        self.route_table = None
        self.route_store = None

    def top1(self, store: ResidentStore, query: np.ndarray) -> tuple[int, float]:
        if self.quantized is not None or self.pruned is not None:
            cids, sims = self.top1_batch(store, np.asarray(query)[None, :])
            return int(cids[0]), float(sims[0])
        return store.nearest(query)

    def top1_batch(self, store: ResidentStore,
                   queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        queries = np.asarray(queries, dtype=np.float32)
        b = queries.shape[0]
        if not store.slot_of:
            return (np.full(b, -1, dtype=np.int64),
                    np.full(b, -np.inf, dtype=np.float64))
        if self.pruned is not None:
            out = self._top1_batch_pruned(store, queries)
            if out is not None:
                return out
        if self.quantized is not None:
            return self._top1_batch_quantized(store, queries)
        return self._top1_batch_exact(store, queries)

    def _top1_batch_exact(self, store: ResidentStore,
                          queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        b = queries.shape[0]
        if not store.slot_of:
            return (np.full(b, -1, dtype=np.int64),
                    np.full(b, -np.inf, dtype=np.float64))
        sims = queries @ store.emb.T                      # (B, n_slots)
        sims[:, ~store.occ] = -np.inf
        idx = np.argmax(sims, axis=1)
        return (store.cid[idx].copy(),
                sims[np.arange(b), idx].astype(np.float64))

    def _top1_batch_quantized(self, store: ResidentStore, queries: np.ndarray
                              ) -> tuple[np.ndarray, np.ndarray]:
        """int8-gemm candidate scan over the host mirror + fp32 rescore.
        Scans slots up to the high-water mark (free rows are zeros — a
        certified free-row winner means every real score was negative,
        the same miss decision the masked exact scan makes)."""
        from repro.kernels.quant import (int8_scores, quantize_rows_int8,
                                         scan_margin)
        b = queries.shape[0]
        hwm, dim = store.hwm, store.emb.shape[1]
        qm = self._qhost.sync(store.version, store.dirty_since, store.emb)
        q8, qs, ql1 = quantize_rows_int8(queries)
        scores = (int8_scores(q8, qm.q8[:hwm])
                  * qs[:, None]) * qm.scale[None, :hwm]
        k = min(self.quantized.k, hwm)
        # stable descending sort = the kernel merge's lower-index tie break
        order = np.argsort(-scores, axis=1, kind="stable")[:, :k]
        vals = np.take_along_axis(scores, order, axis=1).astype(np.float64)
        eps = scan_margin(qs, ql1, qm.scale, qm.l1, dim)
        cids, sims, n_fb, n_union = resolve_topk(
            vals, order, eps, self.quantized.k >= hwm,
            self.quantized.tau_hit,
            lambda rows: self.top1_rows(store, queries, rows),
            lambda sel: self._top1_batch_exact(store, queries[sel]))
        account_scan(self.quant_stats, n_valid=hwm, dim=dim, batch=b,
                     n_union=n_union, n_fallback=n_fb)
        return cids, sims

    def _top1_batch_pruned(self, store: ResidentStore, queries: np.ndarray
                           ) -> Optional[tuple]:
        """Topic-pruned two-stage scan, host oracle: host routing matmul,
        gathered-rows candidate scans (int8 when ``quantized`` is also
        set), and the shared certify-or-fallback driver.  Returns ``None``
        when the routing surface isn't wired for this store (table-less
        policies, foreign stores like arena views) so the caller falls
        through to the quantized/exact paths."""
        table = self.route_table
        if table is None or store is not self.route_store:
            return None
        dim = store.emb.shape[1]
        probes = self.pruned.probes

        if self.quantized is not None:
            scan = self._make_pruned_q8_scan_host(store, queries)
        else:
            def scan(sel, rows):
                c, s = self.top1_rows(store, queries[sel], rows)
                return c, s, rows.size * dim * 4

        return pruned_top1_batch(
            store, table, queries, self.pruned, self._pidx,
            self.prune_stats,
            route_fn=lambda qs, aug, nt: route_topics_host(qs, aug, nt,
                                                           probes),
            scan_fn=scan,
            exact_fn=lambda sel: self._top1_batch_exact(store, queries[sel]))

    def _make_pruned_q8_scan_host(self, store: ResidentStore,
                                  queries: np.ndarray):
        """Stage-2 scan composing ``quantized_lookup``: the gathered
        candidate block is scanned over the int8 host mirror and certified
        by the inner ``resolve_topk`` predicate *within the candidate
        set* (its fallback leg re-scans only the candidates — outer
        certification against unprobed topics still happens in the pruned
        driver).  Gathered int8 + rescore bytes land in the prune ledger;
        the quant ledger is untouched on this path."""
        from repro.kernels.quant import (int8_scores, quantize_rows_int8,
                                         scan_margin)
        dim = store.emb.shape[1]
        qm = self._qhost.sync(store.version, store.dirty_since, store.emb)
        k_cfg = self.quantized.k
        tau = self.quantized.tau_hit

        def scan(sel, rows):
            qs_q = queries[sel]
            q8, qsc, ql1 = quantize_rows_int8(qs_q)
            scores = (int8_scores(q8, qm.q8[rows])
                      * qsc[:, None]) * qm.scale[rows][None, :]
            k = min(k_cfg, rows.size)
            order = np.argsort(-scores, axis=1, kind="stable")[:, :k]
            vals = np.take_along_axis(scores, order,
                                      axis=1).astype(np.float64)
            eps = scan_margin(qsc, ql1, qm.scale[rows], qm.l1[rows], dim)
            # local shortlist indices are ascending positions into the
            # ascending ``rows``, so the rescore keeps the lower-slot tie
            # contract within the candidate set
            cids, sims, n_fb, n_union = resolve_topk(
                vals, order, eps, k_cfg >= rows.size, tau,
                lambda lr: self.top1_rows(store, qs_q, rows[lr]),
                lambda ss: self.top1_rows(store, qs_q[ss], rows))
            nbytes = (rows.size * (dim + 4) + n_union * dim * 4
                      + (rows.size * dim * 4 if n_fb else 0))
            return cids, sims, nbytes

        return scan

    def _top1_multi_pruned(self, arena, queries: np.ndarray
                           ) -> Optional[tuple]:
        """Per-policy pruned pass over the arena's store views: each
        table-backed policy runs the two-stage driver against its own
        :class:`TopicBucketIndex`; table-less policies take a per-view
        exact scan (same per-row dots as the stacked gemm).  Returns
        ``None`` when ``run_arena`` didn't wire ``route_tables``."""
        tables = getattr(self, "route_tables", None)
        if tables is None:
            return None
        if not arena.track_rows:
            raise ValueError("pruned top1_multi needs an ArenaStore "
                             "built with track_rows=True")
        b = queries.shape[0]
        n_pol = arena.occ.shape[0]
        dim = arena.emb.shape[-1]
        probes = self.pruned.probes
        out_c = np.full((n_pol, b), -1, dtype=np.int64)
        out_s = np.full((n_pol, b), -np.inf)
        for p in range(n_pol):
            view = arena.views[p]
            if not view.slot_of:
                continue
            table = tables[p] if p < len(tables) else None
            if table is None:
                cids, sims = self._top1_batch_exact(view, queries)
            else:
                idx = self._pidx_arena.setdefault(p, TopicBucketIndex())
                cids, sims = pruned_top1_batch(
                    view, table, queries, self.pruned, idx,
                    self.prune_stats,
                    route_fn=lambda qs, aug, nt: route_topics_host(
                        qs, aug, nt, probes),
                    scan_fn=lambda sel, rows, v=view: (
                        *self.top1_rows(v, queries[sel], rows),
                        rows.size * dim * 4),
                    exact_fn=lambda sel, v=view: self._top1_batch_exact(
                        v, queries[sel]))
            out_c[p], out_s[p] = cids, sims
        return out_c, out_s

    def top1_rows(self, store: ResidentStore, queries: np.ndarray,
                  rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        queries = np.asarray(queries, dtype=np.float32)
        rows = np.asarray(rows, dtype=np.int64)
        sims = queries @ store.emb[rows].T                # (B, len(rows))
        best = np.argmax(sims, axis=1)
        b = np.arange(queries.shape[0])
        return (store.cid[rows[best]].copy(),
                sims[b, best].astype(np.float64))

    def topk_rows(self, store: ResidentStore, queries: np.ndarray,
                  rows: np.ndarray, k: int
                  ) -> tuple[np.ndarray, np.ndarray]:
        queries = np.asarray(queries, dtype=np.float32)
        rows = np.asarray(rows, dtype=np.int64)
        b = queries.shape[0]
        cids = np.full((b, k), -1, dtype=np.int64)
        sims = np.full((b, k), -np.inf, dtype=np.float64)
        if rows.size == 0:
            return cids, sims
        scores = queries @ store.emb[rows].T              # (B, len(rows))
        # stable descending sort: equal scores keep ascending row position,
        # matching the kernel merge's lower-candidate-index tie break
        order = np.argsort(-scores, axis=1, kind="stable")[:, :k]
        kk = order.shape[1]
        cids[:, :kk] = store.cid[rows[order]]
        sims[:, :kk] = np.take_along_axis(scores, order,
                                          axis=1).astype(np.float64)
        return cids, sims

    def top1_multi(self, arena, queries: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Host stacked pass: ONE (B, P*S) gemm scores the chunk against
        every policy's slab.  Free slots hold zero embeddings, so instead
        of masking, a zero row that wins maps to cid -1 → ``-inf`` — the
        same *decision* the masked per-view scan makes (a zero can only
        win when every real similarity is negative, far below any sensible
        ``tau_hit``); gate-adjacent outcomes are re-scored by the
        reference engine via the arena's epsilon flags."""
        queries = np.asarray(queries, dtype=np.float32)
        if self.pruned is not None:
            out = self._top1_multi_pruned(arena, queries)
            if out is not None:
                return out
        if self.quantized is not None:
            return self._top1_multi_quantized(arena, queries)
        b = queries.shape[0]
        n_pol, n_slots = arena.occ.shape
        flat = arena.emb.reshape(n_pol * n_slots, -1)
        sims3 = (queries @ flat.T).reshape(b, n_pol, n_slots)
        idx = sims3.argmax(axis=2)                        # (B, P)
        vals = np.take_along_axis(sims3, idx[:, :, None],
                                  axis=2)[:, :, 0]        # (B, P)
        cids = arena.cid[np.arange(n_pol)[None, :], idx].T.copy()
        sims = np.where(cids >= 0, vals.T.astype(np.float64), -np.inf)
        return cids, sims

    def _top1_multi_quantized(self, arena, queries: np.ndarray
                              ) -> tuple[np.ndarray, np.ndarray]:
        """Stacked host oracle of the quantized arena scan: one int8 gemm
        over the flat (P*S, D) mirror, then the shared per-policy
        rescore/certify driver against each policy's store view."""
        from repro.kernels.quant import (int8_scores, quantize_rows_int8,
                                         scan_margin)
        if not arena.track_rows:
            raise ValueError("quantized top1_multi needs an ArenaStore "
                             "built with track_rows=True")
        b = queries.shape[0]
        n_pol, n_slots = arena.occ.shape
        dim = arena.emb.shape[-1]
        qm = self._qhost_arena.sync(
            arena.version, arena.dirty_since,
            arena.emb.reshape(n_pol * n_slots, dim))
        q8, qs, ql1 = quantize_rows_int8(queries)
        scores3 = ((int8_scores(q8, qm.q8)
                    * qs[:, None]) * qm.scale[None, :]
                   ).reshape(b, n_pol, n_slots)
        scale2 = qm.scale.reshape(n_pol, n_slots)
        l12 = qm.l1.reshape(n_pol, n_slots)
        hwms = arena.hwms()
        k_cfg = self.quantized.k
        out_c = np.full((n_pol, b), -1, dtype=np.int64)
        out_s = np.full((n_pol, b), -np.inf)
        for p in range(n_pol):
            hw = int(hwms[p])
            if hw == 0:
                continue
            scores = scores3[:, p, :hw]
            k = min(k_cfg, hw)
            order = np.argsort(-scores, axis=1, kind="stable")[:, :k]
            vals = np.take_along_axis(scores, order,
                                      axis=1).astype(np.float64)
            eps = scan_margin(qs, ql1, scale2[p], l12[p], dim)
            view = arena.views[p]
            cids, sims, n_fb, n_union = resolve_topk(
                vals, order, eps, k_cfg >= hw, self.quantized.tau_hit,
                lambda rows, v=view: self.top1_rows(v, queries, rows),
                lambda sel, v=view: self._top1_batch_exact(v, queries[sel]))
            account_scan(self.quant_stats, n_valid=hw, dim=dim, batch=b,
                         n_union=n_union, n_fallback=n_fb)
            out_c[p], out_s[p] = cids, sims
        return out_c, out_s

    def rac_value(self, tsi, tids, tp_last, t_last, alpha, t_now):
        decay = 0.5 ** (alpha * (t_now - t_last[tids]))
        return decay * tp_last[tids] * tsi

    def rac_value_masked(self, tsi, tids, tp_last, t_last, alpha, t_now,
                         valid):
        vals = self.rac_value(tsi, tids, tp_last, t_last, alpha, t_now)
        return np.where(np.asarray(valid, dtype=bool), vals, np.inf)

    def decide_batch(self, store, table, queries, *, alpha=0.0, t_now=0):
        """Host oracle of the fused decision pass (see the protocol)."""
        queries = np.asarray(queries, dtype=np.float32)
        b = queries.shape[0]
        hit_cid, hit_sim = self.top1_batch(store, queries)
        route_tid = np.full(b, -1, dtype=np.int64)
        route_sim = np.full(b, -np.inf, dtype=np.float64)
        victim = None
        if table is not None:
            k = table.topic_hwm
            live_tids = np.flatnonzero(table.rep_valid[:k])
            if live_tids.size:
                # score live topics only: tids are never recycled, so the
                # dense table is mostly retired rows — the gather keeps the
                # host oracle O(live topics), with identical decisions (a
                # retired row could never win a gated route anyway)
                sims = queries @ table.rep[live_tids].T      # (B, live)
                best = np.argmax(sims, axis=1)
                route_sim = sims[np.arange(b), best].astype(np.float64)
                route_tid = live_tids[best].astype(np.int64)
            victim = self.rac_value_masked(
                table.tsi, np.maximum(table.topic_of, 0), table.tp_last,
                table.t_last, alpha, t_now, store.occ)
        return DecisionBatch(hit_cid, hit_sim, route_tid, route_sim, victim)


class KernelBackend:
    """Device path: batched Top-1 via the ``sim_top1`` Pallas kernel and
    eviction scoring via the ``rac_value`` kernel.

    The full (capacity+1, D) slab is passed every call so XLA sees one
    stable shape; query batches are padded up to a multiple of ``q_pad``
    for the same reason.  ``use_pallas=False`` routes through the jnp
    oracles (useful on CPU where interpret-mode overhead dominates).

    The fused decision path keeps the whole scoring state device-resident:
    three :class:`_DeviceMirror`\\ s hold the embedding slab + occupancy
    (synced against the store's mutation journal), the policy table's slot
    slabs (tsi/topic, its slot journal), and its topic tables (TP state +
    representatives, its topic journal).  Steady-state replay therefore
    moves O(mutated rows) per chunk, not O(capacity).
    """

    name = "kernel"

    def __init__(self, use_pallas: bool = True,
                 interpret: bool | None = None, q_pad: int = 8,
                 quantized=None, pruned=None):
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.q_pad = max(1, q_pad)
        self.quantized = as_quantized_config(quantized)
        self.quant_stats = new_quant_stats()
        # topic-pruned two-stage scan (cache/pruned.py): the facade wires
        # route_table/route_store when the acting policy exposes a
        # PolicyTable; run_arena wires route_tables (one per policy)
        self.pruned = as_pruned_config(pruned)
        self.prune_stats = new_prune_stats()
        self._pidx = TopicBucketIndex()
        self._pidx_arena: dict[int, TopicBucketIndex] = {}
        self.route_table = None
        self.route_store = None
        # the (T, D+1) augmented routing matrix [rep | spread], mirrored
        # against the bucket index's own journal
        self._route_mirror = _DeviceMirror({"aug": np.float32})
        self._store_mirror = _DeviceMirror({"emb": np.float32,
                                            "occ": np.int32})
        self._slot_mirror = _DeviceMirror({"tsi": np.float32,
                                           "tid": np.int32})
        self._topic_mirror = _DeviceMirror({"rep": np.float32,
                                            "tp": np.float32,
                                            "tl": np.int32})
        # the arena's stacked (P*S, D) slab, synced against its flat journal
        self._arena_mirror = _DeviceMirror({"emb": np.float32})
        # quantized path: host int8 requantizers + their device mirrors,
        # all keyed on the same journal versions as the fp32 mirrors (the
        # int8 uploads land in sync_stats "bytes" like any other mirror)
        self._qhost = QuantizedSlabMirror()
        self._qhost_arena = QuantizedSlabMirror()
        self._q8_mirror = _DeviceMirror({"q8": np.int8,
                                         "scale": np.float32,
                                         "l1": np.float32})
        self._q8_arena_mirror = _DeviceMirror({"q8": np.int8,
                                               "scale": np.float32,
                                               "l1": np.float32})
        # fused pipeline: device CSR copy of the topic-bucket index, keyed
        # on the index's (store, table) journal triple — NOT its aug
        # version (unassigned-only churn doesn't move the aug journal)
        self._csr_mirror = _DeviceMirror({"indptr": np.int32,
                                          "slots": np.int32})
        self._csr_arena: dict[int, _DeviceMirror] = {}
        self._tracker = None                # telemetry sink (observation-only)
        self._sync_seen: dict[str, int] = {}   # last sync_stats flushed to it

    def set_tracker(self, tracker) -> None:
        """Attach a :class:`repro.telemetry.Tracker` child; the backend
        emits ``sync.*`` counter deltas after each fused decision pass.
        Strictly observation-only — decisions are unaffected."""
        self._tracker = tracker

    def _flush_sync(self) -> None:
        """Emit the since-last-flush delta of ``sync_stats`` as counters."""
        trk = self._tracker
        if trk is None:
            return
        stats = self.sync_stats
        for k, v in stats.items():
            d = v - self._sync_seen.get(k, 0)
            if d:
                trk.count(f"sync.{k}", d)
        self._sync_seen = stats

    @property
    def sync_stats(self) -> dict:
        """Aggregate mirror observability: full uploads vs dirty-row
        scatters, total rows scattered, and host→device bytes moved."""
        mirrors = (self._store_mirror, self._slot_mirror,
                   self._topic_mirror, self._arena_mirror,
                   self._q8_mirror, self._q8_arena_mirror,
                   self._route_mirror, self._csr_mirror,
                   *self._csr_arena.values())
        return {k: sum(m.stats[k] for m in mirrors)
                for k in ("full", "incremental", "rows", "bytes")}

    @property
    def dispatch_stats(self) -> dict:
        """Launch/transfer observability: jitted dispatches issued, blocking
        device→host syncs, and seconds spent inside timed kernel intervals.
        Process-global (the jit caches are too) — consumers read deltas."""
        from repro.kernels import ops
        return dict(ops.dispatch_stats)

    def top1(self, store: ResidentStore, query: np.ndarray) -> tuple[int, float]:
        cids, sims = self.top1_batch(store, np.asarray(query)[None, :])
        return int(cids[0]), float(sims[0])

    def top1_batch(self, store: ResidentStore,
                   queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        queries = np.asarray(queries, dtype=np.float32)
        b = queries.shape[0]
        if not store.slot_of:
            return (np.full(b, -1, dtype=np.int64),
                    np.full(b, -np.inf, dtype=np.float64))
        if self.pruned is not None:
            out = self._top1_batch_pruned(store, queries)
            if out is not None:
                return out
        if self.quantized is not None:
            return self._top1_batch_quantized(store, queries)
        return self._top1_batch_exact(store, queries)

    def _top1_batch_exact(self, store: ResidentStore,
                          queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        from repro.kernels import ops                  # deferred: jax import
        b = queries.shape[0]
        if not store.slot_of:
            return (np.full(b, -1, dtype=np.int64),
                    np.full(b, -np.inf, dtype=np.float64))
        pad = (-b) % self.q_pad
        qp = np.pad(queries, ((0, pad), (0, 0))) if pad else queries
        # runtime n_valid = the store's high-water mark: slots past it have
        # never been occupied, so the kernel skips scoring the free tail
        # (one compilation — the count is scalar-prefetched, not baked in)
        with annotate("rac/sim_top1"):
            vals, idx = ops.run_timed(
                lambda: ops.sim_top1(qp, store.emb, n_valid=store.hwm,
                                     use_pallas=self.use_pallas,
                                     interpret=self.interpret),
                self._tracker, "sim_top1")
        vals = np.asarray(ops.to_host(vals)[:b], dtype=np.float64)
        idx = ops.to_host(idx)[:b]
        cids = store.cid[idx].copy()
        # a free (zeroed) slot can only win when all real sims < 0 → miss
        sims = np.where(cids >= 0, vals, -np.inf)
        return cids, sims

    def _top1_batch_quantized(self, store: ResidentStore,
                              queries: np.ndarray
                              ) -> tuple[np.ndarray, np.ndarray]:
        """Quantized candidate scan: the device streams the int8 mirror
        (4× fewer slab bytes) through ``sim_topk_q8``, then the ≤k
        survivors are rescored in fp32 by :meth:`top1_rows` — the same
        restricted-scan engine the admission rescans trust — and certified
        by the shared safety predicate (exact full scan on failure)."""
        from repro.kernels import ops
        from repro.kernels.quant import quantize_rows_int8, scan_margin
        b = queries.shape[0]
        dim = store.emb.shape[1]
        qm = self._qhost.sync(store.version, store.dirty_since, store.emb)
        dev = self._q8_mirror.sync(
            store.version, store.dirty_since,
            lambda: {"q8": qm.q8, "scale": qm.scale, "l1": qm.l1})
        if self.quantized.fused and b <= self.quantized.fused_max_batch:
            return self._top1_batch_quantized_fused(store, queries, dev)
        pad = (-b) % self.q_pad
        qp = np.pad(queries, ((0, pad), (0, 0))) if pad else queries
        q8, qs, ql1 = quantize_rows_int8(qp)
        k = self.quantized.k
        with annotate("rac/sim_topk_q8"):
            vals, idx = ops.run_timed(
                lambda: ops.sim_topk_q8(q8, qs, dev["q8"], dev["scale"], k,
                                        n_valid=store.hwm,
                                        use_pallas=self.use_pallas,
                                        interpret=self.interpret),
                self._tracker, "sim_topk_q8")
        vals = np.asarray(ops.to_host(vals)[:b], dtype=np.float64)
        rows = ops.to_host(idx)[:b]
        eps = scan_margin(qs[:b], ql1[:b], qm.scale, qm.l1, dim)
        cids, sims, n_fb, n_union = resolve_topk(
            vals, rows, eps, k >= store.hwm, self.quantized.tau_hit,
            lambda r: self.top1_rows(store, queries, r),
            lambda sel: self._top1_batch_exact(store, queries[sel]))
        account_scan(self.quant_stats, n_valid=store.hwm, dim=dim, batch=b,
                     n_union=n_union, n_fallback=n_fb)
        self._flush_sync()
        return cids, sims

    def _top1_batch_pruned(self, store: ResidentStore, queries: np.ndarray
                           ) -> Optional[tuple]:
        """Topic-pruned two-stage scan: stage 1 routes over the mirrored
        (T, D+1) augmented representative matrix (``ops.route_topics``,
        T ≪ S), stage 2 scans only the probed buckets' gathered rows
        (int8 when ``quantized`` is also set), and the shared driver
        certifies each decision against the unprobed-topic bound —
        uncertifiable queries take an exact full-scan fallback.  Returns
        ``None`` when the routing surface isn't wired for this store
        (table-less policies, foreign stores like arena views) so the
        caller falls through to the quantized/exact paths."""
        from repro.kernels import ops
        table = self.route_table
        if table is None or store is not self.route_store:
            return None
        cfg = self.pruned
        idx = self._pidx
        dim = store.emb.shape[1]

        if cfg.fused and queries.shape[0] <= cfg.fused_max_batch \
                and cfg.probes >= 1 and table.rep.shape[0] >= 1 \
                and store.hwm > 0:
            idx.sync(store, table)
            # unbound on purpose: the sharded backend delegates its whole
            # pruned pass here and carries the same mirror attributes but
            # not these helpers
            out = KernelBackend._fused_pruned_batch(self, store, table,
                                                    queries, cfg, idx)
            self._flush_sync()
            return out

        def route(qs, aug, n_top):
            # the driver synced ``idx`` already; freshen the device copy
            # of the aug matrix against the index's own journal
            dev = self._route_mirror.sync(idx.version, idx.dirty_since,
                                          lambda: {"aug": idx.aug})
            b = qs.shape[0]
            pad = (-b) % self.q_pad
            qp = np.pad(qs, ((0, pad), (0, 0))) if pad else qs
            with annotate("rac/route_topics"):
                vals, tids = ops.run_timed(
                    lambda: ops.route_topics(
                        qp, dev["aug"], cfg.probes, n_valid=n_top,
                        use_pallas=self.use_pallas,
                        interpret=self.interpret),
                    self._tracker, "route_topics")
            return ops.to_host(vals)[:b], ops.to_host(tids)[:b]

        if self.quantized is not None:
            # unbound on purpose: the sharded backend delegates its whole
            # pruned pass here and carries the same mirror attributes but
            # not this helper
            scan = KernelBackend._make_pruned_q8_scan(self, store, queries)
        else:
            def scan(sel, rows):
                c, s = self.top1_rows(store, queries[sel], rows)
                return c, s, rows.size * dim * 4

        out = pruned_top1_batch(
            store, table, queries, cfg, idx, self.prune_stats,
            route_fn=route, scan_fn=scan,
            exact_fn=lambda sel: self._top1_batch_exact(store, queries[sel]))
        self._flush_sync()
        return out

    def _top1_batch_quantized_fused(self, store: ResidentStore,
                                    queries: np.ndarray, dev
                                    ) -> tuple[np.ndarray, np.ndarray]:
        """One-launch quantized lookup (``kernels/fused.py``): the int8
        Top-K, the fp32 union rescore, and the ``resolve_topk`` safety
        arms run inside one jitted program; the host maps winner slots to
        cids and exact-rescans only the uncertified rows.  The fp32 slab
        stays mirrored on device for the union gather — a capacity (not
        bandwidth) cost relative to the staged path: the scan itself still
        streams only int8 bytes."""
        from repro.kernels import fused, ops
        b, dim = queries.shape
        cfg = self.quantized
        slab = self._store_mirror.sync(
            store.version, store.dirty_since,
            lambda: {"emb": store.emb, "occ": store.occ})
        bq = fused.pad_pow2(b, 1)       # pow2 bucket, floor 1 (serving b=1)
        qp, q8q, qsc, ql1 = fused.prep_queries(queries, bq)
        n_slots = store.emb.shape[0]
        with annotate("rac/fused_quant"):
            out = ops.run_timed(
                lambda: fused.fused_quant_lookup(
                    qp, q8q, qsc, ql1, slab["emb"], dev["q8"],
                    dev["scale"], dev["l1"], store.hwm, b, cfg.tau_hit,
                    k=min(int(cfg.k), n_slots), use_pallas=self.use_pallas,
                    interpret=self.interpret),
                self._tracker, "fused_quant")
        win, rmax, cert, n_u = ops.to_host_tuple(out)
        win = win[:b].astype(np.int64)
        rmax = np.asarray(rmax[:b], dtype=np.float64)
        certm = cert[:b].astype(bool)
        ok = win < n_slots                       # sentinel = no finite score
        cids = np.where(ok, store.cid[np.minimum(win, n_slots - 1)], -1)
        sims = np.where(cids >= 0, rmax, -np.inf)
        n_fb = int(b - np.count_nonzero(certm))
        if n_fb:
            sel = np.flatnonzero(~certm)
            f_c, f_s = self._top1_batch_exact(store, queries[sel])
            cids[sel] = np.asarray(f_c, dtype=np.int64)
            sims[sel] = np.asarray(f_s, dtype=np.float64)
        account_scan(self.quant_stats, n_valid=store.hwm, dim=dim, batch=b,
                     n_union=int(n_u), n_fallback=n_fb)
        fused.fused_stats["fallback_rows"] += n_fb
        self._flush_sync()
        return cids, sims

    def _fused_pruned_batch(self, store: ResidentStore, table: PolicyTable,
                            queries: np.ndarray, cfg, idx):
        """Mirror-freshening wrapper of :meth:`_fused_pruned_call` for a
        single journaled store (``idx`` must already be synced).  The int8
        mirror is maintained even without a composed quantized config —
        the fused candidate scan is always int8 (see docs)."""
        qm = self._qhost.sync(store.version, store.dirty_since, store.emb)
        slab = self._store_mirror.sync(
            store.version, store.dirty_since,
            lambda: {"emb": store.emb, "occ": store.occ})
        q8d = self._q8_mirror.sync(
            store.version, store.dirty_since,
            lambda: {"q8": qm.q8, "scale": qm.scale, "l1": qm.l1})
        augd = self._route_mirror.sync(idx.version, idx.dirty_since,
                                       lambda: {"aug": idx.aug})
        return KernelBackend._fused_pruned_call(
            self, store, table, queries, cfg, idx, emb_dev=slab["emb"],
            q8_dev=q8d, aug_dev=augd["aug"], csr_mirror=self._csr_mirror,
            slot_off=0, n_slots=store.emb.shape[0], cid_arr=store.cid,
            exact_fn=lambda sel: self._top1_batch_exact(store, queries[sel]),
            stats=self.prune_stats)

    def _fused_pruned_call(self, store, table, queries: np.ndarray, cfg,
                           idx, *, emb_dev, q8_dev, aug_dev, csr_mirror,
                           slot_off: int, n_slots: int, cid_arr,
                           exact_fn, stats: dict):
        """Shared fused-pruned driver (single stores and arena views):
        prep the static shape buckets, make ONE jitted launch covering
        routing → probe cap → CSR gather → int8 scan → fp32 union rescore
        → safety predicates, then map winners/fallbacks and ledger on the
        host.  ``slot_off`` shifts CSR slot ids into the flat (P·S) arena
        slab; ``n_slots`` is the per-view slot count winners map back
        into (the sentinel row lands outside it)."""
        from repro.kernels import fused, ops
        b, dim = queries.shape
        probes = int(cfg.probes)
        indptr_h, slot_ids, unassigned = idx.csr()
        t_rows = idx.aug.shape[0]
        budget = 1 << 30
        if cfg.max_scan_frac is not None:
            budget = max(int(cfg.min_scan_rows),
                         int(cfg.max_scan_frac * store.hwm))
        cap_c = fused.candidate_cap(np.diff(indptr_h), unassigned.size,
                                    probes, budget)
        csr = csr_mirror.sync(
            (idx.key, t_rows, slot_off), lambda v: None,
            lambda: dict(zip(
                ("indptr", "slots"),
                fused.csr_device_arrays(indptr_h, slot_ids + slot_off,
                                        unassigned + slot_off, t_rows))))
        # pow2 bucket, floor 1: every padded row pays a full cap_c-row
        # gather, and the serving path is b=1
        bq = fused.pad_pow2(b, 1)
        qp, q8q, qsc, ql1 = fused.prep_queries(queries, bq)
        k = (int(self.quantized.k) if self.quantized is not None
             else fused.DEFAULT_K)
        with annotate("rac/fused_pruned"):
            out = ops.run_timed(
                lambda: fused.fused_pruned_lookup(
                    qp, q8q, qsc, ql1, emb_dev, q8_dev["q8"],
                    q8_dev["scale"], q8_dev["l1"], aug_dev, csr["indptr"],
                    csr["slots"], int(table.topic_hwm), budget, b,
                    cfg.tau_hit, probes=probes, cap_c=cap_c, k=k,
                    use_pallas=self.use_pallas, interpret=self.interpret),
                self._tracker, "fused_pruned")
        win, rmax, ub, cert, total, probed, capped, n_u = \
            ops.to_host_tuple(out)
        local = win[:b].astype(np.int64) - slot_off
        rmax = np.asarray(rmax[:b], dtype=np.float64)
        certm = cert[:b].astype(bool)
        ok = (local >= 0) & (local < n_slots)
        cids = np.where(ok, cid_arr[np.clip(local, 0, n_slots - 1)], -1)
        sims = np.where(cids >= 0, rmax, -np.inf)
        n_fb = int(b - np.count_nonzero(certm))
        if n_fb:
            sel = np.flatnonzero(~certm)
            f_c, f_s = exact_fn(sel)
            cids[sel] = np.asarray(f_c, dtype=np.int64)
            sims[sel] = np.asarray(f_s, dtype=np.float64)
        tot = int(total[:b].sum())
        ncap = int(capped[:b].sum())
        # gathered int8 candidate bytes (codes + scale + l1) + the fp32
        # union-rescore gather
        slab_bytes = tot * (dim + 8) + int(n_u) * dim * 4
        account_prune(stats, n_valid=int(store.hwm), dim=dim,
                      n_topics=int(table.topic_hwm), batch=b,
                      probes=int(probed[:b].sum()), scanned_rows=tot,
                      slab_bytes=slab_bytes, n_fallback=n_fb,
                      n_capped=ncap)
        fused.fused_stats["fallback_rows"] += n_fb
        fused.fused_stats["capped_rows"] += ncap
        return cids, sims

    def _make_pruned_q8_scan(self, store: ResidentStore,
                             queries: np.ndarray):
        """Stage-2 scan composing ``quantized_lookup``: the gathered
        candidate block is scanned as int8 through ``sim_topk_q8`` and
        certified by the inner ``resolve_topk`` predicate *within the
        candidate set* (its fallback leg re-scans only the candidates —
        outer certification against unprobed topics still happens in the
        pruned driver).  Gathered int8 + rescore bytes land in the prune
        ledger; the quant ledger is untouched on this path."""
        from repro.kernels import ops
        from repro.kernels.quant import quantize_rows_int8, scan_margin
        dim = store.emb.shape[1]
        qm = self._qhost.sync(store.version, store.dirty_since, store.emb)
        k_cfg = self.quantized.k
        tau = self.quantized.tau_hit

        def scan(sel, rows):
            qs_q = queries[sel]
            b = qs_q.shape[0]
            pad = (-b) % self.q_pad
            qp = np.pad(qs_q, ((0, pad), (0, 0))) if pad else qs_q
            q8, qsc, ql1 = quantize_rows_int8(qp)
            # bucket the gathered block like top1_rows so XLA compiles
            # one kernel per bucket, not per distinct candidate count
            n = rows.size
            npad = -(-n // 64) * 64
            c8 = np.zeros((npad, dim), dtype=np.int8)
            c8[:n] = qm.q8[rows]
            csc = np.zeros(npad, dtype=np.float32)
            csc[:n] = qm.scale[rows]
            k = min(k_cfg, n)
            with annotate("rac/sim_topk_q8_pruned"):
                vals, idx = ops.run_timed(
                    lambda: ops.sim_topk_q8(q8, qsc, c8, csc, k, n_valid=n,
                                            use_pallas=self.use_pallas,
                                            interpret=self.interpret),
                    self._tracker, "sim_topk_q8")
            vals = np.asarray(ops.to_host(vals)[:b], dtype=np.float64)
            lrows = ops.to_host(idx)[:b]
            eps = scan_margin(qsc[:b], ql1[:b], qm.scale[rows],
                              qm.l1[rows], dim)
            # local shortlist indices are ascending positions into the
            # ascending ``rows``, so the rescore keeps the lower-slot tie
            # contract within the candidate set
            cids, sims, n_fb, n_union = resolve_topk(
                vals, lrows, eps, k_cfg >= n, tau,
                lambda lr: self.top1_rows(store, qs_q, rows[lr]),
                lambda ss: self.top1_rows(store, qs_q[ss], rows))
            nbytes = (n * (dim + 4) + n_union * dim * 4
                      + (n * dim * 4 if n_fb else 0))
            return cids, sims, nbytes

        return scan

    def _top1_multi_pruned(self, arena, queries: np.ndarray
                           ) -> Optional[tuple]:
        """Per-policy pruned pass over the arena's store views: each
        table-backed policy runs the two-stage driver against its own
        :class:`TopicBucketIndex` (host routing matrices go straight to
        the jitted kernel — per-policy device mirrors aren't worth their
        bookkeeping at arena sizes); table-less policies take a per-view
        exact kernel scan (same per-row f32 dots as the stacked launch).
        Unbound-delegation-safe: the sharded backend calls this body too,
        and arena views are dense, so the exact legs go through
        ``KernelBackend._top1_batch_exact`` explicitly.  Returns ``None``
        when ``run_arena`` didn't wire ``route_tables``."""
        from repro.kernels import ops
        tables = getattr(self, "route_tables", None)
        if tables is None:
            return None
        if not arena.track_rows:
            raise ValueError("pruned top1_multi needs an ArenaStore "
                             "built with track_rows=True")
        b = queries.shape[0]
        n_pol = arena.occ.shape[0]
        n_slots = arena.occ.shape[1]
        dim = arena.emb.shape[-1]
        cfg = self.pruned
        fused_on = cfg.fused and cfg.probes >= 1
        if fused_on:
            # one flat (P·S, D) fp32 + int8 mirror pair serves every
            # policy's fused launch; per-policy CSR slot ids are shifted
            # by p·S into the flat slab
            flat_dev = self._arena_mirror.sync(
                arena.version, arena.dirty_since,
                lambda: {"emb": arena.emb.reshape(n_pol * n_slots, dim)})
            qm = self._qhost_arena.sync(
                arena.version, arena.dirty_since,
                arena.emb.reshape(n_pol * n_slots, dim))
            q8d = self._q8_arena_mirror.sync(
                arena.version, arena.dirty_since,
                lambda: {"q8": qm.q8, "scale": qm.scale, "l1": qm.l1})

        def route(qs, aug, n_top):
            bq = qs.shape[0]
            pad = (-bq) % self.q_pad
            qp = np.pad(qs, ((0, pad), (0, 0))) if pad else qs
            with annotate("rac/route_topics"):
                vals, tids = ops.run_timed(
                    lambda: ops.route_topics(
                        qp, aug, cfg.probes, n_valid=n_top,
                        use_pallas=self.use_pallas,
                        interpret=self.interpret),
                    self._tracker, "route_topics")
            return ops.to_host(vals)[:bq], ops.to_host(tids)[:bq]

        out_c = np.full((n_pol, b), -1, dtype=np.int64)
        out_s = np.full((n_pol, b), -np.inf)
        for p in range(n_pol):
            view = arena.views[p]
            if not view.slot_of:
                continue
            table = tables[p] if p < len(tables) else None
            if table is None:
                cids, sims = KernelBackend._top1_batch_exact(self, view,
                                                             queries)
            elif fused_on and table.rep.shape[0] >= 1 and view.hwm > 0:
                idx = self._pidx_arena.setdefault(p, TopicBucketIndex())
                idx.sync(view, table)
                csr_m = self._csr_arena.setdefault(
                    p, _DeviceMirror({"indptr": np.int32,
                                      "slots": np.int32}))
                # the aug matrix rides the launch as a host array (the
                # staged arena route does the same) — per-policy device
                # mirrors aren't worth their bookkeeping at arena sizes
                cids, sims = KernelBackend._fused_pruned_call(
                    self, view, table, queries, cfg, idx,
                    emb_dev=flat_dev["emb"], q8_dev=q8d,
                    aug_dev=np.asarray(idx.aug, dtype=np.float32),
                    csr_mirror=csr_m, slot_off=p * n_slots,
                    n_slots=n_slots, cid_arr=view.cid,
                    exact_fn=lambda sel, v=view:
                        KernelBackend._top1_batch_exact(self, v,
                                                        queries[sel]),
                    stats=self.prune_stats)
            else:
                idx = self._pidx_arena.setdefault(p, TopicBucketIndex())
                cids, sims = pruned_top1_batch(
                    view, table, queries, cfg, idx, self.prune_stats,
                    route_fn=route,
                    scan_fn=lambda sel, rows, v=view: (
                        *self.top1_rows(v, queries[sel], rows),
                        rows.size * dim * 4),
                    exact_fn=lambda sel, v=view:
                        KernelBackend._top1_batch_exact(self, v,
                                                        queries[sel]))
            out_c[p], out_s[p] = cids, sims
        self._flush_sync()
        return out_c, out_s

    def top1_rows(self, store: ResidentStore, queries: np.ndarray,
                  rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        from repro.kernels import ops
        queries = np.asarray(queries, dtype=np.float32)
        rows = np.asarray(rows, dtype=np.int64)
        b, k = queries.shape[0], rows.shape[0]
        pad = (-b) % self.q_pad
        qp = np.pad(queries, ((0, pad), (0, 0))) if pad else queries
        # gather the restricted candidate block; its row count is padded to
        # a bucket so XLA compiles one kernel per bucket, not per count —
        # the runtime n_valid masks the zero tail exactly as in top1_batch
        kp = -(-k // 64) * 64
        cand = np.zeros((kp, store.emb.shape[1]), dtype=np.float32)
        cand[:k] = store.emb[rows]
        vals, idx = ops.sim_top1(qp, cand, n_valid=k,
                                 use_pallas=self.use_pallas,
                                 interpret=self.interpret)
        vals = np.asarray(ops.to_host(vals)[:b], dtype=np.float64)
        idx = ops.to_host(idx)[:b]
        return store.cid[rows[idx]].copy(), vals

    def topk_rows(self, store: ResidentStore, queries: np.ndarray,
                  rows: np.ndarray, k: int
                  ) -> tuple[np.ndarray, np.ndarray]:
        from repro.kernels import ops
        queries = np.asarray(queries, dtype=np.float32)
        rows = np.asarray(rows, dtype=np.int64)
        b, n = queries.shape[0], rows.shape[0]
        out_c = np.full((b, k), -1, dtype=np.int64)
        out_s = np.full((b, k), -np.inf, dtype=np.float64)
        if n == 0:
            return out_c, out_s
        pad = (-b) % self.q_pad
        qp = np.pad(queries, ((0, pad), (0, 0))) if pad else queries
        # same bucketed candidate gather as top1_rows; the kernel's K is
        # capped at the padded block size (ranks past the restriction come
        # back -inf and are mapped to (-1, -inf) below)
        kp = -(-n // 64) * 64
        cand = np.zeros((kp, store.emb.shape[1]), dtype=np.float32)
        cand[:n] = store.emb[rows]
        kk = min(k, kp)
        vals, idx = ops.sim_topk(qp, cand, kk, n_valid=n,
                                 use_pallas=self.use_pallas,
                                 interpret=self.interpret)
        vals = np.asarray(ops.to_host(vals)[:b], dtype=np.float64)  # (B, kk)
        idx = ops.to_host(idx)[:b]
        finite = np.isfinite(vals)
        out_c[:, :kk] = np.where(
            finite, store.cid[rows[np.minimum(idx, n - 1)]], -1)
        out_s[:, :kk] = np.where(finite, vals, -np.inf)
        return out_c, out_s

    def top1_multi(self, arena, queries: np.ndarray
                   ) -> tuple[np.ndarray, np.ndarray]:
        """Stacked device pass: ONE ``sim_top1_multi`` dispatch scores the
        query chunk against all P policy slabs, each masked to its own
        runtime high-water mark.  The (P*S, D) flat slab is mirrored
        against the arena's flat journal (dirty-row scatter), so
        steady-state chunks move O(mutations) rows for the whole arena."""
        from repro.kernels import ops                  # deferred: jax import
        if not arena.track_rows:
            # host-only arenas skip journaling entirely; a version-keyed
            # mirror would silently serve stale rows
            raise ValueError("KernelBackend.top1_multi needs an ArenaStore "
                             "built with track_rows=True")
        queries = np.asarray(queries, dtype=np.float32)
        b = queries.shape[0]
        n_pol, n_slots = arena.occ.shape
        if not any(v.slot_of for v in arena.views):
            return (np.full((n_pol, b), -1, dtype=np.int64),
                    np.full((n_pol, b), -np.inf, dtype=np.float64))
        if self.pruned is not None:
            out = self._top1_multi_pruned(arena, queries)
            if out is not None:
                return out
        if self.quantized is not None:
            return self._top1_multi_quantized(arena, queries)
        pad = (-b) % self.q_pad
        qp = np.pad(queries, ((0, pad), (0, 0))) if pad else queries
        dim = arena.emb.shape[-1]
        dev = self._arena_mirror.sync(
            arena.version, arena.dirty_since,
            lambda: {"emb": arena.emb.reshape(n_pol * n_slots, dim)})
        with annotate("rac/sim_top1_multi"):
            vals, idx = ops.run_timed(
                lambda: ops.sim_top1_multi(
                    qp, dev["emb"].reshape(n_pol, n_slots, dim),
                    n_valid=arena.hwms(), use_pallas=self.use_pallas,
                    interpret=self.interpret),
                self._tracker, "sim_top1_multi")
        vals = np.asarray(ops.to_host(vals)[:, :b], dtype=np.float64)
        idx = ops.to_host(idx)[:, :b]
        cids = arena.cid[np.arange(n_pol)[:, None], idx].copy()
        # a free (zeroed) slot can only win when all real sims < 0 → miss
        sims = np.where(cids >= 0, vals, -np.inf)
        self._flush_sync()
        return cids, sims

    def _top1_multi_quantized(self, arena, queries: np.ndarray
                              ) -> tuple[np.ndarray, np.ndarray]:
        """Stacked quantized arena scan: ONE ``sim_topk_q8_multi``
        dispatch streams every policy's int8 slab (the 4× byte saving
        multiplied by P), then each policy's survivors are rescored and
        certified against its own store view — per-row kernel-score
        independence makes each policy's shortlist the one its single-slab
        launch would have produced."""
        from repro.kernels import ops
        from repro.kernels.quant import quantize_rows_int8, scan_margin
        b = queries.shape[0]
        n_pol, n_slots = arena.occ.shape
        dim = arena.emb.shape[-1]
        qm = self._qhost_arena.sync(
            arena.version, arena.dirty_since,
            arena.emb.reshape(n_pol * n_slots, dim))
        dev = self._q8_arena_mirror.sync(
            arena.version, arena.dirty_since,
            lambda: {"q8": qm.q8, "scale": qm.scale, "l1": qm.l1})
        pad = (-b) % self.q_pad
        qp = np.pad(queries, ((0, pad), (0, 0))) if pad else queries
        q8, qs, ql1 = quantize_rows_int8(qp)
        k = self.quantized.k
        hwms = arena.hwms()
        with annotate("rac/sim_topk_q8_multi"):
            vals, idx = ops.run_timed(
                lambda: ops.sim_topk_q8_multi(
                    q8, qs, dev["q8"].reshape(n_pol, n_slots, dim),
                    dev["scale"].reshape(n_pol, n_slots), k, n_valid=hwms,
                    use_pallas=self.use_pallas, interpret=self.interpret),
                self._tracker, "sim_topk_q8_multi")
        vals = np.asarray(ops.to_host(vals)[:, :b], dtype=np.float64)
        rows = ops.to_host(idx)[:, :b]
        scale2 = qm.scale.reshape(n_pol, n_slots)
        l12 = qm.l1.reshape(n_pol, n_slots)
        out_c = np.full((n_pol, b), -1, dtype=np.int64)
        out_s = np.full((n_pol, b), -np.inf)
        for p in range(n_pol):
            hw = int(hwms[p])
            if hw == 0:
                continue
            eps = scan_margin(qs[:b], ql1[:b], scale2[p], l12[p], dim)
            view = arena.views[p]
            cids, sims, n_fb, n_union = resolve_topk(
                vals[p], rows[p], eps, k >= hw, self.quantized.tau_hit,
                lambda r, v=view: self.top1_rows(v, queries, r),
                # unbound on purpose: the sharded backend delegates its
                # stacked quantized pass here, and arena views are dense —
                # its own _top1_batch_exact expects sharded-store geometry
                lambda sel, v=view: KernelBackend._top1_batch_exact(
                    self, v, queries[sel]))
            account_scan(self.quant_stats, n_valid=hw, dim=dim, batch=b,
                         n_union=n_union, n_fallback=n_fb)
            out_c[p], out_s[p] = cids, sims
        self._flush_sync()
        return out_c, out_s

    def rac_value_masked(self, tsi, tids, tp_last, t_last, alpha, t_now,
                         valid):
        from repro.kernels import ops
        out = ops.rac_value_masked(
            np.asarray(tsi, dtype=np.float32),
            np.asarray(tids, dtype=np.int32),
            np.asarray(tp_last, dtype=np.float32),
            np.asarray(t_last - t_now, dtype=np.int32),
            np.asarray(valid, dtype=bool), float(alpha), 0,
            use_pallas=self.use_pallas, interpret=self.interpret)
        return np.asarray(ops.to_host(out), dtype=np.float64)

    def rac_value(self, tsi, tids, tp_last, t_last, alpha, t_now):
        from repro.kernels import ops
        # shift timestamps so t_now is the static constant 0: the kernel
        # sees 0 - (t_last - t_now) = t_now - t_last, and jit never
        # recompiles as simulation time advances.
        out = ops.rac_value(np.asarray(tsi, dtype=np.float32),
                            np.asarray(tids, dtype=np.int32),
                            np.asarray(tp_last, dtype=np.float32),
                            np.asarray(t_last - t_now, dtype=np.int32),
                            float(alpha), 0, use_pallas=self.use_pallas,
                            interpret=self.interpret)
        return np.asarray(ops.to_host(out), dtype=np.float64)

    def _device_state(self, store: ResidentStore, table: PolicyTable) -> dict:
        """The mirrored decision state, freshened by dirty-row scatter."""
        slab = self._store_mirror.sync(
            store.version, store.dirty_since,
            lambda: {"emb": store.emb, "occ": store.occ})
        slot = self._slot_mirror.sync(
            table.slot_version, table.dirty_slots_since,
            lambda: {"tsi": table.tsi, "tid": table.topic_of})
        topic = self._topic_mirror.sync(
            table.topic_version, table.dirty_topics_since,
            lambda: {"rep": table.rep, "tp": table.tp_last,
                     "tl": table.t_last})
        return {**slab, **slot, **topic}

    def decide_batch(self, store, table, queries, *, alpha=0.0, t_now=0):
        from repro.kernels import ops
        queries = np.asarray(queries, dtype=np.float32)
        b = queries.shape[0]
        if table is None:
            hit_cid, hit_sim = self.top1_batch(store, queries)
            return DecisionBatch(hit_cid, hit_sim,
                                 np.full(b, -1, dtype=np.int64),
                                 np.full(b, -np.inf, dtype=np.float64), None)
        if self.quantized is not None or self.pruned is not None:
            return self._decide_batch_quantized(store, table, queries,
                                                alpha=alpha, t_now=t_now)
        pad = (-b) % self.q_pad
        qp = np.pad(queries, ((0, pad), (0, 0))) if pad else queries
        dev = self._device_state(store, table)
        # ONE fused dispatch: hit Top-1 (runtime n_valid = store hwm) +
        # routing Top-1 (runtime n_topics = topic hwm) + masked Eq.1 victim
        # values with a runtime t_now — nothing recompiles as fill level,
        # topic count, or simulation time advance
        with annotate("rac/fused_decide"):
            out = ops.run_timed(
                lambda: ops.fused_decide(
                    qp, dev["emb"], store.hwm, dev["rep"], table.topic_hwm,
                    dev["tsi"], dev["tid"], dev["occ"], dev["tp"],
                    dev["tl"], t_now, alpha=float(alpha),
                    use_pallas=self.use_pallas, interpret=self.interpret),
                self._tracker, "fused_decide")
        hv, hi, rv, ri, vv = ops.to_host_tuple(out)
        hv = np.asarray(hv[:b], dtype=np.float64)
        cids = store.cid[np.asarray(hi[:b])].copy()
        # a free (zeroed) slot can only win when all real sims < 0 → miss
        sims = np.where(cids >= 0, hv, -np.inf)
        rv = np.asarray(rv[:b], dtype=np.float64)
        ri = np.where(np.isfinite(rv),
                      np.asarray(ri[:b], dtype=np.int64), -1)
        self._flush_sync()
        return DecisionBatch(cids, sims, ri, rv,
                             np.asarray(vv, dtype=np.float64))

    def _decide_batch_quantized(self, store, table, queries, *, alpha,
                                t_now):
        """Fused decision pass with a reduced-traffic hit leg: the hit
        Top-1 rides ``top1_batch`` — the topic-pruned and/or int8 scan,
        whichever is configured (skipping the fp32 slab upload entirely
        when quantized — the int8 mirror replaces it) — while routing and
        victim scoring run the same ``sim_top1``/``victim_value`` kernel
        math as the exact path's fused launch (per-leg score independence
        keeps the decisions identical)."""
        from repro.kernels import ops
        b = queries.shape[0]
        hit_cid, hit_sim = self.top1_batch(store, queries)
        pad = (-b) % self.q_pad
        qp = np.pad(queries, ((0, pad), (0, 0))) if pad else queries
        slot = self._slot_mirror.sync(
            table.slot_version, table.dirty_slots_since,
            lambda: {"tsi": table.tsi, "tid": table.topic_of})
        topic = self._topic_mirror.sync(
            table.topic_version, table.dirty_topics_since,
            lambda: {"rep": table.rep, "tp": table.tp_last,
                     "tl": table.t_last})
        # ONE auxiliary launch (routing Top-1 + victim values together)
        # instead of the former sim_top1 + victim_value pair
        with annotate("rac/decide_aux"):
            out = ops.run_timed(
                lambda: ops.decide_aux(
                    qp, topic["rep"], table.topic_hwm, slot["tsi"],
                    slot["tid"], np.asarray(store.occ, dtype=np.int32),
                    topic["tp"], topic["tl"], t_now, alpha=float(alpha),
                    use_pallas=self.use_pallas, interpret=self.interpret),
                self._tracker, "decide_aux")
        rv, ri, vv = ops.to_host_tuple(out)
        rv = np.asarray(rv[:b], dtype=np.float64)
        ri = np.where(np.isfinite(rv),
                      np.asarray(ri[:b], dtype=np.int64), -1)
        self._flush_sync()
        return DecisionBatch(hit_cid, hit_sim, ri, rv,
                             np.asarray(vv, dtype=np.float64))


def _backends() -> dict:
    # deferred: repro.cache.sharded pulls in jax-facing modules lazily, but
    # keep even its import off the module path of numpy-only consumers
    from .sharded import ShardedKernelBackend
    return {"numpy": NumpyBackend, "kernel": KernelBackend,
            "sharded": ShardedKernelBackend}


def get_backend(name: str, **kwargs) -> LookupBackend:
    """Instantiate a backend by config name
    (``"numpy"`` | ``"kernel"`` | ``"sharded"``).

    ``kwargs`` are forwarded to the backend constructor *uniformly*;
    unexpected ones raise (a ``TypeError`` from the constructor), they are
    never silently dropped.  An already-built backend instance passes
    through unchanged — constructor kwargs cannot apply to it, so passing
    any alongside an instance raises ``ValueError``."""
    if not isinstance(name, str):
        if not isinstance(name, LookupBackend):
            raise ValueError(f"expected a backend name or LookupBackend "
                             f"instance, got {name!r}")
        if kwargs:
            raise ValueError(f"backend instance {name!r} cannot take "
                             f"constructor kwargs {sorted(kwargs)}")
        return name
    registry = _backends()
    try:
        cls = registry[name]
    except KeyError:
        raise ValueError(f"unknown cache backend {name!r}; "
                         f"expected one of {sorted(registry)}") from None
    return cls(**kwargs)
