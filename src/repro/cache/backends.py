"""Pluggable lookup/scoring backends behind :class:`repro.cache.SemanticCache`.

A backend answers two questions over the resident slab
(:class:`repro.core.store.ResidentStore`):

  - Top-1 retrieval: for a (batch of) query embedding(s), which resident
    entry is most similar, and how similar?  (hit determination)
  - RAC value scoring: Eq. 1 ``TP(Z_q)·TSI(q)`` over the resident table.
    (eviction scoring)

Three implementations with identical hit decisions:

  - :class:`NumpyBackend` — the host path: masked matmul over the dense
    slab (exactly ``ResidentStore.nearest`` for single queries, so the
    refactored simulator stays bit-for-bit with the historical loop).
  - :class:`KernelBackend` — the device path: one ``kernels/ops.sim_top1``
    call scores the whole query batch against the fixed-shape slab up to
    the store's high-water mark (the resident count is a scalar-prefetched
    runtime value, so one XLA compilation serves every fill level), and
    ``kernels/ops.rac_value`` scores evictions.  Free slots hold zero
    embeddings: a zero row can only win Top-1 when every real similarity
    is negative, in which case the query is far below any sensible
    ``tau_hit`` and is reported as a miss ``(-1, -inf)`` — the same
    *decision* the numpy path makes.
  - :class:`~repro.cache.sharded.ShardedKernelBackend` (``"sharded"``) —
    the multi-device path: the slab is row-partitioned across a 1-D cache
    mesh and ``sim_top1`` runs per shard under ``shard_map`` with an
    argmax-reduce merge (see ``repro/cache/sharded.py``).

Backends are stateless with respect to the host store: they read the store
that is passed in, so one backend instance can serve many caches and
``checkpoint()/restore()`` needs no backend cooperation (the sharded
backend's device-side slab is a cache keyed by the store's mutation
version, rebuilt on demand).
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.store import ResidentStore


@runtime_checkable
class LookupBackend(Protocol):
    """Protocol every lookup/scoring backend implements."""

    name: str

    def top1(self, store: ResidentStore,
             query: np.ndarray) -> tuple[int, float]:
        """Top-1 resident for one query -> (cid, sim) or (-1, -inf)."""
        ...

    def top1_batch(self, store: ResidentStore,
                   queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Top-1 residents for (B, D) queries -> (cids (B,), sims (B,))."""
        ...

    def top1_rows(self, store: ResidentStore, queries: np.ndarray,
                  rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Top-1 restricted to the given store ``rows`` (slot indices) —
        the same cosine scoring as :meth:`top1_batch`, so an incremental
        rescan over recently-admitted rows can never disagree with a full
        peek near ``tau_hit``."""
        ...

    def rac_value(self, tsi: np.ndarray, tids: np.ndarray,
                  tp_last: np.ndarray, t_last: np.ndarray,
                  alpha: float, t_now: int) -> np.ndarray:
        """RAC Eq. 1 ``2^(-alpha·(t_now - t_last[tid])) · TP_last[tid] · tsi``."""
        ...

    def rac_value_masked(self, tsi: np.ndarray, tids: np.ndarray,
                         tp_last: np.ndarray, t_last: np.ndarray,
                         alpha: float, t_now: int,
                         valid: np.ndarray) -> np.ndarray:
        """Eq. 1 with a validity mask: invalid entries score ``+inf``
        (used by radix block eviction, where structurally-protected blocks
        must never win the min-value victim scan)."""
        ...


class NumpyBackend:
    """Host-side slab scan (the historical ``ResidentStore.nearest`` path)."""

    name = "numpy"

    def top1(self, store: ResidentStore, query: np.ndarray) -> tuple[int, float]:
        return store.nearest(query)

    def top1_batch(self, store: ResidentStore,
                   queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        queries = np.asarray(queries, dtype=np.float32)
        b = queries.shape[0]
        if not store.slot_of:
            return (np.full(b, -1, dtype=np.int64),
                    np.full(b, -np.inf, dtype=np.float64))
        sims = queries @ store.emb.T                      # (B, n_slots)
        sims[:, ~store.occ] = -np.inf
        idx = np.argmax(sims, axis=1)
        return (store.cid[idx].copy(),
                sims[np.arange(b), idx].astype(np.float64))

    def top1_rows(self, store: ResidentStore, queries: np.ndarray,
                  rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        queries = np.asarray(queries, dtype=np.float32)
        rows = np.asarray(rows, dtype=np.int64)
        sims = queries @ store.emb[rows].T                # (B, len(rows))
        best = np.argmax(sims, axis=1)
        b = np.arange(queries.shape[0])
        return (store.cid[rows[best]].copy(),
                sims[b, best].astype(np.float64))

    def rac_value(self, tsi, tids, tp_last, t_last, alpha, t_now):
        decay = 0.5 ** (alpha * (t_now - t_last[tids]))
        return decay * tp_last[tids] * tsi

    def rac_value_masked(self, tsi, tids, tp_last, t_last, alpha, t_now,
                         valid):
        vals = self.rac_value(tsi, tids, tp_last, t_last, alpha, t_now)
        return np.where(np.asarray(valid, dtype=bool), vals, np.inf)


class KernelBackend:
    """Device path: batched Top-1 via the ``sim_top1`` Pallas kernel and
    eviction scoring via the ``rac_value`` kernel.

    The full (capacity+1, D) slab is passed every call so XLA sees one
    stable shape; query batches are padded up to a multiple of ``q_pad``
    for the same reason.  ``use_pallas=False`` routes through the jnp
    oracles (useful on CPU where interpret-mode overhead dominates).
    """

    name = "kernel"

    def __init__(self, use_pallas: bool = True,
                 interpret: bool | None = None, q_pad: int = 8):
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.q_pad = max(1, q_pad)

    def top1(self, store: ResidentStore, query: np.ndarray) -> tuple[int, float]:
        cids, sims = self.top1_batch(store, np.asarray(query)[None, :])
        return int(cids[0]), float(sims[0])

    def top1_batch(self, store: ResidentStore,
                   queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        from repro.kernels import ops                  # deferred: jax import
        queries = np.asarray(queries, dtype=np.float32)
        b = queries.shape[0]
        if not store.slot_of:
            return (np.full(b, -1, dtype=np.int64),
                    np.full(b, -np.inf, dtype=np.float64))
        pad = (-b) % self.q_pad
        qp = np.pad(queries, ((0, pad), (0, 0))) if pad else queries
        # runtime n_valid = the store's high-water mark: slots past it have
        # never been occupied, so the kernel skips scoring the free tail
        # (one compilation — the count is scalar-prefetched, not baked in)
        vals, idx = ops.sim_top1(qp, store.emb, n_valid=store.hwm,
                                 use_pallas=self.use_pallas,
                                 interpret=self.interpret)
        vals = np.asarray(vals[:b], dtype=np.float64)
        idx = np.asarray(idx[:b])
        cids = store.cid[idx].copy()
        # a free (zeroed) slot can only win when all real sims < 0 → miss
        sims = np.where(cids >= 0, vals, -np.inf)
        return cids, sims

    def top1_rows(self, store: ResidentStore, queries: np.ndarray,
                  rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        from repro.kernels import ops
        queries = np.asarray(queries, dtype=np.float32)
        rows = np.asarray(rows, dtype=np.int64)
        b, k = queries.shape[0], rows.shape[0]
        pad = (-b) % self.q_pad
        qp = np.pad(queries, ((0, pad), (0, 0))) if pad else queries
        # gather the restricted candidate block; its row count is padded to
        # a bucket so XLA compiles one kernel per bucket, not per count —
        # the runtime n_valid masks the zero tail exactly as in top1_batch
        kp = -(-k // 64) * 64
        cand = np.zeros((kp, store.emb.shape[1]), dtype=np.float32)
        cand[:k] = store.emb[rows]
        vals, idx = ops.sim_top1(qp, cand, n_valid=k,
                                 use_pallas=self.use_pallas,
                                 interpret=self.interpret)
        vals = np.asarray(vals[:b], dtype=np.float64)
        idx = np.asarray(idx[:b])
        return store.cid[rows[idx]].copy(), vals

    def rac_value_masked(self, tsi, tids, tp_last, t_last, alpha, t_now,
                         valid):
        from repro.kernels import ops
        out = ops.rac_value_masked(
            np.asarray(tsi, dtype=np.float32),
            np.asarray(tids, dtype=np.int32),
            np.asarray(tp_last, dtype=np.float32),
            np.asarray(t_last - t_now, dtype=np.int32),
            np.asarray(valid, dtype=bool), float(alpha), 0,
            use_pallas=self.use_pallas, interpret=self.interpret)
        return np.asarray(out, dtype=np.float64)

    def rac_value(self, tsi, tids, tp_last, t_last, alpha, t_now):
        from repro.kernels import ops
        # shift timestamps so t_now is the static constant 0: the kernel
        # sees 0 - (t_last - t_now) = t_now - t_last, and jit never
        # recompiles as simulation time advances.
        out = ops.rac_value(np.asarray(tsi, dtype=np.float32),
                            np.asarray(tids, dtype=np.int32),
                            np.asarray(tp_last, dtype=np.float32),
                            np.asarray(t_last - t_now, dtype=np.int32),
                            float(alpha), 0, use_pallas=self.use_pallas,
                            interpret=self.interpret)
        return np.asarray(out, dtype=np.float64)


def _backends() -> dict:
    # deferred: repro.cache.sharded pulls in jax-facing modules lazily, but
    # keep even its import off the module path of numpy-only consumers
    from .sharded import ShardedKernelBackend
    return {"numpy": NumpyBackend, "kernel": KernelBackend,
            "sharded": ShardedKernelBackend}


def get_backend(name: str, **kwargs) -> LookupBackend:
    """Instantiate a backend by config name
    (``"numpy"`` | ``"kernel"`` | ``"sharded"``).

    ``kwargs`` are forwarded to the backend constructor *uniformly*;
    unexpected ones raise (a ``TypeError`` from the constructor), they are
    never silently dropped.  An already-built backend instance passes
    through unchanged — constructor kwargs cannot apply to it, so passing
    any alongside an instance raises ``ValueError``."""
    if not isinstance(name, str):
        if not isinstance(name, LookupBackend):
            raise ValueError(f"expected a backend name or LookupBackend "
                             f"instance, got {name!r}")
        if kwargs:
            raise ValueError(f"backend instance {name!r} cannot take "
                             f"constructor kwargs {sorted(kwargs)}")
        return name
    registry = _backends()
    try:
        cls = registry[name]
    except KeyError:
        raise ValueError(f"unknown cache backend {name!r}; "
                         f"expected one of {sorted(registry)}") from None
    return cls(**kwargs)
