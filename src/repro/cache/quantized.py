"""Quantized int8 candidate generation for the lookup/decision stack.

The exact lookup path streams the fp32 embedding slab (O(S·D) bytes) for
every scan.  With ``CacheConfig.quantized_lookup`` the backends instead:

  1. keep a **per-row-scaled int8 mirror** of the slab fresh via the same
     journal dirty-row machinery as the device mirrors
     (:class:`QuantizedSlabMirror`);
  2. scan it with the quantized Top-K kernel (``ops.sim_topk_q8``) — 4×
     fewer slab bytes moved;
  3. **rescore the ≤k survivors in fp32** against the exact rows (the
     backend's own ``top1_rows`` engine) and certify the result with
     :func:`resolve_topk`'s safety predicate;
  4. fall back to the exact full scan for any query the predicate cannot
     certify (counted — ``cache.rescore_fallbacks`` telemetry).

Decision-exactness argument (docs/quantized_lookup.md has the long form):
``scan_margin`` bounds the per-row quantization error ``eps``, so every
row *not* in the survivor union has exact score ≤ ``kth + eps`` where
``kth`` is the smallest surviving approximate score.  If the rescored
union max beats that threshold, it is the true global Top-1 — and because
every tied true-maximum row is itself in the union, the lowest-slot tie
break matches the exact path's argmax bit-for-bit.  Otherwise, if both
the rescored max and the threshold sit strictly below ``tau_hit``, the
query is a certain miss (no row can reach the tau band) and the
approximate best is decision-equivalent.  Anything else takes the exact
fallback, so hit/miss/eviction sequences are identical to the exact path
by construction, not by luck.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

__all__ = [
    "QuantizedLookupConfig", "as_quantized_config", "new_quant_stats",
    "QuantizedSlabMirror", "resolve_topk", "account_scan",
]


@dataclasses.dataclass(frozen=True)
class QuantizedLookupConfig:
    """Knobs for the quantized candidate-generation path.

    ``k``: survivor-shortlist width of the int8 scan (static per launch
    shape; wider k widens the certified margin and shrinks the fallback
    rate at the cost of rescore work).  ``tau_hit``: the facade's hit
    threshold, used by the certain-miss arm of the safety predicate; when
    ``None`` (content-mode stores, arenas without a tau band) only the
    top-1-margin arm certifies and everything else falls back.
    ``fused`` routes kernel backends through the device-resident fused
    pipeline (int8 scan + fp32 rescore + safety predicate in one jitted
    launch; see ``docs/fused_pipeline.md``); ``fused=False`` keeps the
    staged multi-launch driver.  ``fused_max_batch`` bounds the chunk
    width the fused program serves — wider chunks fall through to the
    staged driver, whose per-stage launches amortize better there.
    """
    k: int = 8
    tau_hit: Optional[float] = None
    fused: bool = True
    fused_max_batch: int = 16


def as_quantized_config(spec) -> Optional[QuantizedLookupConfig]:
    """Normalize a ``CacheConfig.quantized_lookup`` spec: ``False``/``None``
    -> disabled, ``True`` -> defaults, dict -> field overrides, or a ready
    :class:`QuantizedLookupConfig`."""
    if spec is None or spec is False:
        return None
    if spec is True:
        return QuantizedLookupConfig()
    if isinstance(spec, QuantizedLookupConfig):
        return spec
    if isinstance(spec, dict):
        return QuantizedLookupConfig(**spec)
    raise ValueError(f"bad quantized_lookup spec: {spec!r}")


def new_quant_stats() -> dict:
    """Counter surface for the quantized path (mirrors ``sync_stats``):
    scans/queries served, exact-scan fallbacks, fp32 rows rescored, and
    the byte ledger — ``bytes_scanned`` is what the quantized path
    actually read (int8 slab + scales + rescored rows + any fallback
    scans), ``bytes_exact`` what the fp32 path would have read."""
    return {"scans": 0, "queries": 0, "fallbacks": 0, "rescore_rows": 0,
            "bytes_scanned": 0, "bytes_exact": 0}


def account_scan(stats: dict, *, n_valid: int, dim: int, batch: int,
                 n_union: int, n_fallback: int) -> None:
    """Fold one quantized scan into the counter surface.  The int8 scan
    reads ``n_valid`` rows of D int8 + one fp32 scale each; the rescore
    gathers ``n_union`` exact fp32 rows; a fallback re-reads the fp32
    slab once for the whole unsafe sub-batch."""
    stats["scans"] += 1
    stats["queries"] += batch
    stats["fallbacks"] += n_fallback
    stats["rescore_rows"] += n_union
    stats["bytes_exact"] += n_valid * dim * 4
    stats["bytes_scanned"] += n_valid * (dim + 4) + n_union * dim * 4
    if n_fallback:
        stats["bytes_scanned"] += n_valid * dim * 4


class QuantizedSlabMirror:
    """Host-side per-row int8 mirror of a journaled fp32 row slab.

    Same contract as the device ``_DeviceMirror``: keyed on the journal
    ``version``, requantizing only the dirty rows when the journal can
    name them and the delta is small, else a full requantize.  Holds the
    int8 codes, the per-row fp32 scales, and the per-row L1 norms that
    ``scan_margin`` consumes.  Device backends upload ``q8``/``scale``
    from here; the numpy backend scans it directly.
    """

    def __init__(self) -> None:
        self.version = None
        self.q8: Optional[np.ndarray] = None
        self.scale: Optional[np.ndarray] = None
        self.l1: Optional[np.ndarray] = None
        self.stats = {"full": 0, "incremental": 0, "rows": 0}

    def sync(self, version, dirty_since: Callable, emb: np.ndarray
             ) -> "QuantizedSlabMirror":
        from repro.kernels.quant import quantize_rows_int8

        from .backends import small_delta
        emb = np.asarray(emb)
        fresh = (self.q8 is not None and version == self.version
                 and self.q8.shape == emb.shape)
        if fresh:
            return self
        dirty = None
        if self.q8 is not None and self.q8.shape == emb.shape:
            dirty = dirty_since(self.version)
        if dirty is not None and small_delta(len(dirty), emb.shape[0]):
            if dirty:
                rows = np.fromiter(sorted(dirty), dtype=np.int64,
                                   count=len(dirty))
                q8, sc, l1 = quantize_rows_int8(emb[rows])
                self.q8[rows] = q8
                self.scale[rows] = sc
                self.l1[rows] = l1
                self.stats["incremental"] += 1
                self.stats["rows"] += len(rows)
        else:
            self.q8, self.scale, self.l1 = quantize_rows_int8(emb)
            self.stats["full"] += 1
        self.version = version
        return self


def resolve_topk(vals: np.ndarray, rows: np.ndarray, eps: np.ndarray,
                 covers_all: bool, tau_hit: Optional[float],
                 rescore_fn: Callable, exact_fn: Callable
                 ) -> tuple[np.ndarray, np.ndarray, int, int]:
    """Turn int8 survivor shortlists into certified exact decisions.

    ``vals`` (B, K) approximate scores sorted descending (``-inf`` pads),
    ``rows`` (B, K) their slot indices, ``eps`` (B,) the per-query error
    bound, ``covers_all`` whether the shortlist provably contains every
    valid row (k ≥ resident count — no discarded row exists).

    ``rescore_fn(rows_ascending) -> (cids (B,), sims (B,))`` rescores the
    survivor union in fp32 with the backend's own restricted-scan engine
    (for *all* B queries — the union is shared, and restricted scans cost
    O(|union|·D) independent of B).  ``exact_fn(query_indices) ->
    (cids, sims)`` runs the exact full scan for the unsafe sub-batch.

    Safety predicate per query (strict inequalities; see module doc):

    - rescored union max > ``kth + eps``  -> certified exact Top-1;
    - rescored max < tau and ``kth + eps`` < tau -> certified miss;
    - otherwise -> exact fallback.

    Returns ``(cids, sims, n_fallback, n_union)``; free-slot survivors
    (cid < 0) are mapped to ``-inf`` sims at the end, exactly like the
    exact path's post-scan mapping.
    """
    vals = np.asarray(vals, dtype=np.float64)
    b = vals.shape[0]
    finite = np.isfinite(vals)
    if covers_all:
        thresh = np.full(b, -np.inf)
    else:
        kth = vals[:, -1]
        thresh = np.where(np.isfinite(kth), kth + eps, -np.inf)
    uniq = np.unique(np.asarray(rows)[finite])
    r_cids, r_sims = rescore_fn(uniq)
    r_sims = np.asarray(r_sims, dtype=np.float64)
    safe = r_sims > thresh
    if tau_hit is not None:
        safe |= (r_sims < tau_hit) & (thresh < tau_hit)
    cids = np.asarray(r_cids, dtype=np.int64).copy()
    sims = r_sims.copy()
    n_fallback = int(b - np.count_nonzero(safe))
    if n_fallback:
        sel = np.flatnonzero(~safe)
        f_cids, f_sims = exact_fn(sel)
        cids[sel] = np.asarray(f_cids, dtype=np.int64)
        sims[sel] = np.asarray(f_sims, dtype=np.float64)
    sims = np.where(cids >= 0, sims, -np.inf)
    return cids, sims, n_fallback, int(uniq.size)
