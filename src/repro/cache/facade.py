"""`SemanticCache` — the single owner of lookup, admission, and eviction.

Every consumer in the repo (trace simulator, serving engine, examples,
benchmarks) drives the cache through this facade instead of wiring
``ResidentStore`` + ``Policy`` by hand.  The protocol is the paper's
Alg. 1 exactly:

  - ``lookup`` determines a hit under identical semantics for every policy
    (Top-1 cosine >= tau_hit in semantic mode; content-id residency in
    content mode) and notifies the policy of hits.  Lookups never admit.
  - ``admit`` is always-admit (Alg. 1 line 4): insert, then evict while
    over capacity.  Policies express admission control by electing the
    fresh entry as the victim (e.g. TinyLFU).
  - payloads (cached responses) live here too: eviction drops the payload
    and fires the ``"evict"`` event — no consumer hand-rolls payload
    bookkeeping anymore.

Batching: ``lookup_batch``/``admit_batch`` drain whole queues in one
backend call (one ``sim_top1`` kernel launch under the kernel backend).
A batched lookup scores every query against the store *snapshot* at call
time; hits are revalidated against residency when results are applied, so
interleaved evictions can never produce a stale hit.

Event-driven admission: with ``cfg.async_admit`` set, ``admit`` enqueues
onto an :class:`~repro.cache.async_admit.AsyncAdmitter` and returns
immediately — a background worker (or a deterministic ``flush()`` drain)
applies insert + eviction scoring off the request path, firing the same
hooks and metrics as the synchronous path.  All mutable state is guarded
by one reentrant lock so concurrent lookups never observe a half-applied
admission.

Telemetry: ``cfg.tracker`` attaches a :class:`repro.telemetry.Tracker`
the facade emits through — lookup/admit latency histograms, windowed
hit-ratio and occupancy series, tier-tagged eviction counters, and spans
around ``decide_batch`` and the host-tier fall-through.  The device
backends and the tier manager get scoped children of the same tracker
(``backend.*`` / ``tier.*`` names).  Emission is strictly observation-
only (decisions are bit-identical with any tracker — see
``tests/test_telemetry.py``), ``metrics_snapshot()`` consolidates every
counter surface into one dict, and event-subscriber failures are
contained (counted as ``hook_errors``; ``cfg.debug_hooks`` re-raises).
"""
from __future__ import annotations

import contextlib
import copy
import dataclasses
import threading
import time
from typing import Any, Callable, Optional, Sequence

import numpy as np

from repro.core.store import ResidentStore
from repro.core.types import Request
from repro.telemetry.tracker import make_tracker

from .backends import LookupBackend, get_backend
from .types import (CacheConfig, CacheEvent, CacheHit, CacheMetrics,
                    CacheMiss, CacheResult, DecisionBatch)

PolicyFactory = Callable[[int, ResidentStore], Any]

_NULL_CM = contextlib.nullcontext()      # reusable no-op span

_MUTABLE_STATE = ("store", "policy", "payloads", "clock", "metrics",
                  "tiers")

# policy hook attribute -> backend method wired into it (device-side
# eviction scoring: RAC consumes Eq. 1 values, RadixRAC the masked variant)
_VALUE_HOOKS = (("value_backend", "rac_value"),
                ("masked_value_backend", "rac_value_masked"))


def _make_policy(cfg: CacheConfig, store: ResidentStore):
    if cfg.policy == "RAC":
        from repro.core.rac import RACPolicy
        return RACPolicy(cfg.capacity, store, **cfg.policy_kwargs)
    if cfg.policy == "RadixRAC":
        from repro.core.radix import RadixRACPolicy
        return RadixRACPolicy(cfg.capacity, store, **cfg.policy_kwargs)
    from repro.core.policies import BASELINES
    return BASELINES[cfg.policy](cfg.capacity, store, **cfg.policy_kwargs)


class SemanticCache:
    """Batched, backend-pluggable semantic cache (see module docstring).

    ``policy_factory`` overrides ``cfg.policy`` with the simulator's
    ``(capacity, store) -> Policy`` calling convention, so sweep drivers
    can inject pre-built factories unchanged.
    """

    def __init__(self, cfg: CacheConfig,
                 policy_factory: Optional[PolicyFactory] = None,
                 backend: Optional[LookupBackend] = None):
        self.cfg = cfg
        if backend is not None:
            if cfg.backend_kwargs:
                raise ValueError(
                    "backend_kwargs "
                    f"{sorted(cfg.backend_kwargs)} cannot apply to an "
                    "already-built backend instance")
            if cfg.quantized_lookup:
                raise ValueError(
                    "quantized_lookup cannot apply to an already-built "
                    "backend instance — pass quantized= to its "
                    "constructor instead")
            if cfg.pruned_lookup:
                raise ValueError(
                    "pruned_lookup cannot apply to an already-built "
                    "backend instance — pass pruned= to its "
                    "constructor instead")
            self.backend = backend
        else:
            kw = dict(cfg.backend_kwargs)
            if cfg.backend in ("kernel", "sharded"):
                kw.setdefault("use_pallas", cfg.use_pallas)
            if cfg.quantized_lookup:
                # int8 candidate-scan path: fill the safety predicate's
                # tau from the facade's own hit threshold so the
                # certain-miss arm is live in semantic mode (content mode
                # never gates on sims, so only the margin arm applies)
                from .quantized import as_quantized_config
                qcfg = as_quantized_config(cfg.quantized_lookup)
                if qcfg.tau_hit is None and cfg.hit_mode == "semantic":
                    qcfg = dataclasses.replace(qcfg, tau_hit=cfg.tau_hit)
                kw.setdefault("quantized", qcfg)
            if cfg.pruned_lookup:
                # topic-pruned candidate scan: same tau-fill rule — the
                # certain-miss arm of its safety predicate needs the hit
                # threshold to certify sub-tau outcomes without a fallback
                from .pruned import as_pruned_config
                pcfg = as_pruned_config(cfg.pruned_lookup)
                if pcfg.tau_hit is None and cfg.hit_mode == "semantic":
                    pcfg = dataclasses.replace(pcfg, tau_hit=cfg.tau_hit)
                kw.setdefault("pruned", pcfg)
            self.backend = get_backend(cfg.backend, **kw)
        self._quant_fb_seen = 0            # rescore_fallbacks delta base
        self._prune_fb_seen = 0            # prune_fallbacks delta base
        # backends that own their store geometry (e.g. the sharded slab)
        # build it; everyone else gets the plain dense slab
        self.store = (self.backend.make_store(cfg.capacity, cfg.dim)
                      if hasattr(self.backend, "make_store")
                      else ResidentStore(cfg.capacity, cfg.dim))
        self.policy = (policy_factory(cfg.capacity, self.store)
                       if policy_factory is not None
                       else _make_policy(cfg, self.store))
        self.payloads: dict[int, Any] = {}
        self.metrics = CacheMetrics()
        self.clock = 0                     # internal logical time
        self._hooks: dict[str, list[Callable[[CacheEvent], None]]] = {}
        self._lock = threading.RLock()     # guards all mutable state
        self._wire_value_backend()
        # telemetry: strictly observation-only — None skips emission
        # entirely, and decisions are bit-identical with any tracker
        self._trk = make_tracker(cfg.tracker)
        if self._trk is not None and hasattr(self.backend, "set_tracker"):
            self.backend.set_tracker(self._trk.child("backend"))
        # tiered hierarchy (host DRAM tier + ghost metadata) behind the
        # facade; None = single-tier, bit-identical to the pre-tiering path
        self.tiers = None
        if cfg.tiers is not None and (cfg.tiers.host_capacity > 0
                                      or cfg.tiers.ghost_capacity > 0):
            from .tiers import TierManager
            self.tiers = TierManager(
                cfg.tiers, cfg.dim,
                tracker=None if self._trk is None
                else self._trk.child("tier"))
        # event-driven admission: enqueue + background/deterministic drain
        self.admitter = None
        if cfg.async_admit:
            from .async_admit import AsyncAdmitter
            self.admitter = AsyncAdmitter(
                self, background=cfg.async_admit != "sync",
                tracker=self._trk)

    def _wire_value_backend(self):
        for attr, method in _VALUE_HOOKS:
            if hasattr(self.policy, attr):
                setattr(self.policy, attr, getattr(self.backend, method))
        if getattr(self.backend, "pruned", None) is not None:
            # topic routing reads the policy's journaled PolicyTable (rep
            # matrix + topic memberships) against this facade's store;
            # restore() re-runs this, so store swaps stay wired.  A
            # table-less policy leaves route_table None and the backend
            # falls back to the exact scan (still decision-identical).
            self.backend.route_table = getattr(self.policy, "table", None)
            self.backend.route_store = self.store

    # ----------------------------------------------------------- events
    def subscribe(self, kind: str, fn: Callable[[CacheEvent], None]):
        """Register ``fn`` for ``"hit" | "miss" | "admit" | "evict"``."""
        self._hooks.setdefault(kind, []).append(fn)
        return fn

    def _emit(self, kind: str, cid: int, t: int, sim: float = float("nan"),
              payload: Any = None, tier: str = "device"):
        hooks = self._hooks.get(kind)
        if not hooks:
            return
        ev = CacheEvent(kind=kind, cid=cid, t=t, sim=sim,
                        payload=payload, tier=tier)
        for fn in hooks:
            try:
                fn(ev)
            except Exception:
                # a subscriber must never corrupt the cache operation it
                # observes: count the failure and keep going (the
                # development mode re-raises at the call site)
                self.metrics.hook_errors += 1
                if self._trk is not None:
                    self._trk.count("cache.hook_errors",
                                    tags={"kind": kind})
                if self.cfg.debug_hooks:
                    raise

    # ------------------------------------------------------------ basics
    def __len__(self) -> int:
        return len(self.store)

    def __contains__(self, cid: int) -> bool:
        return cid in self.store

    def in_host(self, cid: int) -> bool:
        """Whether ``cid`` currently lives in the host DRAM tier."""
        return (self.tiers is not None and self.tiers.host is not None
                and cid in self.tiers.host)

    @property
    def tier_stats(self) -> dict:
        """Per-tier counters (empty when running single-tier)."""
        return {} if self.tiers is None else self.tiers.stats.snapshot()

    @property
    def tracker(self):
        """The attached :class:`repro.telemetry.Tracker` (or None)."""
        return self._trk

    def metrics_snapshot(self) -> dict:
        """The consolidated observability surface: ONE dict merging the
        :class:`CacheMetrics` counters, the per-tier flow counters
        (``tiers``, when tiered), the device backend's mirror-sync stats
        (``sync``, when the backend keeps device mirrors), and the
        admission-queue state (``pending_admits`` + the producer-visible
        ``admit_stall_s``, split into ``enqueue_s``/``flush_s`` under
        async admission), plus the always-present reduced-traffic scan
        ledgers (``quant``/``prune``) and the launch/transfer ledger
        (``dispatch``).  Consumers (the serving engine's ``stats``,
        benchmarks, reports) read this instead of hand-merging the
        historical surfaces."""
        with self._lock:
            snap = self.metrics.snapshot()
            snap["pending_admits"] = self.pending_admits
            snap["admit_stall_s"] = self.admit_stall_s
            if self.admitter is not None:
                snap["enqueue_s"] = self.admitter.enqueue_s
                snap["flush_s"] = self.admitter.flush_s
            tiers = self.tier_stats
            if tiers:
                snap["tiers"] = tiers
            sync = getattr(self.backend, "sync_stats", None)
            if sync:
                snap["sync"] = dict(sync)
            # the reduced-traffic-scan ledgers are ALWAYS present (zeroed
            # when the path is off) so dashboards never guard a KeyError
            quant = getattr(self.backend, "quant_stats", None)
            if quant is None:
                from .quantized import new_quant_stats
                quant = new_quant_stats()
            snap["quant"] = dict(quant)
            prune = getattr(self.backend, "prune_stats", None)
            if prune is None:
                from .pruned import new_prune_stats
                prune = new_prune_stats()
            snap["prune"] = dict(prune)
            # launch/transfer ledger: always present so dashboards can
            # chart launches-per-chunk without guarding; host backends
            # report zeros (they never dispatch)
            dispatch = getattr(self.backend, "dispatch_stats", None)
            if dispatch is None:
                dispatch = {"launches": 0, "host_syncs": 0, "kernel_s": 0.0}
            snap["dispatch"] = dict(dispatch)
            return snap

    def _flush_quant(self):
        """Emit the since-last-flush delta of quantized-path exact-scan
        fallbacks as the ``cache.rescore_fallbacks`` counter (strictly
        observation-only; call sites hold the lock)."""
        trk = self._trk
        if trk is None or getattr(self.backend, "quantized", None) is None:
            return
        fb = self.backend.quant_stats["fallbacks"]
        d = fb - self._quant_fb_seen
        if d:
            trk.count("cache.rescore_fallbacks", d)
            self._quant_fb_seen = fb

    def _flush_prune(self):
        """Emit the since-last-flush delta of pruned-path exact-scan
        fallbacks as the ``cache.prune_fallbacks`` counter (strictly
        observation-only; call sites hold the lock)."""
        trk = self._trk
        if trk is None or getattr(self.backend, "pruned", None) is None:
            return
        fb = self.backend.prune_stats["fallbacks"]
        d = fb - self._prune_fb_seen
        if d:
            trk.count("cache.prune_fallbacks", d)
            self._prune_fb_seen = fb

    def _tick(self, t: Optional[int]) -> int:
        if t is None:
            self.clock += 1
            return self.clock
        self.clock = max(self.clock, t)
        return t

    def _request(self, cid: int, emb: np.ndarray, t: int,
                 req: Optional[Request]) -> Request:
        return req if req is not None else Request(t=t, cid=cid, emb=emb)

    # ------------------------------------------------------------ lookup
    def lookup(self, emb: np.ndarray, *, cid: int = -1,
               t: Optional[int] = None, req: Optional[Request] = None,
               top1: Optional[tuple[int, float]] = None) -> CacheResult:
        """Hit determination for one query.  Never admits.

        ``cid`` is the query's content id (required for content mode and
        for consumers that track per-content payloads).  ``top1`` is an
        optional precomputed ``(cid, sim)`` from a snapshot ``peek_batch``;
        it is revalidated against residency and recomputed on staleness.
        """
        t0 = time.perf_counter()
        with self._lock:
            t = self._tick(t)
            if self.cfg.hit_mode == "content":
                best_cid, best_sim = cid, float("nan")
                hit_cid = cid if cid in self.store else -1
            else:
                if top1 is not None and (top1[0] < 0 or top1[0] in self.store):
                    best_cid, best_sim = top1
                else:
                    best_cid, best_sim = self.backend.top1(self.store, emb)
                hit_cid = best_cid if best_sim >= self.cfg.tau_hit else -1
            self.metrics.lookups += 1
            if hit_cid >= 0:
                self.metrics.hits += 1
                self.policy.on_hit(hit_cid,
                                   self._request(hit_cid, emb, t, req), t)
                self._emit("hit", hit_cid, t, best_sim,
                           self.payloads.get(hit_cid))
                result: CacheResult = CacheHit(
                    cid=hit_cid, sim=best_sim,
                    payload=self.payloads.get(hit_cid), t=t)
            else:
                # tier fall-through: a device miss may still be served from
                # the host DRAM tier (and promoted back toward the device)
                result = (self._tier_lookup(emb, cid, t)
                          if self.tiers is not None else None)
                if result is None:
                    self.metrics.misses += 1
                    self._emit("miss", cid, t, best_sim)
                    result = CacheMiss(
                        best_cid=best_cid if np.isfinite(best_sim)
                        else -1, best_sim=best_sim, t=t)
            dt = time.perf_counter() - t0
            self.metrics.lookup_s += dt
            trk = self._trk
            if trk is not None:
                trk.observe("cache.lookup_s", dt)
                # windowed hit indicator over logical time -> the
                # hit-ratio-over-time series every workload study wants
                trk.observe("cache.hit", 1.0 if result.hit else 0.0, t)
                self._flush_quant()
                self._flush_prune()
        return result

    def _tier_lookup(self, emb: np.ndarray, cid: int,
                     t: int) -> Optional[CacheHit]:
        """Host-tier fall-through on a device miss (under the lock).

        Serves the payload straight from host DRAM and promotes the served
        entry (plus any ``promote_k`` co-promotion candidates that also
        cleared ``tau_hit``) back through the normal admission path — the
        :class:`~repro.cache.async_admit.AsyncAdmitter` queue when
        configured, so the request path never blocks on device eviction
        scoring.  Ghost metadata rides along via ``revive_ghost`` so the
        policy's arrival path restores the preserved relation evidence."""
        with (self._trk.span("cache.tier_serve")
              if self._trk is not None else _NULL_CM):
            served = self.tiers.serve(np.asarray(emb, dtype=np.float32),
                                      cid=cid, hit_mode=self.cfg.hit_mode,
                                      tau_hit=self.cfg.tau_hit, t=t)
        if not served:
            return None
        revive = getattr(self.policy, "revive_ghost", None)
        for pcid, _psim, pemb, ppayload, pmeta in served:
            if pmeta is not None and revive is not None:
                revive(pcid, pmeta, rep=pemb)
            self.admit(pcid, pemb, payload=ppayload, t=t)
        hcid, sim, _hemb, payload, _meta = served[0]
        self.metrics.hits += 1
        self._emit("hit", hcid, t, sim, payload, tier="host")
        return CacheHit(cid=hcid, sim=sim, payload=payload, t=t)

    def peek_batch(self, embs: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Raw snapshot Top-1 over a (B, D) query block — one backend call,
        no policy/metrics side effects.  Sims are against the store as of
        this call; pair with ``lookup(..., top1=...)`` to apply results."""
        with self._lock:
            out = self.backend.top1_batch(self.store, np.asarray(embs))
            self._flush_quant()
            self._flush_prune()
            return out

    def decide_batch(self, embs: np.ndarray, *,
                     t: Optional[int] = None) -> "DecisionBatch":
        """Fused snapshot decision scoring over a (B, D) query block — ONE
        backend launch computes the Top-1 hit candidates, the Alg. 4
        topic-routing candidates, and the masked Eq. 1 victim values over
        the policy's :class:`~repro.core.policy_table.PolicyTable` (device
        backends mirror the table by dirty-row scatter, so steady-state
        chunks move O(mutations), not O(capacity)).  Like ``peek_batch``
        this has no policy/metrics side effects; the hit columns are
        exactly ``peek_batch``'s answer, so consumers that only need hit
        determination (the serving engine's queue scan) use them
        interchangeably.  With a table-less policy (baselines) the routing
        and victim columns degrade to sentinels."""
        embs = np.asarray(embs, dtype=np.float32)
        with (self._trk.span("cache.decide_batch",
                             tags={"b": int(embs.shape[0])})
              if self._trk is not None else _NULL_CM), self._lock:
            t_now = self.clock if t is None else t
            table = getattr(self.policy, "table", None)
            alpha = float(getattr(self.policy, "alpha", 0.0))
            dec = self.backend.decide_batch(self.store, table, embs,
                                            alpha=alpha, t_now=t_now)
            if self.tiers is not None and self.tiers.host is not None:
                # tier-aware fall-through columns: the host tier's Top-1
                # per query (host-side scoring; the host slab is DRAM-
                # resident by definition)
                dec.host_cid, dec.host_sim = \
                    self.tiers.host.top1_batch(embs)
            self._flush_quant()
            self._flush_prune()
            return dec

    def peek_rows(self, embs: np.ndarray, cids: Sequence[int]
                  ) -> tuple[np.ndarray, np.ndarray]:
        """Snapshot Top-1 restricted to the given resident ``cids``.

        The incremental-rescan primitive: after a full ``peek_batch``, a
        waiting queue only needs rescoring against entries admitted since
        — and it must use the backend's own cosine scoring so the peeked
        sims can never disagree with ``lookup`` near ``tau_hit``.
        Non-resident cids are skipped; with none resident every query
        reports ``(-1, -inf)``."""
        embs = np.asarray(embs, dtype=np.float32)
        with self._lock:
            rows = [self.store.slot_of[c] for c in dict.fromkeys(cids)
                    if c in self.store]
            if not rows:
                b = embs.shape[0]
                return (np.full(b, -1, dtype=np.int64),
                        np.full(b, -np.inf, dtype=np.float64))
            return self.backend.top1_rows(self.store, embs,
                                          np.asarray(rows, dtype=np.int64))

    def lookup_batch(self, embs: Sequence[np.ndarray] | np.ndarray, *,
                     cids: Optional[Sequence[int]] = None,
                     ts: Optional[Sequence[int]] = None,
                     reqs: Optional[Sequence[Request]] = None
                     ) -> list[CacheResult]:
        """Hit determination for a whole query block in ONE backend call.

        Snapshot semantics: similarities are computed against the store at
        call time (lookups never admit, so residency can only change via
        subscriber-driven mutation — hits are revalidated regardless).
        """
        embs = np.asarray(embs, dtype=np.float32)
        b = embs.shape[0]
        cids = list(cids) if cids is not None else [-1] * b
        if self.cfg.hit_mode == "content":
            return [self.lookup(embs[i], cid=cids[i],
                                t=None if ts is None else ts[i],
                                req=None if reqs is None else reqs[i])
                    for i in range(b)]
        t0 = time.perf_counter()
        top_cids, top_sims = self.peek_batch(embs)
        self.metrics.lookup_s += time.perf_counter() - t0
        return [self.lookup(embs[i], cid=cids[i],
                            t=None if ts is None else ts[i],
                            req=None if reqs is None else reqs[i],
                            top1=(int(top_cids[i]), float(top_sims[i])))
                for i in range(b)]

    # ------------------------------------------------------------- admit
    def admit(self, cid: int, emb: np.ndarray, payload: Any = None, *,
              t: Optional[int] = None,
              req: Optional[Request] = None) -> list[int]:
        """Admit ``cid`` (insert-then-evict, Alg. 1).  Returns evicted cids.

        Already-resident cids only refresh their payload (the historical
        semantic-mode behavior: a miss whose content is resident — a
        paraphrase below tau_hit — does not reinsert).

        With ``cfg.async_admit`` the admission is queued (logical time is
        assigned now, so ordering is deterministic) and the returned list
        is empty — evictions surface through the ``"evict"`` hook and
        :meth:`flush`."""
        trk = self._trk
        t0 = time.perf_counter() if trk is not None else 0.0
        if self.admitter is not None:
            # tick + enqueue under one lock: concurrent producers must not
            # queue out of timestamp order, or the FIFO drain would apply
            # decreasing times and diverge from the synchronous path
            with self._lock:
                t = self._tick(t)
                self.admitter.submit(cid, emb, payload, t, req)
            if trk is not None:
                trk.observe("cache.admit_stall_s",
                            time.perf_counter() - t0)
            return []
        out = self._admit_now(cid, emb, payload, t, req)
        if trk is not None:
            # producer-visible stall: in synchronous mode the full
            # insert+evict cost, in async mode just the enqueue above
            trk.observe("cache.admit_stall_s", time.perf_counter() - t0)
        return out

    def _admit_now(self, cid: int, emb: np.ndarray, payload: Any,
                   t: Optional[int], req: Optional[Request]) -> list[int]:
        """The synchronous insert-then-evict body (also the admitter's
        drain target)."""
        t0 = time.perf_counter()
        evicted: list[int] = []
        with self._lock:
            t = self._tick(t)
            if self.cfg.capacity <= 0:
                # nothing can ever be inserted: storing the payload would
                # leak it forever (eviction is the only payload-drop path)
                self.metrics.admit_s += time.perf_counter() - t0
                return evicted
            if payload is not None:
                self.payloads[cid] = payload
            if cid in self.store:
                self.metrics.admit_s += time.perf_counter() - t0
                return evicted
            self.store.insert(cid, emb)
            if self.tiers is not None:
                # drop any stale host copy + feed ghost metadata back into
                # the policy BEFORE on_admit, so the normal arrival path
                # restores the preserved counters
                self.tiers.on_admit(cid, self.policy, emb)
            self.policy.on_admit(cid, self._request(cid, emb, t, req), t)
            self.metrics.admissions += 1
            self._emit("admit", cid, t, payload=payload)
            trk = self._trk
            while len(self.store) > self.cfg.capacity:
                victim = self.policy.victim(t)
                vemb = (self.store.emb[self.store.slot_of[victim]].copy()
                        if self.tiers is not None else None)
                self.store.remove(victim)
                vp = self.payloads.pop(victim, None)
                self.metrics.evictions += 1
                evicted.append(victim)
                etier = "device"
                if self.tiers is not None:
                    # demote instead of dropping: the host tier keeps the
                    # payload (and the ghost tier the relation metadata)
                    meta_fn = getattr(self.policy, "ghost_meta", None)
                    meta = meta_fn(victim) if meta_fn is not None else None
                    if self.tiers.demote(victim, vemb, vp, t, meta):
                        etier = "host"
                self._emit("evict", victim, t, payload=vp, tier=etier)
                if trk is not None:
                    trk.count("cache.evictions", tags={"tier": etier})
            dt = time.perf_counter() - t0
            self.metrics.admit_s += dt
            if trk is not None:
                trk.observe("cache.admit_s", dt)
                trk.observe("cache.occupancy", float(len(self.store)), t)
        return evicted

    # ------------------------------------------------- async admission
    @property
    def pending_admits(self) -> int:
        """Queued-but-unapplied admissions (0 in synchronous mode)."""
        return 0 if self.admitter is None else len(self.admitter)

    @property
    def admit_stall_s(self) -> float:
        """Producer-visible admission stall: in synchronous mode the full
        insert+evict cost; in async mode just enqueue + flush waits."""
        if self.admitter is None:
            return self.metrics.admit_s
        return self.admitter.stall_s

    def flush(self) -> list[int]:
        """Apply all queued admissions (no-op when synchronous); returns
        the cids evicted by the drain since the last flush."""
        if self.admitter is None:
            return []
        return self.admitter.flush()

    drain = flush

    def close(self):
        """Stop the background admission worker (flushes first) and
        revert to inline admission — the cache stays fully usable, later
        ``admit`` calls just pay the insert+evict cost synchronously."""
        if self.admitter is not None:
            self.admitter.close()
            self.admitter = None

    def admit_batch(self, cids: Sequence[int],
                    embs: Sequence[np.ndarray] | np.ndarray,
                    payloads: Optional[Sequence[Any]] = None, *,
                    ts: Optional[Sequence[int]] = None,
                    reqs: Optional[Sequence[Request]] = None) -> list[int]:
        """Admit a block of entries; returns all evicted cids in order.

        With ``cfg.async_admit`` the block is queued and the returned list
        is empty — collect victims from :meth:`flush` or the ``"evict"``
        hook instead."""
        evicted: list[int] = []
        for i, cid in enumerate(cids):
            evicted += self.admit(
                int(cid), np.asarray(embs[i]),
                None if payloads is None else payloads[i],
                t=None if ts is None else ts[i],
                req=None if reqs is None else reqs[i])
        return evicted

    # ------------------------------------------------- checkpoint/restore
    def checkpoint(self) -> dict:
        """Deep snapshot of all mutable state (store, policy, payloads,
        clock, metrics).  Queued async admissions are flushed first so the
        snapshot is a settled state.  Store/policy are copied together so
        the policy's internal store reference stays shared inside the
        snapshot."""
        self.flush()
        with self._lock:
            state = copy.deepcopy({k: getattr(self, k)
                                   for k in _MUTABLE_STATE})
        state["_version"] = 1
        return state

    def restore(self, state: dict):
        """Restore a :meth:`checkpoint` snapshot (the snapshot itself is
        copied, so one checkpoint can be restored multiple times).  Queued
        async admissions are applied to the *old* state first, then
        discarded with it."""
        self.flush()
        keys = [k for k in _MUTABLE_STATE if k in state]   # tolerate older
        restored = copy.deepcopy({k: state[k] for k in keys})  # snapshots
        with self._lock:
            for k in keys:
                setattr(self, k, restored[k])
            self._wire_value_backend()
