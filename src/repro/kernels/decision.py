"""Pallas TPU kernel: occupancy-masked RAC victim scoring with runtime time.

The fused decision path (``ops.fused_decide``) scores one replay chunk in a
single device dispatch: Top-1 similarity over the resident slab (hit
determination), Top-1 over the topic-representative table (Alg. 4
routing), and Eq. 1 victim values over the whole slot table.  The two
Top-1 passes reuse ``similarity_topk``'s kernel; this module supplies the
third leg.

``victim_value_pallas`` extends the ``rac_value`` kernel two ways that the
fused path needs:

  - ``t_now`` is a *runtime* scalar delivered through scalar prefetch
    (``PrefetchScalarGridSpec``), so simulation time advancing between
    chunks never recompiles — the per-eviction ``rac_value`` kernel instead
    bakes ``t_now=0`` and shifts timestamps on the host, which would force
    a re-upload of the whole ``t_last`` table per chunk here.
  - the occupancy mask is applied *in kernel*: free slots score ``+inf``
    directly, so the min-value victim scan can run on the fixed-shape slot
    table without a host-side where().

Tiling matches ``rac_value``: entries stream in tiles of BN with the
per-topic tables VMEM-resident and gathered per tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BN = 1024     # entries per tile


def _victim_value_kernel(tn_ref, tsi_ref, tid_ref, occ_ref, tp_ref, tl_ref,
                         out_ref, *, alpha: float):
    t_now = tn_ref[0]
    tid = jnp.maximum(tid_ref[...], 0)         # free slots carry tid -1
    tp_last = jnp.take(tp_ref[...], tid, axis=0)
    t_last = jnp.take(tl_ref[...], tid, axis=0)
    # subtract in int32 first: only the (small) age is cast, so absolute
    # timestamps past float32's 2^24 integer range never lose precision
    decay = jnp.exp2(-alpha * (t_now - t_last).astype(jnp.float32))
    val = decay * tp_last * tsi_ref[...]
    out_ref[...] = jnp.where(occ_ref[...] > 0, val, jnp.inf)


def victim_value_pallas(tsi: jnp.ndarray, tid: jnp.ndarray,
                        occ: jnp.ndarray, tp_last: jnp.ndarray,
                        t_last: jnp.ndarray, t_now, alpha: float, *,
                        interpret: bool = True):
    """tsi (N,) f32; tid (N,) i32; occ (N,) i32 (0 = free → +inf);
    tp_last/t_last (T,) topic tables; ``t_now`` a runtime int32 scalar.
    N must be a BN multiple (pad tsi/tid with 0 and occ with 0)."""
    n = tsi.shape[0]
    t = tp_last.shape[0]
    assert n % BN == 0
    kernel = functools.partial(_victim_value_kernel, alpha=alpha)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n // BN,),
        in_specs=[pl.BlockSpec((BN,), lambda i, tn: (i,)),
                  pl.BlockSpec((BN,), lambda i, tn: (i,)),
                  pl.BlockSpec((BN,), lambda i, tn: (i,)),
                  pl.BlockSpec((t,), lambda i, tn: (0,)),
                  pl.BlockSpec((t,), lambda i, tn: (0,))],
        out_specs=pl.BlockSpec((BN,), lambda i, tn: (i,)))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(t_now, jnp.int32).reshape(1), tsi, tid, occ,
      tp_last.astype(jnp.float32), t_last.astype(jnp.int32))


def victim_value_multi_pallas(tsi: jnp.ndarray, tid: jnp.ndarray,
                              occ: jnp.ndarray, tp_last: jnp.ndarray,
                              t_last: jnp.ndarray, t_now, alpha: float, *,
                              interpret: bool = True):
    """Policy-stacked victim scoring: one dispatch scores P slot tables.

    All slot-axis inputs carry a leading policy axis — tsi/tid/occ
    ``(P, N)``, the topic tables ``(P, T)`` — and the policy axis is
    walked grid-sequentially (``lax.map``) inside the single dispatch, so
    each slice runs the ``victim_value`` kernel unchanged and the arena
    pays one host→device round-trip for all P policies.  ``t_now`` and
    ``alpha`` are shared across policies (one simulated clock)."""

    def one(args):
        tsi_p, tid_p, occ_p, tp_p, tl_p = args
        return victim_value_pallas(tsi_p, tid_p, occ_p, tp_p, tl_p,
                                   t_now, alpha, interpret=interpret)

    return jax.lax.map(one, (tsi, tid, occ, tp_last, t_last))
