"""Pallas TPU kernel: causal flash attention (prefill), GQA-aware.

Online-softmax tiling: grid = (batch, heads, q_blocks); the q tile
(BQ × D) stays VMEM-resident while K/V stream in BK-sized chunks.  The
causal structure bounds the inner loop at ⌈(q_hi)/BK⌉ chunks, skipping the
upper triangle entirely (≈2× prefill win).  GQA is expressed in the
BlockSpec index map: kv block index = h // group — no K/V repeat in HBM.

VMEM per cell (BQ=128, BK=512, D=128, bf16): q 32 KB + k/v 2×128 KB +
fp32 acc 64 KB ≈ 0.36 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128
BK = 512
NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bk: int, scale: float):
    i = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale              # (BQ, D)
    bq, d = q.shape
    q_lo = i * bq
    n_chunks = (q_lo + bq + bk - 1) // bk                    # causal bound

    def body(c, carry):
        acc, m_i, l_i = carry
        k = k_ref[0, 0, pl.dslice(c * bk, bk), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(c * bk, bk), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        row = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        col = c * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(col <= row, s, NEG)
        m_new = jnp.maximum(m_i, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + p.sum(axis=1)
        acc = acc * alpha[:, None] + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), NEG, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, n_chunks, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, interpret: bool = True) -> jnp.ndarray:
    """q (B, H, S, D); k/v (B, Hkv, S, D); S % BQ == 0; causal."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    assert s % BQ == 0 and d % 128 == 0
    bk = next(x for x in (BK, 256, BQ) if s % x == 0)   # bk must divide s
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_flash_kernel, bk=bk, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, h, s // BQ),
        in_specs=[
            pl.BlockSpec((1, 1, BQ, d), lambda bb, hh, ii: (bb, hh, ii, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bb, hh, ii: (bb, hh // g, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bb, hh, ii: (bb, hh // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, BQ, d), lambda bb, hh, ii: (bb, hh, ii, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
