"""Device-resident fused lookup pipeline.

One jitted program (per static shape bucket) runs the whole approximate
lookup: topic routing → CSR candidate gather → int8 candidate scan →
fp32 union rescore → the ``resolve_pruned``/``resolve_topk`` safety
predicates — entirely on device.  The host gets back one compact result
tuple (winner slot, rescored sim, certification mask, ledger counts) and
only exact-rescans the uncertified rows, instead of interleaving 4–6
dispatches with blocking ``np.asarray`` syncs per chunk the way the
staged drivers in :mod:`repro.cache.pruned`/:mod:`repro.cache.quantized`
do.

Decision parity
---------------
The predicates move to the device but their arms do not change, and the
certified outputs are bit-equal to the exact scan by construction:

* Candidate *selection* is approximate (int8 scores — exact integer
  arithmetic via ``preferred_element_type=int32``, identical across
  batching shapes), but every *reported* similarity comes from the same
  per-pair fp32 kernel math as the exact path: the union of all
  shortlists is sorted by slot id and rescored with ``sim_top1_raw``, so
  a certified winner carries exactly the fp32 bits the full-slab scan
  would have produced, with the same lowest-slot tie rule (the union is
  slot-sorted, and the kernel breaks ties toward the lower index).
* The exclusion threshold ``kth + eps`` and the routing bound are
  evaluated in fp32 on device with an absolute + relative inflation
  (``x + |x|·1e-6 + 1e-6`` after the already-padded ``eps``), so fp32
  rounding can only *add* fallbacks, never certify something the f64
  host predicate would not have.
* ``tau`` comparisons use ``tau_lo`` — the largest float32 strictly
  below ``tau`` — so the device predicate ``v <= tau_lo`` is *exactly*
  the host predicate ``float64(v) < tau`` for any float32 ``v``.

Bucket padding policy
---------------------
Batch is padded to the next power of two (floor 1 — every padded row
pays a full ``cap_c``-row gather, and the serving path is ``b=1``); the
candidate width to a
geometric grid (powers of two plus the 1.5× midpoints, floor 64) sized
from the top-``P`` bucket counts and the probe budget, so a steady-state
chunk loop compiles once per bucket and re-uses that executable for the
rest of the run.  Scratch (query) buffers are donated on accelerators;
on CPU donation is skipped (XLA CPU ignores it and warns).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .ops import (_is_cpu, count_launch, route_topics_raw, sim_top1_raw,
                  sim_topk_q8_raw)
from .quant import quantize_rows_int8

#: Shortlist width when the pruned path runs without a composed
#: quantized config (the fused scan is always int8 — see docs).
DEFAULT_K = 8

#: Driver-side ledger: calls into the fused pipeline, rows that fell back
#: to the exact scan, rows whose probe set was budget-capped.
fused_stats = {"calls": 0, "fallback_rows": 0, "capped_rows": 0}


def reset_stats() -> None:
    for k in fused_stats:
        fused_stats[k] = 0


def compile_counts() -> dict:
    """Number of distinct executables per fused entry point — the
    compile-count monitor the stability test asserts on."""
    return {"pruned": int(_fused_pruned_jit._cache_size()),
            "quant": int(_fused_quant_jit._cache_size())}


# ---------------------------------------------------------------------------
# static-bucket helpers (host side)

def pad_pow2(n: int, min_b: int = 8) -> int:
    """Smallest power of two ≥ ``n`` (floor ``min_b``)."""
    b = min_b
    while b < n:
        b *= 2
    return b


def pad_geo(n: int, min_b: int = 64) -> int:
    """Smallest bucket ≥ ``n`` from the geometric grid {64, 96, 128, 192,
    256, ...} — powers of two plus their 1.5× midpoints.  Roughly halves
    the worst-case overshoot of pure pow2 buckets for the candidate dim,
    which directly multiplies gather bytes."""
    b = min_b
    while True:
        if b >= n:
            return b
        mid = b + b // 2
        if mid >= n:
            return mid
        b *= 2


@functools.lru_cache(maxsize=64)
def tau_lo_f32(tau: float) -> np.float32:
    """Largest float32 strictly below ``tau`` (a float64 threshold).

    For float32 ``v``, ``v <= tau_lo_f32(tau)`` holds iff
    ``float64(v) < tau`` — the device-side form of the staged drivers'
    f64 certain-miss comparisons."""
    t = np.float32(tau)
    while float(t) >= float(tau):
        t = np.nextafter(t, np.float32(-np.inf))
    return t


def prep_queries(queries: np.ndarray, bq: int):
    """Pad a query chunk to the ``bq`` batch bucket and quantize it.

    Returns ``(qp, q8, qscale, ql1)`` — fp32 queries, their int8 mirror,
    per-row scales, and the f32-inflated L1 norms the device-side error
    bound consumes (cast rounding is swallowed by the 1e-6 relative pad,
    keeping the bound an upper bound)."""
    q = np.ascontiguousarray(queries, dtype=np.float32)
    b = q.shape[0]
    if bq > b:
        q = np.pad(q, ((0, bq - b), (0, 0)))
    q8, qs, ql1 = quantize_rows_int8(q)
    ql1_32 = (ql1 * (1.0 + 1e-6)).astype(np.float32)
    return q, q8, qs.astype(np.float32), ql1_32


def csr_device_arrays(indptr: np.ndarray, slot_ids: np.ndarray,
                      unassigned: np.ndarray, t_rows: int):
    """Pack the topic-bucket CSR plus the unassigned segment for device
    upload: ``indptr_dev`` has ``t_rows + 2`` entries (segment ``t_rows``
    is the always-scanned unassigned block) and ``slots_dev`` is padded to
    a pow2 bucket so membership churn doesn't force recompiles."""
    n_mem = int(indptr[-1]) if indptr.size else 0
    slots = np.concatenate([np.asarray(slot_ids, np.int64),
                            np.asarray(unassigned, np.int64)])
    npad = pad_pow2(max(int(slots.size), 1), 64)
    out = np.zeros(npad, np.int32)
    out[: slots.size] = slots
    ip = np.zeros(t_rows + 2, np.int32)
    ip[: t_rows + 1] = indptr
    ip[t_rows + 1] = n_mem + int(unassigned.size)
    return ip, out


def candidate_cap(counts: np.ndarray, n_una: int, probes: int,
                  budget: int) -> int:
    """Static candidate width for the gather: the unassigned block plus
    the smaller of the probe budget and the ``probes`` largest bucket
    counts — an upper bound on any query's candidate total, computed
    without a device sync."""
    p = int(min(probes, counts.size))
    if p <= 0:
        top = 0
    elif p >= counts.size:
        top = int(counts.sum())
    else:
        top = int(np.partition(counts, -p)[-p:].sum())
    return pad_geo(max(1, int(n_una) + min(int(budget), top)))


# ---------------------------------------------------------------------------
# fused bodies

def _union_rescore(qp, emb, u_slots, u_valid, *, use_pallas, interpret):
    """Rescore the (slot-sorted) union of all shortlists in fp32 with the
    same kernel as the exact scan, returning each query's max and the
    lowest winning slot.  Sorting by slot id makes the kernel's
    lowest-*index* tie rule the exact path's lowest-*slot* rule."""
    n_slots = emb.shape[0]
    big = jnp.int32(n_slots)
    flat = jnp.where(u_valid, u_slots.astype(jnp.int32), big).reshape(-1)
    order = jnp.sort(flat)                      # sentinels sort last
    n_u = jnp.sum(u_valid.astype(jnp.int32))
    blk = jnp.take(emb, jnp.minimum(order, n_slots - 1), axis=0)
    rvals, ridx = sim_top1_raw(qp, blk, n_u, use_pallas=use_pallas,
                               interpret=interpret)
    win = jnp.take(order, jnp.clip(ridx, 0, order.shape[0] - 1))
    win = jnp.where(jnp.isfinite(rvals), win, big)
    return win, rvals, n_u


def _eps_f32(ql1, qsc, cl1_max, cs_max, dim):
    """Device-side int8 error bound, padded: the staged ``scan_margin``
    terms evaluated in f32 with 1.06×+1e-6 inflation (vs the host's
    1.05×+1e-7) so f32 rounding of the bound itself stays conservative."""
    eps = (jnp.float32(0.5) * ql1 * cs_max
           + jnp.float32(0.5) * cl1_max * qsc
           + jnp.float32(0.25) * jnp.float32(dim) * qsc * cs_max)
    return eps * jnp.float32(1.06) + jnp.float32(1e-6)


def _inflate(thresh):
    """Absolute + relative inflation of a finite f32 threshold so device
    f32 comparisons can only be *more* conservative than the staged f64
    predicate (−inf passes through untouched)."""
    guard = jnp.where(jnp.isfinite(thresh),
                      jnp.abs(thresh) * jnp.float32(1e-6) + jnp.float32(1e-6),
                      jnp.float32(0.0))
    return thresh + guard


def _fused_pruned_body(qp, q8q, qsc, ql1, emb, q8s, csc, cl1, aug, indptr,
                      slots, n_topics, budget, b_real, tau_lo, *, probes,
                      cap_c, k, armed, use_pallas, interpret):
    """route → cap → CSR gather → int8 scan → fp32 union rescore →
    safety predicates, one trace.  See the module docstring for the
    parity argument; shapes: ``qp (B,D)``, ``emb/q8s (N,D)``,
    ``aug (T,D+1)``, ``indptr (T+2,)``, ``slots (Npad,)``."""
    bsz, dim = qp.shape
    t_rows = aug.shape[0]

    # ---- stage 1: routing (same kernel + k contract as ops.route_topics)
    k_route = min(probes + 1, t_rows)
    vals, tids = route_topics_raw(qp, aug, n_topics, k_route,
                                  use_pallas=use_pallas, interpret=interpret)
    n_pc = min(probes, k_route)
    if vals.shape[1] <= n_pc:      # no natural unprobed-bound column
        vals_e = jnp.concatenate(
            [vals, jnp.full((bsz, 1), -jnp.inf, vals.dtype)], axis=1)
    else:
        vals_e = vals
    pv = vals[:, :n_pc]
    pt = jnp.clip(tids[:, :n_pc], 0, max(t_rows - 1, 0))
    live = jnp.isfinite(pv)

    # ---- stage 2: adaptive probe cap — same greedy prefix rule as the
    # staged driver (cumulative bucket rows ≤ budget); dead columns sort
    # last so the kept set is always a prefix.
    cnt = jnp.where(live, jnp.take(indptr, pt + 1) - jnp.take(indptr, pt), 0)
    csum = jnp.cumsum(cnt, axis=1)
    allowed = jnp.cumprod((csum <= budget).astype(jnp.int32), axis=1) > 0
    take = live & allowed
    p_i = jnp.sum(take.astype(jnp.int32), axis=1)
    ub = jnp.take_along_axis(vals_e, p_i[:, None], axis=1)[:, 0]
    capped = jnp.any(live & ~allowed, axis=1)
    if armed:
        skip = vals[:, 0] <= tau_lo        # certain-miss routing arm
        take = take & ~skip[:, None]
        p_i = jnp.where(skip, 0, p_i)
        ub = jnp.where(skip, vals[:, 0], ub)
        capped = capped & ~skip

    # ---- stage 3: CSR candidate gather.  Per-query segments = kept
    # probes' buckets + the always-scanned unassigned block; position →
    # segment via searchsorted over the per-query segment-end cumsum.
    seg_cnt = jnp.where(take, cnt, 0)
    n_una = indptr[t_rows + 1] - indptr[t_rows]
    ends = jnp.cumsum(
        jnp.concatenate(
            [seg_cnt, jnp.full((bsz, 1), n_una, seg_cnt.dtype)], axis=1),
        axis=1)
    total = ends[:, -1]
    pos = jnp.arange(cap_c, dtype=jnp.int32)
    # searchsorted(e, pos, "right") over ≤ probes+1 segment ends is just
    # a count of ends ≤ pos — the closed form avoids XLA CPU lowering
    # the vmapped binary search to a serial while loop
    seg = jnp.sum((ends[:, :, None] <= pos[None, None, :]).astype(jnp.int32),
                  axis=1)
    seg = jnp.minimum(seg, n_pc).astype(jnp.int32)
    starts = jnp.concatenate(
        [jnp.zeros((bsz, 1), ends.dtype), ends[:, :-1]], axis=1)
    off = pos[None, :] - jnp.take_along_axis(starts, seg, axis=1)
    topic = jnp.take_along_axis(pt, jnp.minimum(seg, n_pc - 1), axis=1)
    base = jnp.where(seg < n_pc, jnp.take(indptr, topic), indptr[t_rows])
    cvalid = pos[None, :] < total[:, None]
    cand = jnp.take(slots, jnp.clip(base + off, 0, slots.shape[0] - 1))
    cand = jnp.where(cvalid, cand, 0)

    # ---- stage 4: int8 candidate scan (exact integer accumulate; the
    # fixed (acc·qs)·cs order matches the q8 kernels bit-for-bit).
    c8 = jnp.take(q8s, cand, axis=0)
    acc = jax.lax.dot_general(q8q, c8, (((1,), (2,)), ((0,), (0,))),
                              preferred_element_type=jnp.int32)
    cs_g = jnp.take(csc, cand)
    scores = jnp.where(cvalid,
                       (acc.astype(jnp.float32) * qsc[:, None]) * cs_g,
                       -jnp.inf)
    cs_max = jnp.max(jnp.where(cvalid, cs_g, 0.0), axis=1)
    cl1_max = jnp.max(jnp.where(cvalid, jnp.take(cl1, cand), 0.0), axis=1)
    eps = _eps_f32(ql1, qsc, cl1_max, cs_max, dim)

    # ---- stage 5: shortlist + exclusion threshold
    k_eff = min(k, cap_c)
    svals, spos = jax.lax.top_k(scores, k_eff)
    kth = svals[:, -1]
    covers = total <= k_eff
    thresh = _inflate(jnp.where(jnp.isfinite(kth) & ~covers,
                                kth + eps, -jnp.inf))

    # ---- stage 6: fp32 union rescore (exact per-pair kernel math)
    row_ok = jnp.arange(bsz, dtype=jnp.int32) < b_real
    u_slots = jnp.take_along_axis(cand, spos, axis=1)
    u_valid = jnp.isfinite(svals) & row_ok[:, None]
    win, rmax, n_u = _union_rescore(qp, emb, u_slots, u_valid,
                                    use_pallas=use_pallas,
                                    interpret=interpret)

    # ---- stage 7: safety predicates (resolve_topk + resolve_pruned arms)
    cert = rmax > jnp.maximum(thresh, ub)
    if armed:
        cert = cert | ((rmax <= tau_lo) & (thresh <= tau_lo)
                       & (ub <= tau_lo))
    probed = jnp.sum((take & (cnt > 0)).astype(jnp.int32), axis=1)
    return (win, rmax, ub, cert, total, probed, capped.astype(jnp.int32),
            n_u)


def _fused_quant_body(qp, q8q, qsc, ql1, emb, q8s, csc, cl1, n_valid, b_real,
                     tau_lo, *, k, armed, use_pallas, interpret):
    """Pure-quantized fused lookup: full-slab int8 Top-K (the same
    ``sim_topk_q8`` kernel launch the staged path makes) + fp32 union
    rescore + the ``resolve_topk`` arms, one trace."""
    bsz, dim = qp.shape
    n_slots = q8s.shape[0]
    vals, rows = sim_topk_q8_raw(q8q, qsc, q8s, csc, n_valid, k,
                                 use_pallas=use_pallas, interpret=interpret)
    m = jnp.arange(n_slots, dtype=jnp.int32) < n_valid
    cs_max = jnp.max(jnp.where(m, csc, 0.0))
    cl1_max = jnp.max(jnp.where(m, cl1, 0.0))
    eps = _eps_f32(ql1, qsc, cl1_max, cs_max, dim)
    kth = vals[:, -1]
    covers = n_valid <= vals.shape[1]
    thresh = _inflate(jnp.where(jnp.isfinite(kth) & ~covers,
                                kth + eps, -jnp.inf))
    row_ok = jnp.arange(bsz, dtype=jnp.int32) < b_real
    u_valid = jnp.isfinite(vals) & row_ok[:, None]
    win, rmax, n_u = _union_rescore(qp, emb, rows, u_valid,
                                    use_pallas=use_pallas,
                                    interpret=interpret)
    cert = rmax > thresh
    if armed:
        cert = cert | ((rmax <= tau_lo) & (thresh <= tau_lo))
    return win, rmax, cert, n_u


# Query buffers are per-call scratch → donate them on accelerators; XLA
# CPU ignores donation (and warns), so skip it there.
_DONATE = () if _is_cpu() else (0, 1, 2, 3)

_fused_pruned_jit = functools.partial(
    jax.jit, static_argnames=("probes", "cap_c", "k", "armed", "use_pallas",
                              "interpret"),
    donate_argnums=_DONATE)(_fused_pruned_body)

_fused_quant_jit = functools.partial(
    jax.jit, static_argnames=("k", "armed", "use_pallas", "interpret"),
    donate_argnums=_DONATE)(_fused_quant_body)


def fused_pruned_lookup(qp, q8q, qsc, ql1, emb, q8s, csc, cl1, aug, indptr,
                        slots, n_topics, budget, b_real, tau, *, probes,
                        cap_c, k, use_pallas=True, interpret=None):
    """One-launch pruned (optionally quantize-composed) lookup.  ``tau``
    is the f64 hit threshold or None; everything else is device-ready.
    Returns the raw device tuple — callers slice off padding rows."""
    armed = tau is not None
    t_lo = tau_lo_f32(tau) if armed else np.float32(0.0)
    fused_stats["calls"] += 1
    count_launch()
    # numpy scalars on purpose: they ride the jit fast path, where eager
    # jnp casts would each dispatch a convert_element_type per call
    return _fused_pruned_jit(qp, q8q, qsc, ql1, emb, q8s, csc, cl1, aug,
                             indptr, slots, np.int32(n_topics),
                             np.int32(budget), np.int32(b_real),
                             np.float32(t_lo), probes=int(probes),
                             cap_c=int(cap_c), k=int(k), armed=armed,
                             use_pallas=use_pallas, interpret=interpret)


def fused_quant_lookup(qp, q8q, qsc, ql1, emb, q8s, csc, cl1, n_valid,
                       b_real, tau, *, k, use_pallas=True, interpret=None):
    """One-launch pure-quantized lookup (full-slab int8 Top-K + rescore +
    predicates).  Same conventions as :func:`fused_pruned_lookup`."""
    armed = tau is not None
    t_lo = tau_lo_f32(tau) if armed else np.float32(0.0)
    fused_stats["calls"] += 1
    count_launch()
    return _fused_quant_jit(qp, q8q, qsc, ql1, emb, q8s, csc, cl1,
                            np.int32(n_valid), np.int32(b_real),
                            np.float32(t_lo), k=int(k), armed=armed,
                            use_pallas=use_pallas, interpret=interpret)
