"""Pallas TPU kernel: single-token GQA decode attention over a KV cache.

Grid = (batch, kv_heads): each cell serves one KV head's query group
(G = H/Hkv query heads, kept VMEM-resident as a (G × D) tile — MXU-friendly
since G·D is small) against that head's cache, streamed in BK chunks with
an online-softmax carry.  The valid length comes from ``pos`` (per-batch
scalar, (B, 1) block) so padding/unwritten cache slots are masked.

This is the serving hot loop: one call per generated token.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BK = 512
NEG = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, *, bk: int,
                   scale: float, s_max: int):
    pos = pos_ref[0, 0]                                   # scalar int32
    q = q_ref[0, 0].astype(jnp.float32) * scale           # (G, D)
    g, d = q.shape
    n_chunks = (pos + bk) // bk                           # ⌈(pos+1)/bk⌉

    def body(c, carry):
        acc, m_i, l_i = carry
        k = k_ref[0, pl.dslice(c * bk, bk), 0, :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(c * bk, bk), 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, bk)
        col = c * bk + jax.lax.broadcasted_iota(jnp.int32, (g, bk), 1)
        s = jnp.where(col <= pos, s, NEG)
        m_new = jnp.maximum(m_i, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + p.sum(axis=1)
        acc = acc * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((g, d), jnp.float32)
    m0 = jnp.full((g,), NEG, jnp.float32)
    l0 = jnp.zeros((g,), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(0, n_chunks, body, (acc0, m0, l0))
    o_ref[0, 0] = (acc / jnp.maximum(l_i, 1e-30)[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            pos: jnp.ndarray, *, interpret: bool = True):
    """q (B, H, D); k/v (B, S, Hkv, D); pos (B,) int32 — index of the
    newest valid cache entry (attend to [0, pos])."""
    b, h, d = q.shape
    s_max, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    bk = next((x for x in (BK, 256, 128) if s_max % x == 0), s_max)
    q4 = q.reshape(b, hkv, g, d)
    pos2 = pos.reshape(b, 1).astype(jnp.int32)
    kernel = functools.partial(_decode_kernel, bk=bk, scale=1.0 / d ** 0.5,
                               s_max=s_max)
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bb, hh: (bb, 0)),
            pl.BlockSpec((1, 1, g, d), lambda bb, hh: (bb, hh, 0, 0)),
            pl.BlockSpec((1, s_max, 1, d), lambda bb, hh: (bb, 0, hh, 0)),
            pl.BlockSpec((1, s_max, 1, d), lambda bb, hh: (bb, 0, hh, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bb, hh: (bb, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(pos2, q4, k, v)
    return out.reshape(b, h, d)
