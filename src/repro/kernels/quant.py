"""Shared int8 quantization helpers for kernels and collectives.

Two families live here:

  - **Per-tensor scale** (``quantize_int8`` / ``dequantize_int8``): one
    fp32 scale for the whole array, used by the distributed gradient
    all-reduce (:mod:`repro.distributed.compression` re-exports these —
    behavior is bit-for-bit the historical one).
  - **Per-row scale** (``quantize_rows_int8``): one symmetric scale per
    row, the right granularity for the cache's embedding slab where row
    magnitudes differ.  Feeds the quantized lookup path
    (:mod:`repro.cache.quantized`, ``ops.sim_topk_q8``).

Exactness plumbing for the quantized scan also lives here:

  - ``int8_scores`` computes *exact* integer dot products of int8 rows on
    the host.  For ``D * 127**2 < 2**24`` every partial sum fits a fp32
    mantissa, so a BLAS fp32 gemm of the int8 values is bit-exact integer
    arithmetic (and an order of magnitude faster than numpy's int32 gemm);
    larger D falls back to int32.
  - ``scan_margin`` bounds ``|approx_score - exact_score|`` per query so
    the rescore step can certify decisions (see docs/quantized_lookup.md).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "quantize_int8", "dequantize_int8", "quantize_rows_int8",
    "int8_scores", "scan_margin",
]


# ---------------------------------------------------------------------------
# Per-tensor scale (jnp; moved verbatim from distributed/compression.py).
# ---------------------------------------------------------------------------

def quantize_int8(g):
    """Symmetric per-tensor int8 quantization: ``(q, scale)``."""
    import jax.numpy as jnp
    scale = jnp.max(jnp.abs(g)).astype(jnp.float32) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q, scale):
    import jax.numpy as jnp
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# Per-row scale (numpy; host mirrors quantize on the host, scan on device).
# ---------------------------------------------------------------------------

def quantize_rows_int8(x: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Symmetric per-row int8 quantization of a ``(N, D)`` fp32 slab.

    Returns ``(q8, scale, l1)`` where ``x[i] ≈ q8[i] * scale[i]`` with
    per-element error ≤ ``scale[i] / 2`` (round-half-even, clip inert
    because ``|x[i,j]| / scale[i] < 127``), and ``l1[i] = sum_j |x[i,j]|``
    in float64 — the row norms ``scan_margin`` needs.  All-zero rows get
    the epsilon scale, ``q8 = 0``, ``l1 = 0``.
    """
    x = np.ascontiguousarray(x, dtype=np.float32)
    ax = np.abs(x)
    scale = (ax.max(axis=1) / 127.0 + 1e-30).astype(np.float32) \
        if x.size else np.zeros((x.shape[0],), np.float32)
    q = np.clip(np.rint(x / scale[:, None]), -127, 127).astype(np.int8) \
        if x.size else np.zeros(x.shape, np.int8)
    l1 = ax.sum(axis=1, dtype=np.float64)
    return q, scale, l1


def int8_scores(q8: np.ndarray, c8: np.ndarray) -> np.ndarray:
    """Exact ``q8 @ c8.T`` integer dot products, returned as float32.

    Each product is ≤ ``127**2 = 16129``; when ``D * 16129 < 2**24`` every
    partial sum is exactly representable in fp32, so the fast BLAS path is
    bit-exact integer arithmetic.  Otherwise an int32 gemm (always exact:
    ``D * 16129 < 2**31`` for any realistic D) is converted — int32 scores
    below ``2**24`` convert to fp32 without rounding, and larger ones only
    occur when the fp32 path was already excluded.
    """
    d = q8.shape[1]
    if d * 16129 < (1 << 24):
        return q8.astype(np.float32) @ c8.astype(np.float32).T
    return (q8.astype(np.int32) @ c8.astype(np.int32).T).astype(np.float32)


def scan_margin(qscale: np.ndarray, q_l1: np.ndarray,
                cand_scale: np.ndarray, cand_l1: np.ndarray,
                dim: int) -> np.ndarray:
    """Per-query upper bound on ``|approx - exact|`` similarity error.

    With ``x = q8*qs + eq`` (``|eq| ≤ qs/2`` elementwise) and
    ``c = c8*cs + ec`` (``|ec| ≤ cs/2``)::

        |approx - exact| = |q·ec + c·eq - eq·ec|
                         ≤ ||q||_1 * cs/2 + ||c||_1 * qs/2 + D * qs*cs/4

    maximized over candidate rows by taking ``max(cand_scale)`` and
    ``max(cand_l1)``.  Rows that were never written are all-zero (epsilon
    scale, zero L1) so the maxima can safely run over the whole mirror.
    The 5% inflation + absolute floor swallows fp32 rounding of both the
    scaled int8 scores and the exact-path dot products (relative error
    ``O(D * 2^-24)``, < 1% of the leading terms for D ≤ 1024) — inflating
    the bound only ever costs extra exact-scan fallbacks, never wrong
    decisions.  Computed in float64; shape ``(B,)``.
    """
    qs = np.asarray(qscale, dtype=np.float64)
    ql1 = np.asarray(q_l1, dtype=np.float64)
    cs = float(np.max(cand_scale)) if np.size(cand_scale) else 0.0
    cl1 = float(np.max(cand_l1)) if np.size(cand_l1) else 0.0
    eps = 0.5 * ql1 * cs + 0.5 * cl1 * qs + 0.25 * float(dim) * qs * cs
    return eps * 1.05 + 1e-7
