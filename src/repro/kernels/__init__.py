"""Pallas TPU kernels for the serving hot spots, with jnp oracles.

  - similarity_topk: semantic-cache hit determination (the paper's named
    cost center) — tiled MXU matmul + running top-1 merge.
  - flash_attention: causal GQA prefill attention (online softmax).
  - decode_attention: one-token GQA decode over a KV cache.
  - rac_value: device-side RAC Eq.1 scoring over the resident table.
  - decision: occupancy-masked Eq.1 victim scoring with a runtime t_now;
    composed with two sim_top1 passes into ``ops.fused_decide`` — the one
    launch per replay chunk behind the backends' ``decide_batch``.

Public API: :mod:`repro.kernels.ops` (jit'd, padded, CPU interpret-mode
fallback); oracles in :mod:`repro.kernels.ref`.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
