"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sim_top1_ref(queries: jnp.ndarray, candidates: jnp.ndarray,
                 n_valid: int):
    """queries (Q,D), candidates (N,D) -> (max sim (Q,), argmax (Q,))."""
    scores = queries.astype(jnp.float32) @ candidates.astype(jnp.float32).T
    col = jnp.arange(candidates.shape[0])
    scores = jnp.where(col[None, :] < n_valid, scores, -jnp.inf)
    return scores.max(axis=1), scores.argmax(axis=1).astype(jnp.int32)


def sim_topk_ref(queries: jnp.ndarray, candidates: jnp.ndarray,
                 n_valid: int, k: int):
    """queries (Q,D), candidates (N,D) -> (vals (Q,K), idx (Q,K)), sorted
    descending; ``lax.top_k`` breaks ties toward the lower index, matching
    the kernel's merge order and a stable descending host sort."""
    scores = queries.astype(jnp.float32) @ candidates.astype(jnp.float32).T
    col = jnp.arange(candidates.shape[0])
    scores = jnp.where(col[None, :] < n_valid, scores, -jnp.inf)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)


def sim_topk_q8_ref(q8: jnp.ndarray, qscale: jnp.ndarray,
                    c8: jnp.ndarray, cscale: jnp.ndarray,
                    n_valid: int, k: int):
    """Quantized-slab Top-K oracle: exact int8×int8→int32 scores rescaled
    per row as ``(acc * qscale) * cscale`` — the same fixed multiply order
    as the Pallas kernel and the numpy host gemm, so all engines produce
    bit-identical approximate similarities."""
    acc = jax.lax.dot_general(
        q8, c8, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)
    scores = (acc.astype(jnp.float32)
              * qscale.astype(jnp.float32)[:, None]) \
        * cscale.astype(jnp.float32)[None, :]
    col = jnp.arange(c8.shape[0])
    scores = jnp.where(col[None, :] < n_valid, scores, -jnp.inf)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True):
    """q (B,H,S,D); k/v (B,Hkv,S,D) -> (B,H,S,D).  fp32 softmax."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, s, d) / jnp.sqrt(d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qf, kf)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", w, vf)
    return out.reshape(b, h, s, d).astype(q.dtype)


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         pos: jnp.ndarray):
    """q (B,H,D); k/v (B,S,Hkv,D); pos (B,) -> (B,H,D)."""
    b, h, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d) / jnp.sqrt(d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qf, kf)
    valid = jnp.arange(s)[None, None, None, :] <= pos[:, None, None, None]
    scores = jnp.where(valid, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, vf)
    return out.reshape(b, h, d).astype(q.dtype)


def rac_value_ref(tsi: jnp.ndarray, tid: jnp.ndarray, tp_last: jnp.ndarray,
                  t_last: jnp.ndarray, alpha: float, t_now: int):
    decay = jnp.exp2(-alpha * (t_now - t_last[tid]).astype(jnp.float32))
    return decay * tp_last[tid].astype(jnp.float32) * tsi


def victim_value_ref(tsi: jnp.ndarray, tid: jnp.ndarray, occ: jnp.ndarray,
                     tp_last: jnp.ndarray, t_last: jnp.ndarray, t_now,
                     alpha: float):
    """Occupancy-masked Eq.1 with a traced t_now (free slots -> +inf)."""
    tid = jnp.maximum(tid, 0)                  # free slots carry tid -1
    decay = jnp.exp2(-alpha * (t_now - t_last[tid]).astype(jnp.float32))
    val = decay * tp_last[tid].astype(jnp.float32) * tsi
    return jnp.where(occ > 0, val, jnp.inf)
