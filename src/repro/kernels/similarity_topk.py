"""Pallas TPU kernels: batched cosine-similarity Top-1 and Top-K retrieval.

This is the semantic cache's hit-determination hot spot (the paper: "hit
determination itself requires costly similarity computation").  TPU-native
design: the (queries × candidates) score tile is one MXU matmul per grid
cell; a running (max, argmax) merge lives in the revisited output block
while candidate tiles stream HBM→VMEM.

Top-K (``sim_topk_pallas``) generalizes the merge: the revisited output
block holds the running (K values, K indices) per query, and each
candidate tile is folded in by K select-and-mask passes over the
``[running | tile]`` concatenation — K is small (shortlists, promotion
scans), so the extra VPU work is negligible next to the MXU matmul.
Ties break toward the lower candidate index, matching a stable descending
host sort.

``n_valid`` is a *runtime* scalar delivered through scalar prefetch
(``PrefetchScalarGridSpec``), so compacted and per-shard stores can mask
their free tail without recompiling as the resident count changes — the
kernel sees one stable (Q, N, D) shape per store geometry.

Tiling: (BQ=128 queries × BC=512 candidates × D) per grid cell; with D=128
fp32 that is  128·128·4 + 512·128·4 + 128·512·4  ≈ 0.6 MB of VMEM per cell,
MXU-aligned on every matmul dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 128      # query tile
BC = 512      # candidate tile


def _sim_top1_kernel(nv_ref, q_ref, c_ref, val_ref, idx_ref):
    """grid = (nq, nc); candidate axis is a sequential reduction.

    ``nv_ref`` is the scalar-prefetched resident count: columns at or past
    it (free tail rows, padding) are masked to -inf before the merge."""
    j = pl.program_id(1)
    n_valid = nv_ref[0]
    q = q_ref[...]                                   # (BQ, D)
    c = c_ref[...]                                   # (BC, D)
    scores = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (BQ, BC) on the MXU
    col = j * BC + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(col < n_valid, scores, -jnp.inf)
    m = jnp.max(scores, axis=1)
    a = j * BC + jnp.argmax(scores, axis=1).astype(jnp.int32)

    @pl.when(j == 0)
    def _init():
        val_ref[...] = m
        idx_ref[...] = a

    @pl.when(j > 0)
    def _merge():
        prev = val_ref[...]
        take = m > prev
        val_ref[...] = jnp.where(take, m, prev)
        idx_ref[...] = jnp.where(take, a, idx_ref[...])


def sim_top1_pallas(queries: jnp.ndarray, candidates: jnp.ndarray,
                    n_valid, *, interpret: bool = True):
    """queries (Q, D), candidates (N, D) both padded to tile multiples;
    returns (vals (Q,), idx (Q,)).  ``n_valid`` is a runtime scalar (python
    int or traced int32) masking the candidate tail — free slots beyond the
    resident high-water mark and padding rows never win Top-1."""
    q_n, d = queries.shape
    c_n = candidates.shape[0]
    assert q_n % BQ == 0 and c_n % BC == 0 and d % 128 == 0
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q_n // BQ, c_n // BC),
        in_specs=[pl.BlockSpec((BQ, d), lambda i, j, nv: (i, 0)),
                  pl.BlockSpec((BC, d), lambda i, j, nv: (j, 0))],
        out_specs=[pl.BlockSpec((BQ,), lambda i, j, nv: (i,)),
                   pl.BlockSpec((BQ,), lambda i, j, nv: (i,))])
    return pl.pallas_call(
        _sim_top1_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((q_n,), jnp.float32),
                   jax.ShapeDtypeStruct((q_n,), jnp.int32)],
        interpret=interpret,
    )(jnp.asarray(n_valid, jnp.int32).reshape(1), queries, candidates)

def _topk_fold(k: int, j, scores, col, val_ref, idx_ref):
    """Fold one masked score tile into the running per-query Top-K held in
    the revisited output block: K select-and-mask passes over the
    ``[running | tile]`` concatenation.  The running list is sorted
    descending with ties already resolved toward lower candidate index,
    and it sits left of the (higher-index) tile columns, so argmax's
    first-occurrence tie break keeps "lower candidate index wins"
    globally.  Shared by the fp32 and int8 Top-K kernels — survivor sets
    are therefore selected identically in both."""

    @pl.when(j == 0)
    def _init():
        val_ref[...] = jnp.full((BQ, k), -jnp.inf, jnp.float32)
        idx_ref[...] = jnp.full((BQ, k), 0, jnp.int32)

    comb_v = jnp.concatenate([val_ref[...], scores], axis=1)
    comb_i = jnp.concatenate([idx_ref[...], col], axis=1)
    new_v, new_i = [], []
    lane = jax.lax.broadcasted_iota(jnp.int32, comb_v.shape, 1)
    for _ in range(k):
        m = jnp.max(comb_v, axis=1)                  # (BQ,)
        a = jnp.argmax(comb_v, axis=1).astype(jnp.int32)
        hit = lane == a[:, None]
        # one-hot max instead of gather: the selected lane's index
        # (indices are >= 0, so the -1 fill never wins)
        new_v.append(m)
        new_i.append(jnp.max(jnp.where(hit, comb_i, -1), axis=1))
        comb_v = jnp.where(hit, -jnp.inf, comb_v)
    val_ref[...] = jnp.stack(new_v, axis=1)
    idx_ref[...] = jnp.stack(new_i, axis=1)


def _make_sim_topk_kernel(k: int):
    """Build a Top-K kernel for a static K (K is a compile-time constant:
    it sizes the revisited output block)."""

    def _sim_topk_kernel(nv_ref, q_ref, c_ref, val_ref, idx_ref):
        # grid = (nq, nc); candidate axis is a sequential reduction over a
        # running per-query Top-K kept in the revisited output block.
        j = pl.program_id(1)
        n_valid = nv_ref[0]
        q = q_ref[...]                                   # (BQ, D)
        c = c_ref[...]                                   # (BC, D)
        scores = jax.lax.dot_general(
            q, c, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (BQ, BC) on the MXU
        col = j * BC + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        scores = jnp.where(col < n_valid, scores, -jnp.inf)
        _topk_fold(k, j, scores, col, val_ref, idx_ref)

    return _sim_topk_kernel


def _make_sim_topk_q8_kernel(k: int):
    """Quantized-slab Top-K: int8 query and candidate tiles hit the MXU as
    an int8×int8→int32 matmul (the tile streams HBM→VMEM at a quarter the
    fp32 bytes — the whole point), then per-row scales rescale the exact
    integer scores into fp32 approximate similarities.  The scale multiply
    order ``(acc * qs) * cs`` is fixed across this kernel, the jnp oracle,
    and the numpy host gemm so all engines emit bit-identical scores."""

    def _sim_topk_q8_kernel(nv_ref, q_ref, qs_ref, c_ref, cs_ref,
                            val_ref, idx_ref):
        j = pl.program_id(1)
        n_valid = nv_ref[0]
        q = q_ref[...]                                   # (BQ, D) int8
        c = c_ref[...]                                   # (BC, D) int8
        acc = jax.lax.dot_general(
            q, c, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32)            # exact int32 scores
        scores = (acc.astype(jnp.float32)
                  * qs_ref[...][:, None]) * cs_ref[...][None, :]
        col = j * BC + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
        scores = jnp.where(col < n_valid, scores, -jnp.inf)
        _topk_fold(k, j, scores, col, val_ref, idx_ref)

    return _sim_topk_q8_kernel


def sim_topk_pallas(queries: jnp.ndarray, candidates: jnp.ndarray,
                    n_valid, k: int, *, interpret: bool = True):
    """queries (Q, D), candidates (N, D) padded to tile multiples; returns
    (vals (Q, K), idx (Q, K)) sorted descending, ties toward the lower
    candidate index.  ``n_valid`` is a runtime scalar masking the candidate
    tail; slots past it come back as (-inf, undefined-index) rows that the
    caller maps to (-inf, -1)."""
    q_n, d = queries.shape
    c_n = candidates.shape[0]
    assert q_n % BQ == 0 and c_n % BC == 0 and d % 128 == 0
    assert 1 <= k <= c_n
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q_n // BQ, c_n // BC),
        in_specs=[pl.BlockSpec((BQ, d), lambda i, j, nv: (i, 0)),
                  pl.BlockSpec((BC, d), lambda i, j, nv: (j, 0))],
        out_specs=[pl.BlockSpec((BQ, k), lambda i, j, nv: (i, 0)),
                   pl.BlockSpec((BQ, k), lambda i, j, nv: (i, 0))])
    return pl.pallas_call(
        _make_sim_topk_kernel(k),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((q_n, k), jnp.float32),
                   jax.ShapeDtypeStruct((q_n, k), jnp.int32)],
        interpret=interpret,
    )(jnp.asarray(n_valid, jnp.int32).reshape(1), queries, candidates)


def sim_topk_q8_pallas(q8: jnp.ndarray, qscale: jnp.ndarray,
                       c8: jnp.ndarray, cscale: jnp.ndarray,
                       n_valid, k: int, *, interpret: bool = True):
    """Top-K over a per-row-quantized slab: ``q8`` (Q, D) int8 with
    ``qscale`` (Q,) fp32, ``c8`` (N, D) int8 with ``cscale`` (N,) fp32,
    all padded to tile multiples (zero rows quantize to zero, so padding
    is exact).  Returns (vals (Q, K), idx (Q, K)) of *approximate* fp32
    similarities, same ordering/tie contract as ``sim_topk_pallas``."""
    q_n, d = q8.shape
    c_n = c8.shape[0]
    assert q_n % BQ == 0 and c_n % BC == 0 and d % 128 == 0
    assert 1 <= k <= c_n
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q_n // BQ, c_n // BC),
        in_specs=[pl.BlockSpec((BQ, d), lambda i, j, nv: (i, 0)),
                  pl.BlockSpec((BQ,), lambda i, j, nv: (i,)),
                  pl.BlockSpec((BC, d), lambda i, j, nv: (j, 0)),
                  pl.BlockSpec((BC,), lambda i, j, nv: (j,))],
        out_specs=[pl.BlockSpec((BQ, k), lambda i, j, nv: (i, 0)),
                   pl.BlockSpec((BQ, k), lambda i, j, nv: (i, 0))])
    return pl.pallas_call(
        _make_sim_topk_q8_kernel(k),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((q_n, k), jnp.float32),
                   jax.ShapeDtypeStruct((q_n, k), jnp.int32)],
        interpret=interpret,
    )(jnp.asarray(n_valid, jnp.int32).reshape(1),
      q8, qscale, c8, cscale)
