"""Pallas TPU kernel: batched cosine-similarity Top-1 retrieval.

This is the semantic cache's hit-determination hot spot (the paper: "hit
determination itself requires costly similarity computation").  TPU-native
design: the (queries × candidates) score tile is one MXU matmul per grid
cell; a running (max, argmax) merge lives in the revisited output block
while candidate tiles stream HBM→VMEM.

``n_valid`` is a *runtime* scalar delivered through scalar prefetch
(``PrefetchScalarGridSpec``), so compacted and per-shard stores can mask
their free tail without recompiling as the resident count changes — the
kernel sees one stable (Q, N, D) shape per store geometry.

Tiling: (BQ=128 queries × BC=512 candidates × D) per grid cell; with D=128
fp32 that is  128·128·4 + 512·128·4 + 128·512·4  ≈ 0.6 MB of VMEM per cell,
MXU-aligned on every matmul dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BQ = 128      # query tile
BC = 512      # candidate tile


def _sim_top1_kernel(nv_ref, q_ref, c_ref, val_ref, idx_ref):
    """grid = (nq, nc); candidate axis is a sequential reduction.

    ``nv_ref`` is the scalar-prefetched resident count: columns at or past
    it (free tail rows, padding) are masked to -inf before the merge."""
    j = pl.program_id(1)
    n_valid = nv_ref[0]
    q = q_ref[...]                                   # (BQ, D)
    c = c_ref[...]                                   # (BC, D)
    scores = jax.lax.dot_general(
        q, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)          # (BQ, BC) on the MXU
    col = j * BC + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(col < n_valid, scores, -jnp.inf)
    m = jnp.max(scores, axis=1)
    a = j * BC + jnp.argmax(scores, axis=1).astype(jnp.int32)

    @pl.when(j == 0)
    def _init():
        val_ref[...] = m
        idx_ref[...] = a

    @pl.when(j > 0)
    def _merge():
        prev = val_ref[...]
        take = m > prev
        val_ref[...] = jnp.where(take, m, prev)
        idx_ref[...] = jnp.where(take, a, idx_ref[...])


def sim_top1_pallas(queries: jnp.ndarray, candidates: jnp.ndarray,
                    n_valid, *, interpret: bool = True):
    """queries (Q, D), candidates (N, D) both padded to tile multiples;
    returns (vals (Q,), idx (Q,)).  ``n_valid`` is a runtime scalar (python
    int or traced int32) masking the candidate tail — free slots beyond the
    resident high-water mark and padding rows never win Top-1."""
    q_n, d = queries.shape
    c_n = candidates.shape[0]
    assert q_n % BQ == 0 and c_n % BC == 0 and d % 128 == 0
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q_n // BQ, c_n // BC),
        in_specs=[pl.BlockSpec((BQ, d), lambda i, j, nv: (i, 0)),
                  pl.BlockSpec((BC, d), lambda i, j, nv: (j, 0))],
        out_specs=[pl.BlockSpec((BQ,), lambda i, j, nv: (i,)),
                   pl.BlockSpec((BQ,), lambda i, j, nv: (i,))])
    return pl.pallas_call(
        _sim_top1_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((q_n,), jnp.float32),
                   jax.ShapeDtypeStruct((q_n,), jnp.int32)],
        interpret=interpret,
    )(jnp.asarray(n_valid, jnp.int32).reshape(1), queries, candidates)
