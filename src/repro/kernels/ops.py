"""Jit'd public wrappers around the Pallas kernels.

Each wrapper pads inputs to kernel tile multiples, dispatches to the kernel
(``interpret=True`` on CPU — the TPU path compiles the same kernels
natively), and unpads the result.  ``use_pallas=False`` falls back to the
ref oracle (used by the serving engine on CPU where interpret-mode overhead
isn't worth it).
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .decision import (BN as _VV_BN, victim_value_multi_pallas,
                       victim_value_pallas)
from .decode_attention import decode_attention_pallas
from .flash_attention import BQ as _FA_BQ, flash_attention_pallas
from .rac_value import BN as _RV_BN, rac_value_pallas
from .similarity_topk import (BC as _ST_BC, BQ as _ST_BQ, sim_top1_pallas,
                              sim_topk_pallas, sim_topk_q8_pallas)


def _is_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


#: Process-global dispatch ledger.  ``launches`` counts kernel dispatches
#: (one per public wrapper call — each is one jitted program), ``host_syncs``
#: counts device→host materializations (every ``to_host``), and ``kernel_s``
#: accumulates blocked-on-device wall time from ``run_timed`` so benches can
#: separate scan time from host-driver overhead.
dispatch_stats = {"launches": 0, "host_syncs": 0, "kernel_s": 0.0}


def count_launch(n: int = 1) -> None:
    """Tick the kernel-dispatch counter (one jitted program launched)."""
    dispatch_stats["launches"] += n


def to_host(x):
    """Materialize ``x`` on the host, counting the sync when it actually
    crosses the device boundary (numpy inputs pass through uncounted)."""
    if isinstance(x, jax.Array):
        dispatch_stats["host_syncs"] += 1
    return np.asarray(x)


def to_host_tuple(xs):
    """Materialize a tuple of device arrays as ONE counted sync — the
    fused pipeline's single device→host transfer per chunk."""
    dispatch_stats["host_syncs"] += 1
    return jax.device_get(xs)


def run_timed(fn, tracker=None, name: str = "kernel"):
    """Run ``fn`` (a zero-arg closure dispatching device work), block until
    its outputs are ready, and charge the interval to
    ``dispatch_stats["kernel_s"]`` — the kernel-time clock the roofline
    table reads alongside wall-clock.  When a tracker is attached the
    interval is also emitted as a trace span."""
    t0 = time.perf_counter()
    out = fn()
    jax.block_until_ready(out)
    t1 = time.perf_counter()
    dispatch_stats["kernel_s"] += t1 - t0
    if tracker is not None:
        tracker.add_span(f"kernel/{name}", t0, t1)
    return out


def _counted(fn):
    """Wrap a public dispatch wrapper so every call ticks ``launches``."""

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        count_launch()
        return fn(*args, **kwargs)

    return wrapper


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0.0) -> jnp.ndarray:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def sim_top1_raw(queries, candidates, n_valid, *, use_pallas: bool = True,
                 interpret: bool | None = None):
    """Un-jitted Top-1 body shared by :func:`sim_top1` and the sharded
    backend (which calls it per shard inside a ``shard_map`` region).
    ``n_valid`` may be a traced int32 scalar — it masks the candidate tail
    at runtime, so one compilation serves every resident count."""
    if not use_pallas:
        return ref.sim_top1_ref(queries, candidates, n_valid)
    interp = _is_cpu() if interpret is None else interpret
    qp = _pad_to(_pad_to(queries, 1, 128), 0, _ST_BQ)
    cp = _pad_to(_pad_to(candidates, 1, 128), 0, _ST_BC)
    vals, idx = sim_top1_pallas(qp.astype(jnp.float32),
                                cp.astype(jnp.float32),
                                n_valid, interpret=interp)
    return vals[: queries.shape[0]], idx[: queries.shape[0]]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def _sim_top1_jit(queries, candidates, n_valid, *, use_pallas, interpret):
    return sim_top1_raw(queries, candidates, n_valid, use_pallas=use_pallas,
                        interpret=interpret)


@_counted
def sim_top1(queries, candidates, n_valid=None, *, use_pallas: bool = True,
             interpret: bool | None = None):
    """Top-1 cosine retrieval: (Q,D)x(N,D) -> (vals (Q,), idx (Q,)).

    ``n_valid`` (default: all of ``candidates``) is a *runtime* resident
    count: rows at or past it are masked to -inf, so compacted and
    per-shard stores stop scoring their free tail without triggering a
    recompile per count."""
    if n_valid is None:
        n_valid = candidates.shape[0]
    return _sim_top1_jit(queries, candidates, jnp.int32(n_valid),
                         use_pallas=use_pallas, interpret=interpret)


def sim_topk_raw(queries, candidates, n_valid, k: int, *,
                 use_pallas: bool = True, interpret: bool | None = None):
    """Un-jitted Top-K body.  ``k`` is static (it sizes the kernel's
    revisited output block); ``n_valid`` may be a traced int32 scalar."""
    if not use_pallas:
        return ref.sim_topk_ref(queries, candidates, n_valid, k)
    interp = _is_cpu() if interpret is None else interpret
    qp = _pad_to(_pad_to(queries, 1, 128), 0, _ST_BQ)
    cp = _pad_to(_pad_to(candidates, 1, 128), 0, _ST_BC)
    vals, idx = sim_topk_pallas(qp.astype(jnp.float32),
                                cp.astype(jnp.float32),
                                n_valid, k, interpret=interp)
    return vals[: queries.shape[0]], idx[: queries.shape[0]]


@functools.partial(jax.jit, static_argnames=("k", "use_pallas", "interpret"))
def _sim_topk_jit(queries, candidates, n_valid, *, k, use_pallas, interpret):
    return sim_topk_raw(queries, candidates, n_valid, k,
                        use_pallas=use_pallas, interpret=interpret)


@_counted
def sim_topk(queries, candidates, k: int, n_valid=None, *,
             use_pallas: bool = True, interpret: bool | None = None):
    """Top-K cosine retrieval: (Q,D)x(N,D) -> (vals (Q,K), idx (Q,K)),
    sorted descending with ties toward the lower candidate index.

    The K-generalization of :func:`sim_top1` behind the host-tier
    promotion scan and shortlist peeks.  ``k`` is static per launch shape;
    ``n_valid`` is the runtime resident count masking the free tail (rows
    at or past it come back as (-inf, undefined) — callers map them to
    (-inf, -1))."""
    if n_valid is None:
        n_valid = candidates.shape[0]
    return _sim_topk_jit(queries, candidates, jnp.int32(n_valid), k=int(k),
                         use_pallas=use_pallas, interpret=interpret)


def route_topics_raw(queries, reps_aug, n_valid, k: int, *,
                     use_pallas: bool = True, interpret: bool | None = None):
    """Un-jitted topic-routing body: augment each query with its L2 norm
    and Top-K the (T, D+1) bound matrix ``[rep | spread]`` — the matmul
    computes ``q·rep_t + ‖q‖·spread_t`` directly (see cache/pruned.py)."""
    qf = queries.astype(jnp.float32)
    qn = jnp.sqrt(jnp.sum(qf * qf, axis=1, keepdims=True))
    qa = jnp.concatenate([qf, qn], axis=1)
    return sim_topk_raw(qa, reps_aug, n_valid, k,
                        use_pallas=use_pallas, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("k", "use_pallas", "interpret"))
def _route_topics_jit(queries, reps_aug, n_valid, *, k, use_pallas,
                      interpret):
    return route_topics_raw(queries, reps_aug, n_valid, k,
                            use_pallas=use_pallas, interpret=interpret)


@_counted
def route_topics(queries, reps_aug, probes: int, n_valid=None, *,
                 use_pallas: bool = True, interpret: bool | None = None):
    """Stage-1 routing for the pruned lookup: (Q,D)x(T,D+1) ->
    (bounds (Q,K), tids (Q,K)), K = probes+1, sorted descending.

    ``reps_aug`` row ``t`` is ``[rep_t | spread_t]`` so scoring the
    norm-augmented query yields each topic's Cauchy–Schwarz score bound;
    the leading ``probes`` columns are the probe set and column
    ``probes`` (when present) bounds every unprobed topic.  ``n_valid``
    masks retired/unborn topic rows to (-inf, undefined), so with fewer
    live topics than probes the unprobed bound is naturally -inf."""
    if n_valid is None:
        n_valid = reps_aug.shape[0]
    k = int(min(probes + 1, reps_aug.shape[0]))
    return _route_topics_jit(queries, reps_aug, jnp.int32(n_valid), k=k,
                             use_pallas=use_pallas, interpret=interpret)


def sim_topk_q8_raw(q8, qscale, c8, cscale, n_valid, k: int, *,
                    use_pallas: bool = True, interpret: bool | None = None):
    """Un-jitted quantized Top-K body shared by :func:`sim_topk_q8` and the
    sharded backend (per shard inside ``shard_map``).  Inputs are the int8
    mirrors plus their per-row fp32 scales; int8 zero-padding is exact
    (zero rows score 0 and sit behind the ``n_valid`` mask anyway)."""
    if not use_pallas:
        return ref.sim_topk_q8_ref(q8, qscale, c8, cscale, n_valid, k)
    interp = _is_cpu() if interpret is None else interpret
    qp = _pad_to(_pad_to(q8, 1, 128, value=0), 0, _ST_BQ, value=0)
    cp = _pad_to(_pad_to(c8, 1, 128, value=0), 0, _ST_BC, value=0)
    qs = _pad_to(qscale, 0, _ST_BQ)
    cs = _pad_to(cscale, 0, _ST_BC)
    vals, idx = sim_topk_q8_pallas(qp.astype(jnp.int8),
                                   qs.astype(jnp.float32),
                                   cp.astype(jnp.int8),
                                   cs.astype(jnp.float32),
                                   n_valid, k, interpret=interp)
    return vals[: q8.shape[0]], idx[: q8.shape[0]]


@functools.partial(jax.jit, static_argnames=("k", "use_pallas", "interpret"))
def _sim_topk_q8_jit(q8, qscale, c8, cscale, n_valid, *, k, use_pallas,
                     interpret):
    return sim_topk_q8_raw(q8, qscale, c8, cscale, n_valid, k,
                           use_pallas=use_pallas, interpret=interpret)


@_counted
def sim_topk_q8(q8, qscale, c8, cscale, k: int, n_valid=None, *,
                use_pallas: bool = True, interpret: bool | None = None):
    """Quantized-slab Top-K candidate generation:
    (Q,D)i8×(N,D)i8 -> (vals (Q,K), idx (Q,K)) of *approximate* fp32
    similarities, same descending order / lower-index tie contract as
    :func:`sim_topk`.

    The candidate-generation half of the quantized lookup path
    (:mod:`repro.cache.quantized`): the scan streams the 4×-smaller int8
    slab, and the caller rescores the ≤K survivors in fp32 to make exact
    decisions.  ``k`` is static (clamped to the candidate count — a
    shortlist can never be wider than the slab); ``n_valid`` is the
    runtime resident count masking the free tail."""
    if n_valid is None:
        n_valid = c8.shape[0]
    return _sim_topk_q8_jit(q8, qscale, c8, cscale, jnp.int32(n_valid),
                            k=int(min(k, c8.shape[0])),
                            use_pallas=use_pallas, interpret=interpret)


def sim_topk_q8_multi_raw(q8, qscale, slabs8, cscales, n_valid, k: int, *,
                          use_pallas: bool = True,
                          interpret: bool | None = None):
    """Un-jitted policy-stacked quantized Top-K body: ``slabs8`` is
    ``(P, N, D)`` int8 with per-row scales ``cscales`` ``(P, N)`` and
    per-policy resident counts ``n_valid`` ``(P,)``.  Same dispatch shape
    as :func:`sim_top1_multi_raw` (grid-sequential ``lax.map`` on the
    pallas path, vmapped oracle otherwise), with the same per-row score
    independence: each policy's survivor set matches its own single-slab
    launch."""
    if use_pallas:
        def one(args):
            slab, cs, nv = args
            return sim_topk_q8_raw(q8, qscale, slab, cs, nv, k,
                                   use_pallas=True, interpret=interpret)

        return jax.lax.map(one, (slabs8, cscales, n_valid))
    return jax.vmap(
        lambda slab, cs, nv: ref.sim_topk_q8_ref(q8, qscale, slab, cs,
                                                 nv, k))(slabs8, cscales,
                                                         n_valid)


@functools.partial(jax.jit, static_argnames=("k", "use_pallas", "interpret"))
def _sim_topk_q8_multi_jit(q8, qscale, slabs8, cscales, n_valid, *, k,
                           use_pallas, interpret):
    return sim_topk_q8_multi_raw(q8, qscale, slabs8, cscales, n_valid, k,
                                 use_pallas=use_pallas, interpret=interpret)


@_counted
def sim_topk_q8_multi(q8, qscale, slabs8, cscales, k: int, n_valid=None, *,
                      use_pallas: bool = True,
                      interpret: bool | None = None):
    """Policy-stacked quantized Top-K: (B,D)i8×(P,N,D)i8 ->
    ((P,B,K), (P,B,K)) — the arena's stacked scan on the 4×-smaller slab,
    where the memory saving is multiplied by P.  ``k`` is clamped to the
    slot-axis width like :func:`sim_topk_q8`."""
    if n_valid is None:
        n_valid = np.full(slabs8.shape[0], slabs8.shape[1], dtype=np.int32)
    return _sim_topk_q8_multi_jit(q8, qscale, slabs8, cscales,
                                  jnp.asarray(n_valid, jnp.int32),
                                  k=int(min(k, slabs8.shape[1])),
                                  use_pallas=use_pallas, interpret=interpret)


def sim_top1_multi_raw(queries, slabs, n_valid, *, use_pallas: bool = True,
                       interpret: bool | None = None):
    """Un-jitted policy-stacked Top-1 body shared by :func:`sim_top1_multi`
    and the sharded backend (which runs it per shard inside ``shard_map``).

    ``slabs`` is ``(P, N, D)`` — one resident slab per policy — and
    ``n_valid`` ``(P,)`` the per-policy runtime resident counts.  The
    pallas path walks the policy axis grid-sequentially (``lax.map``) so
    the whole stack is one dispatch; the jnp-oracle path vmaps.  Per-row
    scores are computed by the same kernel math as :func:`sim_top1_raw`
    regardless of which rows share the launch, so each policy's Top-1
    *decision* is the one its own single-slab launch would have made."""
    if use_pallas:
        def one(args):
            slab, nv = args
            return sim_top1_raw(queries, slab, nv, use_pallas=True,
                                interpret=interpret)

        return jax.lax.map(one, (slabs, n_valid))
    return jax.vmap(
        lambda slab, nv: ref.sim_top1_ref(queries, slab, nv))(slabs, n_valid)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def _sim_top1_multi_jit(queries, slabs, n_valid, *, use_pallas, interpret):
    return sim_top1_multi_raw(queries, slabs, n_valid,
                              use_pallas=use_pallas, interpret=interpret)


@_counted
def sim_top1_multi(queries, slabs, n_valid=None, *, use_pallas: bool = True,
                   interpret: bool | None = None):
    """Policy-stacked Top-1 retrieval: (B,D)x(P,N,D) -> ((P,B), (P,B)).

    The batched-over-policy variant of :func:`sim_top1` behind the
    multi-policy arena: ONE dispatch scores a query chunk against every
    policy's resident slab, with a per-policy runtime ``n_valid`` vector
    masking each slab's free tail (no recompiles as fill levels drift
    apart)."""
    if n_valid is None:
        n_valid = np.full(slabs.shape[0], slabs.shape[1], dtype=np.int32)
    return _sim_top1_multi_jit(queries, slabs,
                               jnp.asarray(n_valid, jnp.int32),
                               use_pallas=use_pallas, interpret=interpret)


@_counted
@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def flash_attention(q, k, v, *, use_pallas: bool = True,
                    interpret: bool | None = None):
    """Causal GQA flash attention.  q (B,H,S,D); k/v (B,Hkv,S,D)."""
    if not use_pallas:
        return ref.attention_ref(q, k, v, causal=True)
    interp = _is_cpu() if interpret is None else interpret
    s = q.shape[2]
    qp = _pad_to(q, 2, _FA_BQ)
    kp = _pad_to(k, 2, _FA_BQ)
    vp = _pad_to(v, 2, _FA_BQ)
    out = flash_attention_pallas(qp, kp, vp, interpret=interp)
    return out[:, :, :s]


@_counted
@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def decode_attention(q, k, v, pos, *, use_pallas: bool = True,
                     interpret: bool | None = None):
    """One-token GQA decode.  q (B,H,D); k/v (B,S,Hkv,D); pos (B,)."""
    if not use_pallas:
        return ref.decode_attention_ref(q, k, v, pos)
    interp = _is_cpu() if interpret is None else interpret
    return decode_attention_pallas(q, k, v, pos, interpret=interp)


def rac_value_raw(tsi, tid, tp_last, t_last, alpha: float, t_now: int, *,
                  use_pallas: bool = True, interpret: bool | None = None):
    """Un-jitted RAC Eq.1 body shared by :func:`rac_value` and the sharded
    backend (per-shard scoring of a chunk of the resident table)."""
    if not use_pallas:
        return ref.rac_value_ref(tsi, tid, tp_last, t_last, alpha, t_now)
    interp = _is_cpu() if interpret is None else interpret
    n = tsi.shape[0]
    tp = _pad_to(tsi.astype(jnp.float32), 0, _RV_BN)
    ti = _pad_to(tid.astype(jnp.int32), 0, _RV_BN)
    out = rac_value_pallas(tp, ti, tp_last, t_last, alpha, t_now,
                           interpret=interp)
    return out[:n]


@_counted
@functools.partial(jax.jit, static_argnames=("alpha", "t_now", "use_pallas",
                                             "interpret"))
def rac_value(tsi, tid, tp_last, t_last, alpha: float, t_now: int, *,
              use_pallas: bool = True, interpret: bool | None = None):
    """RAC Eq.1 scoring over the resident table."""
    return rac_value_raw(tsi, tid, tp_last, t_last, alpha, t_now,
                         use_pallas=use_pallas, interpret=interpret)


def victim_value_raw(tsi, tid, occ, tp_last, t_last, t_now, *, alpha: float,
                     use_pallas: bool = True, interpret: bool | None = None):
    """Un-jitted occupancy-masked Eq.1 body shared by :func:`victim_value`,
    :func:`fused_decide`, and the sharded backend (per-shard scoring of its
    slice of the slot table).  ``t_now`` may be a traced int32 scalar —
    unlike :func:`rac_value`'s static ``t_now=0`` + host timestamp shift,
    the decision path keeps the uploaded ``t_last`` table fixed and lets
    simulation time advance at runtime."""
    if not use_pallas:
        return ref.victim_value_ref(tsi, tid, occ, tp_last, t_last,
                                    t_now, alpha)
    interp = _is_cpu() if interpret is None else interpret
    n = tsi.shape[0]
    ts = _pad_to(tsi.astype(jnp.float32), 0, _VV_BN)
    ti = _pad_to(tid.astype(jnp.int32), 0, _VV_BN)
    oc = _pad_to(occ.astype(jnp.int32), 0, _VV_BN)      # pad rows score +inf
    out = victim_value_pallas(ts, ti, oc, tp_last, t_last, t_now, alpha,
                              interpret=interp)
    return out[:n]


@_counted
@functools.partial(jax.jit, static_argnames=("alpha", "use_pallas",
                                             "interpret"))
def victim_value(tsi, tid, occ, tp_last, t_last, t_now, *, alpha: float,
                 use_pallas: bool = True, interpret: bool | None = None):
    """Occupancy-masked RAC Eq.1 over the fixed-shape slot table with a
    runtime ``t_now`` (free slots score +inf)."""
    return victim_value_raw(tsi, tid, occ, tp_last, t_last,
                            jnp.int32(t_now), alpha=alpha,
                            use_pallas=use_pallas, interpret=interpret)


@_counted
@functools.partial(jax.jit, static_argnames=("alpha", "use_pallas",
                                             "interpret"))
def victim_value_multi(tsi, tid, occ, tp_last, t_last, t_now, *,
                       alpha: float, use_pallas: bool = True,
                       interpret: bool | None = None):
    """Policy-stacked occupancy-masked Eq.1: the victim-score leg of the
    arena's batched-over-policy decision surface.

    ``tsi``/``tid``/``occ`` are ``(P, N)`` slot tables, ``tp_last``/
    ``t_last`` ``(P, T)`` topic tables; returns ``(P, N)`` victim values
    (free slots ``+inf``) from one dispatch — the multi-policy analogue of
    :func:`victim_value`, for policy sets whose eviction scoring is
    table-driven (stacked RAC variants)."""
    if not use_pallas:
        return jax.vmap(
            lambda a, b, c, d, e: ref.victim_value_ref(
                a, b, c, d, e, jnp.int32(t_now), alpha)
        )(tsi, tid, occ, tp_last, t_last)
    interp = _is_cpu() if interpret is None else interpret
    n = tsi.shape[1]
    ts = _pad_to(tsi.astype(jnp.float32), 1, _VV_BN)
    ti = _pad_to(tid.astype(jnp.int32), 1, _VV_BN)
    oc = _pad_to(occ.astype(jnp.int32), 1, _VV_BN)      # pad rows score +inf
    out = victim_value_multi_pallas(ts, ti, oc,
                                    tp_last.astype(jnp.float32),
                                    t_last.astype(jnp.int32),
                                    jnp.int32(t_now), alpha,
                                    interpret=interp)
    return out[:, :n]


def fused_decide_raw(queries, slab, n_valid, reps, n_topics, tsi, tid, occ,
                     tp_last, t_last, t_now, *, alpha: float,
                     use_pallas: bool = True, interpret: bool | None = None):
    """Un-jitted fused decision body (also run per shard by the sharded
    backend): hit Top-1 + routing Top-1 + masked victim values."""
    hit_vals, hit_idx = sim_top1_raw(queries, slab, n_valid,
                                     use_pallas=use_pallas,
                                     interpret=interpret)
    route_vals, route_idx = sim_top1_raw(queries, reps, n_topics,
                                         use_pallas=use_pallas,
                                         interpret=interpret)
    victim = victim_value_raw(tsi, tid, occ, tp_last, t_last, t_now,
                              alpha=alpha, use_pallas=use_pallas,
                              interpret=interpret)
    return hit_vals, hit_idx, route_vals, route_idx, victim


@_counted
@functools.partial(jax.jit, static_argnames=("alpha", "use_pallas",
                                             "interpret"))
def fused_decide(queries, slab, n_valid, reps, n_topics, tsi, tid, occ,
                 tp_last, t_last, t_now, *, alpha: float,
                 use_pallas: bool = True, interpret: bool | None = None):
    """One fused decision dispatch per replay chunk.

    Composes ``sim_top1`` over the resident slab (hit determination, masked
    to the runtime resident count ``n_valid``), ``sim_top1`` over the dense
    topic-representative table (Alg. 4 routing, masked to the runtime topic
    high-water mark ``n_topics``), and the occupancy-masked Eq. 1 victim
    kernel — all under one jit, so a replay chunk costs one host→device
    round-trip regardless of chunk size or fill level."""
    return fused_decide_raw(queries, slab, jnp.int32(n_valid), reps,
                            jnp.int32(n_topics), tsi, tid, occ, tp_last,
                            t_last, jnp.int32(t_now), alpha=alpha,
                            use_pallas=use_pallas, interpret=interpret)


@_counted
@functools.partial(jax.jit, static_argnames=("alpha", "t_now", "use_pallas",
                                             "interpret"))
def rac_value_masked(tsi, tid, tp_last, t_last, valid, alpha: float,
                     t_now: int, *, use_pallas: bool = True,
                     interpret: bool | None = None):
    """RAC Eq.1 over a block table with a structural-validity mask.

    ``valid`` (bool, same shape as ``tsi``) marks entries that are legal
    eviction victims; invalid rows (e.g. radix blocks with live children,
    or the chain tip currently being extended) score ``+inf`` so a
    min-value victim scan can never elect them.  One fused jit: the Eq.1
    kernel plus the mask select, no host round-trip between them."""
    vals = rac_value_raw(tsi, tid, tp_last, t_last, alpha, t_now,
                         use_pallas=use_pallas, interpret=interpret)
    return jnp.where(valid, vals, jnp.inf)


@_counted
@functools.partial(jax.jit, static_argnames=("alpha", "use_pallas",
                                             "interpret"))
def decide_aux(queries, reps, n_topics, tsi, tid, occ, tp_last, t_last,
               t_now, *, alpha: float, use_pallas: bool = True,
               interpret: bool | None = None):
    """Auxiliary decision legs in one dispatch: routing Top-1 over the
    dense topic-representative table plus the occupancy-masked Eq.1 victim
    values.

    The approximate-lookup decide path can't use :func:`fused_decide` (its
    hit leg comes from the quantized/pruned pipeline instead of a dense
    ``sim_top1``), but its remaining legs — Alg. 4 routing and victim
    scoring — still fuse, so a decide chunk costs the fused-lookup launch
    plus exactly one aux launch instead of two separate dispatches."""
    route_vals, route_idx = sim_top1_raw(queries, reps, jnp.int32(n_topics),
                                         use_pallas=use_pallas,
                                         interpret=interpret)
    victim = victim_value_raw(tsi, tid, occ, tp_last, t_last,
                              jnp.int32(t_now), alpha=alpha,
                              use_pallas=use_pallas, interpret=interpret)
    return route_vals, route_idx, victim
