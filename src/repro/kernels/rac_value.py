"""Pallas TPU kernel: vectorized RAC eviction scoring (Eq. 1).

Computes  value[i] = TP_now(topic[i]) · TSI[i]  over all resident entries,
where  TP_now(s) = 2^(−α·(t_now − t_last(s))) · TP_last(s)  is the lazy
closed form of Def. 1.  The per-topic TP table stays VMEM-resident (topics
≤ a few thousand) and is gathered per entry tile; entries stream in tiles
of BN.  This is the device-side half of the policy — the block-manager
scores a whole block table in one call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BN = 1024     # entries per tile


def _rac_value_kernel(tsi_ref, tid_ref, tp_ref, tl_ref, out_ref, *,
                      alpha: float, t_now: int):
    tsi = tsi_ref[...]
    tid = tid_ref[...]
    tp_last = jnp.take(tp_ref[...], tid, axis=0)
    t_last = jnp.take(tl_ref[...], tid, axis=0)
    decay = jnp.exp2(-alpha * (t_now - t_last).astype(jnp.float32))
    out_ref[...] = decay * tp_last * tsi


def rac_value_pallas(tsi: jnp.ndarray, tid: jnp.ndarray,
                     tp_last: jnp.ndarray, t_last: jnp.ndarray,
                     alpha: float, t_now: int, *, interpret: bool = True):
    """tsi (N,) f32; tid (N,) i32; tp_last/t_last (T,) topic tables.
    N must be a BN multiple (pad tsi with 0 / tid with 0)."""
    n = tsi.shape[0]
    t = tp_last.shape[0]
    assert n % BN == 0
    kernel = functools.partial(_rac_value_kernel, alpha=alpha, t_now=t_now)
    return pl.pallas_call(
        kernel,
        grid=(n // BN,),
        in_specs=[pl.BlockSpec((BN,), lambda i: (i,)),
                  pl.BlockSpec((BN,), lambda i: (i,)),
                  pl.BlockSpec((t,), lambda i: (0,)),
                  pl.BlockSpec((t,), lambda i: (0,))],
        out_specs=pl.BlockSpec((BN,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(tsi, tid, tp_last.astype(jnp.float32), t_last.astype(jnp.float32))
