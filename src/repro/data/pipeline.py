"""Deterministic sharded token pipeline with an explicit restart cursor.

Production shape: each data-parallel host owns a disjoint shard of the
corpus and derives every batch purely from (seed, cursor) — no hidden
iterator state — so a restart from a checkpointed cursor replays the exact
same batch stream on any surviving host layout (elastic restart re-shards
by recomputing ``host_slice`` from the new topology).

Offline we synthesize a corpus (mixture of Zipf unigrams + repeated n-gram
'phrases' so the LM has learnable structure); swapping in a real tokenized
corpus only replaces ``_token_block``.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host: int = 0
    corpus_tokens: int = 1 << 24     # synthetic corpus size


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.n_hosts
        # synthetic corpus structure: phrase table + unigram dist
        rng = np.random.default_rng(cfg.seed)
        self._phrases = rng.integers(
            2, cfg.vocab_size, size=(256, 8)).astype(np.int32)
        w = 1.0 / np.arange(1, cfg.vocab_size + 1) ** 1.1
        self._probs = w / w.sum()

    # -- deterministic content ---------------------------------------
    def _token_block(self, block_idx: int) -> np.ndarray:
        """seq_len+1 tokens for global block ``block_idx`` (pure function)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, 7, block_idx]))
        out = np.empty(cfg.seq_len + 1, np.int32)
        i = 0
        while i < cfg.seq_len + 1:
            if rng.random() < 0.3:          # repeated phrase (learnable)
                ph = self._phrases[rng.integers(0, len(self._phrases))]
                n = min(len(ph), cfg.seq_len + 1 - i)
                out[i:i + n] = ph[:n]
                i += n
            else:
                n = min(int(rng.integers(4, 16)), cfg.seq_len + 1 - i)
                out[i:i + n] = rng.choice(
                    cfg.vocab_size, size=n, p=self._probs)
                i += n
        return out

    def batch_at(self, cursor: int) -> dict[str, np.ndarray]:
        """Global step ``cursor`` -> this host's {tokens, labels} slice."""
        cfg = self.cfg
        base = cursor * cfg.global_batch + self.cfg.host * self.local_batch
        blocks = np.stack([self._token_block(base + i)
                           for i in range(self.local_batch)])
        return {"tokens": blocks[:, :-1], "labels": blocks[:, 1:]}

    def __iter__(self):
        c = 0
        while True:
            yield c, self.batch_at(c)
            c += 1
