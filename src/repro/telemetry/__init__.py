"""Unified telemetry subsystem: pluggable trackers, mergeable metric
primitives, and request-path tracing for the cache/serving stack.

Every layer emits through one :class:`Tracker` interface
(:mod:`~repro.telemetry.tracker`): counters, gauges, histogram
observations (log-bucket, shard-mergeable, p50/p95/p99 —
:mod:`~repro.telemetry.metrics`), windowed time series (hit-ratio /
occupancy / promotion-rate over time), spans with Chrome trace-event
export (:mod:`~repro.telemetry.tracing`), and scoped child trackers for
consistent naming across layers.  :mod:`~repro.telemetry.report` renders
text/JSON summaries for benchmarks and CI.

Telemetry is strictly observation-only: cache decisions with any tracker
attached are bit-identical to :data:`NOOP` (and to no tracker at all) —
enforced by the parity test in ``tests/test_telemetry.py`` — and the
no-op hot-path overhead is bounded by
``benchmarks/telemetry_overhead_bench.py``.

Wire-up (see ``docs/observability.md`` for the metric naming scheme)::

    from repro.cache import CacheConfig, SemanticCache
    from repro.telemetry import InMemoryTracker

    trk = InMemoryTracker(window=256)
    cache = SemanticCache(CacheConfig(capacity=512, dim=64, tracker=trk))
    ...
    print(trk.percentiles("cache.lookup_s"))   # {'p50': ..., 'p99': ...}
    print(trk.series("cache.hit"))             # hit-ratio over time
    trk.export_chrome("trace.json")            # chrome://tracing
"""
from .metrics import Histogram, MetricsRegistry, WindowedSeries
from .report import render_text, summarize, write_report
from .tracing import TraceBuffer, annotate, next_trace_id
from .tracker import (NOOP, CompositeTracker, InMemoryTracker, JsonlTracker,
                      NoopTracker, Tracker, make_tracker)

__all__ = [
    "Tracker", "NoopTracker", "NOOP", "InMemoryTracker", "JsonlTracker",
    "CompositeTracker", "make_tracker",
    "Histogram", "WindowedSeries", "MetricsRegistry",
    "TraceBuffer", "annotate", "next_trace_id",
    "summarize", "render_text", "write_report",
]
