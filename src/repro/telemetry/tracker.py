"""Pluggable tracker interface — the one observability surface every
layer of the cache/serving stack emits through.

The shape follows levanter's ``Tracker``: a small abstract emitter API
(counters, gauges, histogram observations, spans, scoped children) with
concrete sinks behind it —

  - :class:`NoopTracker` (and the shared :data:`NOOP` instance): every
    method is a ``pass``; attaching it must be observationally *and*
    decision-wise identical to attaching nothing (enforced by the parity
    test in ``tests/test_telemetry.py`` and the overhead bound in
    ``benchmarks/telemetry_overhead_bench.py``).
  - :class:`InMemoryTracker`: accumulates into a
    :class:`~repro.telemetry.metrics.MetricsRegistry` (log-bucket
    histograms, windowed series) plus a
    :class:`~repro.telemetry.tracing.TraceBuffer` for spans — the sink
    benchmarks and tests read back.
  - :class:`JsonlTracker`: streams every record as one JSON line to a
    file (the ``--tracker jsonl:<path>`` benchmark flag), buffered and
    thread-safe.
  - :class:`CompositeTracker`: fans every record out to child trackers.

Scoping: ``tracker.child("backend")`` returns a view that prefixes every
metric name with ``backend.`` — the facade hands the device backends and
the tier manager scoped children of its own tracker, so one sink sees
the whole stack under a consistent naming scheme (see
``docs/observability.md`` for the scheme).

Trackers are observation-only sinks: they are shared, not copied, by
``copy.deepcopy`` (``__deepcopy__`` returns ``self``), so a facade
``checkpoint()`` never clones a file handle or a half-filled registry.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from typing import Any, Optional, Sequence

from .metrics import MetricsRegistry
from .tracing import TraceBuffer

__all__ = ["Tracker", "NoopTracker", "NOOP", "InMemoryTracker",
           "JsonlTracker", "CompositeTracker", "make_tracker"]

_NULL_SPAN = contextlib.nullcontext()       # reusable & reentrant


class Tracker:
    """Abstract emitter interface (all methods default to no-ops).

    ``tags`` are optional low-cardinality labels (e.g. ``{"tier":
    "host"}``); sinks may fold them into the name or record them
    verbatim.  ``observe(..., t=...)`` additionally feeds a windowed
    time series keyed by ``t`` (logical request time or wall seconds) —
    that is how hit-ratio-over-time and occupancy-over-time are built.
    """

    def count(self, name: str, n: float = 1,
              tags: Optional[dict] = None) -> None:
        pass

    def gauge(self, name: str, value: float,
              tags: Optional[dict] = None) -> None:
        pass

    def observe(self, name: str, value: float, t: Optional[float] = None,
                tags: Optional[dict] = None) -> None:
        pass

    def span(self, name: str, tags: Optional[dict] = None):
        """Context manager timing a scoped operation."""
        return _NULL_SPAN

    def add_span(self, name: str, t0: float, t1: float, *, track: int = 0,
                 tags: Optional[dict] = None) -> None:
        """Record a span whose endpoints the caller already stamped
        (``time.perf_counter`` seconds)."""
        pass

    def child(self, prefix: str) -> "Tracker":
        """A scoped view prefixing every metric/span name."""
        return _ScopedTracker(self, prefix)

    def percentiles(self, name: str) -> Optional[dict]:
        """p50/p95/p99 for a histogram, or None when this sink (or the
        name) has no distribution."""
        return None

    def snapshot(self) -> dict:
        return {}

    def flush(self) -> None:
        pass

    def close(self) -> None:
        self.flush()

    # observation-only sink: checkpoint deep copies share it, never clone
    def __deepcopy__(self, memo) -> "Tracker":
        return self


class NoopTracker(Tracker):
    """Explicit no-op sink; ``child`` returns itself (no wrapper cost)."""

    def child(self, prefix: str) -> "NoopTracker":
        return self


NOOP = NoopTracker()


class _ScopedTracker(Tracker):
    """Name-prefixing view over a parent tracker."""

    def __init__(self, base: Tracker, prefix: str):
        self._base = base
        self._prefix = prefix.rstrip(".") + "."

    def count(self, name, n=1, tags=None):
        self._base.count(self._prefix + name, n, tags)

    def gauge(self, name, value, tags=None):
        self._base.gauge(self._prefix + name, value, tags)

    def observe(self, name, value, t=None, tags=None):
        self._base.observe(self._prefix + name, value, t, tags)

    def span(self, name, tags=None):
        return self._base.span(self._prefix + name, tags)

    def add_span(self, name, t0, t1, *, track=0, tags=None):
        self._base.add_span(self._prefix + name, t0, t1, track=track,
                            tags=tags)

    def percentiles(self, name):
        return self._base.percentiles(self._prefix + name)

    def snapshot(self):
        return self._base.snapshot()

    def flush(self):
        self._base.flush()

    def close(self):                        # scoped views never own the sink
        self._base.flush()


def _tagged(name: str, tags: Optional[dict]) -> str:
    """Fold low-cardinality tags into the metric name (``name{k=v}``),
    sorted for a stable key."""
    if not tags:
        return name
    inner = ",".join(f"{k}={tags[k]}" for k in sorted(tags))
    return f"{name}{{{inner}}}"


class InMemoryTracker(Tracker):
    """Registry + trace-buffer sink (the read-back tracker).

    ``window`` sets the windowed-series bucket width (logical-time units
    for cache series).  All emitters are thread-safe: the async admission
    worker and the request path may emit concurrently.
    """

    def __init__(self, window: int = 256, max_events: int = 100_000):
        self.registry = MetricsRegistry(window=window)
        self.trace = TraceBuffer(max_events=max_events)
        self._lock = threading.Lock()

    def count(self, name, n=1, tags=None):
        with self._lock:
            self.registry.inc(_tagged(name, tags), n)

    def gauge(self, name, value, tags=None):
        with self._lock:
            self.registry.set_gauge(_tagged(name, tags), value)

    def observe(self, name, value, t=None, tags=None):
        key = _tagged(name, tags)
        with self._lock:
            self.registry.observe(key, value)
            if t is not None:
                self.registry.record(key, t, value)

    def span(self, name, tags=None):
        return self.trace.span(name, tags=tags)

    def add_span(self, name, t0, t1, *, track=0, tags=None):
        self.trace.add_span(name, t0, t1, track=track, tags=tags)

    def percentiles(self, name):
        with self._lock:
            h = self.registry.histograms.get(name)
            return None if h is None else h.percentiles()

    def series(self, name) -> list[dict]:
        with self._lock:
            s = self.registry.series.get(name)
            return [] if s is None else s.series()

    def counter(self, name) -> float:
        with self._lock:
            return self.registry.counters.get(name, 0)

    def snapshot(self):
        with self._lock:
            return self.registry.snapshot()

    def export_chrome(self, path: str) -> str:
        return self.trace.export_chrome(path)


class JsonlTracker(Tracker):
    """Streams one JSON line per record to ``path`` (append mode).

    Lines are ``{"kind": "count"|"gauge"|"observe"|"span", "name": ...,
    ...}``; ``wall`` stamps ``time.time()`` so runs interleave sensibly.
    Writes are buffered (``buffer`` lines) and flushed on ``flush``/
    ``close``; the file opens lazily on first record.
    """

    def __init__(self, path: str, buffer: int = 256):
        self.path = path
        self._buffer_n = max(1, int(buffer))
        self._lines: list[str] = []
        self._fh = None
        self._lock = threading.Lock()

    def _write(self, rec: dict) -> None:
        rec["wall"] = time.time()
        with self._lock:
            self._lines.append(json.dumps(rec))
            if len(self._lines) >= self._buffer_n:
                self._flush_locked()

    def _flush_locked(self) -> None:
        if not self._lines:
            return
        if self._fh is None:
            self._fh = open(self.path, "a")
        self._fh.write("\n".join(self._lines) + "\n")
        self._fh.flush()
        self._lines.clear()

    def count(self, name, n=1, tags=None):
        self._write({"kind": "count", "name": name, "n": n,
                     **({"tags": tags} if tags else {})})

    def gauge(self, name, value, tags=None):
        self._write({"kind": "gauge", "name": name, "value": value,
                     **({"tags": tags} if tags else {})})

    def observe(self, name, value, t=None, tags=None):
        self._write({"kind": "observe", "name": name, "value": value,
                     **({"t": t} if t is not None else {}),
                     **({"tags": tags} if tags else {})})

    @contextlib.contextmanager
    def _timed_span(self, name, tags):
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            self.add_span(name, t0, time.perf_counter(), tags=tags)

    def span(self, name, tags=None):
        return self._timed_span(name, tags)

    def add_span(self, name, t0, t1, *, track=0, tags=None):
        self._write({"kind": "span", "name": name, "t0": t0,
                     "dur_s": max(0.0, t1 - t0), "track": track,
                     **({"tags": tags} if tags else {})})

    def flush(self):
        with self._lock:
            self._flush_locked()

    def close(self):
        with self._lock:
            self._flush_locked()
            if self._fh is not None:
                self._fh.close()
                self._fh = None


class CompositeTracker(Tracker):
    """Fans every record out to a list of child trackers."""

    def __init__(self, parts: Sequence[Tracker]):
        self.parts = list(parts)

    def count(self, name, n=1, tags=None):
        for p in self.parts:
            p.count(name, n, tags)

    def gauge(self, name, value, tags=None):
        for p in self.parts:
            p.gauge(name, value, tags)

    def observe(self, name, value, t=None, tags=None):
        for p in self.parts:
            p.observe(name, value, t, tags)

    @contextlib.contextmanager
    def _multi_span(self, name, tags):
        with contextlib.ExitStack() as stack:
            for p in self.parts:
                stack.enter_context(p.span(name, tags))
            yield self

    def span(self, name, tags=None):
        return self._multi_span(name, tags)

    def add_span(self, name, t0, t1, *, track=0, tags=None):
        for p in self.parts:
            p.add_span(name, t0, t1, track=track, tags=tags)

    def percentiles(self, name):
        for p in self.parts:
            out = p.percentiles(name)
            if out is not None:
                return out
        return None

    def snapshot(self):
        out: dict = {}
        for p in self.parts:
            snap = p.snapshot()
            if snap:
                out[type(p).__name__] = snap
        return out

    def flush(self):
        for p in self.parts:
            p.flush()

    def close(self):
        for p in self.parts:
            p.close()


def make_tracker(spec: Any, window: int = 256) -> Optional[Tracker]:
    """Resolve a tracker spec: ``None``/``""`` → None (telemetry off),
    a :class:`Tracker` instance passes through, and strings select a
    sink — ``"noop"``, ``"memory"``, ``"jsonl:<path>"`` — with ``+``
    composing several (``"memory+jsonl:/tmp/t.jsonl"``)."""
    if spec is None or spec == "":
        return None
    if isinstance(spec, Tracker):
        return spec
    if not isinstance(spec, str):
        raise ValueError(f"expected a Tracker or spec string, got {spec!r}")
    parts = []
    for item in spec.split("+"):
        item = item.strip()
        if item == "noop":
            parts.append(NOOP)
        elif item in ("memory", "mem"):
            parts.append(InMemoryTracker(window=window))
        elif item.startswith("jsonl:"):
            parts.append(JsonlTracker(item[len("jsonl:"):]))
        else:
            raise ValueError(
                f"unknown tracker spec {item!r}; expected 'noop', "
                f"'memory', or 'jsonl:<path>' (combine with '+')")
    return parts[0] if len(parts) == 1 else CompositeTracker(parts)
