"""Summary rendering for tracker snapshots — text for humans/CI logs,
JSON for ``bench_results`` artifacts.

``summarize(tracker)`` collapses an :class:`~repro.telemetry.tracker.
InMemoryTracker` (or any tracker exposing ``snapshot()``) into one
JSON-serializable dict; ``render_text`` pretty-prints it with aligned
columns and SI-ish latency units; ``write_report`` does both to disk.
Benchmarks use these so every suite reports through the same surface
instead of hand-formatting its own rows.
"""
from __future__ import annotations

import json
import math
from typing import Optional

from .tracker import Tracker

__all__ = ["summarize", "render_text", "write_report"]


def summarize(tracker: Tracker) -> dict:
    """One JSON-serializable summary dict for a tracker's accumulated
    state (empty sections are dropped)."""
    snap = tracker.snapshot() or {}
    return {k: v for k, v in snap.items() if v}


def _fmt_seconds(v: float) -> str:
    if v != v:                               # nan
        return "nan"
    if v >= 1.0:
        return f"{v:.3f}s"
    if v >= 1e-3:
        return f"{v * 1e3:.2f}ms"
    return f"{v * 1e6:.1f}us"


def _fmt(v: float) -> str:
    if isinstance(v, float) and not v.is_integer():
        return f"{v:.4g}"
    return str(int(v)) if isinstance(v, (int, float)) else str(v)


def render_text(snapshot: dict, title: str = "telemetry",
                series_tail: int = 6) -> str:
    """Aligned text rendering of a ``summarize``/``snapshot`` dict.

    Histograms print count/mean/p50/p95/p99 (latency-formatted — the
    stack's histograms are second-valued timings); series print the last
    ``series_tail`` windows as ``t:mean`` pairs.
    """
    lines = [f"== {title} =="]
    counters = snapshot.get("counters", {})
    if counters:
        lines.append("-- counters")
        width = max(len(k) for k in counters)
        for k in sorted(counters):
            lines.append(f"  {k:<{width}}  {_fmt(counters[k])}")
    gauges = snapshot.get("gauges", {})
    if gauges:
        lines.append("-- gauges")
        width = max(len(k) for k in gauges)
        for k in sorted(gauges):
            lines.append(f"  {k:<{width}}  {_fmt(gauges[k])}")
    hists = snapshot.get("histograms", {})
    if hists:
        lines.append("-- histograms (count mean p50 p95 p99)")
        width = max(len(k) for k in hists)
        for k in sorted(hists):
            h = hists[k]
            lines.append(
                f"  {k:<{width}}  n={h['count']}"
                f" mean={_fmt_seconds(h['mean'])}"
                f" p50={_fmt_seconds(h['p50'])}"
                f" p95={_fmt_seconds(h['p95'])}"
                f" p99={_fmt_seconds(h['p99'])}")
    series = snapshot.get("series", {})
    if series:
        lines.append(f"-- series (last {series_tail} windows, t:mean)")
        width = max(len(k) for k in series)
        for k in sorted(series):
            tail = series[k][-series_tail:]
            vals = " ".join(f"{row['t']}:{row['mean']:.3f}" for row in tail)
            lines.append(f"  {k:<{width}}  {vals}")
    if len(lines) == 1:
        lines.append("  (empty)")
    return "\n".join(lines)


def write_report(tracker: Tracker, json_path: Optional[str] = None,
                 title: str = "telemetry") -> str:
    """Summarize ``tracker``; optionally persist the JSON summary; return
    the text rendering (callers print it)."""
    summary = summarize(tracker)
    if json_path is not None:
        with open(json_path, "w") as f:
            json.dump(summary, f, indent=1, default=_json_default)
    return render_text(summary, title=title)


def _json_default(v):
    if isinstance(v, float) and (math.isnan(v) or math.isinf(v)):
        return None
    raise TypeError(f"not JSON serializable: {type(v)}")
