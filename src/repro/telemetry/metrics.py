"""Mergeable metric primitives behind the tracker interface.

Three shapes cover everything the cache/serving stack reports:

  - :class:`Histogram` — log-bucket latency/size distribution.  Buckets
    grow geometrically (default ``2**(1/4)``, ≤ ~9% relative error per
    bucket), so the whole dynamic range from sub-microsecond enqueues to
    multi-second flush waits fits in a small dict.  Quantile estimation
    (p50/p95/p99) reads the cumulative bucket counts; ``merge`` adds two
    histograms bucket-by-bucket, which is what makes per-shard (or
    per-process) collection composable.
  - :class:`WindowedSeries` — a value aggregated per fixed-width window of
    a (logical or wall) time axis: hit-ratio-over-time is the windowed
    mean of 0/1 hit observations, occupancy-over-time the windowed mean
    of the resident count, promotion rate the windowed count.  Windows
    are keyed sparsely, so long idle stretches cost nothing.
  - :class:`MetricsRegistry` — the named surface over both plus plain
    counters and gauges; :class:`~repro.telemetry.tracker.InMemoryTracker`
    owns one.  Registries merge (shard-mergeable: disjoint or overlapping
    name sets both compose), and ``snapshot()`` renders one nested dict
    for reports and CI assertions.

Nothing in this module imports jax or numpy — the metric path must stay
importable (and cheap) for host-only consumers.
"""
from __future__ import annotations

import math
from typing import Optional

__all__ = ["Histogram", "WindowedSeries", "MetricsRegistry"]

# default bucket growth: 4 buckets per octave -> worst-case relative
# quantile error of sqrt(growth) ~ 9%
_DEFAULT_GROWTH = 2.0 ** 0.25


class Histogram:
    """Log-bucket histogram with exact count/sum/min/max and estimated
    quantiles.

    Observations ``v > 0`` land in bucket ``floor(log(v)/log(growth))``;
    zero and negative observations (a timer that underflowed the clock
    resolution) are counted in a dedicated zero bucket that sorts below
    every log bucket.  Two histograms with the same ``growth`` merge by
    adding bucket counts — the shard-mergeable property the registry and
    the composite tracker rely on.
    """

    __slots__ = ("growth", "_log_g", "buckets", "zeros", "count", "total",
                 "vmin", "vmax")

    def __init__(self, growth: float = _DEFAULT_GROWTH):
        if growth <= 1.0:
            raise ValueError(f"growth must be > 1, got {growth}")
        self.growth = float(growth)
        self._log_g = math.log(self.growth)
        self.buckets: dict[int, int] = {}
        self.zeros = 0
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.total += value
        if value < self.vmin:
            self.vmin = value
        if value > self.vmax:
            self.vmax = value
        if value <= 0.0:
            self.zeros += 1
            return
        b = math.floor(math.log(value) / self._log_g)
        self.buckets[b] = self.buckets.get(b, 0) + 1

    def merge(self, other: "Histogram") -> "Histogram":
        if abs(other.growth - self.growth) > 1e-12:
            raise ValueError("cannot merge histograms with different growth "
                             f"({self.growth} vs {other.growth})")
        for b, n in other.buckets.items():
            self.buckets[b] = self.buckets.get(b, 0) + n
        self.zeros += other.zeros
        self.count += other.count
        self.total += other.total
        self.vmin = min(self.vmin, other.vmin)
        self.vmax = max(self.vmax, other.vmax)
        return self

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else math.nan

    def quantile(self, q: float) -> float:
        """Estimated ``q``-quantile (0 <= q <= 1): the geometric midpoint
        of the bucket holding the target rank, clamped to the exact
        observed [min, max]."""
        if self.count == 0:
            return math.nan
        target = q * self.count
        seen = self.zeros
        if seen >= target and self.zeros:
            return max(0.0, self.vmin)
        for b in sorted(self.buckets):
            seen += self.buckets[b]
            if seen >= target:
                mid = self.growth ** (b + 0.5)
                return min(max(mid, self.vmin), self.vmax)
        return self.vmax

    def percentiles(self) -> dict:
        return {"p50": self.quantile(0.50), "p95": self.quantile(0.95),
                "p99": self.quantile(0.99)}

    def snapshot(self) -> dict:
        return {"count": self.count, "mean": self.mean,
                "min": self.vmin if self.count else math.nan,
                "max": self.vmax if self.count else math.nan,
                **self.percentiles()}


class WindowedSeries:
    """A value aggregated over fixed-width windows of a time axis.

    ``add(t, v)`` folds ``v`` into window ``t // window``; windows are
    sparse (a dict keyed by window index).  ``series()`` renders the
    ordered list of per-window rows — ``mean`` is hit-ratio when the
    observations are 0/1 hit indicators, occupancy when they are resident
    counts, and ``count``/``sum`` give windowed rates.  Merging adds
    window aggregates pairwise, so per-shard series compose exactly.
    """

    __slots__ = ("window", "_sum", "_count")

    def __init__(self, window: int = 256):
        self.window = max(1, int(window))
        self._sum: dict[int, float] = {}
        self._count: dict[int, int] = {}

    def add(self, t: float, value: float) -> None:
        k = int(t) // self.window
        self._sum[k] = self._sum.get(k, 0.0) + float(value)
        self._count[k] = self._count.get(k, 0) + 1

    def merge(self, other: "WindowedSeries") -> "WindowedSeries":
        if other.window != self.window:
            raise ValueError("cannot merge series with different windows "
                             f"({self.window} vs {other.window})")
        for k, s in other._sum.items():
            self._sum[k] = self._sum.get(k, 0.0) + s
            self._count[k] = self._count.get(k, 0) + other._count[k]
        return self

    def __len__(self) -> int:
        return len(self._sum)

    def series(self) -> list[dict]:
        return [{"t": k * self.window, "mean": self._sum[k] / self._count[k],
                 "sum": self._sum[k], "count": self._count[k]}
                for k in sorted(self._sum)]


class MetricsRegistry:
    """Named counters, gauges, histograms, and windowed series.

    The single metrics surface an :class:`~repro.telemetry.tracker.
    InMemoryTracker` accumulates into.  All accessors create-on-first-use
    so emitters never pre-register; ``merge`` composes registries from
    shards/processes; ``snapshot`` renders the nested report dict.
    """

    def __init__(self, window: int = 256,
                 growth: float = _DEFAULT_GROWTH):
        self.window = max(1, int(window))
        self.growth = float(growth)
        self.counters: dict[str, float] = {}
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, Histogram] = {}
        self.series: dict[str, WindowedSeries] = {}

    # ------------------------------------------------------------ emitters
    def inc(self, name: str, n: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def set_gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        self.histogram(name).observe(value)

    def record(self, name: str, t: float, value: float) -> None:
        self.get_series(name).add(t, value)

    # ------------------------------------------------------------ accessors
    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(self.growth)
        return h

    def get_series(self, name: str) -> WindowedSeries:
        s = self.series.get(name)
        if s is None:
            s = self.series[name] = WindowedSeries(self.window)
        return s

    # ------------------------------------------------------------- compose
    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        for k, v in other.counters.items():
            self.inc(k, v)
        self.gauges.update(other.gauges)          # last write wins
        for k, h in other.histograms.items():
            self.histogram(k).merge(h)
        for k, s in other.series.items():
            self.get_series(k).merge(s)
        return self

    def snapshot(self) -> dict:
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: h.snapshot()
                           for k, h in self.histograms.items()},
            "series": {k: s.series() for k, s in self.series.items()},
        }
