"""Span-based request-path tracing with Chrome trace-event export.

A :class:`TraceBuffer` records named spans — either live via the
``span()`` context manager (enter/exit stamps ``time.perf_counter``) or
retroactively via ``add_span(name, t0, t1)`` with timestamps the caller
already holds (the serving engine stamps request arrival/completion
itself).  ``to_chrome()`` renders the buffer as Chrome trace-event JSON
(the ``chrome://tracing`` / Perfetto ``traceEvents`` format), so a
serving run's request lifecycle — arrive → hit / queue → fill → complete
— loads straight into a trace viewer.

``annotate(name)`` is the kernel-launch passthrough: it returns a
``jax.profiler.TraceAnnotation`` when jax is importable (the span then
shows up inside XLA device traces captured with ``jax.profiler.trace``)
and a no-op context otherwise, so host-only consumers never pay a jax
import.  Device backends wrap their fused launches in it.

Trace ids are process-monotonic ints from :func:`next_trace_id` —
decisions must never depend on telemetry, so ids come from a counter,
not a random source.
"""
from __future__ import annotations

import contextlib
import itertools
import json
import threading
import time
from typing import Optional

__all__ = ["TraceBuffer", "annotate", "next_trace_id"]

_trace_ids = itertools.count(1)


def next_trace_id() -> int:
    """Monotonic per-process trace/request id (deterministic, not random)."""
    return next(_trace_ids)


def annotate(name: str):
    """``jax.profiler.TraceAnnotation`` passthrough around kernel launches;
    degrades to a no-op context when jax is unavailable."""
    try:
        import jax
        return jax.profiler.TraceAnnotation(name)
    except Exception:
        return contextlib.nullcontext()


class TraceBuffer:
    """Bounded in-memory span store with Chrome trace-event export.

    Spans are ``(name, t0, dur, track, tags)`` with times in seconds on
    the ``time.perf_counter`` clock; export converts to the microsecond
    timestamps Chrome expects, relative to the buffer's construction
    origin.  ``max_events`` bounds memory on long runs (oldest spans are
    dropped in blocks; the drop count is reported in the export metadata
    so a truncated trace is never mistaken for a complete one).
    """

    def __init__(self, max_events: int = 100_000):
        self.origin = time.perf_counter()
        self.max_events = int(max_events)
        self.events: list[tuple] = []
        self.dropped = 0
        self._lock = threading.Lock()

    def __len__(self) -> int:
        return len(self.events)

    def add_span(self, name: str, t0: float, t1: float, *, track: int = 0,
                 tags: Optional[dict] = None) -> None:
        """Record one completed span (perf_counter seconds)."""
        with self._lock:
            self.events.append((name, t0, max(0.0, t1 - t0), track, tags))
            if len(self.events) > self.max_events:
                cut = max(1, self.max_events // 10)
                del self.events[:cut]
                self.dropped += cut

    @contextlib.contextmanager
    def span(self, name: str, *, track: int = 0, tags: Optional[dict] = None):
        t0 = time.perf_counter()
        try:
            yield self
        finally:
            self.add_span(name, t0, time.perf_counter(), track=track,
                          tags=tags)

    # ------------------------------------------------------------- export
    def to_chrome(self) -> dict:
        """Render as a Chrome trace-event JSON object (``traceEvents`` in
        the "X" complete-event form; load via chrome://tracing, Perfetto,
        or ``json.load``)."""
        with self._lock:
            events = list(self.events)
            dropped = self.dropped
        trace_events = [
            {"name": name, "cat": "repro", "ph": "X",
             "ts": (t0 - self.origin) * 1e6, "dur": dur * 1e6,
             "pid": 0, "tid": track, "args": dict(tags) if tags else {}}
            for name, t0, dur, track, tags in events]
        return {"traceEvents": trace_events, "displayTimeUnit": "ms",
                "otherData": {"dropped_spans": dropped}}

    def export_chrome(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path
