from .config import ModelConfig, ShapeConfig, SHAPES, smoke_variant
from .model import Model, build_model
from .steps import (make_decode_step, make_loss_fn, make_prefill_step,
                    make_train_step)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "smoke_variant", "Model",
           "build_model", "make_loss_fn", "make_train_step",
           "make_prefill_step", "make_decode_step"]
