"""State-space / recurrent blocks: Mamba-style selective SSM (hymba's
parallel heads) and xLSTM's mLSTM / sLSTM cells.

Training uses ``jax.lax.associative_scan`` (Mamba) or ``jax.lax.scan``
(xLSTM) over the sequence; decode is a single O(1) state update — the
property that makes these archs eligible for the ``long_500k`` shape.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.api import lc
from .config import ModelConfig


# ------------------------------------------------------------------ Mamba
def init_mamba(cfg: ModelConfig, rng) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    k = jax.random.split(rng, 7)
    s = 0.02
    return {
        "w_in": jax.random.normal(k[0], (d, 2 * di), cfg.pdtype) * s,
        "conv": jax.random.normal(k[1], (cfg.ssm_conv, di), cfg.pdtype) * s,
        "w_bc": jax.random.normal(k[2], (di, 2 * n), cfg.pdtype) * s,
        "w_dt": jax.random.normal(k[3], (di, di), cfg.pdtype) * (s / 4),
        "b_dt": jnp.full((di,), -4.6, cfg.pdtype),   # softplus^-1(0.01)
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": jax.random.normal(k[5], (di, d), cfg.pdtype) * s,
    }


def _mamba_core(p, cfg, xz, conv_state=None, ssm_state=None):
    """Shared pre-SSM computation.  xz: (B,S,2*di).  Returns scan inputs."""
    cd = cfg.cdtype
    di = cfg.ssm_expand * cfg.d_model
    x, z = xz[..., :di], xz[..., di:]
    # causal depthwise conv over seq
    kw = p["conv"].astype(cd)                       # (K, di)
    if conv_state is None:
        pad = jnp.pad(x, ((0, 0), (cfg.ssm_conv - 1, 0), (0, 0)))
        xc = sum(pad[:, i:i + x.shape[1]] * kw[i] for i in range(cfg.ssm_conv))
        new_conv = pad[:, -(cfg.ssm_conv - 1):] if cfg.ssm_conv > 1 else None
    else:
        # decode: conv_state (B, K-1, di) holds the previous inputs
        window = jnp.concatenate([conv_state.astype(cd), x], axis=1)
        xc = (window * kw[None]).sum(axis=1, keepdims=True)
        new_conv = window[:, 1:]
    xc = jax.nn.silu(xc)
    bc = jnp.einsum("bsd,dn->bsn", xc, p["w_bc"].astype(cd))
    b_ssm, c_ssm = jnp.split(bc, 2, axis=-1)        # (B,S,N) each
    dt = jax.nn.softplus(jnp.einsum("bsd,de->bse", xc, p["w_dt"].astype(cd))
                         + p["b_dt"].astype(cd))    # (B,S,di)
    a = -jnp.exp(p["a_log"])                        # (di, N) fp32
    return x, z, xc, b_ssm, c_ssm, dt, a, new_conv


def mamba_apply(p: dict, cfg: ModelConfig, x_in: jnp.ndarray,
                state: Optional[dict] = None):
    """state=None: full-sequence training/prefill via associative scan.
    state=dict(conv=(B,K-1,di), ssm=(B,di,N)): one-step decode."""
    cd = cfg.cdtype
    xz = jnp.einsum("bsd,de->bse", x_in, p["w_in"].astype(cd))
    if state is None:
        x, z, xc, b_ssm, c_ssm, dt, a, new_conv = _mamba_core(p, cfg, xz)
        # elements: (decay (B,S,di,N), input (B,S,di,N))
        da = jnp.exp(dt.astype(jnp.float32)[..., None] * a)          # decay
        dbx = (dt.astype(jnp.float32)[..., None]
               * b_ssm.astype(jnp.float32)[:, :, None, :]
               * xc.astype(jnp.float32)[..., None])
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a2 * a1, a2 * b1 + b2
        decays, hs = jax.lax.associative_scan(combine, (da, dbx), axis=1)
        y = jnp.einsum("bsdn,bsn->bsd", hs, c_ssm.astype(jnp.float32))
        y = y + xc.astype(jnp.float32) * p["d_skip"]
        new_state = {"conv": new_conv,
                     "ssm": hs[:, -1]} if cfg.ssm_conv > 1 else {"ssm": hs[:, -1]}
    else:
        x, z, xc, b_ssm, c_ssm, dt, a, new_conv = _mamba_core(
            p, cfg, xz, conv_state=state["conv"], ssm_state=state["ssm"])
        da = jnp.exp(dt.astype(jnp.float32)[..., None] * a)          # (B,1,di,N)
        dbx = (dt.astype(jnp.float32)[..., None]
               * b_ssm.astype(jnp.float32)[:, :, None, :]
               * xc.astype(jnp.float32)[..., None])
        h = da[:, 0] * state["ssm"] + dbx[:, 0]                      # (B,di,N)
        y = jnp.einsum("bdn,bn->bd", h, c_ssm[:, 0].astype(jnp.float32))[:, None]
        y = y + xc.astype(jnp.float32) * p["d_skip"]
        new_state = {"conv": new_conv, "ssm": h}
    y = (y.astype(cd) * jax.nn.silu(z))
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(cd))
    return lc(out, "batch", "seq", None), new_state


def mamba_state_shape(cfg: ModelConfig, batch: int) -> dict:
    di = cfg.ssm_expand * cfg.d_model
    st = {"ssm": (batch, di, cfg.ssm_state)}
    if cfg.ssm_conv > 1:
        st["conv"] = (batch, cfg.ssm_conv - 1, di)
    return st


# ------------------------------------------------------------------ mLSTM
def init_mlstm(cfg: ModelConfig, rng) -> dict:
    d = cfg.d_model
    di = cfg.xlstm_expand * d
    h = cfg.n_heads
    k = jax.random.split(rng, 6)
    s = 0.02
    return {
        "w_up": jax.random.normal(k[0], (d, 2 * di), cfg.pdtype) * s,
        "w_qkv": jax.random.normal(k[1], (di, 3 * di), cfg.pdtype) * s,
        "w_if": jax.random.normal(k[2], (di, 2 * h), cfg.pdtype) * s,
        "b_if": jnp.concatenate([jnp.zeros((h,)), jnp.ones((h,)) * 3.0]
                                ).astype(cfg.pdtype),
        "w_down": jax.random.normal(k[3], (di, d), cfg.pdtype) * s,
        "gn_scale": jnp.ones((di,), cfg.pdtype),
    }


def _mlstm_step(carry, inp, hd):
    """Stabilized mLSTM recurrence (Beck et al. '24, eqs. 19-27)."""
    c, n, m = carry                      # (B,H,hd,hd), (B,H,hd), (B,H)
    q, k, v, log_i, log_f = inp          # (B,H,hd) x3, (B,H), (B,H)
    m_new = jnp.maximum(log_f + m, log_i)
    i_g = jnp.exp(log_i - m_new)[..., None]
    f_g = jnp.exp(log_f + m - m_new)[..., None]
    c = f_g[..., None] * c + i_g[..., None] * (v[..., :, None] * k[..., None, :])
    n = f_g * n + i_g * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q))[..., None],
                        jnp.exp(-m_new)[..., None])
    h = jnp.einsum("bhij,bhj->bhi", c, q) / denom
    return (c, n, m_new), h


def mlstm_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                state: Optional[dict] = None):
    cd = cfg.cdtype
    b, s_len, d = x.shape
    h_heads = cfg.n_heads
    di = cfg.xlstm_expand * d
    hd = di // h_heads
    up = jnp.einsum("bsd,de->bse", x, p["w_up"].astype(cd))
    u, z = up[..., :di], up[..., di:]
    qkv = jnp.einsum("bse,ef->bsf", u, p["w_qkv"].astype(cd))
    q, k, v = jnp.split(qkv.astype(jnp.float32), 3, axis=-1)
    q = q.reshape(b, s_len, h_heads, hd).swapaxes(1, 2) / jnp.sqrt(hd)
    k = k.reshape(b, s_len, h_heads, hd).swapaxes(1, 2) / jnp.sqrt(hd)
    v = v.reshape(b, s_len, h_heads, hd).swapaxes(1, 2)
    gates = (jnp.einsum("bse,eg->bsg", u, p["w_if"].astype(cd))
             + p["b_if"].astype(cd)).astype(jnp.float32)
    log_i, f_pre = gates[..., :h_heads], gates[..., h_heads:]
    log_f = -jax.nn.softplus(-f_pre)                 # log sigmoid
    if state is None:
        c0 = jnp.zeros((b, h_heads, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h_heads, hd), jnp.float32)
        m0 = jnp.full((b, h_heads), -1e30, jnp.float32)
        # scan over time axis: reorder to (S, B, H, hd)
        seq = (q.transpose(2, 0, 1, 3), k.transpose(2, 0, 1, 3),
               v.transpose(2, 0, 1, 3),
               log_i.transpose(1, 0, 2), log_f.transpose(1, 0, 2))
        (c, n, m), hs = jax.lax.scan(
            lambda cr, i: _mlstm_step(cr, i, hd), (c0, n0, m0), seq)
        h_seq = hs.transpose(1, 0, 2, 3)             # (B,S,H,hd)
    else:
        seq = (q[:, :, 0], k[:, :, 0], v[:, :, 0], log_i[:, 0], log_f[:, 0])
        (c, n, m), h_one = _mlstm_step((state["c"], state["n"], state["m"]),
                                       seq, hd)
        h_seq = h_one[:, None]                        # (B,1,H,hd)
    new_state = {"c": c, "n": n, "m": m}
    h_flat = h_seq.reshape(b, -1, di).astype(cd)
    # group-norm-ish stabilization then gate
    h_flat = h_flat * jax.lax.rsqrt(
        jnp.mean(h_flat.astype(jnp.float32) ** 2, -1, keepdims=True) + 1e-6
    ).astype(cd) * p["gn_scale"].astype(cd)
    out = jnp.einsum("bse,ed->bsd", h_flat * jax.nn.silu(z),
                     p["w_down"].astype(cd))
    return lc(out, "batch", "seq", None), new_state


def mlstm_state_shape(cfg: ModelConfig, batch: int) -> dict:
    di = cfg.xlstm_expand * cfg.d_model
    hd = di // cfg.n_heads
    return {"c": (batch, cfg.n_heads, hd, hd),
            "n": (batch, cfg.n_heads, hd),
            "m": (batch, cfg.n_heads)}


# ------------------------------------------------------------------ sLSTM
def init_slstm(cfg: ModelConfig, rng) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    k = jax.random.split(rng, 4)
    s = 0.02
    return {
        "w_x": jax.random.normal(k[0], (d, 4 * d), cfg.pdtype) * s,
        "r_h": jax.random.normal(k[1], (h, hd, 4 * hd), cfg.pdtype) * s,
        "b": jnp.zeros((4 * d,), cfg.pdtype),
        "w_up": jax.random.normal(k[2], (d, 2 * cfg.xlstm_expand * d),
                                  cfg.pdtype) * s,
        "w_down": jax.random.normal(k[3], (cfg.xlstm_expand * d, d),
                                    cfg.pdtype) * s,
    }


def _slstm_step(p, cfg, carry, x_t):
    """Stabilized sLSTM cell with per-head recurrent mixing."""
    h_prev, c_prev, n_prev, m_prev = carry           # (B,H,hd) x3, (B,H,hd)
    b, hh, hd = h_prev.shape
    rec = jnp.einsum("bhd,hde->bhe", h_prev, p["r_h"].astype(jnp.float32))
    gates = (x_t.reshape(b, hh, 4 * hd).astype(jnp.float32) + rec)
    zi, ii, fi, oi = jnp.split(gates, 4, axis=-1)
    z = jnp.tanh(zi)
    o = jax.nn.sigmoid(oi)
    log_f = -jax.nn.softplus(-fi)
    m_new = jnp.maximum(log_f + m_prev, ii)
    i_g = jnp.exp(ii - m_new)
    f_g = jnp.exp(log_f + m_prev - m_new)
    c = f_g * c_prev + i_g * z
    n = f_g * n_prev + i_g
    h = o * c / jnp.maximum(n, 1e-6)
    return (h, c, n, m_new)


def slstm_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                state: Optional[dict] = None):
    cd = cfg.cdtype
    b, s_len, d = x.shape
    hh = cfg.n_heads
    hd = d // hh
    xg = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(cd)) + p["b"].astype(cd)
    if state is None:
        h0 = jnp.zeros((b, hh, hd), jnp.float32)
        c0 = jnp.zeros((b, hh, hd), jnp.float32)
        n0 = jnp.ones((b, hh, hd), jnp.float32)
        m0 = jnp.full((b, hh, hd), -1e30, jnp.float32)
        def step(carry, xt):
            new = _slstm_step(p, cfg, carry, xt)
            return new, new[0]
        (h, c, n, m), hs = jax.lax.scan(step, (h0, c0, n0, m0),
                                        xg.transpose(1, 0, 2))
        h_seq = hs.transpose(1, 0, 2, 3).reshape(b, s_len, d)
    else:
        carry = (state["h"], state["c"], state["n"], state["m"])
        h, c, n, m = _slstm_step(p, cfg, carry, xg[:, 0])
        h_seq = h.reshape(b, 1, d)
    new_state = {"h": h, "c": c, "n": n, "m": m}
    up = jnp.einsum("bsd,de->bse", h_seq.astype(cd), p["w_up"].astype(cd))
    di = cfg.xlstm_expand * d
    u, z = up[..., :di], up[..., di:]
    out = jnp.einsum("bse,ed->bsd", u * jax.nn.silu(z), p["w_down"].astype(cd))
    return lc(out, "batch", "seq", None), new_state


def slstm_state_shape(cfg: ModelConfig, batch: int) -> dict:
    hd = cfg.d_model // cfg.n_heads
    sh = (batch, cfg.n_heads, hd)
    return {"h": sh, "c": sh, "n": sh, "m": sh}
