"""Model assembly: decoder-only LMs (dense / MoE / MLA / hybrid / xLSTM),
encoder-decoder (whisper) and VLM (frontend-stub) backbones.

Layer stacks are scanned (``jax.lax.scan`` over stacked params) so HLO size
and compile time are layer-count independent; each scanned block is
optionally rematerialized (``cfg.remat``) for training memory.

Three entry points per model (built by :func:`build_model`):
  - ``forward(params, batch)``          -> logits  (teacher-forced, causal)
  - ``prefill(params, batch)``          -> (last-position logits, cache)
  - ``decode_step(params, cache, batch)`` -> (logits, cache)  (one token)
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.distributed.api import lc  # logical sharding constraint (no-op
                                      # outside a mesh-rule context)
from .config import ModelConfig
from . import layers as L
from . import ssm as S


# --------------------------------------------------------------- embeddings
def init_embeddings(cfg: ModelConfig, rng) -> dict:
    k1, k2 = jax.random.split(rng)
    p = {"tok": jax.random.normal(k1, (cfg.padded_vocab, cfg.d_model),
                                  cfg.pdtype) * 0.02,
         "norm_f": L._norm_init(cfg.d_model, cfg.pdtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = jax.random.normal(
            k2, (cfg.d_model, cfg.padded_vocab), cfg.pdtype) * 0.02
    return p


def embed(p: dict, cfg: ModelConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    x = jnp.take(p["tok"].astype(cfg.cdtype), tokens, axis=0)
    return lc(x, "batch", "seq", None)


def unembed(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = lc(x, "batch", "seq", None)     # gather SP residual before the head
    x = L.rmsnorm(p["norm_f"], x, cfg.norm_eps)
    w = (p["tok"].T if cfg.tie_embeddings else p["unembed"]).astype(cfg.cdtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return lc(logits, "batch", "seq", "vocab")


# ------------------------------------------------------------------ blocks
def init_block(cfg: ModelConfig, rng) -> dict:
    """One decoder block's params (family-dependent)."""
    ks = jax.random.split(rng, 6)
    p: dict[str, Any] = {"ln1": L._norm_init(cfg.d_model, cfg.pdtype),
                         "ln2": L._norm_init(cfg.d_model, cfg.pdtype)}
    if cfg.family == "ssm":
        # xLSTM: both cell kinds present; per-layer selector picks one
        p["mlstm"] = S.init_mlstm(cfg, ks[0])
        p["slstm"] = S.init_slstm(cfg, ks[1])
        return p
    if cfg.attention == "mla":
        p["attn"] = L.init_mla(cfg, ks[0])
    elif cfg.attention != "none":
        p["attn"] = L.init_attention(cfg, ks[0])
    if cfg.family == "hybrid" and cfg.ssm_state > 0:
        p["mamba"] = S.init_mamba(cfg, ks[1])
    if cfg.is_moe:
        p["moe"] = L.init_moe(cfg, ks[2])
    elif cfg.d_ff > 0:
        p["mlp"] = L.init_mlp(cfg, ks[2])
    return p


def block_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                positions: jnp.ndarray, decode_mask=None,
                cache: Optional[dict] = None, cache_pos=None,
                layer_is_slstm=None):
    """Returns (x, new_cache).  decode_mask (B,T) marks valid cache slots
    (decode only); train/prefill masks are banded on the fly."""
    # TP-region input: gathered to FULL sequence exactly once here (Megatron
    # SP boundary); qkv/MLP dots consume it locally, outputs reduce-scatter
    # back into the seq-sharded residual (§Perf: constraining h to stay
    # seq-sharded made every projection gather independently — 3× traffic)
    h = lc(L.rmsnorm(p["ln1"], x, cfg.norm_eps), "batch", "seq", "dmodel")
    h = jax.ad_checkpoint.checkpoint_name(h, "blk_attn_in")
    window = cfg.sliding_window if cfg.attention == "sliding" else 0
    new_cache: dict = {}
    if cfg.family == "ssm":
        m_out, m_state = S.mlstm_apply(p["mlstm"], cfg, h,
                                       None if cache is None else cache["mlstm"])
        s_out, s_state = S.slstm_apply(p["slstm"], cfg, h,
                                       None if cache is None else cache["slstm"])
        sel = layer_is_slstm.astype(h.dtype)
        attn_out = sel * s_out + (1 - sel) * m_out
        new_cache = {"mlstm": m_state, "slstm": s_state}
    elif cfg.family == "hybrid":
        a_out, kv = L.attention_apply(
            p["attn"], cfg, h, positions, window=window,
            kv_cache=None if cache is None else cache["kv"],
            cache_positions=cache_pos, decode_mask=decode_mask)
        mb_out, mb_state = S.mamba_apply(
            p["mamba"], cfg, h, None if cache is None else cache["mamba"])
        attn_out = 0.5 * (a_out + mb_out)          # parallel heads (hymba)
        new_cache = {"kv": kv, "mamba": mb_state}
    elif cfg.attention == "mla":
        attn_out, kv = L.mla_apply(p["attn"], cfg, h, positions,
                                   kv_cache=None if cache is None else cache["kv"],
                                   cache_positions=cache_pos,
                                   decode_mask=decode_mask)
        new_cache = {"kv": kv}
    else:
        attn_out, kv = L.attention_apply(
            p["attn"], cfg, h, positions, window=window,
            kv_cache=None if cache is None else cache["kv"],
            cache_positions=cache_pos, decode_mask=decode_mask)
        new_cache = {"kv": kv}
    # residual stream is sequence-sharded between TP regions (Megatron SP);
    # "seq_sp" maps to the model axis for train/prefill of wide models
    x = lc(x + attn_out, "batch", "seq_sp", "dmodel")
    h2 = lc(L.rmsnorm(p["ln2"], x, cfg.norm_eps), "batch", "seq", "dmodel")
    h2 = jax.ad_checkpoint.checkpoint_name(h2, "blk_mlp_in")
    if cfg.is_moe:
        x = x + L.moe_apply(p["moe"], cfg, h2)
    elif cfg.d_ff > 0:
        x = x + L.mlp_apply(p["mlp"], cfg, h2)
    return lc(x, "batch", "seq_sp", "dmodel"), new_cache


# ----------------------------------------------------------- encoder blocks
def init_enc_block(cfg: ModelConfig, rng) -> dict:
    ks = jax.random.split(rng, 2)
    return {"ln1": L._norm_init(cfg.d_model, cfg.pdtype),
            "ln2": L._norm_init(cfg.d_model, cfg.pdtype),
            "attn": L.init_attention(cfg, ks[0]),
            "mlp": L.init_mlp(cfg, ks[1])}


def enc_block_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                    positions: jnp.ndarray):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    a, _ = L.attention_apply(p["attn"], cfg, h, positions, causal=False)
    x = x + a
    h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + L.mlp_apply(p["mlp"], cfg, h2)


def init_xattn_block(cfg: ModelConfig, rng) -> dict:
    ks = jax.random.split(rng, 3)
    return {"ln1": L._norm_init(cfg.d_model, cfg.pdtype),
            "lnx": L._norm_init(cfg.d_model, cfg.pdtype),
            "ln2": L._norm_init(cfg.d_model, cfg.pdtype),
            "attn": L.init_attention(cfg, ks[0]),
            "xattn": L.init_attention(cfg, ks[1]),
            "mlp": L.init_mlp(cfg, ks[2])}


def xattn_block_apply(p: dict, cfg: ModelConfig, x, positions,
                      decode_mask, enc_out, cache=None, cache_pos=None):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    a, kv = L.attention_apply(p["attn"], cfg, h, positions,
                              kv_cache=None if cache is None else cache["kv"],
                              cache_positions=cache_pos,
                              decode_mask=decode_mask)
    x = lc(x + a, "batch", "seq_sp", "dmodel")
    hx = L.rmsnorm(p["lnx"], x, cfg.norm_eps)
    xa, _ = L.attention_apply(p["xattn"], cfg, hx, positions, causal=False,
                              use_rope=False, xattn_kv=enc_out)
    x = lc(x + xa, "batch", "seq_sp", "dmodel")
    h2 = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + L.mlp_apply(p["mlp"], cfg, h2)
    return lc(x, "batch", "seq_sp", "dmodel"), {"kv": kv}


# ------------------------------------------------------------------- Model
class Model:
    """Family-dispatching functional model."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- init ---------------------------------------------------------
    def init(self, rng) -> dict:
        cfg = self.cfg
        k_emb, k_blocks, k_enc = jax.random.split(rng, 3)
        params = {"emb": init_embeddings(cfg, k_emb)}
        block_init = init_xattn_block if cfg.n_enc_layers else init_block
        kd = jax.random.split(k_blocks, cfg.n_layers)
        if cfg.scan_layers:
            params["blocks"] = jax.vmap(lambda k: block_init(cfg, k))(kd)
        else:
            params["blocks"] = [block_init(cfg, k) for k in kd]
        if cfg.n_enc_layers:
            ks = jax.random.split(k_enc, cfg.n_enc_layers)
            if cfg.scan_layers:
                params["enc"] = jax.vmap(lambda k: init_enc_block(cfg, k))(ks)
            else:
                params["enc"] = [init_enc_block(cfg, k) for k in ks]
        return params

    def init_shapes(self, rng=None) -> dict:
        """Parameter ShapeDtypeStructs without allocation (dry-run path)."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self.init, rng)

    # -- helpers ------------------------------------------------------
    def _slstm_mask(self) -> jnp.ndarray:
        m = jnp.zeros((self.cfg.n_layers, 1, 1, 1))
        for i in self.cfg.slstm_at:
            m = m.at[i].set(1.0)
        return m

    def _run_stack(self, params, x, positions, decode_mask=None, cache=None,
                   cache_pos=None, enc_out=None):
        cfg = self.cfg
        slstm_sel = self._slstm_mask() if cfg.family == "ssm" else None

        def body(carry_x, scanned):
            layer_p, layer_cache, sel = scanned
            if enc_out is not None:
                out, new_c = xattn_block_apply(layer_p, cfg, carry_x, positions,
                                               decode_mask, enc_out,
                                               layer_cache, cache_pos)
            else:
                out, new_c = block_apply(layer_p, cfg, carry_x, positions,
                                         decode_mask, layer_cache, cache_pos,
                                         layer_is_slstm=sel)
            return out, new_c

        if cfg.scan_layers:
            fn = body
            if cfg.remat:
                policy = (jax.checkpoint_policies.save_only_these_names(
                    "blk_attn_in", "blk_mlp_in")
                    if cfg.remat_policy == "save_boundaries" else None)
                fn = jax.checkpoint(body, prevent_cse=False, policy=policy)
            sel = (slstm_sel if slstm_sel is not None
                   else jnp.zeros((cfg.n_layers, 1, 1, 1)))
            x, new_cache = jax.lax.scan(
                lambda c, s: fn(c, s), x,
                (params["blocks"], cache, sel))
            return x, new_cache
        new_caches = []
        for i in range(cfg.n_layers):
            layer_cache = None if cache is None else jax.tree.map(
                lambda a: a[i], cache)
            sel = (slstm_sel[i] if slstm_sel is not None else jnp.zeros((1, 1, 1)))
            x, nc = body(x, (params["blocks"][i], layer_cache, sel))
            new_caches.append(nc)
        if new_caches and new_caches[0]:
            new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
        else:
            new_cache = None
        return x, new_cache

    def _encode(self, params, audio_embeds):
        cfg = self.cfg
        x = audio_embeds.astype(cfg.cdtype)
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                     x.shape[:2])
        if cfg.scan_layers:
            def body(carry, layer_p):
                return enc_block_apply(layer_p, cfg, carry, positions), None
            x, _ = jax.lax.scan(body, x, params["enc"])
        else:
            for i in range(cfg.n_enc_layers):
                x = enc_block_apply(params["enc"][i], cfg, x, positions)
        return x

    # -- full-sequence forward (train) --------------------------------
    def forward(self, params, batch: dict) -> jnp.ndarray:
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s_len = tokens.shape
        x = embed(params["emb"], cfg, tokens)
        if cfg.family == "vlm":
            img = batch["image_embeds"].astype(cfg.cdtype)
            n_img = img.shape[1]
            assert n_img <= s_len, (
                f"vlm: {n_img} image tokens exceed seq_len {s_len}")
            x = jnp.concatenate([img, x[:, n_img:]], axis=1)
        positions = jnp.broadcast_to(jnp.arange(s_len)[None], (b, s_len))
        enc_out = None
        if cfg.n_enc_layers:
            enc_out = self._encode(params, batch["audio_embeds"])
        x, _ = self._run_stack(params, x, positions, cache=None,
                               enc_out=enc_out)
        return unembed(params["emb"], cfg, x)

    # -- caches --------------------------------------------------------
    def cache_spec(self, batch: int, max_seq: int) -> dict:
        """Shapes/dtypes of the decode cache (per layer, stacked on L)."""
        cfg = self.cfg
        kd = jnp.dtype(cfg.compute_dtype)
        ls = cfg.n_layers

        def stack(shape):
            return (ls, *shape)
        if cfg.family == "ssm":
            spec = {"mlstm": {k: (stack(v), jnp.float32)
                              for k, v in S.mlstm_state_shape(cfg, batch).items()},
                    "slstm": {k: (stack(v), jnp.float32)
                              for k, v in S.slstm_state_shape(cfg, batch).items()}}
            return spec
        if cfg.family == "hybrid":
            w = min(cfg.sliding_window or max_seq, max_seq)
            spec = {"kv": {"k": (stack((batch, w, cfg.n_kv_heads, cfg.hd)), kd),
                           "v": (stack((batch, w, cfg.n_kv_heads, cfg.hd)), kd)},
                    "mamba": {k: (stack(v), jnp.float32)
                              for k, v in S.mamba_state_shape(cfg, batch).items()}}
            return spec
        if cfg.attention == "mla":
            return {"kv": {"c_kv": (stack((batch, max_seq, cfg.kv_lora_rank)), kd),
                           "k_rope": (stack((batch, max_seq, cfg.rope_head_dim)), kd)}}
        return {"kv": {"k": (stack((batch, max_seq, cfg.n_kv_heads, cfg.hd)), kd),
                       "v": (stack((batch, max_seq, cfg.n_kv_heads, cfg.hd)), kd)}}

    def init_cache(self, batch: int, max_seq: int) -> dict:
        return jax.tree.map(lambda sd: jnp.zeros(sd[0], sd[1]),
                            self.cache_spec(batch, max_seq),
                            is_leaf=lambda x: isinstance(x, tuple)
                            and len(x) == 2 and isinstance(x[0], tuple))

    def cache_shape_structs(self, batch: int, max_seq: int):
        return jax.tree.map(lambda sd: jax.ShapeDtypeStruct(sd[0], sd[1]),
                            self.cache_spec(batch, max_seq),
                            is_leaf=lambda x: isinstance(x, tuple)
                            and len(x) == 2 and isinstance(x[0], tuple))

    # -- decode --------------------------------------------------------
    def decode_step(self, params, cache, batch: dict):
        """One-token decode.  batch: tokens (B,1), pos (B,) current position,
        plus enc/vlm extras.  Cache is functional (returned updated)."""
        cfg = self.cfg
        tokens, pos = batch["tokens"], batch["pos"]
        b = tokens.shape[0]
        x = embed(params["emb"], cfg, tokens)
        positions = pos[:, None]
        # enc-dec decode: encoder output was computed once at prefill and is
        # carried alongside the cache (real engines cache cross-attn KV)
        enc_out = batch.get("enc_out")

        if cfg.family == "ssm":
            decode_mask = None
            cache_pos = None
        elif cfg.family == "hybrid":
            # ring-buffer window cache: slot i holds absolute position
            # p ≡ i (mod W); mask stale/unwritten/out-of-window slots
            w = cache["kv"]["k"].shape[2]
            cache_pos = jnp.mod(pos, w)
            slot_age = jnp.mod(pos[:, None] - jnp.arange(w)[None], w)
            valid = (pos[:, None] - slot_age) >= 0
            within = slot_age < (cfg.sliding_window or 10**9)
            decode_mask = valid & within                  # (B, W)
        else:
            max_seq = (cache["kv"]["k"].shape[2] if cfg.attention != "mla"
                       else cache["kv"]["c_kv"].shape[2])
            cache_pos = pos
            decode_mask = jnp.arange(max_seq)[None] <= pos[:, None]  # (B,S)
        x, new_cache = self._run_stack(params, x, positions, decode_mask,
                                       cache=cache, cache_pos=cache_pos,
                                       enc_out=enc_out)
        logits = unembed(params["emb"], cfg, x)
        return logits[:, 0], new_cache

    # -- prefill -------------------------------------------------------
    def prefill(self, params, batch: dict):
        """Teacher-forced pass returning last-position logits (the cache
        write-back for prefill is exercised via decode; prefill measures the
        compute cost of context ingestion, which dominates)."""
        logits = self.forward(params, batch)
        return logits[:, -1]


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
