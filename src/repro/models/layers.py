"""Composable transformer layers: norms, RoPE, attention (GQA / MLA /
sliding-window / cross), MLPs (SwiGLU / GeGLU / squared-ReLU / GELU) and
capacity-factor MoE with token dispatch.

Functional style: ``init_*`` builds a param dict; ``*_apply`` consumes it.
Activations are annotated with logical axes via ``repro.distributed.api.lc``
(no-ops outside a mesh-rule context).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.api import lc
from .config import ModelConfig


def _norm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p: dict, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ------------------------------------------------------------------- RoPE
def rope_cos_sin(positions: jnp.ndarray, dim: int, theta: float):
    """positions: (...,) int32 -> cos/sin of shape (..., dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x: (..., heads, dim); cos/sin broadcast over the head axis."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(x.dtype)


# -------------------------------------------------------------- attention
def init_attention(cfg: ModelConfig, rng, d_model: Optional[int] = None) -> dict:
    d = d_model or cfg.d_model
    hd, h, hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    k = jax.random.split(rng, 5)
    s = 0.02
    p = {
        "wq": jax.random.normal(k[0], (d, h, hd), cfg.pdtype) * s,
        "wk": jax.random.normal(k[1], (d, hkv, hd), cfg.pdtype) * s,
        "wv": jax.random.normal(k[2], (d, hkv, hd), cfg.pdtype) * s,
        "wo": jax.random.normal(k[3], (h, hd, d), cfg.pdtype) * s,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), cfg.pdtype)
        p["bk"] = jnp.zeros((hkv, hd), cfg.pdtype)
        p["bv"] = jnp.zeros((hkv, hd), cfg.pdtype)
    return p


def _band_mask(q_idx, k_idx, causal: bool, window: int):
    """(…,sq,st) boolean mask computed on the fly (never S×S global)."""
    m = jnp.ones(q_idx.shape[:-1] + (q_idx.shape[-1], k_idx.shape[-1]), bool)
    qi = q_idx[..., :, None]
    ki = k_idx[..., None, :]
    if causal:
        m &= ki <= qi
    if window > 0:
        m &= ki > qi - window
    return m


_NAIVE_MAX_SEQ = 1024     # below this, materializing scores is fine


def sdpa(q, k, v, *, causal: bool = True, window: int = 0,
         scale: Optional[float] = None, q_chunk: int = 512):
    """Memory-efficient GQA attention core (XLA 'flash' pattern).

    q (B,S,H,D); k/v (B,T,Hkv,D).  For long sequences, scans over q chunks
    so only an (…, q_chunk, T) score tile is ever live; the scan body is
    rematerialized in the backward pass.  On real TPUs the Pallas kernel
    (kernels/flash_attention.py) replaces this under shard_map; the XLA
    formulation keeps the dry-run memory profile equivalent.
    """
    b, sq, h, d = q.shape
    t = k.shape[1]
    hkv = k.shape[2]
    g = h // hkv
    dv = v.shape[-1]                # may differ from d (MLA fused scores)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    cdtype = q.dtype
    qg = q.reshape(b, sq, hkv, g, d)

    def attend(q_i, q_idx):
        # named scope marks the region the Pallas flash kernel fuses in
        # VMEM on TPU — the roofline memory term excludes its HBM traffic
        # (kernels/flash_attention.py is the TPU implementation)
        with jax.named_scope("fused_attn"):
            s = jnp.einsum("bskgd,btkd->bkgst", q_i, k).astype(jnp.float32)
            s = s * scale
            mask = _band_mask(q_idx, jnp.arange(t), causal, window)
            s = jnp.where(mask[None, None, None], s, -1e30)
            w = jax.nn.softmax(s, axis=-1).astype(cdtype)
            return jnp.einsum("bkgst,btke->bskge", w, v)

    if sq <= _NAIVE_MAX_SEQ or sq % q_chunk != 0 or sq <= q_chunk:
        out = attend(qg, jnp.arange(sq))
        return out.reshape(b, sq, h, dv)

    nq = sq // q_chunk
    qs = qg.reshape(b, nq, q_chunk, hkv, g, d).transpose(1, 0, 2, 3, 4, 5)

    @jax.checkpoint
    def step(_, xs):
        i, q_i = xs
        q_idx = i * q_chunk + jnp.arange(q_chunk)
        return None, attend(q_i, q_idx)

    _, outs = jax.lax.scan(step, None, (jnp.arange(nq), qs))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, hkv, g, dv)
    return out.reshape(b, sq, h, dv)


def _decode_sdpa(q, k, v, valid_mask, scale: Optional[float] = None):
    """Single-query attention.  q (B,1,H,D); k/v (B,T,Hkv,D);
    valid_mask (B,T) bool.  O(T) memory (never T×T)."""
    b, _, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, g, d)
    with jax.named_scope("fused_attn"):
        s = jnp.einsum("bkgd,btkd->bkgt", qg, k).astype(jnp.float32) * scale
        s = jnp.where(valid_mask[:, None, None, :], s, -1e30)
        w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgt,btkd->bkgd", w, v)
    return out.reshape(b, 1, h, d)


def attention_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                    positions: jnp.ndarray, *, causal: bool = True,
                    window: int = 0, kv_cache=None, cache_positions=None,
                    decode_mask=None, use_rope: bool = True,
                    xattn_kv: Optional[jnp.ndarray] = None):
    """GQA attention.  Modes:
       - self-attn train/prefill: kv_cache None; on-the-fly banded mask
       - decode: kv_cache = dict(k=(B,T,Hkv,D), v=...), x is (B,1,d),
         decode_mask (B,T) marks valid cache slots
       - cross-attn: xattn_kv = encoder states (no rope, no cache logic)
    """
    cd = cfg.cdtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
    kv_src = xattn_kv if xattn_kv is not None else x
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"].astype(cd))
    if "bk" in p:
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    q = lc(q, "batch", "seq", "heads", None)
    k = lc(k, "batch", "seq", "kv_heads", None)
    v = lc(v, "batch", "seq", "kv_heads", None)
    if use_rope and xattn_kv is None:
        cos, sin = rope_cos_sin(positions, cfg.hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)     # decode: positions is (B,1) = current
    new_cache = None
    if kv_cache is not None:
        # functional single-position cache update (decode)
        idx = cache_positions                      # (B,) int32 write index
        bidx = jnp.arange(k.shape[0])
        k_all = kv_cache["k"].at[bidx, idx].set(k[:, 0].astype(kv_cache["k"].dtype))
        v_all = kv_cache["v"].at[bidx, idx].set(v[:, 0].astype(kv_cache["v"].dtype))
        new_cache = {"k": k_all, "v": v_all}
        out = _decode_sdpa(q, k_all.astype(cd), v_all.astype(cd), decode_mask)
    else:
        out = sdpa(q, k, v, causal=causal and xattn_kv is None,
                   window=window)
    out = lc(out, "batch", "seq", "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    return lc(y, "batch", "seq", None), new_cache


# ------------------------------------------------------------------- MLA
def init_mla(cfg: ModelConfig, rng) -> dict:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    r, rh = cfg.kv_lora_rank, cfg.rope_head_dim
    k = jax.random.split(rng, 6)
    s = 0.02
    return {
        "wq": jax.random.normal(k[0], (d, h, hd + rh), cfg.pdtype) * s,
        "wdkv": jax.random.normal(k[1], (d, r), cfg.pdtype) * s,
        "wuk": jax.random.normal(k[2], (r, h, hd), cfg.pdtype) * s,
        "wuv": jax.random.normal(k[3], (r, h, hd), cfg.pdtype) * s,
        "wkr": jax.random.normal(k[4], (d, rh), cfg.pdtype) * s,
        "wo": jax.random.normal(k[5], (h, hd, d), cfg.pdtype) * s,
    }


def mla_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray,
              positions: jnp.ndarray, *, kv_cache=None,
              cache_positions=None, decode_mask=None):
    """Multi-head latent attention (DeepSeek-V2).

    Prefill/train: decompress K/V per head and run the shared chunked GQA
    core (rope and nope score terms fused via head-dim concat).
    Decode: *absorbed* path — score and combine directly in the compressed
    c_kv space; the cache stores (c_kv, k_rope) only.
    """
    cd = cfg.cdtype
    hd, h, rh, r = cfg.hd, cfg.n_heads, cfg.rope_head_dim, cfg.kv_lora_rank
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    q = lc(q, "batch", "seq", "heads", None)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    cos, sin = rope_cos_sin(positions, rh, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)

    c_kv = jnp.einsum("bsd,dr->bsr", x, p["wdkv"].astype(cd))      # (B,S,r)
    k_rope_new = jnp.einsum("bsd,dk->bsk", x, p["wkr"].astype(cd))  # (B,S,rh)
    scale = 1.0 / float(hd + rh) ** 0.5
    if kv_cache is None:
        k_rope = apply_rope(k_rope_new[:, :, None, :], cos, sin)
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wuk"].astype(cd))
        vv = jnp.einsum("bsr,rhk->bshk", c_kv, p["wuv"].astype(cd))
        # fuse nope+rope score terms: concat along head_dim (Hkv == H)
        q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_cat = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (rh,))], -1)
        out = sdpa(q_cat, k_cat, vv, causal=True, scale=scale)
        new_cache = None
    else:
        idx = cache_positions
        bidx = jnp.arange(x.shape[0])
        kr = apply_rope(k_rope_new[:, :, None, :], cos, sin)[:, :, 0]
        ckv_all = kv_cache["c_kv"].at[bidx, idx].set(
            c_kv[:, 0].astype(kv_cache["c_kv"].dtype))
        kr_all = kv_cache["k_rope"].at[bidx, idx].set(
            kr[:, 0].astype(kv_cache["k_rope"].dtype))
        new_cache = {"c_kv": ckv_all, "k_rope": kr_all}
        # absorbed attention in compressed space
        q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, p["wuk"].astype(cd))
        with jax.named_scope("fused_attn"):
            scores = (jnp.einsum("bshr,btr->bhst", q_abs, ckv_all.astype(cd)) +
                      jnp.einsum("bshk,btk->bhst", q_rope, kr_all.astype(cd)))
            scores = scores.astype(jnp.float32) * scale
            scores = jnp.where(decode_mask[:, None, None, :], scores, -1e30)
            w = jax.nn.softmax(scores, axis=-1).astype(cd)
            out_c = jnp.einsum("bhst,btr->bshr", w, ckv_all.astype(cd))
        out = jnp.einsum("bshr,rhk->bshk", out_c, p["wuv"].astype(cd))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    return lc(y, "batch", "seq", None), new_cache


# ------------------------------------------------------------------- MLPs
def _n_in(mlp: str) -> int:
    return 2 if mlp in ("swiglu", "geglu") else 1


def init_mlp(cfg: ModelConfig, rng, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    k = jax.random.split(rng, 3)
    s = 0.02
    p = {"wi": jax.random.normal(k[0], (d, f), cfg.pdtype) * s,
         "wo": jax.random.normal(k[2], (f, d), cfg.pdtype) * s}
    if _n_in(cfg.mlp) == 2:
        p["wg"] = jax.random.normal(k[1], (d, f), cfg.pdtype) * s
    return p


def _act(h, g, kind: str):
    if kind == "swiglu":
        return jax.nn.silu(g) * h
    if kind == "geglu":
        return jax.nn.gelu(g) * h
    if kind == "relu2":
        r = jax.nn.relu(h)
        return r * r
    return jax.nn.gelu(h)


def mlp_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    cd = cfg.cdtype
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(cd))
    g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(cd)) if "wg" in p else None
    h = lc(_act(h, g, cfg.mlp), "batch", "seq", "ffn")
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(cd))
    return lc(y, "batch", "seq", None)


# -------------------------------------------------------------------- MoE
def init_moe(cfg: ModelConfig, rng) -> dict:
    d, e = cfg.d_model, cfg.n_experts
    f = cfg.expert_d_ff or cfg.d_ff
    k = jax.random.split(rng, 5)
    s = 0.02
    p = {
        "router": jax.random.normal(k[0], (d, e), jnp.float32) * s,
        "wi": jax.random.normal(k[1], (e, d, f), cfg.pdtype) * s,
        "wo": jax.random.normal(k[3], (e, f, d), cfg.pdtype) * s,
    }
    if _n_in(cfg.mlp) == 2:
        p["wg"] = jax.random.normal(k[2], (e, d, f), cfg.pdtype) * s
    if cfg.n_shared_experts:
        sf = f * cfg.n_shared_experts
        sub = dataclasses.replace(cfg, d_ff=sf)
        p["shared"] = init_mlp(sub, k[4], d_ff=sf)
    return p


def moe_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Top-k capacity-factor MoE with scatter dispatch (Switch-style).

    Expert buffers are sharded over the ``expert`` logical axis (EP); the
    scatter/gather between token- and expert-sharded layouts lowers to
    all-to-all under SPMD.
    """
    cd = cfg.cdtype
    b, s_len, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(-1, d)                                   # (T, d)
    t = xt.shape[0]
    cap = max(1, -(-int(t * k * cfg.capacity_factor) // e))  # ceil division

    logits = (xt.astype(jnp.float32) @ p["router"])          # (T, E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                     # (T, K)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    e_flat = topi.reshape(-1)                                # (T*K,)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)      # (T*K, E)
    pos = jnp.cumsum(onehot, axis=0) * onehot                # running count
    pos_in_e = pos.sum(-1) - 1                               # (T*K,)
    keep = pos_in_e < cap
    src = jnp.repeat(jnp.arange(t), k)
    safe_pos = jnp.where(keep, pos_in_e, cap - 1)

    buf = jnp.zeros((e, cap, d), cd)
    buf = buf.at[jnp.where(keep, e_flat, e - 1), safe_pos].add(
        jnp.where(keep[:, None], xt[src].astype(cd), 0))
    buf = lc(buf, "expert", None, None)

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(cd))
    g = (jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(cd))
         if "wg" in p else None)
    h = _act(h, g, cfg.mlp)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(cd))
    out_buf = lc(out_buf, "expert", None, None)

    gathered = out_buf[e_flat, safe_pos]                     # (T*K, d)
    gathered = jnp.where(keep[:, None], gathered, 0)
    y = (gathered.reshape(t, k, d) *
         topv.reshape(t, k, 1).astype(cd)).sum(axis=1)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], cfg, x).reshape(t, d)
    return lc(y.reshape(b, s_len, d), "batch", "seq", None)
