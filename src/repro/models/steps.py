"""Training / serving step factories used by the launcher, dry-run, smoke
tests and benchmarks.  Everything is a pure function of (params, state,
batch) so pjit shardings apply cleanly.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim import AdamWConfig, adamw_update
from .config import ModelConfig
from .model import Model


def make_loss_fn(model: Model) -> Callable:
    cfg = model.cfg

    def loss_fn(params, batch):
        logits = model.forward(params, batch)           # (B,S,V)
        labels = batch["labels"]
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None],
                                   axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        nll = (logz - gold) * mask
        # small z-loss stabilizes big-vocab training
        zloss = 1e-4 * jnp.square(logz) * mask
        denom = jnp.maximum(mask.sum(), 1.0)
        return (nll.sum() + zloss.sum()) / denom

    return loss_fn


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    accum_steps: int = 1) -> Callable:
    """Train step with optional gradient accumulation: the global batch is
    split into ``accum_steps`` microbatches scanned sequentially, so peak
    activation memory scales with the microbatch (DESIGN.md §4)."""
    loss_fn = make_loss_fn(model)

    def train_step(params, opt_state, batch):
        if accum_steps <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                return x.reshape(accum_steps, x.shape[0] // accum_steps,
                                 *x.shape[1:])
            micro = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mb):
                l_acc, g_acc = acc
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (l_acc + l, g_acc), None

            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        params, opt_state, metrics = adamw_update(opt_cfg, params, grads,
                                                  opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def make_decode_step(model: Model) -> Callable:
    def decode_step(params, cache, batch):
        logits, cache = model.decode_step(params, cache, batch)
        next_tok = jnp.argmax(logits, axis=-1)
        return next_tok, logits, cache

    return decode_step
