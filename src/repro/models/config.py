"""Model configuration — one dataclass covering every assigned architecture.

Families: dense | moe | hybrid | ssm | encdec | vlm.  All dims are the exact
assignment numbers; ``padded_vocab`` rounds the embedding table up so the
vocab dimension divides the 16-way tensor-parallel axis with 128-lane-aligned
shards (noted in DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp

VOCAB_PAD = 2048      # 16-way TP x 128-lane alignment


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # attention
    attention: str = "full"      # full | mla | sliding | none
    qkv_bias: bool = False
    sliding_window: int = 0      # for attention == "sliding"
    rope_theta: float = 10_000.0

    # mlp
    mlp: str = "swiglu"          # swiglu | geglu | relu2 | gelu

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25

    # MLA (deepseek)
    kv_lora_rank: int = 0
    rope_head_dim: int = 64

    # SSM (mamba-style; hymba parallel heads)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2

    # xLSTM
    slstm_at: Sequence[int] = ()
    xlstm_expand: int = 2

    # enc-dec / multimodal frontends (stubs provide precomputed embeddings)
    n_enc_layers: int = 0
    n_frontend_tokens: int = 0   # audio frames / image patches
    frontend: str = "none"       # none | audio | vision

    # numerics / compile scalability
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    scan_layers: bool = True
    remat: bool = True
    # "none": full recompute; "save_boundaries": keep post-norm TP-region
    # inputs (±memory/collective trade — §Perf measured it a net loss when
    # weight gathers dominate; kept as a knob)
    remat_policy: str = "none"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # ---------------------------------------------------------------- utils
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return ((self.vocab_size + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch hold a 500k context (long_500k shape)?"""
        return self.family in ("ssm",) or (
            self.family == "hybrid" and self.attention == "sliding")

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head), unpadded."""
        d, hd, v = self.d_model, self.hd, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            di = self.xlstm_expand * d
            per_layer = 2 * d * di + di * (3 * di) + 2 * d   # rough xLSTM block
        else:
            if self.attention == "mla":
                qk = d * (self.n_heads * (hd + self.rope_head_dim))
                kv = d * self.kv_lora_rank + self.kv_lora_rank * self.n_heads * (hd + hd)
                o = self.n_heads * hd * d
                per_layer += qk + kv + o + d * self.rope_head_dim
            elif self.attention != "none":
                per_layer += d * self.n_heads * hd            # q
                per_layer += 2 * d * self.n_kv_heads * hd     # k, v
                per_layer += self.n_heads * hd * d            # o
            if self.is_moe:
                e_ff = self.expert_d_ff or self.d_ff
                n_in = 2 if self.mlp in ("swiglu", "geglu") else 1
                per_layer += self.n_experts * (n_in + 1) * d * e_ff
                per_layer += self.n_shared_experts * (n_in + 1) * d * e_ff
                per_layer += d * self.n_experts                # router
            elif self.d_ff > 0:
                n_in = 2 if self.mlp in ("swiglu", "geglu") else 1
                per_layer += (n_in + 1) * d * self.d_ff
            if self.family == "hybrid" and self.ssm_state > 0:
                di = self.ssm_expand * d
                per_layer += 2 * d * di + di * d + di * (2 * self.ssm_state + 1)
            per_layer += 2 * d                                 # norms
        total = emb + self.n_layers * per_layer
        if self.n_enc_layers:
            enc_layer = 4 * d * self.n_heads * hd + 3 * d * self.d_ff + 2 * d
            total += self.n_enc_layers * enc_layer
            total += self.n_layers * (2 * d * self.n_kv_heads * hd +
                                      2 * d * self.n_heads * hd)  # cross-attn
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                    # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        n_layers=min(cfg.n_layers, 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 4),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2),
        expert_d_ff=64 if cfg.expert_d_ff else 0,
        capacity_factor=4.0,     # tiny-T smoke batches: avoid routing drops
        kv_lora_rank=64 if cfg.kv_lora_rank else 0,
        rope_head_dim=16 if cfg.kv_lora_rank else 64,
        ssm_state=min(cfg.ssm_state, 8),
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else 0,
        slstm_at=tuple(i for i in cfg.slstm_at if i < 2),
        n_enc_layers=min(cfg.n_enc_layers, 2),
        n_frontend_tokens=min(cfg.n_frontend_tokens,
                              8 if cfg.frontend == "vision" else 16),
        param_dtype="float32",
        compute_dtype="float32",
        scan_layers=False,
        remat=False,
    )
