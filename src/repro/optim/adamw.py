"""AdamW with cosine schedule and global-norm clipping (pure JAX).

Optimizer moments are stored fp32 regardless of param dtype (mixed-precision
training standard); state trees mirror the param tree so the same sharding
rules apply (ZeRO-style: the launcher shards m/v over the data axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params: Any) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any,
                 state: dict) -> tuple[Any, dict, dict]:
    step = state["step"] + 1
    # global-norm clip in fp32
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_lr(cfg, step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1 - cfg.b1) * g
        v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m_new / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    params_new = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return params_new, {"m": m_new, "v": v_new, "step": step}, metrics
