from .adamw import AdamWConfig, adamw_init, adamw_update, cosine_lr

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_lr"]
