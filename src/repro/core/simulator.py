"""Trace-driven cache simulator with shared hit semantics (paper §2, §4.2).

All policies see the *same* request sequence under *identical* hit
semantics.  Two equivalent hit modes:

  - ``content``:  hit iff the request's content id is resident (query-level
    content equivalence).  O(1), used for large sweeps.
  - ``semantic``: hit iff the Top-1 resident by cosine similarity clears
    tau_hit (embedding-based semantic equivalence; the mode the paper's
    semantic cache uses).  The synthetic embedding geometry makes the two
    agree (paraphrase sim ≈ 0.93 > tau_hit > in-topic distinct ≈ 0.72);
    ``tests/test_simulator.py`` asserts the agreement.

Admission is always-admit (paper Alg. 1 line 4: insert, then evict while
over capacity) — policies express admission control by electing the fresh
entry as the victim (e.g. TinyLFU).
"""
from __future__ import annotations

import time
from typing import Callable

from .store import ResidentStore
from .types import Stats, Trace

PolicyFactory = Callable[[int, ResidentStore], "Policy"]


def hr_full(trace: Trace) -> float:
    """Infinite-cache hit ratio: every non-first occurrence hits."""
    seen: set[int] = set()
    hits = 0
    for r in trace.requests:
        if r.cid in seen:
            hits += 1
        seen.add(r.cid)
    return hits / max(1, len(trace.requests))


def run_policy(trace: Trace, capacity: int, factory: PolicyFactory,
               hit_mode: str = "content", tau_hit: float = 0.85,
               name: str | None = None) -> Stats:
    dim = trace.requests[0].emb.shape[0]
    store = ResidentStore(capacity, dim)
    policy = factory(capacity, store)
    stats = Stats(policy=name or getattr(policy, "name", factory.__name__),
                  capacity=capacity, requests=len(trace.requests))
    t0 = time.perf_counter()
    for req in trace.requests:
        if hit_mode == "content":
            hit_cid = req.cid if req.cid in store else -1
        else:
            cid, sim = store.nearest(req.emb)
            hit_cid = cid if sim >= tau_hit else -1
        if hit_cid >= 0:
            stats.hits += 1
            policy.on_hit(hit_cid, req, req.t)
        else:
            stats.misses += 1
            if capacity <= 0:
                continue
            if hit_mode == "content" or req.cid not in store:
                store.insert(req.cid, req.emb)
                policy.on_admit(req.cid, req, req.t)
                while len(store) > capacity:
                    v = policy.victim(req.t)
                    store.remove(v)
                    stats.evictions += 1
    stats.wall_s = time.perf_counter() - t0
    stats.hr_full = hr_full(trace)
    return stats


def run_many(trace: Trace, capacity: int,
             factories: dict[str, PolicyFactory], **kw) -> list[Stats]:
    return [run_policy(trace, capacity, f, name=n, **kw)
            for n, f in factories.items()]


def default_factories(include_belady: bool = True,
                      include_extra: bool = False) -> dict[str, PolicyFactory]:
    """Paper baseline set (§4.2) + RAC variants."""
    from .policies import BASELINES
    from .rac import RAC_VARIANTS, make_rac

    paper_baselines = ["FIFO", "LRU", "CLOCK", "TTL", "TinyLFU", "ARC",
                       "S3-FIFO", "SIEVE", "2Q", "LHD", "LeCaR"]
    extra = ["LFU", "LRU-2", "GDSF", "RANDOM"]
    names = paper_baselines + (extra if include_extra else [])
    if include_belady:
        names.append("Belady")

    fac: dict[str, PolicyFactory] = {}
    for n in names:
        cls = BASELINES[n]
        fac[n] = (lambda cap, store, _c=cls: _c(cap, store))
    for n, kwargs in RAC_VARIANTS.items():
        if n in ("RAC", "RAC w/o TP", "RAC w/o TSI") or include_extra:
            fac[n] = make_rac(**kwargs)
    return fac
