"""Trace-driven cache simulator with shared hit semantics (paper §2, §4.2).

All policies see the *same* request sequence under *identical* hit
semantics, enforced by driving every run through the unified
:class:`repro.cache.SemanticCache` facade.  Two equivalent hit modes:

  - ``content``:  hit iff the request's content id is resident (query-level
    content equivalence).  O(1), used for large sweeps.
  - ``semantic``: hit iff the Top-1 resident by cosine similarity clears
    tau_hit (embedding-based semantic equivalence; the mode the paper's
    semantic cache uses).  The synthetic embedding geometry makes the two
    agree (paraphrase sim ≈ 0.93 > tau_hit > in-topic distinct ≈ 0.72);
    ``tests/test_simulator.py`` asserts the agreement.

Admission is always-admit (paper Alg. 1 line 4: insert, then evict while
over capacity) — policies express admission control by electing the fresh
entry as the victim (e.g. TinyLFU).

``run_policy`` replays one request at a time (bit-for-bit the historical
loop, one backend Top-1 per request).  ``run_policy_batched`` is the
large-sweep fast path and is *exact*: each chunk is scored by ONE fused
``decide_batch`` launch against the chunk-start snapshot, and the replay
closes the snapshot gap incrementally — every intra-chunk admission
rescores only the chunk's remaining queries against the one new row (a
rank-1 host update), and a query whose running best was evicted mid-chunk
falls back to a fresh backend Top-1 exactly as ``run_policy`` would have
computed it.  Hit/miss/eviction decisions are therefore bit-identical to
``run_policy`` for every chunk size (``tests/test_simulator.py`` asserts
this across content/semantic modes, chunk sizes, and all three backends;
exactness is modulo float-exact similarity ties between distinct
embeddings, which the synthetic geometry excludes).  Content mode needs no
similarity work and simply delegates.
"""
from __future__ import annotations

import inspect
import time
from typing import TYPE_CHECKING, Callable

import numpy as np

from .store import ResidentStore
from .types import Stats, Trace

if TYPE_CHECKING:                      # deferred at runtime: repro.cache
    from repro.cache import SemanticCache   # imports repro.core.{store,types}

PolicyFactory = Callable[[int, ResidentStore], "Policy"]

# host-vs-backend float slack: an incremental rescore whose outcome sits
# within this band of the running best (or of tau_hit) falls back to the
# reference backend scan, so scoring-engine accumulation order can never
# flip a decision (see run_policy_batched)
_EPS = 1e-4


def with_seed(factory: PolicyFactory, seed: int | None) -> PolicyFactory:
    """Bind a deterministic ``seed`` into a policy factory.

    Factories that expose a ``seed`` parameter (everything built by
    :func:`default_factories`, covering the RNG-bearing baselines LeCaR /
    RANDOM / LHD / TinyLFU's sketch) get it bound; plain ``(capacity,
    store)`` factories pass through untouched, so callers can thread one
    seed through a mixed factory dict without per-policy wiring."""
    if seed is None:
        return factory
    try:
        params = inspect.signature(factory).parameters
    except (TypeError, ValueError):          # builtins/partials without sig
        return factory
    if "seed" not in params:
        return factory

    def seeded(capacity, store):
        return factory(capacity, store, seed=seed)

    seeded.__name__ = getattr(factory, "__name__", "policy")
    return seeded


def hr_full(trace: Trace) -> float:
    """Infinite-cache hit ratio: every non-first occurrence hits."""
    seen: set[int] = set()
    hits = 0
    for r in trace.requests:
        if r.cid in seen:
            hits += 1
        seen.add(r.cid)
    return hits / max(1, len(trace.requests))


def _make_cache(trace: Trace, capacity: int, factory: PolicyFactory,
                hit_mode: str, tau_hit: float, backend: str,
                use_pallas: bool) -> "SemanticCache":
    # deferred: repro.cache depends on repro.core.{store,types}, and this
    # module is imported during repro.core package init
    from repro.cache import CacheConfig, SemanticCache
    dim = trace.requests[0].emb.shape[0]
    cfg = CacheConfig(capacity=capacity, dim=dim, tau_hit=tau_hit,
                      hit_mode=hit_mode, backend=backend,
                      use_pallas=use_pallas)
    return SemanticCache(cfg, policy_factory=factory)


def _finish(stats: Stats, cache: "SemanticCache", trace: Trace,
            t0: float) -> Stats:
    m = cache.metrics
    stats.hits, stats.misses, stats.evictions = m.hits, m.misses, m.evictions
    stats.wall_s = time.perf_counter() - t0
    stats.hr_full = hr_full(trace)
    return stats


def run_policy(trace: Trace, capacity: int, factory: PolicyFactory,
               hit_mode: str = "content", tau_hit: float = 0.85,
               name: str | None = None, backend: str = "numpy",
               use_pallas: bool = True, seed: int | None = None) -> Stats:
    """Replay ``trace`` through a :class:`SemanticCache` one request at a
    time — the reference protocol every policy is compared under."""
    cache = _make_cache(trace, capacity, with_seed(factory, seed), hit_mode,
                        tau_hit, backend, use_pallas)
    stats = Stats(policy=name or getattr(cache.policy, "name",
                                         factory.__name__),
                  capacity=capacity, requests=len(trace.requests))
    t0 = time.perf_counter()
    for req in trace.requests:
        r = cache.lookup(req.emb, cid=req.cid, t=req.t, req=req)
        if not r.hit:
            cache.admit(req.cid, req.emb, t=req.t, req=req)
    return _finish(stats, cache, trace, t0)


def run_policy_batched(trace: Trace, capacity: int, factory: PolicyFactory,
                       hit_mode: str = "semantic", tau_hit: float = 0.85,
                       name: str | None = None, backend: str = "numpy",
                       chunk: int = 512, use_pallas: bool = True,
                       seed: int | None = None) -> Stats:
    """Exact incremental batched replay (one fused launch per chunk).

    The chunk-start ``decide_batch`` snapshot supplies every query's
    running-best Top-1; the replay then applies requests in order and
    keeps the snapshot exact:

      - an admission that inserts a new row rescores the chunk's remaining
        queries against that one embedding (an entry of the chunk's Gram
        matrix — no extra kernel launch) and promotes strictly-better
        candidates.  Because these rescores are host dot products while
        the snapshot came from the backend's own scoring engine, any new
        row landing within a small epsilon of a query's running best also
        *flags* that query: at its turn the snapshot is discarded and
        ``lookup`` recomputes a fresh backend Top-1 — the identical call
        ``run_policy`` makes — so borderline decisions near ``tau_hit``
        (or near-tied argmaxes) are always made by the same engine;
      - a query whose running best was evicted at any point in the chunk
        (even if the same cid was later re-admitted under a fresh
        embedding) is flagged the same way;
      - hits never mutate residency, so their snapshots stay valid.

    Decisions (hit cids, admissions, eviction victims) are bit-identical
    to :func:`run_policy`: every query's best is taken over exactly the
    entries resident at its own turn, and every decision that could hinge
    on sub-epsilon float differences between scoring engines falls back to
    the reference scan.  ``chunk=1`` degenerates to the per-request loop.
    Content mode needs no similarity work and simply delegates.
    """
    if hit_mode == "content":
        return run_policy(trace, capacity, factory, hit_mode=hit_mode,
                          tau_hit=tau_hit, name=name, backend=backend,
                          use_pallas=use_pallas, seed=seed)
    cache = _make_cache(trace, capacity, with_seed(factory, seed), hit_mode,
                        tau_hit, backend, use_pallas)
    stats = Stats(policy=name or getattr(cache.policy, "name",
                                         factory.__name__),
                  capacity=capacity, requests=len(trace.requests))
    t0 = time.perf_counter()
    reqs = trace.requests
    step = max(1, chunk)
    for lo in range(0, len(reqs), step):
        block = reqs[lo:lo + step]
        b = len(block)
        embs = np.stack([r.emb for r in block]).astype(np.float32,
                                                       copy=False)
        dec = cache.decide_batch(embs)
        best_cid = np.asarray(dec.hit_cid, dtype=np.int64).copy()
        best_sim = np.asarray(dec.hit_sim, dtype=np.float64).copy()
        # an intra-chunk admission's row IS that request's own embedding,
        # so every possible incremental-rescore similarity is an entry of
        # the chunk's Gram matrix: one gemm replaces per-admission matvecs
        # (skipped for huge chunks where the B x B buffer would dominate)
        gram = embs @ embs.T if 1 < b <= 8192 else None
        # flagged[j]: query j's decision could hinge on a host-vs-backend
        # float difference (an intra-chunk row within _EPS of its running
        # best) — force the reference backend scan at its turn
        flagged = np.zeros(b, dtype=bool)
        promoted = np.zeros(b, dtype=bool)   # best came from a host rescore
        gone: set[int] = set()         # cids evicted at any point this chunk
        for i, req in enumerate(block):
            c = int(best_cid[i])
            # a running best that was ever evicted this chunk is stale even
            # if re-admitted (the re-admission carries a fresh embedding);
            # a host-promoted best within _EPS of the hit threshold could
            # flip under the backend's own accumulation order — both cases
            # drop the snapshot so lookup() recomputes the full Top-1
            stale = (flagged[i] or c in gone
                     or (promoted[i]
                         and abs(best_sim[i] - tau_hit) <= _EPS))
            top1 = None if stale else (c, float(best_sim[i]))
            r = cache.lookup(req.emb, cid=req.cid, t=req.t, req=req,
                             top1=top1)
            if r.hit:
                continue
            was_resident = req.cid in cache
            gone.update(cache.admit(req.cid, req.emb, t=req.t, req=req))
            if not was_resident and req.cid in cache and i + 1 < b:
                # exact incremental rescore: the one dirtied row is scored
                # against the remaining queries (strictly-better wins; a
                # near-tie flags the query for the reference scan instead)
                sims = (gram[i + 1:, i] if gram is not None else
                        embs[i + 1:] @ np.asarray(req.emb,
                                                  dtype=np.float32))
                tail = best_sim[i + 1:]
                # a near-tie only matters when it can change a decision:
                # below the hit gate the argmax identity is irrelevant
                # (the lookup is a miss either way, and evicted bests are
                # handled by `gone`), so only gate-adjacent ties flag
                flagged[i + 1:] |= ((np.abs(sims - tail) <= _EPS)
                                    & (np.maximum(sims, tail)
                                       >= tau_hit - _EPS))
                upd = sims > tail
                if upd.any():
                    tail[upd] = sims[upd]
                    best_cid[i + 1:][upd] = req.cid
                    promoted[i + 1:][upd] = True
    return _finish(stats, cache, trace, t0)


def run_many(trace: Trace, capacity: int,
             factories: dict[str, PolicyFactory], batched: bool = False,
             arena: bool = False, seed: int | None = None,
             **kw) -> list[Stats]:
    """Run every factory under identical settings.

    ``arena=True`` routes the whole dict through the one-pass multi-policy
    arena (:func:`repro.core.arena.run_arena`): one trace pass, one stacked
    snapshot launch per chunk, bit-identical decisions to the sequential
    replays.  ``batched=True`` (sequential) routes each policy through
    :func:`run_policy_batched` (forwarding e.g. ``chunk=``); the
    batched-only kwargs are dropped when neither flag is set so callers
    can toggle without editing their kwargs.  ``seed`` is bound into every
    factory that accepts one (see :func:`with_seed`)."""
    if arena:
        from .arena import run_arena
        return run_arena(trace, capacity, factories, seed=seed, **kw)
    if batched:
        runner = run_policy_batched
    else:
        runner = run_policy
        kw.pop("chunk", None)
    return [runner(trace, capacity, f, name=n, seed=seed, **kw)
            for n, f in factories.items()]


def default_factories(include_belady: bool = True,
                      include_extra: bool = False,
                      seed: int | None = None) -> dict[str, PolicyFactory]:
    """Paper baseline set (§4.2) + RAC variants.

    Every baseline factory exposes a ``seed`` kwarg; ``seed=`` here binds a
    default so the RNG-bearing policies (LeCaR, RANDOM, LHD, TinyLFU's
    sketch) are reproducible across reruns without per-policy wiring (a
    per-run ``run_many(seed=...)`` still overrides it)."""
    from .policies import BASELINES, RNG_BASELINES
    from .rac import RAC_VARIANTS, make_rac

    paper_baselines = ["FIFO", "LRU", "CLOCK", "TTL", "TinyLFU", "ARC",
                       "S3-FIFO", "SIEVE", "2Q", "LHD", "LeCaR"]
    extra = ["LFU", "LRU-2", "GDSF", "RANDOM"]
    names = paper_baselines + (extra if include_extra else [])
    if include_belady:
        names.append("Belady")

    fac: dict[str, PolicyFactory] = {}
    for n in names:
        cls = BASELINES[n]
        rng = n in RNG_BASELINES

        def f(cap, store, seed=seed, _c=cls, _rng=rng):
            kw = {"seed": seed} if (_rng and seed is not None) else {}
            return _c(cap, store, **kw)

        f.__name__ = n
        fac[n] = f
    for n, kwargs in RAC_VARIANTS.items():
        if n in ("RAC", "RAC w/o TP", "RAC w/o TSI") or include_extra:
            fac[n] = make_rac(**kwargs)
    return fac
