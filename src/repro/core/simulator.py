"""Trace-driven cache simulator with shared hit semantics (paper §2, §4.2).

All policies see the *same* request sequence under *identical* hit
semantics, enforced by driving every run through the unified
:class:`repro.cache.SemanticCache` facade.  Two equivalent hit modes:

  - ``content``:  hit iff the request's content id is resident (query-level
    content equivalence).  O(1), used for large sweeps.
  - ``semantic``: hit iff the Top-1 resident by cosine similarity clears
    tau_hit (embedding-based semantic equivalence; the mode the paper's
    semantic cache uses).  The synthetic embedding geometry makes the two
    agree (paraphrase sim ≈ 0.93 > tau_hit > in-topic distinct ≈ 0.72);
    ``tests/test_simulator.py`` asserts the agreement.

Admission is always-admit (paper Alg. 1 line 4: insert, then evict while
over capacity) — policies express admission control by electing the fresh
entry as the victim (e.g. TinyLFU).

``run_policy`` replays one request at a time (bit-for-bit the historical
loop); ``run_policy_batched`` is the large-sweep fast path that scores a
whole chunk of queries per backend call (one ``sim_top1`` kernel launch
under ``backend="kernel"``), with snapshot semantics inside a chunk.
"""
from __future__ import annotations

import time
from typing import Callable

import numpy as np

from .store import ResidentStore
from .types import Stats, Trace

PolicyFactory = Callable[[int, ResidentStore], "Policy"]


def hr_full(trace: Trace) -> float:
    """Infinite-cache hit ratio: every non-first occurrence hits."""
    seen: set[int] = set()
    hits = 0
    for r in trace.requests:
        if r.cid in seen:
            hits += 1
        seen.add(r.cid)
    return hits / max(1, len(trace.requests))


def _make_cache(trace: Trace, capacity: int, factory: PolicyFactory,
                hit_mode: str, tau_hit: float, backend: str,
                use_pallas: bool) -> "SemanticCache":
    # deferred: repro.cache depends on repro.core.{store,types}, and this
    # module is imported during repro.core package init
    from repro.cache import CacheConfig, SemanticCache
    dim = trace.requests[0].emb.shape[0]
    cfg = CacheConfig(capacity=capacity, dim=dim, tau_hit=tau_hit,
                      hit_mode=hit_mode, backend=backend,
                      use_pallas=use_pallas)
    return SemanticCache(cfg, policy_factory=factory)


def _finish(stats: Stats, cache: SemanticCache, trace: Trace,
            t0: float) -> Stats:
    m = cache.metrics
    stats.hits, stats.misses, stats.evictions = m.hits, m.misses, m.evictions
    stats.wall_s = time.perf_counter() - t0
    stats.hr_full = hr_full(trace)
    return stats


def run_policy(trace: Trace, capacity: int, factory: PolicyFactory,
               hit_mode: str = "content", tau_hit: float = 0.85,
               name: str | None = None, backend: str = "numpy",
               use_pallas: bool = True) -> Stats:
    """Replay ``trace`` through a :class:`SemanticCache` one request at a
    time — the reference protocol every policy is compared under."""
    cache = _make_cache(trace, capacity, factory, hit_mode, tau_hit,
                        backend, use_pallas)
    stats = Stats(policy=name or getattr(cache.policy, "name",
                                         factory.__name__),
                  capacity=capacity, requests=len(trace.requests))
    t0 = time.perf_counter()
    for req in trace.requests:
        r = cache.lookup(req.emb, cid=req.cid, t=req.t, req=req)
        if not r.hit:
            cache.admit(req.cid, req.emb, t=req.t, req=req)
    return _finish(stats, cache, trace, t0)


def run_policy_batched(trace: Trace, capacity: int, factory: PolicyFactory,
                       hit_mode: str = "semantic", tau_hit: float = 0.85,
                       name: str | None = None, backend: str = "numpy",
                       chunk: int = 512, use_pallas: bool = True) -> Stats:
    """Large-sweep fast path: Top-1 similarities are computed one chunk at
    a time (one backend call per chunk) against the store snapshot at
    chunk start.

    Hits are revalidated against residency before they count (an entry
    evicted mid-chunk can never serve a stale hit; the lookup falls back
    to an exact scan).  The remaining approximation: a query whose only
    match is admitted *within the same chunk* scores as a miss, exactly as
    if the whole chunk had arrived concurrently.  (Those extra admissions
    also perturb the eviction trajectory, so per-trace hit counts are
    close to but not bounded by the exact replay's.)  ``chunk=1``
    degenerates to :func:`run_policy`.  Content mode needs no similarity
    work and simply delegates.
    """
    if hit_mode == "content":
        return run_policy(trace, capacity, factory, hit_mode=hit_mode,
                          tau_hit=tau_hit, name=name, backend=backend)
    cache = _make_cache(trace, capacity, factory, hit_mode, tau_hit,
                        backend, use_pallas)
    stats = Stats(policy=name or getattr(cache.policy, "name",
                                         factory.__name__),
                  capacity=capacity, requests=len(trace.requests))
    t0 = time.perf_counter()
    reqs = trace.requests
    for lo in range(0, len(reqs), max(1, chunk)):
        block = reqs[lo:lo + max(1, chunk)]
        embs = np.stack([r.emb for r in block])
        top_cids, top_sims = cache.peek_batch(embs)
        for req, c, s in zip(block, top_cids, top_sims):
            r = cache.lookup(req.emb, cid=req.cid, t=req.t, req=req,
                             top1=(int(c), float(s)))
            if not r.hit:
                cache.admit(req.cid, req.emb, t=req.t, req=req)
    return _finish(stats, cache, trace, t0)


def run_many(trace: Trace, capacity: int,
             factories: dict[str, PolicyFactory], **kw) -> list[Stats]:
    return [run_policy(trace, capacity, f, name=n, **kw)
            for n, f in factories.items()]


def default_factories(include_belady: bool = True,
                      include_extra: bool = False) -> dict[str, PolicyFactory]:
    """Paper baseline set (§4.2) + RAC variants."""
    from .policies import BASELINES
    from .rac import RAC_VARIANTS, make_rac

    paper_baselines = ["FIFO", "LRU", "CLOCK", "TTL", "TinyLFU", "ARC",
                       "S3-FIFO", "SIEVE", "2Q", "LHD", "LeCaR"]
    extra = ["LFU", "LRU-2", "GDSF", "RANDOM"]
    names = paper_baselines + (extra if include_extra else [])
    if include_belady:
        names.append("Belady")

    fac: dict[str, PolicyFactory] = {}
    for n in names:
        cls = BASELINES[n]
        fac[n] = (lambda cap, store, _c=cls: _c(cap, store))
    for n, kwargs in RAC_VARIANTS.items():
        if n in ("RAC", "RAC w/o TP", "RAC w/o TSI") or include_extra:
            fac[n] = make_rac(**kwargs)
    return fac
