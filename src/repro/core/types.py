"""Core datatypes for the RAC cache-replacement framework.

A *trace* is a time-ordered sequence of :class:`Request`.  Each request
carries a content id (``cid``) identifying the unique underlying query
content, and an embedding.  Paraphrases of the same content share a ``cid``
but have (slightly) different embeddings; the embedding geometry is built so
that ``sim(paraphrase, original) >= tau_hit`` while distinct contents stay
below ``tau_hit`` (see :mod:`repro.core.embeddings`).

``topic`` / ``session`` / ``parent_idx`` are *generator-side ground truth*
used for analysis and for the offline-optimal policy; online policies only
see ``cid`` lazily through hit determination plus the embedding.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass
class Request:
    """One arrival in the trace."""

    t: int                      # time step (position in trace)
    cid: int                    # unique content id (ground truth equivalence)
    emb: np.ndarray             # unit-norm embedding, shape (dim,)
    topic: int = -1             # ground-truth topic label  Z_t
    session: int = -1           # ground-truth session/episode id
    parent_cid: int = -1        # ground-truth dependency parent (-1: root)
    next_use: int = -1          # next position with same cid (-1: never); filled by simulator
    timestamp: float = 0.0      # wall-clock style timestamp (OASST-style traces)


@dataclasses.dataclass
class Trace:
    """A full request sequence plus generator metadata."""

    requests: list[Request]
    n_topics: int = 0
    meta: dict = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.requests)

    def __iter__(self):
        return iter(self.requests)

    def cids(self) -> np.ndarray:
        return np.array([r.cid for r in self.requests], dtype=np.int64)

    def with_next_use(self) -> "Trace":
        """Fill ``next_use`` pointers (needed by Belady-MIN)."""
        last_seen: dict[int, int] = {}
        for i in range(len(self.requests) - 1, -1, -1):
            r = self.requests[i]
            r.next_use = last_seen.get(r.cid, -1)
            last_seen[r.cid] = i
        return self


@dataclasses.dataclass
class Stats:
    """Outcome of one simulation run."""

    policy: str = ""
    capacity: int = 0
    hits: int = 0
    misses: int = 0
    evictions: int = 0
    requests: int = 0
    hr_full: float = float("nan")   # infinite-cache hit ratio on same trace
    wall_s: float = 0.0

    @property
    def hit_ratio(self) -> float:
        return self.hits / max(1, self.requests)

    @property
    def hr_norm(self) -> float:
        """Normalized hit ratio  HR_algo(C) / HR_full  (paper §4.2)."""
        if not np.isfinite(self.hr_full) or self.hr_full <= 0:
            return float("nan")
        return self.hit_ratio / self.hr_full

    def row(self) -> str:
        return (f"{self.policy},{self.capacity},{self.hits},{self.misses},"
                f"{self.hit_ratio:.4f},{self.hr_norm:.4f},{self.wall_s:.3f}")


ROW_HEADER = "policy,capacity,hits,misses,hit_ratio,hr_norm,wall_s"


def summarize(stats: Sequence[Stats]) -> str:
    return "\n".join([ROW_HEADER] + [s.row() for s in stats])
