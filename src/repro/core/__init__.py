"""Core RAC library: the paper's cache-replacement contribution.

Public API:
    - Trace generation:  synthetic_trace, oasst_style_trace, SynthConfig,
      OASSTConfig
    - Policies:          RACPolicy (+ make_rac, RAC_VARIANTS), BASELINES
    - Policy state:      PolicyTable (journaled RAC scoring slabs; device
      backends mirror it for the fused decide_batch path), MutationJournal
    - Simulation:        run_policy, run_policy_batched (exact incremental
      batched replay), run_many, default_factories, hr_full
    - Types:             Request, Trace, Stats

The cache protocol itself (lookup / admit / evict, payloads, metrics,
backends) lives in :mod:`repro.cache`; the simulation drivers here replay
traces through that facade.
"""
from .arena import ArenaStore, run_arena
from .embeddings import EmbeddingSpace, cosine
from .legacy_policies import LEGACY_BASELINES
from .policies import BASELINES, ArrayPolicy, Policy
from .policy_table import PolicyTable, SlabTable
from .rac import RAC_VARIANTS, RACPolicy, make_rac
from .radix import RadixRACPolicy
from .simulator import (default_factories, hr_full, run_many, run_policy,
                        run_policy_batched, with_seed)
from .store import MutationJournal, ResidentStore
from .structural import pagerank_power_jax, pagerank_reversed, \
    pagerank_scores
from .traces import (OASSTConfig, SynthConfig, measured_long_reuse_ratio,
                     oasst_style_trace, synthetic_trace)
from .types import Request, Stats, Trace, summarize

__all__ = [
    "EmbeddingSpace", "cosine", "BASELINES", "LEGACY_BASELINES", "Policy",
    "ArrayPolicy", "RACPolicy",
    "RadixRACPolicy", "PolicyTable", "SlabTable", "ArenaStore", "run_arena",
    "with_seed",
    "RAC_VARIANTS", "make_rac", "run_policy", "run_policy_batched",
    "run_many",
    "default_factories", "hr_full", "MutationJournal", "ResidentStore",
    "pagerank_reversed",
    "pagerank_power_jax", "pagerank_scores", "SynthConfig", "OASSTConfig",
    "synthetic_trace",
    "oasst_style_trace", "measured_long_reuse_ratio", "Request", "Stats",
    "Trace", "summarize",
]
