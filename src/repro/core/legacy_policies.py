"""Legacy host-loop baseline policies — the frozen parity oracle.

This module preserves the historical per-request implementations of every
baseline (OrderedDict / heap / deque state, scalar ``on_hit`` / ``on_admit``
/ ``victim``) exactly as they ran before the array-state refactor of
:mod:`repro.core.policies`.  They are NOT used by the figure suite anymore;
they exist so tests can assert that each vectorized array-state policy
makes bit-identical hit/miss/eviction decisions to its host-loop
counterpart (mirroring the ``LegacyKVBlockManager`` pattern from the
KV-manager refactor).  Do not "improve" these classes: their value is that
they never change.

``LEGACY_BASELINES`` mirrors :data:`repro.core.policies.BASELINES` name for
name.  The only delta from the historical file is that ``TinyLFUPolicy``
grew the same ``seed`` kwarg as the array version (feeding the count-min
sketch salt) so seeded runs stay comparable.
"""
from __future__ import annotations

import heapq
import random
from collections import OrderedDict, deque

import numpy as np

from .policies import INF, Policy, _CountMinSketch

class FIFOPolicy(Policy):
    name = "FIFO"

    def __init__(self, capacity, store=None, **kw):
        super().__init__(capacity, store)
        self.q: deque[int] = deque()

    def on_hit(self, cid, req, t):
        pass

    def on_admit(self, cid, req, t):
        self.q.append(cid)

    def victim(self, t):
        return self.q.popleft()


class LRUPolicy(Policy):
    name = "LRU"

    def __init__(self, capacity, store=None, **kw):
        super().__init__(capacity, store)
        self.od: OrderedDict[int, None] = OrderedDict()

    def on_hit(self, cid, req, t):
        self.od.move_to_end(cid)

    def on_admit(self, cid, req, t):
        self.od[cid] = None

    def victim(self, t):
        cid, _ = self.od.popitem(last=False)
        return cid


class CLOCKPolicy(Policy):
    name = "CLOCK"

    def __init__(self, capacity, store=None, **kw):
        super().__init__(capacity, store)
        self.ring: OrderedDict[int, bool] = OrderedDict()  # cid -> ref bit

    def on_hit(self, cid, req, t):
        self.ring[cid] = True

    def on_admit(self, cid, req, t):
        self.ring[cid] = False

    def victim(self, t):
        # sweep: give second chance to referenced entries
        while True:
            cid, ref = next(iter(self.ring.items()))
            if ref:
                self.ring[cid] = False
                self.ring.move_to_end(cid)
            else:
                del self.ring[cid]
                return cid


class TTLPolicy(Policy):
    """Expire-first (admit time + ttl), LRU among the unexpired."""
    name = "TTL"

    def __init__(self, capacity, store=None, ttl: int = 2000, **kw):
        super().__init__(capacity, store)
        self.ttl = ttl
        self.od: OrderedDict[int, None] = OrderedDict()
        self.deadline: dict[int, int] = {}

    def on_hit(self, cid, req, t):
        self.od.move_to_end(cid)

    def on_admit(self, cid, req, t):
        self.od[cid] = None
        self.deadline[cid] = t + self.ttl

    def victim(self, t):
        expired = [c for c in self.od if self.deadline[c] <= t]
        if expired:
            cid = min(expired, key=lambda c: self.deadline[c])
        else:
            cid = next(iter(self.od))
        del self.od[cid]
        del self.deadline[cid]
        return cid


class LFUPolicy(Policy):
    """LFU with LRU tie-break (lazy heap)."""
    name = "LFU"

    def __init__(self, capacity, store=None, **kw):
        super().__init__(capacity, store)
        self.freq: dict[int, int] = {}
        self.stamp: dict[int, int] = {}
        self.heap: list[tuple[int, int, int]] = []   # (freq, stamp, cid)
        self._n = 0

    def _touch(self, cid, t):
        self._n += 1
        self.stamp[cid] = self._n
        heapq.heappush(self.heap, (self.freq[cid], self._n, cid))

    def on_hit(self, cid, req, t):
        self.freq[cid] += 1
        self._touch(cid, t)

    def on_admit(self, cid, req, t):
        self.freq[cid] = 1
        self._touch(cid, t)

    def victim(self, t):
        while True:
            f, s, cid = heapq.heappop(self.heap)
            if cid in self.freq and self.freq[cid] == f and self.stamp[cid] == s:
                del self.freq[cid]
                del self.stamp[cid]
                return cid


class _CountMinSketch:
    def __init__(self, width: int, depth: int = 4, seed: int = 7):
        self.w = max(16, width)
        self.d = depth
        self.tab = np.zeros((depth, self.w), dtype=np.uint8)  # 8-bit counters
        rng = random.Random(seed)
        self.salts = [rng.getrandbits(32) for _ in range(depth)]
        self.ops = 0

    def _idx(self, key: int, row: int) -> int:
        h = (key * 0x9E3779B97F4A7C15 + self.salts[row]) & 0xFFFFFFFFFFFFFFFF
        return (h >> 17) % self.w

    def add(self, key: int):
        self.ops += 1
        for r in range(self.d):
            i = self._idx(key, r)
            if self.tab[r, i] < 255:
                self.tab[r, i] += 1
        if self.ops >= 8 * self.w:       # periodic aging (halve)
            self.tab >>= 1
            self.ops = 0

    def estimate(self, key: int) -> int:
        return int(min(self.tab[r, self._idx(key, r)] for r in range(self.d)))


class TinyLFUPolicy(Policy):
    """TinyLFU admission over an LRU main cache (simplified W-TinyLFU).

    Admission control is expressed through victim selection: the newly
    inserted entry itself is evicted when its sketch frequency does not beat
    the main cache's LRU victim.
    """
    name = "TinyLFU"

    def __init__(self, capacity, store=None, seed: int = 0, **kw):
        super().__init__(capacity, store)
        self.od: OrderedDict[int, None] = OrderedDict()
        self.sketch = _CountMinSketch(width=capacity * 8, seed=7 + seed)
        self.window: deque[int] = deque()         # recent admissions (window)
        self.window_size = max(1, capacity // 100)

    def on_hit(self, cid, req, t):
        self.sketch.add(cid)
        self.od.move_to_end(cid)

    def on_admit(self, cid, req, t):
        self.sketch.add(cid)
        self.od[cid] = None
        self.window.append(cid)
        while len(self.window) > self.window_size:
            self.window.popleft()

    def victim(self, t):
        newest = next(reversed(self.od))
        oldest = next(iter(self.od))
        if newest in self.window and newest != oldest:
            # admission duel: candidate vs main LRU victim
            if self.sketch.estimate(newest) > self.sketch.estimate(oldest):
                del self.od[oldest]
                return oldest
            del self.od[newest]
            return newest
        del self.od[oldest]
        return oldest


class ARCPolicy(Policy):
    """Adaptive Replacement Cache (Megiddo & Modha, FAST'03)."""
    name = "ARC"

    def __init__(self, capacity, store=None, **kw):
        super().__init__(capacity, store)
        self.p = 0.0
        self.t1: OrderedDict[int, None] = OrderedDict()
        self.t2: OrderedDict[int, None] = OrderedDict()
        self.b1: OrderedDict[int, None] = OrderedDict()
        self.b2: OrderedDict[int, None] = OrderedDict()

    def on_hit(self, cid, req, t):
        if cid in self.t1:
            del self.t1[cid]
            self.t2[cid] = None
        else:
            self.t2.move_to_end(cid)

    def on_admit(self, cid, req, t):
        c = self.capacity
        if cid in self.b1:
            self.p = min(c, self.p + max(1.0, len(self.b2) / max(1, len(self.b1))))
            del self.b1[cid]
            self.t2[cid] = None
        elif cid in self.b2:
            self.p = max(0.0, self.p - max(1.0, len(self.b1) / max(1, len(self.b2))))
            del self.b2[cid]
            self.t2[cid] = None
        else:
            l1 = len(self.t1) + len(self.b1)
            if l1 >= c:
                if self.b1:
                    self.b1.popitem(last=False)
            elif l1 + len(self.t2) + len(self.b2) >= 2 * c:
                if self.b2:
                    self.b2.popitem(last=False)
            self.t1[cid] = None

    def victim(self, t):
        if self.t1 and (len(self.t1) > self.p or not self.t2):
            cid, _ = self.t1.popitem(last=False)
            self.b1[cid] = None
        else:
            cid, _ = self.t2.popitem(last=False)
            self.b2[cid] = None
        # bound ghost lists
        while len(self.b1) > self.capacity:
            self.b1.popitem(last=False)
        while len(self.b2) > self.capacity:
            self.b2.popitem(last=False)
        return cid


class S3FIFOPolicy(Policy):
    """S3-FIFO (Yang et al., SOSP'23 / NSDI'23): small + main + ghost FIFOs."""
    name = "S3-FIFO"

    def __init__(self, capacity, store=None, small_frac: float = 0.1, **kw):
        super().__init__(capacity, store)
        self.small_cap = max(1, int(capacity * small_frac))
        self.small: deque[int] = deque()
        self.main: deque[int] = deque()
        self.ghost: OrderedDict[int, None] = OrderedDict()
        self.freq: dict[int, int] = {}
        self.in_main: set[int] = set()

    def on_hit(self, cid, req, t):
        self.freq[cid] = min(3, self.freq.get(cid, 0) + 1)

    def on_admit(self, cid, req, t):
        self.freq[cid] = 0
        if cid in self.ghost:
            del self.ghost[cid]
            self.main.append(cid)
            self.in_main.add(cid)
        else:
            self.small.append(cid)

    def _evict_main(self) -> int:
        while True:
            cid = self.main.popleft()
            if cid not in self.in_main:
                continue
            if self.freq.get(cid, 0) > 0:
                self.freq[cid] -= 1
                self.main.append(cid)
            else:
                self.in_main.discard(cid)
                self.freq.pop(cid, None)
                return cid

    def victim(self, t):
        if len(self.small) > self.small_cap or not self.main:
            while self.small:
                cid = self.small.popleft()
                if self.freq.get(cid, 0) > 1:
                    self.main.append(cid)       # promote
                    self.in_main.add(cid)
                    self.freq[cid] = 0
                else:
                    self.ghost[cid] = None
                    while len(self.ghost) > self.capacity:
                        self.ghost.popitem(last=False)
                    self.freq.pop(cid, None)
                    return cid
        return self._evict_main()


class SIEVEPolicy(Policy):
    """SIEVE (Zhang et al., NSDI'24): FIFO queue + moving hand + visited bits."""
    name = "SIEVE"

    def __init__(self, capacity, store=None, **kw):
        super().__init__(capacity, store)
        self.order: OrderedDict[int, bool] = OrderedDict()  # head=oldest
        self.hand: int | None = None                         # cid at hand

    def on_hit(self, cid, req, t):
        self.order[cid] = True

    def on_admit(self, cid, req, t):
        self.order[cid] = False   # insert at tail (newest)

    def victim(self, t):
        keys = list(self.order.keys())
        idx = keys.index(self.hand) if self.hand in self.order else 0
        n = len(keys)
        for _ in range(2 * n + 1):
            cid = keys[idx % n]
            if cid not in self.order:
                idx += 1
                continue
            if self.order[cid]:
                self.order[cid] = False
                idx += 1
            else:
                nxt = keys[(idx + 1) % n]
                self.hand = nxt if nxt != cid else None
                del self.order[cid]
                return cid
        cid, _ = self.order.popitem(last=False)   # fallback (unreachable)
        return cid


class TwoQPolicy(Policy):
    """2Q (Johnson & Shasha, VLDB'94): A1in FIFO + A1out ghost + Am LRU."""
    name = "2Q"

    def __init__(self, capacity, store=None, kin_frac=0.25, kout_frac=0.5, **kw):
        super().__init__(capacity, store)
        self.kin = max(1, int(capacity * kin_frac))
        self.kout = max(1, int(capacity * kout_frac))
        self.a1in: deque[int] = deque()
        self.a1out: OrderedDict[int, None] = OrderedDict()
        self.am: OrderedDict[int, None] = OrderedDict()
        self.in_a1in: set[int] = set()

    def on_hit(self, cid, req, t):
        if cid in self.am:
            self.am.move_to_end(cid)
        # hits in A1in leave position unchanged (2Q semantics)

    def on_admit(self, cid, req, t):
        if cid in self.a1out:
            del self.a1out[cid]
            self.am[cid] = None
        else:
            self.a1in.append(cid)
            self.in_a1in.add(cid)

    def victim(self, t):
        if len(self.a1in) > self.kin or not self.am:
            while self.a1in:
                cid = self.a1in.popleft()
                if cid in self.in_a1in:
                    self.in_a1in.discard(cid)
                    self.a1out[cid] = None
                    while len(self.a1out) > self.kout:
                        self.a1out.popitem(last=False)
                    return cid
        cid, _ = self.am.popitem(last=False)
        return cid


class LRU2Policy(Policy):
    """LRU-2 (O'Neil et al.): evict max backward-2nd-access distance."""
    name = "LRU-2"

    def __init__(self, capacity, store=None, **kw):
        super().__init__(capacity, store)
        self.hist: dict[int, tuple[int, int]] = {}   # cid -> (t_prev, t_last)
        self.heap: list[tuple[int, int, int]] = []   # (k2_time, t_last, cid)

    def _push(self, cid):
        k2, last = self.hist[cid]
        heapq.heappush(self.heap, (k2, last, cid))

    def on_hit(self, cid, req, t):
        _, last = self.hist[cid]
        self.hist[cid] = (last, t)
        self._push(cid)

    def on_admit(self, cid, req, t):
        self.hist[cid] = (-10**9, t)                 # no 2nd-to-last yet
        self._push(cid)

    def victim(self, t):
        while True:
            k2, last, cid = heapq.heappop(self.heap)
            if cid in self.hist and self.hist[cid] == (k2, last):
                del self.hist[cid]
                return cid


class GDSFPolicy(Policy):
    """GreedyDual-Size-Frequency with unit size/cost: H = L + freq."""
    name = "GDSF"

    def __init__(self, capacity, store=None, **kw):
        super().__init__(capacity, store)
        self.L = 0.0
        self.freq: dict[int, int] = {}
        self.h: dict[int, float] = {}
        self.heap: list[tuple[float, int, int]] = []
        self._n = 0

    def _push(self, cid):
        self._n += 1
        heapq.heappush(self.heap, (self.h[cid], self._n, cid))

    def on_hit(self, cid, req, t):
        self.freq[cid] += 1
        self.h[cid] = self.L + self.freq[cid]
        self._push(cid)

    def on_admit(self, cid, req, t):
        self.freq[cid] = 1
        self.h[cid] = self.L + 1.0
        self._push(cid)

    def victim(self, t):
        while True:
            h, _, cid = heapq.heappop(self.heap)
            if cid in self.h and self.h[cid] == h:
                self.L = h
                del self.h[cid]
                del self.freq[cid]
                return cid


class LHDPolicy(Policy):
    """LHD (Beckmann et al., NSDI'18), simplified with sampling.

    Hit density per log2-age class is estimated online from observed hit /
    eviction ages; eviction samples ``n_sample`` residents and removes the
    minimum-density one (as in the paper's implementation).
    """
    name = "LHD"
    N_CLASSES = 32

    def __init__(self, capacity, store=None, n_sample: int = 64, seed: int = 0, **kw):
        super().__init__(capacity, store)
        self.n_sample = n_sample
        self.rng = random.Random(seed)
        self.last: dict[int, int] = {}
        self.keys: list[int] = []
        self.pos: dict[int, int] = {}
        self.hit_age = np.ones(self.N_CLASSES)
        self.ev_age = np.ones(self.N_CLASSES)

    @staticmethod
    def _cls(age: int) -> int:
        return min(LHDPolicy.N_CLASSES - 1, max(0, int(np.log2(age + 1))))

    def _density(self, cid: int, t: int) -> float:
        age = t - self.last[cid]
        c = self._cls(age)
        p_hit = self.hit_age[c] / (self.hit_age[c] + self.ev_age[c])
        exp_life = (age + 1.0)
        return p_hit / exp_life

    def _add(self, cid):
        self.pos[cid] = len(self.keys)
        self.keys.append(cid)

    def _del(self, cid):
        i = self.pos.pop(cid)
        last = self.keys.pop()
        if last != cid:
            self.keys[i] = last
            self.pos[last] = i

    def on_hit(self, cid, req, t):
        self.hit_age[self._cls(t - self.last[cid])] += 1
        self.last[cid] = t

    def on_admit(self, cid, req, t):
        self.last[cid] = t
        self._add(cid)

    def victim(self, t):
        n = len(self.keys)
        sample = (self.keys if n <= self.n_sample
                  else [self.keys[self.rng.randrange(n)] for _ in range(self.n_sample)])
        cid = min(sample, key=lambda c: (self._density(c, t), -self.last[c], c))
        self.ev_age[self._cls(t - self.last[cid])] += 1
        self._del(cid)
        del self.last[cid]
        return cid


class LeCaRPolicy(Policy):
    """LeCaR (Vietri et al., HotStorage'18): regret-weighted LRU/LFU experts."""
    name = "LeCaR"

    def __init__(self, capacity, store=None, learning_rate=0.45,
                 discount=None, seed=0, **kw):
        super().__init__(capacity, store)
        self.lr = learning_rate
        self.d = discount if discount is not None else 0.005 ** (1.0 / capacity)
        self.w = np.array([0.5, 0.5])            # [LRU, LFU]
        self.rng = random.Random(seed)
        self.lru: OrderedDict[int, None] = OrderedDict()
        self.freq: dict[int, int] = {}
        self.h_lru: OrderedDict[int, int] = OrderedDict()   # ghost: cid -> evict t
        self.h_lfu: OrderedDict[int, int] = OrderedDict()

    def _reward(self, ghost: OrderedDict, idx: int, cid: int, t: int):
        if cid in ghost:
            dt = t - ghost.pop(cid)
            r = self.d ** dt
            upd = np.ones(2)
            upd[idx] = np.exp(-self.lr * r)      # penalize the expert at fault
            self.w = self.w * upd
            self.w = self.w / self.w.sum()

    def on_hit(self, cid, req, t):
        self.lru.move_to_end(cid)
        self.freq[cid] += 1

    def on_admit(self, cid, req, t):
        self._reward(self.h_lru, 0, cid, t)
        self._reward(self.h_lfu, 1, cid, t)
        self.lru[cid] = None
        self.freq[cid] = 1

    def victim(self, t):
        use_lru = self.rng.random() < self.w[0]
        if use_lru:
            cid = next(iter(self.lru))
            self.h_lru[cid] = t
            while len(self.h_lru) > self.capacity:
                self.h_lru.popitem(last=False)
        else:
            cid = min(self.freq, key=lambda c: (self.freq[c], c))
            self.h_lfu[cid] = t
            while len(self.h_lfu) > self.capacity:
                self.h_lfu.popitem(last=False)
        del self.lru[cid]
        del self.freq[cid]
        return cid


class BeladyPolicy(Policy):
    """Belady's MIN — offline optimal; uses precomputed next-use indices."""
    name = "Belady"
    requires_future = True

    def __init__(self, capacity, store=None, **kw):
        super().__init__(capacity, store)
        self.next_use: dict[int, int] = {}
        self.heap: list[tuple[int, int]] = []    # (-next_use_key, cid)

    @staticmethod
    def _key(nu: int) -> int:
        return 10 ** 12 if nu < 0 else nu        # never-used-again = farthest

    def _record(self, cid, req):
        self.next_use[cid] = req.next_use
        heapq.heappush(self.heap, (-self._key(req.next_use), cid))

    def on_hit(self, cid, req, t):
        self._record(cid, req)

    def on_admit(self, cid, req, t):
        self._record(cid, req)

    def victim(self, t):
        while True:
            negk, cid = heapq.heappop(self.heap)
            if cid in self.next_use and -negk == self._key(self.next_use[cid]):
                del self.next_use[cid]
                return cid


class RandomPolicy(Policy):
    name = "RANDOM"

    def __init__(self, capacity, store=None, seed=0, **kw):
        super().__init__(capacity, store)
        self.rng = random.Random(seed)
        self.keys: list[int] = []
        self.pos: dict[int, int] = {}

    def on_hit(self, cid, req, t):
        pass

    def on_admit(self, cid, req, t):
        self.pos[cid] = len(self.keys)
        self.keys.append(cid)

    def victim(self, t):
        i = self.rng.randrange(len(self.keys))
        cid = self.keys[i]
        last = self.keys.pop()
        if last != cid:
            self.keys[i] = last
            self.pos[last] = i
        del self.pos[cid]
        return cid


LEGACY_BASELINES: dict[str, type[Policy]] = {
    p.name: p for p in [
        FIFOPolicy, LRUPolicy, CLOCKPolicy, TTLPolicy, LFUPolicy,
        TinyLFUPolicy, ARCPolicy, S3FIFOPolicy, SIEVEPolicy, TwoQPolicy,
        LRU2Policy, GDSFPolicy, LHDPolicy, LeCaRPolicy, BeladyPolicy,
        RandomPolicy,
    ]
}
