"""RadixRAC — RAC eviction for radix-structured KV prefix blocks.

The paper's second instantiation (§2, Alg. 3) caches fixed-size KV prefix
blocks in a radix tree: the parent edge IS the dependency link, and
eviction under block pressure ranks blocks by Value = TP(topic)·TSI(block)
with SGLang's children-first structural constraint.  This policy carries
exactly that scoring under the generic :mod:`repro.core.policies`
protocol, so :class:`repro.serving.kv_manager.KVBlockManager` can run on a
content-mode :class:`repro.cache.SemanticCache` and share the facade's
metrics, hooks, checkpoint, and device scoring surface with the
query-level cache:

  - the *manager* owns the tree (token keys, prefix matching) and tells
    the policy about structure through :meth:`stage` (topic + parent of
    the next admission) and :meth:`touch_topic` (one TP refresh per
    request, Alg. 2);
  - the *policy* owns per-slot scoring slabs (freq/dep/last_t/topic),
    maintains the Alg. 3 TSI cascade on hits and new links, and elects
    victims by ``argmin TP·TSI`` over blocks with no live children;
  - victim scoring is one batched ``rac_value`` call — the facade wires
    ``masked_value_backend`` to the backend's :meth:`rac_value_masked`,
    so the host numpy path and the device kernel path both score the
    whole block table with structurally-protected blocks masked to +inf.

Self-eviction: when every block is structurally protected (all have live
children, or are the chain currently being extended), the freshly
admitted block itself is elected — the facade's always-admit protocol
turns that into "allocation failed", matching the legacy manager's
``victim < 0`` path, and the staged parent link is rolled back.

Determinism matches the legacy host manager bit for bit on the numpy
backend: values are float64, ties break on (value, last-access, cid).
"""
from __future__ import annotations

import numpy as np

from .policies import Policy


class RadixRACPolicy(Policy):
    name = "RadixRAC"

    def __init__(self, capacity, store=None, *,
                 alpha: float = 0.001,         # TP decay coefficient (Def. 1)
                 lam: float = 2.0,             # structural weight λ (Def. 2)
                 **kw):
        super().__init__(capacity, store)
        assert store is not None, "RadixRAC scores over the resident store"
        self.alpha = alpha
        self.lam = lam
        n = store.emb.shape[0]
        # per-slot scoring slabs (aligned with store slots)
        self.freq = np.zeros(n, dtype=np.float64)
        self.dep = np.zeros(n, dtype=np.float64)
        self.last_t = np.full(n, -1, dtype=np.int64)
        self.topic_of = np.full(n, -1, dtype=np.int64)
        self.parent = np.full(n, -1, dtype=np.int64)     # parent cid (-1 root)
        self.n_children = np.zeros(n, dtype=np.int64)    # live children count
        # topic TP tables (grown dynamically), indexed by tid
        self.tp_last = np.zeros(256, dtype=np.float64)
        self.t_last = np.zeros(256, dtype=np.int64)
        self._next_tid = 0
        # admission staging (set by the manager before each cache.admit)
        self._staged: tuple[int, int] | None = None      # (topic, parent)
        self._fresh = -1                  # last admitted cid (self-evict target)
        self.protect: set[int] = set()    # chain tip being extended
        # facade-wired device scorers (see repro.cache.facade._VALUE_HOOKS)
        self.value_backend = None
        self.masked_value_backend = None

    # ------------------------------------------------------------------ TP
    def _grow_tp(self, tid: int):
        while tid >= len(self.tp_last):
            self.tp_last = np.concatenate([self.tp_last,
                                           np.zeros_like(self.tp_last)])
            self.t_last = np.concatenate([self.t_last,
                                          np.zeros_like(self.t_last)])

    def touch_topic(self, tid: int | None, t: int) -> int:
        """Alg. 2 decay-and-increment; ``tid=None`` opens a fresh topic.
        Called once per request by the block manager (a conversation is a
        topic episode — every request touches exactly one topic)."""
        if tid is None:
            tid = self._next_tid
        self._grow_tp(tid)
        self._next_tid = max(self._next_tid, tid + 1)
        self.tp_last[tid] = (0.5 ** (self.alpha * (t - self.t_last[tid]))
                             * self.tp_last[tid] + 1.0)
        self.t_last[tid] = t
        return tid

    def tp_now(self, tid: int, t: int) -> float:
        return float(0.5 ** (self.alpha * (t - self.t_last[tid]))
                     * self.tp_last[tid])

    # ------------------------------------------------------------ protocol
    def stage(self, topic: int, parent: int):
        """Declare the structure of the next admission: its topic and its
        radix parent (-1 for a root).  The parent is also the chain tip
        currently being extended, so it joins the protected set."""
        self._staged = (topic, parent)
        self.protect = {parent} if parent >= 0 else set()

    def on_hit(self, cid, req, t):
        """Alg. 3 hit path: freq bump + one-hop dep cascade to the radix
        parent (the radix edge is the dependency link, no DetectParent)."""
        s = self.store.slot_of[cid]
        self.freq[s] += 1.0
        self.last_t[s] = t
        p = int(self.parent[s])
        if p >= 0 and p in self.store.slot_of:
            self.dep[self.store.slot_of[p]] += 1.0

    def on_admit(self, cid, req, t):
        assert self._staged is not None, \
            "RadixRAC admissions must be staged (topic, parent) first"
        topic, parent = self._staged
        self._staged = None
        self._fresh = cid
        s = self.store.slot_of[cid]
        self.freq[s] = 1.0
        self.dep[s] = 0.0
        self.last_t[s] = t
        self.topic_of[s] = topic
        self.parent[s] = parent
        if parent >= 0 and parent in self.store.slot_of:
            sp = self.store.slot_of[parent]
            self.n_children[sp] += 1
            self.dep[sp] += 1.0           # new link (Alg. 3 new=1 path)

    # ------------------------------------------------------------- eviction
    def value_scores(self, t: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batched masked Value(q) over all resident blocks: (cids, values,
        valid).  Invalid (structurally protected) blocks score +inf."""
        slots = np.fromiter(self.store.slot_of.values(), dtype=np.int64,
                            count=len(self.store.slot_of))
        cids = np.fromiter(self.store.slot_of.keys(), dtype=np.int64,
                           count=len(self.store.slot_of))
        tids = self.topic_of[slots]
        tsi = self.freq[slots] + self.lam * self.dep[slots]
        valid = self.n_children[slots] == 0
        if self.protect or self._fresh >= 0:
            blocked = self.protect | {self._fresh}
            valid &= np.fromiter((int(c) not in blocked for c in cids),
                                 dtype=bool, count=len(cids))
        if self.masked_value_backend is not None:
            values = self.masked_value_backend(tsi, tids, self.tp_last,
                                               self.t_last, self.alpha, t,
                                               valid)
        else:
            tp = (0.5 ** (self.alpha * (t - self.t_last[tids]))
                  * self.tp_last[tids])
            values = np.where(valid, tp * tsi, np.inf)
        return cids, values, valid

    def victim(self, t):
        cids, values, valid = self.value_scores(t)
        if not valid.any():
            # everything is structurally protected: elect the fresh block
            # itself (always-admit admission control — the manager reads
            # this as "allocation failed", like the legacy victim<0 path)
            victim = self._fresh
            self._unlink_fresh()
        else:
            slots = np.array([self.store.slot_of[int(c)] for c in cids])
            order = np.lexsort((cids, self.last_t[slots], values))
            victim = int(cids[order[0]])
        self._forget(victim)
        return victim

    def _unlink_fresh(self):
        """Roll back the staged parent link of a failed admission so the
        parent's dep/children match the legacy never-inserted state."""
        s = self.store.slot_of[self._fresh]
        p = int(self.parent[s])
        if p >= 0 and p in self.store.slot_of:
            sp = self.store.slot_of[p]
            self.n_children[sp] -= 1
            self.dep[sp] -= 1.0

    def _forget(self, cid: int):
        s = self.store.slot_of[cid]
        p = int(self.parent[s])
        if p >= 0 and cid != self._fresh and p in self.store.slot_of:
            self.n_children[self.store.slot_of[p]] -= 1
        self.freq[s] = 0.0
        self.dep[s] = 0.0                 # dep(parent) survives (Def. 2)
        self.last_t[s] = -1
        self.topic_of[s] = -1
        self.parent[s] = -1
        if cid == self._fresh:
            self._fresh = -1
