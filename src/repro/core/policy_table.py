"""PolicyTable — the RAC scoring state as a device-syncable structure.

:class:`repro.core.rac.RACPolicy` historically kept its per-slot counters
(freq/dep/tsi/topic_of/last_t/arrive_t), the per-topic TP tables
(tp_last/t_last), and the topic representatives as loose numpy arrays and
per-``TopicState`` embeddings.  That layout was host-only: every fused
device decision (Top-1 lookup + Alg. 4 routing + Eq. 1 victim scoring)
would have had to re-upload everything per call.

The PolicyTable packs the same state into two journaled array families:

  - **slot axis** (aligned with :class:`~repro.core.store.ResidentStore`
    slots): ``freq``, ``dep``, ``tsi``, ``topic_of``, ``last_t``,
    ``arrive_t``.  Mutations stamp ``slot_log``.
  - **topic axis** (indexed by tid, grown by doubling): ``tp_last``,
    ``t_last``, the dense representative table ``rep`` (T, D) with a
    ``rep_valid`` mask, and ``topic_hwm`` (all live tids < hwm, the
    runtime ``n_valid`` for the routing kernel).  Mutations stamp
    ``topic_log``.

Both journals are :class:`~repro.core.store.MutationJournal` instances —
the exact protocol device backends already use to sync the resident slab —
so a backend caches an uploaded copy keyed by ``(slot_version,
topic_version)`` and scatters only the dirty rows on the next
``decide_batch`` (see ``repro.cache.backends.KernelBackend``).

Deleted topics zero their ``rep`` row (mirroring the store's zeroed free
slots): a zero representative can only win routing Top-1 when every real
similarity is negative, far below any sensible ``tau_route`` gate, so the
host-masked and device-zeroed paths make identical routing *decisions*.

The policy remains the single writer; it mutates the arrays in place and
stamps the touched row through :meth:`touch_slot` / :meth:`touch_topic`
(or the ``set_rep`` / ``clear_slot`` / ``clear_topic`` helpers that stamp
for it).  Checkpointing needs no cooperation: a ``deepcopy`` of the table
carries its journals, and globally-unique stamps keep a restored
snapshot's versions honest.
"""
from __future__ import annotations

import numpy as np

from .store import MutationJournal


class SlabTable:
    """Journaled named per-slot arrays — the generic array-state slab.

    The generalization of the :class:`PolicyTable` slot axis that the
    array-state baseline policies (:mod:`repro.core.policies`) build on:
    each field is a fixed-size 1-D array aligned with the resident store's
    slots, and every mutation can be stamped into one shared
    :class:`~repro.core.store.MutationJournal` — the exact dirty-row sync
    protocol device backends already speak for the embedding slab and the
    RAC scoring tables, so a backend can mirror any policy's metadata
    without knowing which policy owns it.

    ``specs`` maps field name -> ``(dtype, fill)``; fields are exposed as
    attributes (``slabs.seq``, ``slabs.freq``, ...).  The owning policy is
    the single writer: it mutates rows in place and stamps them through
    :meth:`touch` / :meth:`touch_rows`.  Freed slots are *not* cleared on
    eviction — selection masks on store occupancy, and the next admission
    into the slot overwrites every field it reads — so the hot path stays
    O(touched rows); :meth:`clear` exists for policies that do want the
    reset.

    ``journal=False`` (the array-state baselines' default) skips the
    per-row log entirely: nothing mirrors their slabs to a device yet, and
    on the replay hot path a million no-op stamps are real wall time.
    Pass ``journal=True`` (the default) to turn the dirty-row protocol on
    for slabs a device backend will scatter-sync.
    """

    def __init__(self, n_slots: int, journal: bool = True, **specs):
        self.n_slots = n_slots
        self._specs = dict(specs)
        for name, (dtype, fill) in specs.items():
            setattr(self, name, np.full(n_slots, fill, dtype=dtype))
        self.log = MutationJournal() if journal else None

    @property
    def version(self) -> int | None:
        return None if self.log is None else self.log.version

    def dirty_since(self, version: int) -> set[int] | None:
        return None if self.log is None else self.log.dirty_since(version)

    def touch(self, slot: int):
        """Record that row ``slot`` was mutated."""
        if self.log is not None:
            self.log.stamp(int(slot))

    def touch_rows(self, slots):
        """Stamp a batch of mutated rows (vectorized hooks)."""
        if self.log is not None:
            for s in slots:
                self.log.stamp(int(s))

    def clear(self, slot: int):
        """Reset every field of ``slot`` to its fill value."""
        for name, (_, fill) in self._specs.items():
            getattr(self, name)[slot] = fill
        self.touch(slot)


class PolicyTable:
    """Journaled slot/topic scoring slabs (see module docstring)."""

    def __init__(self, n_slots: int, dim: int, n_topics: int = 256):
        self.dim = dim
        # -- slot axis (aligned with store slots) --------------------------
        self.freq = np.zeros(n_slots, dtype=np.float64)
        self.dep = np.zeros(n_slots, dtype=np.float64)
        self.tsi = np.zeros(n_slots, dtype=np.float64)
        self.topic_of = np.full(n_slots, -1, dtype=np.int64)
        self.last_t = np.full(n_slots, -1, dtype=np.int64)
        self.arrive_t = np.full(n_slots, -1, dtype=np.int64)
        # -- topic axis (indexed by tid, doubled on demand) ----------------
        self.tp_last = np.zeros(n_topics, dtype=np.float64)
        self.t_last = np.zeros(n_topics, dtype=np.int64)
        self.rep = np.zeros((n_topics, dim), dtype=np.float32)
        self.rep_valid = np.zeros(n_topics, dtype=bool)
        self.topic_hwm = 0                     # all live tids < topic_hwm
        # -- dirty-row sync ------------------------------------------------
        self.slot_log = MutationJournal()
        self.topic_log = MutationJournal()

    # ------------------------------------------------------------ versions
    @property
    def slot_version(self) -> int:
        return self.slot_log.version

    @property
    def topic_version(self) -> int:
        return self.topic_log.version

    def dirty_slots_since(self, version: int) -> set[int] | None:
        return self.slot_log.dirty_since(version)

    def dirty_topics_since(self, version: int) -> set[int] | None:
        return self.topic_log.dirty_since(version)

    # ------------------------------------------------------------ stamping
    def touch_slot(self, slot: int):
        """Record that the slot-axis row ``slot`` was mutated."""
        self.slot_log.stamp(int(slot))

    def touch_topic(self, tid: int):
        """Record that the topic-axis row ``tid`` was mutated."""
        tid = int(tid)
        if tid + 1 > self.topic_hwm:
            self.topic_hwm = tid + 1
        self.topic_log.stamp(tid)

    # ------------------------------------------------------------- helpers
    @property
    def n_topic_rows(self) -> int:
        return len(self.tp_last)

    def grow_topics(self, tid: int):
        """Double every topic-axis array until ``tid`` is addressable.

        Growth reallocates the arrays, so device mirrors detect the shape
        change and fall back to a full upload (shape mismatch, not the
        journal, is the signal — the journal stays small)."""
        while tid >= len(self.tp_last):
            self.tp_last = np.concatenate([self.tp_last,
                                           np.zeros_like(self.tp_last)])
            self.t_last = np.concatenate([self.t_last,
                                          np.zeros_like(self.t_last)])
            self.rep = np.concatenate([self.rep, np.zeros_like(self.rep)])
            self.rep_valid = np.concatenate([self.rep_valid,
                                             np.zeros_like(self.rep_valid)])

    def set_rep(self, tid: int, emb: np.ndarray, valid: bool = True):
        """Install ``emb`` as topic ``tid``'s representative."""
        self.grow_topics(tid)
        self.rep[tid] = emb
        self.rep_valid[tid] = valid
        self.touch_topic(tid)

    def clear_topic(self, tid: int):
        """Retire a deleted topic: zero its representative row so it can
        never win a routing Top-1 (the TP cells keep their last value —
        ghost revival overwrites them before the tid goes live again)."""
        self.rep[tid] = 0.0
        self.rep_valid[tid] = False
        self.touch_topic(tid)

    def clear_slot(self, slot: int):
        """Reset a freed slot's scoring row (eviction path)."""
        self.freq[slot] = 0.0
        self.dep[slot] = 0.0
        self.tsi[slot] = 0.0
        self.topic_of[slot] = -1
        self.touch_slot(slot)
