"""Deterministic synthetic embedding space with paper-faithful geometry.

The real paper uses a sentence-embedding model and cosine similarity with a
semantic-equivalence threshold tau_hit = 0.85.  Offline we build a synthetic
unit-norm embedding space whose *similarity structure* matches what the
policy consumes:

  - paraphrases of the same content:            sim ≈ 0.93  (> tau_hit)
  - distinct contents within the same topic:    sim ≈ 0.72  (> tau_edge=0.6,
                                                             < tau_hit)
  - contents of different topics:               sim ≲ 0.30  (< tau_edge)

Construction: each topic ``s`` gets a random unit centroid ``c_s``; a content
item ``i`` in topic ``s`` is ``normalize(c_s·cosθ + u_i·sinθ)`` with a random
orthogonal-ish direction ``u_i`` (θ chosen so item–item in-topic similarity
is ≈ cos²θ ≈ 0.72).  Dependency-linked items share part of their ``u``
component so parent–child similarity is slightly higher than generic
in-topic similarity (≈ 0.78) — mirroring discourse continuity.  A paraphrase
mixes the item embedding with fresh noise at angle φ (cosφ ≈ 0.93).

Everything is keyed by integer ids and a seed → bit-for-bit reproducible
without storing any table (embeddings are *derived*, not sampled-and-kept,
via counter-based RNG).
"""
from __future__ import annotations

import numpy as np

# geometry defaults (see module docstring).  Calibrated so the *maximum*
# cross-content similarity (parent-child pairs) stays below tau_hit=0.85
# while paraphrases stay above it:  generic in-topic ≈ 0.70, parent-child
# ≈ 0.79, paraphrase ≈ 0.93  (tests/test_traces.py asserts the separation).
_COS_THETA = float(np.sqrt(0.70))    # in-topic radial component
_COS_PHI = 0.93                      # paraphrase fidelity
_DEP_SHARE = 0.25                    # fraction of tangent dir shared w/ parent


def _unit(v: np.ndarray) -> np.ndarray:
    n = np.linalg.norm(v, axis=-1, keepdims=True)
    return v / np.maximum(n, 1e-12)


def _rng(seed: int, *ids: int) -> np.random.Generator:
    """Counter-based RNG: independent stream per (seed, ids) tuple."""
    return np.random.default_rng(np.random.SeedSequence([seed, *[i & 0x7FFFFFFF for i in ids]]))


class EmbeddingSpace:
    """Derives embeddings for (topic, content, paraphrase) ids on demand."""

    def __init__(self, dim: int = 64, seed: int = 0,
                 cos_theta: float = _COS_THETA, cos_phi: float = _COS_PHI):
        self.dim = dim
        self.seed = seed
        self.cos_theta = cos_theta
        self.sin_theta = float(np.sqrt(1 - cos_theta ** 2))
        self.cos_phi = cos_phi
        self.sin_phi = float(np.sqrt(1 - cos_phi ** 2))
        self._centroids: dict[int, np.ndarray] = {}
        self._tangents: dict[int, np.ndarray] = {}

    # -- pieces ------------------------------------------------------------
    def topic_centroid(self, topic: int) -> np.ndarray:
        c = self._centroids.get(topic)
        if c is None:
            c = _unit(_rng(self.seed, 1, topic).standard_normal(self.dim))
            self._centroids[topic] = c
        return c

    def _tangent(self, topic: int, content: int, parent_content: int = -1) -> np.ndarray:
        key = (topic << 32) ^ (content & 0xFFFFFFFF)
        u = self._tangents.get(key)
        if u is not None:
            return u
        c = self.topic_centroid(topic)
        g = _rng(self.seed, 2, topic, content).standard_normal(self.dim)
        u = _unit(g - (g @ c) * c)               # orthogonal to centroid
        if parent_content >= 0:
            up = self._tangent(topic, parent_content)
            u = _unit(_DEP_SHARE * up + (1 - _DEP_SHARE) * u)
            u = _unit(u - (u @ c) * c)
        self._tangents[key] = u
        return u

    # -- public ------------------------------------------------------------
    def content_embedding(self, topic: int, content: int,
                          parent_content: int = -1) -> np.ndarray:
        """Canonical embedding of a unique content item."""
        c = self.topic_centroid(topic)
        u = self._tangent(topic, content, parent_content)
        return _unit(self.cos_theta * c + self.sin_theta * u)

    def paraphrase(self, base: np.ndarray, topic: int, content: int,
                   occurrence: int) -> np.ndarray:
        """A paraphrased re-ask of the same content (occurrence>0)."""
        if occurrence == 0:
            return base
        g = _rng(self.seed, 3, topic, content, occurrence).standard_normal(self.dim)
        noise = _unit(g - (g @ base) * base)
        return _unit(self.cos_phi * base + self.sin_phi * noise)


def cosine(a: np.ndarray, b: np.ndarray) -> float:
    return float(np.dot(a, b))
