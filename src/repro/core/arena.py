"""Multi-policy arena: one-pass evaluation of P policies over one trace.

``run_many`` historically replayed the trace once per policy — P full
passes, P per-request scoring calls, P Gram matrices.  The arena replays
it ONCE: the P policies' resident slabs live in one stacked ``(P, S, D)``
:class:`ArenaStore`, every chunk of B requests is scored against all P
slabs by a single policy-stacked Top-1 launch
(``LookupBackend.top1_multi``, backed by ``kernels/ops.sim_top1_multi`` on
the device backends), and the per-policy replay that closes each chunk's
snapshot gap reuses the exact-incremental machinery of
``run_policy_batched`` — with the chunk's embedding stack and Gram matrix
computed once and shared by all P policies.

Decisions are bit-identical to the sequential per-policy replays
(``run_policy``); the same guarantees and the same fallbacks apply:

  - every query's running best is maintained against the entries resident
    at its own turn (rank-1 Gram-row rescores per intra-chunk admission,
    per policy);
  - a query whose running best was evicted mid-chunk, or whose decision
    could hinge on sub-epsilon float differences between scoring engines
    (a promoted or snapshot best within ``_EPS`` of ``tau_hit``), discards
    the snapshot and recomputes a fresh single-store backend Top-1 — the
    identical call ``run_policy`` makes.  The snapshot-near-``tau_hit``
    flag is a superset of ``run_policy_batched``'s protections: the
    stacked launch is a different dispatch shape than the per-request
    scan, so gate-adjacent snapshots always re-score on the reference
    engine (exactness stays modulo float-exact similarity ties between
    distinct embeddings, which the synthetic geometry excludes);
  - content mode needs no similarity work: the one-pass win is the shared
    trace walk plus the policies' vectorized batch hooks — runs of
    consecutive hits flush through ``on_hit_batch`` in one slab write.

Policy hooks run host-side exactly as the facade would drive them
(hit -> ``on_hit``, miss -> insert + ``on_admit`` + evict-while-over, a
below-threshold miss on resident content does not reinsert), and policies
exposing device eviction scoring hooks (RAC's ``value_backend``) are wired
to the backend the same way :class:`repro.cache.SemanticCache` wires them,
so RAC variants ride the arena unchanged.

``backend`` may be ``"numpy"``, ``"kernel"``, or ``"sharded"``; the
sharded backend shards the stacked slab's slot axis under ``shard_map``
(see ``ShardedKernelBackend.top1_multi``) and delegates flagged
single-query rescans to the dense kernel path (per-row scores are
row-independent, so the dense scan reproduces the sharded merge's
decision).
"""
from __future__ import annotations

import time

import numpy as np

from .simulator import _EPS, PolicyFactory, hr_full, with_seed
from .store import MutationJournal, ResidentStore
from .types import Stats, Trace


class _ArenaView(ResidentStore):
    """One policy's resident store: views into the arena's stacked arrays.

    Behaves exactly like a dense :class:`ResidentStore` (same slot
    allocation, same zero-freed-rows contract), but its ``emb``/``occ``/
    ``cid`` rows alias the arena's ``(P, S, D)`` buffers, so mutating
    through the view keeps the stacked launch's input current for free.
    Mutations *bump* the view's own journal (single-store backend calls
    key their mirrors on its version; a flagged-fallback full upload is
    fine, so no per-row log is kept) and stamp the arena's flat journal at
    row ``p * S + slot`` when a device backend is attached (the stacked
    mirror's dirty-row sync); host-only runs bump it instead.
    """

    def __init__(self, arena: "ArenaStore", p: int):
        self.capacity = arena.capacity
        self.emb = arena.emb[p]
        self.occ = arena.occ[p]
        self.cid = arena.cid[p]
        self.slot_of = {}
        self._free = list(range(arena.n_slots - 1, -1, -1))
        self.hwm = 0
        self._log = MutationJournal()
        self._arena = arena
        self._p = p

    def _stamp(self, slot: int):
        # journaling exists for device mirrors only: host-only arenas
        # (track_rows=False) skip it entirely — nothing keys on these
        # versions — while device arenas stamp the flat journal AND the
        # view's own row journal: the per-view consumers (quantized host
        # mirrors, the fused pipeline's topic-bucket indices) key on the
        # view version and use dirty_since for incremental refresh, so a
        # bare bump would force a full rebuild per mutation
        arena = self._arena
        if arena.track_rows:
            self._log.stamp(slot)
            arena._log.stamp(self._p * arena.n_slots + slot)

    # lean clones of ResidentStore.insert/remove: identical state changes,
    # no assert / placement-hook / stamp-method indirection — this pair
    # runs once per miss per policy and is a measurable slice of the sweep
    def insert(self, cid: int, emb) -> int:
        slot = self._free.pop()
        self.emb[slot] = emb
        self.occ[slot] = True
        self.cid[slot] = cid
        self.slot_of[cid] = slot
        if slot >= self.hwm:
            self.hwm = slot + 1
        self._stamp(slot)
        return slot

    def remove(self, cid: int) -> int:
        slot = self.slot_of.pop(cid)
        self.occ[slot] = False
        self.cid[slot] = -1
        # zero the freed row: device backends score the full fixed-shape
        # slab, and a zero embedding can never clear tau_hit > 0
        self.emb[slot] = 0.0
        self._free.append(slot)
        self._stamp(slot)
        return slot


class ArenaStore:
    """P stacked resident slabs sharing one ``(P, S, D)`` buffer.

    ``views[p]`` is policy p's :class:`ResidentStore`-compatible store;
    the stacked arrays are what ``top1_multi`` scores (device backends
    mirror the flat ``(P*S, D)`` slab against :attr:`dirty_since`)."""

    def __init__(self, n_policies: int, capacity: int, dim: int,
                 track_rows: bool = False):
        self.n_policies = n_policies
        self.capacity = capacity
        self.dim = dim
        self.n_slots = capacity + 1        # Alg. 1 insert-then-evict spare
        # per-row journaling feeds device dirty-row scatter; host-only
        # backends skip the log and pay only a version bump per mutation
        self.track_rows = track_rows
        self.emb = np.zeros((n_policies, self.n_slots, dim), np.float32)
        self.occ = np.zeros((n_policies, self.n_slots), bool)
        self.cid = np.full((n_policies, self.n_slots), -1, np.int64)
        self._log = MutationJournal()
        self.views = [_ArenaView(self, p) for p in range(n_policies)]

    @property
    def version(self) -> int:
        return self._log.version

    def dirty_since(self, version: int) -> set[int] | None:
        """Flat (p * S + slot) rows mutated after ``version``."""
        return self._log.dirty_since(version)

    def hwms(self) -> np.ndarray:
        """Per-policy high-water marks (the stacked launch's n_valid)."""
        return np.fromiter((v.hwm for v in self.views), dtype=np.int64,
                           count=self.n_policies)

    def __len__(self) -> int:
        return sum(len(v) for v in self.views)


def _flush_hits(pol, cids: list, reqs: list, ts: list):
    if cids:
        pol.on_hit_batch(cids, reqs, ts)
        cids.clear()
        reqs.clear()
        ts.clear()


def run_arena(trace: Trace, capacity: int,
              factories: dict[str, PolicyFactory],
              hit_mode: str = "content", tau_hit: float = 0.85,
              backend: str = "numpy", chunk: int = 512,
              use_pallas: bool = True,
              seed: int | None = None,
              quantized: bool | dict = False,
              pruned: bool | dict = False) -> list[Stats]:
    """One-pass arena replay of every factory (see module docstring).

    Returns one :class:`Stats` per factory, in dict order, with hit /
    miss / eviction counts bit-identical to ``run_policy`` per policy.
    ``wall_s`` reports each policy's amortized share (total arena wall
    time / P) so throughput comparisons against sequential runs stay
    apples-to-apples.  ``quantized`` routes the stacked Top-1 scan onto
    the int8 mirror path (:mod:`repro.cache.quantized`) — decisions are
    unchanged; the semantic-mode hit threshold is filled into the
    quantized config's certain-miss arm automatically.  ``pruned`` routes
    it through the topic-pruned two-stage scan (:mod:`repro.cache.
    pruned`) instead — each table-backed policy's probe runs over its own
    per-policy bucket index; table-less policies fall back to the exact
    per-view scan.  The two compose (``pruned`` + ``quantized``)."""
    from repro.cache.backends import KernelBackend, get_backend
    from repro.cache.facade import _VALUE_HOOKS

    names = list(factories)
    n_pol = len(names)
    if not n_pol:
        return []
    # resolve the backend FIRST and classify by the resolved instance, so
    # an already-built backend object (the contract get_backend documents)
    # selects the same arena wiring as its config-name spelling
    kw = {"use_pallas": use_pallas} if backend in ("kernel", "sharded") else {}
    if quantized:
        import dataclasses as _dc

        from repro.cache.quantized import as_quantized_config
        qcfg = as_quantized_config(quantized)
        if qcfg.tau_hit is None and hit_mode == "semantic":
            qcfg = _dc.replace(qcfg, tau_hit=tau_hit)
        kw["quantized"] = qcfg
    if pruned:
        import dataclasses as _dc

        from repro.cache.pruned import as_pruned_config
        pcfg = as_pruned_config(pruned)
        if pcfg.tau_hit is None and hit_mode == "semantic":
            pcfg = _dc.replace(pcfg, tau_hit=tau_hit)
        kw["pruned"] = pcfg
    be = get_backend(backend, **kw)
    device = be.name in ("kernel", "sharded")
    dim = trace.requests[0].emb.shape[0]
    # the quantized mirror and the pruned bucket indices key on the
    # arena's flat journal, so either path needs row tracking even on
    # the numpy backend
    arena = ArenaStore(n_pol, capacity, dim,
                       track_rows=device or bool(quantized) or bool(pruned))
    policies = [with_seed(factories[n], seed)(capacity, arena.views[i])
                for i, n in enumerate(names)]
    if pruned:
        # per-policy routing tables: each table-backed policy probes its
        # own topic structure; None entries take the exact per-view scan
        be.route_tables = [getattr(pol, "table", None) for pol in policies]

    # reference engine for flagged single-query rescans: the backend itself,
    # except under "sharded" where a dense kernel scan computes the same
    # per-row scores without re-fanning one query across the mesh
    ref_be = (KernelBackend(use_pallas=getattr(be, "use_pallas", use_pallas))
              if be.name == "sharded" else be)
    for pol in policies:
        for attr, method in _VALUE_HOOKS:
            if hasattr(pol, attr):
                setattr(pol, attr, getattr(ref_be, method))

    stats = [Stats(policy=n, capacity=capacity, requests=len(trace.requests))
             for n in names]
    semantic = hit_mode == "semantic"
    reqs = trace.requests
    step = max(1, chunk)
    t0 = time.perf_counter()
    if semantic:
        # per-policy carry state is chunk-local; allocate once per chunk
        for lo in range(0, len(reqs), step):
            block = reqs[lo:lo + step]
            b = len(block)
            embs = np.stack([r.emb for r in block]).astype(np.float32,
                                                          copy=False)
            snap_cid, snap_sim = be.top1_multi(arena, embs)
            gram = embs @ embs.T if 1 < b <= 8192 else None
            for p in range(n_pol):
                _replay_semantic(policies[p], arena.views[p], stats[p],
                                 block, embs, gram,
                                 np.asarray(snap_cid[p], np.int64).copy(),
                                 np.asarray(snap_sim[p], np.float64).copy(),
                                 capacity, tau_hit, ref_be)
    else:
        for lo in range(0, len(reqs), step):
            block = reqs[lo:lo + step]
            # extracted once, shared by every policy's replay
            cids = [r.cid for r in block]
            ts = [r.t for r in block]
            for p in range(n_pol):
                _replay_content(policies[p], arena.views[p], stats[p],
                                block, cids, ts, capacity)
    wall = time.perf_counter() - t0
    hrf = hr_full(trace)
    for s in stats:
        s.wall_s = wall / n_pol
        s.hr_full = hrf
    return stats


def _replay_content(pol, store, st: Stats, block, cids, ts, capacity: int):
    """Content-mode chunk replay: O(1) residency hits, batched hit runs.
    ``cids``/``ts`` are the chunk's request fields, extracted once by the
    caller and shared across all P policies; bound methods are hoisted —
    this body runs once per (request, policy) and its own overhead is a
    measurable slice of the sweep."""
    slot_of = store.slot_of
    insert, remove = store.insert, store.remove
    on_admit, victim = pol.on_admit, pol.victim
    on_hit_batch = pol.on_hit_batch
    hits = misses = evictions = 0
    pc: list = []
    pr: list = []
    pt: list = []
    for i, cid in enumerate(cids):
        if cid in slot_of:
            hits += 1
            pc.append(cid)
            pr.append(block[i])
            pt.append(ts[i])
            continue
        if pc:
            on_hit_batch(pc, pr, pt)
            pc, pr, pt = [], [], []
        misses += 1
        req = block[i]
        t = ts[i]
        insert(cid, req.emb)
        on_admit(cid, req, t)
        while len(slot_of) > capacity:
            remove(victim(t))
            evictions += 1
    if pc:
        on_hit_batch(pc, pr, pt)
    st.hits += hits
    st.misses += misses
    st.evictions += evictions


def _replay_semantic(pol, store, st: Stats, block, embs, gram,
                     best_cid, best_sim, capacity: int, tau_hit: float,
                     ref_be):
    """Semantic-mode chunk replay for one policy — the exact-incremental
    body of ``run_policy_batched`` against this policy's snapshot row,
    restructured so clean-hit runs are consumed without a per-request
    Python step.

    ``ok[j]`` marks queries whose snapshot decides a hit with no
    engine-drift risk: best over the hit gate, not epsilon-flagged, and
    not a host-promoted best sitting on the gate.  Hits never mutate
    residency, so a maximal ``ok`` run is one ``on_hit_batch`` flush; the
    first non-``ok`` query is handled individually (reference rescan when
    flagged, the admit/evict machinery on a miss).  An eviction flags
    every remaining query currently holding the victim as its best — a
    sticky superset of ``run_policy_batched``'s use-time ``gone`` check
    (strictly more reference rescans, identical decisions)."""
    b = len(block)
    # flagged[j]: query j's decision could hinge on a host-vs-backend (or
    # stacked-vs-single launch) float difference — force the reference
    # backend scan at its turn.  Snapshot bests already gate-adjacent are
    # flagged up front (see module docstring).
    flagged = np.abs(best_sim - tau_hit) <= _EPS
    promoted = np.zeros(b, dtype=bool)   # best came from a host rescore
    ok = (best_sim >= tau_hit) & ~flagged
    slot_of = store.slot_of
    i = 0
    while i < b:
        if ok[i]:
            rest = ok[i:]
            stop = int(np.argmin(rest))          # first False, 0 if none
            j = i + (stop if not rest[stop] else rest.size)
            st.hits += j - i
            # the facade notifies the HIT cid for each served query
            pol.on_hit_batch(best_cid[i:j].tolist(), block[i:j],
                             [r.t for r in block[i:j]])
            i = j
            continue
        req = block[i]
        c = int(best_cid[i])
        sim = float(best_sim[i])
        if flagged[i] or (promoted[i] and abs(sim - tau_hit) <= _EPS):
            c, sim = ref_be.top1(store, req.emb)
            c = int(c)
        if sim >= tau_hit:
            st.hits += 1
            pol.on_hit(c, req, req.t)
            i += 1
            continue
        st.misses += 1
        if req.cid in slot_of:
            i += 1
            continue   # paraphrase below tau_hit: resident, no reinsert
        store.insert(req.cid, req.emb)
        pol.on_admit(req.cid, req, req.t)
        evicted = []
        while len(slot_of) > capacity:
            v = pol.victim(req.t)
            store.remove(v)
            st.evictions += 1
            evicted.append(v)
        if i + 1 < b:
            tail_cid = best_cid[i + 1:]
            tail = best_sim[i + 1:]
            tail_flag = flagged[i + 1:]
            for v in evicted:
                tail_flag |= tail_cid == v
            if req.cid in slot_of:
                # exact incremental rescore: the one dirtied row is scored
                # against the remaining queries (strictly-better wins; a
                # near-tie flags the query for the reference scan instead)
                sims = (gram[i + 1:, i] if gram is not None else
                        embs[i + 1:] @ np.asarray(req.emb,
                                                  dtype=np.float32))
                tail_flag |= ((np.abs(sims - tail) <= _EPS)
                              & (np.maximum(sims, tail) >= tau_hit - _EPS))
                upd = sims > tail
                if upd.any():
                    tail[upd] = sims[upd]
                    tail_cid[upd] = req.cid
                    promoted[i + 1:][upd] = True
            ok[i + 1:] = ((tail >= tau_hit) & ~tail_flag
                          & ~(promoted[i + 1:]
                              & (np.abs(tail - tau_hit) <= _EPS)))
        i += 1
