"""Structural-importance ranking on the intra-topic dependency DAG.

Implements the paper's Appendix 7.2: a PageRank/TextRank-style random walk
with uniform restart on the *reversed* prerequisite edges, so importance
propagates from dependents back to their context anchors.  The stationary
distribution is computed by power iteration (Proposition 2).

``pagerank_reversed`` is the pure-numpy oracle used by tests;
``pagerank_power_jax`` is an equivalent jax.lax.while_loop formulation used
by the device-side scoring path.
"""
from __future__ import annotations

import numpy as np


def pagerank_reversed(edges: list[tuple[int, int]], n: int,
                      beta: float = 0.85, tol: float = 1e-10,
                      max_iter: int = 200) -> np.ndarray:
    """Stationary scores r(u) of the uniform-restart walk (Eq. 3/4).

    ``edges`` are prerequisite links (u -> v): u is an anchor required by v.
    The walk runs on reversed edges (v -> u): dependents push importance to
    their anchors.  Dangling nodes jump uniformly.
    """
    if n == 0:
        return np.zeros(0)
    # build reversed adjacency: from v to u for each (u, v)
    out_deg = np.zeros(n, dtype=np.int64)         # out-degree in reversed graph
    for (u, v) in edges:
        out_deg[v] += 1
    r = np.full(n, 1.0 / n)
    for _ in range(max_iter):
        contrib = np.zeros(n)
        # mass from dangling nodes (out_deg == 0 in reversed graph)
        dangling = r[out_deg == 0].sum() / n
        for (u, v) in edges:
            contrib[u] += r[v] / out_deg[v]
        r_new = (1.0 - beta) / n + beta * (contrib + dangling)
        if np.abs(r_new - r).sum() < tol:
            return r_new
        r = r_new
    return r


def pagerank_power_jax(adj: "jax.Array", beta: float = 0.85,
                       iters: int = 64) -> "jax.Array":
    """JAX power iteration on a dense reversed-transition matrix.

    ``adj[u, v] = 1`` iff prerequisite edge u -> v exists.  Returns r over n
    nodes.  Used for batched on-device re-scoring of topic DAGs.
    """
    import jax.numpy as jnp
    import jax

    n = adj.shape[0]
    out_deg = adj.sum(axis=0)                       # reversed out-degree of v
    # column-stochastic transition P[u, v] = adj[u,v] / out_deg[v]
    p = jnp.where(out_deg[None, :] > 0, adj / jnp.maximum(out_deg[None, :], 1), 0.0)
    dang = (out_deg == 0).astype(adj.dtype)

    def body(_, r):
        spread = p @ r + (dang @ r) / n
        return (1.0 - beta) / n + beta * spread

    r0 = jnp.full((n,), 1.0 / n, dtype=adj.dtype)
    return jax.lax.fori_loop(0, iters, body, r0)
