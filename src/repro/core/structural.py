"""Structural-importance ranking on the intra-topic dependency DAG.

Implements the paper's Appendix 7.2: a PageRank/TextRank-style random walk
with uniform restart on the *reversed* prerequisite edges, so importance
propagates from dependents back to their context anchors.  The stationary
distribution is computed by power iteration (Proposition 2).

``pagerank_reversed`` is the pure-numpy oracle used by tests;
``pagerank_power_jax`` is an equivalent jax power iteration, and
``pagerank_scores`` selects between the two — RAC's
``structural_mode="pagerank"`` drives its refreshes through it with
``device=True``, so the appendix path runs on the accelerator and the
oracle stays the parity reference.
"""
from __future__ import annotations

import functools

import numpy as np


def pagerank_reversed(edges: list[tuple[int, int]], n: int,
                      beta: float = 0.85, tol: float = 1e-10,
                      max_iter: int = 200) -> np.ndarray:
    """Stationary scores r(u) of the uniform-restart walk (Eq. 3/4).

    ``edges`` are prerequisite links (u -> v): u is an anchor required by v.
    The walk runs on reversed edges (v -> u): dependents push importance to
    their anchors.  Dangling nodes jump uniformly.
    """
    if n == 0:
        return np.zeros(0)
    # build reversed adjacency: from v to u for each (u, v)
    out_deg = np.zeros(n, dtype=np.int64)         # out-degree in reversed graph
    for (u, v) in edges:
        out_deg[v] += 1
    r = np.full(n, 1.0 / n)
    for _ in range(max_iter):
        contrib = np.zeros(n)
        # mass from dangling nodes (out_deg == 0 in reversed graph)
        dangling = r[out_deg == 0].sum() / n
        for (u, v) in edges:
            contrib[u] += r[v] / out_deg[v]
        r_new = (1.0 - beta) / n + beta * (contrib + dangling)
        if np.abs(r_new - r).sum() < tol:
            return r_new
        r = r_new
    return r


def pagerank_power_jax(adj: "jax.Array", beta: float = 0.85,
                       iters: int = 64) -> "jax.Array":
    """JAX power iteration on a dense reversed-transition matrix.

    ``adj[u, v] = 1`` iff prerequisite edge u -> v exists.  Returns r over n
    nodes.  Used for batched on-device re-scoring of topic DAGs.
    """
    import jax.numpy as jnp
    import jax

    n = adj.shape[0]
    out_deg = adj.sum(axis=0)                       # reversed out-degree of v
    # column-stochastic transition P[u, v] = adj[u,v] / out_deg[v]
    p = jnp.where(out_deg[None, :] > 0, adj / jnp.maximum(out_deg[None, :], 1), 0.0)
    dang = (out_deg == 0).astype(adj.dtype)

    def body(_, r):
        spread = p @ r + (dang @ r) / n
        return (1.0 - beta) / n + beta * spread

    r0 = jnp.full((n,), 1.0 / n, dtype=adj.dtype)
    return jax.lax.fori_loop(0, iters, body, r0)


@functools.lru_cache(maxsize=1)
def _pagerank_jit():
    import jax
    return jax.jit(pagerank_power_jax, static_argnames=("beta", "iters"))


def pagerank_scores(edges: list[tuple[int, int]], n: int,
                    beta: float = 0.85, device: bool = False,
                    iters: int = 128) -> np.ndarray:
    """Stationary scores through a selectable engine.

    ``device=False`` runs the numpy oracle (tolerance-converged);
    ``device=True`` builds the dense reversed-transition adjacency and runs
    the jitted :func:`pagerank_power_jax` power iteration (``iters=128``
    puts the iteration error at ``beta^128 ≈ 1e-9``, below float32
    resolution, so the two engines agree to numerical precision on simple
    graphs — edges are assumed unique, which DetectParent's one-parent
    rule guarantees)."""
    if not device:
        return pagerank_reversed(edges, n, beta=beta)
    if n == 0:
        return np.zeros(0)
    adj = np.zeros((n, n), dtype=np.float32)
    for (u, v) in edges:
        adj[u, v] = 1.0
    r = _pagerank_jit()(adj, beta=beta, iters=iters)
    return np.asarray(r, dtype=np.float64)
