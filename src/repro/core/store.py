"""Slot-based resident-entry store shared by the simulator and policies.

Keeps a dense numpy slab of resident embeddings for vectorized semantic hit
determination (the `similarity_topk` Pallas kernel consumes the same layout
on TPU), plus per-slot metadata arrays that relation-aware policies (RAC)
score over in O(m) vectorized time.

Entries are keyed by content id (``cid``): re-admitting content that was
evicted earlier re-uses the same key, which matches query-level caching in
the paper (one entry per unique query content).

Slot *placement* is a policy of the store subclass: the base class packs a
single free-list (LIFO reuse, so occupied slots stay below a high-water
mark ``hwm`` that device backends pass as the kernel's runtime ``n_valid``);
:class:`repro.cache.sharded.ShardedStore` overrides ``_alloc``/``_release``
to route new entries onto the least-loaded shard of a row-partitioned slab.

Mutation tracking lives in :class:`MutationJournal`, shared with
:class:`repro.core.policy_table.PolicyTable` (the RAC scoring slabs ride
the same dirty-row sync protocol as the embedding slab).  ``version`` is a
globally-unique mutation stamp: two journaled objects carry the same
version only if their arrays are identical (deep copies that have not
diverged), which lets device backends cache an uploaded copy keyed by
version alone.  The bounded journal records which row each stamp touched,
so a device backend holding arrays uploaded at an older version of *this*
lineage can ask :meth:`MutationJournal.dirty_since` for the exact row set
to DMA instead of re-uploading everything.
"""
from __future__ import annotations

import itertools
from collections import deque

import numpy as np

_STAMP = itertools.count(1)     # global mutation stamps (see class docstring)

_JOURNAL_LEN = 4096             # mutations remembered for dirty-row sync


class MutationJournal:
    """Bounded (version, row) mutation log with globally-unique stamps.

    One journal tracks one row-indexed axis of one array family (the
    store's slot axis, the policy table's slot axis, its topic axis, ...).
    Deep copies keep their history: stamps are globally unique, so a
    diverged copy's version can never be mistaken for this lineage's.
    """

    def __init__(self, maxlen: int = _JOURNAL_LEN):
        self.maxlen = maxlen
        self.version = next(_STAMP)
        # (version, row) pairs, version-ascending.  _base is the version
        # held just before the oldest journal entry — the earliest version
        # dirty_since can answer for.
        self._journal: deque[tuple[int, int]] = deque()
        self._base = self.version

    def stamp(self, row: int):
        """Record a mutation of ``row`` under a fresh global version."""
        self.version = next(_STAMP)
        self._journal.append((self.version, row))
        while len(self._journal) > self.maxlen:
            self._base = self._journal.popleft()[0]

    def bump(self):
        """Advance the version WITHOUT recording the row — the cheap path
        for owners whose mirrors never scatter (e.g. the arena's per-view
        journals, where a flagged fallback re-upload is fine).  Staleness
        detection stays exact: the base moves with the version, so
        ``dirty_since`` answers the conservative ``None`` (full upload)
        for every version that predates the bump."""
        self.version = next(_STAMP)
        self._base = self.version

    def dirty_since(self, version: int) -> set[int] | None:
        """Rows mutated after ``version``, or None if unanswerable.

        ``version`` must be a stamp this exact lineage has held and that
        is still covered by the journal; anything else returns None (aged
        out, or a foreign/diverged lineage's stamp).
        """
        if version == self.version:
            return set()
        if version < self._base:
            return None                    # aged out (or foreign lineage)
        known = version == self._base
        dirty: set[int] = set()
        for v, row in self._journal:
            if v <= version:
                known = known or v == version
                continue
            if not known:
                return None   # ``version`` was never a stamp of this lineage
            dirty.add(row)
        return dirty if known else None


class ResidentStore:
    def __init__(self, capacity: int, dim: int, n_slots: int | None = None):
        # one spare slot: Alg.1 inserts first, then evicts while |C| > C
        self.capacity = capacity
        n = capacity + 1 if n_slots is None else n_slots
        assert n >= capacity + 1
        self.emb = np.zeros((n, dim), dtype=np.float32)
        self.occ = np.zeros(n, dtype=bool)
        self.cid = np.full(n, -1, dtype=np.int64)
        self.slot_of: dict[int, int] = {}      # cid -> slot
        self._free: list[int] = list(range(n - 1, -1, -1))
        self.hwm = 0                           # all occupied slots < hwm
        # deepcopied with the store, so a restored checkpoint keeps its own
        # lineage's history
        self._log = MutationJournal()

    @property
    def version(self) -> int:
        return self._log.version

    def _stamp(self, slot: int):
        self._log.stamp(slot)

    def dirty_since(self, version: int) -> set[int] | None:
        """Slots mutated after ``version`` (see
        :meth:`MutationJournal.dirty_since`)."""
        return self._log.dirty_since(version)

    def __len__(self) -> int:
        return len(self.slot_of)

    def __contains__(self, cid: int) -> bool:
        return cid in self.slot_of

    def keys(self):
        return self.slot_of.keys()

    # -- slot placement (overridden by sharded stores) ----------------------
    def _alloc(self) -> int:
        return self._free.pop()

    def _release(self, slot: int):
        self._free.append(slot)

    def insert(self, cid: int, emb: np.ndarray) -> int:
        assert cid not in self.slot_of
        slot = self._alloc()
        self.emb[slot] = emb
        self.occ[slot] = True
        self.cid[slot] = cid
        self.slot_of[cid] = slot
        self.hwm = max(self.hwm, slot + 1)
        self._stamp(slot)
        return slot

    def remove(self, cid: int) -> int:
        slot = self.slot_of.pop(cid)
        self.occ[slot] = False
        self.cid[slot] = -1
        # zero the freed row: device backends score the full fixed-shape
        # slab, and a zero embedding can never clear tau_hit > 0
        self.emb[slot] = 0.0
        self._release(slot)
        self._stamp(slot)
        return slot

    # -- semantic hit determination (identical for every policy) -----------
    def nearest(self, q: np.ndarray) -> tuple[int, float]:
        """Top-1 resident by cosine similarity. Returns (cid, sim) or (-1, -inf)."""
        if not self.slot_of:
            return -1, float("-inf")
        sims = self.emb @ q
        sims[~self.occ] = -np.inf
        s = int(np.argmax(sims))
        return int(self.cid[s]), float(sims[s])
