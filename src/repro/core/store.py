"""Slot-based resident-entry store shared by the simulator and policies.

Keeps a dense numpy slab of resident embeddings for vectorized semantic hit
determination (the `similarity_topk` Pallas kernel consumes the same layout
on TPU), plus per-slot metadata arrays that relation-aware policies (RAC)
score over in O(m) vectorized time.

Entries are keyed by content id (``cid``): re-admitting content that was
evicted earlier re-uses the same key, which matches query-level caching in
the paper (one entry per unique query content).
"""
from __future__ import annotations

import numpy as np


class ResidentStore:
    def __init__(self, capacity: int, dim: int):
        # one spare slot: Alg.1 inserts first, then evicts while |C| > C
        self.capacity = capacity
        n = capacity + 1
        self.emb = np.zeros((n, dim), dtype=np.float32)
        self.occ = np.zeros(n, dtype=bool)
        self.cid = np.full(n, -1, dtype=np.int64)
        self.slot_of: dict[int, int] = {}      # cid -> slot
        self._free: list[int] = list(range(n - 1, -1, -1))

    def __len__(self) -> int:
        return len(self.slot_of)

    def __contains__(self, cid: int) -> bool:
        return cid in self.slot_of

    def keys(self):
        return self.slot_of.keys()

    def insert(self, cid: int, emb: np.ndarray) -> int:
        assert cid not in self.slot_of
        slot = self._free.pop()
        self.emb[slot] = emb
        self.occ[slot] = True
        self.cid[slot] = cid
        self.slot_of[cid] = slot
        return slot

    def remove(self, cid: int) -> int:
        slot = self.slot_of.pop(cid)
        self.occ[slot] = False
        self.cid[slot] = -1
        # zero the freed row: device backends score the full fixed-shape
        # slab, and a zero embedding can never clear tau_hit > 0
        self.emb[slot] = 0.0
        self._free.append(slot)
        return slot

    # -- semantic hit determination (identical for every policy) -----------
    def nearest(self, q: np.ndarray) -> tuple[int, float]:
        """Top-1 resident by cosine similarity. Returns (cid, sim) or (-1, -inf)."""
        if not self.slot_of:
            return -1, float("-inf")
        sims = self.emb @ q
        sims[~self.occ] = -np.inf
        s = int(np.argmax(sims))
        return int(self.cid[s]), float(sims[s])
