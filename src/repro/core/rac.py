"""RAC — Relation-Aware Cache replacement (the paper's contribution).

Implements, faithfully:

  - Alg. 1  main workflow: on every arrival refresh TP, update TSI, insert,
            and evict ``argmin TP(Z_i)·TSI(q_i)`` under capacity pressure.
  - Alg. 2  cache-side topic routing + O(1) lazy TP refresh
            (Def. 1:  TP_t(s) = Σ_{i∈H_t(s)} (1/2)^{α(t-i)}, maintained via
            the closed form (1/2)^{α(t-t_last)} · TP_last).
  - Alg. 3  constant-time TSI update cascade
            (Def. 2:  TSI(q) = freq(q) + λ·dep(q)), with the one-parent
            DetectParent rule  score(k,t) = sim(q_k,q_t)/(t-k)  over cached
            same-topic candidates inside a look-back window T, gated by
            τ_edge.
  - Alg. 4  representative-index shortlist routing (top-K + similarity gate).
  - Alg. 5  TSI-max anchor representative with lazy refresh on eviction and
            empty-topic deletion.
  - App.7.2 optional PageRank structural refinement
            (``structural_mode="pagerank"``, refreshed through
            ``structural.pagerank_scores`` — the jax power iteration on
            device by default, the numpy oracle with
            ``structural_device=False``).

Ablations (§4.4): ``use_tp=False`` → RAC w/o TP; ``use_tsi=False`` → RAC
w/o TSI.  Ties are broken by (value, last-access, cid) for determinism.

State layout — the PolicyTable split
------------------------------------
The per-request *semantics* (routing, DetectParent, the TSI cascade,
anchor maintenance, ghost metadata) live here as plain Python driving
dense arrays; the arrays themselves — the slot-aligned freq/dep/tsi/
topic_of/last_t/arrive_t slabs, the per-topic tp_last/t_last tables, and
the dense topic-representative matrix — are owned by a
:class:`repro.core.policy_table.PolicyTable`.  Every mutation stamps the
table's slot/topic :class:`~repro.core.store.MutationJournal`, so device
backends mirror the scoring state with dirty-row scatters and serve the
whole decision surface (Top-1 lookup + Alg. 4 routing + Eq. 1 victim
scoring) from one fused launch (``decide_batch``).  A full host eviction
scan stays one vectorized O(m) pass over the same slabs; the facade wires
``value_backend`` so Eq. 1 scoring can also run through
``kernels/ops.rac_value`` on device.
"""
from __future__ import annotations

import numpy as np

from . import structural
from .policies import Policy
from .policy_table import PolicyTable

_NEG = -1.0


class TopicState:
    """Host bookkeeping for one live topic (Alg. 2/5).

    The representative embedding itself lives in the PolicyTable's dense
    ``rep`` matrix so device routing can score every topic in one kernel;
    ``rep`` here is a journaled read/write view of that row."""

    __slots__ = ("tid", "table", "src", "members", "dirty")

    def __init__(self, tid: int, table: PolicyTable, rep: np.ndarray,
                 src: int):
        self.tid = tid
        self.table = table
        self.src = src                 # anchor cid realizing rep (Alg. 5)
        self.members: set[int] = set()
        self.dirty = False             # anchor invalidated by eviction
        table.set_rep(tid, rep)

    @property
    def rep(self) -> np.ndarray:
        return self.table.rep[self.tid]

    @rep.setter
    def rep(self, emb: np.ndarray):
        self.table.set_rep(self.tid, emb)


class RACPolicy(Policy):
    name = "RAC"

    def __init__(self, capacity, store=None, *,
                 tau_route: float = 0.65,      # topic routing gate (Alg. 2/4)
                 tau_edge: float = 0.60,       # dependency-link gate (§3.3)
                 alpha: float = 0.001,         # TP decay coefficient (Def. 1)
                 lam: float = 2.0,             # structural weight λ (Def. 2)
                 lookback: int = 64,           # DetectParent window T
                 shortlist_k: int = 8,         # ANN shortlist size (Alg. 4)
                 use_tp: bool = True,
                 use_tsi: bool = True,
                 structural_mode: str = "onehop",   # "onehop" | "pagerank"
                 structural_device: bool = True,
                                               # pagerank engine: jax power
                                               # iteration vs numpy oracle
                 pagerank_beta: float = 0.85,
                 pagerank_every: int = 64,     # evictions between PR refreshes
                 topic_memory: bool = True,    # Alg.2 Data: TP state persists
                                               # "for each appeared topic";
                                               # False = Alg.5-literal (delete
                                               # state with the empty topic)
                 value_mode: str = "normalized",
                                               # "normalized": TP·TSI/Σ_topic TSI
                                               #   — the §3.1 derivation reading
                                               #   (Value ≈ π_Z·p(q|Z); p(q|Z) is
                                               #   a normalized conditional)
                                               # "paper": literal Eq.1 TP·TSI
                                               #   product of raw counters
                 probation: int = 0,           # beyond-paper: entries younger
                                               # than this are eviction-exempt
                 ghost_limit: int = 1 << 18,   # FIFO bound on evicted-entry
                                               # lifetime metadata (g_freq/g_dep)
                 ghost_topic_limit: int = 4096,
                                               # FIFO bound on the ghost topic
                                               # memory (dead topics' TP state)
                 **kw):
        super().__init__(capacity, store)
        assert store is not None, "RAC scores over the resident store"
        self.tau_route = tau_route
        self.tau_edge = tau_edge
        self.alpha = alpha
        self.lam = lam
        self.lookback = lookback
        self.shortlist_k = shortlist_k
        self.use_tp = use_tp
        self.use_tsi = use_tsi
        self.structural_mode = structural_mode
        self.structural_device = structural_device
        self.pr_beta = pagerank_beta
        self.pr_every = max(1, pagerank_every)
        self.topic_memory = topic_memory
        self.value_mode = value_mode
        self.probation = probation

        # all scoring slabs (slot axis) and topic tables (topic axis) live
        # in the journaled PolicyTable so device backends can mirror them
        self.table = PolicyTable(store.emb.shape[0], store.emb.shape[1])

        # lifetime relation metadata (Def. 2: freq(q) counts hits "so far in
        # topic s" — a lifetime counter that survives eviction; par(q_t) "is
        # cached for future accesses").  Bounded FIFO ghosts, kept in the
        # shared GhostTier structure (deferred import: repro.cache imports
        # this module through the core package, so a module-level import
        # here would close the cycle mid-initialization).
        from repro.cache.tiers import GhostTier
        # cid -> (freq, dep, tid); batch_div=16 reproduces the historical
        # amortized drop loop bit-for-bit
        self.ghosts = GhostTier(ghost_limit, batch_div=16)
        self.ghost_limit = ghost_limit
        self.ghost_topic_limit = ghost_topic_limit
        self.par: dict[int, int] = {}          # cid -> parent cid (or -1)
        self.children: dict[int, set[int]] = {}  # resident DAG (for pagerank)

        self.topics: dict[int, TopicState] = {}
        self._next_tid = 0
        # ghost topic memory (beyond-paper option): tid -> (rep, tp, t_last)
        self.ghost_topics = GhostTier(ghost_topic_limit)
        self._evictions = 0
        self._pr_scores: dict[int, float] = {}   # cid -> pagerank structural term
        # optional device-side Eq.1 scorer (repro.cache wires the lookup
        # backend's rac_value here); signature
        # (tsi, tids, tp_last, t_last, alpha, t_now) -> values
        self.value_backend = None

    # -- ghost views (the authoritative records live in self.ghosts) -------
    @property
    def g_freq(self) -> dict[int, float]:
        """Lifetime hit counters of evicted entries (read-only view)."""
        return {c: e[0] for c, e in self.ghosts.items()}

    @property
    def g_dep(self) -> dict[int, float]:
        """Lifetime dependency counters of evicted entries (read-only)."""
        return {c: e[1] for c, e in self.ghosts.items()}

    # -- table views (the authoritative arrays live in self.table) ---------
    freq = property(lambda self: self.table.freq)
    dep = property(lambda self: self.table.dep)
    tsi = property(lambda self: self.table.tsi)
    topic_of = property(lambda self: self.table.topic_of)
    last_t = property(lambda self: self.table.last_t)
    arrive_t = property(lambda self: self.table.arrive_t)
    tp_last = property(lambda self: self.table.tp_last)
    t_last = property(lambda self: self.table.t_last)

    # ------------------------------------------------------------------ TP
    def _grow_tp(self, tid: int):
        self.table.grow_topics(tid)

    def tp_now(self, tid: int, t: int) -> float:
        """Lazy closed-form evaluation (Def. 1)."""
        return float(0.5 ** (self.alpha * (t - self.t_last[tid])) * self.tp_last[tid])

    def _refresh_tp(self, tid: int, t: int):
        """Decay-and-increment on a topic hit (Alg. 2 lines 6-7)."""
        self.tp_last[tid] = 0.5 ** (self.alpha * (t - self.t_last[tid])) * self.tp_last[tid] + 1.0
        self.t_last[tid] = t
        self.table.touch_topic(tid)

    # -------------------------------------------------------------- routing
    def _refresh_anchor(self, ts: TopicState):
        """Lazy TSI-max anchor refresh (Alg. 5 Refresh)."""
        if not ts.dirty:
            return
        best, best_v = -1, -np.inf
        for cid in ts.members:
            s = self.store.slot_of[cid]
            v = (self.tsi[s], -self.last_t[s], -cid)   # deterministic ties
            if best < 0 or v > best_v:
                best, best_v = cid, v
        if best >= 0:
            ts.src = best
            ts.rep = self.store.emb[self.store.slot_of[best]]
        ts.dirty = False

    def _route(self, emb: np.ndarray, t: int) -> int:
        """Alg. 4: shortlist over representatives + similarity gate."""
        if self.topics:
            tids = list(self.topics.keys())
            for tid in tids:
                self._refresh_anchor(self.topics[tid])
            # the dense table IS the stacked representative matrix: one
            # fancy-index gather replaces per-topic stacking
            reps = self.table.rep[np.fromiter(tids, dtype=np.int64,
                                              count=len(tids))]
            sims = reps @ emb
            k = min(self.shortlist_k, len(tids))
            short = np.argpartition(-sims, k - 1)[:k]
            best = max(short, key=lambda i: (sims[i], -tids[i]))
            if sims[best] >= self.tau_route:
                return tids[best]
        # beyond-paper: try ghost topic memory before creating a new topic
        if self.topic_memory and self.ghost_topics:
            gids = list(self.ghost_topics.keys())
            reps = np.stack([self.ghost_topics[g][0] for g in gids])
            sims = reps @ emb
            gi = int(np.argmax(sims))
            if sims[gi] >= self.tau_route:
                tid = gids[gi]
                rep, tp_last, t_last = self.ghost_topics.pop(tid)
                ts = TopicState(tid, self.table, rep, -1)
                ts.dirty = False
                self.topics[tid] = ts
                self.tp_last[tid] = tp_last
                self.t_last[tid] = t_last
                self.table.touch_topic(tid)
                return tid
        return -1

    def _new_topic(self, emb: np.ndarray, src: int, t: int) -> int:
        tid = self._next_tid
        self._next_tid += 1
        self._grow_tp(tid)
        ts = TopicState(tid, self.table, emb, src)
        self.topics[tid] = ts
        self.tp_last[tid] = 0.0
        self.t_last[tid] = t
        self.table.touch_topic(tid)
        return tid

    # ------------------------------------------------------------- parents
    def _detect_parent(self, cid: int, emb: np.ndarray, tid: int, t: int) -> int:
        """DetectParent (§3.3): Top-1 cached same-topic predecessor within
        the look-back window under score = sim/(t-k), gated by τ_edge."""
        ts = self.topics[tid]
        cands, slots = [], []
        for other in ts.members:
            if other == cid:
                continue
            s = self.store.slot_of[other]
            dt = t - int(self.last_t[s])
            if 0 < dt <= self.lookback:
                cands.append((other, dt))
                slots.append(s)
        if not cands:
            return -1
        sims = self.store.emb[slots] @ emb
        best, best_score = -1, -np.inf
        for (other, dt), sim in zip(cands, sims):
            if sim < self.tau_edge:
                continue
            sc = sim / dt
            if sc > best_score or (sc == best_score and other < best):
                best, best_score = other, sc
        return best

    # ------------------------------------------------------------ TSI (Alg.3)
    def _update_tsi(self, cid: int, emb: np.ndarray, tid: int, t: int):
        s = self.store.slot_of[cid]
        self.freq[s] += 1.0
        self.tsi[s] = self.freq[s] + self.lam * self.dep[s]
        self.table.touch_slot(s)
        if cid in self.par:
            qp, new = self.par[cid], False
        else:
            qp = self._detect_parent(cid, emb, tid, t)
            self.par[cid] = qp
            new = True
            if qp >= 0:
                self.children.setdefault(qp, set()).add(cid)
        if qp >= 0 and qp in self.store.slot_of:
            self.children.setdefault(qp, set()).add(cid)
            sp = self.store.slot_of[qp]
            self.dep[sp] += self.freq[s] if new else 1.0
            self.tsi[sp] = self.freq[sp] + self.lam * self.dep[sp]
            self.table.touch_slot(sp)
            pt = int(self.topic_of[sp])
            if pt in self.topics and self.topics[pt].src == qp:
                pass                                   # anchor strengthened
            elif pt in self.topics and self.tsi[sp] > self._anchor_tsi(pt):
                self._set_anchor(pt, qp, sp)

    def _anchor_tsi(self, tid: int) -> float:
        ts = self.topics[tid]
        if ts.src < 0 or ts.src not in self.store.slot_of:
            return -np.inf
        return float(self.tsi[self.store.slot_of[ts.src]])

    def _set_anchor(self, tid: int, cid: int, slot: int):
        ts = self.topics[tid]
        ts.src = cid
        ts.rep = self.store.emb[slot]
        ts.dirty = False

    # ------------------------------------------------------------- protocol
    def _arrive(self, cid: int, req, t: int, is_admit: bool):
        s = self.store.slot_of[cid]
        if is_admit:
            # restore lifetime counters (ghost metadata) or start fresh
            ghost = self.ghosts.pop(cid, None)
            self.freq[s] = ghost[0] if ghost is not None else 0.0
            self.dep[s] = ghost[1] if ghost is not None else 0.0
            self.tsi[s] = self.freq[s] + self.lam * self.dep[s]
            self.arrive_t[s] = t
            tid = self._route(req.emb, t)
            if tid < 0:
                tid = self._new_topic(req.emb, cid, t)
            self.topic_of[s] = tid
            self.table.touch_slot(s)
            self.topics[tid].members.add(cid)
        else:
            tid = int(self.topic_of[s])
            if tid not in self.topics:          # defensive; should not happen
                tid = self._new_topic(self.store.emb[s], cid, t)
                self.topic_of[s] = tid
                self.table.touch_slot(s)
                self.topics[tid].members.add(cid)
        self._refresh_tp(tid, t)                # Alg. 2: topic hit
        self._update_tsi(cid, req.emb, tid, t)  # Alg. 3
        self.last_t[s] = t
        self.table.touch_slot(s)
        # Alg. 5 OnInsert: promote anchor if newcomer has max TSI
        ts = self.topics[tid]
        if is_admit:
            self._refresh_anchor(ts)
            if ts.src < 0 or self.tsi[s] > self._anchor_tsi(tid):
                self._set_anchor(tid, cid, s)

    def on_hit(self, cid, req, t):
        self._arrive(cid, req, t, is_admit=False)

    def on_admit(self, cid, req, t):
        self._arrive(cid, req, t, is_admit=True)

    # ------------------------------------------------------------- eviction
    def _structural_refresh(self):
        """Optional App. 7.2: PageRank over resident intra-topic DAGs
        (the jax power iteration by default; ``structural_device=False``
        selects the numpy oracle)."""
        self._pr_scores.clear()
        for tid, ts in self.topics.items():
            members = [c for c in ts.members if c in self.store.slot_of]
            if len(members) < 2:
                continue
            idx = {c: i for i, c in enumerate(members)}
            edges = []
            for c in members:
                p = self.par.get(c, -1)
                if p >= 0 and p in idx:
                    edges.append((idx[p], idx[c]))
            if not edges:
                continue
            r = structural.pagerank_scores(edges, len(members),
                                           beta=self.pr_beta,
                                           device=self.structural_device)
            scale = len(members)                 # r ~ 1/n → scale to O(1)
            for c, i in idx.items():
                self._pr_scores[c] = scale * float(r[i])

    def _residents(self) -> tuple[np.ndarray, np.ndarray]:
        """(cids, slots) of every resident, in insertion order."""
        n = len(self.store.slot_of)
        return (np.fromiter(self.store.slot_of.keys(), dtype=np.int64,
                            count=n),
                np.fromiter(self.store.slot_of.values(), dtype=np.int64,
                            count=n))

    def value_scores(self, t: int,
                     residents: tuple[np.ndarray, np.ndarray] | None = None
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized Value(q) = TP(Z_q)·TSI(q) over all residents."""
        cids, slots = residents if residents is not None else \
            self._residents()
        tids = self.topic_of[slots]
        if self.use_tsi:
            if self.structural_mode == "pagerank" and self._pr_scores:
                pr = np.array([self._pr_scores.get(int(c), 0.0) for c in cids])
                tsi = self.freq[slots] + self.lam * pr
            else:
                tsi = self.tsi[slots]
        else:
            tsi = np.ones(len(slots))
        if self.value_mode == "normalized" and self.use_tsi:
            # p(q|s) reading of §3.1: normalize TSI by resident topic mass
            mass = np.zeros(int(tids.max()) + 1)
            np.add.at(mass, tids, tsi)
            tsi = tsi / np.maximum(mass[tids], 1e-9)
        if not self.use_tp:
            return cids, tsi
        if self.value_backend is not None:
            return cids, self.value_backend(tsi, tids, self.tp_last,
                                            self.t_last, self.alpha, t)
        tp = 0.5 ** (self.alpha * (t - self.t_last[tids])) * self.tp_last[tids]
        return cids, tp * tsi

    def victim(self, t):
        if self.structural_mode == "pagerank" and self._evictions % self.pr_every == 0:
            self._structural_refresh()
        self._evictions += 1
        cids, slots = self._residents()
        cids, values = self.value_scores(t, (cids, slots))
        if self.probation > 0:
            # beyond-paper recency guard: fresh entries are exempt unless
            # everything resident is fresh
            guarded = (t - self.arrive_t[slots]) < self.probation
            if not guarded.all():
                values = np.where(guarded, np.inf, values)
        # deterministic: min value, then least-recently-used, then smallest cid
        order = np.lexsort((cids, self.last_t[slots], values))
        victim = int(cids[order[0]])
        self._forget(victim)
        return victim

    def _forget(self, cid: int):
        s = self.store.slot_of[cid]
        tid = int(self.topic_of[s])
        ts = self.topics.get(tid)
        if ts is not None:
            ts.members.discard(cid)
            if not ts.members:
                # Alg. 5: delete empty topic (optionally remember TP state)
                if self.topic_memory:
                    # bounded by ghost_topic_limit (FIFO drop of the oldest)
                    self.ghost_topics.put(tid, (ts.rep.copy(),
                                                float(self.tp_last[tid]),
                                                int(self.t_last[tid])))
                del self.topics[tid]
                self.table.clear_topic(tid)
            elif ts.src == cid:
                ts.src = -1
                ts.dirty = True                 # lazy refresh (Alg. 5 OnEvict)
        # persist lifetime counters as ghost metadata (Def. 2 semantics);
        # par(cid) stays cached (§3.3).  Resident-DAG edges are pruned.
        # The GhostTier enforces the FIFO bound (limit//16 drop batches
        # amortize the dict churn; the bound stays hard for tiny limits).
        for old in self.ghosts.put(cid, (float(self.freq[s]),
                                         float(self.dep[s]), tid)):
            self.par.pop(old, None)
        p = self.par.get(cid)
        if p is not None and p >= 0 and p in self.children:
            self.children[p].discard(cid)
        self.children.pop(cid, None)            # children keep their cached par
        self.table.clear_slot(s)
        self._pr_scores.pop(cid, None)

    # ------------------------------------------------- tiering integration
    def ghost_meta(self, cid: int) -> dict | None:
        """Snapshot the just-forgotten entry's relation evidence for the
        tier manager (called by the facade right after an eviction, while
        the ghost record is guaranteed fresh).  Carries the lifetime
        counters plus the topic's TP state so a ghost revival can rebuild
        both — even after this policy's own bounded ghosts age it out."""
        e = self.ghosts.get(cid)
        if e is None:
            return None
        freq, dep, tid = e
        if tid in self.topics:
            tp, tl = float(self.tp_last[tid]), int(self.t_last[tid])
        elif tid in self.ghost_topics:
            _, tp, tl = self.ghost_topics[tid]
        else:
            tp, tl = 0.0, 0
        return {"freq": freq, "dep": dep, "tid": int(tid),
                "tp": float(tp), "tl": int(tl)}

    def revive_ghost(self, cid: int, meta: dict, rep=None):
        """Feed tier-preserved relation evidence back in at re-admission
        (called by the facade *before* ``on_admit``, so the normal arrival
        path restores the counters).  The policy's own records win when
        still present; the tier metadata only fills what aged out."""
        tid = int(meta.get("tid", -1))
        if cid not in self.ghosts:
            for old in self.ghosts.put(cid, (float(meta.get("freq", 0.0)),
                                             float(meta.get("dep", 0.0)),
                                             tid)):
                self.par.pop(old, None)
        if (self.topic_memory and rep is not None and 0 <= tid
                and tid < self._next_tid and tid not in self.topics
                and tid not in self.ghost_topics):
            # the demoted topic re-enters hot through _route's ghost-topic
            # revival, carrying its preserved TP state
            self.ghost_topics.put(
                tid, (np.asarray(rep, dtype=np.float32).copy(),
                      float(meta.get("tp", 0.0)), int(meta.get("tl", 0))))


def make_rac(**kwargs):
    """Factory matching the simulator's (capacity, store) calling convention."""
    def f(capacity, store):
        return RACPolicy(capacity, store, **kwargs)
    f.__name__ = kwargs.get("name", "RAC")
    return f


RAC_VARIANTS = {
    "RAC": dict(),
    "RAC w/o TP": dict(use_tp=False),
    "RAC w/o TSI": dict(use_tsi=False),
    "RAC (Eq.1 literal)": dict(value_mode="paper", topic_memory=False),
    "RAC (pagerank)": dict(structural_mode="pagerank"),
    "RAC (probation)": dict(probation=32),
}
