"""Baseline eviction policies as vectorized array-state over per-slot slabs.

Every baseline (paper §4.2) keeps its metadata in fixed-size per-slot
arrays — a :class:`repro.core.policy_table.SlabTable`, the journaled slab
protocol the RAC :class:`~repro.core.policy_table.PolicyTable` already
rides — indexed by the resident store's slot ids.  The protocol driven by
:mod:`repro.core.simulator` and :class:`repro.cache.SemanticCache` is
unchanged:

  - ``on_hit(cid, req, t)``   — the store served ``req`` from entry ``cid``
  - ``on_admit(cid, req, t)`` — a miss; entry ``cid`` was just inserted
  - ``victim(t) -> cid``      — called while the store is over capacity;
                                must return a resident cid

plus the vectorized surface the multi-policy arena
(:mod:`repro.core.arena`) drives:

  - ``on_hit_batch(cids, reqs, ts)`` / ``on_admit_batch(...)`` — apply a
    run of consecutive events in one call.  The base implementations loop;
    policies whose update is expressible as slab writes override them with
    numpy ops that produce the *identical* final state (last-write-wins
    sequences, ``np.add.at`` counters).
  - ``victim_scores(t) -> (mask, keys)`` — the lexicographic eviction
    keys over the slot axis for score-ordered policies; ``victim`` is then
    a masked argmin (smallest key tuple wins).  Sweep/adaptive policies
    (CLOCK, SIEVE, ARC, S3-FIFO, ...) override ``victim`` wholesale with a
    vectorized transcription of their historical walk.

Hit determination is owned by the simulator/facade and identical for every
policy; policies only order residents.  Victim selection runs under the
**sentinel-forget invariant**: a policy's ordering slab holds the dtype's
max sentinel (``_SEQ0`` / ``+inf``) at every non-resident slot — the fill
value initially, re-written by ``victim`` when it elects a slot — so the
common eviction is one unmasked C ``argmin`` over the slab, with no
occupancy mask or temporary.  Slabs that are not ordering keys are left
stale at freed slots (masked selections exclude them; the next admission
overwrites them).

Every policy here makes bit-identical hit/miss/eviction decisions to its
historical host-loop counterpart, which is retained verbatim in
:mod:`repro.core.legacy_policies` as the parity oracle
(``tests/test_arena.py`` asserts the equivalence across hit modes, chunk
sizes, and backends).  RNG-bearing policies (TinyLFU's sketch salt, LHD,
LeCaR, RANDOM) take a ``seed`` kwarg, threaded from
``run_many``/``default_factories`` for reproducible reruns.

Implemented baselines: FIFO, LRU, CLOCK, TTL, LFU, TinyLFU, ARC, S3-FIFO,
SIEVE, 2Q, LRU-2, GDSF, LHD, LeCaR, Belady-MIN (offline optimal), RANDOM.
"""
from __future__ import annotations

import random
from collections import OrderedDict, deque

import numpy as np

from .policy_table import SlabTable

INF = float("inf")

_SEQ0 = np.int64(1) << 62          # fill for never-written sequence slabs


class Policy:
    name = "base"
    requires_future = False

    def __init__(self, capacity: int, store=None, **kw):
        self.capacity = capacity
        self.store = store

    def on_hit(self, cid: int, req, t: int):  # pragma: no cover - interface
        raise NotImplementedError

    def on_admit(self, cid: int, req, t: int):
        raise NotImplementedError

    def victim(self, t: int) -> int:
        raise NotImplementedError

    # -- batched surface (default: the scalar loop, always correct) --------
    def on_hit_batch(self, cids, reqs, ts):
        for i, cid in enumerate(cids):
            self.on_hit(cid, reqs[i], ts[i])

    def on_admit_batch(self, cids, reqs, ts):
        for i, cid in enumerate(cids):
            self.on_admit(cid, reqs[i], ts[i])


_SENTINELS: dict = {}


def _sentinel(dtype):
    s = _SENTINELS.get(dtype.char)
    if s is None:
        s = np.inf if dtype.kind == "f" else np.iinfo(dtype).max
        _SENTINELS[dtype.char] = s
    return s


def _lex_argmin(mask: np.ndarray, *keys: np.ndarray) -> int:
    """Slot of the lexicographically smallest key tuple among ``mask``.

    Masked-out rows take the dtype's max sentinel (every live key is
    strictly below it), so the common single-key case is one ``where`` +
    one C ``argmin``; ties refine through successive keys.  The caller
    guarantees a non-empty mask and that the final key is unique (or that
    full ties are observationally equivalent)."""
    k = keys[0]
    masked = np.where(mask, k, _sentinel(k.dtype))
    i = int(masked.argmin())
    for nxt in keys[1:]:
        tie = masked == masked[i]
        if np.count_nonzero(tie) == 1:
            return i
        masked = np.where(tie, nxt, _sentinel(nxt.dtype))
        i = int(masked.argmin())
    return i


def _lex_argmin_nomask(*keys: np.ndarray) -> int:
    """Lexicographic argmin over the whole slot axis, relying on the
    sentinel-forget invariant: every non-resident slot holds its key
    dtype's sentinel (the slab fill, re-written by ``victim``), so no
    occupancy mask — and no masked temporary — is needed."""
    k = keys[0]
    i = int(k.argmin())
    for nxt in keys[1:]:
        tie = k == k[i]
        if np.count_nonzero(tie) == 1:
            return i
        k = np.where(tie, nxt, _sentinel(nxt.dtype))
        i = int(k.argmin())
    return i


def _assign_last(arr: np.ndarray, slots: np.ndarray, vals: np.ndarray):
    """``arr[slots] = vals`` with deterministic last-write-wins on
    duplicate slots (what the scalar loop would leave behind)."""
    u, ridx = np.unique(slots[::-1], return_index=True)
    arr[u] = vals[len(slots) - 1 - ridx]
    return u


class ArrayPolicy(Policy):
    """Base for slab-backed baselines (see module docstring).

    ``slab_spec`` declares the per-slot fields; ``self.slabs`` is the
    journaled :class:`SlabTable` sized to the store's slot count.  ``_seq``
    is the monotone touch counter every recency/insertion ordering is
    expressed in.
    """

    slab_spec: dict = {}
    #: per-row slab journaling (device dirty-row sync) — off by default:
    #: nothing mirrors baseline slabs yet and the stamps are hot-path cost
    journal_slabs: bool = False

    def __init__(self, capacity: int, store=None, **kw):
        super().__init__(capacity, store)
        if store is None:
            raise ValueError(f"{self.name}: array-state policies order "
                             "residents by store slot and need the store")
        self.n_slots = store.emb.shape[0]
        self.slabs = SlabTable(self.n_slots, journal=self.journal_slabs,
                               **self.slab_spec)
        self._ctr = 0

    def _slot(self, cid: int) -> int:
        return self.store.slot_of[cid]

    def _slots(self, cids) -> np.ndarray:
        so = self.store.slot_of
        return np.array([so[c] for c in cids], dtype=np.int64)

    def _tick(self) -> int:
        self._ctr += 1
        return self._ctr

    def _tick_n(self, n: int) -> np.ndarray:
        """``n`` fresh ascending sequence values."""
        base = self._ctr
        self._ctr += n
        return np.arange(base + 1, base + n + 1, dtype=np.int64)

    # -- score-ordered eviction (overridden by sweep/adaptive policies) ----
    def victim_scores(self, t: int):
        """(mask, lexicographic key arrays) over the slot axis; the victim
        is the masked lexicographic argmin.  ``None`` when the policy's
        eviction is not a pure score order (it overrides ``victim``)."""
        return None

    def _on_evict(self, slot: int, cid: int, t: int):
        """Post-selection bookkeeping hook for score-ordered policies."""

    def victim(self, t: int) -> int:
        mask, keys = self.victim_scores(t)
        slot = _lex_argmin(mask, *keys)
        cid = int(self.store.cid[slot])
        self._on_evict(slot, cid, t)
        return cid


# ---------------------------------------------------------------------------
class FIFOPolicy(ArrayPolicy):
    name = "FIFO"
    slab_spec = {"seq": (np.int64, _SEQ0)}

    def on_hit(self, cid, req, t):
        pass

    def on_hit_batch(self, cids, reqs, ts):
        pass

    def on_admit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.seq[s] = self._tick()
        self.slabs.touch(s)

    def victim_scores(self, t):
        return self.store.occ, (self.slabs.seq,)

    def victim(self, t):
        seq = self.slabs.seq
        s = int(seq.argmin())          # sentinel-forget: free slots = _SEQ0
        seq[s] = _SEQ0
        self.slabs.touch(s)
        return int(self.store.cid[s])


class LRUPolicy(ArrayPolicy):
    name = "LRU"
    slab_spec = {"seq": (np.int64, _SEQ0)}

    def on_hit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.seq[s] = self._tick()
        self.slabs.touch(s)

    def on_hit_batch(self, cids, reqs, ts):
        slots = self._slots(cids)
        u = _assign_last(self.slabs.seq, slots, self._tick_n(len(slots)))
        self.slabs.touch_rows(u)

    on_admit = on_hit

    def victim_scores(self, t):
        return self.store.occ, (self.slabs.seq,)

    def victim(self, t):
        seq = self.slabs.seq
        s = int(seq.argmin())          # sentinel-forget: free slots = _SEQ0
        seq[s] = _SEQ0
        self.slabs.touch(s)
        return int(self.store.cid[s])


class CLOCKPolicy(ArrayPolicy):
    name = "CLOCK"
    slab_spec = {"seq": (np.int64, _SEQ0), "ref": (bool, False)}

    def on_hit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.ref[s] = True
        self.slabs.touch(s)

    def on_hit_batch(self, cids, reqs, ts):
        slots = self._slots(cids)
        self.slabs.ref[slots] = True
        self.slabs.touch_rows(slots)

    def on_admit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.seq[s] = self._tick()
        self.slabs.ref[s] = False
        self.slabs.touch(s)

    def victim(self, t):
        # the historical sweep in one pass: the hand starts at the ring
        # head (min seq); every referenced entry it passes is cleared and
        # moved to the tail in ring order; the first unreferenced entry is
        # evicted.  All-referenced rings clear everyone and evict the head.
        seq, ref = self.slabs.seq, self.slabs.ref
        masked = np.where(ref, _SEQ0, seq)   # sentinel-forget free slots
        vslot = int(masked.argmin())
        if masked[vslot] >= _SEQ0:
            # every resident referenced: clear all refs, evict the head
            # (relative ring order is unchanged)
            resident = seq < _SEQ0
            ref[resident] = False
            if self.slabs.log is not None:
                self.slabs.touch_rows(np.flatnonzero(resident))
            vslot = int(seq.argmin())
        else:
            pred = np.flatnonzero(ref & (seq < seq[vslot]))
            if pred.size:
                pred = pred[np.argsort(seq[pred], kind="stable")]
                ref[pred] = False
                seq[pred] = self._tick_n(pred.size)
                self.slabs.touch_rows(pred)
        seq[vslot] = _SEQ0
        self.slabs.touch(vslot)
        return int(self.store.cid[vslot])


class TTLPolicy(ArrayPolicy):
    """Expire-first (admit time + ttl), LRU among the unexpired."""
    name = "TTL"
    slab_spec = {"seq": (np.int64, _SEQ0), "deadline": (np.int64, _SEQ0)}

    def __init__(self, capacity, store=None, ttl: int = 2000, **kw):
        super().__init__(capacity, store)
        self.ttl = ttl

    def on_hit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.seq[s] = self._tick()
        self.slabs.touch(s)

    def on_hit_batch(self, cids, reqs, ts):
        slots = self._slots(cids)
        u = _assign_last(self.slabs.seq, slots, self._tick_n(len(slots)))
        self.slabs.touch_rows(u)

    def on_admit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.seq[s] = self._tick()
        self.slabs.deadline[s] = t + self.ttl
        self.slabs.touch(s)

    def victim(self, t):
        seq, dl = self.slabs.seq, self.slabs.deadline
        expired = dl <= t              # sentinel-forget: free slots = _SEQ0
        if expired.any():
            # min deadline; ties fall back to LRU position, matching the
            # historical min() over the recency-ordered dict
            vslot = _lex_argmin(expired, dl, seq)
        else:
            vslot = int(seq.argmin())
        seq[vslot] = _SEQ0
        dl[vslot] = _SEQ0
        self.slabs.touch(vslot)
        return int(self.store.cid[vslot])


class LFUPolicy(ArrayPolicy):
    """LFU with LRU tie-break."""
    name = "LFU"
    slab_spec = {"freq": (np.int64, _SEQ0), "stamp": (np.int64, _SEQ0)}

    def on_hit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.freq[s] += 1
        self.slabs.stamp[s] = self._tick()
        self.slabs.touch(s)

    def on_hit_batch(self, cids, reqs, ts):
        slots = self._slots(cids)
        np.add.at(self.slabs.freq, slots, 1)
        u = _assign_last(self.slabs.stamp, slots, self._tick_n(len(slots)))
        self.slabs.touch_rows(u)

    def on_admit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.freq[s] = 1
        self.slabs.stamp[s] = self._tick()
        self.slabs.touch(s)

    def victim_scores(self, t):
        return self.store.occ, (self.slabs.freq, self.slabs.stamp)

    def victim(self, t):
        freq, stamp = self.slabs.freq, self.slabs.stamp
        vslot = _lex_argmin_nomask(freq, stamp)
        freq[vslot] = _SEQ0            # sentinel-forget
        stamp[vslot] = _SEQ0
        self.slabs.touch(vslot)
        return int(self.store.cid[vslot])


class _CountMinSketch:
    def __init__(self, width: int, depth: int = 4, seed: int = 7):
        self.w = max(16, width)
        self.d = depth
        self.tab = np.zeros((depth, self.w), dtype=np.uint8)  # 8-bit counters
        rng = random.Random(seed)
        self.salts = [rng.getrandbits(32) for _ in range(depth)]
        self.ops = 0

    def _idx(self, key: int, row: int) -> int:
        h = (key * 0x9E3779B97F4A7C15 + self.salts[row]) & 0xFFFFFFFFFFFFFFFF
        return (h >> 17) % self.w

    def add(self, key: int):
        self.ops += 1
        for r in range(self.d):
            i = self._idx(key, r)
            if self.tab[r, i] < 255:
                self.tab[r, i] += 1
        if self.ops >= 8 * self.w:       # periodic aging (halve)
            self.tab >>= 1
            self.ops = 0

    def estimate(self, key: int) -> int:
        return int(min(self.tab[r, self._idx(key, r)] for r in range(self.d)))


class TinyLFUPolicy(ArrayPolicy):
    """TinyLFU admission over an LRU main cache (simplified W-TinyLFU).

    Admission control is expressed through victim selection: the newly
    inserted entry itself is evicted when its sketch frequency does not
    beat the main cache's LRU victim.  The sketch is already array state
    (a fixed (depth, width) counter table); recency rides the seq slab.
    """
    name = "TinyLFU"
    slab_spec = {"seq": (np.int64, _SEQ0)}

    def __init__(self, capacity, store=None, seed: int = 0, **kw):
        super().__init__(capacity, store)
        self.sketch = _CountMinSketch(width=capacity * 8, seed=7 + seed)
        self.window: deque[int] = deque()         # recent admissions (window)
        self.window_size = max(1, capacity // 100)
        self._mru_slot = -1            # slot of the latest touch (hit/admit)

    def on_hit(self, cid, req, t):
        self.sketch.add(cid)
        s = self._slot(cid)
        self.slabs.seq[s] = self._tick()
        self._mru_slot = s
        self.slabs.touch(s)

    def on_hit_batch(self, cids, reqs, ts):
        sketch_add = self.sketch.add
        slot_of = self.store.slot_of
        seq = self.slabs.seq
        s = -1
        for cid in cids:
            sketch_add(cid)
            s = slot_of[cid]
            seq[s] = self._tick()
        self._mru_slot = s
        if self.slabs.log is not None:
            self.slabs.touch_rows([slot_of[c] for c in cids])

    def on_admit(self, cid, req, t):
        self.sketch.add(cid)
        s = self._slot(cid)
        self.slabs.seq[s] = self._tick()
        self._mru_slot = s
        self.slabs.touch(s)
        self.window.append(cid)
        while len(self.window) > self.window_size:
            self.window.popleft()

    def victim(self, t):
        seq = self.slabs.seq
        oldest = int(seq.argmin())     # sentinel-forget: free slots = _SEQ0
        # victim always follows an admission (Alg. 1 insert-then-evict),
        # so the MRU touch IS the newest entry — no slab scan needed
        newest = self._mru_slot
        new_cid = int(self.store.cid[newest])
        old_cid = int(self.store.cid[oldest])
        if new_cid in self.window and new_cid != old_cid:
            # admission duel: candidate vs main LRU victim
            vslot, cid = ((oldest, old_cid)
                          if self.sketch.estimate(new_cid)
                          > self.sketch.estimate(old_cid)
                          else (newest, new_cid))
        else:
            vslot, cid = oldest, old_cid
        seq[vslot] = _SEQ0
        self.slabs.touch(vslot)
        return cid


class ARCPolicy(ArrayPolicy):
    """Adaptive Replacement Cache (Megiddo & Modha, FAST'03).

    Resident membership (T1 recency list vs T2 frequency list) and order
    live in slabs; the bounded ghost lists B1/B2 are cid-keyed host dicts
    exactly as in the historical implementation.
    """
    name = "ARC"
    slab_spec = {"which": (np.int8, 0), "seq": (np.int64, _SEQ0)}

    def __init__(self, capacity, store=None, **kw):
        super().__init__(capacity, store)
        self.p = 0.0
        self.b1: OrderedDict[int, None] = OrderedDict()
        self.b2: OrderedDict[int, None] = OrderedDict()
        self.n_t1 = 0
        self.n_t2 = 0

    def on_hit(self, cid, req, t):
        s = self._slot(cid)
        if self.slabs.which[s] == 1:
            self.slabs.which[s] = 2
            self.n_t1 -= 1
            self.n_t2 += 1
        self.slabs.seq[s] = self._tick()
        self.slabs.touch(s)

    def on_admit(self, cid, req, t):
        c = self.capacity
        s = self._slot(cid)
        if cid in self.b1:
            self.p = min(c, self.p + max(1.0, len(self.b2) / max(1, len(self.b1))))
            del self.b1[cid]
            self.slabs.which[s] = 2
            self.n_t2 += 1
        elif cid in self.b2:
            self.p = max(0.0, self.p - max(1.0, len(self.b1) / max(1, len(self.b2))))
            del self.b2[cid]
            self.slabs.which[s] = 2
            self.n_t2 += 1
        else:
            l1 = self.n_t1 + len(self.b1)
            if l1 >= c:
                if self.b1:
                    self.b1.popitem(last=False)
            elif l1 + self.n_t2 + len(self.b2) >= 2 * c:
                if self.b2:
                    self.b2.popitem(last=False)
            self.slabs.which[s] = 1
            self.n_t1 += 1
        self.slabs.seq[s] = self._tick()
        self.slabs.touch(s)

    def victim(self, t):
        which, seq = self.slabs.which, self.slabs.seq
        if self.n_t1 and (self.n_t1 > self.p or not self.n_t2):
            vslot = int(np.where(which == 1, seq, _SEQ0).argmin())
            cid = int(self.store.cid[vslot])
            self.b1[cid] = None
            self.n_t1 -= 1
        else:
            vslot = int(np.where(which == 2, seq, _SEQ0).argmin())
            cid = int(self.store.cid[vslot])
            self.b2[cid] = None
            self.n_t2 -= 1
        which[vslot] = 0
        self.slabs.touch(vslot)
        # bound ghost lists
        while len(self.b1) > self.capacity:
            self.b1.popitem(last=False)
        while len(self.b2) > self.capacity:
            self.b2.popitem(last=False)
        return cid


class S3FIFOPolicy(ArrayPolicy):
    """S3-FIFO (Yang et al., SOSP'23 / NSDI'23): small + main + ghost FIFOs.

    Queue membership/order/frequency are slabs; the historical pop-and-
    reappend walks collapse to one vectorized pass each — an entry at
    queue position ``pos`` with frequency ``f`` is evicted from MAIN after
    ``f`` full demotion cycles plus ``pos`` steps, so the victim is the
    lexicographic min of ``(freq, seq)`` and every entry processed before
    it is decremented and re-sequenced exactly as the walk would have.
    """
    name = "S3-FIFO"
    slab_spec = {"queue": (np.int8, 0),        # 0 none / 1 small / 2 main
                 "seq": (np.int64, _SEQ0),
                 "freq": (np.int64, 0)}

    def __init__(self, capacity, store=None, small_frac: float = 0.1, **kw):
        super().__init__(capacity, store)
        self.small_cap = max(1, int(capacity * small_frac))
        self.ghost: OrderedDict[int, None] = OrderedDict()
        self.n_small = 0
        self.n_main = 0

    def on_hit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.freq[s] = min(3, self.slabs.freq[s] + 1)
        self.slabs.touch(s)

    def on_hit_batch(self, cids, reqs, ts):
        slots = self._slots(cids)
        np.add.at(self.slabs.freq, slots, 1)
        np.minimum(self.slabs.freq, 3, out=self.slabs.freq)
        self.slabs.touch_rows(slots)

    def on_admit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.freq[s] = 0
        if cid in self.ghost:
            del self.ghost[cid]
            self.slabs.queue[s] = 2
            self.n_main += 1
        else:
            self.slabs.queue[s] = 1
            self.n_small += 1
        self.slabs.seq[s] = self._tick()
        self.slabs.touch(s)

    def _evict_main(self) -> int:
        queue, seq, freq = self.slabs.queue, self.slabs.seq, self.slabs.freq
        mask = self.store.occ & (queue == 2)
        vslot = _lex_argmin(mask, freq, seq)
        fmin = int(freq[vslot])
        before = np.flatnonzero(mask & (seq < seq[vslot]))
        after = np.flatnonzero(mask & (seq > seq[vslot]))
        freq[before] -= fmin + 1       # processed fmin+1 times before evict
        freq[after] -= fmin            # processed fmin full cycles
        if fmin > 0:
            # every survivor was re-appended: tail-of-final-pass entries
            # (after) precede the re-processed head entries (before)
            walk = np.concatenate([after[np.argsort(seq[after],
                                                    kind="stable")],
                                   before[np.argsort(seq[before],
                                                     kind="stable")]])
            seq[walk] = self._tick_n(walk.size)
            self.slabs.touch_rows(walk)
        elif before.size:
            order = before[np.argsort(seq[before], kind="stable")]
            seq[order] = self._tick_n(order.size)
            self.slabs.touch_rows(order)
        queue[vslot] = 0
        self.n_main -= 1
        self.slabs.touch(vslot)
        return int(self.store.cid[vslot])

    def victim(self, t):
        queue, seq, freq = self.slabs.queue, self.slabs.seq, self.slabs.freq
        if self.n_small > self.small_cap or not self.n_main:
            small = np.flatnonzero(self.store.occ & (queue == 1))
            small = small[np.argsort(seq[small], kind="stable")]
            keep = freq[small] > 1                 # promoted on the walk
            first = np.flatnonzero(~keep)
            k = int(first[0]) if first.size else small.size
            promo = small[:k]
            if promo.size:
                queue[promo] = 2
                freq[promo] = 0
                seq[promo] = self._tick_n(promo.size)
                self.slabs.touch_rows(promo)
                self.n_small -= promo.size
                self.n_main += promo.size
            if first.size:
                vslot = int(small[k])
                cid = int(self.store.cid[vslot])
                self.ghost[cid] = None
                while len(self.ghost) > self.capacity:
                    self.ghost.popitem(last=False)
                queue[vslot] = 0
                self.n_small -= 1
                self.slabs.touch(vslot)
                return cid
        return self._evict_main()


class SIEVEPolicy(ArrayPolicy):
    """SIEVE (Zhang et al., NSDI'24): FIFO order + moving hand + visited bits."""
    name = "SIEVE"
    slab_spec = {"seq": (np.int64, _SEQ0), "visited": (bool, False)}

    def __init__(self, capacity, store=None, **kw):
        super().__init__(capacity, store)
        self.hand: int | None = None               # cid at hand

    def on_hit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.visited[s] = True
        self.slabs.touch(s)

    def on_hit_batch(self, cids, reqs, ts):
        slots = self._slots(cids)
        self.slabs.visited[slots] = True
        self.slabs.touch_rows(slots)

    def on_admit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.seq[s] = self._tick()           # insert at tail (newest)
        self.slabs.visited[s] = False
        self.slabs.touch(s)

    def victim(self, t):
        # the historical hand walk without sorting: order residents by the
        # CYCLIC key (insertion seq rotated so the hand is first); the
        # victim is the min-cyclic-key unvisited entry, everything walked
        # past loses its visited bit, and the hand moves to the victim's
        # ring successor.  SIEVE never reorders entries, so seqs are
        # untouched.  Free slots hold the seq sentinel (sentinel-forget).
        seq, visited = self.slabs.seq, self.slabs.visited
        big = _sentinel(seq.dtype)
        hslot = (self.store.slot_of.get(self.hand, -1)
                 if self.hand is not None else -1)
        if hslot >= 0:
            hseq = seq[hslot]
            ckey = np.where(seq >= hseq, seq - hseq, seq - hseq + _SEQ0)
            ckey[seq >= _SEQ0] = big               # exclude free slots
        else:
            ckey = np.where(seq < _SEQ0, seq, big)
        cand = np.where(visited, big, ckey)
        vslot = int(cand.argmin())
        if cand[vslot] >= big:
            # all residents visited: one full pass clears everyone, the
            # second evicts the walk head
            vslot = int(ckey.argmin())
            passed = ckey < big
        else:
            passed = visited & (ckey < ckey[vslot])
        visited[passed] = False
        if self.slabs.log is not None:
            self.slabs.touch_rows(np.flatnonzero(passed))
        cid = int(self.store.cid[vslot])
        # ring successor in the pre-eviction snapshot (wraps to the head)
        nkey = np.where(ckey > ckey[vslot], ckey, big)
        nslot = int(nkey.argmin())
        if nkey[nslot] >= big:
            nslot = int(ckey.argmin())             # victim was cyclic-last
        nxt = int(self.store.cid[nslot])
        self.hand = nxt if nxt != cid else None
        seq[vslot] = _SEQ0             # sentinel-forget
        self.slabs.touch(vslot)
        return cid


class TwoQPolicy(ArrayPolicy):
    """2Q (Johnson & Shasha, VLDB'94): A1in FIFO + A1out ghost + Am LRU."""
    name = "2Q"
    slab_spec = {"queue": (np.int8, 0),            # 1 A1in / 2 Am
                 "seq": (np.int64, _SEQ0)}

    def __init__(self, capacity, store=None, kin_frac=0.25, kout_frac=0.5, **kw):
        super().__init__(capacity, store)
        self.kin = max(1, int(capacity * kin_frac))
        self.kout = max(1, int(capacity * kout_frac))
        self.a1out: OrderedDict[int, None] = OrderedDict()
        self.n_in = 0
        self.n_am = 0

    def on_hit(self, cid, req, t):
        s = self._slot(cid)
        if self.slabs.queue[s] == 2:
            self.slabs.seq[s] = self._tick()
            self.slabs.touch(s)
        # hits in A1in leave position unchanged (2Q semantics)

    def on_hit_batch(self, cids, reqs, ts):
        slots = self._slots(cids)
        vals = self._tick_n(len(slots))
        am = self.slabs.queue[slots] == 2
        if am.any():
            u = _assign_last(self.slabs.seq, slots[am], vals[am])
            self.slabs.touch_rows(u)

    def on_admit(self, cid, req, t):
        s = self._slot(cid)
        if cid in self.a1out:
            del self.a1out[cid]
            self.slabs.queue[s] = 2
            self.n_am += 1
        else:
            self.slabs.queue[s] = 1
            self.n_in += 1
        self.slabs.seq[s] = self._tick()
        self.slabs.touch(s)

    def victim(self, t):
        queue, seq = self.slabs.queue, self.slabs.seq
        if (self.n_in > self.kin or not self.n_am) and self.n_in:
            vslot = int(np.where(queue == 1, seq, _SEQ0).argmin())
            cid = int(self.store.cid[vslot])
            self.a1out[cid] = None
            while len(self.a1out) > self.kout:
                self.a1out.popitem(last=False)
            self.n_in -= 1
        else:
            vslot = int(np.where(queue == 2, seq, _SEQ0).argmin())
            cid = int(self.store.cid[vslot])
            self.n_am -= 1
        queue[vslot] = 0
        self.slabs.touch(vslot)
        return cid


class LRU2Policy(ArrayPolicy):
    """LRU-2 (O'Neil et al.): evict max backward-2nd-access distance."""
    name = "LRU-2"
    slab_spec = {"k2": (np.int64, _SEQ0), "last": (np.int64, 0)}

    def on_hit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.k2[s] = self.slabs.last[s]
        self.slabs.last[s] = t
        self.slabs.touch(s)

    def on_admit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.k2[s] = -10**9                  # no 2nd-to-last yet
        self.slabs.last[s] = t
        self.slabs.touch(s)

    def victim_scores(self, t):
        return self.store.occ, (self.slabs.k2, self.slabs.last,
                                self.store.cid)

    def victim(self, t):
        k2 = self.slabs.k2
        vslot = _lex_argmin_nomask(k2, self.slabs.last, self.store.cid)
        k2[vslot] = _SEQ0              # sentinel-forget
        self.slabs.touch(vslot)
        return int(self.store.cid[vslot])


class GDSFPolicy(ArrayPolicy):
    """GreedyDual-Size-Frequency with unit size/cost: H = L + freq."""
    name = "GDSF"
    slab_spec = {"freq": (np.int64, 0), "h": (np.float64, INF),
                 "stamp": (np.int64, _SEQ0)}

    def __init__(self, capacity, store=None, **kw):
        super().__init__(capacity, store)
        self.L = 0.0

    def on_hit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.freq[s] += 1
        self.slabs.h[s] = self.L + self.slabs.freq[s]
        self.slabs.stamp[s] = self._tick()
        self.slabs.touch(s)

    def on_hit_batch(self, cids, reqs, ts):
        slots = self._slots(cids)
        np.add.at(self.slabs.freq, slots, 1)
        u = _assign_last(self.slabs.stamp, slots, self._tick_n(len(slots)))
        self.slabs.h[u] = self.L + self.slabs.freq[u]
        self.slabs.touch_rows(u)

    def on_admit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.freq[s] = 1
        self.slabs.h[s] = self.L + 1.0
        self.slabs.stamp[s] = self._tick()
        self.slabs.touch(s)

    def victim_scores(self, t):
        return self.store.occ, (self.slabs.h, self.slabs.stamp)

    def victim(self, t):
        h = self.slabs.h
        vslot = _lex_argmin_nomask(h, self.slabs.stamp)   # free slots: +inf
        self.L = float(h[vslot])
        h[vslot] = INF                 # sentinel-forget
        self.slabs.touch(vslot)
        return int(self.store.cid[vslot])


class LHDPolicy(ArrayPolicy):
    """LHD (Beckmann et al., NSDI'18), simplified with sampling.

    Hit density per log2-age class is estimated online from observed hit /
    eviction ages; eviction samples ``n_sample`` residents and removes the
    minimum-density one.  The sampling order (and hence the rng stream)
    replicates the historical swap-remove key list exactly.
    """
    name = "LHD"
    N_CLASSES = 32
    slab_spec = {"last": (np.int64, 0)}

    def __init__(self, capacity, store=None, n_sample: int = 64, seed: int = 0,
                 **kw):
        super().__init__(capacity, store)
        self.n_sample = n_sample
        self.rng = random.Random(seed)
        self.keys: list[int] = []
        self.pos: dict[int, int] = {}
        self.hit_age = np.ones(self.N_CLASSES)
        self.ev_age = np.ones(self.N_CLASSES)

    @staticmethod
    def _cls(age: int) -> int:
        return min(LHDPolicy.N_CLASSES - 1, max(0, int(np.log2(age + 1))))

    def _cls_vec(self, ages: np.ndarray) -> np.ndarray:
        return np.minimum(self.N_CLASSES - 1,
                          np.maximum(0, np.log2(ages + 1).astype(np.int64)))

    def _add(self, cid):
        self.pos[cid] = len(self.keys)
        self.keys.append(cid)

    def _del(self, cid):
        i = self.pos.pop(cid)
        last = self.keys.pop()
        if last != cid:
            self.keys[i] = last
            self.pos[last] = i

    def on_hit(self, cid, req, t):
        s = self._slot(cid)
        self.hit_age[self._cls(t - self.slabs.last[s])] += 1
        self.slabs.last[s] = t
        self.slabs.touch(s)

    def on_hit_batch(self, cids, reqs, ts):
        slots = self._slots(cids)
        if np.unique(slots).size != slots.size:
            # an age depends on the previous touch of the same slot —
            # duplicate slots need the sequential order
            return Policy.on_hit_batch(self, cids, reqs, ts)
        ages = np.asarray(ts, dtype=np.int64) - self.slabs.last[slots]
        np.add.at(self.hit_age, self._cls_vec(ages), 1)
        self.slabs.last[slots] = ts
        self.slabs.touch_rows(slots)

    def on_admit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.last[s] = t
        self.slabs.touch(s)
        self._add(cid)

    def _sample(self, n: int) -> list[int]:
        """``n_sample`` draws of ``rng.randrange(n)``, consuming the exact
        bit stream ``random.Random._randbelow_with_getrandbits`` would —
        bit-identical samples to the legacy oracle, minus two Python
        frames per draw."""
        getrandbits = self.rng.getrandbits
        k = n.bit_length()
        keys = self.keys
        out = []
        for _ in range(self.n_sample):
            r = getrandbits(k)
            while r >= n:
                r = getrandbits(k)
            out.append(keys[r])
        return out

    def victim(self, t):
        n = len(self.keys)
        sample = self.keys if n <= self.n_sample else self._sample(n)
        cids = np.fromiter(sample, dtype=np.int64, count=len(sample))
        slots = self._slots(sample)
        last = self.slabs.last[slots]
        ages = t - last
        c = self._cls_vec(ages)
        p_hit = self.hit_age[c] / (self.hit_age[c] + self.ev_age[c])
        dens = p_hit / (ages + 1.0)
        # historical min(sample, key=(density, -last, cid)) — full ties
        # only occur between duplicate samples of one cid
        i = _lex_argmin(np.ones(len(sample), dtype=bool), dens, -last, cids)
        cid = int(cids[i])
        self.ev_age[self._cls(t - int(last[i]))] += 1
        self._del(cid)
        return cid


class LeCaRPolicy(ArrayPolicy):
    """LeCaR (Vietri et al., HotStorage'18): regret-weighted LRU/LFU experts."""
    name = "LeCaR"
    slab_spec = {"seq": (np.int64, _SEQ0), "freq": (np.int64, _SEQ0)}

    def __init__(self, capacity, store=None, learning_rate=0.45,
                 discount=None, seed=0, **kw):
        super().__init__(capacity, store)
        self.lr = learning_rate
        self.d = discount if discount is not None else 0.005 ** (1.0 / capacity)
        self.w = np.array([0.5, 0.5])            # [LRU, LFU]
        self.rng = random.Random(seed)
        self.h_lru: OrderedDict[int, int] = OrderedDict()   # ghost: cid -> evict t
        self.h_lfu: OrderedDict[int, int] = OrderedDict()

    def _reward(self, ghost: OrderedDict, idx: int, cid: int, t: int):
        if cid in ghost:
            dt = t - ghost.pop(cid)
            r = self.d ** dt
            upd = np.ones(2)
            upd[idx] = np.exp(-self.lr * r)      # penalize the expert at fault
            self.w = self.w * upd
            self.w = self.w / self.w.sum()

    def on_hit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.seq[s] = self._tick()
        self.slabs.freq[s] += 1
        self.slabs.touch(s)

    def on_hit_batch(self, cids, reqs, ts):
        slots = self._slots(cids)
        np.add.at(self.slabs.freq, slots, 1)
        u = _assign_last(self.slabs.seq, slots, self._tick_n(len(slots)))
        self.slabs.touch_rows(u)

    def on_admit(self, cid, req, t):
        self._reward(self.h_lru, 0, cid, t)
        self._reward(self.h_lfu, 1, cid, t)
        s = self._slot(cid)
        self.slabs.seq[s] = self._tick()
        self.slabs.freq[s] = 1
        self.slabs.touch(s)

    def victim(self, t):
        seq, freq = self.slabs.seq, self.slabs.freq
        use_lru = self.rng.random() < self.w[0]
        if use_lru:
            vslot = int(seq.argmin())  # sentinel-forget: free slots = _SEQ0
            cid = int(self.store.cid[vslot])
            self.h_lru[cid] = t
            while len(self.h_lru) > self.capacity:
                self.h_lru.popitem(last=False)
        else:
            vslot = _lex_argmin_nomask(freq, self.store.cid)
            cid = int(self.store.cid[vslot])
            self.h_lfu[cid] = t
            while len(self.h_lfu) > self.capacity:
                self.h_lfu.popitem(last=False)
        seq[vslot] = _SEQ0
        freq[vslot] = _SEQ0
        self.slabs.touch(vslot)
        return cid


class BeladyPolicy(ArrayPolicy):
    """Belady's MIN — offline optimal; uses precomputed next-use indices.

    The slab stores the NEGATED farthest-next-use key, so the max-distance
    victim is a plain lexicographic argmin under the sentinel-forget
    invariant (free slots hold ``_SEQ0``, above every real ``-key``)."""
    name = "Belady"
    requires_future = True
    slab_spec = {"negkey": (np.int64, _SEQ0)}

    _NEVER = 10 ** 12                            # never-used-again = farthest

    @classmethod
    def _key(cls, nu: int) -> int:
        return cls._NEVER if nu < 0 else nu

    def on_hit(self, cid, req, t):
        s = self._slot(cid)
        self.slabs.negkey[s] = -self._key(req.next_use)
        self.slabs.touch(s)

    def on_hit_batch(self, cids, reqs, ts):
        slots = self._slots(cids)
        nus = np.fromiter((r.next_use for r in reqs), dtype=np.int64,
                          count=len(reqs))
        vals = np.where(nus < 0, -self._NEVER, -nus)
        u = _assign_last(self.slabs.negkey, slots, vals)
        self.slabs.touch_rows(u)

    on_admit = on_hit

    def victim_scores(self, t):
        return self.store.occ, (self.slabs.negkey, self.store.cid)

    def victim(self, t):
        negkey = self.slabs.negkey
        vslot = _lex_argmin_nomask(negkey, self.store.cid)
        negkey[vslot] = _SEQ0          # sentinel-forget
        self.slabs.touch(vslot)
        return int(self.store.cid[vslot])


class RandomPolicy(ArrayPolicy):
    name = "RANDOM"

    def __init__(self, capacity, store=None, seed=0, **kw):
        super().__init__(capacity, store)
        self.rng = random.Random(seed)
        self.keys: list[int] = []
        self.pos: dict[int, int] = {}

    def on_hit(self, cid, req, t):
        pass

    def on_hit_batch(self, cids, reqs, ts):
        pass

    def on_admit(self, cid, req, t):
        self.pos[cid] = len(self.keys)
        self.keys.append(cid)

    def victim(self, t):
        i = self.rng.randrange(len(self.keys))
        cid = self.keys[i]
        last = self.keys.pop()
        if last != cid:
            self.keys[i] = last
            self.pos[last] = i
        del self.pos[cid]
        return cid


BASELINES: dict[str, type[Policy]] = {
    p.name: p for p in [
        FIFOPolicy, LRUPolicy, CLOCKPolicy, TTLPolicy, LFUPolicy,
        TinyLFUPolicy, ARCPolicy, S3FIFOPolicy, SIEVEPolicy, TwoQPolicy,
        LRU2Policy, GDSFPolicy, LHDPolicy, LeCaRPolicy, BeladyPolicy,
        RandomPolicy,
    ]
}

#: baselines whose decisions consume randomness (seed-threading targets)
RNG_BASELINES = frozenset({"TinyLFU", "LHD", "LeCaR", "RANDOM"})
