"""Workload generators (paper §4.2).

Two families:

1. ``synthetic_trace`` — topic-level semi-Markov generator.  A trace
   concatenates variable-length topic *episodes*; each episode is one
   complete multi-turn session (never split / interleaved).  Topics are
   drawn Zipf(γ).  Sessions carry an intra-episode dependency DAG (root
   context query + dependent follow-ups).  Two controlled stress axes:

     - *long-reuse ratio*: fraction of reuse events whose reuse distance
       exceeds the reference cache capacity C (repeats of prior sessions
       placed at randomized long/short distances);
     - *Zipf exponent γ*: topic-popularity skew.

   Session repeats come in two modes mirroring the paper's Example 1:
   *full repeat* (all queries recur, paraphrased — the {b0*..b5*} pattern)
   and *anchor variant* (context anchors recur, leaves are new queries that
   depend on them — the {a0, a1*..a5*} pattern).

2. ``oasst_style_trace`` — timestamp-continuous dialogue traces shaped like
   OASST1 (the corpus itself is unavailable offline): Poisson arrivals of
   conversation threads, tree-structured turns, Zipf topic popularity,
   cross-user repeats of popular prompts.  10 sub-traces = 10 seeds.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .embeddings import EmbeddingSpace
from .types import Request, Trace


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class SynthConfig:
    n_topics: int = 120
    sessions_per_topic: int = 40      # session pool bound per topic
    trace_len: int = 10_000
    capacity_ref: int = 1000          # C used to classify long vs short reuse
    zipf_gamma: float = 0.7
    long_reuse_ratio: float = 0.5     # target fraction of long reuse events
    repeat_prob: float = 0.35         # fraction of session slots that are
                                      # full repeats of a pooled session
    core_lo: int = 2                  # per-topic core-DAG size (anchors)
    core_hi: int = 4
    session_len_lo: int = 6
    session_len_hi: int = 14
    core_ask_prob: float = 0.85       # prob a session re-asks each core
    dim: int = 64
    seed: int = 0


class _Session:
    """A generated session: ordered queries with dependency parents."""

    __slots__ = ("topic", "cids", "parents")

    def __init__(self, topic: int, cids: list[int], parents: list[int]):
        self.topic = topic
        self.cids = cids
        self.parents = parents          # parent cid per query (-1 = root)


class _TopicDAG:
    """Per-topic persistent core DAG (paper §4.2: sessions within a topic
    share context-ordered dependencies; variants extend branches while
    re-using the topic's core/anchor queries — Example 1's a0 / b2)."""

    __slots__ = ("topic", "core_cids", "core_parents", "sessions")

    def __init__(self, topic: int, rng: np.random.Generator,
                 cfg: SynthConfig, next_cid: list[int]):
        self.topic = topic
        n_core = int(rng.integers(cfg.core_lo, cfg.core_hi + 1))
        self.core_cids: list[int] = []
        self.core_parents: list[int] = []
        for i in range(n_core):
            cid = next_cid[0]
            next_cid[0] += 1
            # core 0 is the root context; later cores depend on the root
            self.core_parents.append(-1 if i == 0 else self.core_cids[0])
            self.core_cids.append(cid)
        self.sessions: list[_Session] = []

    def new_session(self, rng: np.random.Generator, cfg: SynthConfig,
                    next_cid: list[int]) -> _Session:
        """A fresh variant: re-ask (most of) the cores, extend new leaves."""
        cids, parents = [], []
        for cid, par in zip(self.core_cids, self.core_parents):
            if not cids or rng.random() < cfg.core_ask_prob:
                cids.append(cid)
                parents.append(par if (par < 0 or par in cids) else cids[0])
        n_leaf = int(rng.integers(cfg.session_len_lo - 2,
                                  cfg.session_len_hi - 2)) + 1
        for _ in range(max(1, n_leaf)):
            cid = next_cid[0]
            next_cid[0] += 1
            # leaves depend on the root core (60%) or a uniform earlier query
            j = 0 if rng.random() < 0.6 else int(rng.integers(0, len(cids)))
            parents.append(cids[j])
            cids.append(cid)
        sess = _Session(self.topic, cids, parents)
        if len(self.sessions) < cfg.sessions_per_topic:
            self.sessions.append(sess)
        return sess


def _zipf_probs(n: int, gamma: float) -> np.ndarray:
    w = (np.arange(1, n + 1, dtype=np.float64)) ** (-gamma)
    return w / w.sum()


def synthetic_trace(cfg: SynthConfig) -> Trace:
    rng = np.random.default_rng(cfg.seed)
    space = EmbeddingSpace(dim=cfg.dim, seed=cfg.seed ^ 0x5EED)
    topic_p = _zipf_probs(cfg.n_topics, cfg.zipf_gamma)
    # shuffle topic identities so popularity rank is not the topic id
    topic_ids = rng.permutation(cfg.n_topics)

    next_cid = [0]
    dags: dict[int, _TopicDAG] = {}
    history: list[tuple[_Session, int]] = []    # (session, last emit end pos)
    cid_topic: dict[int, int] = {}
    cid_parent: dict[int, int] = {}
    occur: dict[int, int] = {}

    requests: list[Request] = []
    session_id = 0
    last_topic = -1

    def emit(sess: _Session, sid: int):
        for cid, par in zip(sess.cids, sess.parents):
            t = len(requests)
            if t >= cfg.trace_len:
                return
            cid_topic[cid] = sess.topic
            cid_parent.setdefault(cid, par)
            k = occur.get(cid, 0)
            occur[cid] = k + 1
            base = space.content_embedding(sess.topic, cid,
                                           parent_content=cid_parent[cid])
            emb = space.paraphrase(base, sess.topic, cid, k)
            requests.append(Request(t=t, cid=cid, emb=emb.astype(np.float32),
                                    topic=sess.topic, session=sid,
                                    parent_cid=cid_parent[cid]))

    while len(requests) < cfg.trace_len:
        sess = None
        if history and rng.random() < cfg.repeat_prob:
            # full repeat of a pooled session, placed long or short
            want_long = rng.random() < cfg.long_reuse_ratio
            pos = len(requests)
            longs = [i for i, (_, end) in enumerate(history)
                     if pos - end > cfg.capacity_ref]
            shorts = [i for i, (_, end) in enumerate(history)
                      if 0 < pos - end <= cfg.capacity_ref]
            pool = longs if (want_long and longs) else (shorts or longs)
            if pool:
                sess, _ = history[int(rng.choice(pool))]
        if sess is None:
            # new session (variant) in a Zipf-drawn topic — re-asks the
            # topic's core anchors, extends fresh dependent leaves
            for _ in range(8):
                tix = int(rng.choice(cfg.n_topics, p=topic_p))
                topic = int(topic_ids[tix])
                if topic != last_topic or cfg.n_topics == 1:
                    break
            dag = dags.get(topic)
            if dag is None:
                dag = dags[topic] = _TopicDAG(topic, rng, cfg, next_cid)
            sess = dag.new_session(rng, cfg, next_cid)
        emit(sess, session_id)
        history.append((sess, len(requests)))
        last_topic = sess.topic
        session_id += 1

    tr = Trace(requests=requests[:cfg.trace_len], n_topics=cfg.n_topics,
               meta=dict(kind="synthetic", cfg=dataclasses.asdict(cfg),
                         unique=len({r.cid for r in requests[:cfg.trace_len]})))
    return tr.with_next_use()


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class OASSTConfig:
    trace_len: int = 10_000
    n_topics: int = 300               # broad topic pool, heavy-tailed
    zipf_gamma: float = 0.95
    thread_rate: float = 0.35         # new threads per emitted message
    mean_thread_len: float = 6.0
    branch_prob: float = 0.18         # tree branching (alt continuations)
    popular_repeat_prob: float = 0.30 # new root repeats a popular prior root
    dim: int = 64
    seed: int = 0


def oasst_style_trace(cfg: OASSTConfig) -> Trace:
    """Timestamp-continuous interleaved dialogue threads (OASST1-shaped)."""
    rng = np.random.default_rng(cfg.seed)
    space = EmbeddingSpace(dim=cfg.dim, seed=cfg.seed ^ 0x0A55)
    topic_p = _zipf_probs(cfg.n_topics, cfg.zipf_gamma)
    topic_ids = rng.permutation(cfg.n_topics)

    next_cid = [0]
    # events: (timestamp, topic, cid, parent_cid, thread)
    events: list[tuple[float, int, int, int, int]] = []
    root_pool: dict[int, list[int]] = {}        # topic -> root cids
    root_uses: dict[int, int] = {}
    clock = 0.0
    thread_id = 0
    # generate threads until enough messages
    while len(events) < int(cfg.trace_len * 1.2):
        clock += rng.exponential(1.0 / cfg.thread_rate)
        tix = int(rng.choice(cfg.n_topics, p=topic_p))
        topic = int(topic_ids[tix])
        pool = root_pool.setdefault(topic, [])
        if pool and rng.random() < cfg.popular_repeat_prob:
            # popular prompts recur across users (weighted by prior use)
            w = np.array([1.0 + root_uses.get(c, 0) for c in pool])
            root = int(rng.choice(pool, p=w / w.sum()))
        else:
            root = next_cid[0]
            next_cid[0] += 1
            pool.append(root)
        root_uses[root] = root_uses.get(root, 0) + 1
        # thread tree: follow-up turns with exponential gaps, may branch
        n = max(1, int(rng.poisson(cfg.mean_thread_len)))
        nodes = [(root, -1, clock)]
        frontier = [root]
        tstamp = clock
        for _ in range(n - 1):
            tstamp += rng.exponential(2.0)
            parent = frontier[-1] if rng.random() > cfg.branch_prob else \
                frontier[int(rng.integers(0, len(frontier)))]
            cid = next_cid[0]
            next_cid[0] += 1
            nodes.append((cid, parent, tstamp))
            frontier.append(cid)
        for cid, par, ts in nodes:
            events.append((ts, topic, cid, par, thread_id))
        thread_id += 1

    events.sort(key=lambda e: e[0])
    events = events[:cfg.trace_len]

    occur: dict[int, int] = {}
    cid_parent: dict[int, int] = {}
    requests: list[Request] = []
    for t, (ts, topic, cid, par, thr) in enumerate(events):
        cid_parent.setdefault(cid, par)
        k = occur.get(cid, 0)
        occur[cid] = k + 1
        base = space.content_embedding(topic, cid, parent_content=cid_parent[cid])
        emb = space.paraphrase(base, topic, cid, k)
        requests.append(Request(t=t, cid=cid, emb=emb.astype(np.float32),
                                topic=topic, session=thr,
                                parent_cid=cid_parent[cid], timestamp=ts))

    tr = Trace(requests=requests, n_topics=cfg.n_topics,
               meta=dict(kind="oasst_style", cfg=dataclasses.asdict(cfg),
                         unique=len({r.cid for r in requests})))
    return tr.with_next_use()


def measured_long_reuse_ratio(trace: Trace, capacity: int) -> float:
    """Fraction of reuse events with positional reuse distance > capacity."""
    last: dict[int, int] = {}
    long_n = total = 0
    for r in trace.requests:
        if r.cid in last:
            total += 1
            if r.t - last[r.cid] > capacity:
                long_n += 1
        last[r.cid] = r.t
    return long_n / max(1, total)
