"""Logical-axis sharding annotations.

Models annotate activations with *logical* axis names (``"batch"``,
``"heads"``, ``"ffn"``, ``"expert"``, …).  The launcher activates a rule set
mapping logical names to mesh axes; outside a rule context the annotations
are no-ops, so the same model code runs on a laptop and on a 512-chip mesh.

    with use_rules(mesh, {"batch": ("pod", "data"), "heads": "model", ...}):
        lowered = jax.jit(step).lower(...)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _current() -> tuple[Optional[Mesh], dict]:
    return getattr(_state, "mesh", None), getattr(_state, "rules", {})


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: dict[str, Union[str, tuple, None]]):
    """Activate a logical->mesh axis mapping for constraints below."""
    prev = _current()
    _state.mesh, _state.rules = mesh, dict(rules)
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def logical_to_spec(axes: tuple[Optional[str], ...],
                    rules: dict) -> P:
    parts = []
    used: set = set()
    for a in axes:
        m = rules.get(a) if a is not None else None
        # one mesh axis may appear at most once in a PartitionSpec
        if m is None:
            parts.append(None)
            continue
        key = tuple(m) if isinstance(m, (tuple, list)) else (m,)
        if any(k in used for k in key):
            parts.append(None)
        else:
            used.update(key)
            parts.append(tuple(m) if isinstance(m, (tuple, list)) else m)
    return P(*parts)


def lc(x, *axes: Optional[str]):
    """Logical constraint: shard ``x`` by logical axis names (no-op when no
    rule context is active or shapes don't divide)."""
    mesh, rules = _current()
    if mesh is None or not rules:
        return x
    spec = logical_to_spec(axes, rules)
    # skip constraints that don't divide the dims evenly
    for dim, part in zip(x.shape, spec):
        if part is None:
            continue
        n = 1
        for ax in (part if isinstance(part, tuple) else (part,)):
            n *= mesh.shape[ax]
        if dim % n != 0:
            return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def spec_for(axes: tuple[Optional[str], ...]) -> P:
    """Resolve logical axes to a PartitionSpec under the active rules."""
    _, rules = _current()
    return logical_to_spec(axes, rules)
