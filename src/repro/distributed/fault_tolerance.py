"""Fault tolerance for 1000+-node fleets: heartbeats, straggler detection,
and elastic re-mesh planning.

On real multi-host deployments these hooks sit in the launcher process; the
mechanisms are host-side and hardware-agnostic, so they are fully
exercisable (and unit-tested) in this container:

  - HeartbeatMonitor: hosts report per-step heartbeats; a host missing
    ``timeout_s`` is declared dead -> the runner snapshots (checkpoint is
    already step-atomic) and requests an elastic restart.
  - StragglerDetector: robust z-score over per-host step wall-times
    (median/MAD); persistent stragglers are flagged for replacement —
    the mitigation used by production TPU fleets, where a slow host
    throttles every synchronous collective.
  - plan_elastic_mesh: given the surviving host count, pick the largest
    mesh (pods × data × model) that preserves the model axis (TP degree is
    a property of the checkpointed sharding; data/pod axes shrink freely).
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class HostState:
    last_beat: float | None = None
    step: int = -1
    alive: bool = True


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, timeout_s: float = 60.0,
                 clock=time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        self.hosts = {h: HostState() for h in range(n_hosts)}

    def beat(self, host: int, step: int):
        st = self.hosts[host]
        st.last_beat = self.clock()
        st.step = step
        st.alive = True

    def dead_hosts(self) -> list[int]:
        now = self.clock()
        dead = []
        for h, st in self.hosts.items():
            if st.last_beat is not None and now - st.last_beat > self.timeout_s:
                st.alive = False
                dead.append(h)
        return dead

    def all_alive(self) -> bool:
        return not self.dead_hosts()


class StragglerDetector:
    """Flag hosts whose step time is a robust outlier for >= ``patience``
    consecutive steps (median + k·MAD rule)."""

    def __init__(self, n_hosts: int, k: float = 4.0, patience: int = 3):
        self.k = k
        self.patience = patience
        self.strikes = [0] * n_hosts

    def observe(self, step_times: list[float]) -> list[int]:
        xs = sorted(step_times)
        n = len(xs)
        med = xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])
        mad = sorted(abs(x - med) for x in xs)[n // 2] or 1e-9
        flagged = []
        for h, t in enumerate(step_times):
            if (t - med) / (1.4826 * mad) > self.k:
                self.strikes[h] += 1
            else:
                self.strikes[h] = 0
            if self.strikes[h] >= self.patience:
                flagged.append(h)
        return flagged


def plan_elastic_mesh(n_hosts_alive: int, chips_per_host: int,
                      model_parallel: int,
                      pod_size_chips: int = 256) -> dict:
    """Largest (pod, data, model) mesh on the surviving chips, preserving
    the checkpoint's TP degree.  Returns axis sizes + dropped-chip count."""
    chips = n_hosts_alive * chips_per_host
    if chips < model_parallel:
        raise ValueError("not enough chips to preserve the model axis")
    data = chips // model_parallel
    pods = max(1, chips // pod_size_chips)
    while data % pods != 0 and pods > 1:
        pods -= 1
    used = data * model_parallel
    return {"pod": pods, "data": data // pods, "model": model_parallel,
            "chips_used": used, "chips_idle": chips - used}
