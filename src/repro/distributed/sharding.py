"""Sharding rule engine: maps every parameter / input / cache tensor of an
(arch × shape) cell onto the production mesh.

Strategy (DESIGN.md §4):
  - TP over ``model``: attention heads, FFN hidden, vocab, MoE experts
    (experts fall back to intra-expert FFN TP when n_experts doesn't divide
    the axis, e.g. grok-1's 8 experts on a 16-way axis).
  - DP over ``("pod", "data")`` for the batch.
  - FSDP/ZeRO over ``data`` for params + optimizer moments of large models.
  - Decode KV caches: batch over DP, sequence over ``model`` when KV heads
    don't divide the TP axis (XLA SPMD handles the sharded-softmax
    all-reduce), else KV heads over ``model``.
  - long_500k (batch=1): states over ``model``, ring-window over ``data``
    (sequence parallelism).

Divisibility is checked per tensor — anything that doesn't divide cleanly
is replicated on that axis (never an error).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, SHAPES


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    tp: str = "model"
    dp: tuple = ("data",)            # ("pod","data") on the multi-pod mesh
    fsdp: bool = False               # shard params/moments over dp[-1]
    # training shards params+moments over data from 8B params (memory);
    # decode avoids weight sharding until 12B — TP-only weights are
    # resident and the per-token gathers vanish (§Perf: gemma decode
    # collective 17.5 ms -> 0.35 ms; train FSDP-off was refuted: −3%
    # collectives for +19 GiB peak)
    fsdp_min_params_train: int = 8_000_000_000
    fsdp_min_params_decode: int = 12_000_000_000
    # decode weight-stationary mode (§Perf iteration): replicate the token
    # batch over dp for the dense compute so the 2D-sharded weights are
    # consumed in place (partial matmul + small activation all-reduce)
    # instead of re-gathering every layer's weights per generated token.
    # The KV cache stays batch-sharded (attention runs batch-local).
    decode_2d: bool = False

    @staticmethod
    def for_mesh(mesh: Mesh, cfg: ModelConfig,
                 shape_kind: str = "train") -> "ShardingPlan":
        dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
        n = cfg.n_params()
        if shape_kind == "decode":
            fsdp = n >= ShardingPlan.fsdp_min_params_decode
            return ShardingPlan(dp=dp, fsdp=fsdp, decode_2d=fsdp)
        return ShardingPlan(dp=dp,
                            fsdp=n >= ShardingPlan.fsdp_min_params_train)


# -- parameter logical axes -------------------------------------------------
# leaf-name -> logical axis names per dim (leading "layer" dim is prepended
# automatically for scanned stacks)
_PARAM_AXES: list[tuple[str, tuple]] = [
    (r"emb/tok$",            ("vocab", "embed")),
    (r"emb/unembed$",        ("embed", "vocab")),
    (r"(^|/)ln\w*/scale$",   ("embed",)),
    (r"norm_f/scale$",       ("embed",)),
    (r"gn_scale$",           ("inner",)),
    (r"attn/wq$",            ("embed", "heads", "hd")),
    (r"attn/w[kv]$",         ("embed", "kv_heads", "hd")),
    (r"attn/wo$",            ("heads", "hd", "embed")),
    (r"attn/b[q]$",          ("heads", "hd")),
    (r"attn/b[kv]$",         ("kv_heads", "hd")),
    (r"xattn/wq$",           ("embed", "heads", "hd")),
    (r"xattn/w[kv]$",        ("embed", "kv_heads", "hd")),
    (r"xattn/wo$",           ("heads", "hd", "embed")),
    (r"attn/wdkv$",          ("embed", "kv_lora")),
    (r"attn/wu[kv]$",        ("kv_lora", "heads", "hd")),
    (r"attn/wkr$",           ("embed", None)),
    (r"mlp/w[ig]$",          ("embed", "ffn")),
    (r"mlp/wo$",             ("ffn", "embed")),
    (r"moe/router$",         ("embed", "expert")),
    (r"moe/w[ig]$",          ("expert", "embed", "expert_ffn")),
    (r"moe/wo$",             ("expert", "expert_ffn", "embed")),
    (r"moe/shared/w[ig]$",   ("embed", "ffn")),
    (r"moe/shared/wo$",      ("ffn", "embed")),
    (r"mamba/w_in$",         ("embed", "inner")),
    (r"mamba/conv$",         (None, "inner")),
    (r"mamba/w_bc$",         ("inner", None)),
    (r"mamba/w_dt$",         ("inner", "inner2")),
    (r"mamba/[ab]_dt$",      ("inner",)),
    (r"mamba/a_log$",        ("inner", None)),
    (r"mamba/d_skip$",       ("inner",)),
    (r"mamba/w_out$",        ("inner", "embed")),
    (r"mlstm/w_up$",         ("embed", "inner")),
    (r"mlstm/w_qkv$",        ("inner", "inner2")),
    (r"mlstm/w_if$",         ("inner", None)),
    (r"mlstm/b_if$",         (None,)),
    (r"mlstm/w_down$",       ("inner", "embed")),
    (r"slstm/w_x$",          ("embed", "inner")),
    (r"slstm/r_h$",          (None, None, None)),
    (r"slstm/b$",            (None,)),
    (r"slstm/w_up$",         ("embed", "inner")),
    (r"slstm/w_down$",       ("inner", "embed")),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _fits(mesh: Mesh, dim: int, axis) -> bool:
    return dim % _axis_size(mesh, axis) == 0


def param_spec(path_s: str, shape: tuple, cfg: ModelConfig,
               plan: ShardingPlan, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf."""
    axes: Optional[tuple] = None
    for pat, ax in _PARAM_AXES:
        if re.search(pat, path_s):
            axes = ax
            break
    if axes is None:
        return P()
    # scanned stacks carry a leading layer dim
    if len(shape) == len(axes) + 1:
        axes = (None, *axes)
    elif len(shape) != len(axes):
        return P()

    tp_used = False
    fsdp_used = False
    parts: list = []
    # TP priority order per logical name
    for dim, name in zip(shape, axes):
        part = None
        if name in ("vocab", "heads", "kv_heads", "ffn", "expert",
                    "kv_lora", "inner") and not tp_used:
            if _fits(mesh, dim, plan.tp):
                part = plan.tp
                tp_used = True
        elif name == "expert_ffn" and not tp_used:
            if _fits(mesh, dim, plan.tp):
                part = plan.tp
                tp_used = True
        parts.append(part)
    # second pass: FSDP shards the first eligible unused dim over data
    if plan.fsdp:
        fsdp_ax = plan.dp[-1]
        for i, (dim, name) in enumerate(zip(shape, axes)):
            if parts[i] is None and name == "embed" and \
                    _fits(mesh, dim, fsdp_ax):
                parts[i] = fsdp_ax
                fsdp_used = True
                break
        if not fsdp_used:       # fall back: any unsharded divisible dim
            for i, dim in enumerate(shape):
                if parts[i] is None and axes[i] is not None and \
                        _fits(mesh, dim, fsdp_ax):
                    parts[i] = fsdp_ax
                    break
    return P(*parts)


def param_shardings(params_tree, cfg: ModelConfig, plan: ShardingPlan,
                    mesh: Mesh):
    """Tree of NamedShardings matching a (ShapeDtypeStruct) param tree."""
    def f(path, leaf):
        spec = param_spec(_path_str(path), leaf.shape, cfg, plan, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(f, params_tree)


# -- inputs / caches --------------------------------------------------------
def batch_shardings(cfg: ModelConfig, shape: str, specs_tree,
                    plan: ShardingPlan, mesh: Mesh):
    """NamedShardings for the input_specs() tree of one cell."""
    sc = SHAPES[shape]
    dp = plan.dp if _fits(mesh, sc.global_batch, plan.dp) else (
        plan.dp[-1] if _fits(mesh, sc.global_batch, plan.dp[-1]) else None)

    def cache_spec(path_s: str, shp: tuple) -> P:
        # stacked caches: (L, B, S, ...) — batch over DP; seq or heads on TP
        parts: list = [None] * len(shp)
        if len(shp) >= 2 and _fits(mesh, shp[1], dp):
            parts[1] = dp
        if len(shp) >= 3:
            # kv: (L,B,S,Hkv,hd) | mla: (L,B,S,r) | ring: (L,B,W,Hkv,hd)
            if "kv/k" in path_s or "kv/v" in path_s or "c_kv" in path_s \
                    or "k_rope" in path_s:
                if len(shp) == 5 and _fits(mesh, shp[3], plan.tp):
                    parts[3] = plan.tp           # kv heads divide TP
                elif _fits(mesh, shp[2], plan.tp):
                    parts[2] = plan.tp           # shard the sequence
            else:
                # recurrent states: shard the widest inner dim on TP
                for i in range(2, len(shp)):
                    if parts[i] is None and shp[i] % _axis_size(mesh, plan.tp) == 0 \
                            and shp[i] >= _axis_size(mesh, plan.tp):
                        parts[i] = plan.tp
                        break
        return P(*parts)

    def f(path, leaf):
        path_s = _path_str(path)
        shp = leaf.shape
        if "cache" in path_s:
            return NamedSharding(mesh, cache_spec(path_s, shp))
        parts: list = [None] * len(shp)
        if (len(shp) >= 1 and dp is not None and shp[0] == sc.global_batch
                and _fits(mesh, shp[0], dp)
                and not (plan.decode_2d and sc.kind == "decode")):
            parts[0] = dp
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(f, specs_tree)


def activation_rules(cfg: ModelConfig, shape: str, plan: ShardingPlan,
                     mesh: Mesh) -> dict:
    """Logical-axis rules for repro.distributed.api.use_rules."""
    sc = SHAPES[shape]
    dp = plan.dp if _fits(mesh, sc.global_batch, plan.dp) else (
        plan.dp[-1] if _fits(mesh, sc.global_batch, plan.dp[-1]) else None)
    rules = {
        "batch": None if (plan.decode_2d and sc.kind == "decode") else dp,
        "heads": plan.tp if cfg.n_heads % _axis_size(mesh, plan.tp) == 0 else None,
        "kv_heads": plan.tp if cfg.n_kv_heads % _axis_size(mesh, plan.tp) == 0 else None,
        "ffn": plan.tp,
        "vocab": plan.tp,
        "expert": plan.tp if (cfg.n_experts and
                              cfg.n_experts % _axis_size(mesh, plan.tp) == 0) else None,
        "seq": None,
        # Megatron sequence parallelism: residual stream seq-sharded over
        # the TP axis between TP regions (train/prefill, attention models;
        # recurrent scans keep their sequence axis unsharded).  §Perf: for
        # narrow models (d_model < 4096) SP's activation-memory win is
        # irrelevant and its per-boundary gathers dominate — skip it.
        "seq_sp": (plan.tp if sc.kind in ("train", "prefill") and
                   cfg.family in ("dense", "moe", "encdec", "vlm") and
                   cfg.d_model >= 4096 else None),
        # decode weight-stationary mode: residual features sharded over the
        # data axis so every matmul is a local partial-sum + small
        # activation all-reduce (no per-token weight gathers)
        "dmodel": (plan.dp[-1] if (plan.decode_2d and sc.kind == "decode")
                   else None),
    }
    if shape == "long_500k":
        rules["seq"] = plan.dp[-1]      # sequence parallelism for SP decode
    return rules
