"""Gradient compression: int8 quantized all-reduce with error feedback.

Large-scale distributed optimization trick (DESIGN.md §4): gradients are
quantized per-tensor to int8 around a shared fp32 scale before the data-
parallel all-reduce, and the quantization error is fed back into the next
step's gradient (error-feedback keeps SGD/Adam convergence unbiased in
expectation).  4× less DP collective traffic; optional — off by default.

Pure functions so the launcher can jit them into the train step.  The
per-tensor int8 codec itself lives in :mod:`repro.kernels.quant` (shared
with the cache's quantized lookup path) and is re-exported here unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quant import dequantize_int8, quantize_int8

__all__ = ["quantize_int8", "dequantize_int8", "compress_grads",
           "decompress_grads", "init_residuals"]


def compress_grads(grads, residuals):
    """Returns (quantized tree, scales tree, new residuals tree)."""
    def one(g, r):
        g_fb = g.astype(jnp.float32) + r
        q, s = quantize_int8(g_fb)
        deq = dequantize_int8(q, s)
        return q, s, g_fb - deq
    out = jax.tree.map(one, grads, residuals)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    r = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return q, s, r


def decompress_grads(q, s):
    return jax.tree.map(dequantize_int8, q, s)


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
