"""Distribution substrate: sharding rules, checkpointing, fault tolerance,
gradient compression, logical-axis annotations."""
from .api import lc, use_rules
from .sharding import ShardingPlan

__all__ = ["lc", "use_rules", "ShardingPlan"]
