"""Sharded checkpointing with atomic commit and elastic restore.

Layout (one directory per step):

    ckpt_dir/step_000123/
        manifest.json            # step, mesh shape, data cursor, tree spec
        shard_h000.npz           # this host's param/optimizer shards
    ckpt_dir/step_000123.COMMIT  # empty marker written last (atomic rename)

Design points for 1000+-node fleets:
  - every host writes only its addressable shards (no gather to host 0);
  - the manifest stores the *global* array shapes + PartitionSpecs, so a
    restart may use a different mesh (elastic re-shard on load);
  - commit marker is written after all shards fsync — a crashed write
    leaves no half-checkpoint (restore picks the newest committed step);
  - the data-pipeline cursor rides in the manifest: restart replays the
    exact batch sequence (bit-for-bit deterministic resume).

This CPU container exercises the single-host path; the multi-host path
only changes which shards each process owns (jax.process_index()).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(tree, flat: dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in paths[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = flat[key]
        leaves.append(np.asarray(arr, dtype=leaf.dtype).reshape(leaf.shape))
    return jax.tree_util.tree_unflatten(paths[1], leaves)


def save_checkpoint(ckpt_dir: str, step: int, state: dict,
                    extra: dict | None = None) -> str:
    """state: pytree of arrays (params/opt/rng).  extra: JSON metadata
    (data cursor, mesh shape, trace position, ...)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{step:08d}"
    final = os.path.join(ckpt_dir, name)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".{name}.tmp")
    try:
        flat = _flatten(state)
        host = jax.process_index()
        np.savez(os.path.join(tmp, f"shard_h{host:03d}.npz"), **flat)
        manifest = {
            "step": step,
            "n_hosts": jax.process_count(),
            "keys": sorted(flat.keys()),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                      # atomic publish
        open(final + ".COMMIT", "w").close()        # commit marker
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for n in os.listdir(ckpt_dir):
        if n.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, n) + ".COMMIT"):
            steps.append(int(n.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, state_like, step: int | None = None):
    """Restore into the structure of ``state_like`` (elastic: shapes are
    validated against the manifest, re-sharding happens on device_put).
    Returns (state, extra) or (None, None) when nothing committed."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None, None
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat: dict[str, np.ndarray] = {}
    for n in sorted(os.listdir(d)):
        if n.startswith("shard_") and n.endswith(".npz"):
            with np.load(os.path.join(d, n)) as z:
                for k in z.files:
                    flat[k] = z[k]
    state = _unflatten_into(state_like, flat)
    return state, manifest["extra"]
