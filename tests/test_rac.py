"""RAC policy unit tests: Def.1/Def.2 faithfulness, Alg.1-5 behavior,
Example 1 (anchors survive topic switches), ghost-metadata bounds,
PageRank appendix (numpy oracle vs the wired jax device path)."""
import numpy as np
import pytest

from repro.core import EmbeddingSpace, Request, pagerank_reversed
from repro.core import structural
from repro.core.policies import LRUPolicy
from repro.core.rac import RACPolicy
from repro.core.store import ResidentStore
from repro.core.structural import pagerank_scores


def _req(t, cid, emb):
    return Request(t=t, cid=cid, emb=emb)


def _mk(capacity=6, dim=16, **kw):
    store = ResidentStore(capacity, dim)
    pol = RACPolicy(capacity, store, **kw)
    return store, pol


def _arrive(store, pol, cid, emb, t, capacity):
    if cid in store:
        pol.on_hit(cid, _req(t, cid, emb), t)
        return True
    store.insert(cid, emb)
    pol.on_admit(cid, _req(t, cid, emb), t)
    while len(store) > capacity:
        store.remove(pol.victim(t))
    return False


# ---------------------------------------------------------------- TP (Def.1)
def test_tp_lazy_closed_form_matches_direct_sum(rng):
    """TP_t(s) = Σ_{i∈H_t(s)} 0.5^{α(t-i)} — the O(1) lazy update must
    equal the direct definition at every step."""
    alpha = 0.02
    store, pol = _mk(capacity=50, alpha=alpha, tau_route=0.3)
    space = EmbeddingSpace(dim=16, seed=1)
    hit_times = []
    t = 0
    for k in range(60):
        t += int(rng.integers(1, 9))
        emb = space.paraphrase(space.content_embedding(0, 0), 0, 0, k)
        _arrive(store, pol, 0, emb.astype(np.float32), t, 50)
        hit_times.append(t)
        # single topic 0 throughout
        assert len(pol.topics) == 1
        tid = next(iter(pol.topics))
        direct = sum(0.5 ** (alpha * (t - i)) for i in hit_times)
        assert pol.tp_now(tid, t) == pytest.approx(direct, rel=1e-9)


def test_new_topic_created_beyond_gate():
    store, pol = _mk(capacity=10, tau_route=0.65)
    space = EmbeddingSpace(dim=16, seed=2)
    e0 = space.content_embedding(0, 0).astype(np.float32)
    e1 = space.content_embedding(1, 1).astype(np.float32)  # other topic
    _arrive(store, pol, 0, e0, 1, 10)
    _arrive(store, pol, 1, e1, 2, 10)
    assert pol._next_tid == 2     # cross-topic sim ≈ 0 -> two topics


def test_same_topic_routes_together():
    store, pol = _mk(capacity=10, dim=32, tau_route=0.65)
    space = EmbeddingSpace(dim=32, seed=3)
    for i in range(5):
        e = space.content_embedding(7, 100 + i,
                                    parent_content=100 if i else -1)
        _arrive(store, pol, 100 + i, e.astype(np.float32), i + 1, 10)
    assert pol._next_tid == 1
    tid = next(iter(pol.topics))
    assert len(pol.topics[tid].members) == 5


# -------------------------------------------------------------- TSI (Def.2)
def test_tsi_update_cascade_alg3():
    """Hand-checked Alg.3: child accesses propagate dep to the parent."""
    store, pol = _mk(capacity=10, dim=32, tau_route=0.3, tau_edge=0.5,
                     lam=1.0, lookback=10)
    space = EmbeddingSpace(dim=32, seed=4)
    root = space.content_embedding(0, 0).astype(np.float32)
    child = space.content_embedding(0, 1, parent_content=0).astype(np.float32)
    _arrive(store, pol, 0, root, 1, 10)       # freq(0)=1
    _arrive(store, pol, 1, child, 2, 10)      # freq(1)=1; parent detect -> 0
    s0 = store.slot_of[0]
    s1 = store.slot_of[1]
    assert pol.par[1] == 0
    # new link: dep(parent) += freq(child) = 1
    assert pol.dep[s0] == 1.0
    assert pol.tsi[s0] == pytest.approx(pol.freq[s0] + pol.lam * 1.0)
    # re-access child: cached parent, dep(parent) += 1
    _arrive(store, pol, 1, child, 3, 10)
    assert pol.dep[s0] == 2.0
    assert pol.freq[s1] == 2.0


def test_lifetime_metadata_survives_eviction():
    """Def.2: freq counts hits 'so far' — ghost metadata restores on
    re-admission."""
    store, pol = _mk(capacity=2, tau_route=0.3)
    space = EmbeddingSpace(dim=16, seed=5)
    e = {i: space.content_embedding(i, i).astype(np.float32) for i in range(4)}
    for t, cid in enumerate([0, 0, 0]):            # freq(0) = 3
        _arrive(store, pol, cid, e[cid], t + 1, 2)
    pol._forget(0)                                  # force the eviction path
    store.remove(0)
    assert pol.g_freq[0] == 3.0
    _arrive(store, pol, 0, e[0], 10, 2)
    s0 = store.slot_of[0]
    assert pol.freq[s0] == 4.0    # restored 3 + this access


# ------------------------------------------------------------ Example 1
def test_example1_rac_keeps_anchors_lru_does_not():
    """Paper Example 1: alternate two topics with anchor reuse; under a
    tight cache RAC retains the context anchors across switches and scores
    hits where LRU gets none."""
    space = EmbeddingSpace(dim=32, seed=6)
    cap = 6

    def session(topic, anchor, leaves, occ):
        out = [(anchor, space.paraphrase(
            space.content_embedding(topic, anchor), topic, anchor, occ))]
        for leaf in leaves:
            out.append((leaf, space.content_embedding(topic, leaf,
                                                      parent_content=anchor)))
        return out

    # a0..a5 | b0..b5 | a0,a1*..a5* | b0,b1*..b5*  (anchors recur)
    stream = []
    stream += session(0, 0, [1, 2, 3, 4, 5], 0)
    stream += session(1, 10, [11, 12, 13, 14, 15], 0)
    stream += session(0, 0, [21, 22, 23, 24, 25], 1)
    stream += session(1, 10, [31, 32, 33, 34, 35], 1)

    def run(policy_cls, **kw):
        store = ResidentStore(cap, 32)
        pol = policy_cls(cap, store, **kw)
        hits = 0
        for t, (cid, emb) in enumerate(stream):
            hits += _arrive(store, pol, cid, emb.astype(np.float32),
                            t + 1, cap)
        return hits

    lru_hits = run(LRUPolicy)
    rac_hits = run(RACPolicy, tau_route=0.5, tau_edge=0.5, alpha=0.01,
                   lam=2.0)
    assert lru_hits == 0          # every reuse is beyond LRU's horizon
    assert rac_hits >= 2          # both anchor re-asks hit under RAC


# ------------------------------------------------------------- eviction
def test_eviction_prefers_low_value_topic():
    # Eq.1-literal ordering (the normalized default would bounce the
    # fresh topic-A leaf instead — covered by the Example 1 test)
    store, pol = _mk(capacity=4, dim=32, tau_route=0.5, alpha=0.05,
                     value_mode="paper")
    space = EmbeddingSpace(dim=32, seed=7)
    # topic A hit many times (hot), topic B once (cold)
    ea = {i: space.content_embedding(0, i, parent_content=0 if i else -1)
          for i in range(3)}
    eb = space.content_embedding(1, 100)
    t = 0
    for rep in range(3):
        for i in range(3):
            t += 1
            _arrive(store, pol, i, ea[i].astype(np.float32), t, 4)
    t += 1
    _arrive(store, pol, 100, eb.astype(np.float32), t, 4)
    # force one eviction: the cold B entry must go before hot A members
    t += 1
    enew = space.content_embedding(0, 50, parent_content=0)
    _arrive(store, pol, 50, enew.astype(np.float32), t, 4)
    assert 100 not in store
    assert 0 in store


def test_victim_determinism():
    for _ in range(2):
        store, pol = _mk(capacity=3, tau_route=0.5)
        space = EmbeddingSpace(dim=16, seed=8)
        order = []
        for t, cid in enumerate([0, 1, 2, 3, 4, 5]):
            emb = space.content_embedding(cid % 2, cid).astype(np.float32)
            was_hit = _arrive(store, pol, cid, emb, t + 1, 3)
            order.append(sorted(store.keys()))
        if _ == 0:
            first = order
        else:
            assert order == first


# --------------------------------------------------------- ghost bounds
def test_ghost_limit_fifo_bound():
    """The declared ghost_limit is a hard FIFO bound: a trace that evicts
    3x the limit of distinct contents never grows g_freq/g_dep past it,
    and the survivors are the most recently forgotten cids."""
    limit = 64
    cap = 4
    store, pol = _mk(capacity=cap, tau_route=0.3, ghost_limit=limit)
    space = EmbeddingSpace(dim=16, seed=11)
    n = 3 * limit + cap
    for t, cid in enumerate(range(n)):
        emb = space.content_embedding(cid % 8, cid).astype(np.float32)
        _arrive(store, pol, cid, emb, t + 1, cap)
        assert len(pol.g_freq) <= limit
        assert len(pol.g_dep) <= limit
        assert set(pol.g_dep) == set(pol.g_freq)
    assert len(pol.g_freq) > 0
    # FIFO: every surviving ghost is newer than every dropped one
    assert min(pol.g_freq) > 0


def test_ghost_limit_tiny_limits_stay_bounded():
    """Degenerate limits (smaller than the drop batch) still bound."""
    for limit in (0, 1, 3):
        store, pol = _mk(capacity=2, tau_route=0.3, ghost_limit=limit)
        space = EmbeddingSpace(dim=16, seed=12)
        for t, cid in enumerate(range(24)):
            emb = space.content_embedding(cid % 4, cid).astype(np.float32)
            _arrive(store, pol, cid, emb, t + 1, 2)
            assert len(pol.g_freq) <= limit
            assert len(pol.g_dep) <= limit


def test_ghost_topic_limit_bounds_topic_memory():
    """The topic-memory ghost table (Alg.2 TP revival state) honors the
    configurable ``ghost_topic_limit`` — it is no longer a hard-coded 4096:
    a trace cycling through 4x the limit of distinct topics never grows
    ``ghost_topics`` past the bound, and revival still works inside it."""
    limit = 8
    store, pol = _mk(capacity=2, tau_route=0.3, ghost_topic_limit=limit)
    space = EmbeddingSpace(dim=16, seed=14)
    for t, cid in enumerate(range(4 * limit)):
        emb = space.content_embedding(cid, cid).astype(np.float32)
        _arrive(store, pol, cid, emb, t + 1, 2)
        assert len(pol.ghost_topics) <= limit
    assert len(pol.ghost_topics) > 0
    # a ghost topic inside the bound revives with its TP state (Alg.2):
    # re-arriving content of a remembered topic must not mint a new tid
    gid = max(pol.ghost_topics.keys())
    ntid = pol._next_tid
    emb = space.content_embedding(4 * limit - 1, 4 * limit - 1)
    _arrive(store, pol, 4 * limit - 1, emb.astype(np.float32), 200, 2)
    assert pol._next_tid == ntid            # revived, not re-created
    assert gid not in pol.ghost_topics or len(pol.ghost_topics) <= limit


def test_ghost_restore_still_works_under_limit():
    """A ghost inside the bound still restores its lifetime counters."""
    store, pol = _mk(capacity=2, tau_route=0.3, ghost_limit=8)
    space = EmbeddingSpace(dim=16, seed=13)
    e = {i: space.content_embedding(i, i).astype(np.float32)
         for i in range(4)}
    for t, cid in enumerate([0, 0, 0]):             # freq(0) = 3
        _arrive(store, pol, cid, e[cid], t + 1, 2)
    pol._forget(0)
    store.remove(0)
    assert pol.g_freq[0] == 3.0
    _arrive(store, pol, 0, e[0], 10, 2)
    assert pol.freq[store.slot_of[0]] == 4.0


# ------------------------------------------------------------- pagerank
def test_pagerank_matches_linear_solve(rng):
    n = 7
    edges = [(0, 1), (0, 2), (1, 3), (2, 3), (3, 4), (0, 5), (5, 6)]
    beta = 0.85
    r = pagerank_reversed(edges, n, beta=beta)
    assert r.sum() == pytest.approx(1.0, abs=1e-8)
    # solve the stationary equation directly: r = (1-b)/n + b (P^T r + dang)
    out_deg = np.zeros(n)
    for (u, v) in edges:
        out_deg[v] += 1
    P = np.zeros((n, n))          # P[u,v] = 1/outdeg(v) for reversed v->u
    for (u, v) in edges:
        P[u, v] = 1.0 / out_deg[v]
    dang = (out_deg == 0).astype(float)
    A = np.eye(n) - beta * (P + np.outer(np.full(n, 1.0 / n), dang))
    b = np.full(n, (1 - beta) / n)
    r_direct = np.linalg.solve(A, b)
    np.testing.assert_allclose(r, r_direct, atol=1e-8)
    # anchors (0) must rank highest: most downstream mass
    assert r[0] == r.max()


def test_pagerank_power_jax_matches_oracle_on_random_dags(rng):
    """Parity of the wired device path: pagerank_scores(device=True) runs
    the jax power iteration and must agree with the pagerank_reversed
    numpy oracle on random DAGs (edges u->v with u < v, so acyclic)."""
    for _ in range(5):
        n = int(rng.integers(3, 24))
        edges = [(u, v) for v in range(1, n) for u in range(v)
                 if rng.random() < 0.3]
        r_np = pagerank_reversed(edges, n)
        r_jx = pagerank_scores(edges, n, device=True)
        assert r_jx.shape == (n,)
        np.testing.assert_allclose(r_jx, r_np, atol=2e-5)
        assert r_jx.sum() == pytest.approx(1.0, abs=1e-4)


def test_pagerank_scores_host_engine_is_oracle():
    edges = [(0, 1), (0, 2), (1, 3)]
    np.testing.assert_array_equal(pagerank_scores(edges, 4, device=False),
                                  pagerank_reversed(edges, 4))


def test_rac_pagerank_mode_runs_on_device_path(monkeypatch):
    """structural_mode="pagerank" drives refreshes through the jax power
    iteration by default (the formerly dead device path)."""
    calls = {"device": 0}
    orig = structural.pagerank_scores

    def spy(edges, n, beta=0.85, device=False, iters=128):
        calls["device"] += bool(device)
        return orig(edges, n, beta=beta, device=device, iters=iters)

    monkeypatch.setattr(structural, "pagerank_scores", spy)
    store, pol = _mk(capacity=8, dim=32, structural_mode="pagerank",
                     pagerank_every=1, tau_route=0.5)
    space = EmbeddingSpace(dim=32, seed=9)
    for t, cid in enumerate(range(12)):
        emb = space.content_embedding(0, cid,
                                      parent_content=0 if cid else -1)
        _arrive(store, pol, cid, emb.astype(np.float32), t + 1, 8)
    assert len(store) <= 8
    assert calls["device"] > 0


def test_rac_pagerank_oracle_engine_still_available():
    """structural_device=False keeps the numpy oracle engine selectable."""
    store, pol = _mk(capacity=8, dim=32, structural_mode="pagerank",
                     structural_device=False, pagerank_every=1,
                     tau_route=0.5)
    space = EmbeddingSpace(dim=32, seed=9)
    for t, cid in enumerate(range(12)):
        emb = space.content_embedding(0, cid,
                                      parent_content=0 if cid else -1)
        _arrive(store, pol, cid, emb.astype(np.float32), t + 1, 8)
    assert len(store) <= 8
