"""Workload generator properties (paper §4.2 axes respond to their knobs)."""
import numpy as np

from repro.core import (OASSTConfig, SynthConfig, measured_long_reuse_ratio,
                        oasst_style_trace, synthetic_trace)


def test_long_reuse_knob_monotone():
    ratios = []
    for lr in (0.3, 0.6, 0.9):
        cfg = SynthConfig(trace_len=4000, seed=3, long_reuse_ratio=lr,
                          capacity_ref=400)
        tr = synthetic_trace(cfg)
        ratios.append(measured_long_reuse_ratio(tr, 400))
    assert ratios[0] < ratios[1] < ratios[2]


def test_zipf_gamma_concentrates_topics():
    def head_share(gamma):
        tr = synthetic_trace(SynthConfig(trace_len=4000, seed=4,
                                         zipf_gamma=gamma))
        counts = {}
        for r in tr.requests:
            counts[r.topic] = counts.get(r.topic, 0) + 1
        top = sorted(counts.values(), reverse=True)
        return sum(top[:10]) / sum(top)
    assert head_share(1.2) > head_share(0.4)


def test_topic_cores_recur_across_sessions():
    tr = synthetic_trace(SynthConfig(trace_len=4000, seed=5))
    by_cid_sessions = {}
    for r in tr.requests:
        by_cid_sessions.setdefault(r.cid, set()).add(r.session)
    multi = sum(1 for s in by_cid_sessions.values() if len(s) >= 3)
    assert multi > 50          # topic cores exist and recur


def test_episodes_never_interleave():
    tr = synthetic_trace(SynthConfig(trace_len=2000, seed=6))
    seen_done = set()
    cur = None
    for r in tr.requests:
        if r.session != cur:
            assert r.session not in seen_done, "session interleaved"
            if cur is not None:
                seen_done.add(cur)
            cur = r.session


def test_oasst_style_timestamps_and_structure():
    tr = oasst_style_trace(OASSTConfig(trace_len=3000, seed=7))
    ts = [r.timestamp for r in tr.requests]
    assert all(b >= a for a, b in zip(ts, ts[1:]))      # chronological
    assert len(tr.requests) == 3000
    # conversations interleave (unlike synthetic episodes)
    switches = sum(1 for a, b in zip(tr.requests, tr.requests[1:])
                   if a.session != b.session)
    assert switches > 500
    # repeats exist (popular prompts recur across users)
    cids = [r.cid for r in tr.requests]
    assert len(set(cids)) < len(cids)


def test_semantic_hits_match_content_hits():
    """The embedding geometry keeps semantic (cosine) and content (cid)
    hit determination in agreement (paper: identical hit semantics)."""
    from repro.core import run_policy
    from repro.core.policies import LRUPolicy
    tr = synthetic_trace(SynthConfig(trace_len=1500, seed=8))
    cap = 200
    s_content = run_policy(tr, cap, lambda c, st: LRUPolicy(c, st),
                           hit_mode="content")
    s_sem = run_policy(tr, cap, lambda c, st: LRUPolicy(c, st),
                       hit_mode="semantic", tau_hit=0.85)
    # identical up to rare borderline-similarity flips
    assert abs(s_content.hits - s_sem.hits) <= 0.02 * len(tr.requests)
