"""Additional coverage: optimizer behavior, engine eviction paths,
OASST structure validity, checkpoint manifests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_lr


def test_cosine_schedule_endpoints():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_frac=0.1)
    assert float(cosine_lr(cfg, jnp.asarray(0))) == 0.0
    assert abs(float(cosine_lr(cfg, jnp.asarray(10))) - 1e-3) < 1e-8  # peak
    end = float(cosine_lr(cfg, jnp.asarray(100)))
    assert abs(end - 1e-4) < 1e-8                                # min_lr_frac


def test_adamw_converges_on_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=200,
                      weight_decay=0.0, clip_norm=100.0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}            # d/dx (x²)
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["x"]).max()) < 0.05


def test_adamw_grad_clip_reported():
    cfg = AdamWConfig(clip_norm=1.0)
    params = {"x": jnp.zeros(3)}
    state = adamw_init(params)
    _, _, metrics = adamw_update(cfg, params, {"x": jnp.full(3, 100.0)}, state)
    assert float(metrics["grad_norm"]) > 100.0    # raw norm reported


def test_engine_eviction_keeps_capacity():
    from repro.configs import get_config
    from repro.core import EmbeddingSpace
    from repro.models import smoke_variant
    from repro.serving import EngineConfig, ServingEngine
    eng = ServingEngine(smoke_variant(get_config("paper")),
                        EngineConfig(cache_capacity=4, max_new_tokens=2,
                                     max_batch=2, max_seq=32))
    space = EmbeddingSpace(dim=64, seed=3)
    reqs = [(i, space.content_embedding(i % 3, i).astype(np.float32), [2, 3])
            for i in range(12)]
    eng.run(reqs)
    assert len(eng.store) <= 4
    # responses map only holds resident entries
    assert set(eng.responses) <= set(eng.store.keys())


def test_oasst_thread_parents_precede_children():
    from repro.core import OASSTConfig, oasst_style_trace
    tr = oasst_style_trace(OASSTConfig(trace_len=2000, seed=9))
    seen = set()
    violations = 0
    for r in tr.requests:
        if r.parent_cid >= 0 and r.parent_cid not in seen:
            violations += 1
        seen.add(r.cid)
    # thread interleaving may reorder a few, but parents overwhelmingly
    # precede their children (discourse causality)
    assert violations < 0.02 * len(tr.requests)


def test_checkpoint_manifest_contents(tmp_path):
    from repro.distributed.checkpoint import save_checkpoint
    import json, os
    d = save_checkpoint(str(tmp_path), 3,
                        {"a": np.ones((2, 3), np.float32)},
                        extra={"cursor": 3, "mesh": [16, 16]})
    man = json.load(open(os.path.join(d, "manifest.json")))
    assert man["step"] == 3
    assert man["shapes"]["a"] == [2, 3]
    assert man["extra"]["mesh"] == [16, 16]


def test_vocab_padding_alignment():
    from repro.configs import ARCH_IDS, get_config
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.padded_vocab % 2048 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
        assert cfg.padded_vocab - cfg.vocab_size < 2048


def test_shape_cells_assignment_coverage():
    """40 assigned cells = 10 archs × 4 shapes; 32 runnable + 8 noted
    long_500k skips for full-attention archs."""
    from repro.configs import ARCH_IDS, get_config, shape_cells
    total = runnable = 0
    for a in ARCH_IDS:
        cells = shape_cells(get_config(a))
        total += 4
        runnable += len(cells)
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(cells)
    assert total == 40
    assert runnable == 32
    assert {c for a in ("hymba-1.5b", "xlstm-125m")
            for c in shape_cells(get_config(a))} >= {"long_500k"}
