"""Facade-routed KV-block manager vs the legacy host implementation:
bit-identical hit/evict decisions on replayed token traces, structural
radix validity under the masked device scoring path, and the shared
facade metrics/hook surface."""
import numpy as np
import pytest

from repro.cache import NumpyBackend
from repro.core.radix import RadixRACPolicy
from repro.serving import KVBlockManager, LegacyKVBlockManager


def _token_trace(seed: int, n: int = 300) -> list[list[int]]:
    """Mixed workload: hot shared prefixes, extensions, and one-offs."""
    rng = np.random.default_rng(seed)
    hot = [list(range(16)), list(range(700, 712))]
    convs = []
    for _ in range(n):
        r = rng.random()
        if r < 0.25:        # reuse + extend a hot prefix
            h = hot[int(rng.integers(0, len(hot)))]
            convs.append(h + list(rng.integers(500, 600, size=int(rng.integers(0, 12)))))
        elif r < 0.4:       # partial hot prefix
            convs.append(hot[0][: 4 * int(rng.integers(1, 5))])
        else:               # one-off conversation
            base = 1000 + 40 * int(rng.integers(0, 60))
            convs.append(list(range(base, base + int(rng.integers(3, 30)))))
    return convs


def _resident_chains(mgr) -> list[tuple]:
    """Bid-independent residency fingerprint: every block's token chain."""
    def chain(bid):
        out = []
        while bid >= 0:
            b = mgr.blocks[bid]
            out.append(b.tokens)
            bid = b.parent
        return tuple(reversed(out))
    return sorted(chain(bid) for bid in mgr.blocks)


@pytest.mark.parametrize("seed,n_blocks", [(0, 24), (1, 8), (2, 48), (3, 3)])
def test_facade_manager_matches_legacy_decisions(seed, n_blocks):
    """The acceptance criterion: identical hit tokens, allocations,
    topics, and eviction outcomes per request across capacities (including
    n_blocks=3, where chains outgrow the store and allocation must fail
    exactly like the legacy victim<0 path)."""
    new = KVBlockManager(n_blocks=n_blocks, block_tokens=4)
    old = LegacyKVBlockManager(n_blocks=n_blocks, block_tokens=4)
    for i, conv in enumerate(_token_trace(seed)):
        rn = new.on_request(list(conv))
        ro = old.on_request(list(conv))
        assert rn["hit_tokens"] == ro["hit_tokens"], i
        assert len(rn["new_blocks"]) == len(ro["new_blocks"]), i
        assert rn["topic"] == ro["topic"], i
        assert new.used == old.used, i
        assert _resident_chains(new) == _resident_chains(old), i
    assert new.used <= n_blocks


def test_facade_manager_uses_facade_metrics_and_hooks():
    """Block eviction shares the facade's metrics/hook surface with the
    response cache: every block hit/miss/admit/evict is observable."""
    mgr = KVBlockManager(n_blocks=8, block_tokens=4)
    events = []
    for kind in ("hit", "miss", "admit", "evict"):
        mgr.cache.subscribe(kind, lambda ev, k=kind: events.append(k))
    mgr.on_request(list(range(16)))           # 4 new blocks
    mgr.on_request(list(range(16)))           # 4 block hits
    m = mgr.cache.metrics
    assert m.admissions == 4 and m.hits == 4 and m.misses == 4
    assert events.count("admit") == 4 and events.count("hit") == 4
    mgr.on_request(list(range(100, 120)))     # 5 more -> 1 eviction
    assert mgr.cache.metrics.evictions == 1
    assert events.count("evict") == 1


def test_radix_policy_masks_children_through_backend():
    """The masked Eq.1 scan: blocks with live children (or protected)
    score +inf through the backend's rac_value_masked."""
    from repro.core.store import ResidentStore
    store = ResidentStore(4, 1)
    pol = RadixRACPolicy(4, store)
    pol.masked_value_backend = NumpyBackend().rac_value_masked
    tid = pol.touch_topic(None, 1)
    for cid, parent in [(0, -1), (1, 0), (2, 1)]:
        store.insert(cid, np.zeros(1, np.float32))
        pol.stage(topic=tid, parent=parent)
        pol.on_admit(cid, None, 1)
    pol.protect.clear()
    pol._fresh = -1
    cids, values, valid = pol.value_scores(t=2)
    by = dict(zip(cids.tolist(), values.tolist()))
    assert by[0] == np.inf and by[1] == np.inf      # live children
    assert np.isfinite(by[2])                       # leaf is evictable
    assert pol.victim(2) == 2


@pytest.mark.parametrize("backend", ["kernel"])
def test_kernel_backend_manager_keeps_radix_validity(backend):
    """Device scoring path (jnp oracle on CPU): the children-first mask is
    a hard constraint regardless of float32 value rounding."""
    mgr = KVBlockManager(n_blocks=8, block_tokens=4, backend=backend,
                         use_pallas=False)
    rng = np.random.default_rng(5)
    for i in range(40):
        base = 100 * int(rng.integers(0, 12))
        mgr.on_request(list(range(base, base + int(rng.integers(4, 20)))))
        for bid, b in mgr.blocks.items():
            for ch in b.children:
                assert ch in mgr.blocks
            assert b.parent < 0 or b.parent in mgr.blocks, \
                f"orphan block {bid}: parent evicted first"
    assert mgr.cache.metrics.evictions > 0


def test_kv_manager_checkpoint_restores_mirror_with_cache():
    """The manager's checkpoint covers both the facade state and the
    radix mirror, so a restored manager never reports prefix hits for
    blocks the cache no longer holds."""
    mgr = KVBlockManager(n_blocks=8, block_tokens=4)
    mgr.on_request(list(range(8)))
    snap = mgr.checkpoint()
    mgr.on_request(list(range(100, 120)))     # churn past capacity
    assert len(mgr.blocks) > 2
    mgr.restore(snap)
    assert mgr.used == 2 and len(mgr.blocks) == 2
    assert set(mgr.blocks) == set(mgr.cache.store.keys())
    r = mgr.on_request(list(range(8)))        # rolled-back chain hits again
    assert r["hit_tokens"] == 8
    r2 = mgr.on_request(list(range(100, 108)))  # churned chain is gone
    assert r2["hit_tokens"] == 0


def test_rac_value_masked_kernel_matches_numpy():
    rng = np.random.default_rng(0)
    from repro.cache import KernelBackend
    nb, kb = NumpyBackend(), KernelBackend(use_pallas=False)
    tsi = rng.random(64)
    tids = rng.integers(0, 8, 64)
    tp_last = rng.random(8) * 5
    t_last = rng.integers(0, 300, 8)
    valid = rng.random(64) < 0.6
    a = nb.rac_value_masked(tsi, tids, tp_last, t_last, 0.001, 400, valid)
    b = kb.rac_value_masked(tsi, tids, tp_last, t_last, 0.001, 400, valid)
    assert np.array_equal(np.isinf(a), np.isinf(b))
    np.testing.assert_allclose(a[valid], b[valid], rtol=1e-5)
