"""Dry-run integration: one full (arch × shape × mesh) cell compiled in a
subprocess with 512 placeholder devices (slow-ish but the core deliverable),
plus HLO cost-model calibration checks in-process."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch,shape,flags", [
    ("smollm-360m", "prefill_32k", []),
    ("xlstm-125m", "decode_32k", ["--multi-pod"]),
])
def test_dryrun_cell_compiles(arch, shape, flags, tmp_path):
    out = tmp_path / "rec.jsonl"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--out", str(out)] + flags,
        env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.loads(out.read_text().splitlines()[0])
    assert rec["flops"] > 0
    assert rec["peak_bytes_per_device"] > 0
    assert rec["bottleneck"] in ("compute", "memory", "collective")
    assert rec["n_chips"] == (512 if "--multi-pod" in flags else 256)


def test_hlo_cost_model_calibration():
    """Scan trip counts, dot flops, ring collective bytes — exact on
    hand-checkable programs."""
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_cost import analyze

    def scanmm(a):
        def body(x, _):
            return x @ x, None
        y, _ = jax.lax.scan(body, a, None, length=12)
        return y

    A = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(scanmm).lower(A).compile()
    r = analyze(c.as_text())
    assert r["flops"] == 12 * 2 * 128 ** 3


def test_mesh_factory_shapes():
    from repro.launch.mesh import make_production_mesh
    # importing must not touch device state; building needs 256 devices
    n = len(__import__("jax").devices())
    if n < 256:
        with pytest.raises(ValueError):
            make_production_mesh()
    else:  # pragma: no cover
        assert make_production_mesh().devices.size == 256
