"""Serving integration: engine cache behavior + RAC-scored KV-block
manager (radix validity, prefix reuse, eviction under pressure)."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import EmbeddingSpace
from repro.models import smoke_variant
from repro.serving import EngineConfig, KVBlockManager, ServingEngine


@pytest.fixture(scope="module")
def engine():
    mcfg = smoke_variant(get_config("paper"))
    ecfg = EngineConfig(cache_capacity=16, max_new_tokens=4, max_batch=4,
                        max_seq=64)
    return ServingEngine(mcfg, ecfg)


def test_repeat_request_hits_and_matches(engine):
    space = EmbeddingSpace(dim=64, seed=11)
    e = space.content_embedding(0, 0).astype(np.float32)
    p = space.paraphrase(e, 0, 0, 1).astype(np.float32)
    prompt = [5, 6, 7]
    done1 = engine.run([(0, e, prompt)])
    assert not done1[0].cached
    out1 = done1[0].out_tokens
    done2 = engine.run([(0, p, prompt)])      # paraphrase of the same query
    assert done2[0].cached
    assert done2[0].out_tokens == out1        # served from cache verbatim
    assert engine.stats["hits"] == 1


def test_engine_batches_multiple_misses(engine):
    space = EmbeddingSpace(dim=64, seed=12)
    reqs = [(100 + i, space.content_embedding(3, 100 + i).astype(np.float32),
             [2, 3, 4]) for i in range(6)]
    done = engine.run(reqs)
    assert len(done) == 6
    assert all(len(r.out_tokens) == 4 for r in done)


def test_engine_async_admit_matches_sync():
    """The acceptance criterion: with async_admit the engine returns
    identical request outputs (tokens, hit flags) to the blocking path,
    while generation slots no longer pay the admit cost inline."""
    from repro.core import SynthConfig, synthetic_trace

    mcfg = smoke_variant(get_config("paper"))
    trace = synthetic_trace(SynthConfig(trace_len=60, n_topics=8, seed=4))
    rng = np.random.default_rng(4)
    reqs = [(r.cid, r.emb, list(rng.integers(2, mcfg.vocab_size, size=3)))
            for r in trace.requests]

    def run(async_admit):
        eng = ServingEngine(mcfg, EngineConfig(
            cache_capacity=16, max_new_tokens=3, max_batch=4, max_seq=64,
            async_admit=async_admit))
        done = eng.run([(c, e, list(t)) for c, e, t in reqs])
        out = [(r.rid, r.cid, r.cached, tuple(r.out_tokens)) for r in done]
        stats = eng.stats
        eng.close()
        return out, stats

    out_sync, s_sync = run(False)
    out_async, s_async = run(True)
    assert out_sync == out_async
    for k in ("hits", "misses", "evictions", "generated_tokens", "batches"):
        assert s_sync[k] == s_async[k], k


# ------------------------------------------------------------ KV blocks
def test_kv_prefix_reuse():
    mgr = KVBlockManager(n_blocks=64, block_tokens=4)
    conv = list(range(20))
    r1 = mgr.on_request(conv)
    assert r1["hit_tokens"] == 0
    assert len(r1["new_blocks"]) == 5
    # same conversation extended: full prefix reuse
    r2 = mgr.on_request(conv + [99, 98, 97, 96])
    assert r2["hit_tokens"] == 20
    assert len(r2["new_blocks"]) == 1


def test_kv_eviction_respects_radix_validity():
    mgr = KVBlockManager(n_blocks=8, block_tokens=4)
    mgr.on_request(list(range(16)))           # 4 blocks, chain
    mgr.on_request(list(range(100, 116)))     # 4 more -> full
    mgr.on_request(list(range(200, 216)))     # needs evictions
    # invariant: no block with live children was evicted
    for bid, b in mgr.blocks.items():
        for ch in b.children:
            assert ch in mgr.blocks
        if b.parent >= 0 and b.parent not in mgr.blocks:
            pytest.fail(f"orphan block {bid}: parent evicted first")


def test_kv_hot_prefix_survives():
    mgr = KVBlockManager(n_blocks=8, block_tokens=4)
    hot = list(range(8))                      # 2 blocks, reused often
    for _ in range(5):
        mgr.on_request(hot)
    root_key = tuple(hot[:4])
    hot_root = mgr.root_index[root_key]
    # flood with one-off conversations to force evictions
    for i in range(10):
        mgr.on_request(list(range(1000 + 16 * i, 1000 + 16 * i + 12)))
    assert mgr.root_index.get(root_key) == hot_root   # anchor retained
    r = mgr.on_request(hot)
    assert r["hit_tokens"] == 8
