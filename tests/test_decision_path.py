"""The fused decision path: decide_batch parity across backends, the
PolicyTable device mirrors (dirty-row sync against the mutation journals,
including a missed-touch detector sweep), victim-value consistency with
the policy's own scoring, and the shard_map fused variant."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.cache import (CacheConfig, KernelBackend, NumpyBackend,
                         SemanticCache)
from repro.core import EmbeddingSpace, SynthConfig, synthetic_trace


def _filled_rac(backend, n=48, capacity=40, dim=64, policy_kwargs=None,
                **bkw):
    space = EmbeddingSpace(dim=dim, seed=5)
    cache = SemanticCache(CacheConfig(capacity=capacity, dim=dim,
                                      backend=backend, policy="RAC",
                                      use_pallas=False,
                                      policy_kwargs=policy_kwargs or {},
                                      backend_kwargs=bkw))
    for i in range(n):
        e = space.content_embedding(i % 6, i).astype(np.float32)
        r = cache.lookup(e, cid=i)
        if not r.hit:
            cache.admit(i, e)
    return cache, space


def _queries(space, dim=64):
    return np.stack(
        [space.paraphrase(space.content_embedding(i % 6, i), i % 6, i, 1)
         .astype(np.float32) for i in range(12)]
        + [space.content_embedding(9, 900 + j).astype(np.float32)
           for j in range(4)])


def _assert_decisions_agree(cn, dn, cb, db, tau_route=0.65):
    np.testing.assert_array_equal(dn.hit_cid, db.hit_cid)
    np.testing.assert_allclose(dn.hit_sim, db.hit_sim, atol=1e-5)
    # routing candidates agree as *decisions* (host masks retired topics to
    # -inf, device zeroes their rep rows — identical once gated)
    gn = np.where(dn.route_sim >= tau_route, dn.route_tid, -1)
    gb = np.where(db.route_sim >= tau_route, db.route_tid, -1)
    np.testing.assert_array_equal(gn, gb)
    live = gn >= 0
    np.testing.assert_allclose(dn.route_sim[live], db.route_sim[live],
                               atol=1e-5)
    # victim values agree per cid (slot layouts differ across stores)
    cids = sorted(cn.store.slot_of)
    assert cids == sorted(cb.store.slot_of)
    vn = np.array([dn.victim_value[cn.store.slot_of[c]] for c in cids])
    vb = np.array([db.victim_value[cb.store.slot_of[c]] for c in cids])
    np.testing.assert_allclose(vn, vb, rtol=1e-5)
    assert np.isinf(dn.victim_value[~cn.store.occ]).all()
    assert np.isinf(db.victim_value[~cb.store.occ]).all()


@pytest.mark.parametrize("backend,bkw", [("kernel", {}),
                                         ("sharded", {"n_shards": 1}),
                                         ("sharded", {"n_shards": 4})])
def test_decide_batch_backend_parity(backend, bkw):
    """Hit, routing, and victim columns of one fused launch agree with the
    numpy host oracle after identical replays."""
    cn, space = _filled_rac("numpy")
    cb, _ = _filled_rac(backend, **bkw)
    qs = _queries(space)
    _assert_decisions_agree(cn, cn.decide_batch(qs), cb,
                            cb.decide_batch(qs))


def test_decide_batch_tableless_policy_degrades():
    """Baseline policies have no PolicyTable: decide_batch still answers
    hit Top-1 (== peek_batch) with sentinel routing/victim columns."""
    space = EmbeddingSpace(dim=32, seed=1)
    for backend in ("numpy", "kernel"):
        cache = SemanticCache(CacheConfig(capacity=8, dim=32, policy="LRU",
                                          backend=backend,
                                          use_pallas=False))
        for i in range(6):
            cache.admit(i, space.content_embedding(0, i).astype(np.float32))
        qs = np.stack([space.content_embedding(0, i).astype(np.float32)
                       for i in range(4)])
        dec = cache.decide_batch(qs)
        pc, ps = cache.peek_batch(qs)
        np.testing.assert_array_equal(dec.hit_cid, pc)
        np.testing.assert_allclose(dec.hit_sim, ps, atol=1e-6)
        assert dec.victim_value is None
        assert (dec.route_tid == -1).all()
        assert np.isneginf(dec.route_sim).all()


def test_decide_victim_matches_value_scores_paper_mode():
    """The fused victim column IS Eq.1-literal TP·TSI: it must equal the
    policy's own value_scores under value_mode="paper"."""
    cache, space = _filled_rac("numpy",
                               policy_kwargs={"value_mode": "paper"})
    t = cache.clock
    dec = cache.decide_batch(_queries(space), t=t)
    cids, vals = cache.policy.value_scores(t)
    slots = [cache.store.slot_of[int(c)] for c in cids]
    np.testing.assert_allclose(dec.victim_value[slots], vals, rtol=1e-5)


def test_kernel_mirrors_stay_fresh_through_replay():
    """Missed-touch detector: replay a mutation-heavy trace through the
    kernel backend and, every few requests, check the device-mirrored
    decision state against the numpy host oracle reading the same
    store/table.  Any RACPolicy mutation that forgets to stamp a journal
    row shows up here as a stale mirror."""
    kb = KernelBackend(use_pallas=False)
    nb = NumpyBackend()
    trace = synthetic_trace(SynthConfig(trace_len=300, seed=4))
    dim = trace.requests[0].emb.shape[0]
    cache = SemanticCache(CacheConfig(capacity=20, dim=dim,
                                      hit_mode="semantic", policy="RAC"),
                          backend=kb)
    probe = np.stack([r.emb for r in trace.requests[:8]])
    alpha = cache.policy.alpha
    for i, req in enumerate(trace.requests):
        r = cache.lookup(req.emb, cid=req.cid, t=req.t, req=req)
        if not r.hit:
            cache.admit(req.cid, req.emb, t=req.t, req=req)
        if i % 23 == 0:
            dk = cache.decide_batch(probe)
            dn = nb.decide_batch(cache.store, cache.policy.table, probe,
                                 alpha=alpha, t_now=cache.clock)
            np.testing.assert_array_equal(dk.hit_cid, dn.hit_cid)
            occ = cache.store.occ
            np.testing.assert_allclose(dk.victim_value[occ],
                                       dn.victim_value[occ], rtol=1e-4)
            assert np.isinf(dk.victim_value[~occ]).all()
            gk = np.where(dk.route_sim >= 0.65, dk.route_tid, -1)
            gn = np.where(dn.route_sim >= 0.65, dn.route_tid, -1)
            np.testing.assert_array_equal(gk, gn)
    stats = kb.sync_stats
    # the whole point of the journals: steady state scatters dirty rows
    # instead of re-uploading the slabs
    assert stats["incremental"] > 0
    assert stats["rows"] > 0


def test_policy_table_journal_semantics():
    """PolicyTable's two journals answer dirty-row queries independently
    and refuse foreign versions, like the store journal they reuse."""
    from repro.core.policy_table import PolicyTable
    tb = PolicyTable(16, 8)
    v_slot, v_topic = tb.slot_version, tb.topic_version
    tb.freq[3] = 1.0
    tb.touch_slot(3)
    tb.set_rep(2, np.ones(8, dtype=np.float32))
    assert tb.dirty_slots_since(v_slot) == {3}
    assert tb.dirty_topics_since(v_topic) == {2}
    assert tb.topic_hwm == 3
    assert tb.dirty_slots_since(tb.slot_version) == set()
    assert tb.dirty_slots_since(v_topic) is None        # foreign lineage
    tb.clear_slot(3)
    assert tb.dirty_slots_since(v_slot) == {3}
    tb.clear_topic(2)
    assert not tb.rep_valid[2] and not tb.rep[2].any()
    # growth keeps hwm and reallocates every topic array together
    tb.grow_topics(600)
    assert (len(tb.tp_last) == len(tb.t_last) == len(tb.rep)
            == len(tb.rep_valid) >= 601)


@pytest.mark.slow_mesh
def test_sharded_fused_decide_shard_map_in_subprocess():
    """With enough devices the fused decision pass runs under shard_map
    (per-shard sim_top1 + victim slices, all_gather argmax merge) and
    agrees with the numpy oracle."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = """
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=4").strip()
import numpy as np
from repro.cache import NumpyBackend, ShardedKernelBackend, ShardedStore
from repro.core.policy_table import PolicyTable
rng = np.random.default_rng(1)
store = ShardedStore(300, 64, n_shards=4)
table = PolicyTable(store.emb.shape[0], 64)
embs = rng.standard_normal((200, 64)).astype(np.float32)
embs /= np.linalg.norm(embs, axis=1, keepdims=True)
for i in range(200):
    s = store.insert(i, embs[i])
    table.tsi[s] = rng.random() * 10
    table.topic_of[s] = int(rng.integers(0, 12))
    table.touch_slot(s)
for tid in range(12):
    table.tp_last[tid] = rng.random() * 5
    table.t_last[tid] = int(rng.integers(0, 400))
    table.set_rep(tid, embs[tid])
store.remove(7); store.remove(90)
table.clear_slot(store.emb.shape[0] - 1)   # arbitrary stamped row
q = rng.standard_normal((32, 64)).astype(np.float32)
q /= np.linalg.norm(q, axis=1, keepdims=True)
sb = ShardedKernelBackend(n_shards=4, use_pallas=False)
assert sb.mesh() is not None, "mesh must be active with 4 devices"
dn = NumpyBackend().decide_batch(store, table, q, alpha=0.001, t_now=500)
ds = sb.decide_batch(store, table, q, alpha=0.001, t_now=500)
np.testing.assert_array_equal(dn.hit_cid, ds.hit_cid)
np.testing.assert_allclose(dn.hit_sim, ds.hit_sim, atol=1e-5)
occ = store.occ
np.testing.assert_allclose(dn.victim_value[occ], ds.victim_value[occ],
                           rtol=1e-4)
assert np.isinf(ds.victim_value[~occ]).all()
gn = np.where(dn.route_sim >= 0.65, dn.route_tid, -1)
gs = np.where(ds.route_sim >= 0.65, ds.route_tid, -1)
np.testing.assert_array_equal(gn, gs)
print("OK")
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
