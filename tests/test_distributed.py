"""Distribution substrate: checkpoint round-trip + elastic restore,
fault-tolerance primitives, gradient compression, sharding rule engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.checkpoint import (latest_step, restore_checkpoint,
                                          save_checkpoint)
from repro.distributed.compression import (compress_grads, decompress_grads,
                                           init_residuals)
from repro.distributed.fault_tolerance import (HeartbeatMonitor,
                                               StragglerDetector,
                                               plan_elastic_mesh)
from repro.distributed.sharding import ShardingPlan, param_spec


def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"w": np.arange(12.0).reshape(3, 4),
                        "b": np.zeros(4)},
             "step": np.int32(7)}
    save_checkpoint(str(tmp_path), 7, state, extra={"cursor": 7})
    save_checkpoint(str(tmp_path), 9, state, extra={"cursor": 9})
    assert latest_step(str(tmp_path)) == 9
    restored, extra = restore_checkpoint(str(tmp_path), state)
    assert extra["cursor"] == 9
    np.testing.assert_array_equal(restored["params"]["w"],
                                  state["params"]["w"])


def test_checkpoint_uncommitted_ignored(tmp_path):
    state = {"x": np.ones(3)}
    d = save_checkpoint(str(tmp_path), 5, state, extra={})
    os.remove(d + ".COMMIT")                   # simulate crash pre-commit
    assert latest_step(str(tmp_path)) is None
    r, _ = restore_checkpoint(str(tmp_path), state)
    assert r is None


def test_train_restart_is_bit_deterministic(tmp_path):
    """Full restart determinism: train 6 steps; vs train 3 + restore + 3."""
    from repro.launch.train import main as train_main
    base = ["--arch", "smollm-360m", "--smoke", "--batch", "2",
            "--seq", "32", "--log-every", "100"]
    l_full = train_main(base + ["--steps", "6"])
    ck = str(tmp_path / "ck")
    train_main(base + ["--steps", "6", "--stop-at", "3", "--ckpt-dir", ck,
                       "--ckpt-every", "3"])
    l_resumed = train_main(base + ["--steps", "6", "--ckpt-dir", ck,
                                   "--ckpt-every", "100"])
    np.testing.assert_allclose(l_full[3:], l_resumed, rtol=1e-6)


def test_heartbeat_detects_dead_host():
    clock = [0.0]
    hb = HeartbeatMonitor(n_hosts=3, timeout_s=10, clock=lambda: clock[0])
    for h in range(3):
        hb.beat(h, 1)
    clock[0] = 5.0
    hb.beat(0, 2)
    hb.beat(1, 2)
    clock[0] = 12.0
    assert hb.dead_hosts() == [2]


def test_straggler_detector_flags_persistent_outlier():
    det = StragglerDetector(n_hosts=4, k=3.0, patience=2)
    times = [1.0, 1.01, 0.99, 1.0]
    assert det.observe(times) == []
    slow = [1.0, 1.02, 0.98, 3.0]
    assert det.observe(slow) == []
    assert det.observe(slow) == [3]


def test_elastic_mesh_preserves_tp():
    plan = plan_elastic_mesh(n_hosts_alive=120, chips_per_host=4,
                             model_parallel=16)
    assert plan["model"] == 16
    assert plan["pod"] * plan["data"] * plan["model"] == plan["chips_used"]
    assert plan["chips_used"] <= 480


def test_gradient_compression_error_feedback():
    """int8 EF compression: accumulated updates converge to the true sum."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32) * 0.01
    params = {"w": g_true}
    res = init_residuals(params)
    acc = jnp.zeros_like(g_true)
    for _ in range(50):
        q, s, res = compress_grads({"w": g_true}, res)
        deq = decompress_grads(q, s)
        acc = acc + deq["w"]
    np.testing.assert_allclose(np.asarray(acc) / 50, np.asarray(g_true),
                               atol=2e-4)


def test_param_spec_divisibility():
    """Every spec must evenly divide its dims (else replicate)."""
    from repro.launch.mesh import abstract_mesh
    mesh = abstract_mesh((2, 2), ("data", "model"))
    from repro.configs import get_config
    cfg = get_config("smollm-360m")
    plan = ShardingPlan(dp=("data",), fsdp=True)
    cases = [
        ("blocks/attn/wq", (32, 960, 15, 64)),
        ("blocks/mlp/wi", (32, 960, 2560)),
        ("emb/tok", (49152, 960)),
        ("blocks/moe/wi", (27, 64, 2048, 1408)),
    ]
    for path, shape in cases:
        spec = param_spec(path, shape, cfg, plan, mesh)
        for dim, part in zip(shape, tuple(spec) + (None,) * 8):
            if part is None:
                continue
            n = 1
            for ax in (part if isinstance(part, tuple) else (part,)):
                n *= mesh.shape[ax]
            assert dim % n == 0, (path, shape, spec)
