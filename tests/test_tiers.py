"""Tiered cache hierarchy (device -> host DRAM -> ghost): unit behavior
of the tiers, the demote/promote/revive flows through the facade, the
single-tier bit-exactness guarantee across backends and hit modes, and
checkpoint/restore round-trips that include tier state."""
import numpy as np
import pytest

from repro.cache import (CacheConfig, GhostTier, HostTier, SemanticCache,
                         TierConfig)
from repro.core import EmbeddingSpace


# --------------------------------------------------------- GhostTier unit
def test_ghost_tier_fifo_bound_and_drop_report():
    g = GhostTier(3)
    assert g.put("a", 1) == [] and g.put("b", 2) == [] and g.put("c", 3) == []
    assert g.put("d", 4) == ["a"]            # oldest out, reported
    assert len(g) == 3 and "a" not in g and g["d"] == 4
    assert list(g.keys()) == ["b", "c", "d"]


def test_ghost_tier_update_keeps_insertion_position():
    g = GhostTier(2)
    g.put("a", 1)
    g.put("b", 2)
    assert g.put("a", 9) == []               # update in place, no drop
    assert g["a"] == 9
    assert g.put("c", 3) == ["a"]            # "a" kept its (oldest) slot


def test_ghost_tier_batched_drop_amortizes():
    g = GhostTier(16, batch_div=4)
    dropped = []
    for i in range(17):
        dropped += g.put(i, i)
    assert dropped == [0, 1, 2, 3]           # one batch of capacity//4
    assert len(g) == 13
    assert min(g.keys()) == 4


def test_ghost_tier_tiny_capacities_stay_bounded():
    for cap in (0, 1, 2):
        g = GhostTier(cap, batch_div=16)     # batch = 0 -> still drops >= 1
        for i in range(10):
            g.put(i, i)
            assert len(g) <= cap


# ---------------------------------------------------------- HostTier unit
def test_host_tier_put_take_roundtrip_is_journaled():
    ht = HostTier(capacity=4, dim=8)
    v0 = ht.store.version
    e = np.arange(8, dtype=np.float32)
    assert ht.put(3, e, ["payload"], t=1, meta={"freq": 2.0}) == []
    assert ht.store.version > v0             # demote = journal entry
    assert 3 in ht and len(ht) == 1
    v1 = ht.store.version
    emb, payload, meta = ht.take(3, t=2)
    assert ht.store.version > v1             # promote = journal entry
    np.testing.assert_array_equal(emb, e)
    assert payload == ["payload"] and meta == {"freq": 2.0}
    assert 3 not in ht and len(ht) == 0      # remove-at-serve


def test_host_tier_lru_eviction_by_demote_time():
    ht = HostTier(capacity=2, dim=4)
    e = np.ones(4, np.float32)
    ht.put(10, e, "a", t=5, meta={"tid": 1})
    ht.put(11, e, "b", t=9, meta=None)
    dropped = ht.put(12, e, "c", t=7, meta=None)
    assert dropped == [(10, {"tid": 1})]     # smallest last_t out first
    assert 10 not in ht and 11 in ht and 12 in ht
    # insert-then-evict: the re-put itself pushes out the now-coldest 12,
    # and a fresh timestamp protects 10 on the next demotion
    assert [c for c, _ in ht.put(10, e, "a", t=20, meta=None)] == [12]
    assert [c for c, _ in ht.put(13, e, "d", t=21, meta=None)] == [11]
    assert 10 in ht and 13 in ht


def test_host_tier_topk_scores_occupied_rows_only():
    rng = np.random.default_rng(0)
    ht = HostTier(capacity=8, dim=16)
    embs = rng.standard_normal((5, 16)).astype(np.float32)
    embs /= np.linalg.norm(embs, axis=1, keepdims=True)
    for i in range(5):
        ht.put(i, embs[i], None, t=i, meta=None)
    cids, sims = ht.topk(embs[2], k=3)
    assert cids[0, 0] == 2 and sims[0, 0] == pytest.approx(1.0, abs=1e-5)
    assert set(cids[0].tolist()) <= set(range(5))


# ------------------------------------------------- facade flow: demote/promote
def _space_embs(dim=32, n=24, seed=7):
    space = EmbeddingSpace(dim=dim, seed=seed)
    return space, [space.content_embedding(i % 6, i).astype(np.float32)
                   for i in range(n)]


def _tiered(capacity=4, host=16, ghost=64, **kw):
    return SemanticCache(CacheConfig(
        capacity=capacity, dim=32, tau_hit=0.85, policy="RAC",
        tiers=TierConfig(host_capacity=host, ghost_capacity=ghost), **kw))


def test_demotion_preserves_payload_and_host_hit_promotes():
    cache = _tiered()
    events = []
    for kind in ("hit", "evict"):
        cache.subscribe(kind,
                        lambda ev, k=kind: events.append((k, ev.cid, ev.tier)))
    _, embs = _space_embs()
    for i in range(12):
        assert not cache.lookup(embs[i], cid=i).hit
        cache.admit(i, embs[i], payload=[f"p{i}"])
    demoted = [c for c in range(12) if cache.in_host(c)]
    assert len(demoted) == 8                 # 12 admitted - 4 device-resident
    assert all(("evict", c, "host") in events for c in demoted)
    target = demoted[0]
    r = cache.lookup(embs[target], cid=target)
    assert r.hit and r.cid == target and r.payload == [f"p{target}"]
    assert events[-1] == ("hit", target, "host")
    assert target in cache                   # promoted to the device tier
    assert not cache.in_host(target)         # remove-at-serve: single copy
    st = cache.tier_stats
    assert st["demotions"] >= 12 - 4 and st["host_hits"] == 1
    assert st["promotions"] == 1
    assert cache.metrics.hits == 1           # host hits are hits


def test_content_mode_host_hit_serves_exact_cid():
    cache = _tiered(hit_mode="content")
    _, embs = _space_embs()
    for i in range(12):
        cache.admit(i, embs[i], payload=[i])
    target = next(c for c in range(12) if cache.in_host(c))
    r = cache.lookup(embs[target], cid=target)
    assert r.hit and r.cid == target and r.payload == [target]
    assert target in cache and not cache.in_host(target)


def test_promote_k_co_promotes_near_duplicates():
    space = EmbeddingSpace(dim=32, seed=9)
    cache = SemanticCache(CacheConfig(
        capacity=2, dim=32, tau_hit=0.85, policy="LRU",
        tiers=TierConfig(host_capacity=16, ghost_capacity=0, promote_k=4)))
    base = space.content_embedding(0, 0).astype(np.float32)
    close = [space.paraphrase(base, 0, 0, j).astype(np.float32)
             for j in (1, 2)]
    far = [space.content_embedding(3 + j, 100 + j).astype(np.float32)
           for j in range(4)]
    for cid, e in enumerate([base] + close + far):
        cache.admit(cid, e, payload=[cid])
    in_host = [c for c in range(3) if cache.in_host(c)]
    assert len(in_host) >= 2                 # the near-duplicates demoted
    r = cache.lookup(base, cid=99)
    assert r.hit and r.payload == [in_host[0]]   # best host rank served
    promoted = cache.tier_stats["promotions"]
    assert promoted >= 2                     # served rank + co-promotions
    # every promoted entry stays owned somewhere: on device, or demoted
    # right back when the co-promotions themselves overflow capacity 2
    for c in in_host:
        assert c in cache or cache.in_host(c)
        assert cache.payloads.get(c) == [c] or \
            cache.tiers.host.payloads.get(c) == [c]


def test_async_promotion_rides_the_admit_queue():
    """The request path never blocks on promotion: a host hit returns the
    payload immediately and the re-admission is queued, applied at the
    next flush exactly like any other async admission."""
    cache = _tiered(async_admit="sync")
    _, embs = _space_embs()
    for i in range(12):
        cache.admit(i, embs[i], payload=[i])
    cache.flush()
    target = next(c for c in range(12) if cache.in_host(c))
    r = cache.lookup(embs[target], cid=target)
    assert r.hit and r.payload == [target]   # served before any admission
    assert cache.pending_admits >= 1         # promotion queued, not applied
    assert target not in cache               # ...so not on device yet
    assert not cache.in_host(target)         # but already owned by the queue
    cache.flush()
    assert target in cache                   # settled at the batch boundary
    assert cache.tier_stats["promotions"] == 1


# ----------------------------------------------------------- ghost revival
def test_ghost_tier_readmits_demoted_topic_hot():
    """The acceptance flow: an entry (and its topic) demoted all the way
    out re-enters *hot* — the tier's ghost metadata outlives the policy's
    own bounded ghosts, restoring the lifetime freq counter AND the dead
    topic's TP state (no new topic is minted on re-admission)."""
    cache = SemanticCache(CacheConfig(
        capacity=2, dim=32, tau_hit=0.85, policy="RAC",
        policy_kwargs=dict(ghost_limit=1, ghost_topic_limit=1,
                           tau_route=0.3),
        tiers=TierConfig(host_capacity=0, ghost_capacity=64)))
    space = EmbeddingSpace(dim=32, seed=4)
    e0 = space.content_embedding(0, 0).astype(np.float32)
    cache.admit(0, e0, payload=["r0"])
    for _ in range(3):
        assert cache.lookup(e0, cid=0).hit   # freq(0) grows to 4
    pol = cache.policy
    tid0 = int(pol.topic_of[cache.store.slot_of[0]])
    # flood with distinct topics at a much later time (topic 0's TP has
    # decayed to ~0, so Eq.1 evicts cid 0) — ages it out of the policy's
    # own 1-entry ghost list and 1-entry topic memory
    for j in range(1, 9):
        ej = space.content_embedding(j, j).astype(np.float32)
        cache.admit(j, ej, t=5000 + j)
    assert 0 not in cache and 0 not in pol.g_freq       # policy forgot
    assert tid0 not in pol.topics and tid0 not in pol.ghost_topics
    g = cache.tiers.ghost_get(0)
    assert g is not None and g["freq"] == 4.0           # the tier did not
    ntid = pol._next_tid
    cache.admit(0, e0, payload=["r0-again"])            # re-admission
    st = cache.tier_stats
    assert st["ghost_revivals"] == 1
    s0 = cache.store.slot_of[0]
    assert pol.freq[s0] == 5.0               # lifetime counter restored (+1)
    assert pol._next_tid == ntid             # topic revived, not re-created
    assert int(pol.topic_of[s0]) == tid0


def test_ghost_lists_split_arc_style():
    """B1 holds demoted-never-promoted metadata; a promoted entry that
    falls all the way out again lands in B2."""
    cache = _tiered(capacity=2, host=2, ghost=8)
    _, embs = _space_embs()
    for i in range(6):
        cache.admit(i, embs[i], payload=[i])
    tm = cache.tiers
    assert len(tm.ghost_b1) > 0 and len(tm.ghost_b2) == 0
    target = next(c for c in range(6) if cache.in_host(c))
    assert cache.lookup(embs[target], cid=target).hit   # promote it
    for i in range(6, 12):                   # flood it out again (late t:
        cache.admit(i, embs[i], payload=[i], t=5000 + i)   # TP decayed)
    assert target in tm.ghost_b2             # promoted-then-lost
    assert cache.tier_stats["ghost_drops"] + len(tm.ghost_b1) \
        + len(tm.ghost_b2) == cache.tier_stats["ghost_inserts"]


# --------------------------------------------------- single-tier bit-exactness
def _replay(backend, hit_mode, tiers, *, capacity=8, n=80):
    space = EmbeddingSpace(dim=32, seed=21)
    bkw = {"n_shards": 2} if backend == "sharded" else {}
    cache = SemanticCache(CacheConfig(
        capacity=capacity, dim=32, tau_hit=0.85, hit_mode=hit_mode,
        backend=backend, use_pallas=False, backend_kwargs=bkw,
        policy="RAC", tiers=tiers))
    events = []
    for kind in ("hit", "miss", "admit", "evict"):
        cache.subscribe(
            kind, lambda ev, k=kind: events.append((k, ev.cid, ev.tier)))
    log = []
    for i in range(n):
        cid = i % 24
        emb = space.content_embedding(cid % 6, cid).astype(np.float32)
        r = cache.lookup(emb, cid=cid)
        log.append((cid, r.hit, r.cid if r.hit else -1))
        if not r.hit:
            cache.admit(cid, emb, payload=[cid])
    counters = {k: v for k, v in cache.metrics.snapshot().items()
                if not k.endswith("_s")}
    return cache, log, counters, events


@pytest.mark.parametrize("backend", ["numpy", "kernel", "sharded"])
@pytest.mark.parametrize("hit_mode", ["content", "semantic"])
def test_disabled_tiers_bit_identical_to_single_tier(backend, hit_mode):
    """The guarantee the whole PR hangs on: host tier sized 0 and ghosts
    disabled means the facade never constructs a TierManager and every
    decision — hit/miss sequence, victims, event stream, counters — is
    identical to the single-tier path, on every backend and hit mode."""
    c0, l0, m0, e0 = _replay(backend, hit_mode, None)
    c1, l1, m1, e1 = _replay(
        backend, hit_mode, TierConfig(host_capacity=0, ghost_capacity=0))
    assert c1.tiers is None and c1.tier_stats == {}
    assert l0 == l1
    assert m0 == m1
    assert e0 == e1
    assert sorted(c0.store.keys()) == sorted(c1.store.keys())


def test_tiered_decisions_identical_across_backends():
    """Tiering must not break backend equivalence: the same tiered replay
    produces the same decision/event stream under numpy, kernel, and
    sharded scoring."""
    tiers = TierConfig(host_capacity=16, ghost_capacity=32)
    ref = _replay("numpy", "semantic", tiers)
    for backend in ("kernel", "sharded"):
        got = _replay(backend, "semantic", tiers)
        assert got[1] == ref[1]
        assert got[2] == ref[2]
        assert got[3] == ref[3]
        assert got[0].tier_stats == ref[0].tier_stats


# ------------------------------------------------------ checkpoint/restore
@pytest.mark.parametrize("backend", ["numpy", "kernel", "sharded"])
def test_checkpoint_restore_roundtrip_includes_tiers(backend):
    """A restored snapshot carries the whole hierarchy: the same request
    tail replays bit-identically (decisions, events, tier stats, host
    membership) on every backend."""
    space = EmbeddingSpace(dim=32, seed=31)
    bkw = {"n_shards": 2} if backend == "sharded" else {}

    def mk():
        return SemanticCache(CacheConfig(
            capacity=4, dim=32, tau_hit=0.85, backend=backend,
            use_pallas=False, backend_kwargs=bkw, policy="RAC",
            tiers=TierConfig(host_capacity=12, ghost_capacity=32)))

    reqs = [(i % 20, space.content_embedding(i % 5, i % 20)
             .astype(np.float32)) for i in range(70)]

    def drive(cache, chunk):
        out = []
        for cid, emb in chunk:
            r = cache.lookup(emb, cid=cid)
            out.append((cid, r.hit, r.cid if r.hit else -1))
            if not r.hit:
                cache.admit(cid, emb, payload=[cid])
        return out

    cache = mk()
    drive(cache, reqs[:40])
    snap = cache.checkpoint()
    host_at_snap = sorted(c for c in range(20) if cache.in_host(c))
    stats_at_snap = cache.tier_stats
    tail_a = drive(cache, reqs[40:])
    stats_a, store_a = cache.tier_stats, sorted(cache.store.keys())

    cache.restore(snap)
    assert sorted(c for c in range(20) if cache.in_host(c)) == host_at_snap
    assert cache.tier_stats == stats_at_snap
    tail_b = drive(cache, reqs[40:])
    assert tail_b == tail_a                  # bit-identical continuation
    assert cache.tier_stats == stats_a
    assert sorted(cache.store.keys()) == store_a


def test_restore_accepts_pre_tiering_snapshots():
    """Snapshots written before the tiers field existed must restore."""
    cache = SemanticCache(CacheConfig(capacity=4, dim=8, policy="LRU"))
    cache.admit(1, np.ones(8, np.float32), payload=["x"])
    snap = cache.checkpoint()
    del snap["tiers"]                        # simulate an old snapshot
    cache.admit(2, np.full(8, 2, np.float32))
    cache.restore(snap)
    assert 1 in cache and 2 not in cache and cache.payloads == {1: ["x"]}


# ---------------------------------------------------- decide_batch columns
def test_decide_batch_reports_host_fallthrough_columns():
    cache = _tiered()
    _, embs = _space_embs()
    for i in range(12):
        cache.admit(i, embs[i], payload=[i])
    demoted = [c for c in range(12) if cache.in_host(c)]
    dec = cache.decide_batch(np.stack([embs[c] for c in demoted]))
    assert dec.host_cid is not None and dec.host_sim is not None
    np.testing.assert_array_equal(dec.host_cid, np.asarray(demoted))
    assert (dec.host_sim > 0.99).all()       # exact embeddings
    # the device columns still miss (those entries are not resident)
    assert all(int(c) not in demoted for c in dec.hit_cid)
    # untiered caches keep the legacy shape
    plain = SemanticCache(CacheConfig(capacity=4, dim=32, policy="RAC"))
    plain.admit(0, embs[0])
    dec = plain.decide_batch(embs[0][None, :])
    assert dec.host_cid is None and dec.host_sim is None
