"""The unified SemanticCache facade: protocol parity with the historical
simulator loop, numpy-vs-kernel backend equivalence, payload/eviction
hooks, checkpoint/restore, and the no-inline-cache-logic guarantee for the
serving engine."""
import numpy as np
import pytest

from repro.cache import (CacheConfig, CacheHit, CacheMiss, KernelBackend,
                         NumpyBackend, SemanticCache, get_backend)
from repro.core import (EmbeddingSpace, SynthConfig, default_factories,
                        run_policy, synthetic_trace)
from repro.core.store import ResidentStore


# ------------------------------------------------------------------ parity
def _seed_loop(trace, capacity, factory, hit_mode="content", tau_hit=0.85):
    """The pre-facade simulator protocol, verbatim — the parity oracle."""
    dim = trace.requests[0].emb.shape[0]
    store = ResidentStore(capacity, dim)
    policy = factory(capacity, store)
    hits = misses = evictions = 0
    for req in trace.requests:
        if hit_mode == "content":
            hit_cid = req.cid if req.cid in store else -1
        else:
            cid, sim = store.nearest(req.emb)
            hit_cid = cid if sim >= tau_hit else -1
        if hit_cid >= 0:
            hits += 1
            policy.on_hit(hit_cid, req, req.t)
        else:
            misses += 1
            if capacity <= 0:
                continue
            if hit_mode == "content" or req.cid not in store:
                store.insert(req.cid, req.emb)
                policy.on_admit(req.cid, req, req.t)
                while len(store) > capacity:
                    v = policy.victim(req.t)
                    store.remove(v)
                    evictions += 1
    return hits, misses, evictions


@pytest.fixture(scope="module")
def trace_10k():
    return synthetic_trace(SynthConfig(trace_len=10_000, seed=0)).with_next_use()


@pytest.mark.parametrize("name", ["RAC", "LRU", "S3-FIFO", "Belady"])
def test_run_policy_reproduces_seed_counts_content(trace_10k, name):
    facs = default_factories(include_belady=True)
    cap = int(0.1 * trace_10k.meta["unique"])
    ref = _seed_loop(trace_10k, cap, facs[name], hit_mode="content")
    s = run_policy(trace_10k, cap, facs[name], hit_mode="content", name=name)
    assert (s.hits, s.misses, s.evictions) == ref


@pytest.mark.parametrize("name", ["RAC", "LRU"])
def test_run_policy_reproduces_seed_counts_semantic(trace_10k, name):
    facs = default_factories(include_belady=True)
    cap = int(0.1 * trace_10k.meta["unique"])
    ref = _seed_loop(trace_10k, cap, facs[name], hit_mode="semantic")
    s = run_policy(trace_10k, cap, facs[name], hit_mode="semantic",
                   name=name)
    assert (s.hits, s.misses, s.evictions) == ref


# ------------------------------------------------------- backend equivalence
def _filled_cache(backend, n=40, capacity=50, dim=64, policy="LRU"):
    space = EmbeddingSpace(dim=dim, seed=5)
    cache = SemanticCache(CacheConfig(capacity=capacity, dim=dim,
                                      backend=backend, policy=policy))
    embs = [space.content_embedding(i % 8, i).astype(np.float32)
            for i in range(n)]
    for i, e in enumerate(embs):
        cache.admit(i, e, payload=[i])
    return cache, space, embs


def test_lookup_batch_kernel_matches_numpy():
    cn, space, embs = _filled_cache("numpy")
    ck, _, _ = _filled_cache("kernel")
    queries = np.stack(
        [space.paraphrase(embs[i], i % 8, i, 1).astype(np.float32)
         for i in range(len(embs))]
        + [space.content_embedding(9, 1000 + j).astype(np.float32)
           for j in range(8)])
    n_cids, n_sims = cn.peek_batch(queries)
    k_cids, k_sims = ck.peek_batch(queries)
    np.testing.assert_array_equal(n_cids, k_cids)
    np.testing.assert_allclose(n_sims, k_sims, atol=1e-5)
    rn = cn.lookup_batch(queries, cids=list(range(len(queries))))
    rk = ck.lookup_batch(queries, cids=list(range(len(queries))))
    assert [r.hit for r in rn] == [r.hit for r in rk]
    assert [r.cid if r.hit else -1 for r in rn] == \
           [r.cid if r.hit else -1 for r in rk]
    assert sum(r.hit for r in rn) == len(embs)      # paraphrases all hit
    assert cn.metrics.hits == ck.metrics.hits


def test_lookup_batch_matches_sequential_lookups():
    cn, space, embs = _filled_cache("numpy")
    cs, _, _ = _filled_cache("numpy")
    queries = np.stack(
        [space.paraphrase(embs[i], i % 8, i, 1).astype(np.float32)
         for i in range(10)])
    batched = cn.lookup_batch(queries)
    single = [cs.lookup(q) for q in queries]
    for b, s in zip(batched, single):
        assert b.hit == s.hit and b.cid == s.cid
        np.testing.assert_allclose(b.sim, s.sim, atol=1e-6)


def test_kernel_rac_value_matches_numpy():
    rng = np.random.default_rng(0)
    nb, kb = NumpyBackend(), KernelBackend()
    tsi = rng.random(100)
    tids = rng.integers(0, 16, 100)
    tp_last = rng.random(16) * 5
    t_last = rng.integers(0, 500, 16)
    a = nb.rac_value(tsi, tids, tp_last, t_last, 0.001, 700)
    b = kb.rac_value(tsi, tids, tp_last, t_last, 0.001, 700)
    np.testing.assert_allclose(a, b, rtol=1e-5)


def test_get_backend_rejects_unknown():
    with pytest.raises(ValueError):
        get_backend("cuda")


# ------------------------------------------------------ facade semantics
def test_lookup_never_admits_and_admit_evicts():
    space = EmbeddingSpace(dim=32, seed=1)
    cache = SemanticCache(CacheConfig(capacity=2, dim=32, policy="FIFO"))
    e = [space.content_embedding(0, i).astype(np.float32) for i in range(3)]
    assert isinstance(cache.lookup(e[0], cid=0), CacheMiss)
    assert len(cache) == 0                      # miss did not admit
    cache.admit(0, e[0], payload="r0")
    cache.admit(1, e[1], payload="r1")
    evicted = cache.admit(2, e[2], payload="r2")
    assert evicted == [0] and len(cache) == 2   # FIFO over capacity 2
    assert 0 not in cache.payloads              # payload died with entry
    r = cache.lookup(e[1], cid=1)
    assert isinstance(r, CacheHit) and r.payload == "r1"


def test_event_hooks_fire():
    space = EmbeddingSpace(dim=32, seed=2)
    cache = SemanticCache(CacheConfig(capacity=1, dim=32, policy="LRU"))
    seen = []
    for kind in ("hit", "miss", "admit", "evict"):
        cache.subscribe(kind, lambda ev, k=kind: seen.append((k, ev.cid)))
    e0 = space.content_embedding(0, 0).astype(np.float32)
    e1 = space.content_embedding(1, 1).astype(np.float32)
    cache.lookup(e0, cid=0)                     # miss
    cache.admit(0, e0, payload="x")             # admit
    cache.lookup(e0, cid=0)                     # hit
    cache.admit(1, e1)                          # admit + evict 0
    kinds = [k for k, _ in seen]
    assert kinds == ["miss", "admit", "hit", "admit", "evict"]
    assert seen[-1] == ("evict", 0)
    m = cache.metrics
    assert (m.hits, m.misses, m.admissions, m.evictions) == (1, 1, 2, 1)


def test_checkpoint_restore_roundtrip():
    cache, space, embs = _filled_cache("numpy", n=30, capacity=32)
    cache.lookup(embs[3], cid=3)
    snap = cache.checkpoint()
    before = (cache.metrics.hits, cache.metrics.evictions, len(cache.store),
              sorted(cache.store.keys()), dict(cache.payloads))
    for j in range(50):                          # churn everything
        cache.admit(2000 + j,
                    space.content_embedding(11, 2000 + j).astype(np.float32))
    assert sorted(cache.store.keys()) != before[3]
    cache.restore(snap)
    after = (cache.metrics.hits, cache.metrics.evictions, len(cache.store),
             sorted(cache.store.keys()), dict(cache.payloads))
    assert after == before
    # restored cache still behaves: resident entry hits again
    assert cache.lookup(embs[3], cid=3).hit


def test_content_mode_lookup_batch():
    cache = SemanticCache(CacheConfig(capacity=8, dim=16, policy="LRU",
                                      hit_mode="content"))
    rng = np.random.default_rng(0)
    embs = rng.standard_normal((4, 16)).astype(np.float32)
    cache.admit_batch([0, 1], embs[:2])
    rs = cache.lookup_batch(embs, cids=[0, 1, 2, 3])
    assert [r.hit for r in rs] == [True, True, False, False]


# ----------------------------------------------------------- engine facade
def test_engine_has_no_inline_cache_logic():
    """The acceptance criterion: ServingEngine owns no cache protocol —
    lookup/admit/evict live behind SemanticCache only."""
    import inspect

    from repro.serving.engine import ServingEngine
    assert not hasattr(ServingEngine, "_lookup")
    assert not hasattr(ServingEngine, "_admit")
    src = inspect.getsource(ServingEngine)
    assert "ResidentStore(" not in src and "RACPolicy(" not in src
    # batched hot path: the whole queue is scored in one facade call
    assert "peek_batch" in src
