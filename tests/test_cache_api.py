"""The unified SemanticCache facade: protocol parity with the historical
simulator loop, numpy-vs-kernel backend equivalence, sharded-vs-numpy
decision parity across shard counts, payload/eviction hooks,
checkpoint/restore, and the no-inline-cache-logic guarantee for the
serving engine."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.cache import (CacheConfig, CacheHit, CacheMiss, KernelBackend,
                         NumpyBackend, SemanticCache, ShardedKernelBackend,
                         ShardedStore, get_backend)
from repro.core import (EmbeddingSpace, SynthConfig, default_factories,
                        run_policy, synthetic_trace)
from repro.core.store import ResidentStore


# ------------------------------------------------------------------ parity
def _seed_loop(trace, capacity, factory, hit_mode="content", tau_hit=0.85):
    """The pre-facade simulator protocol, verbatim — the parity oracle."""
    dim = trace.requests[0].emb.shape[0]
    store = ResidentStore(capacity, dim)
    policy = factory(capacity, store)
    hits = misses = evictions = 0
    for req in trace.requests:
        if hit_mode == "content":
            hit_cid = req.cid if req.cid in store else -1
        else:
            cid, sim = store.nearest(req.emb)
            hit_cid = cid if sim >= tau_hit else -1
        if hit_cid >= 0:
            hits += 1
            policy.on_hit(hit_cid, req, req.t)
        else:
            misses += 1
            if capacity <= 0:
                continue
            if hit_mode == "content" or req.cid not in store:
                store.insert(req.cid, req.emb)
                policy.on_admit(req.cid, req, req.t)
                while len(store) > capacity:
                    v = policy.victim(req.t)
                    store.remove(v)
                    evictions += 1
    return hits, misses, evictions


@pytest.fixture(scope="module")
def trace_10k():
    return synthetic_trace(SynthConfig(trace_len=10_000, seed=0)).with_next_use()


@pytest.mark.parametrize("name", ["RAC", "LRU", "S3-FIFO", "Belady"])
def test_run_policy_reproduces_seed_counts_content(trace_10k, name):
    facs = default_factories(include_belady=True)
    cap = int(0.1 * trace_10k.meta["unique"])
    ref = _seed_loop(trace_10k, cap, facs[name], hit_mode="content")
    s = run_policy(trace_10k, cap, facs[name], hit_mode="content", name=name)
    assert (s.hits, s.misses, s.evictions) == ref


@pytest.mark.parametrize("name", ["RAC", "LRU"])
def test_run_policy_reproduces_seed_counts_semantic(trace_10k, name):
    facs = default_factories(include_belady=True)
    cap = int(0.1 * trace_10k.meta["unique"])
    ref = _seed_loop(trace_10k, cap, facs[name], hit_mode="semantic")
    s = run_policy(trace_10k, cap, facs[name], hit_mode="semantic",
                   name=name)
    assert (s.hits, s.misses, s.evictions) == ref


# ------------------------------------------------------- backend equivalence
def _filled_cache(backend, n=40, capacity=50, dim=64, policy="LRU"):
    space = EmbeddingSpace(dim=dim, seed=5)
    cache = SemanticCache(CacheConfig(capacity=capacity, dim=dim,
                                      backend=backend, policy=policy))
    embs = [space.content_embedding(i % 8, i).astype(np.float32)
            for i in range(n)]
    for i, e in enumerate(embs):
        cache.admit(i, e, payload=[i])
    return cache, space, embs


def test_lookup_batch_kernel_matches_numpy():
    cn, space, embs = _filled_cache("numpy")
    ck, _, _ = _filled_cache("kernel")
    queries = np.stack(
        [space.paraphrase(embs[i], i % 8, i, 1).astype(np.float32)
         for i in range(len(embs))]
        + [space.content_embedding(9, 1000 + j).astype(np.float32)
           for j in range(8)])
    n_cids, n_sims = cn.peek_batch(queries)
    k_cids, k_sims = ck.peek_batch(queries)
    np.testing.assert_array_equal(n_cids, k_cids)
    np.testing.assert_allclose(n_sims, k_sims, atol=1e-5)
    rn = cn.lookup_batch(queries, cids=list(range(len(queries))))
    rk = ck.lookup_batch(queries, cids=list(range(len(queries))))
    assert [r.hit for r in rn] == [r.hit for r in rk]
    assert [r.cid if r.hit else -1 for r in rn] == \
           [r.cid if r.hit else -1 for r in rk]
    assert sum(r.hit for r in rn) == len(embs)      # paraphrases all hit
    assert cn.metrics.hits == ck.metrics.hits


def test_lookup_batch_matches_sequential_lookups():
    cn, space, embs = _filled_cache("numpy")
    cs, _, _ = _filled_cache("numpy")
    queries = np.stack(
        [space.paraphrase(embs[i], i % 8, i, 1).astype(np.float32)
         for i in range(10)])
    batched = cn.lookup_batch(queries)
    single = [cs.lookup(q) for q in queries]
    for b, s in zip(batched, single):
        assert b.hit == s.hit and b.cid == s.cid
        np.testing.assert_allclose(b.sim, s.sim, atol=1e-6)


def test_kernel_rac_value_matches_numpy():
    rng = np.random.default_rng(0)
    nb, kb = NumpyBackend(), KernelBackend()
    tsi = rng.random(100)
    tids = rng.integers(0, 16, 100)
    tp_last = rng.random(16) * 5
    t_last = rng.integers(0, 500, 16)
    a = nb.rac_value(tsi, tids, tp_last, t_last, 0.001, 700)
    b = kb.rac_value(tsi, tids, tp_last, t_last, 0.001, 700)
    np.testing.assert_allclose(a, b, rtol=1e-5)


def test_get_backend_rejects_unknown():
    with pytest.raises(ValueError):
        get_backend("cuda")


# ------------------------------------------------------ facade semantics
def test_lookup_never_admits_and_admit_evicts():
    space = EmbeddingSpace(dim=32, seed=1)
    cache = SemanticCache(CacheConfig(capacity=2, dim=32, policy="FIFO"))
    e = [space.content_embedding(0, i).astype(np.float32) for i in range(3)]
    assert isinstance(cache.lookup(e[0], cid=0), CacheMiss)
    assert len(cache) == 0                      # miss did not admit
    cache.admit(0, e[0], payload="r0")
    cache.admit(1, e[1], payload="r1")
    evicted = cache.admit(2, e[2], payload="r2")
    assert evicted == [0] and len(cache) == 2   # FIFO over capacity 2
    assert 0 not in cache.payloads              # payload died with entry
    r = cache.lookup(e[1], cid=1)
    assert isinstance(r, CacheHit) and r.payload == "r1"


def test_event_hooks_fire():
    space = EmbeddingSpace(dim=32, seed=2)
    cache = SemanticCache(CacheConfig(capacity=1, dim=32, policy="LRU"))
    seen = []
    for kind in ("hit", "miss", "admit", "evict"):
        cache.subscribe(kind, lambda ev, k=kind: seen.append((k, ev.cid)))
    e0 = space.content_embedding(0, 0).astype(np.float32)
    e1 = space.content_embedding(1, 1).astype(np.float32)
    cache.lookup(e0, cid=0)                     # miss
    cache.admit(0, e0, payload="x")             # admit
    cache.lookup(e0, cid=0)                     # hit
    cache.admit(1, e1)                          # admit + evict 0
    kinds = [k for k, _ in seen]
    assert kinds == ["miss", "admit", "hit", "admit", "evict"]
    assert seen[-1] == ("evict", 0)
    m = cache.metrics
    assert (m.hits, m.misses, m.admissions, m.evictions) == (1, 1, 2, 1)


def test_checkpoint_restore_roundtrip():
    cache, space, embs = _filled_cache("numpy", n=30, capacity=32)
    cache.lookup(embs[3], cid=3)
    snap = cache.checkpoint()
    before = (cache.metrics.hits, cache.metrics.evictions, len(cache.store),
              sorted(cache.store.keys()), dict(cache.payloads))
    for j in range(50):                          # churn everything
        cache.admit(2000 + j,
                    space.content_embedding(11, 2000 + j).astype(np.float32))
    assert sorted(cache.store.keys()) != before[3]
    cache.restore(snap)
    after = (cache.metrics.hits, cache.metrics.evictions, len(cache.store),
             sorted(cache.store.keys()), dict(cache.payloads))
    assert after == before
    # restored cache still behaves: resident entry hits again
    assert cache.lookup(embs[3], cid=3).hit


def test_content_mode_lookup_batch():
    cache = SemanticCache(CacheConfig(capacity=8, dim=16, policy="LRU",
                                      hit_mode="content"))
    rng = np.random.default_rng(0)
    embs = rng.standard_normal((4, 16)).astype(np.float32)
    cache.admit_batch([0, 1], embs[:2])
    rs = cache.lookup_batch(embs, cids=[0, 1, 2, 3])
    assert [r.hit for r in rs] == [True, True, False, False]


# ------------------------------------------------------ sharded store parity
def _replay_decisions(trace, capacity, backend, n_requests=2000, **bkw):
    """Replay a trace slice through the facade, recording every decision
    (hit cids, admissions, eviction victims) via the event hooks."""
    dim = trace.requests[0].emb.shape[0]
    cache = SemanticCache(CacheConfig(capacity=capacity, dim=dim,
                                      backend=backend, policy="RAC",
                                      use_pallas=False, backend_kwargs=bkw))
    events = []
    for kind in ("hit", "miss", "admit", "evict"):
        cache.subscribe(kind, lambda ev, k=kind: events.append((k, ev.cid)))
    for req in trace.requests[:n_requests]:
        r = cache.lookup(req.emb, cid=req.cid, t=req.t, req=req)
        if not r.hit:
            cache.admit(req.cid, req.emb, t=req.t, req=req)
    return events, cache


@pytest.fixture(scope="module")
def numpy_decisions(trace_10k):
    cap = int(0.1 * trace_10k.meta["unique"])
    return _replay_decisions(trace_10k, cap, "numpy")


@pytest.mark.parametrize("n_shards", [1, 2, 4])
def test_sharded_matches_numpy_decisions(trace_10k, numpy_decisions, n_shards):
    """The acceptance criterion: identical hit/miss cids, admissions, and
    eviction victims across shard counts — RAC policy, semantic mode."""
    cap = int(0.1 * trace_10k.meta["unique"])
    ev_n, cache_n = numpy_decisions
    ev_s, cache_s = _replay_decisions(trace_10k, cap, "sharded",
                                      n_shards=n_shards)
    assert ev_s == ev_n
    assert isinstance(cache_s.store, ShardedStore)
    assert cache_s.store.n_shards == n_shards
    m_n, m_s = cache_n.metrics, cache_s.metrics
    assert (m_s.hits, m_s.misses, m_s.evictions) == \
           (m_n.hits, m_n.misses, m_n.evictions)
    # row partitioning really happened: per-shard load counters agree with
    # an exact recount of where every resident slot actually lives (strict
    # balance is NOT an invariant — evictions pick victims by value, not
    # by shard — so only the bookkeeping is asserted)
    store = cache_s.store
    recount = np.bincount([s // store.rows_per_shard
                           for s in store.slot_of.values()],
                          minlength=store.n_shards)
    np.testing.assert_array_equal(store.load, recount)
    assert store.load.sum() == len(store)


def test_sharded_lookup_batch_matches_numpy_pallas():
    """Small-batch parity with the Pallas kernel path active per shard."""
    cn, space, embs = _filled_cache("numpy")
    cs = SemanticCache(CacheConfig(capacity=50, dim=64, backend="sharded",
                                   policy="LRU",
                                   backend_kwargs={"n_shards": 4}))
    for i, e in enumerate(embs):
        cs.admit(i, e, payload=[i])
    queries = np.stack(
        [space.paraphrase(embs[i], i % 8, i, 1).astype(np.float32)
         for i in range(len(embs))]
        + [space.content_embedding(9, 1000 + j).astype(np.float32)
           for j in range(8)])
    n_cids, n_sims = cn.peek_batch(queries)
    s_cids, s_sims = cs.peek_batch(queries)
    np.testing.assert_array_equal(n_cids, s_cids)
    np.testing.assert_allclose(n_sims, s_sims, atol=1e-5)


def test_sharded_empty_and_all_slots_free():
    """Lookups against an empty sharded cache (all slots free) miss with
    best_cid -1; a store with occupied and empty shards still resolves."""
    space = EmbeddingSpace(dim=32, seed=3)
    cache = SemanticCache(CacheConfig(capacity=6, dim=32, policy="LRU",
                                      backend="sharded", use_pallas=False,
                                      backend_kwargs={"n_shards": 4}))
    e = [space.content_embedding(0, i).astype(np.float32) for i in range(3)]
    r = cache.lookup(e[0], cid=0)
    assert isinstance(r, CacheMiss) and r.best_cid == -1
    cache.admit(0, e[0])                        # 3 of 4 shards stay empty
    assert (cache.store.load > 0).sum() == 1
    assert cache.lookup(e[0], cid=0).hit
    r = cache.lookup(e[1], cid=1)
    assert not r.hit and r.best_cid == 0        # nearest resident reported


@pytest.mark.parametrize("n_shards", [2, 4])
def test_sharded_capacity_boundary(n_shards):
    """Exactly capacity admissions → no eviction; one more → exactly one,
    with the same victim the numpy backend elects."""
    rng = np.random.default_rng(4)
    cap, dim = 8, 32
    embs = rng.standard_normal((cap + 1, dim)).astype(np.float32)
    embs /= np.linalg.norm(embs, axis=1, keepdims=True)

    def fill(backend, **bkw):
        c = SemanticCache(CacheConfig(capacity=cap, dim=dim, policy="RAC",
                                      backend=backend, use_pallas=False,
                                      backend_kwargs=bkw))
        evicted = []
        for i in range(cap):
            evicted += c.admit(i, embs[i])
        assert evicted == [] and len(c) == cap
        evicted = c.admit(cap, embs[cap])
        assert len(evicted) == 1 and len(c) == cap
        return evicted

    assert fill("sharded", n_shards=n_shards) == fill("numpy")


def test_sharded_checkpoint_restore_roundtrip():
    """All sharded state (slab, free lists, loads, hwm) survives the
    facade's checkpoint/restore with no backend cooperation."""
    space = EmbeddingSpace(dim=64, seed=5)
    cache = SemanticCache(CacheConfig(capacity=32, dim=64, policy="LRU",
                                      backend="sharded", use_pallas=False,
                                      backend_kwargs={"n_shards": 4}))
    embs = [space.content_embedding(i % 8, i).astype(np.float32)
            for i in range(30)]
    for i, e in enumerate(embs):
        cache.admit(i, e, payload=[i])
    cache.lookup(embs[3], cid=3)
    snap = cache.checkpoint()
    before = (sorted(cache.store.keys()), cache.store.load.tolist(),
              cache.store.local_hwm.tolist(), cache.metrics.hits)
    for j in range(50):
        cache.admit(2000 + j,
                    space.content_embedding(11, 2000 + j).astype(np.float32))
    assert sorted(cache.store.keys()) != before[0]
    cache.restore(snap)
    after = (sorted(cache.store.keys()), cache.store.load.tolist(),
             cache.store.local_hwm.tolist(), cache.metrics.hits)
    assert after == before
    assert cache.lookup(embs[3], cid=3).hit


@pytest.mark.slow_mesh
def test_sharded_shard_map_path_in_subprocess():
    """With enough devices the mesh path (shard_map + all_gather argmax
    merge) is exercised end-to-end and agrees with the numpy backend."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = """
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=4").strip()
import numpy as np
from repro.cache import NumpyBackend, ShardedKernelBackend, ShardedStore
rng = np.random.default_rng(1)
store = ShardedStore(300, 64, n_shards=4)
embs = rng.standard_normal((200, 64)).astype(np.float32)
embs /= np.linalg.norm(embs, axis=1, keepdims=True)
for i in range(200):
    store.insert(i, embs[i])
store.remove(7); store.remove(90)
q = rng.standard_normal((64, 64)).astype(np.float32)
q /= np.linalg.norm(q, axis=1, keepdims=True)
sb = ShardedKernelBackend(n_shards=4, use_pallas=False)
assert sb.mesh() is not None, "mesh must be active with 4 devices"
nc, ns = NumpyBackend().top1_batch(store, q)
sc, ss = sb.top1_batch(store, q)
np.testing.assert_array_equal(nc, sc)
np.testing.assert_allclose(ns, ss, atol=1e-5)
print("OK")
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


def test_get_backend_kwargs_uniform():
    """kwargs pass through to every backend; unexpected ones raise instead
    of being silently dropped."""
    b = get_backend("sharded", n_shards=2, use_pallas=False)
    assert isinstance(b, ShardedKernelBackend) and b.n_shards == 2
    with pytest.raises(TypeError):
        get_backend("numpy", use_pallas=True)
    with pytest.raises(TypeError):
        get_backend("kernel", n_shards=2)
    with pytest.raises(ValueError):
        get_backend(NumpyBackend(), use_pallas=True)
    assert isinstance(get_backend(NumpyBackend()), NumpyBackend)


# ----------------------------------------------------------- engine facade
def test_engine_has_no_inline_cache_logic():
    """The acceptance criterion: ServingEngine owns no cache protocol —
    lookup/admit/evict live behind SemanticCache only."""
    import inspect

    from repro.serving.engine import ServingEngine
    assert not hasattr(ServingEngine, "_lookup")
    assert not hasattr(ServingEngine, "_admit")
    src = inspect.getsource(ServingEngine)
    assert "ResidentStore(" not in src and "RACPolicy(" not in src
    # batched hot path: the whole queue is scored in one fused facade
    # launch, and rescans stay row-restricted through the backend
    assert "decide_batch" in src
    assert "peek_rows" in src
