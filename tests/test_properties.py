"""Hypothesis property tests on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import BASELINES, Request, Trace, hr_full, run_policy
from repro.core.policies import BeladyPolicy
from repro.core.rac import RACPolicy
from repro.core.store import ResidentStore
from repro.core.structural import pagerank_reversed

POLICY_NAMES = sorted(BASELINES.keys())


def _trace(cids, dim=8):
    reqs = []
    for t, c in enumerate(cids):
        e = np.zeros(dim, np.float32)
        e[c % dim] = 1.0
        reqs.append(Request(t=t, cid=int(c), emb=e))
    return Trace(requests=reqs).with_next_use()


@given(cids=st.lists(st.integers(0, 30), min_size=1, max_size=200),
       cap=st.integers(1, 12),
       name=st.sampled_from(POLICY_NAMES))
@settings(max_examples=60, deadline=None)
def test_capacity_never_exceeded_and_counts_consistent(cids, cap, name):
    tr = _trace(cids)
    s = run_policy(tr, cap, lambda c, st_: BASELINES[name](c, st_), name=name)
    assert s.hits + s.misses == len(cids)
    assert s.evictions <= s.misses
    assert 0.0 <= s.hit_ratio <= 1.0


@given(cids=st.lists(st.integers(0, 20), min_size=5, max_size=150))
@settings(max_examples=30, deadline=None)
def test_belady_hits_monotone_in_capacity(cids):
    tr = _trace(cids)
    prev = -1
    for cap in (1, 2, 4, 8, 16):
        s = run_policy(tr, cap, lambda c, st_: BeladyPolicy(c, st_))
        assert s.hits >= prev
        prev = s.hits


@given(cids=st.lists(st.integers(0, 20), min_size=5, max_size=150))
@settings(max_examples=30, deadline=None)
def test_infinite_cache_reaches_hr_full(cids):
    tr = _trace(cids)
    s = run_policy(tr, len(cids) + 1, lambda c, st_: BASELINES["LRU"](c, st_))
    assert s.hit_ratio == hr_full(tr)
    assert s.evictions == 0


@given(cids=st.lists(st.integers(0, 25), min_size=1, max_size=150),
       cap=st.integers(1, 10),
       mode=st.sampled_from(["normalized", "paper"]))
@settings(max_examples=40, deadline=None)
def test_rac_invariants(cids, cap, mode):
    """RAC-specific: capacity, topic-member consistency, value finiteness."""
    tr = _trace(cids, dim=16)
    store = ResidentStore(cap, 16)
    pol = RACPolicy(cap, store, value_mode=mode, tau_route=0.3)
    for req in tr.requests:
        if req.cid in store:
            pol.on_hit(req.cid, req, req.t)
        else:
            store.insert(req.cid, req.emb)
            pol.on_admit(req.cid, req, req.t)
            while len(store) > cap:
                v = pol.victim(req.t)
                store.remove(v)
    assert len(store) <= cap
    # every resident belongs to exactly one live topic's member set
    members = [c for ts in pol.topics.values() for c in ts.members]
    assert sorted(members) == sorted(store.keys())
    if len(store):
        cids_, vals = pol.value_scores(tr.requests[-1].t + 1)
        assert np.isfinite(vals).all()
        assert (vals >= 0).all()


@given(n=st.integers(2, 12), beta=st.floats(0.05, 0.95),
       data=st.data())
@settings(max_examples=40, deadline=None)
def test_pagerank_is_distribution_and_anchor_dominates_chain(n, beta, data):
    edges = [(i, i + 1) for i in range(n - 1)]   # chain: 0 is the root anchor
    r = pagerank_reversed(edges, n, beta=beta)
    assert abs(r.sum() - 1.0) < 1e-6
    assert (r >= 0).all()
    assert r[0] == r.max()       # root of the reversed chain accumulates


@given(seed=st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_synthetic_trace_deterministic(seed):
    from repro.core import SynthConfig, synthetic_trace
    cfg = SynthConfig(trace_len=300, n_topics=10, seed=seed)
    a = synthetic_trace(cfg)
    b = synthetic_trace(cfg)
    assert [r.cid for r in a.requests] == [r.cid for r in b.requests]
    assert all(np.array_equal(x.emb, y.emb)
               for x, y in zip(a.requests[:50], b.requests[:50]))


@given(seed=st.integers(0, 1000), cursor=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_data_pipeline_cursor_determinism(seed, cursor):
    from repro.data import DataConfig, TokenPipeline
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=4, seed=seed)
    p1 = TokenPipeline(cfg)
    p2 = TokenPipeline(cfg)
    b1 = p1.batch_at(cursor)
    b2 = p2.batch_at(cursor)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert np.array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
