"""Unit tests for the baseline eviction policies (paper §4.2 set)."""
import itertools

import numpy as np
import pytest

from repro.core import BASELINES, Request, Trace, run_policy
from repro.core.policies import BeladyPolicy, LRUPolicy
from repro.core.store import ResidentStore


def _trace_from_cids(cids, dim=8):
    reqs = []
    for t, c in enumerate(cids):
        e = np.zeros(dim, np.float32)
        e[c % dim] = 1.0
        reqs.append(Request(t=t, cid=int(c), emb=e))
    return Trace(requests=reqs).with_next_use()


def _drive(policy_cls, cids, capacity, **kw):
    """Run a policy manually; return list of (evicted cid at each step)."""
    tr = _trace_from_cids(cids)
    store = ResidentStore(capacity, 8)
    pol = policy_cls(capacity, store, **kw)
    evictions = []
    hits = 0
    for req in tr.requests:
        if req.cid in store:
            hits += 1
            pol.on_hit(req.cid, req, req.t)
        else:
            store.insert(req.cid, req.emb)
            pol.on_admit(req.cid, req, req.t)
            while len(store) > capacity:
                v = pol.victim(req.t)
                store.remove(v)
                evictions.append(v)
    return hits, evictions, store


def test_lru_evicts_least_recent():
    hits, ev, _ = _drive(LRUPolicy, [1, 2, 3, 1, 4], capacity=3)
    # after 1,2,3 cache full; access 1 -> MRU; admit 4 evicts 2
    assert ev == [2]
    assert hits == 1


def test_fifo_order():
    hits, ev, _ = _drive(BASELINES["FIFO"], [1, 2, 3, 1, 4, 5], capacity=3)
    assert ev == [1, 2]          # insertion order regardless of the hit


def test_clock_second_chance():
    # 1,2,3 fill; hit 1 sets ref; inserting 4 must skip 1 and evict 2
    hits, ev, _ = _drive(BASELINES["CLOCK"], [1, 2, 3, 1, 4], capacity=3)
    assert ev == [2]


def test_sieve_retains_visited():
    hits, ev, _ = _drive(BASELINES["SIEVE"], [1, 2, 3, 1, 4], capacity=3)
    assert ev == [2]             # 1 visited -> survives the hand


def test_lfu_evicts_least_frequent():
    hits, ev, _ = _drive(BASELINES["LFU"], [1, 1, 2, 3, 4], capacity=3)
    assert ev == [2]             # 2 and 3 tie on freq; 2 is older


def test_belady_is_optimal_on_small_traces(rng):
    """Belady must beat or match every other policy (exhaustively checked
    against brute-force optimal on random small traces)."""
    for trial in range(20):
        cids = rng.integers(0, 6, size=24).tolist()
        cap = 3
        hits_b, _, _ = _drive(BeladyPolicy, cids, cap)
        # brute force optimal via DP over reachable cache states
        from functools import lru_cache
        seq = tuple(cids)

        def solve(i, cache):
            if i == len(seq):
                return 0
            c = seq[i]
            if c in cache:
                return 1 + solve(i + 1, cache)
            if len(cache) < cap:
                return solve(i + 1, tuple(sorted(cache + (c,))))
            best = solve(i + 1, cache)          # bypass (admit-then-self-evict)
            for out in cache:
                new = tuple(sorted([x for x in cache if x != out] + [c]))
                best = max(best, solve(i + 1, new))
            return best
        solve = lru_cache(maxsize=None)(solve)
        opt = solve(0, ())
        assert hits_b == opt, f"Belady {hits_b} != OPT {opt} on {cids}"


@pytest.mark.parametrize("name", sorted(BASELINES.keys()))
def test_policy_respects_capacity_and_victim_valid(name, rng):
    cids = rng.integers(0, 40, size=300).tolist()
    cap = 10
    hits, ev, store = _drive(BASELINES[name], cids, cap)
    assert len(store) <= cap
    assert hits >= 0
    # all evicted cids were real and not resident afterwards
    for v in ev:
        assert isinstance(v, int)


@pytest.mark.parametrize("name", ["LRU", "ARC", "S3-FIFO", "SIEVE", "2Q",
                                  "TinyLFU", "LeCaR", "LHD", "GDSF",
                                  "LRU-2"])
def test_policy_hits_on_repeats(name):
    # a tight loop over 3 items in a cap-4 cache must hit after warmup
    cids = [1, 2, 3] * 10
    hits, _, _ = _drive(BASELINES[name], cids, capacity=4)
    assert hits >= 24            # 27 re-accesses; allow warm-up slack


def test_run_policy_smoke():
    tr = _trace_from_cids([1, 2, 1, 3, 2, 1] * 5)
    s = run_policy(tr, 2, lambda c, st: LRUPolicy(c, st), name="LRU")
    assert s.hits + s.misses == len(tr.requests)
    assert 0 < s.hit_ratio < 1
    assert s.hr_full > 0
