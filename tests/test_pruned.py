"""Topic-pruned two-stage lookup: routing-kernel parity (device vs host
oracle), incremental bucket-index maintenance vs full rebuild, gathered
candidate-set tie-break preservation on churned stores, decision parity
of ``pruned_lookup`` against the exact path across all three backends
(alone and composed with ``quantized_lookup``), the probe-width property
sweep, and the facade/telemetry wiring."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.cache import CacheConfig, SemanticCache
from repro.cache.backends import KernelBackend, NumpyBackend
from repro.cache.pruned import (NEG, PrunedLookupConfig, TopicBucketIndex,
                                as_pruned_config, new_prune_stats,
                                route_topics_host)
from repro.cache.sharded import ShardedKernelBackend
from repro.core.policy_table import PolicyTable
from repro.core.store import ResidentStore


def _unit_rows(rng, n, dim):
    x = rng.standard_normal((n, dim)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _clustered(rng, n, dim, n_topics, sigma=0.05):
    """A clustered store + matching routing table (reps = true centers,
    memberships journaled the way a policy would)."""
    centers = _unit_rows(rng, n_topics, dim)
    assign = rng.integers(0, n_topics, size=n)
    noise = sigma * rng.standard_normal((n, dim)).astype(np.float32)
    embs = centers[assign] + noise
    embs /= np.linalg.norm(embs, axis=1, keepdims=True)
    store = ResidentStore(n + 8, dim)
    for i in range(n):
        store.insert(i, embs[i])
    table = PolicyTable(store.emb.shape[0], dim)
    for t in range(n_topics):
        table.set_rep(t, centers[t])
    for slot in range(n):
        table.topic_of[slot] = assign[slot]
        table.touch_slot(slot)
    return store, table, embs, centers


# ------------------------------------------------------- config plumbing
def test_pruned_config_normalization():
    assert as_pruned_config(None) is None
    assert as_pruned_config(False) is None
    assert as_pruned_config(True) == PrunedLookupConfig()
    pc = as_pruned_config({"probes": 4, "tau_hit": 0.9})
    assert pc.probes == 4 and pc.tau_hit == 0.9
    assert as_pruned_config(pc) is pc
    with pytest.raises(ValueError):
        as_pruned_config("yes")
    assert set(new_prune_stats()) == {"scans", "queries", "fallbacks",
                                      "probed_topics", "scanned_rows",
                                      "rows_exact", "bytes_scanned",
                                      "bytes_exact", "capped"}


def test_prebuilt_backend_rejects_pruned_lookup():
    with pytest.raises(ValueError):
        SemanticCache(CacheConfig(capacity=4, dim=8, pruned_lookup=True),
                      backend=NumpyBackend())


def test_pruned_multi_requires_row_tracking(rng):
    from repro.core.arena import ArenaStore
    arena = ArenaStore(2, 10, 16, track_rows=False)
    for be in (NumpyBackend(pruned=True),
               KernelBackend(use_pallas=False, pruned=True),
               ShardedKernelBackend(n_shards=2, use_pallas=False,
                                    pruned=True)):
        be.route_tables = [None, None]
        arena.views[0].insert(1, _unit_rows(rng, 1, 16)[0])
        with pytest.raises(ValueError):
            be.top1_multi(arena, _unit_rows(rng, 2, 16))


# ------------------------------------------------------- routing kernel
def test_route_topics_kernel_matches_host_oracle(rng):
    from repro.kernels import ops
    dim, n_top, n_valid, probes = 48, 24, 19, 3
    q = _unit_rows(rng, 9, dim)
    aug = np.zeros((n_top, dim + 1), dtype=np.float32)
    aug[:n_valid, :dim] = _unit_rows(rng, n_valid, dim)
    aug[:n_valid, dim] = rng.uniform(0.05, 0.6, n_valid)
    aug[n_valid:, dim] = NEG
    hv, ht = route_topics_host(q, aug, n_valid, probes)
    jv, jt = ops.route_topics(q, aug, probes, n_valid=n_valid,
                              use_pallas=False)
    pv, pt = ops.route_topics(q, aug, probes, n_valid=n_valid,
                              use_pallas=True)
    # the two device engines are bit-identical (same pattern as sim_topk)
    np.testing.assert_array_equal(np.asarray(pv), np.asarray(jv))
    np.testing.assert_array_equal(np.asarray(pt), np.asarray(jt))
    # the host oracle may differ in the last ulp (BLAS summation order) —
    # routing only picks which buckets to probe, the safety predicate
    # certifies decisions regardless, so tolerance is the contract here
    np.testing.assert_allclose(np.asarray(jv, dtype=np.float64), hv,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(jt, dtype=np.int64), ht)


def test_route_topics_fewer_topics_than_probes(rng):
    """T <= P: every live topic is probed and the (P+1)-th bound column
    simply does not exist — the driver treats that as ub = -inf."""
    from repro.kernels import ops
    q = _unit_rows(rng, 3, 16)
    aug = np.zeros((2, 17), dtype=np.float32)
    aug[:, :16] = _unit_rows(np.random.default_rng(5), 2, 16)
    aug[:, 16] = 0.1
    vals, tids = ops.route_topics(q, aug, probes=4, n_valid=2,
                                  use_pallas=False)
    assert np.asarray(vals).shape[1] == 2     # k = min(P+1, T)
    assert set(np.asarray(tids).ravel().tolist()) == {0, 1}


# ----------------------------------------------------------- bucket index
def test_bucket_index_incremental_matches_rebuild(rng):
    store, table, embs, centers = _clustered(rng, 40, 24, 6)
    idx = TopicBucketIndex()
    idx.sync(store, table)
    assert idx.stats["full"] == 1

    # churn: eviction, admission, a topic move, an unassigned row, and a
    # representative update
    store.remove(3)
    new = _unit_rows(rng, 2, 24)
    s_a = store.insert(100, new[0])
    table.topic_of[s_a] = 2
    table.touch_slot(s_a)
    s_b = store.insert(101, new[1])           # stays unassigned (-1)
    table.topic_of[7] = 4                     # moved buckets
    table.touch_slot(7)
    table.set_rep(1, _unit_rows(rng, 1, 24)[0])
    idx.sync(store, table)
    assert idx.stats["incremental"] >= 1 and idx.stats["full"] == 1

    fresh = TopicBucketIndex()
    fresh.sync(store, table)
    for ix in (idx, fresh):                   # force the lazy CSR pack
        ix.group_key(np.arange(6))
    np.testing.assert_array_equal(idx.indptr, fresh.indptr)
    np.testing.assert_array_equal(idx.slot_ids, fresh.slot_ids)
    np.testing.assert_array_equal(idx.unassigned, fresh.unassigned)
    np.testing.assert_array_equal(idx.aug, fresh.aug)
    assert s_b in idx.unassigned.tolist()
    # candidate sets always include the unassigned bucket
    rows = idx.candidate_rows(idx.group_key(np.array([2])))
    assert s_b in rows.tolist() and s_a in rows.tolist()


def test_bucket_index_spread_bounds_members(rng):
    """The aug spread column is a true Cauchy–Schwarz bound: for every
    member x and unit query q, q·x <= q·rep + |q|·spread."""
    store, table, embs, centers = _clustered(rng, 60, 32, 5, sigma=0.2)
    idx = TopicBucketIndex()
    idx.sync(store, table)
    idx.group_key(np.arange(5))               # force the lazy CSR pack
    q = _unit_rows(rng, 50, 32)
    for t in range(5):
        rows = idx.slot_ids[idx.indptr[t]:idx.indptr[t + 1]]
        if rows.size == 0:
            continue
        best = (q @ store.emb[rows].T).max(axis=1)
        bound = q @ idx.aug[t, :-1] + idx.aug[t, -1]
        assert (best <= bound + 1e-6).all()


# ------------------------------------------------ gathered-set tie-breaks
def test_topk_rows_gathered_candidates_keep_lower_slot_tie_rule(rng):
    """Churned store with duplicate embeddings spread across buckets that
    interleave slot ranges: the gathered candidate set must preserve the
    exact path's lower-slot tie rule, i.e. candidate_rows is ascending
    and every backend's topk over it lists the duplicates slot-ordered."""
    dim = 16
    store = ResidentStore(40, dim)
    vecs = _unit_rows(rng, 40, dim)
    dup = vecs[0]
    for i in range(36):
        store.insert(i, vecs[i])
    for slot in (3, 17, 29):                  # exact duplicates
        store.remove(int(store.cid[slot]))
        store.insert(100 + slot, dup)
    assert [int(store.slot_of[100 + s]) for s in (3, 17, 29)] == [3, 17, 29]
    store.remove(int(store.cid[11]))          # churn hole inside the range

    table = PolicyTable(store.emb.shape[0], dim)
    table.set_rep(0, dup)
    table.set_rep(1, vecs[5])
    # buckets deliberately interleave slot ranges
    for slot, t in ((17, 0), (3, 1), (29, 0), (5, 1)):
        table.topic_of[slot] = t
        table.touch_slot(slot)
    idx = TopicBucketIndex()
    idx.sync(store, table)
    rows = idx.candidate_rows(idx.group_key(np.array([0, 1])))
    assert (np.diff(rows) > 0).all()          # strictly ascending
    assert {3, 17, 29, 5} <= set(rows.tolist())

    q = dup[None, :]
    expect = None
    for be in (NumpyBackend(), KernelBackend(use_pallas=False),
               ShardedKernelBackend(n_shards=2, use_pallas=False)):
        cids, sims = be.topk_rows(store, q, rows, k=3)
        if expect is None:
            expect = (cids, sims)
            # four rows tie at sim 1.0 (slot 0 holds the original dup and
            # rides in via the unassigned bucket): slot order must win
            assert cids[0].tolist() == [0, 100 + 3, 100 + 17]
        else:
            np.testing.assert_array_equal(cids, expect[0])
            np.testing.assert_array_equal(sims, expect[1])


# ------------------------------------------------------- decision parity
def _drive(cfg_kw, reqs):
    cache = SemanticCache(CacheConfig(**cfg_kw))
    events = []
    for kind in ("hit", "miss", "admit", "evict"):
        cache.subscribe(kind, lambda ev, k=kind: events.append((k, ev.cid)))
    for cid, emb in reqs:
        if not cache.lookup(emb, cid=cid).hit:
            cache.admit(cid, emb)
    return events, cache


def _workload(rng, n=160, dim=48, n_base=24, jitter=0.05):
    base = _unit_rows(rng, n_base, dim)
    reqs = []
    for i in range(n):
        j = int(rng.integers(0, n_base))
        v = base[j] + jitter * rng.standard_normal(dim).astype(np.float32)
        reqs.append((j * 1000 + i, (v / np.linalg.norm(v)).astype(np.float32)))
    return reqs


@pytest.mark.parametrize("backend", ["numpy", "kernel", "sharded"])
@pytest.mark.parametrize("hit_mode", ["semantic", "content"])
def test_facade_event_parity_pruned_vs_exact(rng, backend, hit_mode):
    reqs = _workload(rng)
    kw = dict(capacity=18, dim=48, backend=backend, hit_mode=hit_mode)
    if backend == "sharded":
        kw["backend_kwargs"] = {"n_shards": 2}
    if backend != "numpy":
        kw["use_pallas"] = False
    ev0, _ = _drive(dict(kw), reqs)
    for probes in (1, 2, 8):
        ev1, c1 = _drive(dict(kw, pruned_lookup={"probes": probes}), reqs)
        assert ev1 == ev0, (backend, hit_mode, probes)
        if hit_mode == "semantic":
            assert c1.backend.prune_stats["scans"] > 0
    # composed with the int8 scan: still the same decision stream
    ev2, _ = _drive(dict(kw, pruned_lookup=True, quantized_lookup=True),
                    reqs)
    assert ev2 == ev0, (backend, hit_mode, "pruned+quant")


@pytest.mark.parametrize("backend", ["numpy", "kernel"])
def test_run_arena_pruned_parity(rng, backend):
    from repro.core import default_factories
    from repro.core.arena import run_arena
    from repro.core.types import Request, Trace
    reqs = [Request(t=i, cid=cid, emb=emb)
            for i, (cid, emb) in enumerate(_workload(rng, n=200))]
    trace = Trace(requests=reqs)
    allf = default_factories()
    facs = {"LRU": allf["LRU"], "RAC": allf["RAC"]}
    kw = dict(hit_mode="semantic", backend=backend, use_pallas=False,
              seed=0)
    s0 = run_arena(trace, 20, facs, **kw)
    s1 = run_arena(trace, 20, facs, pruned=True, **kw)
    s2 = run_arena(trace, 20, facs, pruned=True, quantized=True, **kw)
    for a, b, c in zip(s0, s1, s2):
        assert (a.hits, a.misses, a.evictions) == \
               (b.hits, b.misses, b.evictions)
        assert (a.hits, a.misses, a.evictions) == \
               (c.hits, c.misses, c.evictions)


def test_backend_pruned_hits_bit_equal_with_exact(rng):
    """Per-backend contract on the kernel engines (the host BLAS oracle
    may differ in the last ulp between full and gathered gemms, same as
    the quantized rescore): on a churned clustered store the certified
    pruned Top-1 keeps the hit mask identical and every hit's (cid, sim)
    bit-equal to the same backend's exact scan (certified misses are
    decision-equal)."""
    tau = 0.85

    def fill(be, r):
        n, dim, n_topics = 55, 64, 8
        centers = _unit_rows(r, n_topics, dim)
        assign = r.integers(0, n_topics, size=n)
        embs = centers[assign] \
            + 0.05 * r.standard_normal((n, dim)).astype(np.float32)
        embs /= np.linalg.norm(embs, axis=1, keepdims=True)
        store = (be.make_store(n + 5, dim) if hasattr(be, "make_store")
                 else ResidentStore(n + 5, dim))
        for i in range(n):
            store.insert(i, embs[i])
        for i in range(0, 18, 3):             # churn holes
            store.remove(i)
        table = PolicyTable(store.emb.shape[0], dim)
        for t in range(n_topics):
            table.set_rep(t, centers[t])
        for cid, slot in store.slot_of.items():
            table.topic_of[slot] = assign[cid]
            table.touch_slot(slot)
        return store, table, embs

    for mk in (lambda **kw: KernelBackend(use_pallas=False, **kw),
               lambda **kw: ShardedKernelBackend(n_shards=3,
                                                 use_pallas=False, **kw)):
        r = np.random.default_rng(2)
        store, table, embs = fill(mk(), r)
        q = np.concatenate([
            _unit_rows(r, 9, 64),                       # fresh misses
            embs[[20, 30, 40]]                          # exact dup hits
            + 0.002 * r.standard_normal((3, 64)).astype(np.float32)])
        q /= np.linalg.norm(q, axis=1, keepdims=True)
        exact = mk()
        c0, s0 = exact.top1_batch(store, q)
        for spec in ({"probes": 1, "tau_hit": tau},
                     {"probes": 2, "tau_hit": tau},
                     {"probes": 8, "tau_hit": tau},
                     {"probes": 2, "tau_hit": None}):
            pb = mk(pruned=spec)
            pb.route_table = table
            pb.route_store = store
            c1, s1 = pb.top1_batch(store, q)
            hit0 = s0 >= tau
            np.testing.assert_array_equal(hit0, s1 >= tau)
            np.testing.assert_array_equal(c0[hit0], c1[hit0])
            np.testing.assert_array_equal(s0[hit0], s1[hit0])
            assert pb.prune_stats["scans"] == 1
            # without the tau arm every non-dominant result falls back to
            # the exact scan — then even misses are bit-equal
            if spec["tau_hit"] is None:
                np.testing.assert_array_equal(c0, c1)
                np.testing.assert_array_equal(s0, s1)


# --------------------------------------------------- probe-width property
def _decisions_match_exact(seed, probes, backend, tau):
    """Property body: pruned event stream == exact event stream.  The
    probe widths cover P=1, the default, and P >= live topics (where
    routing certifies trivially)."""
    rng = np.random.default_rng(seed)
    reqs = _workload(rng, n=60, dim=32, n_base=10,
                     jitter=float(rng.uniform(0.02, 0.4)))
    kw = dict(capacity=8, dim=32, tau_hit=tau, backend=backend)
    if backend != "numpy":
        kw["use_pallas"] = False
    ev0, _ = _drive(dict(kw), reqs)
    ev1, _ = _drive(dict(kw, pruned_lookup={"probes": probes}), reqs)
    assert ev1 == ev0


def test_pruned_decisions_property_random_workloads():
    try:
        from hypothesis import HealthCheck, given, settings
        from hypothesis import strategies as st
    except ImportError:
        # hypothesis is optional in the image: fall back to a seeded
        # sweep over the same parameter space so the property still runs
        rng = np.random.default_rng(0)
        for _ in range(12):
            _decisions_match_exact(int(rng.integers(2 ** 31)),
                                   int(rng.choice([1, 2, 256])),
                                   str(rng.choice(["numpy", "kernel"])),
                                   float(rng.uniform(0.5, 0.99)))
        return

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(min_value=0, max_value=2 ** 31 - 1),
           st.sampled_from([1, 2, 256]),
           st.sampled_from(["numpy", "kernel"]),
           st.floats(min_value=0.5, max_value=0.99))
    def prop(seed, probes, backend, tau):
        _decisions_match_exact(seed, probes, backend, tau)

    prop()


# ----------------------------------------------------- telemetry wiring
def test_metrics_snapshot_ledgers_always_present(rng):
    reqs = _workload(rng, n=30)
    _, cache = _drive(dict(capacity=10, dim=48, backend="kernel",
                           use_pallas=False), reqs)
    assert cache.backend.pruned is None
    snap = cache.metrics_snapshot()
    assert snap["prune"] == new_prune_stats()     # zeroed, never missing
    assert snap["quant"]["scans"] == 0


def test_fallback_counter_reaches_tracker(rng):
    """Split one near-duplicate into a foreign topic (the journal-driven
    bucket index must follow arbitrary table churn): that topic's rep is
    far from its new member, so its intra-topic spread blows up and its
    bound exceeds every candidate sim — arm 1 cannot certify, while the
    duplicates' sims >= tau keep the certain-miss arm off.  The path must
    take counted exact fallbacks, and the counter must flow to the
    tracker and the snapshot."""
    cache = SemanticCache(CacheConfig(
        capacity=40, dim=48, tau_hit=0.5, backend="kernel",
        use_pallas=False, tracker="memory",
        pruned_lookup={"probes": 1}))
    center = _unit_rows(rng, 1, 48)[0]
    tight = center + 0.01 * rng.standard_normal((10, 48)).astype(np.float32)
    tight /= np.linalg.norm(tight, axis=1, keepdims=True)
    scatter = _unit_rows(rng, 20, 48)
    for i, v in enumerate(np.concatenate([tight, scatter])):
        cache.admit(i, v)                     # unconditional: keep all twins
    tbl = cache.policy.table
    slot = cache.store.slot_of[1]             # twin b -> a scatter topic
    foreign = int(tbl.topic_of[cache.store.slot_of[10]])
    tbl.topic_of[slot] = foreign
    tbl.touch_slot(slot)
    for a, b in zip(tight[:-1], tight[1:]):   # mid-point queries: sim >= tau
        q = (a + b) / 2.0
        cache.lookup(q / np.linalg.norm(q), cid=-1)
    fb = cache.backend.prune_stats["fallbacks"]
    assert fb > 0
    counters = cache.tracker.snapshot()["counters"]
    assert counters.get("cache.prune_fallbacks") == fb
    snap = cache.metrics_snapshot()
    assert snap["prune"]["fallbacks"] == fb
    # routing-matrix uploads ride the backend.sync byte ledger
    assert snap["sync"]["bytes"] > 0


def test_checkpoint_restore_rewires_route_store(rng):
    reqs = _workload(rng, n=60)
    ev0, _ = _drive(dict(capacity=12, dim=48), reqs)
    cache = SemanticCache(CacheConfig(capacity=12, dim=48,
                                      pruned_lookup=True))
    events = []
    cache.subscribe("evict", lambda ev: events.append(ev.cid))
    snap = cache.checkpoint()
    cache.restore(snap)
    assert cache.backend.route_store is cache.store
    for cid, emb in reqs:
        if not cache.lookup(emb, cid=cid).hit:
            cache.admit(cid, emb)
    ev1, _ = _drive(dict(capacity=12, dim=48, pruned_lookup=True), reqs)
    assert [e for e in ev1 if e[0] == "evict"] == \
           [("evict", c) for c in events]
    assert ev1 == ev0


# ------------------------------------------------------------ mesh path
@pytest.mark.slow_mesh
def test_sharded_pruned_mesh_path_in_subprocess():
    """With 4 host devices the pruned sharded lookup (dense probe
    delegation; the exact-fallback leg runs the per-shard shard_map scan
    + all_gather merge) makes the same decisions as the exact mesh
    path."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = """
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=4").strip()
import numpy as np
from repro.cache import ShardedKernelBackend, ShardedStore
from repro.core.policy_table import PolicyTable
rng = np.random.default_rng(1)
def unit(x):
    return x / np.linalg.norm(x, axis=-1, keepdims=True)
def fill():
    store = ShardedStore(300, 64, n_shards=4)
    r = np.random.default_rng(4)
    centers = unit(r.standard_normal((8, 64)).astype(np.float32))
    assign = r.integers(0, 8, size=200)
    embs = unit(centers[assign]
                + 0.05 * r.standard_normal((200, 64)).astype(np.float32))
    for i in range(200):
        store.insert(i, embs[i].astype(np.float32))
    store.remove(7); store.remove(90)
    table = PolicyTable(store.emb.shape[0], 64)
    for t in range(8):
        table.set_rep(t, centers[t])
    for i in range(200):
        slot = store.slot_of.get(i)
        if slot is not None:
            table.topic_of[slot] = assign[i]
            table.touch_slot(slot)
    return store, table, embs
q = unit(rng.standard_normal((32, 64)).astype(np.float32))
ex = ShardedKernelBackend(n_shards=4, use_pallas=False)
st, _, embs = fill()
q[0] = embs[3]; q[1] = embs[100]
assert ex.mesh() is not None
c0, s0 = ex.top1_batch(st, q)
pb = ShardedKernelBackend(n_shards=4, use_pallas=False,
                          pruned={"probes": 2, "tau_hit": 0.85})
stp, table, _ = fill()
pb.route_table = table
pb.route_store = stp
c1, s1 = pb.top1_batch(stp, q)
hit0 = s0 >= 0.85
np.testing.assert_array_equal(hit0, s1 >= 0.85)
np.testing.assert_array_equal(c0[hit0], c1[hit0])
np.testing.assert_array_equal(s0[hit0], s1[hit0])
assert pb.prune_stats["scans"] == 1
assert hit0.any()
print("OK")
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
