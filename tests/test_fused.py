"""Device-resident fused decision pipeline (``kernels/fused.py``).

Contracts under test:

- **Bit-parity within a backend**: the fused single-launch path must
  reproduce the staged multi-launch driver's (hit, cid, sim) event
  stream bit-for-bit — same kernel engine, same tie contract, same
  safety predicates — across semantic/content modes and the
  pruned/quantized/composed configs.
- **Decision parity across backends**: hit/miss + cid sequences match
  the numpy host oracle (sims may differ in the last ulp between the
  pallas gemm and host BLAS — a pre-existing exact-path property, so
  cross-backend assertions are decisions-only).
- **Compile stability**: steady-state replay reuses one executable per
  fused entry point; store growth only recompiles at static shape
  bucket boundaries.
- **Probe-cap accounting**: the adaptive scan budget truncates probes
  identically on the staged and fused paths and lands in the ``capped``
  ledger, with decisions still exact.
- **Dispatch ledger**: ``metrics_snapshot()['dispatch']`` is always
  present; kernel backends tick launches/host_syncs, host backends
  report zeros.
"""
import numpy as np
import pytest

from repro.cache import CacheConfig, SemanticCache
from repro.cache.pruned import PrunedLookupConfig
from repro.cache.quantized import QuantizedLookupConfig
from repro.kernels import fused


def _workload(n=240, dim=32, n_proto=48, jitter=0.05, seed=7):
    rng = np.random.default_rng(seed)
    protos = rng.standard_normal((n_proto, dim)).astype(np.float32)
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)
    reqs = []
    for i in range(n):
        j = int(rng.integers(0, n_proto))
        p = protos[j] + jitter * rng.standard_normal(dim).astype(np.float32)
        p /= np.linalg.norm(p)
        reqs.append((j, p.astype(np.float32)))
    return reqs


def _events(backend, pruned, quant, *, mode="semantic", tau=0.80,
            capacity=40, use_pallas=False, reqs=None, **bk):
    cfg = CacheConfig(capacity=capacity, dim=32, tau_hit=tau,
                      hit_mode=mode, backend=backend,
                      pruned_lookup=pruned, quantized_lookup=quant,
                      use_pallas=use_pallas, backend_kwargs=bk)
    cache = SemanticCache(cfg)
    ev = []
    for cid, emb in (reqs or _workload()):
        r = cache.lookup(emb)
        ev.append((r.hit, getattr(r, "cid", -1),
                   float(getattr(r, "sim", float("-inf")))))
        if not r.hit:
            cache.admit(cid, emb)
    return ev, cache


def _decisions(ev):
    return [(h, c) for h, c, _ in ev]


# ------------------------------------------------------- config plumbing
def test_fused_is_the_default():
    assert PrunedLookupConfig().fused is True
    assert QuantizedLookupConfig().fused is True
    assert set(fused.fused_stats) == {"calls", "fallback_rows",
                                      "capped_rows"}
    assert set(fused.compile_counts()) == {"pruned", "quant"}


def test_shape_buckets():
    assert fused.pad_pow2(1, 8) == 8
    assert fused.pad_pow2(9, 8) == 16
    assert fused.pad_geo(1) == 64
    assert fused.pad_geo(65) == 96          # pow2 + 1.5x midpoints
    assert fused.pad_geo(97) == 128
    # tau_lo is the largest f32 strictly below tau: device `v <= tau_lo`
    # must decide exactly like host f64 `v < tau`
    tau = 0.85
    lo = float(fused.tau_lo_f32(tau))
    assert lo < tau
    assert np.nextafter(np.float32(lo), np.float32(np.inf)) >= \
        np.float32(tau)


# --------------------------------------------------- bit-parity contracts
@pytest.mark.parametrize("mode", ["semantic", "content"])
@pytest.mark.parametrize("pruned,quant", [
    (True, False), (False, True), (True, True)])
def test_fused_matches_staged_bit_for_bit(mode, pruned, quant):
    """Same backend, fused vs staged: the full (hit, cid, sim) stream is
    bit-equal — the fused union rescore runs the same kernel engine over
    the same candidate rows with the same lowest-slot tie contract."""
    ev_f, cache = _events("kernel", pruned and {"fused": True},
                          quant and {"fused": True}, mode=mode)
    ev_s, _ = _events("kernel", pruned and {"fused": False},
                      quant and {"fused": False}, mode=mode)
    assert ev_f == ev_s
    if mode == "semantic":
        # the fused path actually ran (its ledgers moved)
        snap = cache.metrics_snapshot()
        ledger = snap["prune"] if pruned else snap["quant"]
        assert ledger["scans"] > 0


@pytest.mark.parametrize("pruned,quant", [
    (True, False), (False, True), (True, True)])
def test_fused_decisions_match_numpy(pruned, quant):
    ev_f, _ = _events("kernel", pruned and {"fused": True},
                      quant and {"fused": True})
    ev_n, _ = _events("numpy", pruned, quant)
    assert _decisions(ev_f) == _decisions(ev_n)


def test_fused_pallas_kernel_parity():
    """One pallas-engine combo (interpret mode on CPU): fused == staged
    with the real kernel bodies, not just the jnp oracles."""
    ev_f, _ = _events("kernel", {"fused": True}, {"fused": True},
                      use_pallas=True)
    ev_s, _ = _events("kernel", {"fused": False}, {"fused": False},
                      use_pallas=True)
    assert ev_f == ev_s


@pytest.mark.parametrize("n_shards", [1, 2])
def test_sharded_fused_decision_parity(n_shards):
    """The sharded backend's unbound delegation reaches the fused path
    (same mirrors, sharded exact fallback) and keeps decision parity
    with its own staged driver and the numpy oracle."""
    ev_f, cache = _events("sharded", {"fused": True}, False,
                          n_shards=n_shards)
    ev_s, _ = _events("sharded", {"fused": False}, False,
                      n_shards=n_shards)
    ev_n, _ = _events("numpy", True, False)
    assert _decisions(ev_f) == _decisions(ev_s)
    assert _decisions(ev_f) == _decisions(ev_n)
    assert cache.metrics_snapshot()["prune"]["scans"] > 0


def test_arena_fused_parity():
    from repro.core import default_factories
    from repro.core.arena import run_arena
    from repro.core.types import Request, Trace
    reqs = [Request(t=i, cid=cid, emb=emb)
            for i, (cid, emb) in enumerate(_workload(n=200))]
    trace = Trace(requests=reqs)
    allf = default_factories()
    facs = {"LRU": allf["LRU"], "RAC": allf["RAC"]}
    kw = dict(hit_mode="semantic", tau_hit=0.80, backend="kernel",
              use_pallas=False, seed=0)
    key = lambda st: [(s.policy, s.hits, s.misses, s.evictions)
                      for s in st]
    st_f = run_arena(trace, 24, facs, pruned={"fused": True}, **kw)
    st_s = run_arena(trace, 24, facs, pruned={"fused": False}, **kw)
    assert key(st_f) == key(st_s)


# ------------------------------------------------------ compile stability
def test_fused_compile_stability():
    """Steady-state replay (full store, fixed batch bucket) reuses ONE
    executable per fused entry point — no per-chunk recompiles."""
    reqs = _workload(n=260)
    cfg = CacheConfig(capacity=40, dim=32, tau_hit=0.80,
                      hit_mode="semantic", backend="kernel",
                      pruned_lookup={"fused": True},
                      quantized_lookup={"fused": True},
                      use_pallas=False)
    cache = SemanticCache(cfg)
    for cid, emb in reqs[:60]:               # warm: fill + first buckets
        if not cache.lookup(emb).hit:
            cache.admit(cid, emb)
    before = fused.compile_counts()
    for cid, emb in reqs[60:]:
        if not cache.lookup(emb).hit:
            cache.admit(cid, emb)
    assert fused.compile_counts() == before


# ------------------------------------------------------ probe-cap account
def test_probe_cap_fused_staged_parity():
    """A tight scan budget truncates the probe list identically on both
    drivers (device cumulative-count prefix == host searchsorted), shows
    up in the ``capped`` ledger, and decisions stay exact."""
    tight = {"probes": 8, "max_scan_frac": 0.05, "min_scan_rows": 1}
    ev_f, cache_f = _events("kernel", dict(tight, fused=True), False)
    ev_s, cache_s = _events("kernel", dict(tight, fused=False), False)
    ev_x, _ = _events("kernel", False, False)
    assert ev_f == ev_s
    assert _decisions(ev_f) == _decisions(ev_x)
    capped_f = cache_f.backend.prune_stats["capped"]
    capped_s = cache_s.backend.prune_stats["capped"]
    assert capped_f > 0
    assert capped_f == capped_s


def test_uncapped_budget_keeps_small_stores_whole():
    """The min_scan_rows floor keeps the default budget above small
    stores, so the cap never truncates them (no behavior drift for the
    existing test workloads)."""
    ev_f, cache = _events("kernel", {"fused": True}, False)
    assert cache.backend.prune_stats["capped"] == 0
    assert fused.candidate_cap(np.array([4, 2, 3]), 2, 2, 256) >= 9


# ------------------------------------------------------- dispatch ledger
def test_dispatch_ledger_in_snapshot():
    ev, cache = _events("kernel", {"fused": True}, False, reqs=_workload(n=24))
    snap = cache.metrics_snapshot()
    assert set(snap["dispatch"]) == {"launches", "host_syncs", "kernel_s"}
    assert snap["dispatch"]["launches"] > 0
    assert snap["dispatch"]["host_syncs"] > 0
    assert snap["dispatch"]["kernel_s"] >= 0.0
    # host backend: the ledger is present and inert
    _, host = _events("numpy", True, False, reqs=_workload(n=8))
    host_snap = host.metrics_snapshot()
    assert set(host_snap["dispatch"]) == {"launches", "host_syncs",
                                          "kernel_s"}


def test_fused_launch_count_per_lookup():
    """Steady-state fused pruned lookups cost ONE fused launch each (the
    decide path adds one aux launch; this test drives lookup() directly)."""
    from repro.kernels import ops
    reqs = _workload(n=120)
    cfg = CacheConfig(capacity=40, dim=32, tau_hit=0.80,
                      hit_mode="semantic", backend="kernel",
                      pruned_lookup={"fused": True}, use_pallas=False)
    cache = SemanticCache(cfg)
    for cid, emb in reqs[:80]:
        if not cache.lookup(emb).hit:
            cache.admit(cid, emb)
    fb0 = fused.fused_stats["fallback_rows"]
    base = ops.dispatch_stats["launches"]
    hits = 0
    for cid, emb in reqs[80:]:
        if cache.lookup(emb).hit:
            hits += 1
    n, fb = 40, fused.fused_stats["fallback_rows"] - fb0
    assert hits > 0
    # one fused launch per lookup; each uncertified row may add exact-
    # fallback launches, so bound with the observed fallback count
    assert ops.dispatch_stats["launches"] - base <= n + 2 * fb
