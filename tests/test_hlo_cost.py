"""HLO cost-model unit tests beyond the calibration in test_dryrun:
dynamic-slice/update accounting, fused-region boundaries, sharding-plan
shape-kind rules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import HloCostModel, analyze
from repro.launch.mesh import abstract_mesh


def test_dynamic_slice_counts_slice_not_operand():
    """A scan slicing a big stacked array must bill slice-sized traffic."""
    big = jax.ShapeDtypeStruct((64, 256, 256), jnp.float32)   # 16 MB

    def f(stack):
        def body(c, x):
            return c + x.sum(), None
        out, _ = jax.lax.scan(body, 0.0, stack)
        return out

    c = jax.jit(f).lower(big).compile()
    r = analyze(c.as_text())
    # naive operand counting would bill 64 × 16 MB ≈ 1 GB; slice-sized
    # accounting stays within ~4× of one pass over the data
    assert r["hbm_bytes"] < 4 * 64 * 256 * 256 * 4


def test_fused_attn_region_excludes_interior():
    """Score tiles inside the named region don't hit the memory term."""
    from repro.models import layers as L
    b, s, h, d = 1, 512, 4, 128
    Q = jax.ShapeDtypeStruct((b, s, h, d), jnp.float32)

    def attn(q):
        return L.sdpa(q, q, q, causal=True)

    c = jax.jit(attn).lower(Q).compile()
    r = analyze(c.as_text())
    qkv_bytes = 3 * b * s * h * d * 4
    score_bytes = b * h * s * s * 4
    # interior (score) traffic excluded: total well below one score pass
    assert r["hbm_bytes"] < qkv_bytes * 12 + score_bytes * 0.5
    # flops still counted (scores + out ≈ 4·b·h·s²·d, ±mask/softmax)
    assert r["flops"] >= 2 * 2 * b * h * s * s * d * 0.9


def test_sharding_plan_kind_rules():
    from repro.configs import get_config
    from repro.distributed.sharding import ShardingPlan
    mesh = abstract_mesh((16, 16), ("data", "model"))
    gemma = get_config("gemma-7b")          # 8.5B
    qwen = get_config("qwen1.5-110b")       # 111B
    small = get_config("smollm-360m")
    assert ShardingPlan.for_mesh(mesh, gemma, "train").fsdp
    assert not ShardingPlan.for_mesh(mesh, gemma, "decode").fsdp
    p = ShardingPlan.for_mesh(mesh, qwen, "decode")
    assert p.fsdp and p.decode_2d
    assert not ShardingPlan.for_mesh(mesh, small, "train").fsdp


def test_collective_ring_factors():
    from repro.launch.hlo_cost import HloCostModel
    hlo = """
HloModule test

ENTRY %main (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  ROOT %ar = f32[64]{0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    m = HloCostModel(hlo)
    fl, cb, hb = m.entry_cost()
    # ring all-reduce: 2·b·(n-1)/n = 2·256·3/4 = 384
    assert cb == 2 * 64 * 4 * 3 / 4
