"""Simulator-level invariants promised by core/simulator.py: the two hit
modes agree under the synthetic embedding geometry, and the batched replay
is EXACT — bit-identical hit/miss/eviction counts to the one-at-a-time
replayer across hit modes, chunk sizes, and backends."""
import numpy as np
import pytest

from repro.core import (SynthConfig, run_many, run_policy,
                        run_policy_batched, synthetic_trace)
from repro.core.policies import LRUPolicy
from repro.core.rac import make_rac


@pytest.fixture(scope="module")
def trace():
    return synthetic_trace(SynthConfig(trace_len=1500, seed=8))


@pytest.fixture(scope="module")
def trace_short():
    return synthetic_trace(SynthConfig(trace_len=600, seed=3))


def test_content_semantic_hit_mode_agreement(trace):
    """Content (cid residency) and semantic (Top-1 cosine >= tau_hit) hit
    determination agree: paraphrase sim ~0.93 clears tau_hit=0.85 while
    distinct in-topic content stays ~0.72 below it (core/embeddings.py)."""
    cap = 150
    for factory in (make_rac(), lambda c, st: LRUPolicy(c, st)):
        s_content = run_policy(trace, cap, factory, hit_mode="content")
        s_sem = run_policy(trace, cap, factory, hit_mode="semantic",
                           tau_hit=0.85)
        # identical up to rare borderline-similarity flips
        assert abs(s_content.hits - s_sem.hits) <= 0.02 * len(trace.requests)
        assert s_content.hits + s_content.misses == len(trace.requests)
        assert s_sem.hits + s_sem.misses == len(trace.requests)


def test_batched_chunk1_is_exact(trace):
    """chunk=1 degenerates to the one-at-a-time replayer bit-for-bit."""
    s_exact = run_policy(trace, 100, lambda c, st: LRUPolicy(c, st),
                         hit_mode="semantic")
    s_b1 = run_policy_batched(trace, 100, lambda c, st: LRUPolicy(c, st),
                              hit_mode="semantic", chunk=1)
    assert (s_b1.hits, s_b1.misses, s_b1.evictions) == \
           (s_exact.hits, s_exact.misses, s_exact.evictions)


def test_batched_large_chunk_exact(trace):
    """The incremental rescore closes the historical snapshot gap: a large
    chunk is bit-identical to exact replay, not merely close."""
    s_exact = run_policy(trace, 100, make_rac(), hit_mode="semantic")
    s_b = run_policy_batched(trace, 100, make_rac(), hit_mode="semantic",
                             chunk=128)
    assert (s_b.hits, s_b.misses, s_b.evictions) == \
           (s_exact.hits, s_exact.misses, s_exact.evictions)


# --------------------------------------------------- exact-replay matrix
@pytest.fixture(scope="module")
def exact_ref(trace_short):
    """run_policy reference counts, cached per (backend, hit_mode)."""
    memo = {}

    def get(backend, hit_mode):
        key = (backend, hit_mode)
        if key not in memo:
            s = run_policy(trace_short, 60, make_rac(), hit_mode=hit_mode,
                           backend=backend, use_pallas=False)
            memo[key] = (s.hits, s.misses, s.evictions)
        return memo[key]

    return get


@pytest.mark.parametrize("backend", ["numpy", "kernel", "sharded"])
@pytest.mark.parametrize("hit_mode", ["content", "semantic"])
@pytest.mark.parametrize("chunk", [1, 7, 512])
def test_batched_replay_exact_matrix(trace_short, exact_ref, backend,
                                     hit_mode, chunk):
    """The PR acceptance matrix: run_policy_batched is bit-identical to
    run_policy across hit modes x chunk sizes x backends (RAC policy —
    eviction trajectories must agree too, not just hits)."""
    ref = exact_ref(backend, hit_mode)
    s = run_policy_batched(trace_short, 60, make_rac(), hit_mode=hit_mode,
                           backend=backend, chunk=chunk, use_pallas=False)
    assert (s.hits, s.misses, s.evictions) == ref
    assert s.hits + s.misses == len(trace_short.requests)


def test_batched_content_mode_delegates(trace):
    s_exact = run_policy(trace, 100, lambda c, st: LRUPolicy(c, st),
                         hit_mode="content")
    s_b = run_policy_batched(trace, 100, lambda c, st: LRUPolicy(c, st),
                             hit_mode="content", chunk=64)
    assert (s_b.hits, s_b.misses, s_b.evictions) == \
           (s_exact.hits, s_exact.misses, s_exact.evictions)


def test_run_many_forwards_batched(trace_short):
    """run_many(batched=True) routes through run_policy_batched (and
    forwards chunk=); with the exact replay the counts match run_policy."""
    facs = {"LRU": lambda c, st: LRUPolicy(c, st), "RAC": make_rac()}
    plain = run_many(trace_short, 60, facs, hit_mode="semantic")
    batched = run_many(trace_short, 60, facs, batched=True,
                       hit_mode="semantic", chunk=64)
    for a, b in zip(plain, batched):
        assert (a.hits, a.misses, a.evictions) == \
               (b.hits, b.misses, b.evictions)
