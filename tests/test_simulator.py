"""Simulator-level invariants promised by core/simulator.py: the two hit
modes agree under the synthetic embedding geometry, and the batched fast
path matches the exact replayer."""
import numpy as np
import pytest

from repro.core import (SynthConfig, run_policy, run_policy_batched,
                        synthetic_trace)
from repro.core.policies import LRUPolicy
from repro.core.rac import make_rac


@pytest.fixture(scope="module")
def trace():
    return synthetic_trace(SynthConfig(trace_len=1500, seed=8))


def test_content_semantic_hit_mode_agreement(trace):
    """Content (cid residency) and semantic (Top-1 cosine >= tau_hit) hit
    determination agree: paraphrase sim ~0.93 clears tau_hit=0.85 while
    distinct in-topic content stays ~0.72 below it (core/embeddings.py)."""
    cap = 150
    for factory in (make_rac(), lambda c, st: LRUPolicy(c, st)):
        s_content = run_policy(trace, cap, factory, hit_mode="content")
        s_sem = run_policy(trace, cap, factory, hit_mode="semantic",
                           tau_hit=0.85)
        # identical up to rare borderline-similarity flips
        assert abs(s_content.hits - s_sem.hits) <= 0.02 * len(trace.requests)
        assert s_content.hits + s_content.misses == len(trace.requests)
        assert s_sem.hits + s_sem.misses == len(trace.requests)


def test_batched_chunk1_is_exact(trace):
    """chunk=1 degenerates to the one-at-a-time replayer bit-for-bit."""
    s_exact = run_policy(trace, 100, lambda c, st: LRUPolicy(c, st),
                         hit_mode="semantic")
    s_b1 = run_policy_batched(trace, 100, lambda c, st: LRUPolicy(c, st),
                              hit_mode="semantic", chunk=1)
    assert (s_b1.hits, s_b1.misses, s_b1.evictions) == \
           (s_exact.hits, s_exact.misses, s_exact.evictions)


def test_batched_large_chunk_close(trace):
    """Snapshot batching only misses same-chunk admissions: the hit ratio
    stays close to exact replay and capacity is never violated."""
    s_exact = run_policy(trace, 100, make_rac(), hit_mode="semantic")
    s_b = run_policy_batched(trace, 100, make_rac(), hit_mode="semantic",
                             chunk=128)
    assert s_b.hits + s_b.misses == len(trace.requests)
    assert abs(s_b.hit_ratio - s_exact.hit_ratio) < 0.1


def test_batched_content_mode_delegates(trace):
    s_exact = run_policy(trace, 100, lambda c, st: LRUPolicy(c, st),
                         hit_mode="content")
    s_b = run_policy_batched(trace, 100, lambda c, st: LRUPolicy(c, st),
                             hit_mode="content", chunk=64)
    assert (s_b.hits, s_b.misses, s_b.evictions) == \
           (s_exact.hits, s_exact.misses, s_exact.evictions)
