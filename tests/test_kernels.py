"""Per-kernel shape/dtype sweeps, asserting allclose against the pure-jnp
oracles in kernels/ref.py (Pallas executed in interpret mode on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("q_n,c_n,d", [(1, 1, 32), (7, 100, 64),
                                       (37, 901, 64), (128, 512, 128),
                                       (130, 1500, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sim_top1(rng, q_n, c_n, d, dtype):
    q = jnp.asarray(rng.standard_normal((q_n, d)), dtype)
    c = jnp.asarray(rng.standard_normal((c_n, d)), dtype)
    v1, i1 = ops.sim_top1(q, c)
    v2, i2 = ref.sim_top1_ref(q.astype(jnp.float32), c.astype(jnp.float32),
                              c_n)
    np.testing.assert_allclose(v1, v2, atol=2e-2 if dtype == jnp.bfloat16
                               else 1e-4)
    # indices must agree except where scores tie within tolerance
    diff = np.asarray(i1) != np.asarray(i2)
    if diff.any():
        np.testing.assert_allclose(np.asarray(v1)[diff], np.asarray(v2)[diff],
                                   atol=2e-2)


@pytest.mark.parametrize("n_valid", [0, 1, 3, 700, 901])
def test_sim_top1_dynamic_n_valid(rng, n_valid):
    """The resident count is a runtime scalar: one jitted callable serves
    every fill level, masking the candidate tail past ``n_valid``."""
    q = jnp.asarray(rng.standard_normal((37, 64)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((901, 64)), jnp.float32)
    v1, i1 = ops.sim_top1(q, c, n_valid=n_valid)
    v2, i2 = ref.sim_top1_ref(q, c, n_valid)
    if n_valid == 0:
        assert np.all(np.asarray(v1) == -np.inf)
        return
    np.testing.assert_allclose(v1, v2, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    assert np.asarray(i1).max() < n_valid       # free tail never wins


def test_sim_top1_n_valid_no_recompile(rng):
    """Varying n_valid must not recompile (it is traced, not static)."""
    q = jnp.asarray(rng.standard_normal((8, 64)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((512, 64)), jnp.float32)
    ops.sim_top1(q, c, n_valid=512)
    from repro.kernels.ops import _sim_top1_jit
    sizes0 = _sim_top1_jit._cache_size()
    for nv in (1, 5, 200, 511):
        ops.sim_top1(q, c, n_valid=nv)
    assert _sim_top1_jit._cache_size() == sizes0


@pytest.mark.parametrize("k", [1, 4, 16])
@pytest.mark.parametrize("q_n,c_n,d", [(1, 64, 32), (7, 100, 64),
                                       (37, 901, 64), (128, 512, 128)])
def test_sim_topk(rng, q_n, c_n, d, k):
    """Top-K retrieval (Pallas interpret mode) matches the lax.top_k oracle:
    descending scores, ties broken toward the lower candidate index."""
    q = jnp.asarray(rng.standard_normal((q_n, d)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((c_n, d)), jnp.float32)
    v1, i1 = ops.sim_topk(q, c, k)
    v2, i2 = ref.sim_topk_ref(q, c, c_n, k)
    np.testing.assert_allclose(v1, v2, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    # ranks are strictly ordered per row
    vals = np.asarray(v1)
    assert (np.diff(vals, axis=1) <= 1e-6).all()


@pytest.mark.parametrize("n_valid", [0, 1, 3, 97, 100])
def test_sim_topk_dynamic_n_valid(rng, n_valid):
    """Runtime resident count masks the tail; ranks past the restriction
    come back as -inf with index 0 (callers map them to cid -1)."""
    k = 8
    q = jnp.asarray(rng.standard_normal((5, 64)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((100, 64)), jnp.float32)
    v1, i1 = ops.sim_topk(q, c, k, n_valid=n_valid)
    if n_valid == 0:
        assert np.all(np.asarray(v1) == -np.inf)
        return
    v2, i2 = ref.sim_topk_ref(q, c, n_valid, k)
    np.testing.assert_allclose(v1, v2, atol=1e-4)
    live = np.asarray(v2) > -np.inf
    np.testing.assert_array_equal(np.asarray(i1)[live], np.asarray(i2)[live])
    assert np.asarray(i1)[live].max() < n_valid     # free tail never ranks


def test_sim_topk_ties_break_low(rng):
    """Duplicate candidates: every rank is filled and ties resolve toward
    the lower candidate index, matching the host-side stable argsort."""
    q = jnp.asarray(rng.standard_normal((3, 64)), jnp.float32)
    row = rng.standard_normal((1, 64)).astype(np.float32)
    c = jnp.asarray(np.repeat(row, 16, axis=0), jnp.float32)
    v, i = ops.sim_topk(q, c, 4)
    np.testing.assert_array_equal(np.asarray(i),
                                  np.tile(np.arange(4), (3, 1)))
    np.testing.assert_allclose(np.asarray(v),
                               np.repeat(np.asarray(v)[:, :1], 4, axis=1),
                               atol=1e-6)


@pytest.mark.parametrize("backend_name", ["numpy", "kernel", "sharded"])
@pytest.mark.parametrize("k", [1, 4, 16])
def test_backend_topk_rows_parity(rng, backend_name, k):
    """`topk_rows` through every backend agrees with the numpy oracle on a
    row-restricted store scan (descending, ties to lower row position,
    ranks past the restriction = (-1, -inf))."""
    from repro.cache import get_backend
    from repro.cache.backends import NumpyBackend
    from repro.core.store import ResidentStore

    store = ResidentStore(24, 64)
    for cid in range(18):
        e = rng.standard_normal(64).astype(np.float32)
        store.insert(cid, e / np.linalg.norm(e))
    rows = [store.slot_of[c] for c in (0, 3, 5, 7, 11, 16)]
    q = rng.standard_normal((9, 64)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    oc, os_ = NumpyBackend().topk_rows(store, q, rows, k)
    bc, bs = get_backend(backend_name).topk_rows(store, q, rows, k)
    assert bc.shape == bs.shape == (9, k)
    np.testing.assert_allclose(bs, os_, atol=1e-4)
    np.testing.assert_array_equal(bc, oc)
    if k > len(rows):                       # tail ranks are sentinels
        assert (bc[:, len(rows):] == -1).all()
        assert np.isneginf(bs[:, len(rows):]).all()


@pytest.mark.parametrize("b,h,hkv,s,d", [(1, 2, 1, 64, 128),
                                         (2, 4, 2, 200, 128),
                                         (1, 8, 2, 300, 128),
                                         (2, 2, 2, 513, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(rng, b, h, hkv, s, d, dtype):
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), dtype)
    o1 = ops.flash_attention(q, k, v)
    o2 = ref.attention_ref(q, k, v)
    atol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=atol)


@pytest.mark.parametrize("b,h,hkv,s,d", [(1, 2, 1, 128, 128),
                                         (2, 4, 2, 1024, 128),
                                         (2, 8, 2, 768, 128),
                                         (3, 4, 4, 257, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(rng, b, h, hkv, s, d, dtype):
    q = jnp.asarray(rng.standard_normal((b, h, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    pos = jnp.asarray(rng.integers(0, s, size=b), jnp.int32)
    o1 = ops.decode_attention(q, k, v, pos)
    o2 = ref.decode_attention_ref(q, k, v, pos)
    atol = 3e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), atol=atol)


@pytest.mark.parametrize("n,t", [(1, 1), (777, 33), (1024, 4), (2049, 100)])
def test_rac_value(rng, n, t):
    tsi = jnp.asarray(rng.random(n), jnp.float32)
    tid = jnp.asarray(rng.integers(0, t, n), jnp.int32)
    tp = jnp.asarray(rng.random(t) * 10, jnp.float32)
    tl = jnp.asarray(rng.integers(0, 1000, t), jnp.int32)
    r1 = ops.rac_value(tsi, tid, tp, tl, 0.001, 1500)
    r2 = ref.rac_value_ref(tsi, tid, tp, tl, 0.001, 1500)
    np.testing.assert_allclose(r1, r2, atol=1e-5)


def test_rac_value_matches_policy_scoring(rng):
    """Device-side Eq.1 kernel agrees with the host policy's value_scores
    (paper mode, no normalization)."""
    from repro.core import EmbeddingSpace, Request
    from repro.core.rac import RACPolicy
    from repro.core.store import ResidentStore

    store = ResidentStore(32, 16)
    pol = RACPolicy(32, store, value_mode="paper", tau_route=0.3)
    space = EmbeddingSpace(dim=16, seed=0)
    for t in range(40):
        cid = int(rng.integers(0, 24))
        emb = space.content_embedding(cid % 3, cid).astype(np.float32)
        req = Request(t=t, cid=cid, emb=emb)
        if cid in store:
            pol.on_hit(cid, req, t)
        else:
            store.insert(cid, emb)
            pol.on_admit(cid, req, t)
            while len(store) > 32:
                store.remove(pol.victim(t))
    t_now = 50
    cids, host_vals = pol.value_scores(t_now)
    slots = np.array([store.slot_of[int(c)] for c in cids])
    tids = pol.topic_of[slots]
    dev_vals = ops.rac_value(
        jnp.asarray(pol.tsi[slots], jnp.float32),
        jnp.asarray(tids, jnp.int32),
        jnp.asarray(pol.tp_last[:pol._next_tid + 1], jnp.float32),
        jnp.asarray(pol.t_last[:pol._next_tid + 1], jnp.int32),
        pol.alpha, t_now)
    np.testing.assert_allclose(np.asarray(dev_vals), host_vals, rtol=1e-5)


@pytest.mark.parametrize("n,t", [(1, 1), (777, 33), (1024, 4), (2049, 100)])
def test_victim_value(rng, n, t):
    """The decision kernel (Pallas, interpret mode on CPU): occupancy-
    masked Eq.1 with a runtime t_now matches the jnp oracle, including
    free slots (tid -1 -> +inf) and t_now varying without re-dispatchable
    shape changes."""
    tsi = jnp.asarray(rng.random(n), jnp.float32)
    tid = jnp.asarray(rng.integers(-1, t, n), jnp.int32)
    occ = jnp.asarray(rng.integers(0, 2, n), jnp.int32)
    tp = jnp.asarray(rng.random(t) * 10, jnp.float32)
    tl = jnp.asarray(rng.integers(0, 1000, t), jnp.int32)
    for t_now in (1500, 2600):
        r1 = ops.victim_value(tsi, tid, occ, tp, tl, t_now, alpha=0.001)
        r2 = ref.victim_value_ref(tsi, tid, occ, tp, tl, t_now, 0.001)
        np.testing.assert_allclose(r1, r2, atol=1e-5)
        free = ~np.asarray(occ, dtype=bool)
        assert np.isinf(np.asarray(r1)[free]).all()


def test_victim_value_large_timestamps(rng):
    """Absolute clocks past float32's 2^24 integer range must not skew the
    decay: the kernel subtracts in int32 before casting the age."""
    base = 1 << 25
    tsi = jnp.ones(64, jnp.float32)
    tid = jnp.zeros(64, jnp.int32)
    occ = jnp.ones(64, jnp.int32)
    tp = jnp.asarray([2.0], jnp.float32)
    tl = jnp.asarray([base + 1], jnp.int32)          # age = 9 at t_now
    r = ops.victim_value(tsi, tid, occ, tp, tl, base + 10, alpha=0.1)
    np.testing.assert_allclose(r, 2.0 * 0.5 ** (0.1 * 9), rtol=1e-5)


def test_fused_decide_composes_the_three_legs(rng):
    """One fused dispatch (Pallas interpret mode) returns exactly what the
    three oracle legs return: hit top-1, routing top-1, victim values."""
    q = jnp.asarray(rng.standard_normal((13, 64)), jnp.float32)
    slab = jnp.asarray(rng.standard_normal((300, 64)), jnp.float32)
    reps = jnp.asarray(rng.standard_normal((40, 64)), jnp.float32)
    tsi = jnp.asarray(rng.random(300), jnp.float32)
    tid = jnp.asarray(rng.integers(-1, 40, 300), jnp.int32)
    occ = jnp.asarray(rng.integers(0, 2, 300), jnp.int32)
    tp = jnp.asarray(rng.random(40) * 5, jnp.float32)
    tl = jnp.asarray(rng.integers(0, 500, 40), jnp.int32)
    hv, hi, rv, ri, vv = ops.fused_decide(q, slab, 260, reps, 40, tsi, tid,
                                          occ, tp, tl, 700, alpha=0.001)
    ev, ei = ref.sim_top1_ref(q, slab, 260)
    np.testing.assert_allclose(hv, ev, atol=1e-4)
    np.testing.assert_array_equal(hi, ei)
    ev, ei = ref.sim_top1_ref(q, reps, 40)
    np.testing.assert_allclose(rv, ev, atol=1e-4)
    np.testing.assert_array_equal(ri, ei)
    np.testing.assert_allclose(
        vv, ref.victim_value_ref(tsi, tid, occ, tp, tl, 700, 0.001),
        atol=1e-5)


def test_sim_top1_multi_matches_per_policy(rng):
    """The policy-stacked Top-1 (one dispatch, per-policy runtime n_valid)
    equals P independent sim_top1 launches, on both engine paths."""
    P, N, D, B = 3, 512, 128, 16
    slabs = jnp.asarray(rng.standard_normal((P, N, D)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)
    nv = np.array([100, 512, 1], dtype=np.int32)
    for use_pallas in (False, True):
        vals, idx = ops.sim_top1_multi(q, slabs, nv, use_pallas=use_pallas)
        assert vals.shape == idx.shape == (P, B)
        for p in range(P):
            v1, i1 = ops.sim_top1(q, slabs[p], n_valid=int(nv[p]),
                                  use_pallas=use_pallas)
            np.testing.assert_array_equal(np.asarray(i1), np.asarray(idx)[p])
            np.testing.assert_allclose(np.asarray(v1), np.asarray(vals)[p],
                                       atol=1e-5)
        # n_valid masks each slab's tail independently
        assert (np.asarray(idx)[2] == 0).all()


def test_victim_value_multi_matches_per_policy(rng):
    """Stacked occupancy-masked Eq.1 equals P independent victim_value
    launches (per-policy topic tables, shared clock)."""
    P, N, T = 3, 2048, 32
    tsi = jnp.asarray(rng.random((P, N)), jnp.float32)
    tid = jnp.asarray(rng.integers(-1, T, (P, N)), jnp.int32)
    occ = jnp.asarray(rng.integers(0, 2, (P, N)), jnp.int32)
    tp = jnp.asarray(rng.random((P, T)) * 5, jnp.float32)
    tl = jnp.asarray(rng.integers(0, 500, (P, T)), jnp.int32)
    for use_pallas in (False, True):
        vv = ops.victim_value_multi(tsi, tid, occ, tp, tl, 700, alpha=0.01,
                                    use_pallas=use_pallas)
        assert vv.shape == (P, N)
        for p in range(P):
            v1 = ops.victim_value(tsi[p], tid[p], occ[p], tp[p], tl[p],
                                  700, alpha=0.01, use_pallas=use_pallas)
            np.testing.assert_allclose(np.asarray(v1), np.asarray(vv)[p],
                                       atol=1e-5)
        free = ~np.asarray(occ, dtype=bool)
        assert np.isinf(np.asarray(vv)[free]).all()
