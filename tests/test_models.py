"""Per-architecture smoke tests (reduced same-family configs) + decode/
forward parity (KV-cache correctness) + one train step (finite loss/grads).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (build_model, make_train_step, smoke_variant)
from repro.optim import AdamWConfig, adamw_init

RNG = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=16):
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(2, cfg.vocab_size, (b, s)),
        jnp.int32)}
    if cfg.frontend == "audio":
        batch["audio_embeds"] = 0.1 * jnp.ones(
            (b, cfg.n_frontend_tokens, cfg.d_model), cfg.cdtype)
    if cfg.frontend == "vision":
        batch["image_embeds"] = 0.1 * jnp.ones(
            (b, cfg.n_frontend_tokens, cfg.d_model), cfg.cdtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_variant(get_config(arch))
    model = build_model(cfg)
    params = model.init(RNG)
    batch = _batch(cfg)
    logits = model.forward(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_finite(arch):
    cfg = smoke_variant(get_config(arch))
    model = build_model(cfg)
    params = model.init(RNG)
    batch = _batch(cfg)
    batch["labels"] = batch["tokens"]
    step = make_train_step(model, AdamWConfig(lr=1e-3))
    params2, opt2, metrics = step(params, adamw_init(params), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, params2)
    assert max(jax.tree.leaves(d)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Teacher-forcing parity: decoding token-by-token through the cache
    must reproduce the full-sequence forward logits (validates every cache
    layout: linear KV, MLA compressed, ring window, SSM/xLSTM states)."""
    cfg = smoke_variant(get_config(arch))
    model = build_model(cfg)
    params = model.init(RNG)
    b, s = 2, 12
    batch = _batch(cfg, b, s)
    if cfg.family == "vlm":
        # stand in the token embeddings as "image" embeds so the decode
        # stream (tokens only) is information-identical to the forward
        from repro.models.model import embed
        batch["image_embeds"] = embed(params["emb"], cfg,
                                      batch["tokens"][:, :cfg.n_frontend_tokens])
    full = model.forward(params, batch)      # (B,S,V)

    cache = model.init_cache(b, s + 4)
    errs = []
    for pos in range(s):
        dbatch = {"tokens": batch["tokens"][:, pos:pos + 1],
                  "pos": jnp.full((b,), pos, jnp.int32)}
        if cfg.frontend == "audio":
            # decode consumes the cached encoder output
            enc = model._encode(params, batch["audio_embeds"])
            dbatch["enc_out"] = enc
        logits, cache = model.decode_step(params, cache, dbatch)
        errs.append(float(jnp.abs(
            logits - full[:, pos]).max()))
    tail = errs
    assert max(tail) < (2e-1 if cfg.family in ("ssm", "hybrid") else 5e-2), \
        f"decode/forward divergence {max(tail)} (per-pos {tail})"


def test_moe_routing_conserves_tokens():
    """Capacity-factor dispatch: with ample capacity every token's top-k
    mass is preserved (combine weights sum to 1 per token)."""
    from repro.models.layers import init_moe, moe_apply
    cfg = dataclasses.replace(
        smoke_variant(get_config("deepseek-v2-lite-16b")),
        capacity_factor=8.0)
    p = init_moe(cfg, RNG)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          cfg.cdtype)
    y = moe_apply(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    # zero input -> zero output (router softmax over zeros is uniform but
    # expert MLPs map 0 -> 0 without biases)
    y0 = moe_apply(p, cfg, jnp.zeros_like(x))
    np.testing.assert_allclose(np.asarray(y0, np.float32), 0.0, atol=1e-5)


def test_sliding_window_masks_distant_tokens():
    from repro.models import layers as L
    b, h, s, d = 1, 2, 64, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))
    out_w = L.sdpa(q, k, v, causal=True, window=8)
    # perturb a token far outside every later query's window
    k2 = k.at[:, 0].add(10.0)
    v2 = v.at[:, 0].add(10.0)
    out_w2 = L.sdpa(q, k2, v2, causal=True, window=8)
    np.testing.assert_allclose(np.asarray(out_w[:, 16:]),
                               np.asarray(out_w2[:, 16:]), atol=1e-5)


def test_scan_equals_unrolled_stack():
    cfg = smoke_variant(get_config("gemma-7b"))
    cfg_scan = dataclasses.replace(cfg, scan_layers=True)
    model_u = build_model(cfg)
    model_s = build_model(cfg_scan)
    params_u = model_u.init(RNG)
    # restack the unrolled params for the scanned model
    import jax.tree_util as jtu
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *params_u["blocks"])
    params_s = {"emb": params_u["emb"], "blocks": stacked}
    batch = _batch(cfg)
    lu = model_u.forward(params_u, batch)
    ls = model_s.forward(params_s, batch)
    np.testing.assert_allclose(np.asarray(lu, np.float32),
                               np.asarray(ls, np.float32), atol=2e-4)
