"""Telemetry subsystem: metric primitives, tracker sinks/scoping, the
observation-only (bit-exact decision parity) contract across backends and
hit modes, hook-failure containment, and the consolidated
``metrics_snapshot`` surface."""
import copy
import json
import math

import numpy as np
import pytest

from repro.cache import CacheConfig, SemanticCache
from repro.core import EmbeddingSpace, SynthConfig, synthetic_trace
from repro.telemetry import (NOOP, CompositeTracker, Histogram,
                             InMemoryTracker, JsonlTracker, MetricsRegistry,
                             NoopTracker, WindowedSeries, make_tracker,
                             render_text, summarize)


# ------------------------------------------------------- metric primitives
def test_histogram_quantiles_within_bucket_error():
    h = Histogram()
    rng = np.random.default_rng(0)
    vals = rng.lognormal(mean=-7, sigma=1.0, size=5000)
    for v in vals:
        h.observe(float(v))
    for q, true in ((0.5, np.quantile(vals, 0.5)),
                    (0.95, np.quantile(vals, 0.95)),
                    (0.99, np.quantile(vals, 0.99))):
        est = h.quantile(q)
        # log-bucket growth 2**0.25 -> <= ~9% relative bucket error
        assert abs(est - true) / true < 0.12, (q, est, true)
    assert h.count == 5000
    assert math.isclose(h.mean, float(np.mean(vals)), rel_tol=1e-9)
    p = h.percentiles()
    assert set(p) == {"p50", "p95", "p99"} and p["p50"] <= p["p99"]


def test_histogram_merge_equals_single_pass():
    a, b, both = Histogram(), Histogram(), Histogram()
    rng = np.random.default_rng(1)
    for i, v in enumerate(rng.exponential(size=400)):
        (a if i % 2 else b).observe(float(v))
        both.observe(float(v))
    a.merge(b)
    assert a.count == both.count
    assert a.buckets == both.buckets
    assert a.quantile(0.5) == both.quantile(0.5)
    assert a.vmin == both.vmin and a.vmax == both.vmax


def test_histogram_zero_and_bounds():
    h = Histogram()
    for v in (0.0, 0.0, 4.0):
        h.observe(v)
    assert h.quantile(0.0) == 0.0          # zero bucket sorts first
    assert h.quantile(1.0) <= h.vmax       # clamped to observed range


def test_windowed_series_means():
    s = WindowedSeries(window=10)
    for t, v in ((0, 1.0), (3, 0.0), (9, 1.0), (10, 1.0), (25, 0.0)):
        s.add(t, v)
    pts = s.series()
    assert [p["t"] for p in pts] == [0, 10, 20]
    assert pts[0]["mean"] == pytest.approx(2 / 3)
    assert pts[0]["count"] == 3
    assert pts[1]["mean"] == 1.0 and pts[2]["mean"] == 0.0


def test_registry_merge():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.inc("n", 2)
    b.inc("n", 3)
    b.inc("only_b")
    a.observe("lat", 1.0)
    b.observe("lat", 3.0)
    b.record("hit", 5, 1.0)
    a.merge(b)
    assert a.counters["n"] == 5 and a.counters["only_b"] == 1
    assert a.histograms["lat"].count == 2
    assert a.series["hit"].series()[0]["count"] == 1
    snap = a.snapshot()
    assert snap["counters"]["n"] == 5
    assert "lat" in snap["histograms"]


# ------------------------------------------------------------ tracker sinks
def test_child_scoping_prefixes_names():
    trk = InMemoryTracker()
    trk.child("backend").count("sync.rows", 5)
    trk.child("tier").child("host").count("hits")
    assert trk.counter("backend.sync.rows") == 5
    assert trk.counter("tier.host.hits") == 1


def test_tags_fold_into_metric_name():
    trk = InMemoryTracker()
    trk.count("cache.evictions", tags={"tier": "host"})
    trk.count("cache.evictions", tags={"tier": "device"})
    assert trk.counter("cache.evictions{tier=host}") == 1
    assert trk.counter("cache.evictions{tier=device}") == 1


def test_make_tracker_specs(tmp_path):
    assert make_tracker(None) is None
    assert make_tracker("") is None
    trk = InMemoryTracker()
    assert make_tracker(trk) is trk
    assert isinstance(make_tracker("noop"), NoopTracker)
    assert isinstance(make_tracker("memory"), InMemoryTracker)
    jl = make_tracker(f"jsonl:{tmp_path / 't.jsonl'}")
    assert isinstance(jl, JsonlTracker)
    combo = make_tracker(f"memory+jsonl:{tmp_path / 'u.jsonl'}")
    assert isinstance(combo, CompositeTracker) and len(combo.parts) == 2
    with pytest.raises(ValueError):
        make_tracker("wandb")
    with pytest.raises(ValueError):
        make_tracker(123)


def test_tracker_shared_not_cloned_by_deepcopy():
    trk = InMemoryTracker()
    assert copy.deepcopy(trk) is trk
    assert copy.deepcopy(NOOP) is NOOP


def test_jsonl_round_trip(tmp_path):
    path = tmp_path / "t.jsonl"
    trk = JsonlTracker(str(path), buffer=2)
    trk.count("a", 2, tags={"x": 1})
    trk.gauge("g", 0.5)
    trk.observe("h", 1e-3, t=7)
    with trk.span("s"):
        pass
    trk.close()
    recs = [json.loads(line) for line in path.read_text().splitlines()]
    kinds = [r["kind"] for r in recs]
    assert kinds == ["count", "gauge", "observe", "span"]
    assert recs[0]["tags"] == {"x": 1}
    assert recs[2]["t"] == 7
    assert all("wall" in r for r in recs)


def test_chrome_export_is_valid(tmp_path):
    trk = InMemoryTracker()
    with trk.span("cache.decide_batch", tags={"b": 4}):
        pass
    trk.add_span("serve.request", 1.0, 1.5, track=3,
                 tags={"outcome": "hit"})
    path = tmp_path / "trace.json"
    trk.export_chrome(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert len(evs) == 2
    for e in evs:
        assert e["ph"] == "X" and e["dur"] >= 0 and "ts" in e
    names = {e["name"] for e in evs}
    assert names == {"cache.decide_batch", "serve.request"}


def test_report_render(tmp_path):
    trk = InMemoryTracker()
    trk.count("cache.evictions", 3)
    trk.gauge("cache.queue_depth", 2)
    trk.observe("cache.lookup_s", 1e-4)
    trk.observe("cache.hit", 1.0, t=10)
    txt = render_text(summarize(trk), title="t")
    assert "cache.evictions" in txt and "cache.lookup_s" in txt
    from repro.telemetry import write_report
    out = write_report(trk, str(tmp_path / "r.json"), title="t")
    doc = json.loads((tmp_path / "r.json").read_text())
    assert doc["counters"]["cache.evictions"] == 3
    assert "cache.lookup_s" in doc["histograms"]
    assert out


# --------------------------------------------- observation-only bit parity
def _replay_events(backend, hit_mode, tracker, trace, capacity,
                   use_pallas=False, n_shards=2):
    kw = {"n_shards": n_shards} if backend == "sharded" else {}
    cache = SemanticCache(CacheConfig(
        capacity=capacity, dim=trace.requests[0].emb.shape[0],
        tau_hit=0.85, hit_mode=hit_mode, backend=backend,
        use_pallas=use_pallas, backend_kwargs=kw, tracker=tracker))
    events = []
    for kind in ("hit", "miss", "admit", "evict"):
        cache.subscribe(kind, lambda ev: events.append(
            (ev.kind, ev.cid, ev.t, ev.tier)))
    for r in trace.requests:
        res = cache.lookup(r.emb, cid=r.cid, t=r.t)
        if not res.hit:
            cache.admit(r.cid, r.emb, payload=(r.cid,), t=r.t)
    counters = (cache.metrics.hits, cache.metrics.misses,
                cache.metrics.evictions, cache.metrics.admissions)
    cache.close()
    return events, counters


@pytest.fixture(scope="module")
def parity_trace():
    return synthetic_trace(SynthConfig(trace_len=300, n_topics=8,
                                       dim=16, seed=4))


@pytest.mark.parametrize("backend", ["numpy", "kernel", "sharded"])
@pytest.mark.parametrize("hit_mode", ["content", "semantic"])
def test_decisions_bit_identical_across_trackers(parity_trace, backend,
                                                 hit_mode, tmp_path):
    trackers = [None, NOOP, InMemoryTracker(),
                JsonlTracker(str(tmp_path / f"{backend}-{hit_mode}.jsonl"))]
    runs = [_replay_events(backend, hit_mode, trk, parity_trace, 24)
            for trk in trackers]
    ref_events, ref_counters = runs[0]
    assert ref_counters[2] > 0          # workload actually evicts
    for events, counters in runs[1:]:
        assert events == ref_events
        assert counters == ref_counters


def test_backend_sync_counters_flow_to_tracker(parity_trace):
    trk = InMemoryTracker()
    cache = SemanticCache(CacheConfig(
        capacity=24, dim=16, hit_mode="semantic", backend="kernel",
        use_pallas=False, tracker=trk))
    for r in parity_trace.requests[:50]:
        res = cache.lookup(r.emb, cid=r.cid, t=r.t)
        if not res.hit:
            cache.admit(r.cid, r.emb, t=r.t)
    cache.decide_batch(np.stack([r.emb for r in parity_trace.requests[:8]]))
    assert trk.counter("backend.sync.full") >= 1
    assert trk.counter("backend.sync.bytes") > 0
    snap = cache.metrics_snapshot()
    assert snap["sync"]["full"] >= 1 and snap["sync"]["bytes"] > 0


# -------------------------------------------------- hook-failure containment
def test_poisoned_hook_is_contained_and_counted():
    trk = InMemoryTracker()
    cache = SemanticCache(CacheConfig(capacity=4, dim=8,
                                      hit_mode="content", tracker=trk))

    def _boom(ev):
        raise RuntimeError("poisoned subscriber")

    cache.subscribe("admit", _boom)

    seen = []
    cache.subscribe("admit", lambda ev: seen.append(ev.cid))
    emb = np.ones(8, dtype=np.float32)
    evicted = cache.admit(1, emb)          # must not raise
    assert evicted == [] and 1 in cache
    assert seen == [1]                     # later hooks still ran
    assert cache.metrics.hook_errors == 1
    assert trk.counter("cache.hook_errors{kind=admit}") == 1
    assert cache.metrics_snapshot()["hook_errors"] == 1


def test_debug_hooks_reraises():
    cache = SemanticCache(CacheConfig(capacity=4, dim=8,
                                      hit_mode="content", debug_hooks=True))
    cache.subscribe("admit", lambda ev: (_ for _ in ()).throw(
        RuntimeError("boom")))
    with pytest.raises(RuntimeError, match="boom"):
        cache.admit(1, np.ones(8, dtype=np.float32))
    assert cache.metrics.hook_errors == 1


# ------------------------------------------------- consolidated snapshot
def test_metrics_snapshot_merges_all_surfaces():
    from repro.cache import TierConfig
    space = EmbeddingSpace(dim=16, seed=9)
    cache = SemanticCache(CacheConfig(
        capacity=4, dim=16, hit_mode="content", async_admit="sync",
        tiers=TierConfig(host_capacity=8, ghost_capacity=8),
        tracker=InMemoryTracker()))
    for i in range(10):
        emb = space.content_embedding(i % 3, i).astype(np.float32)
        if not cache.lookup(emb, cid=i).hit:
            cache.admit(i, emb, payload=[i])
    cache.flush()
    snap = cache.metrics_snapshot()
    for key in ("hits", "misses", "evictions", "hit_ratio", "hook_errors",
                "pending_admits", "admit_stall_s", "enqueue_s", "flush_s",
                "tiers"):
        assert key in snap, key
    assert snap["pending_admits"] == 0
    assert snap["tiers"]["demotions"] > 0
    cache.close()


def test_checkpoint_restore_shares_tracker():
    trk = InMemoryTracker()
    cache = SemanticCache(CacheConfig(capacity=4, dim=8,
                                      hit_mode="content", tracker=trk))
    emb = np.ones(8, dtype=np.float32)
    cache.admit(1, emb)
    snap = cache.checkpoint()
    cache.admit(2, emb)
    cache.restore(snap)
    assert cache.tracker is trk            # never cloned by the deep copy
    cache.lookup(emb, cid=1)
    assert trk.percentiles("cache.lookup_s") is not None
