"""Quantized int8 lookup path: quantizer round-trip bounds, kernel-engine
score parity (pallas / jnp oracle / numpy host gemm), decision parity of
``quantized_lookup`` against the exact path across all three backends and
both hit modes (including a tau placed inside the quantization noise band,
which must fall back rather than diverge), the compression re-export, the
facade/telemetry wiring, and a hypothesis property sweep."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.cache import CacheConfig, SemanticCache
from repro.cache.backends import KernelBackend, NumpyBackend
from repro.cache.quantized import (QuantizedLookupConfig, as_quantized_config,
                                   new_quant_stats)
from repro.cache.sharded import ShardedKernelBackend
from repro.kernels.quant import (dequantize_int8, int8_scores, quantize_int8,
                                 quantize_rows_int8, scan_margin)


def _unit_rows(rng, n, dim):
    x = rng.standard_normal((n, dim)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


# --------------------------------------------------------------- quantizer
def test_quantize_rows_roundtrip_bound(rng):
    x = _unit_rows(rng, 50, 96) * rng.uniform(0.1, 3.0, (50, 1))
    q8, scale, l1 = quantize_rows_int8(x)
    assert q8.dtype == np.int8 and scale.dtype == np.float32
    assert np.abs(q8).max() <= 127
    # per-row symmetric scheme: |x - q*s| <= s/2 elementwise
    err = np.abs(x - q8.astype(np.float32) * scale[:, None])
    assert (err <= scale[:, None] / 2 + 1e-7).all()
    np.testing.assert_allclose(l1, np.abs(x).sum(axis=1), rtol=1e-6)


def test_quantize_rows_zero_row_is_inert(rng):
    x = np.zeros((3, 64), dtype=np.float32)
    x[1] = _unit_rows(rng, 1, 64)[0]
    q8, scale, l1 = quantize_rows_int8(x)
    assert (q8[0] == 0).all() and (q8[2] == 0).all()
    assert l1[0] == 0.0 and scale[0] > 0      # epsilon scale, no div-by-0


def test_scan_margin_bounds_true_score_error(rng):
    q = _unit_rows(rng, 16, 128)
    c = _unit_rows(rng, 300, 128) * rng.uniform(0.2, 2.0, (300, 1))
    q8, qs, ql1 = quantize_rows_int8(q)
    c8, cs, cl1 = quantize_rows_int8(c)
    approx = (int8_scores(q8, c8) * qs[:, None]) * cs[None, :]
    exact = q @ c.T
    eps = scan_margin(qs, ql1, cs, cl1, 128)          # (16,)
    assert (np.abs(approx - exact).max(axis=1) < eps).all()


def test_int8_scores_is_exact_integer_gemm(rng):
    q8 = rng.integers(-127, 128, (9, 256)).astype(np.int8)
    c8 = rng.integers(-127, 128, (33, 256)).astype(np.int8)
    ref = q8.astype(np.int64) @ c8.astype(np.int64).T
    np.testing.assert_array_equal(int8_scores(q8, c8).astype(np.int64), ref)


# ------------------------------------------------- compression re-export
def test_compression_reexports_shared_quantizer(rng):
    from repro.distributed import compression
    assert compression.quantize_int8 is quantize_int8
    assert compression.dequantize_int8 is dequantize_int8
    g = rng.standard_normal((64, 32)).astype(np.float32)
    q, s = quantize_int8(g)
    np.testing.assert_array_equal(np.asarray(q),
                                  np.asarray(compression.quantize_int8(g)[0]))
    back = dequantize_int8(q, s)
    assert np.abs(np.asarray(back) - g).max() <= float(s) / 2 + 1e-7


# ------------------------------------------------------ kernel engines
def test_sim_topk_q8_pallas_matches_ref_and_host(rng):
    from repro.kernels import ops, ref
    q = _unit_rows(rng, 7, 128)
    c = _unit_rows(rng, 600, 128)
    q8, qs, _ = quantize_rows_int8(q)
    c8, cs, _ = quantize_rows_int8(c)
    n_valid, k = 570, 5
    pv, pi = ops.sim_topk_q8(q8, qs, c8, cs, k, n_valid=n_valid,
                             use_pallas=True)
    rv, ri = ref.sim_topk_q8_ref(q8, qs, c8, cs, n_valid, k)
    np.testing.assert_array_equal(np.asarray(pv), np.asarray(rv))
    np.testing.assert_array_equal(np.asarray(pi), np.asarray(ri))
    # numpy host gemm with the same fixed multiply order is bit-identical
    host = (int8_scores(q8, c8[:n_valid]) * qs[:, None]) * cs[None, :n_valid]
    order = np.argsort(-host, axis=1, kind="stable")[:, :k]
    np.testing.assert_array_equal(np.asarray(pi), order)
    np.testing.assert_array_equal(
        np.asarray(pv), np.take_along_axis(host, order, axis=1))


def test_sim_topk_q8_multi_matches_per_slab(rng):
    from repro.kernels import ops
    q = _unit_rows(rng, 5, 64)
    slabs = np.stack([_unit_rows(rng, 200, 64) for _ in range(3)])
    q8, qs, _ = quantize_rows_int8(q)
    f8, fs, _ = quantize_rows_int8(slabs.reshape(-1, 64))
    s8 = f8.reshape(3, 200, 64)
    ss = fs.reshape(3, 200)
    nv = np.array([200, 150, 3], dtype=np.int32)
    for use_pallas in (False, True):
        mv, mi = ops.sim_topk_q8_multi(q8, qs, s8, ss, 4, n_valid=nv,
                                       use_pallas=use_pallas)
        for p in range(3):
            v, i = ops.sim_topk_q8(q8, qs, s8[p], ss[p], 4,
                                   n_valid=int(nv[p]),
                                   use_pallas=use_pallas)
            np.testing.assert_array_equal(np.asarray(mv)[p], np.asarray(v))
            np.testing.assert_array_equal(np.asarray(mi)[p], np.asarray(i))


# ------------------------------------------------------- config plumbing
def test_quantized_config_normalization():
    assert as_quantized_config(None) is None
    assert as_quantized_config(False) is None
    assert as_quantized_config(True) == QuantizedLookupConfig()
    qc = as_quantized_config({"k": 4, "tau_hit": 0.9})
    assert qc.k == 4 and qc.tau_hit == 0.9
    assert as_quantized_config(qc) is qc
    with pytest.raises(ValueError):
        as_quantized_config("yes")
    assert set(new_quant_stats()) == {"scans", "queries", "fallbacks",
                                      "rescore_rows", "bytes_scanned",
                                      "bytes_exact"}


def test_prebuilt_backend_rejects_quantized_lookup():
    with pytest.raises(ValueError):
        SemanticCache(CacheConfig(capacity=4, dim=8, quantized_lookup=True),
                      backend=NumpyBackend())


def test_quantized_multi_requires_row_tracking(rng):
    from repro.core.arena import ArenaStore
    arena = ArenaStore(2, 10, 16, track_rows=False)
    for be in (NumpyBackend(quantized=True),
               KernelBackend(use_pallas=False, quantized=True),
               ShardedKernelBackend(n_shards=2, use_pallas=False,
                                    quantized=True)):
        arena.views[0].insert(1, _unit_rows(rng, 1, 16)[0])
        with pytest.raises(ValueError):
            be.top1_multi(arena, _unit_rows(rng, 2, 16))


# ------------------------------------------------------- decision parity
def _drive(cfg_kw, reqs):
    cache = SemanticCache(CacheConfig(**cfg_kw))
    events = []
    for kind in ("hit", "miss", "admit", "evict"):
        cache.subscribe(kind, lambda ev, k=kind: events.append((k, ev.cid)))
    for cid, emb in reqs:
        if not cache.lookup(emb, cid=cid).hit:
            cache.admit(cid, emb)
    return events, cache


def _workload(rng, n=160, dim=48, n_base=24, jitter=0.05):
    base = _unit_rows(rng, n_base, dim)
    reqs = []
    for i in range(n):
        j = int(rng.integers(0, n_base))
        v = base[j] + jitter * rng.standard_normal(dim).astype(np.float32)
        reqs.append((j * 1000 + i, (v / np.linalg.norm(v)).astype(np.float32)))
    return reqs


@pytest.mark.parametrize("backend", ["numpy", "kernel", "sharded"])
@pytest.mark.parametrize("hit_mode", ["semantic", "content"])
def test_facade_event_parity_quantized_vs_exact(rng, backend, hit_mode):
    reqs = _workload(rng)
    kw = dict(capacity=18, dim=48, backend=backend, hit_mode=hit_mode)
    if backend == "sharded":
        kw["backend_kwargs"] = {"n_shards": 2}
    if backend != "numpy":
        kw["use_pallas"] = False
    ev0, _ = _drive(dict(kw), reqs)
    for k in (1, 4, 16):
        ev1, c1 = _drive(dict(kw, quantized_lookup={"k": k}), reqs)
        assert ev1 == ev0, (backend, hit_mode, k)
        if hit_mode == "semantic":
            assert c1.backend.quant_stats["scans"] > 0


def test_facade_quant_off_by_default(rng):
    reqs = _workload(rng, n=30)
    _, cache = _drive(dict(capacity=10, dim=48, backend="kernel",
                           use_pallas=False), reqs)
    assert cache.backend.quantized is None
    assert cache.backend.quant_stats == new_quant_stats()
    # the ledger key is always present, zeroed when the path is off
    assert cache.metrics_snapshot()["quant"] == new_quant_stats()


def test_tau_inside_noise_band_falls_back_with_parity(rng):
    """Place tau_hit inside the quantization noise band of real scores:
    the safety predicate cannot certify those queries, so the path must
    take the exact fallback (counted) and still match decisions."""
    reqs = _workload(rng, n=120, jitter=0.3)
    # pick tau at the median observed Top-1 sim so many queries sit on
    # the decision boundary, where eps-wide bands matter most
    probe = SemanticCache(CacheConfig(capacity=18, dim=48))
    sims = []
    for cid, emb in reqs:
        r = probe.lookup(emb, cid=cid)
        sims.append(r.sim if r.hit else r.best_sim)
        if not r.hit:
            probe.admit(cid, emb)
    tau = float(np.median([s for s in sims if np.isfinite(s)]))
    kw = dict(capacity=18, dim=48, tau_hit=tau, backend="kernel",
              use_pallas=False)
    ev0, _ = _drive(dict(kw), reqs)
    ev1, c1 = _drive(dict(kw, quantized_lookup={"k": 1}), reqs)
    assert ev1 == ev0
    # k=1 cannot self-certify a hit (no margin over itself): every hit
    # near tau exercises the fallback leg
    assert c1.backend.quant_stats["fallbacks"] > 0


def test_fallback_counter_reaches_tracker(rng):
    embs = _unit_rows(rng, 10, 48)
    cache = SemanticCache(CacheConfig(
        capacity=16, dim=48, backend="kernel", use_pallas=False,
        tracker="memory", quantized_lookup={"k": 1}))
    for i, v in enumerate(embs):
        cache.admit(i, v)
    for v in embs:                     # exact duplicates: guaranteed hits
        assert cache.lookup(v).hit
    counters = cache.tracker.snapshot()["counters"]
    fb = cache.backend.quant_stats["fallbacks"]
    assert fb > 0
    assert counters.get("cache.rescore_fallbacks") == fb
    snap = cache.metrics_snapshot()
    assert snap["quant"]["fallbacks"] == fb
    # int8 mirror uploads ride the backend.sync byte ledger
    assert snap["sync"]["bytes"] > 0


@pytest.mark.parametrize("backend", ["numpy", "kernel", "sharded"])
def test_run_arena_quantized_parity(rng, backend):
    from repro.core.arena import run_arena
    from repro.core.policies import BASELINES
    from repro.core.types import Request, Trace
    reqs = [Request(t=i, cid=cid, emb=emb)
            for i, (cid, emb) in enumerate(_workload(rng, n=200))]
    trace = Trace(requests=reqs)
    facs = {"LRU": BASELINES["LRU"], "LFU": BASELINES["LFU"]}
    s0 = run_arena(trace, 20, facs, hit_mode="semantic", backend=backend,
                   use_pallas=False)
    s1 = run_arena(trace, 20, facs, hit_mode="semantic", backend=backend,
                   use_pallas=False, quantized=True)
    for a, b in zip(s0, s1):
        assert (a.hits, a.misses, a.evictions) == \
               (b.hits, b.misses, b.evictions)


def test_backend_quantized_topk_bit_parity_with_exact(rng):
    """Per-backend contract on the kernel engines: the certified quantized
    Top-1 is bit-identical to the same backend's exact scan (fixed-order
    fp32 rescore), across churn and all three k regimes."""
    def fill(be):
        store = be.make_store(60, 64) if hasattr(be, "make_store") else None
        if store is None:
            from repro.core.store import ResidentStore
            store = ResidentStore(60, 64)
        vecs = _unit_rows(np.random.default_rng(2), 55, 64)
        for i, v in enumerate(vecs):
            store.insert(i, v)
        for i in range(0, 18, 3):
            store.remove(i)
        return store
    q = _unit_rows(rng, 21, 64)
    for mk in (lambda **kw: KernelBackend(use_pallas=False, **kw),
               lambda **kw: ShardedKernelBackend(n_shards=3,
                                                 use_pallas=False, **kw)):
        exact = mk()
        st = fill(exact)
        c0, s0 = exact.top1_batch(st, q)
        for spec in ({"k": 1}, {"k": 4, "tau_hit": 0.8},
                     {"k": 64, "tau_hit": 0.8}):
            qb = mk(quantized=spec)
            st_q = fill(qb)
            c1, s1 = qb.top1_batch(st_q, q)
            np.testing.assert_array_equal(c0, c1)
            np.testing.assert_array_equal(s0, s1)


@pytest.mark.slow_mesh
def test_sharded_quantized_mesh_path_in_subprocess():
    """With 4 host devices the quantized shard_map lookup (per-shard int8
    top-k + all_gather merge) runs end-to-end and makes the same
    decisions as the exact mesh path."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = """
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=4").strip()
import numpy as np
from repro.cache import ShardedKernelBackend, ShardedStore
rng = np.random.default_rng(1)
def fill():
    store = ShardedStore(300, 64, n_shards=4)
    r = np.random.default_rng(4)
    embs = r.standard_normal((200, 64)).astype(np.float32)
    embs /= np.linalg.norm(embs, axis=1, keepdims=True)
    for i in range(200):
        store.insert(i, embs[i])
    store.remove(7); store.remove(90)
    return store
q = rng.standard_normal((32, 64)).astype(np.float32)
q /= np.linalg.norm(q, axis=1, keepdims=True)
ex = ShardedKernelBackend(n_shards=4, use_pallas=False)
st = fill()
q[0] = st.emb[3]; q[1] = st.emb[100]
assert ex.mesh() is not None
c0, s0 = ex.top1_batch(st, q)
qb = ShardedKernelBackend(n_shards=4, use_pallas=False,
                          quantized={"k": 8, "tau_hit": 0.85})
stq = fill()
c1, s1 = qb.top1_batch(stq, q)
np.testing.assert_array_equal(c0, c1)
np.testing.assert_array_equal(s0, s1)
assert qb.quant_stats["scans"] == 1
print("OK")
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


# --------------------------------------------------------- property test
def _decisions_match_exact(seed, k, backend, tau):
    """Property body: quantized event stream == exact event stream."""
    rng = np.random.default_rng(seed)
    reqs = _workload(rng, n=60, dim=32, n_base=10,
                     jitter=float(rng.uniform(0.02, 0.4)))
    kw = dict(capacity=8, dim=32, tau_hit=tau, backend=backend)
    if backend != "numpy":
        kw["use_pallas"] = False
    ev0, _ = _drive(dict(kw), reqs)
    ev1, _ = _drive(dict(kw, quantized_lookup={"k": k}), reqs)
    assert ev1 == ev0


def test_quantized_decisions_property_random_workloads():
    try:
        from hypothesis import HealthCheck, given, settings
        from hypothesis import strategies as st
    except ImportError:
        # hypothesis is optional in the image: fall back to a seeded
        # sweep over the same parameter space so the property still runs
        rng = np.random.default_rng(0)
        for _ in range(12):
            _decisions_match_exact(int(rng.integers(2 ** 31)),
                                   int(rng.choice([1, 4, 16])),
                                   str(rng.choice(["numpy", "kernel"])),
                                   float(rng.uniform(0.5, 0.99)))
        return

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(min_value=0, max_value=2 ** 31 - 1),
           st.sampled_from([1, 4, 16]),
           st.sampled_from(["numpy", "kernel"]),
           st.floats(min_value=0.5, max_value=0.99))
    def prop(seed, k, backend, tau):
        _decisions_match_exact(seed, k, backend, tau)

    prop()
