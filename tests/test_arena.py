"""Multi-policy arena + array-state policy guarantees.

The PR acceptance surface:

  - every array-state baseline makes bit-identical hit/miss/eviction
    decisions (including the eviction *sequence*) to its legacy host-loop
    counterpart (``repro.core.legacy_policies``);
  - the one-pass arena (``run_arena``) reproduces sequential legacy
    ``run_many`` counts for every baseline across content/semantic hit
    modes x chunk sizes {1, 7, 512} x numpy/kernel backends (plus the
    sharded backend's single-device fallback and, in a subprocess, its
    4-device shard_map merge);
  - the vectorized batch hooks leave the same policy state as the scalar
    loop (hypothesis property test on random traces);
  - ``seed`` threads from ``run_many``/``default_factories`` into the
    RNG-bearing policies.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (LEGACY_BASELINES, SynthConfig, default_factories,
                        run_many, run_policy, synthetic_trace)
from repro.core.arena import ArenaStore, run_arena
from repro.core.policies import BASELINES
from repro.core.store import ResidentStore
from repro.core.types import Request, Trace

ALL_NAMES = sorted(BASELINES)


# --------------------------------------------------------------- helpers
def _trace_from_cids(cids, dim=8):
    reqs = []
    for t, c in enumerate(cids):
        e = np.zeros(dim, np.float32)
        e[c % dim] = 1.0
        reqs.append(Request(t=t, cid=int(c), emb=e))
    return Trace(requests=reqs).with_next_use()


def _drive(cls, tr, capacity, batch_hits=False, **kw):
    """Manual Alg.1 drive -> (hits, eviction sequence).  ``batch_hits``
    routes runs of consecutive hits through ``on_hit_batch``."""
    store = ResidentStore(capacity, 8)
    pol = cls(capacity, store, **kw)
    ev, hits = [], 0
    pc, pr, pt = [], [], []
    for req in tr.requests:
        if req.cid in store:
            hits += 1
            if batch_hits:
                pc.append(req.cid)
                pr.append(req)
                pt.append(req.t)
                continue
            pol.on_hit(req.cid, req, req.t)
            continue
        if pc:
            pol.on_hit_batch(pc, pr, pt)
            pc, pr, pt = [], [], []
        store.insert(req.cid, req.emb)
        pol.on_admit(req.cid, req, req.t)
        while len(store) > capacity:
            v = pol.victim(req.t)
            store.remove(v)
            ev.append(v)
    if pc:
        pol.on_hit_batch(pc, pr, pt)
    return hits, ev


def _legacy_facs(names):
    return {n: (lambda c, s, _c=LEGACY_BASELINES[n]: _c(c, s))
            for n in names}


def _array_facs(names):
    return {n: (lambda c, s, _c=BASELINES[n]: _c(c, s)) for n in names}


def _counts(stats):
    return [(s.policy, s.hits, s.misses, s.evictions) for s in stats]


# ------------------------------------- array vs legacy (policy protocol)
@pytest.mark.parametrize("name", ALL_NAMES)
def test_array_policy_matches_legacy_eviction_sequence(name, rng):
    """Stronger than counts: the full eviction SEQUENCE must match, for
    scalar and batched hit delivery alike."""
    for trial in range(6):
        cids = rng.integers(0, 20 + 8 * trial, size=400).tolist()
        cap = [3, 5, 10, 17, 2, 29][trial]
        tr = _trace_from_cids(cids)
        ref = _drive(LEGACY_BASELINES[name], tr, cap)
        assert _drive(BASELINES[name], tr, cap) == ref
        assert _drive(BASELINES[name], tr, cap, batch_hits=True) == ref


@pytest.mark.parametrize("name", ["FIFO", "LRU", "TTL", "LFU", "LRU-2",
                                  "GDSF", "Belady"])
def test_victim_scores_agrees_with_fast_victim(name, rng):
    """The score-ordered policies carry two encodings of their eviction
    order: the ``victim_scores`` lexicographic keys (the generic masked
    argmin in ``ArrayPolicy.victim``) and the sentinel-forget fast
    ``victim``.  They must elect the same cid from any reachable state."""
    import copy

    from repro.core.policies import ArrayPolicy
    cids = rng.integers(0, 40, size=250).tolist()
    tr = _trace_from_cids(cids)
    store = ResidentStore(10, 8)
    pol = BASELINES[name](10, store)
    for req in tr.requests:
        if req.cid in store:
            pol.on_hit(req.cid, req, req.t)
            continue
        store.insert(req.cid, req.emb)
        pol.on_admit(req.cid, req, req.t)
        if len(store) > 10:
            if pol.victim_scores(req.t) is not None:
                p2 = copy.deepcopy(pol)
                generic = ArrayPolicy.victim(p2, req.t)
                assert generic == pol.victim(req.t)
                store.remove(generic)
            else:
                store.remove(pol.victim(req.t))


@pytest.mark.parametrize("name", ["LRU", "LFU", "ARC", "S3-FIFO", "TinyLFU"])
def test_on_admit_batch_matches_scalar(name, rng):
    """Batched admission (no capacity pressure) leaves the same state as
    the scalar loop: subsequent decisions on a shared tail must agree."""
    warm = [Request(t=t, cid=c, emb=np.eye(8, dtype=np.float32)[c % 8])
            for t, c in enumerate(range(12))]
    tail = rng.integers(0, 30, size=200).tolist()
    tr = _trace_from_cids(tail)

    def finish(pol, store):
        ev, hits = [], 0
        for req in tr.requests:
            req.t += len(warm)
            if req.cid in store:
                hits += 1
                pol.on_hit(req.cid, req, req.t)
            else:
                store.insert(req.cid, req.emb)
                pol.on_admit(req.cid, req, req.t)
                while len(store) > 20:
                    v = pol.victim(req.t)
                    store.remove(v)
                    ev.append(v)
            req.t -= len(warm)
        return hits, ev

    outs = []
    for batched in (False, True):
        store = ResidentStore(20, 8)
        pol = BASELINES[name](20, store)
        for r in warm:
            store.insert(r.cid, r.emb)
        if batched:
            pol.on_admit_batch([r.cid for r in warm], warm,
                               [r.t for r in warm])
        else:
            for r in warm:
                pol.on_admit(r.cid, r, r.t)
        outs.append(finish(pol, store))
    assert outs[0] == outs[1]


# ------------------------------------------------- arena parity matrix
@pytest.fixture(scope="module")
def trace_short():
    return synthetic_trace(SynthConfig(trace_len=500, seed=11))


@pytest.fixture(scope="module")
def legacy_ref(trace_short):
    """Sequential legacy run_policy counts per (backend, hit_mode)."""
    memo = {}

    def get(backend, hit_mode):
        key = (backend, hit_mode)
        if key not in memo:
            stats = [run_policy(trace_short, 40, f, name=n,
                                hit_mode=hit_mode, backend=backend,
                                use_pallas=False)
                     for n, f in _legacy_facs(ALL_NAMES).items()]
            memo[key] = _counts(stats)
        return memo[key]

    return get


@pytest.mark.parametrize("backend", ["numpy", "kernel"])
@pytest.mark.parametrize("hit_mode", ["content", "semantic"])
@pytest.mark.parametrize("chunk", [1, 7, 512])
def test_arena_parity_matrix(trace_short, legacy_ref, backend, hit_mode,
                             chunk):
    """The acceptance matrix: one arena pass over EVERY baseline is
    bit-identical to the sequential legacy replays."""
    stats = run_arena(trace_short, 40, _array_facs(ALL_NAMES),
                      hit_mode=hit_mode, backend=backend, chunk=chunk,
                      use_pallas=False)
    assert _counts(stats) == legacy_ref(backend, hit_mode)


def test_arena_includes_rac_variants(trace_short):
    """RAC rides the arena unchanged (policy hooks are generic): counts
    match its own sequential facade replay, per variant."""
    from repro.core.rac import make_rac
    facs = {"RAC": make_rac(), "RAC w/o TP": make_rac(use_tp=False)}
    seq = run_many(trace_short, 40, facs, hit_mode="semantic")
    arena = run_many(trace_short, 40, facs, arena=True, hit_mode="semantic")
    assert _counts(seq) == _counts(arena)


def test_arena_sharded_backend_fallback(trace_short):
    """backend="sharded" (single-device per-shard loop + argmax merge)
    makes the same decisions as the numpy arena and the sequential runs."""
    ref = run_arena(trace_short, 40, _array_facs(["LRU", "TTL", "LHD"]),
                    hit_mode="semantic", backend="numpy")
    stats = run_arena(trace_short, 40, _array_facs(["LRU", "TTL", "LHD"]),
                      hit_mode="semantic", backend="sharded",
                      use_pallas=False)
    assert _counts(stats) == _counts(ref)


def test_run_many_arena_flag(trace_short):
    """run_many(arena=True) is the documented entry point."""
    facs = _array_facs(["LRU", "FIFO"])
    a = run_many(trace_short, 40, facs, arena=True, hit_mode="content")
    b = run_many(trace_short, 40, facs, hit_mode="content")
    assert _counts(a) == _counts(b)
    assert a[0].hr_full == b[0].hr_full
    assert a[0].wall_s > 0


# --------------------------------------------------- stacked launch paths
def _assert_same_top1_decisions(nc, ns, kc, ks):
    """Engines must agree on every decision-relevant answer: identical
    winners wherever the best similarity is positive, and agreement that
    nothing clears any positive gate elsewhere (a zeroed free slot may
    out-score a negative real best on one engine and not the other — both
    are misses at any sensible tau_hit, cf. the backend docstrings)."""
    pos = np.asarray(ns) > 0
    np.testing.assert_array_equal(pos, np.asarray(ks) > 0)
    np.testing.assert_array_equal(np.asarray(nc)[pos], np.asarray(kc)[pos])
    np.testing.assert_allclose(np.asarray(ns)[pos], np.asarray(ks)[pos],
                               atol=1e-5)


def test_top1_multi_backends_agree(rng):
    """numpy / kernel / sharded top1_multi make identical per-policy Top-1
    decisions over one stacked arena slab."""
    from repro.cache.backends import KernelBackend, NumpyBackend
    from repro.cache.sharded import ShardedKernelBackend
    dim = 32
    arena = ArenaStore(3, 50, dim, track_rows=True)
    for p, n in enumerate((40, 51, 3)):
        embs = rng.standard_normal((n, dim)).astype(np.float32)
        embs /= np.linalg.norm(embs, axis=1, keepdims=True)
        for i in range(n):
            arena.views[p].insert(1000 * p + i, embs[i])
    q = rng.standard_normal((9, dim)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    nc, ns = NumpyBackend().top1_multi(arena, q)
    assert nc.shape == ns.shape == (3, 9)
    assert (ns[0] > 0).any() and (ns[1] > 0).any()
    for be in (KernelBackend(use_pallas=False),
               ShardedKernelBackend(n_shards=2, use_pallas=False)):
        kc, ks = be.top1_multi(arena, q)
        _assert_same_top1_decisions(nc, ns, kc, ks)


def test_kernel_top1_multi_tracks_mutations(rng):
    """The stacked device mirror follows inserts/removals (dirty-row
    scatter keyed on the arena's flat journal)."""
    from repro.cache.backends import KernelBackend, NumpyBackend
    dim = 16
    arena = ArenaStore(2, 20, dim, track_rows=True)
    embs = rng.standard_normal((30, dim)).astype(np.float32)
    embs /= np.linalg.norm(embs, axis=1, keepdims=True)
    for i in range(10):
        arena.views[0].insert(i, embs[i])
        arena.views[1].insert(100 + i, embs[i + 10])
    kb = KernelBackend(use_pallas=False)
    nb = NumpyBackend()
    q = embs[20:25]
    _assert_same_top1_decisions(*nb.top1_multi(arena, q),
                                *kb.top1_multi(arena, q))
    arena.views[0].remove(3)
    arena.views[1].insert(999, q[0])
    nc, ns = nb.top1_multi(arena, q)
    assert nc[1, 0] == 999 and ns[1, 0] > 0.99   # the fresh row must win
    _assert_same_top1_decisions(nc, ns, *kb.top1_multi(arena, q))
    assert kb._arena_mirror.stats["incremental"] >= 1


@pytest.mark.slow_mesh
def test_sharded_top1_multi_shard_map_in_subprocess():
    """4-device mesh: the stacked per-shard launch + argmax merge equals
    the numpy oracle."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = """
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=4").strip()
import numpy as np
from repro.core.arena import ArenaStore
from repro.cache.backends import NumpyBackend
from repro.cache.sharded import ShardedKernelBackend
rng = np.random.default_rng(5)
P, cap, dim = 3, 97, 64
arena = ArenaStore(P, cap, dim, track_rows=True)
for p in range(P):
    n = [60, 97, 5][p]
    embs = rng.standard_normal((n, dim)).astype(np.float32)
    embs /= np.linalg.norm(embs, axis=1, keepdims=True)
    for i in range(n):
        arena.views[p].insert(1000 * p + i, embs[i])
q = rng.standard_normal((13, dim)).astype(np.float32)
q /= np.linalg.norm(q, axis=1, keepdims=True)
nb = NumpyBackend()
sb = ShardedKernelBackend(n_shards=4, use_pallas=False)
assert sb.mesh() is not None
def check():
    nc, ns = nb.top1_multi(arena, q)
    sc, ss = sb.top1_multi(arena, q)
    pos = ns > 0
    np.testing.assert_array_equal(pos, ss > 0)
    np.testing.assert_array_equal(nc[pos], sc[pos])
    np.testing.assert_allclose(ns[pos], ss[pos], atol=1e-5)
check()
assert sb.sync_stats["full"] == 1, sb.sync_stats
check()                                     # same version -> cached slab
assert {k: sb.sync_stats[k] for k in ("full", "incremental", "rows")} \
    == {"full": 1, "incremental": 0, "rows": 0}
arena.views[2].remove(2000)
arena.views[0].insert(7777, q[0])
check()                                     # 2 dirty rows -> device scatter
assert sb.sync_stats["full"] == 1, sb.sync_stats
assert sb.sync_stats["incremental"] == 1, sb.sync_stats
assert sb.sync_stats["rows"] == 2, sb.sync_stats
print("OK")
"""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(repo, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout


# ------------------------------------------------------- seed threading
def _seedable_facs(names):
    """Factories following the default_factories convention: a ``seed``
    kwarg that run_many(seed=...) binds via ``with_seed``."""
    def make(cls):
        def f(cap, store, seed=None):
            kw = {"seed": seed} if seed is not None else {}
            return cls(cap, store, **kw)
        f.__name__ = cls.name
        return f

    return {n: make(BASELINES[n]) for n in names}


def test_seed_threads_to_rng_policies(trace_short):
    facs = _seedable_facs(["RANDOM", "LeCaR"])
    a = run_many(trace_short, 20, facs, hit_mode="content", seed=1)
    b = run_many(trace_short, 20, facs, hit_mode="content", seed=1)
    c = run_many(trace_short, 20, facs, hit_mode="content", seed=2)
    assert _counts(a) == _counts(b)
    assert _counts(a) != _counts(c)      # RANDOM's victims must move
    # arena threads the same seed
    d = run_many(trace_short, 20, facs, arena=True, hit_mode="content",
                 seed=2)
    assert _counts(c) == _counts(d)


def test_default_factories_seed_kwarg(trace_short):
    f1 = default_factories(include_extra=True, seed=7)
    f2 = default_factories(include_extra=True, seed=7)
    f3 = default_factories(include_extra=True, seed=8)
    cnt = lambda fac: _counts(run_many(trace_short, 20,
                                       {"RANDOM": fac["RANDOM"]},
                                       hit_mode="content"))
    assert cnt(f1) == cnt(f2)
    assert cnt(f1) != cnt(f3)


def test_seeded_legacy_matches_seeded_array(trace_short):
    """Seed threading preserves the legacy parity (same rng streams)."""
    for name in ("RANDOM", "LeCaR", "LHD", "TinyLFU"):
        leg = run_policy(trace_short, 20,
                         lambda c, s, _c=LEGACY_BASELINES[name]:
                         _c(c, s, seed=3),
                         hit_mode="content")
        arr = run_policy(trace_short, 20,
                         lambda c, s, _c=BASELINES[name]: _c(c, s, seed=3),
                         hit_mode="content")
        assert (leg.hits, leg.misses, leg.evictions) == \
               (arr.hits, arr.misses, arr.evictions)


# --------------------------------------------------------- property test
def test_array_legacy_property_random_traces():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(st.integers(min_value=0, max_value=30),
                    min_size=20, max_size=150),
           st.integers(min_value=2, max_value=12),
           st.sampled_from(ALL_NAMES))
    def prop(cids, cap, name):
        tr = _trace_from_cids(cids)
        ref = _drive(LEGACY_BASELINES[name], tr, cap)
        assert _drive(BASELINES[name], tr, cap) == ref
        assert _drive(BASELINES[name], tr, cap, batch_hits=True) == ref

    prop()


def test_arena_property_random_traces():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(st.integers(min_value=0, max_value=25),
                    min_size=30, max_size=120),
           st.integers(min_value=2, max_value=10))
    def prop(cids, cap):
        tr = _trace_from_cids(cids)
        names = ["LRU", "TTL", "ARC", "S3-FIFO", "SIEVE", "Belady"]
        seq = run_many(tr, cap, _legacy_facs(names), hit_mode="content")
        arena = run_arena(tr, cap, _array_facs(names), hit_mode="content")
        assert _counts(seq) == _counts(arena)

    prop()
