"""CacheEvent emission contract: per-operation ordering, tier tags on
demote/promote flows, parity of the event stream between synchronous and
async-flushed admission, and content- vs semantic-mode hit events."""
import math

import numpy as np
import pytest

from repro.cache import CacheConfig, SemanticCache, TierConfig
from repro.core import EmbeddingSpace, SynthConfig, synthetic_trace


def _recorder(cache, events):
    for kind in ("hit", "miss", "admit", "evict"):
        cache.subscribe(kind, lambda ev: events.append(ev))
    return events


def _drive(cache, trace):
    for r in trace.requests:
        res = cache.lookup(r.emb, cid=r.cid, t=r.t)
        if not res.hit:
            cache.admit(r.cid, r.emb, payload=(r.cid,), t=r.t)
    cache.flush()


@pytest.fixture(scope="module")
def small_trace():
    return synthetic_trace(SynthConfig(trace_len=200, n_topics=6,
                                       dim=16, seed=2))


# ----------------------------------------------------------- ordering
def test_event_order_miss_admit_evict():
    """One over-capacity admission emits miss -> admit -> evict, with the
    evict carrying the victim's payload."""
    cache = SemanticCache(CacheConfig(capacity=1, dim=4,
                                      hit_mode="content", policy="LRU"))
    events = _recorder(cache, [])
    e = np.ones(4, dtype=np.float32)
    cache.lookup(e, cid=1, t=1)
    cache.admit(1, e, payload="p1", t=1)
    cache.lookup(e, cid=2, t=2)
    cache.admit(2, e, payload="p2", t=2)
    kinds = [(ev.kind, ev.cid) for ev in events]
    assert kinds == [("miss", 1), ("admit", 1),
                     ("miss", 2), ("admit", 2), ("evict", 1)]
    evict = events[-1]
    assert evict.payload == "p1" and evict.tier == "device"
    assert events[1].payload == "p1"       # admit carries its payload


@pytest.mark.parametrize("async_admit", [False, "sync", True])
def test_event_stream_identical_across_admission_modes(small_trace,
                                                       async_admit):
    """Flushing at every batch boundary makes the async event stream
    identical to the synchronous one — same (kind, cid, t, tier) tuples
    in the same order.  (Without flushes, deferred admissions are
    *supposed* to change later hit decisions; parity is defined at flush
    boundaries, which is exactly how the serving engine drives it.)"""
    def run(mode):
        cache = SemanticCache(CacheConfig(
            capacity=16, dim=16, hit_mode="content", async_admit=mode))
        events = _recorder(cache, [])
        for r in small_trace.requests:
            res = cache.lookup(r.emb, cid=r.cid, t=r.t)
            if not res.hit:
                cache.admit(r.cid, r.emb, payload=(r.cid,), t=r.t)
            cache.flush()
        cache.close()
        return [(ev.kind, ev.cid, ev.t, ev.tier) for ev in events]

    assert run(async_admit) == run(False)


def test_async_flush_event_order_is_submission_order():
    """Queued admissions apply (and emit) in FIFO submission order."""
    cache = SemanticCache(CacheConfig(capacity=8, dim=4,
                                      hit_mode="content",
                                      async_admit="sync"))
    admits = []
    cache.subscribe("admit", lambda ev: admits.append(ev.cid))
    e = np.ones(4, dtype=np.float32)
    for cid in (5, 3, 9, 1):
        cache.admit(cid, e)
    assert admits == []                    # nothing applied before flush
    cache.flush()
    assert admits == [5, 3, 9, 1]
    cache.close()


# ------------------------------------------------------ hit-mode semantics
def test_content_mode_hit_sim_is_nan():
    cache = SemanticCache(CacheConfig(capacity=4, dim=4,
                                      hit_mode="content"))
    events = _recorder(cache, [])
    e = np.ones(4, dtype=np.float32)
    cache.admit(7, e, payload="x")
    assert cache.lookup(e, cid=7).hit
    hit = [ev for ev in events if ev.kind == "hit"][0]
    assert math.isnan(hit.sim) and hit.payload == "x"


def test_semantic_mode_hit_sim_clears_tau():
    space = EmbeddingSpace(dim=16, seed=3)
    cache = SemanticCache(CacheConfig(capacity=4, dim=16, tau_hit=0.85,
                                      hit_mode="semantic"))
    events = _recorder(cache, [])
    emb = space.content_embedding(0, 1).astype(np.float32)
    cache.admit(1, emb, payload="y")
    assert cache.lookup(emb, cid=1).hit
    far = -emb                             # cosine -1: a definitive miss
    assert not cache.lookup(far, cid=2).hit
    hit = [ev for ev in events if ev.kind == "hit"][0]
    miss = [ev for ev in events if ev.kind == "miss"][-1]
    assert hit.sim >= 0.85
    assert miss.sim <= 0.0                 # best-known sim rides the event


# ------------------------------------------------------- tier-tagged flows
def test_demote_and_promote_tier_tags():
    """Eviction into the host tier tags the evict event ``tier="host"``;
    a host-tier serve emits a ``tier="host"`` hit and re-admits (promotes)
    the entry through the normal admission path."""
    space = EmbeddingSpace(dim=16, seed=4)
    cache = SemanticCache(CacheConfig(
        capacity=2, dim=16, tau_hit=0.85, hit_mode="semantic",
        tiers=TierConfig(host_capacity=8, ghost_capacity=8)))
    events = _recorder(cache, [])
    embs = {i: space.content_embedding(i, i).astype(np.float32)
            for i in range(4)}
    for i in range(4):                     # capacity 2 -> 0,1 demoted
        cache.admit(i, embs[i], payload=f"p{i}", t=i + 1)
    evicts = [ev for ev in events if ev.kind == "evict"]
    assert [ev.tier for ev in evicts] == ["host", "host"]
    assert cache.in_host(0) and not (0 in cache)

    n_admits = sum(ev.kind == "admit" for ev in events)
    res = cache.lookup(embs[0], cid=0, t=10)   # served from host DRAM
    assert res.hit and res.payload == "p0"
    host_hits = [ev for ev in events if ev.kind == "hit"]
    assert host_hits[-1].tier == "host" and host_hits[-1].cid == 0
    # promotion re-entered via admit: a fresh admit event (+ its eviction)
    assert sum(ev.kind == "admit" for ev in events) == n_admits + 1
    assert 0 in cache and not cache.in_host(0)
    promote_evict = [ev for ev in events if ev.kind == "evict"][-1]
    assert promote_evict.tier == "host"    # displaced entry demoted too


def test_device_hit_tier_tag_is_device(small_trace):
    cache = SemanticCache(CacheConfig(capacity=32, dim=16,
                                      hit_mode="content"))
    events = _recorder(cache, [])
    _drive(cache, small_trace)
    hits = [ev for ev in events if ev.kind == "hit"]
    assert hits and all(ev.tier == "device" for ev in hits)
