"""Event-driven admission: flush determinism vs synchronous admit, the
payload-leak fix, the row-restricted peek, and the dirty-row store
journal behind the sharded backend's incremental slab sync."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.cache import (CacheConfig, KernelBackend, NumpyBackend,
                         SemanticCache)
from repro.core import EmbeddingSpace
from repro.core.store import ResidentStore


def _drive(mode, *, capacity=16, dim=32, batch=5):
    """One fixed replay script in the engine's batch-boundary discipline:
    a batch of lookups, then the misses' admissions, then a flush — so
    every lookup sees a settled store in all three admission modes."""
    space = EmbeddingSpace(dim=dim, seed=2)
    cache = SemanticCache(CacheConfig(capacity=capacity, dim=dim,
                                      policy="RAC", async_admit=mode))
    events = []
    for kind in ("hit", "miss", "admit", "evict"):
        cache.subscribe(kind, lambda ev, k=kind: events.append((k, ev.cid)))
    reqs = [(i, space.content_embedding(i % 6, i // 6).astype(np.float32)
             if i < 30 else
             space.paraphrase(space.content_embedding(i % 6, (i - 30) // 6)
                              .astype(np.float32), i % 6, (i - 30) // 6, 1)
             .astype(np.float32))
            for i in range(60)]
    for start in range(0, len(reqs), batch):
        chunk = reqs[start:start + batch]
        missed = [(cid, emb) for cid, emb in chunk
                  if not cache.lookup(emb, cid=cid).hit]
        for cid, emb in missed:
            cache.admit(cid, emb, payload=[cid])
        cache.flush()
    cache.close()
    counters = {k: v for k, v in cache.metrics.snapshot().items()
                if not k.endswith("_s")}
    return cache, counters, events


def test_flush_matches_synchronous_admit():
    """The determinism criterion: after flush(), store, payloads, metrics
    counters, clock, and the admit/evict decision sequence are identical
    across inline, queued-deterministic ('sync'), and background-worker
    modes."""
    ref_cache, ref_counters, ref_events = _drive(False)
    ref_admits = [e for e in ref_events if e[0] in ("admit", "evict")]
    for mode in ("sync", True):
        cache, counters, events = _drive(mode)
        assert sorted(cache.store.keys()) == sorted(ref_cache.store.keys())
        assert cache.payloads == ref_cache.payloads
        assert counters == ref_counters
        assert cache.clock == ref_cache.clock
        # admissions and eviction victims happen in the same order (only
        # their interleaving with lookups moves — that's the async point)
        assert [e for e in events if e[0] in ("admit", "evict")] == ref_admits


def test_async_admit_defers_until_flush():
    space = EmbeddingSpace(dim=16, seed=3)
    cache = SemanticCache(CacheConfig(capacity=4, dim=16, policy="LRU",
                                      async_admit="sync"))
    e = space.content_embedding(0, 0).astype(np.float32)
    assert cache.admit(0, e, payload="r") == []
    assert cache.pending_admits == 1 and len(cache) == 0
    evicted = cache.flush()
    assert evicted == [] and len(cache) == 1 and cache.pending_admits == 0
    assert cache.lookup(e, cid=0).hit


def test_flush_reports_drained_evictions():
    rng = np.random.default_rng(4)
    cache = SemanticCache(CacheConfig(capacity=2, dim=8, policy="FIFO",
                                      async_admit="sync"))
    embs = rng.standard_normal((4, 8)).astype(np.float32)
    for i in range(4):
        cache.admit(i, embs[i])
    assert cache.flush() == [0, 1]            # FIFO victims, in drain order


def test_checkpoint_flushes_queued_admissions():
    rng = np.random.default_rng(5)
    cache = SemanticCache(CacheConfig(capacity=8, dim=8, policy="LRU",
                                      async_admit="sync"))
    cache.admit(7, rng.standard_normal(8).astype(np.float32), payload="x")
    snap = cache.checkpoint()                  # settles the queue first
    assert 7 in snap["store"].slot_of and snap["payloads"] == {7: "x"}
    cache.restore(snap)
    assert 7 in cache and cache.payloads == {7: "x"}


def test_drain_error_surfaces_at_flush_and_worker_survives():
    """An admission that would raise inline must raise at flush() — not
    hang the flush wait or silently vanish — and the worker keeps
    draining afterwards."""
    cache = SemanticCache(CacheConfig(capacity=4, dim=8, policy="LRU",
                                      async_admit=True))
    cache.admit(1, np.ones(3, np.float32))      # wrong-shaped embedding
    with pytest.raises(ValueError):
        cache.flush()
    cache.admit(2, np.ones(8, np.float32))
    assert cache.flush() == []
    assert 2 in cache and 1 not in cache
    cache.close()


def test_close_reverts_to_inline_admission():
    """close() stops the worker but leaves the cache usable: later admits
    apply synchronously instead of raising into the caller's loop."""
    cache = SemanticCache(CacheConfig(capacity=4, dim=8, policy="LRU",
                                      async_admit=True))
    cache.admit(1, np.ones(8, np.float32))
    cache.close()
    assert 1 in cache and cache.admitter is None
    assert cache.admit(2, np.full(8, 2, np.float32)) == []   # inline now
    assert 2 in cache and cache.pending_admits == 0


@pytest.mark.parametrize("mode", ["sync", True])
def test_close_drains_submissions_racing_past_the_flush(mode):
    """close() hardening: an admission submitted *between* close()'s flush
    and the closed mark — the window a tier promotion rides in through a
    concurrent lookup — must still be applied, never silently dropped.

    The race is simulated deterministically: the admitter's flush is
    wrapped to submit one more item right after the drain completes, so
    the late item is guaranteed to land inside the window."""
    cache = SemanticCache(CacheConfig(capacity=8, dim=8, policy="LRU",
                                      async_admit=mode))
    cache.admit(1, np.ones(8, np.float32), payload=["early"])
    adm = cache.admitter
    orig_flush = adm.flush

    def racing_flush():
        out = orig_flush()
        adm.submit(9, np.full(8, 2, np.float32), ["late"], cache.clock + 1,
                   None)
        return out

    adm.flush = racing_flush
    cache.close()
    assert 1 in cache and 9 in cache          # nothing dropped
    assert cache.payloads[9] == ["late"]
    assert len(adm) == 0 and adm.applied == 2


def test_capacity_zero_admit_never_leaks_payload():
    """Regression: with capacity<=0 nothing is ever inserted, so the
    payload must not be stored (eviction could never drop it)."""
    cache = SemanticCache(CacheConfig(capacity=0, dim=8, policy="LRU"))
    cache.admit(1, np.ones(8, np.float32), payload=list(range(1000)))
    assert cache.payloads == {} and len(cache) == 0


# ------------------------------------------------------- row-restricted peek
@pytest.mark.parametrize("backend", ["numpy", "kernel"])
def test_peek_rows_matches_full_peek(backend):
    """A rescan restricted to the full resident set must agree with
    peek_batch exactly — same backend scoring, no host dot-product drift."""
    space = EmbeddingSpace(dim=64, seed=6)
    cache = SemanticCache(CacheConfig(capacity=40, dim=64, policy="LRU",
                                      backend=backend, use_pallas=False))
    embs = [space.content_embedding(i % 8, i).astype(np.float32)
            for i in range(32)]
    for i, e in enumerate(embs):
        cache.admit(i, e)
    queries = np.stack([space.paraphrase(embs[i], i % 8, i, 1)
                        for i in range(12)]).astype(np.float32)
    full_c, full_s = cache.peek_batch(queries)
    sub_c, sub_s = cache.peek_rows(queries, list(range(32)))
    np.testing.assert_array_equal(full_c, sub_c)
    np.testing.assert_allclose(full_s, sub_s, atol=1e-5)
    # restricted to a strict subset: results come only from that subset
    some = [3, 17, 20]
    c, s = cache.peek_rows(queries, some + [999])     # non-resident skipped
    assert set(c.tolist()) <= set(some)
    # empty/non-resident restriction: every query reports a hard miss
    c, s = cache.peek_rows(queries, [999])
    assert (c == -1).all() and (s == -np.inf).all()


def test_peek_rows_kernel_matches_numpy():
    space = EmbeddingSpace(dim=64, seed=7)
    caches = {}
    for backend in ("numpy", "kernel"):
        cache = SemanticCache(CacheConfig(capacity=40, dim=64, policy="LRU",
                                          backend=backend, use_pallas=False))
        for i in range(24):
            cache.admit(i, space.content_embedding(i % 5, i)
                        .astype(np.float32))
        caches[backend] = cache
    queries = np.stack([space.content_embedding(j % 5, 100 + j)
                        for j in range(9)]).astype(np.float32)
    rows = [1, 4, 9, 16, 23]
    nc, ns = caches["numpy"].peek_rows(queries, rows)
    kc, ks = caches["kernel"].peek_rows(queries, rows)
    np.testing.assert_array_equal(nc, kc)
    np.testing.assert_allclose(ns, ks, atol=1e-5)


# ------------------------------------------------------- dirty-row journal
def test_dirty_since_semantics():
    store = ResidentStore(8, 4)
    v0 = store.version
    assert store.dirty_since(v0) == set()
    s1 = store.insert(1, np.ones(4, np.float32))
    v1 = store.version
    s2 = store.insert(2, np.full(4, 2, np.float32))
    assert store.dirty_since(v0) == {s1, s2}
    assert store.dirty_since(v1) == {s2}
    assert store.dirty_since(store.version) == set()
    # a stamp this store never held (e.g. a diverged copy's) is refused
    assert store.dirty_since(store.version + 1) is None
    assert store.dirty_since(v0 - 1) is None
    # remove() journals too
    store.remove(1)
    assert store.dirty_since(v1) == {s1, s2}


def test_dirty_since_diverged_copy_refused():
    import copy
    store = ResidentStore(8, 4)
    store.insert(1, np.ones(4, np.float32))
    twin = copy.deepcopy(store)
    store.insert(2, np.full(4, 2, np.float32))   # diverge original
    twin.insert(3, np.full(4, 3, np.float32))    # diverge copy
    assert twin.dirty_since(store.version) is None
    assert store.dirty_since(twin.version) is None


@pytest.mark.slow_mesh
def test_sharded_incremental_sync_in_subprocess():
    """Mesh path: after the first full upload, small mutations reach the
    device slab via a dirty-row scatter — and lookups stay bit-identical
    to the numpy backend."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = """
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=4").strip()
import numpy as np
from repro.cache import NumpyBackend, ShardedKernelBackend, ShardedStore
rng = np.random.default_rng(2)
store = ShardedStore(300, 64, n_shards=4)
embs = rng.standard_normal((240, 64)).astype(np.float32)
embs /= np.linalg.norm(embs, axis=1, keepdims=True)
for i in range(200):
    store.insert(i, embs[i])
q = rng.standard_normal((32, 64)).astype(np.float32)
q /= np.linalg.norm(q, axis=1, keepdims=True)
sb = ShardedKernelBackend(n_shards=4, use_pallas=False)
assert sb.mesh() is not None
nb = NumpyBackend()
def check():
    nc, ns = nb.top1_batch(store, q)
    sc, ss = sb.top1_batch(store, q)
    np.testing.assert_array_equal(nc, sc)
    np.testing.assert_allclose(ns, ss, atol=1e-5)
check()
assert sb.sync_stats["full"] == 1 and sb.sync_stats["incremental"] == 0
store.remove(7)
store.insert(201, embs[201])
check()                                   # 2 dirty rows -> scatter
store.remove(90); store.remove(91); store.insert(202, embs[202])
check()
assert sb.sync_stats["full"] == 1, sb.sync_stats
# slot reuse dedupes (remove+insert can hit the same row), so the scatter
# moves between 1 row (all reused) and 5 (all distinct) across both syncs
assert sb.sync_stats["incremental"] == 2, sb.sync_stats
assert 2 <= sb.sync_stats["rows"] <= 5, sb.sync_stats
print("OK")
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(repo, "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
