"""Paper Figure 5: parameter sensitivity at 10% capacity — routing
threshold τ, TP decay α, structural weight λ."""
from __future__ import annotations

import numpy as np

from repro.core import SynthConfig, synthetic_trace
from repro.core.rac import make_rac

from .common import N_SEEDS, TRACE_LEN, Timer, emit, run_setting, save_json

SWEEPS = {
    "tau_route": [0.35, 0.45, 0.55, 0.65, 0.75, 0.85],
    "alpha": [0.0001, 0.0003, 0.001, 0.003, 0.01, 0.03],
    "lam": [0.0, 0.5, 1.0, 2.0, 4.0, 8.0],
}


def run(seeds=None):
    traces = []
    for seed in range(seeds or N_SEEDS):
        tr = synthetic_trace(SynthConfig(trace_len=TRACE_LEN, seed=seed))
        traces.append((tr, max(8, int(0.10 * tr.meta["unique"]))))
    results = {}
    for pname, values in SWEEPS.items():
        curve = {}
        for v in values:
            hits = []
            for tr, cap in traces:
                fac = {f"RAC[{pname}={v}]": make_rac(**{pname: v})}
                hits.append(next(iter(
                    run_setting(tr, cap, fac).values())).hit_ratio)
            curve[str(v)] = float(np.mean(hits))
        results[pname] = curve
    return results


def main():
    with Timer() as t:
        res = run()
    for pname, curve in res.items():
        best = max(curve, key=curve.get)
        worst = min(curve, key=curve.get)
        emit(f"fig5/{pname}", t.us / len(res),
             f"best {pname}={best} hr={curve[best]:.4f}; "
             f"worst {pname}={worst} hr={curve[worst]:.4f}")
    save_json("fig5.json", res)
    return res


if __name__ == "__main__":
    main()
