"""Decision-path benchmark: fused batched replay vs the per-request loop.

Replays one semantic-mode RAC sweep two ways and measures wall time:

  - **legacy**: ``run_policy`` — one backend Top-1 call per request (the
    historical host round-trip per arrival);
  - **fused**: ``run_policy_batched`` — ONE fused ``decide_batch`` launch
    per chunk (hit Top-1 + routing + victim scoring over the
    device-mirrored PolicyTable), with the exact incremental rescore
    closing the snapshot gap, swept over chunk sizes.

Because the batched replay is now *exact*, the two paths must produce
bit-identical hit/miss/eviction counts — asserted on every row, so the
speedup is measured between decision-equivalent runs (same trajectory,
same evictions), not merely similar ones.

The legacy baseline runs twice, bracketing the fused chunk sweep, and the
speedup compares against the *mean* of the two — shared boxes throttle
over a multi-minute benchmark, and an A/B layout that always runs one
mode first would hand that mode the cool-CPU advantage.

    PYTHONPATH=src python -m benchmarks.decision_path_bench
    PYTHONPATH=src python -m benchmarks.decision_path_bench --smoke

Env knobs: BENCH_DECISION_LEN (default 50000 requests).
"""
from __future__ import annotations

import argparse
import os

from repro.core import SynthConfig, run_policy, run_policy_batched, \
    synthetic_trace
from repro.core.rac import make_rac

from .common import emit, save_json

N_REQUESTS = int(os.environ.get("BENCH_DECISION_LEN", "50000"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--backend", default="kernel",
                    choices=["numpy", "kernel", "sharded"],
                    help="kernel (default) measures the device decision "
                         "path, where the per-request loop pays one "
                         "dispatch per arrival; numpy measures the host "
                         "slab-scan engines")
    ap.add_argument("--chunks", default="64,512,4096")
    ap.add_argument("--pallas", action="store_true",
                    help="use the Pallas kernels (device path) instead of "
                         "the jnp oracles under kernel/sharded backends")
    args = ap.parse_args(argv)
    n = args.requests or (2000 if args.smoke else N_REQUESTS)
    chunks = [int(c) for c in args.chunks.split(",") if c]
    trace = synthetic_trace(SynthConfig(trace_len=n, seed=0))
    cap = max(64, int(0.1 * trace.meta["unique"]))

    def legacy_run():
        return run_policy(trace, cap, make_rac(), hit_mode="semantic",
                          backend=args.backend, use_pallas=args.pallas,
                          name="RAC")

    legacy = legacy_run()
    ref = (legacy.hits, legacy.misses, legacy.evictions)
    rows = [{"mode": "legacy_per_request", "chunk": 1,
             "wall_s": legacy.wall_s, "hits": legacy.hits,
             "evictions": legacy.evictions,
             "us_per_request": 1e6 * legacy.wall_s / n}]
    emit(f"decision_path/legacy/{args.backend}",
         rows[0]["us_per_request"],
         f"wall={legacy.wall_s:.2f}s,hits={legacy.hits}")

    best = None
    for chunk in chunks:
        s = run_policy_batched(trace, cap, make_rac(), hit_mode="semantic",
                               backend=args.backend, chunk=chunk,
                               use_pallas=args.pallas, name="RAC")
        assert (s.hits, s.misses, s.evictions) == ref, \
            f"fused chunk={chunk} diverged from the exact replay: " \
            f"{(s.hits, s.misses, s.evictions)} != {ref}"
        rows.append({"mode": "fused", "chunk": chunk, "wall_s": s.wall_s,
                     "hits": s.hits, "evictions": s.evictions,
                     "us_per_request": 1e6 * s.wall_s / n})
        best = min(best, s.wall_s) if best is not None else s.wall_s
        emit(f"decision_path/fused/{args.backend}/chunk{chunk}",
             rows[-1]["us_per_request"],
             f"wall={s.wall_s:.2f}s,exact=1")

    legacy2 = legacy_run()                   # drift bracket (see docstring)
    rows.append({"mode": "legacy_per_request", "chunk": 1,
                 "wall_s": legacy2.wall_s, "hits": legacy2.hits,
                 "evictions": legacy2.evictions,
                 "us_per_request": 1e6 * legacy2.wall_s / n})
    emit(f"decision_path/legacy2/{args.backend}",
         rows[-1]["us_per_request"], f"wall={legacy2.wall_s:.2f}s")
    legacy_wall = 0.5 * (legacy.wall_s + legacy2.wall_s)
    speedup = legacy_wall / max(best, 1e-9)
    emit(f"decision_path/speedup/{args.backend}", 0.0,
         f"fused_over_legacy={speedup:.2f}x,requests={n}")
    save_json("decision_path_bench.json",
              {"backend": args.backend, "requests": n, "capacity": cap,
               "rows": rows, "speedup": speedup})
    return rows


if __name__ == "__main__":
    main()
