"""Paper Figure 2: hit ratio under the two synthetic stress axes.

(a) long-reuse-distance ratio sweep 50%..90% (γ=0.7 fixed)
(b) Zipf exponent sweep γ ∈ {0.7..1.2}  (long-reuse 50% fixed)

Capacity 10% of the unique footprint (paper §4.2 RQ1 configuration).
All policies replay through the one-pass multi-policy arena (bit-identical
decisions to sequential replay; ``BENCH_ARENA=0`` reverts).

``--smoke``: tiny trace (1500 requests), 2 seeds — the CI configuration.
"""
from __future__ import annotations

import sys

from repro.core import SynthConfig, synthetic_trace

from .common import (N_SEEDS, TRACE_LEN, Timer, agg, emit, factories,
                     gains, run_setting, save_json)

# smallest length where the long-reuse arm actually fires (shorter traces
# are identical across the ratio sweep, which defeats the smoke's purpose)
SMOKE_TRACE_LEN = 1500
SMOKE_SEEDS = 2


def reuse_distance(trace_len=None, seeds=None):
    results = {}
    for ratio in (0.5, 0.6, 0.7, 0.8, 0.9):
        rows = []
        for seed in range(seeds or N_SEEDS):
            tr = synthetic_trace(SynthConfig(
                trace_len=trace_len or TRACE_LEN, seed=seed,
                long_reuse_ratio=ratio, zipf_gamma=0.7))
            cap = max(8, int(0.10 * tr.meta["unique"]))
            rows.append(run_setting(tr, cap, factories()))
        m = agg(rows)
        results[f"long={ratio}"] = {"means": m, **gains(m)}
    return results


def zipf_skew(trace_len=None, seeds=None):
    results = {}
    for gamma in (0.7, 0.8, 0.9, 1.0, 1.1, 1.2):
        rows = []
        for seed in range(seeds or N_SEEDS):
            tr = synthetic_trace(SynthConfig(
                trace_len=trace_len or TRACE_LEN, seed=seed,
                long_reuse_ratio=0.5, zipf_gamma=gamma))
            cap = max(8, int(0.10 * tr.meta["unique"]))
            rows.append(run_setting(tr, cap, factories()))
        m = agg(rows)
        results[f"gamma={gamma}"] = {"means": m, **gains(m)}
    return results


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    smoke = "--smoke" in argv
    tl = SMOKE_TRACE_LEN if smoke else None
    seeds = SMOKE_SEEDS if smoke else None
    suffix = "_smoke" if smoke else ""
    with Timer() as t:
        ra = reuse_distance(trace_len=tl, seeds=seeds)
    for k, v in ra.items():
        emit(f"fig2a/{k}", t.us / len(ra),
             f"rac={v['rac']:.4f} best={v['best_baseline']:.4f} "
             f"gain={100*v['gain_vs_best']:+.1f}%")
    save_json(f"fig2a{suffix}.json", ra)
    with Timer() as t:
        rb = zipf_skew(trace_len=tl, seeds=seeds)
    for k, v in rb.items():
        emit(f"fig2b/{k}", t.us / len(rb),
             f"rac={v['rac']:.4f} best={v['best_baseline']:.4f} "
             f"gain={100*v['gain_vs_best']:+.1f}%")
    save_json(f"fig2b{suffix}.json", rb)
    return {"fig2a": ra, "fig2b": rb}


if __name__ == "__main__":
    main()
