"""Benchmark entrypoint — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Full result tables land in
``bench_results/*.json`` (consumed by EXPERIMENTS.md).

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig2 fig4  # subset
    PYTHONPATH=src python -m benchmarks.run --list     # registered names
    PYTHONPATH=src python -m benchmarks.run --tracker jsonl:bench_results/run.jsonl serving_async
Env knobs: BENCH_SEEDS (default 3), BENCH_TRACE_LEN (default 10000),
BENCH_ARENA (default 1: fig sweeps run the one-pass multi-policy arena),
BENCH_TRACKER (telemetry sink spec; ``--tracker`` overrides it).
"""
from __future__ import annotations

import sys

from . import (cache_api_bench, common, decision_path_bench, faithfulness,
               fig1_example, fig2_stress, fig3_real, fig4_ablation,
               fig5_sensitivity, fused_pipeline_bench, kernel_bench, overhead,
               policy_arena_bench, quantized_lookup_bench, roofline,
               serving_async_bench, sharded_lookup_bench,
               telemetry_overhead_bench, tiered_cache_bench)

SUITES = {
    "fig1": fig1_example.main,      # Example 1 / Figure 1 demonstration
    "fig2": lambda: fig2_stress.main([]),  # stress axes (paper Fig. 2a/2b)
    "fig3": fig3_real.main,        # OASST-style capacities (Fig. 3)
    "fig4": fig4_ablation.main,    # TP/TSI ablation (Fig. 4)
    "fig5": fig5_sensitivity.main,  # parameter sensitivity (Fig. 5)
    "faithfulness": faithfulness.main,  # reproduction-decision ablation
    "overhead": overhead.main,     # per-request policy latency
    "kernels": kernel_bench.main,  # Pallas kernel micro-bench
    "roofline": roofline.main,     # dry-run roofline table
    "cache_api": lambda: cache_api_bench.main([]),  # facade lookup throughput
    "sharded": lambda: sharded_lookup_bench.main([]),  # multi-device lookup
    "serving_async": lambda: serving_async_bench.main([]),  # admit slot stall
    "decision": lambda: decision_path_bench.main([]),  # fused vs per-request
    "arena": lambda: policy_arena_bench.main([]),  # multi-policy one-pass
    "tiered": lambda: tiered_cache_bench.main([]),  # device/host/ghost tiers
    "telemetry": lambda: telemetry_overhead_bench.main([]),  # tracker overhead
    "quantized": lambda: quantized_lookup_bench.main([]),  # int8 scan path
    "fused": lambda: fused_pipeline_bench.main([]),  # one-launch decision path
}


def main() -> None:
    argv = sys.argv[1:]
    # --tracker <spec> / --tracker=<spec>: suite-wide telemetry sink
    # (threaded through benchmarks.common.bench_tracker())
    while True:
        hit = next((i for i, a in enumerate(argv)
                    if a == "--tracker" or a.startswith("--tracker=")), None)
        if hit is None:
            break
        if argv[hit] == "--tracker":
            if hit + 1 >= len(argv):
                raise SystemExit("--tracker needs a spec "
                                 "(e.g. jsonl:bench_results/run.jsonl)")
            common.TRACKER_SPEC = argv[hit + 1]
            del argv[hit:hit + 2]
        else:
            common.TRACKER_SPEC = argv[hit].split("=", 1)[1]
            del argv[hit]
    if "--list" in argv:
        for name in SUITES:
            print(name)
        return
    picks = [a for a in argv if a in SUITES] or list(SUITES)
    print("name,us_per_call,derived")
    for name in picks:
        SUITES[name]()


if __name__ == "__main__":
    main()
