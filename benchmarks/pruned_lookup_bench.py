"""Topic-pruned two-stage lookup vs the exact full scan.

The tentpole claim: routing each query against the (T, D) topic
representatives and scanning only the top-P probe buckets makes lookup
traffic scale with the *hot* working set instead of total capacity,
while the certify-or-fallback predicate keeps hit/miss decisions
**identical** to the exact path.  This benchmark drives
``KernelBackend.top1_batch`` both ways over one 50k-entry clustered
store (64 topics, OASST-style session locality: hot-topic-skewed
near-duplicate queries plus fresh-direction misses) and reports:

- the decision fingerprint: the hit mask must be identical and every
  hit's (cid, sim) **bit-equal** (certified misses are decision-equal —
  the reported sub-tau sim may come from the candidate set only);
- the rows ledger from ``prune_stats`` — ``rows_exact`` (rows the full
  scan scores) vs ``scanned_rows`` (routing + probed buckets).  The run
  *asserts* a minimum scanned-rows reduction at the default probe width
  (default 3.0x, env ``BENCH_PRUNE_MIN_TRAFFIC``) — CI smoke runs this
  as a regression gate, same pattern as the quantized bench;
- a probe-width sweep P ∈ {1, 2, 4, 8} and the composed pruned+quant
  configuration, whose int8 candidate scan multiplies the byte
  reduction on top of the row reduction;
- measured wall-clock plus the modeled HBM-roof view (``BENCH_HBM_BW``,
  v5e default 819 GB/s).  On the CPU oracle path the modeled numbers
  are the headline; on a real accelerator the measured ones are.

Every row also lands as a ``lookup_scan`` JSONL record (with
``path`` ∈ {exact, pruned, pruned+quant}) in
``bench_results/lookup_scan.jsonl``; ``benchmarks.roofline`` renders
them in the same unified table as the quantized bench's rows.

    PYTHONPATH=src python -m benchmarks.pruned_lookup_bench
    PYTHONPATH=src python -m benchmarks.pruned_lookup_bench --smoke
    PYTHONPATH=src python -m benchmarks.pruned_lookup_bench --pallas
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from .common import OUT_DIR, emit, save_json

# the same HBM roof the dry-run roofline models (v5e: 819 GB/s/chip)
HBM_BW = float(os.environ.get("BENCH_HBM_BW", 819e9))
MIN_TRAFFIC = float(os.environ.get("BENCH_PRUNE_MIN_TRAFFIC", "3.0"))

N_ENTRIES = 50_000
DIM = 128
N_QUERIES = 256
N_TOPICS = 64
N_HOT = 4          # topics the query stream concentrates on
TAU = 0.85
PROBES = (1, 2, 4, 8)


def _unit(x):
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


def _fill_clustered(n: int, dim: int, n_topics: int):
    """A topically clustered store + its routing surface: ``n`` unit rows
    in ``n_topics`` tight clusters (sigma such that intra-topic spread
    stays well under the cross-topic gap — the regime where routing
    margins certify), with a :class:`PolicyTable` holding the exact
    cluster centers as representatives and the true memberships."""
    from repro.core import ResidentStore
    from repro.core.policy_table import PolicyTable
    rng = np.random.default_rng(7)
    centers = _unit(rng.standard_normal((n_topics, dim)).astype(np.float32))
    assign = rng.integers(0, n_topics, size=n)
    embs = _unit(centers[assign]
                 + 0.027 * rng.standard_normal((n, dim)).astype(np.float32)
                 ).astype(np.float32)
    store = ResidentStore(n, dim)
    for i in range(n):
        store.insert(i, embs[i])
    table = PolicyTable(store.emb.shape[0], dim)
    for t in range(n_topics):
        table.set_rep(t, centers[t])
    for slot in range(n):
        table.topic_of[slot] = assign[slot]
        table.touch_slot(slot)
    return store, table, embs, assign


def _queries(embs: np.ndarray, assign: np.ndarray, n_q: int,
             n_topics: int):
    """Hot-topic-skewed stream: half near-duplicates of residents from
    ``N_HOT`` hot topics (certified hits, high bucket reuse across the
    batch — the session-locality shape the KV-cache-in-the-wild study
    reports), half fresh directions (certain misses under tau)."""
    rng = np.random.default_rng(13)
    dim = embs.shape[1]
    hot = rng.choice(n_topics, size=N_HOT, replace=False)
    hot_rows = np.flatnonzero(np.isin(assign, hot))
    base = embs[rng.choice(hot_rows, size=n_q)]
    near = base + 0.005 * rng.standard_normal((n_q, dim)).astype(np.float32)
    fresh = _unit(rng.standard_normal((n_q, dim)).astype(np.float32))
    q = np.where((np.arange(n_q) % 2 == 0)[:, None], near, fresh)
    return _unit(q).astype(np.float32)


def _time(fn, repeats: int) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _dispatch_delta(fn):
    """Run ``fn`` once and return the launch/sync/kernel-interval deltas
    it cost (``repro.kernels.ops.dispatch_stats`` is process-global)."""
    from repro.kernels import ops
    before = dict(ops.dispatch_stats)
    fn()
    return {k: ops.dispatch_stats[k] - before[k] for k in before}


def _fingerprint(tau, c0, s0, c1, s1):
    """Decision parity: identical hit mask, bit-equal (cid, sim) on
    hits.  Certified misses are decision-equal only — their reported
    best-so-far may come from the probed candidate set."""
    hit0 = s0 >= tau
    np.testing.assert_array_equal(hit0, s1 >= tau)
    np.testing.assert_array_equal(c0[hit0], c1[hit0])
    np.testing.assert_array_equal(s0[hit0], s1[hit0])


def bench_pair(n: int, dim: int, probes: int, tau: float, use_pallas: bool,
               repeats: int, n_q: int = N_QUERIES,
               quantized: bool = False) -> dict:
    """One exact-vs-pruned cell; asserts the decision fingerprint and
    returns the measured + modeled throughput row."""
    from repro.cache import KernelBackend
    from repro.cache.pruned import new_prune_stats
    store, table, embs, assign = _fill_clustered(n, dim, N_TOPICS)
    queries = _queries(embs, assign, n_q, N_TOPICS)

    ex = KernelBackend(use_pallas=use_pallas)
    kw = {"quantized": {"k": 8, "tau_hit": tau}} if quantized else {}
    pr = KernelBackend(use_pallas=use_pallas,
                       pruned={"probes": probes, "tau_hit": tau}, **kw)
    pr.route_table = table          # what the facade wires from the policy
    pr.route_store = store
    c0, s0 = ex.top1_batch(store, queries)          # warm (jit + upload)
    c1, s1 = pr.top1_batch(store, queries)
    _fingerprint(tau, c0, s0, c1, s1)

    t_exact = _time(lambda: ex.top1_batch(store, queries), repeats)
    pr.prune_stats.update(new_prune_stats())
    t_pruned = _time(lambda: pr.top1_batch(store, queries), repeats)
    disp = _dispatch_delta(lambda: pr.top1_batch(store, queries))

    st = pr.prune_stats
    per_scan_p = st["bytes_scanned"] / st["scans"]
    per_scan_e = st["bytes_exact"] / st["scans"]
    rows_ratio = st["rows_exact"] / max(1, st["scanned_rows"])
    path = "pruned+quant" if quantized else "pruned"
    row = {
        "path": path,
        "n": n, "dim": dim, "probes": probes, "tau": tau,
        "k": 8 if quantized else None,
        "pallas": use_pallas, "queries": n_q,
        "rows_per_query": st["scanned_rows"] / st["queries"],
        "rows_ratio": rows_ratio,
        "t_exact_s": t_exact, "t_pruned_s": t_pruned,
        "speedup": t_exact / t_pruned,
        "bytes_exact": per_scan_e, "bytes_scanned": per_scan_p,
        "traffic_ratio": per_scan_e / per_scan_p,
        "fallback_rate": st["fallbacks"] / st["queries"],
        "probed_topics": st["probed_topics"] / st["queries"],
        # measured: bytes the path actually moved per second of scan
        "gbps_exact": per_scan_e / t_exact / 1e9,
        "gbps_pruned": per_scan_p / t_pruned / 1e9,
        # effective: fp32-equivalent bytes served per second of scan
        "effective_gbps": per_scan_e / t_pruned / 1e9,
        # modeled at the HBM roof: what a memory-bound device pays
        "t_exact_roof_s": per_scan_e / HBM_BW,
        "t_pruned_roof_s": per_scan_p / HBM_BW,
        "hbm_bw": HBM_BW,
        # dispatch ledger for one batch pass: jitted launches, blocking
        # device→host syncs, and seconds inside the timed kernel
        # intervals (the roofline renders the kernel-interval roof view
        # from t_kernel_s)
        "launches": disp["launches"],
        "host_syncs": disp["host_syncs"],
        "t_kernel_s": disp["kernel_s"],
    }
    emit(f"pruned_lookup/n={n}/{path}/p={probes}",
         1e6 * t_pruned / n_q,
         f"rows/q={row['rows_per_query']:.0f}({rows_ratio:.1f}x),"
         f"traffic={row['traffic_ratio']:.2f}x,"
         f"fallback={100 * row['fallback_rate']:.1f}%,"
         f"eff={row['effective_gbps']:.1f}GB/s")
    return row


def exact_row(n: int, dim: int, use_pallas: bool, repeats: int,
              n_q: int = N_QUERIES) -> dict:
    """The exact-path baseline row for the unified roofline table."""
    from repro.cache import KernelBackend
    store, table, embs, assign = _fill_clustered(n, dim, N_TOPICS)
    queries = _queries(embs, assign, n_q, N_TOPICS)
    ex = KernelBackend(use_pallas=use_pallas)
    ex.top1_batch(store, queries)                   # warm
    t_exact = _time(lambda: ex.top1_batch(store, queries), repeats)
    disp = _dispatch_delta(lambda: ex.top1_batch(store, queries))
    # per-scan slab bytes, batch-amortized — the same convention as the
    # quant/prune ledgers' bytes_exact (the slab streams once per batch)
    bytes_e = float(store.hwm) * dim * 4
    row = {
        "path": "exact", "n": n, "dim": dim, "probes": None, "k": None,
        "tau": TAU, "pallas": use_pallas, "queries": n_q,
        "rows_per_query": float(store.hwm), "rows_ratio": 1.0,
        "t_exact_s": t_exact, "speedup": 1.0,
        "bytes_exact": bytes_e, "bytes_scanned": bytes_e,
        "traffic_ratio": 1.0, "fallback_rate": 0.0,
        "gbps_exact": bytes_e / t_exact / 1e9,
        "effective_gbps": bytes_e / t_exact / 1e9,
        "t_exact_roof_s": bytes_e / HBM_BW,
        "hbm_bw": HBM_BW,
        "launches": disp["launches"],
        "host_syncs": disp["host_syncs"],
        "t_kernel_s": disp["kernel_s"],
    }
    emit(f"pruned_lookup/n={n}/exact", 1e6 * t_exact / n_q,
         f"rows/q={row['rows_per_query']:.0f},"
         f"eff={row['effective_gbps']:.1f}GB/s")
    return row


def _append_jsonl(rows: list[dict]) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "lookup_scan.jsonl")
    with open(path, "a") as f:
        for r in rows:
            f.write(json.dumps({"kind": "lookup_scan", **r}) + "\n")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    ap.add_argument("--pallas", action="store_true",
                    help="device scans via the Pallas kernels (interpret "
                         "mode on CPU — slow; default is the jnp oracle)")
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args(argv)
    n = 8_000 if args.smoke else N_ENTRIES
    n_q = 64 if args.smoke else N_QUERIES
    repeats = args.repeats or (2 if args.smoke else 5)
    probes = (1, 2) if args.smoke else PROBES

    rows = [exact_row(n, DIM, args.pallas, repeats, n_q=n_q)]
    rows += [bench_pair(n, DIM, p, TAU, args.pallas, repeats, n_q=n_q)
             for p in probes]
    rows.append(bench_pair(n, DIM, 2, TAU, args.pallas, repeats, n_q=n_q,
                           quantized=True))

    # regression gate on the default-probe-width (P=2) cell: routing must
    # keep lookup cost bound to the probed buckets.  rows_ratio is the
    # gated metric (bucket rows scored vs full-slab rows) — a predicate
    # regression shows up as exact full-scan fallbacks, which count every
    # slab row back into scanned_rows and drag the ratio down immediately.
    gate = next(r for r in rows if r["path"] == "pruned"
                and r["probes"] == 2)
    assert gate["rows_ratio"] >= MIN_TRAFFIC, (
        f"pruned scan rows reduction {gate['rows_ratio']:.2f}x fell below "
        f"the {MIN_TRAFFIC:.1f}x floor (BENCH_PRUNE_MIN_TRAFFIC)")

    _append_jsonl(rows)
    save_json("pruned_lookup.json",
              {"rows": rows, "hbm_bw": HBM_BW,
               "min_traffic": MIN_TRAFFIC, "smoke": args.smoke})
    return rows


if __name__ == "__main__":
    main()
