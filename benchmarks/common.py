"""Shared benchmark plumbing: policy sets, timed runs, CSV/JSON output.

All runs route through the unified ``repro.cache.SemanticCache`` facade
(via ``run_policy`` / ``run_policy_batched``); ``backend=`` selects the
numpy slab scan or the device ``sim_top1`` kernel path.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core import (OASSTConfig, SynthConfig, default_factories,
                        oasst_style_trace, run_many, run_policy,
                        run_policy_batched, synthetic_trace)

OUT_DIR = os.environ.get("BENCH_OUT", "bench_results")
N_SEEDS = int(os.environ.get("BENCH_SEEDS", "3"))
TRACE_LEN = int(os.environ.get("BENCH_TRACE_LEN", "10000"))
# one-pass multi-policy arena (decisions are bit-identical to the
# sequential replays); BENCH_ARENA=0 restores the per-policy loop
USE_ARENA = os.environ.get("BENCH_ARENA", "1") != "0"
# telemetry sink spec for bench runs ("memory", "jsonl:<path>", ...);
# settable via the env or ``run.py --tracker``.  Empty = telemetry off.
TRACKER_SPEC = os.environ.get("BENCH_TRACKER", "")


def bench_tracker():
    """Build the suite-wide tracker from ``TRACKER_SPEC`` (None when
    telemetry is off) — benchmarks attach it to caches/engines so a
    whole run's metrics land in one sink."""
    from repro.telemetry import make_tracker
    return make_tracker(TRACKER_SPEC or None)

PAPER_BASELINES = ["FIFO", "LRU", "CLOCK", "TTL", "TinyLFU", "ARC",
                   "S3-FIFO", "SIEVE", "2Q", "LHD", "LeCaR"]


def factories(include_belady=True, seed=None):
    return default_factories(include_belady=include_belady, seed=seed)


def run_setting(trace, capacity, facs, hit_mode="content",
                backend="numpy", batched=False, chunk=512,
                use_pallas=True, arena=None, seed=None):
    """Run every factory under one setting -> {name: Stats}.

    ``arena=None`` defers to the ``BENCH_ARENA`` env toggle (default on):
    the whole dict replays in ONE trace pass through
    :func:`repro.core.arena.run_arena`.  Sequential mode honors
    ``batched=True`` for BOTH hit modes — content-mode runs route through
    ``run_policy_batched`` as well (it delegates internally), so the flag
    is never silently dropped."""
    if arena is None:
        arena = USE_ARENA
    if arena:
        stats = run_many(trace, capacity, facs, arena=True,
                         hit_mode=hit_mode, backend=backend, chunk=chunk,
                         use_pallas=use_pallas, seed=seed)
        return dict(zip(facs.keys(), stats))
    out = {}
    for name, f in facs.items():
        if batched:
            s = run_policy_batched(trace, capacity, f, name=name,
                                   hit_mode=hit_mode, backend=backend,
                                   chunk=chunk, use_pallas=use_pallas,
                                   seed=seed)
        else:
            s = run_policy(trace, capacity, f, name=name, hit_mode=hit_mode,
                           backend=backend, use_pallas=use_pallas, seed=seed)
        out[name] = s
    return out


def agg(rows: list[dict]) -> dict:
    """mean over seeds: {policy: mean hit_ratio}."""
    keys = rows[0].keys()
    return {k: float(np.mean([r[k].hit_ratio for r in rows])) for k in keys}


def gains(means: dict) -> dict:
    base = {k: v for k, v in means.items()
            if k in PAPER_BASELINES}
    best = max(base.values())
    avg = float(np.mean(list(base.values())))
    rac = means.get("RAC", float("nan"))
    return {"best_baseline": best, "avg_baseline": avg,
            "rac": rac,
            "gain_vs_best": rac / best - 1 if best else float("nan"),
            "gain_vs_avg": rac / avg - 1 if avg else float("nan")}


def emit(name: str, wall_us: float, derived: str):
    print(f"{name},{wall_us:.1f},{derived}", flush=True)


def save_json(fname: str, obj):
    """Write ``OUT_DIR/fname`` plus a timestamped copy under
    ``OUT_DIR/history/`` so successive runs (and CI artifacts) keep every
    result instead of overwriting the last one."""
    os.makedirs(OUT_DIR, exist_ok=True)
    payload = json.dumps(obj, indent=1)
    with open(os.path.join(OUT_DIR, fname), "w") as f:
        f.write(payload)
    hist = os.path.join(OUT_DIR, "history")
    os.makedirs(hist, exist_ok=True)
    stamp = time.strftime("%Y%m%dT%H%M%S")
    stem, ext = os.path.splitext(fname)
    with open(os.path.join(hist, f"{stem}-{stamp}{ext or '.json'}"),
              "w") as f:
        f.write(payload)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6
