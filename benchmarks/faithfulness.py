"""Reproduction-decision ablation: quantifies the three faithfulness
resolutions documented in DESIGN.md §6 / EXPERIMENTS §Paper-claims:

  1. lifetime vs residency-scoped freq/dep metadata (Def. 2 "so far"),
  2. persistent vs deleted empty-topic TP state (Alg. 2 Data vs Alg. 5),
  3. normalized (π·p derivation) vs literal Eq. 1 Value.

Run:  PYTHONPATH=src python -m benchmarks.run faithfulness
"""
from __future__ import annotations

import numpy as np

from repro.core import SynthConfig, synthetic_trace
from repro.core.policies import LRUPolicy
from repro.core.rac import make_rac

from .common import N_SEEDS, TRACE_LEN, Timer, emit, run_setting, save_json


def run(seeds=None):
    variants = {
        "RAC (full: lifetime+topicmem+normalized)": make_rac(),
        "RAC value_mode=paper (Eq.1 literal)": make_rac(value_mode="paper"),
        "RAC no topic memory (Alg.5 literal)": make_rac(topic_memory=False),
        "RAC Eq.1 + no topic memory": make_rac(value_mode="paper",
                                               topic_memory=False),
        "LRU (reference)": lambda c, s: LRUPolicy(c, s),
    }
    rows = []
    for seed in range(seeds or N_SEEDS):
        tr = synthetic_trace(SynthConfig(trace_len=TRACE_LEN, seed=seed))
        cap = max(8, int(0.10 * tr.meta["unique"]))
        rows.append(run_setting(tr, cap, variants))
    return {k: float(np.mean([r[k].hit_ratio for r in rows]))
            for k in variants}


def main():
    with Timer() as t:
        res = run()
    for k, v in sorted(res.items(), key=lambda kv: -kv[1]):
        emit(f"faithfulness/{k}", t.us / len(res), f"hit_ratio={v:.4f}")
    save_json("faithfulness.json", res)
    return res


if __name__ == "__main__":
    main()
