"""Tiered cache hierarchy benchmark: single-tier vs device+host+ghost.

Replays Fig. 3-style OASST traces through the :class:`SemanticCache`
facade at device capacities 5% / 10% / 20% of the unique footprint, with
a host DRAM tier sized 4x the device slab (and a ghost tier sized like
the host tier).  Reports, per capacity:

  - **hit_ratio** — single-tier vs tiered (host-tier hits are real hits:
    the payload is served from host DRAM and the entry promoted back
    through the admission path);
  - **admit_stall_s** — producer-visible admission blocking.  The tiered
    run admits more (every promotion re-enters the admission path), so it
    is measured both blocking and with the async admitter, where the
    promotion cost leaves the request path;
  - tier flow counters (demotions, promotions, host hits, ghost revivals).

    PYTHONPATH=src python -m benchmarks.tiered_cache_bench
    PYTHONPATH=src python -m benchmarks.tiered_cache_bench --smoke
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.cache import CacheConfig, SemanticCache, TierConfig
from repro.core import OASSTConfig, oasst_style_trace

from .common import N_SEEDS, TRACE_LEN, emit, save_json

HOST_FACTOR = 4          # host tier rows per device row (the paper's DRAM
                         # tier is an order of magnitude over HBM; 4x keeps
                         # the benchmark's working set realistic)


def replay(trace, capacity: int, tiers: TierConfig | None,
           async_admit=False) -> dict:
    cache = SemanticCache(CacheConfig(
        capacity=capacity, dim=trace.requests[0].emb.shape[0],
        tau_hit=0.85, hit_mode="semantic", policy="RAC",
        async_admit=async_admit, tiers=tiers))
    t0 = time.perf_counter()
    for req in trace.requests:
        r = cache.lookup(req.emb, cid=req.cid, t=req.t, req=req)
        if not r.hit:
            cache.admit(req.cid, req.emb, payload=[req.cid], t=req.t)
    cache.flush()
    wall = time.perf_counter() - t0
    m = cache.metrics
    row = {"hit_ratio": m.hit_ratio, "hits": m.hits, "misses": m.misses,
           "evictions": m.evictions, "admit_stall_s": cache.admit_stall_s,
           "wall_s": wall, **cache.tier_stats}
    cache.close()
    return row


def run(capacity_fracs=(0.05, 0.10, 0.20), n_traces=None, trace_len=None):
    n = n_traces or N_SEEDS
    tl = trace_len or TRACE_LEN
    traces = [oasst_style_trace(OASSTConfig(trace_len=tl, seed=s))
              for s in range(n)]
    results = {}
    for frac in capacity_fracs:
        rows = {"single": [], "tiered": [], "tiered_async": []}
        for tr in traces:
            cap = max(8, int(frac * tr.meta["unique"]))
            tiers = TierConfig(host_capacity=HOST_FACTOR * cap,
                               ghost_capacity=HOST_FACTOR * cap)
            rows["single"].append(replay(tr, cap, None))
            rows["tiered"].append(replay(tr, cap, tiers))
            rows["tiered_async"].append(replay(tr, cap, tiers,
                                               async_admit=True))
        mean = {mode: {k: float(np.mean([r[k] for r in rs]))
                       for k in rs[0]}
                for mode, rs in rows.items()}
        single, tiered = mean["single"], mean["tiered"]
        results[f"cap={frac}"] = {
            **{mode: m for mode, m in mean.items()},
            "hit_gain": tiered["hit_ratio"] - single["hit_ratio"],
            "stall_ratio_async": (mean["tiered_async"]["admit_stall_s"]
                                  / max(tiered["admit_stall_s"], 1e-9)),
        }
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    ap.add_argument("--traces", type=int, default=None)
    ap.add_argument("--trace-len", type=int, default=None)
    args = ap.parse_args(argv)
    n = args.traces or (1 if args.smoke else None)
    tl = args.trace_len or (600 if args.smoke else None)
    res = run(n_traces=n, trace_len=tl)
    for k, v in res.items():
        emit(f"tiered/{k}", 1e6 * v["tiered"]["wall_s"],
             f"hr_single={v['single']['hit_ratio']:.4f} "
             f"hr_tiered={v['tiered']['hit_ratio']:.4f} "
             f"gain={v['hit_gain']:+.4f} "
             f"promotions={v['tiered']['promotions']:.0f} "
             f"async_stall_ratio={v['stall_ratio_async']:.2f}")
    save_json("tiered_cache_bench.json", res)
    # the tiered hierarchy must never lose hits: every single-tier hit is
    # still a hit (host tier only adds a fall-through level)
    for k, v in res.items():
        assert v["tiered"]["hit_ratio"] >= v["single"]["hit_ratio"], k
    return res


if __name__ == "__main__":
    main()
